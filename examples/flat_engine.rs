//! Flat-blob parallel optimizer engine demo — runs entirely on the host,
//! no AOT artifacts needed.
//!
//! ```sh
//! cargo run --release --example flat_engine
//! ```
//!
//! What happens: a model-shaped layout (embed, layers, head + AdaLomo's
//! factored state) is packed into one flat f32 blob exactly as the runtime
//! manifest would; `FlatOptimizer` then steps the blob in place, walking
//! segments in fused-backward order and sharding the work across scoped
//! worker threads. The demo verifies parity against the per-tensor
//! `ParamOpt` path, then races the two shard plans across worker counts.

use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode,
};
use adalomo::optim::{pool, OptKind, ParamOpt};
use adalomo::runtime::HostBlob;
use adalomo::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let d = 128;
    let params: Vec<(String, Vec<usize>)> = {
        let mut p = vec![("embed".to_string(), vec![256, d])];
        for l in 0..4 {
            p.push((format!("l{l}.attn_norm"), vec![d]));
            for w in ["wq", "wk", "wv", "wo"] {
                p.push((format!("l{l}.{w}"), vec![d, d]));
            }
            p.push((format!("l{l}.ffn_norm"), vec![d]));
            p.push((format!("l{l}.w_gate"), vec![d, 2 * d]));
            p.push((format!("l{l}.w_up"), vec![d, 2 * d]));
            p.push((format!("l{l}.w_down"), vec![2 * d, d]));
        }
        p.push(("final_norm".to_string(), vec![d]));
        p.push(("head".to_string(), vec![d, 256]));
        p
    };
    let specs: Vec<(&str, &[usize])> =
        params.iter().map(|(n, s)| (n.as_str(), s.as_slice())).collect();
    let kind = OptKind::AdaLomo;
    let layout = synthetic_layout(kind, &specs);
    println!(
        "layout: {} segments, {} trainable floats, {} state floats",
        layout.segments.len(),
        layout.params_len,
        layout.metrics_offset() - layout.params_len,
    );

    let (blob0, grads) = seeded_blob_and_grads(&layout, 9);

    // The engine walks segments in fused-backward order (head first,
    // layers in reverse, embedding last) — same schedule as the fused
    // group programs in coordinator/fused.rs.
    let engine = FlatOptimizer::new(kind, &layout, 1, ShardMode::Segments)?;
    let order = engine.task_order();
    println!(
        "fused-backward walk: {} .. {} ({} segments)",
        order.first().unwrap(),
        order.last().unwrap(),
        order.len()
    );

    // Parity: 5 engine steps (through the HostBlob convenience path) vs 5
    // per-tensor ParamOpt steps.
    let steps = 5u64;
    let mut hb = HostBlob::new(blob0.clone(), "synthetic/adalomo", &layout)?;
    let mut engine =
        FlatOptimizer::new(kind, &layout, pool::default_shards(), ShardMode::Contiguous)?;
    for t in 1..=steps {
        engine.step_blob(&mut hb, &grads, t, 1e-2, 0.0)?;
    }
    // Shape-aware zero-copy segment views over the stepped blob.
    for name in ["embed", "head", "embed@r"] {
        let v = hb.segment_view(&layout, name)?;
        println!("  {name}: shape {:?}, rms {:.4e}", v.shape(), v.rms());
    }
    let blob = hb.data;
    let mut worst = 0f32;
    for seg in layout.trainable() {
        let mut theta = Tensor::new(
            &seg.shape,
            blob0[seg.offset..seg.offset + seg.size].to_vec(),
        )?;
        let g = Tensor::new(
            &seg.shape,
            grads[seg.offset..seg.offset + seg.size].to_vec(),
        )?;
        let mut opt = ParamOpt::new(kind, &seg.shape);
        for t in 1..=steps {
            opt.step(&mut theta, &g, t, 1e-2, 0.0);
        }
        for (a, b) in theta
            .data()
            .iter()
            .zip(&blob[seg.offset..seg.offset + seg.size])
        {
            worst = worst.max((a - b).abs());
        }
    }
    println!("parity vs per-tensor ParamOpt after {steps} steps: max |Δ| = {worst:.2e}");
    assert!(worst <= 1e-6, "flat engine diverged from the reference");

    // Throughput: shard plans across worker counts.
    let cores = pool::default_shards();
    let mut shard_counts = vec![1usize, 2, cores];
    shard_counts.sort_unstable();
    shard_counts.dedup();
    println!("\nthroughput ({} hardware threads):", cores);
    for (mode, label) in [
        (ShardMode::Segments, "segments "),
        (ShardMode::Contiguous, "contiguous"),
    ] {
        for &shards in &shard_counts {
            let mut engine = FlatOptimizer::new(kind, &layout, shards, mode)?;
            let mut blob = blob0.clone();
            let mut t = 0u64;
            let iters = 30;
            let t0 = std::time::Instant::now();
            for _ in 0..iters {
                t += 1;
                engine.step(&mut blob, &grads, t, 1e-2, 0.0)?;
            }
            let dt = t0.elapsed().as_secs_f64() / iters as f64;
            println!(
                "  {label} x{shards}: {:8.2}ms/step  ({:.0} Mfloat/s)",
                dt * 1e3,
                layout.params_len as f64 / dt / 1e6
            );
        }
    }
    Ok(())
}
