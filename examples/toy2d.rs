//! Paper Appendix A / Fig. 6: optimizer trajectories on
//! f(x,y) = x² + y² − 2e^{−5[(x−1)²+y²]} − 3e^{−5[(x+1)²+y²]}.
//!
//! Renders an ASCII phase portrait: from the same start, SGD and
//! SGD+momentum descend into the local well at (+1, 0); SGD+variance and
//! Adam cross to the global optimum at (−1, 0). Both the Rust-native
//! optimizers and (when artifacts exist) the AOT toy2d programs are run —
//! they must agree.

use adalomo::experiments as exp;
use adalomo::optim::OptKind;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    let kinds = [
        (OptKind::Sgd, 's'),
        (OptKind::SgdMomentum, 'm'),
        (OptKind::SgdVariance, 'v'),
        (OptKind::AdamW, 'a'),
    ];
    let (w, h) = (68usize, 24usize);
    let (x0, x1, y0, y1) = (-1.6f32, 1.6f32, -0.35f32, 1.1f32);
    let mut grid = vec![vec![' '; w]; h];
    let mark = |grid: &mut Vec<Vec<char>>, x: f32, y: f32, ch: char| {
        let col = ((x - x0) / (x1 - x0) * (w as f32 - 1.0)).round();
        let row = ((y1 - y) / (y1 - y0) * (h as f32 - 1.0)).round();
        if (0.0..w as f32).contains(&col) && (0.0..h as f32).contains(&row) {
            let (r, c) = (row as usize, col as usize);
            if grid[r][c] == ' ' || grid[r][c] == '.' {
                grid[r][c] = ch;
            }
        }
    };
    // Landscape contour hints: the two wells.
    mark(&mut grid, -1.0, 0.0, 'G');
    mark(&mut grid, 1.0, 0.0, 'L');

    let mut table = Table::new("Fig. 6 — final positions")
        .header(&["optimizer", "glyph", "x", "y", "f", "basin"]);
    for (kind, ch) in kinds {
        let traj = exp::toy2d_trajectory(
            kind,
            exp::TOY2D_LR,
            exp::TOY2D_STEPS,
            exp::TOY2D_START,
        );
        for p in &traj {
            mark(&mut grid, p.0, p.1, ch);
        }
        let last = traj.last().unwrap();
        table.row(vec![
            kind.name().into(),
            ch.to_string(),
            fnum(last.0 as f64),
            fnum(last.1 as f64),
            fnum(last.2 as f64),
            exp::toy2d_basin(&traj).into(),
        ]);
    }
    mark(&mut grid, exp::TOY2D_START.0, exp::TOY2D_START.1, '+');

    println!(
        "start '+' at {:?}; wells: G = global (-1,0), L = local (+1,0)\n",
        exp::TOY2D_START
    );
    for row in &grid {
        println!("  {}", row.iter().collect::<String>());
    }
    println!();
    table.print();

    // Cross-check through the AOT artifacts when available.
    if exp::artifacts_available() {
        let session = exp::open_session()?;
        println!("\nAOT cross-check (toy2d_* artifacts):");
        for (kind, entry) in [
            (OptKind::Sgd, "sgd"),
            (OptKind::SgdMomentum, "sgd_momentum"),
            (OptKind::SgdVariance, "sgd_variance"),
            (OptKind::AdamW, "adamw"),
        ] {
            let layout = session.manifest.layout(&format!("toy2d/{entry}"))?;
            let mut blob = vec![0f32; layout.blob_len];
            blob[0] = exp::TOY2D_START.0;
            blob[1] = exp::TOY2D_START.1;
            let mut buf = session.upload_f32(&blob, &[layout.blob_len])?;
            for t in 1..=exp::TOY2D_STEPS {
                let sched = session.upload_f32(
                    &[exp::TOY2D_LR, t as f32, 0.0, 1.0],
                    &[4],
                )?;
                buf = session
                    .execute_buf(&format!("toy2d_{entry}"), &[&buf, &sched])?;
            }
            let out = session.fetch_f32_raw(&buf, 2)?;
            let native = exp::toy2d_trajectory(
                kind,
                exp::TOY2D_LR,
                exp::TOY2D_STEPS,
                exp::TOY2D_START,
            );
            let nl = native.last().unwrap();
            println!(
                "  {entry:14} AOT ({:+.3}, {:+.3})  native ({:+.3}, {:+.3})  {}",
                out[0],
                out[1],
                nl.0,
                nl.1,
                if (out[0] - nl.0).abs() < 0.05 { "agree" } else { "DISAGREE" }
            );
        }
    }
    Ok(())
}
