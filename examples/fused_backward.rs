//! The fused-backward scheduler (paper §2.1/§3.2) running for real: the
//! train step split into L+2 group programs executed in backward order,
//! with at most one group's weight gradients materialized per program —
//! and the chained result bit-comparable to the monolithic step.
//!
//! ```sh
//! cargo run --release --example fused_backward
//! ```

use adalomo::coordinator::fused;
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::runtime::Manifest;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    if !exp::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let session = exp::open_session()?;
    let (preset, opt) = ("nano", "adalomo");
    let groups = fused::fused_groups(&session, preset, opt)
        .expect("nano fused artifacts");
    let sizes = fused::group_grad_sizes(&session, preset, opt)?;
    let total: usize = sizes.iter().sum();

    let mut t = Table::new(
        "Fused-backward groups (backward order) and their gradient liveness",
    )
    .header(&["group", "contents", "grad floats", "% of model"]);
    for (k, size) in sizes.iter().enumerate() {
        let contents = if k == 0 {
            "head + final_norm".to_string()
        } else if k == groups - 1 {
            "embedding".to_string()
        } else {
            format!("layer {}", groups - 2 - k)
        };
        t.row(vec![
            k.to_string(),
            contents,
            size.to_string(),
            fnum(100.0 * *size as f64 / total as f64),
        ]);
    }
    t.print();
    println!(
        "peak liveness: {} floats = {:.1}% of the {} total — the O(1) \
         gradient-memory property at program granularity\n",
        sizes.iter().max().unwrap(),
        100.0 * *sizes.iter().max().unwrap() as f64 / total as f64,
        total
    );

    // Equivalence: chained fused groups == monolithic step.
    let p = session.manifest.preset(preset)?.clone();
    let layout = session.manifest.layout("nano/adalomo")?.clone();
    let (b, t_len) = (p.batch_size, p.seq_len);
    let seed = session.upload_i32(&[7], &[])?;
    let blob = session
        .execute_buf(&Manifest::init_name(preset, opt), &[&seed])?;
    let mut loader = DataLoader::lm(Domain::C4, 7, b, t_len, 40_000);
    let batch = loader.next_batch();
    let x = session.upload_i32(&batch.x, &[b, t_len])?;
    let y = session.upload_i32(&batch.y, &[b, t_len])?;
    let sched = session.upload_f32(&[5e-4, 1.0, 0.0, 1.0], &[4])?;

    let t0 = std::time::Instant::now();
    let mono = session
        .execute_buf(&Manifest::train_step_name(preset, opt), &[&blob, &x, &y, &sched])?;
    let mono_time = t0.elapsed().as_secs_f64();
    let t0 = std::time::Instant::now();
    let fused_out =
        fused::fused_step(&session, preset, opt, &blob, &x, &y, &sched)?;
    let fused_time = t0.elapsed().as_secs_f64();

    let a = session.fetch_f32_raw(&mono, layout.blob_len)?;
    let bvec = session.fetch_f32_raw(&fused_out, layout.blob_len)?;
    let max_diff = a[..layout.metrics_offset()]
        .iter()
        .zip(&bvec[..layout.metrics_offset()])
        .map(|(x, y)| (x - y).abs())
        .fold(0f32, f32::max);
    println!("monolithic step: {:.1} ms", mono_time * 1e3);
    println!(
        "fused step:      {:.1} ms ({groups} programs, {:.1}x compute — \
         the price of program-granular liveness on this demo path)",
        fused_time * 1e3,
        fused_time / mono_time
    );
    println!("max |Δparam| between the two: {max_diff:.2e}");
    assert!(max_diff < 1e-4, "fused must equal monolithic");
    println!("✓ fused backward reproduces the monolithic update exactly");
    Ok(())
}
