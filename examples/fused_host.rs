//! Fused-backward host mirror demo — runs entirely on the host, no AOT
//! artifacts needed.
//!
//! ```sh
//! cargo run --release --example fused_host
//! ```
//!
//! What happens: the flat engine's trainable tasks are grouped into the
//! fused-backward walk (head block, layers L-1..0, embedding — the same
//! G = L+2 grouping as the XLA-granularity `coordinator/fused.rs`
//! demonstrator), and a step is executed group by group: produce one
//! group's gradient, step exactly that group, free the buffer before the
//! next group exists. Peak live-gradient bytes are MEASURED and checked
//! against the analytic `memsim::liveness::simulate_grouped` prediction,
//! then the same group-granular producer drives the async pipeline so the
//! bucket exchange overlaps gradient *production* — bit-identical to the
//! lockstep path, with the producing side never holding the full image.

use adalomo::coordinator::fused_host::{
    fused_host_step, FusedHostGrads, GroupGradSource,
};
use adalomo::coordinator::pipeline::{self, PipelineConfig};
use adalomo::memsim::{liveness, Arch};
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode,
};
use adalomo::optim::{pool, OptKind};

fn main() -> anyhow::Result<()> {
    let arch = Arch::preset("micro").unwrap();
    let params = arch.param_specs();
    let specs: Vec<(&str, &[usize])> = params
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    let kind = OptKind::AdaLomo;
    let layout = synthetic_layout(kind, &specs);
    let (blob0, _) = seeded_blob_and_grads(&layout, 9);
    println!(
        "preset micro: {} trainable floats across {} segments",
        layout.params_len,
        params.len()
    );

    // The fused-backward walk, made visible: per-group tasks and extents.
    let mut engine = FlatOptimizer::new(
        kind,
        &layout,
        pool::default_shards().min(4),
        ShardMode::Contiguous,
    )?;
    let order = engine.task_order();
    println!("\nfused-backward groups (G = L + 2):");
    for g in 0..engine.n_groups() {
        let tasks = engine.group_tasks(g);
        let (lo, hi) = engine.group_extents()[g];
        println!(
            "  group {g}: [{lo:>7}, {hi:>7})  {:>7} floats  {} .. {}",
            hi - lo,
            order[tasks.start],
            order[tasks.end - 1],
        );
    }

    // One mirrored step: measured liveness vs the analytic prediction.
    let mut blob = blob0.clone();
    let mut src = FusedHostGrads::per_rank(&engine, 1, 21, 0.02)
        .pop()
        .unwrap();
    let report = fused_host_step(&mut engine, &mut blob, &mut src, 1, 1e-3, 0.0)?;
    let predicted = liveness::simulate_grouped(&arch, 4);
    println!(
        "\nmeasured peak live gradient: {} bytes ({:.1}% of the {}-byte \
         full image)",
        report.peak_live_grad_bytes,
        100.0 * report.live_fraction(),
        report.full_grad_bytes
    );
    println!(
        "analytic prediction (memsim::liveness): {} bytes — measured == \
         predicted: {}",
        predicted.peak_bytes,
        report.peak_live_grad_bytes == predicted.peak_bytes
    );
    assert_eq!(report.curve_bytes, predicted.curve);

    // The grouped pipeline: exchange overlaps production; still bitwise
    // identical to the lockstep full-image path.
    println!("\nfused pipeline vs lockstep (2 ranks):");
    let mut cfg = PipelineConfig::new(4, layout.params_len.div_ceil(16));
    cfg.n_shards = pool::shards_with_reserved(2).min(4);
    let grouped: Vec<Box<dyn GroupGradSource>> =
        FusedHostGrads::per_rank(&engine, 2, 33, 0.02)
            .into_iter()
            .map(|s| Box::new(s) as Box<dyn GroupGradSource>)
            .collect();
    let (pipe, r) = pipeline::run_pipelined_fused(
        &layout,
        kind,
        ShardMode::Contiguous,
        &blob0,
        grouped,
        &cfg,
    )?;
    let full: Vec<Box<dyn pipeline::GradSource>> =
        FusedHostGrads::per_rank(&engine, 2, 33, 0.02)
            .into_iter()
            .map(|s| Box::new(s) as Box<dyn pipeline::GradSource>)
            .collect();
    let (seq, _) = pipeline::run_sequential(
        &layout,
        kind,
        ShardMode::Contiguous,
        &blob0,
        full,
        &cfg,
    )?;
    let identical = pipe
        .iter()
        .zip(&seq)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    println!(
        "  bitwise identical = {identical}; exposed {:.3}ms vs \
         compute+comm {:.3}ms ({:.2}x overlap)",
        r.exposed_secs * 1e3,
        (r.compute_secs + r.comm_secs) * 1e3,
        r.overlap_efficiency
    );
    println!(
        "  producing rank held at most {} of {} gradient bytes \
         ({:.1}% live)",
        r.peak_live_grad_bytes,
        r.full_grad_bytes,
        100.0 * r.peak_live_grad_bytes as f64 / r.full_grad_bytes as f64
    );
    assert!(identical, "fused pipeline diverged from the lockstep path");
    assert!(r.peak_live_grad_bytes < r.full_grad_bytes);
    Ok(())
}
