//! Paper §4.2 / Figs. 2-3 (+ Appendix B Figs. 7-8): further pre-training
//! the base model on the `chinese` and `python_code` domains, AdamW vs
//! AdaLomo, plus the gradient-normalization ablation.
//!
//! ```sh
//! cargo run --release --example further_pretraining
//! ADALOMO_FP_DOMAIN=python_code cargo run --release --example further_pretraining
//! ```
//!
//! Shapes to reproduce: (a) both optimizers track each other closely, with
//! AdaLomo at or slightly below AdamW by the end; (b) the `chinese` domain
//! starts at far higher perplexity than `python_code` and improves more
//! (domain distance, DESIGN.md §4); (c) AdaLomo with and without gradient
//! normalization converges identically (grouped update normalization makes
//! the second backward pass unnecessary).

use adalomo::data::Domain;
use adalomo::experiments as exp;
use adalomo::metrics::ascii_curve;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    if !exp::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let preset =
        std::env::var("ADALOMO_FP_PRESET").unwrap_or_else(|_| "nano".into());
    let steps: usize = std::env::var("ADALOMO_FP_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(200);
    let session = exp::open_session()?;
    let base = exp::ensure_base_checkpoint(&session, &preset, 400, 42, "runs")?;

    let domains = match std::env::var("ADALOMO_FP_DOMAIN").as_deref() {
        Ok(name) => vec![Domain::parse(name)?],
        Err(_) => vec![Domain::Chinese, Domain::PythonCode],
    };
    let mut table = Table::new(
        "Figs. 2-3 + 7-8 reproduction — further pre-training (final eval)",
    )
    .header(&["domain", "optimizer", "start ppl", "final ppl", "final acc"]);

    for domain in domains {
        for opt in ["adamw", "adalomo", "adalomo_gnorm"] {
            println!("==> {} / {opt}", domain.name());
            let report = exp::further_pretrain(
                &session, &preset, opt, domain, steps, &base, 42, "runs",
            )?;
            print!("{}", ascii_curve(&report.curve, 60, 7));
            let first = report.eval_curve.first().copied();
            let last = report.eval_curve.last().copied();
            table.row(vec![
                domain.name().into(),
                opt.into(),
                fnum(first.map(|e| e.1).unwrap_or(f64::NAN)),
                fnum(last.map(|e| e.1).unwrap_or(f64::NAN)),
                fnum(last.map(|e| e.2).unwrap_or(f64::NAN)),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper claims: AdaLomo ≈ AdamW curves overlap (Figs. 2-3); \
         AdaLomo ± grad-norm identical (Figs. 7-8 — grouped normalization \
         replaces the two-pass global norm)."
    );
    Ok(())
}
