//! END-TO-END DRIVER (paper §4.3 / Fig. 4): from-scratch pre-training on
//! the C4 stand-in, comparing SGD / Adafactor / AdamW / AdaLomo — the full
//! system exercised on a real (synthetic-corpus) workload, with loss
//! curves, validation perplexity/accuracy and throughput logged to
//! `runs/`.
//!
//! ```sh
//! cargo run --release --example pretrain_from_scratch                 # tiny, 300 steps
//! ADALOMO_E2E_PRESET=small ADALOMO_E2E_STEPS=400 \
//!   cargo run --release --example pretrain_from_scratch               # ~21M params
//! ```
//!
//! The paper's Fig. 4 claim to reproduce: AdamW ≈ Adafactor ≈ AdaLomo,
//! all clearly better than SGD.

use adalomo::experiments as exp;
use adalomo::metrics::ascii_curve;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    if !exp::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let preset =
        std::env::var("ADALOMO_E2E_PRESET").unwrap_or_else(|_| "tiny".into());
    let steps: usize = std::env::var("ADALOMO_E2E_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(300);
    let session = exp::open_session()?;
    let info = session.manifest.preset(&preset)?.clone();
    println!(
        "from-scratch pre-training on c4 — preset {preset} ({} params), {steps} steps\n",
        info.n_params
    );

    let opts = ["sgd", "adafactor", "adamw", "adalomo"];
    let reports =
        exp::optimizer_comparison(&session, &preset, &opts, steps, 42, "runs")?;

    let mut table = Table::new(
        "Fig. 4 reproduction — from-scratch pre-training (final metrics)",
    )
    .header(&["optimizer", "final loss", "val ppl", "val acc", "tokens/s"]);
    for opt in opts {
        let r = &reports[opt];
        let (ppl, acc) = r
            .eval_curve
            .last()
            .map(|&(_, p, a)| (p, a))
            .unwrap_or((f64::NAN, f64::NAN));
        table.row(vec![
            opt.into(),
            fnum(r.final_loss as f64),
            fnum(ppl),
            fnum(acc),
            fnum(r.tokens_per_sec),
        ]);
        println!("--- {opt} ---");
        print!("{}", ascii_curve(&r.curve, 60, 8));
    }
    table.print();

    // The paper's shape: adaptive methods beat SGD decisively.
    let sgd = reports["sgd"].final_loss;
    let adalomo = reports["adalomo"].final_loss;
    let adamw = reports["adamw"].final_loss;
    println!(
        "\nshape check: sgd {sgd:.3} vs adamw {adamw:.3} vs adalomo {adalomo:.3}"
    );
    if adalomo < sgd && adamw < sgd {
        println!("✓ adaptive methods (AdamW, AdaLomo) beat SGD — Fig. 4 shape holds");
    } else {
        println!("✗ unexpected ordering — see runs/ for curves");
    }
    println!("\nloss curves + eval series: runs/scratch_{preset}_<opt>_c4/metrics.jsonl");
    Ok(())
}
