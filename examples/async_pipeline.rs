//! Async rank pipeline demo — runs entirely on the host, no AOT
//! artifacts needed.
//!
//! ```sh
//! cargo run --release --example async_pipeline
//! ```
//!
//! What happens: per-rank worker threads stream their gradients in
//! fixed-size buckets over bounded channels; the leader reduces each
//! bucket in rank order (the fixed reduction order) and immediately steps
//! every tensor the bucket completes on the flat engine, while later
//! buckets are still "on the fabric" (ring all-reduce cost model). The
//! demo verifies the pipelined path is bitwise identical to the lockstep
//! reduce-then-step path, shows which segments each bucket completes, and
//! races ranks × bucket sizes for overlap efficiency.

use adalomo::coordinator::pipeline::{
    self, BucketPlan, PipelineConfig,
};
use adalomo::data::{DataLoader, Domain};
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode,
};
use adalomo::optim::{pool, OptKind};
use adalomo::runtime::HostBlob;

fn main() -> anyhow::Result<()> {
    let d = 64;
    let params: Vec<(String, Vec<usize>)> = {
        let mut p = vec![("embed".to_string(), vec![256, d])];
        for l in 0..2 {
            p.push((format!("l{l}.attn_norm"), vec![d]));
            for w in ["wq", "wk", "wv", "wo"] {
                p.push((format!("l{l}.{w}"), vec![d, d]));
            }
            p.push((format!("l{l}.ffn_norm"), vec![d]));
            p.push((format!("l{l}.w_gate"), vec![d, 2 * d]));
            p.push((format!("l{l}.w_up"), vec![d, 2 * d]));
            p.push((format!("l{l}.w_down"), vec![2 * d, d]));
        }
        p.push(("final_norm".to_string(), vec![d]));
        p.push(("head".to_string(), vec![d, 256]));
        p
    };
    let specs: Vec<(&str, &[usize])> =
        params.iter().map(|(n, s)| (n.as_str(), s.as_slice())).collect();
    let kind = OptKind::AdaLomo;
    let layout = synthetic_layout(kind, &specs);
    let (blob0, _) = seeded_blob_and_grads(&layout, 9);
    println!(
        "layout: {} segments, {} trainable floats",
        layout.segments.len(),
        layout.params_len
    );

    // The bucket lifecycle, made visible: which segments does each bucket
    // touch, and which tasks does its reduction complete?
    let n_buckets = 8usize;
    let bucket_elems = layout.params_len.div_ceil(n_buckets);
    let plan = BucketPlan::new(layout.params_len, bucket_elems);
    let engine = FlatOptimizer::new(kind, &layout, 1, ShardMode::Segments)?;
    let order = engine.task_order();
    let ready = plan.ready_schedule(&engine.task_extents());
    let hb = HostBlob::new(blob0.clone(), "synthetic/adalomo", &layout)?;
    println!("\nbucket lifecycle ({} buckets x {bucket_elems} floats):", plan.n_buckets());
    for (b, &(lo, hi)) in plan.buckets.iter().enumerate() {
        let touched = layout.segments_in_range(lo, hi).count();
        let completes: Vec<&str> =
            ready[b].iter().map(|&ti| order[ti]).collect();
        // Bucket-granular view of the raw range (what the exchange moves).
        let rms = {
            let r = hb.range(lo, hi)?;
            (r.iter().map(|x| x * x).sum::<f32>() / r.len() as f32).sqrt()
        };
        println!(
            "  bucket {b}: [{lo:>6}, {hi:>6})  rms {rms:.3}  touches {touched} segments, completes {:?}",
            completes
        );
    }

    // Identity: pipelined == sequential, bit for bit, with data-conditioned
    // gradients and a fixed validation set for the eval check.
    let mut cfg = PipelineConfig::new(4, bucket_elems);
    cfg.n_shards = pool::shards_with_reserved(2).min(4);
    let sources =
        || pipeline::token_sources(Domain::C4, 11, 2, 2, 32, 8_000, 5e-3);
    let (pipe, _) = pipeline::run_pipelined(
        &layout,
        kind,
        ShardMode::Contiguous,
        &blob0,
        sources(),
        &cfg,
    )?;
    let (seq, _) = pipeline::run_sequential(
        &layout,
        kind,
        ShardMode::Contiguous,
        &blob0,
        sources(),
        &cfg,
    )?;
    let identical = pipe
        .iter()
        .zip(&seq)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    let mut val = DataLoader::lm(Domain::C4, 999, 2, 32, 8_000);
    let lp = pipeline::host_eval_loss(&pipe[..layout.params_len], &mut val, 4);
    let ls = pipeline::host_eval_loss(&seq[..layout.params_len], &mut val, 4);
    println!(
        "\npipelined vs sequential: bitwise identical = {identical}, \
         fixed-set eval loss {lp:.6e} vs {ls:.6e}"
    );
    assert!(identical, "pipelined path diverged from the lockstep path");
    assert_eq!(lp.to_bits(), ls.to_bits());

    // Overlap: exposed (critical path) vs fully-exposed compute + comm.
    println!("\noverlap efficiency (4 steps, AdaLomo, contiguous shards):");
    for n_ranks in [2usize, 4, 8] {
        for n_buckets in [4usize, 16, 64] {
            let bucket = layout.params_len.div_ceil(n_buckets);
            let mut cfg = PipelineConfig::new(4, bucket);
            cfg.n_shards = pool::shards_with_reserved(n_ranks).min(4);
            let sources = pipeline::synthetic_sources(n_ranks, 31, 0.02);
            let (_, r) = pipeline::run_pipelined(
                &layout,
                kind,
                ShardMode::Contiguous,
                &blob0,
                sources,
                &cfg,
            )?;
            println!(
                "  x{:<2} ranks, {:>3} buckets: exposed {:8.3}ms vs \
                 compute+comm {:8.3}ms  => {:.2}x",
                r.n_ranks,
                r.n_buckets,
                r.exposed_secs * 1e3,
                (r.compute_secs + r.comm_secs) * 1e3,
                r.overlap_efficiency
            );
        }
    }
    Ok(())
}
