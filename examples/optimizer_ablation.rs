//! Paper §2.2 / Fig. 1: the moments ablation that motivates AdaLomo.
//! Train the same model with Adam, SGD, SGD+momentum (Eq. 3) and
//! SGD+variance (Eq. 4); the claim is that the runs keeping the *second*
//! moment (Adam, SGD+variance) reach a clearly lower loss than those
//! without it (SGD, SGD+momentum) — momentum alone does not close the gap.
//!
//! ```sh
//! cargo run --release --example optimizer_ablation
//! ```

use adalomo::experiments as exp;
use adalomo::metrics::ascii_curve;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    if !exp::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let preset =
        std::env::var("ADALOMO_AB_PRESET").unwrap_or_else(|_| "nano".into());
    let steps: usize = std::env::var("ADALOMO_AB_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(250);
    let session = exp::open_session()?;
    println!("Fig. 1 ablation — {preset}, {steps} steps (adamw run uses wd=0 = plain Adam)\n");

    let opts = ["sgd", "sgd_momentum", "sgd_variance", "adamw"];
    let reports =
        exp::optimizer_comparison(&session, &preset, &opts, steps, 42, "runs")?;

    let mut table = Table::new("Fig. 1 reproduction — final train loss")
        .header(&["optimizer", "moments kept", "final loss"]);
    let labels = [
        ("sgd", "none"),
        ("sgd_momentum", "first (Eq. 3)"),
        ("sgd_variance", "second (Eq. 4)"),
        ("adamw", "both (Adam)"),
    ];
    for (opt, moments) in labels {
        let r = &reports[opt];
        table.row(vec![
            opt.into(),
            moments.into(),
            fnum(r.final_loss as f64),
        ]);
        println!("--- {opt} ---");
        print!("{}", ascii_curve(&r.curve, 60, 7));
    }
    table.print();

    let sgd = reports["sgd"].final_loss;
    let momentum = reports["sgd_momentum"].final_loss;
    let variance = reports["sgd_variance"].final_loss;
    let adam = reports["adamw"].final_loss;
    println!("\npaper Fig. 1 shape: loss(adam) ≈ loss(variance) < loss(momentum) ≈ loss(sgd)");
    let second_moment_wins =
        variance < sgd && adam < sgd && variance < momentum;
    println!(
        "second moment is the decisive factor: {}",
        if second_moment_wins { "✓ reproduced" } else { "✗ check runs/" }
    );
    Ok(())
}
