//! Paper §4.4 / Table 1 / Fig. 5 / Table 8: the memory & throughput
//! profile, from the analytic simulator calibrated against the paper's own
//! measurements (the testbed substitution — DESIGN.md §4) plus measured
//! step times of the real artifacts for the local scaling shape.
//!
//! ```sh
//! cargo run --release --example memory_throughput
//! ```

use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::memsim::{liveness, memory, paper, throughput, Arch};
use adalomo::runtime::Manifest;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    // ---- Table 1: closed-form model-state memory --------------------------
    let arch = Arch::analytic("llama7b").unwrap();
    let mut t1 = Table::new(
        "Table 1 — model-state bytes/param (paper: LoRA ~2M, AdamW 16M, AdaLomo ~2M)",
    )
    .header(&["method", "param", "grad", "opt state", "total"]);
    for m in [
        memory::Method::LoRA { rank: 8 },
        memory::Method::AdamW,
        memory::Method::AdaLomo,
    ] {
        let b = memory::model_state_bytes(&arch, m);
        let n = arch.n_params() as f64;
        t1.row(vec![
            m.name().into(),
            fnum(b.params / n),
            fnum(b.gradients / n),
            fnum(b.optimizer_state / n),
            fnum(b.model_state() / n),
        ]);
    }
    t1.print();

    // ---- Fig 5a / Table 8 memory ------------------------------------------
    let act = memory::calibrate();
    println!(
        "calibrated activation model: {:.2} B/token/layer/d_model, {:.2} GB/GPU overhead\n",
        act.act_coeff,
        act.gpu_overhead / memory::GB
    );
    let mut t8m = Table::new("Fig 5a / Table 8 — memory (GB), modeled vs paper")
        .header(&["model", "method", "modeled", "paper", "err"]);
    for &(arch_name, method, gpus, mb, paper_gb, _) in paper::TABLE8 {
        let est = memory::estimate(
            &memory::TrainSetup {
                arch: Arch::analytic(arch_name).unwrap(),
                method: memory::Method::parse(method)?,
                n_gpus: gpus,
                micro_batch: mb,
                seq_len: paper::PROFILE_SEQ_LEN,
            },
            act,
        )
        .total_gb();
        t8m.row(vec![
            arch_name.into(),
            method.into(),
            fnum(est),
            fnum(paper_gb),
            format!("{:+.0}%", 100.0 * (est - paper_gb) / paper_gb),
        ]);
    }
    t8m.print();

    // ---- Fig 5b / Table 8 throughput --------------------------------------
    let hw = throughput::Hardware::default();
    let eff = throughput::calibrate();
    let mut t8t =
        Table::new("Fig 5b / Table 8 — throughput (TGS), modeled vs paper")
            .header(&["model", "method", "modeled", "paper", "err"]);
    for &(arch_name, method, gpus, mb, _, paper_tgs) in paper::TABLE8 {
        let tgs = throughput::tgs(
            &memory::TrainSetup {
                arch: Arch::analytic(arch_name).unwrap(),
                method: memory::Method::parse(method)?,
                n_gpus: gpus,
                micro_batch: mb,
                seq_len: paper::PROFILE_SEQ_LEN,
            },
            hw,
            eff,
        );
        t8t.row(vec![
            arch_name.into(),
            method.into(),
            fnum(tgs),
            fnum(paper_tgs),
            format!("{:+.0}%", 100.0 * (tgs - paper_tgs) / paper_tgs),
        ]);
    }
    t8t.print();

    // ---- gradient liveness (the §2.1 argument) -----------------------------
    let mut tl = Table::new("Gradient liveness (llama65b)")
        .header(&["mode", "peak grad GB", "vs standard"]);
    let std_peak = liveness::simulate(
        &Arch::analytic("llama65b").unwrap(),
        liveness::BackwardMode::Standard,
    )
    .peak_bytes as f64;
    for (name, mode) in [
        ("standard", liveness::BackwardMode::Standard),
        ("fused (LOMO/AdaLomo)", liveness::BackwardMode::Fused),
    ] {
        let r = liveness::simulate(
            &Arch::analytic("llama65b").unwrap(),
            mode,
        );
        tl.row(vec![
            name.into(),
            fnum(r.peak_bytes as f64 / memory::GB),
            format!("{:.2}%", 100.0 * r.peak_bytes as f64 / std_peak),
        ]);
    }
    tl.print();

    // ---- measured (real artifacts): per-method step time on this host -----
    if exp::artifacts_available() {
        let session = exp::open_session()?;
        let preset = "nano";
        let p = session.manifest.preset(preset)?.clone();
        let (b, t) = (p.batch_size, p.seq_len);
        let mut tm = Table::new(&format!(
            "Measured on this host — {preset} ({} params), CPU PJRT",
            p.n_params
        ))
        .header(&["optimizer", "ms/step", "tokens/s"]);
        for opt in ["sgd", "adamw", "adafactor", "lomo", "adalomo"] {
            let entry = Manifest::train_step_name(preset, opt);
            session.compile(&entry)?;
            let seed = session.upload_i32(&[1], &[])?;
            let mut blob = session
                .execute_buf(&Manifest::init_name(preset, opt), &[&seed])?;
            let mut loader = DataLoader::lm(Domain::C4, 3, b, t, 80_000);
            let reps = 12;
            let t0 = std::time::Instant::now();
            for step in 1..=reps {
                let batch = loader.next_batch();
                let x = session.upload_i32(&batch.x, &[b, t])?;
                let y = session.upload_i32(&batch.y, &[b, t])?;
                let sched = session
                    .upload_f32(&[1e-3, step as f32, 0.0, 1.0], &[4])?;
                blob = session
                    .execute_buf(&entry, &[&blob, &x, &y, &sched])?;
            }
            let dt = t0.elapsed().as_secs_f64() / reps as f64;
            tm.row(vec![
                opt.into(),
                fnum(dt * 1e3),
                fnum((b * t) as f64 / dt),
            ]);
        }
        tm.print();
    }
    Ok(())
}
