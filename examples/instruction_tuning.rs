//! Paper §4.1 / Table 2: instruction tuning with {none, LoRA, AdamW, LOMO,
//! AdaLomo} followed by the five-benchmark synthetic suite (MMLU/BBH/
//! GSM8K/HumanEval/AlpacaFarm stand-ins — see data::instruct).
//!
//! ```sh
//! cargo run --release --example instruction_tuning                 # nano
//! ADALOMO_IT_PRESET=micro cargo run --release --example instruction_tuning
//! ```
//!
//! Shape to reproduce: tuned models beat the raw base model everywhere;
//! AdaLomo ≈ AdamW ≥ LoRA > LOMO on average.

use adalomo::experiments as exp;
use adalomo::util::table::{fnum, Table};

fn main() -> anyhow::Result<()> {
    if !exp::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let preset =
        std::env::var("ADALOMO_IT_PRESET").unwrap_or_else(|_| "nano".into());
    let steps: usize = std::env::var("ADALOMO_IT_STEPS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(500);
    let base_steps = 400;
    let n_items = 24;
    let session = exp::open_session()?;

    println!("base model: {base_steps} AdamW steps on c4 (the LLaMA stand-in)...");
    let base =
        exp::ensure_base_checkpoint(&session, &preset, base_steps, 42, "runs")?;

    let mut table = Table::new(&format!(
        "Table 2 reproduction — {preset}, {steps} tuning steps, {n_items} items/benchmark"
    ))
    .header(&[
        "method", "knowledge", "reasoning", "arithmetic", "code", "writing",
        "avg",
    ]);
    let mut avgs = std::collections::BTreeMap::new();
    for method in ["none", "lora", "adamw", "lomo", "adalomo"] {
        println!("==> {method}");
        let outcome = exp::instruction_tune(
            &session, &preset, method, steps, &base, 42, "runs", n_items,
        )?;
        table.row(vec![
            method.into(),
            fnum(outcome.suite.scores["knowledge"]),
            fnum(outcome.suite.scores["reasoning"]),
            fnum(outcome.suite.scores["arithmetic"]),
            fnum(outcome.suite.scores["code"]),
            fnum(outcome.suite.scores["writing"]),
            fnum(outcome.suite.avg),
        ]);
        avgs.insert(method, outcome.suite.avg);
    }
    table.print();

    println!("\npaper Table 2 (LLaMA-7B averages): N/A 18.1 | LoRA 26.5 | AdamW 29.1 | LOMO 24.0 | AdaLomo 30.8");
    let ok = avgs["adalomo"] >= avgs["lomo"] && avgs["adamw"] >= avgs["none"];
    println!(
        "shape check (AdaLomo ≥ LOMO, tuned ≥ base): {}",
        if ok { "✓ holds" } else { "✗ violated" }
    );
    Ok(())
}
