//! Quickstart: train a byte-level LLaMA-style model with AdaLomo through
//! the full three-layer stack in ~30 seconds.
//!
//! ```sh
//! make artifacts                       # once: python AOT -> artifacts/
//! cargo run --release --example quickstart
//! ```
//!
//! What happens: the Rust coordinator loads the AOT-compiled HLO program
//! `train_step_nano_adalomo` via PJRT, initializes the training-state blob
//! *on device* from a seed, then drives the step loop — per step only the
//! token batch (and a 4-float schedule) crosses the host/device boundary.

use adalomo::config::{Phase, RunConfig};
use adalomo::coordinator::Trainer;
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::metrics::ascii_curve;

fn main() -> anyhow::Result<()> {
    if !exp::artifacts_available() {
        eprintln!("artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let session = exp::open_session()?;
    let preset = session.manifest.preset("nano")?.clone();
    println!(
        "model: {} params, {} layers, d_model {}, byte vocab {}",
        preset.n_params, preset.n_layers, preset.d_model, preset.vocab
    );

    let mut cfg = RunConfig::new("nano", "adalomo", Phase::Scratch, 120);
    cfg.lr = 1e-2; // AdaLomo's relative step: no small-model rescale needed
    cfg.log_every = 10;
    cfg.eval_every = 40;
    let (b, t) = (preset.batch_size, preset.seq_len);
    let train = DataLoader::lm(Domain::C4, 42, b, t, 1_000_000);
    let val = DataLoader::lm(Domain::C4, 43, b, t, 16 * b * (t + 1));

    let mut trainer = Trainer::new(&session, cfg, train, Some(val))?;
    let report = trainer.train()?;

    println!("\nloss curve:");
    print!("{}", ascii_curve(&report.curve, 60, 10));
    for (step, ppl, acc) in &report.eval_curve {
        println!("eval@{step}: perplexity {ppl:.1}, next-token acc {acc:.3}");
    }
    println!(
        "\n{} steps in {:.1}s — {:.0} tokens/s (uniform-guess loss would be ln 256 = 5.545)",
        report.steps, report.wall_secs, report.tokens_per_sec
    );

    // The blob can come back to the host for checkpointing at any time.
    let blob = trainer.host_blob()?;
    println!("checkpoint blob: {} f32s ({})", blob.data.len(), blob.layout_key);
    Ok(())
}
