//! Unified execution engine demo — one `ExecPlan` leader loop behind all
//! four training paths, with checkpoint/suspend/resume. Runs entirely on
//! the host, no AOT artifacts needed.
//!
//! ```sh
//! cargo run --release --example engine_checkpoint
//! ```
//!
//! What happens: the same deterministic rank gradients drive the four
//! plan cells the legacy entry points map to (lockstep, pipelined,
//! pipelined-fused, fused-host mirror) and all four land bitwise on the
//! same parameters; then a pipelined-fused run is suspended at its
//! midpoint, serialized to a versioned checkpoint file, resumed "in a new
//! process", and shown to reproduce the uninterrupted run byte for byte —
//! the `make ckpt-smoke` story, narrated.

use adalomo::coordinator::engine::{Engine, ExecPlan, RankSources};
use adalomo::coordinator::fused_host;
use adalomo::coordinator::pipeline::{self, PipelineConfig};
use adalomo::data::{DataLoader, Domain};
use adalomo::memsim::Arch;
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, ShardMode,
};
use adalomo::optim::{pool, OptKind};
use adalomo::runtime::checkpoint;

const SEED: u64 = 33;
const SCALE: f32 = 0.02;

/// The canonical reconstruction the CLI's `--resume` uses: sources come
/// from the plan (seed included) alone.
fn sources_for(eng: &Engine) -> RankSources {
    fused_host::plan_sources(eng.plan(), eng.group_extents(), SCALE)
}

fn main() -> anyhow::Result<()> {
    let arch = Arch::preset("micro").unwrap();
    let params = arch.param_specs();
    let specs: Vec<(&str, &[usize])> = params
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    let kind = OptKind::AdaLomo;
    let layout = synthetic_layout(kind, &specs);
    let (blob0, _) = seeded_blob_and_grads(&layout, 9);
    let mut cfg = PipelineConfig::new(6, layout.params_len.div_ceil(16));
    cfg.n_shards = pool::shards_with_reserved(2).min(4);
    println!(
        "preset micro: {} trainable floats; {} steps per run",
        layout.params_len, cfg.steps
    );

    // One leader loop, four plans: identical gradients must land
    // identical parameters on every cell of the (production x order x
    // granularity) space the legacy entry points inhabit.
    println!("\nfour plans, one engine (2 ranks each):");
    let mut blobs: Vec<(String, Vec<f32>)> = Vec::new();
    for plan in [
        ExecPlan::sequential(kind, ShardMode::Contiguous, 2, &cfg),
        ExecPlan::pipelined(kind, ShardMode::Contiguous, 2, &cfg),
        ExecPlan::pipelined_fused(kind, ShardMode::Contiguous, 2, &cfg),
        ExecPlan::fused_host(kind, ShardMode::Contiguous, 2, &cfg),
    ] {
        let mut plan = plan;
        plan.seed = SEED;
        let desc = plan.describe();
        let mut eng = Engine::new(&layout, &blob0, plan)?;
        let sources = sources_for(&eng);
        let report = eng.run(sources)?;
        println!(
            "  {desc}\n    -> exposed {:8.3}ms vs compute+comm {:8.3}ms \
             ({:.2}x overlap), peak live grad {:6.1}% of image",
            report.exposed_secs * 1e3,
            (report.compute_secs + report.comm_secs) * 1e3,
            report.overlap_efficiency,
            100.0 * report.live_fraction(),
        );
        blobs.push((desc, eng.into_blob()));
    }
    let (ref_desc, reference) = &blobs[0];
    for (desc, blob) in &blobs[1..] {
        let identical = blob
            .iter()
            .zip(reference)
            .all(|(a, b)| a.to_bits() == b.to_bits());
        assert!(identical, "{desc} diverged from {ref_desc}");
    }
    println!("  all four blobs bitwise identical = true");

    // Suspend / checkpoint / resume: stop a pipelined-fused run at step
    // 3, write the versioned checkpoint, resume from the file alone, and
    // compare against the uninterrupted run.
    println!("\nsuspend at step 3 -> checkpoint -> resume:");
    let mut plan =
        ExecPlan::pipelined_fused(kind, ShardMode::Contiguous, 2, &cfg);
    plan.seed = SEED;
    let dir = std::env::temp_dir();
    let mid = dir.join(format!("engine_demo_mid_{}.bin", std::process::id()));
    let mut part = Engine::new(&layout, &blob0, plan.clone())?;
    part.suspend_at(3);
    let sources = sources_for(&part);
    part.run(sources)?;
    part.save(&mid)?;
    let ck = checkpoint::load(&mid)?;
    println!(
        "  wrote {} ({} bytes): step {} of {}, {} segments",
        mid.display(),
        std::fs::metadata(&mid)?.len(),
        ck.step,
        ck.plan.steps,
        ck.layout.segments.len()
    );
    drop(part);

    let mut resumed = Engine::resume(&mid)?;
    let sources = sources_for(&resumed);
    resumed.run(sources)?;
    let mut full = Engine::new(&layout, &blob0, plan)?;
    let sources = sources_for(&full);
    full.run(sources)?;
    // `Engine::blob()` widens a fresh snapshot per call — take each once.
    let resumed_blob = resumed.blob();
    let full_blob = full.blob();
    let identical = resumed_blob
        .iter()
        .zip(&full_blob)
        .all(|(a, b)| a.to_bits() == b.to_bits());
    let mut val = DataLoader::lm(Domain::C4, 999, 2, 32, 8_000);
    let lr_ = pipeline::host_eval_loss(
        &resumed_blob[..layout.params_len],
        &mut val,
        4,
    );
    let lf = pipeline::host_eval_loss(
        &full_blob[..layout.params_len],
        &mut val,
        4,
    );
    println!(
        "  resumed vs uninterrupted: bitwise identical = {identical}, \
         fixed-val-set eval loss {lr_:.6e} vs {lf:.6e}"
    );
    assert!(identical, "resumed run diverged from the uninterrupted run");
    assert_eq!(lr_.to_bits(), lf.to_bits());
    std::fs::remove_file(&mid).ok();
    Ok(())
}
