# AdaLomo reproduction — build/test/lint entry points.
#
# Tier-1 verify is `make ci-tier1`; `make lint` adds the fmt + clippy gates
# wired alongside it (also run by .github/workflows/ci.yml).

CARGO ?= cargo

.PHONY: build test bench fmt fmt-fix clippy lint ci-tier1 ci artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	ADALOMO_BENCH_FAST=1 $(CARGO) bench

fmt:
	$(CARGO) fmt --all -- --check

fmt-fix:
	$(CARGO) fmt --all

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

lint: fmt clippy

ci-tier1: build test

ci: lint ci-tier1

# Python AOT pass: lowers the JAX/Pallas layers to HLO artifacts the Rust
# runtime executes. Requires jax in the environment.
artifacts:
	cd python && python -m compile.aot --out ../artifacts
