# AdaLomo reproduction — build/test/lint entry points.
#
# Tier-1 verify is `make ci-tier1`; `make lint` adds the fmt + clippy gates
# wired alongside it. The GitHub workflow (.github/workflows/ci.yml) runs
# THESE targets — never re-spell the commands in YAML, so the two cannot
# drift.

CARGO ?= cargo

.PHONY: build test bench bench-smoke fmt fmt-fix clippy lint ci-tier1 ci \
	test-pjrt artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	ADALOMO_BENCH_FAST=1 $(CARGO) bench

# The two host-only micro benches CI smoke-runs on every PR (and uploads
# as a workflow artifact): optimizer-step cost + the async-pipeline
# overlap-efficiency numbers, and runtime dispatch/transfer overhead.
bench-smoke:
	ADALOMO_BENCH_FAST=1 $(CARGO) bench --bench bench_micro_optim
	ADALOMO_BENCH_FAST=1 $(CARGO) bench --bench bench_micro_runtime

fmt:
	$(CARGO) fmt --all -- --check

fmt-fix:
	$(CARGO) fmt --all

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

lint: fmt clippy

ci-tier1: build test

ci: lint ci-tier1

# Artifact-gated integration tests (need `make artifacts` + real PJRT —
# run by the workflow's manually-dispatched `pjrt` job).
test-pjrt:
	$(CARGO) test -q -- --ignored

# Python AOT pass: lowers the JAX/Pallas layers to HLO artifacts the Rust
# runtime executes. Requires jax in the environment.
artifacts:
	cd python && python -m compile.aot --out ../artifacts
