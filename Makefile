# AdaLomo reproduction — build/test/lint entry points.
#
# Tier-1 verify is `make ci-tier1`; `make lint` adds the fmt + clippy +
# rustdoc gates wired alongside it. The GitHub workflow
# (.github/workflows/ci.yml) runs THESE targets — never re-spell the
# commands in YAML, so the two cannot drift.

CARGO ?= cargo

.PHONY: build test bench bench-smoke bench-json bench-gate bench-check \
	bench-bless ckpt-smoke chaos fmt fmt-fix clippy doc analyze lint \
	ci-tier1 ci miri tsan test-pjrt artifacts

build:
	$(CARGO) build --release

test:
	$(CARGO) test -q

bench:
	ADALOMO_BENCH_FAST=1 $(CARGO) bench

# The two host-only micro benches CI smoke-runs on every PR (and uploads
# as a workflow artifact): optimizer-step cost + the async-pipeline
# overlap-efficiency numbers, and runtime dispatch/transfer overhead.
bench-smoke:
	ADALOMO_BENCH_FAST=1 $(CARGO) bench --bench bench_micro_optim
	ADALOMO_BENCH_FAST=1 $(CARGO) bench --bench bench_micro_runtime

# Machine-readable benches: same two micro benches in fast mode, with the
# tracked metrics (optimizer step ns/elem, overlap efficiency, peak-live-
# gradient bytes from the fused-host mirror) merged into
# BENCH_pipeline.json — uploaded as a CI artifact next to bench-smoke.txt
# so the perf trajectory is diffable, not free text.
bench-json:
	rm -f BENCH_pipeline.json
	ADALOMO_BENCH_FAST=1 ADALOMO_BENCH_JSON=$(CURDIR)/BENCH_pipeline.json \
		$(CARGO) bench --bench bench_micro_optim
	ADALOMO_BENCH_FAST=1 ADALOMO_BENCH_JSON=$(CURDIR)/BENCH_pipeline.json \
		$(CARGO) bench --bench bench_micro_runtime

# Regression gate over an EXISTING BENCH_pipeline.json: fail when a
# tracked metric drifts beyond the tolerance STATED PER METRIC in
# bench/baseline.json. Deterministic byte-count metrics are pinned
# two-sided ("exact" — improvements must re-bless too); timing metrics
# get wide slack for CI-runner variance; overlap_efficiency_x4 is
# timing-derived with a hard floor of 1.0, so its bound sits below the
# floor — it rides along for trajectory visibility, not as a hard gate.
# CI runs the benches once (bench-json) then this compare-only target.
bench-gate:
	$(CARGO) run --release --quiet -- bench-check \
		--current BENCH_pipeline.json --baseline bench/baseline.json

# One-shot local convenience: measure + gate (sequenced explicitly so
# `make -j` cannot race the gate ahead of the measurement).
bench-check: bench-json
	$(MAKE) bench-gate

# INTENTIONAL perf shift? Re-baseline with one line:
#   make bench-bless
# (re-measures, then rewrites every baseline value while KEEPING each
# metric's stated tolerance/direction — never copy the flat measurement
# file over the structured baseline).
bench-bless: bench-json
	$(CARGO) run --release --quiet -- bench-check --bless \
		--current BENCH_pipeline.json --baseline bench/baseline.json

# Checkpoint suspend/resume smoke (tier-1): run the same engine plan once
# uninterrupted and once suspended at its midpoint + resumed from the
# checkpoint file, then assert the two final checkpoints are
# byte-identical. One `cmp` validates the blob bits AND the versioned
# header (step counter + plan position) in one shot. Runs BOTH storage
# dtypes: the bf16 leg additionally asserts (checkpoint-inspect --dtype)
# that the resumed file really stores bf16, and that it undercuts the f32
# twin's size (the tentpole's 2x claim, smoke-tested end to end). A third
# cell runs the q8 wire rung (--wire q8) so the checkpointed
# error-feedback accumulators are exercised across a real suspend/resume:
# the resume must land byte-identical too, and checkpoint-inspect --wire
# asserts the rung survived the round trip.
CKPT_SMOKE_DIR := $(CURDIR)/target/ckpt-smoke
ckpt-smoke:
	rm -rf $(CKPT_SMOKE_DIR) && mkdir -p $(CKPT_SMOKE_DIR)
	$(CARGO) run --release --quiet -- train --plan pipelined-fused \
		--preset nano --steps 6 --ranks 2 \
		--out $(CKPT_SMOKE_DIR)/full.bin
	$(CARGO) run --release --quiet -- train --plan pipelined-fused \
		--preset nano --steps 6 --ranks 2 --suspend-at 3 \
		--out $(CKPT_SMOKE_DIR)/mid.bin
	$(CARGO) run --release --quiet -- train \
		--resume $(CKPT_SMOKE_DIR)/mid.bin \
		--out $(CKPT_SMOKE_DIR)/resumed.bin
	$(CARGO) run --release --quiet -- checkpoint-inspect \
		--ckpt $(CKPT_SMOKE_DIR)/resumed.bin --dtype f32
	cmp $(CKPT_SMOKE_DIR)/full.bin $(CKPT_SMOKE_DIR)/resumed.bin
	$(CARGO) run --release --quiet -- train --plan pipelined-fused \
		--preset nano --steps 6 --ranks 2 --dtype bf16 \
		--out $(CKPT_SMOKE_DIR)/full16.bin
	$(CARGO) run --release --quiet -- train --plan pipelined-fused \
		--preset nano --steps 6 --ranks 2 --dtype bf16 --suspend-at 3 \
		--out $(CKPT_SMOKE_DIR)/mid16.bin
	$(CARGO) run --release --quiet -- train \
		--resume $(CKPT_SMOKE_DIR)/mid16.bin \
		--out $(CKPT_SMOKE_DIR)/resumed16.bin
	$(CARGO) run --release --quiet -- checkpoint-inspect \
		--ckpt $(CKPT_SMOKE_DIR)/resumed16.bin --dtype bf16
	cmp $(CKPT_SMOKE_DIR)/full16.bin $(CKPT_SMOKE_DIR)/resumed16.bin
	@test $$(wc -c < $(CKPT_SMOKE_DIR)/full16.bin) -lt \
		$$(( $$(wc -c < $(CKPT_SMOKE_DIR)/full.bin) * 55 / 100 )) \
		|| { echo "bf16 checkpoint not under 55% of f32"; exit 1; }
	$(CARGO) run --release --quiet -- train --plan pipelined-fused \
		--preset nano --steps 6 --ranks 2 --wire q8 \
		--out $(CKPT_SMOKE_DIR)/fullq8.bin
	$(CARGO) run --release --quiet -- train --plan pipelined-fused \
		--preset nano --steps 6 --ranks 2 --wire q8 --suspend-at 3 \
		--out $(CKPT_SMOKE_DIR)/midq8.bin
	$(CARGO) run --release --quiet -- train \
		--resume $(CKPT_SMOKE_DIR)/midq8.bin \
		--out $(CKPT_SMOKE_DIR)/resumedq8.bin
	$(CARGO) run --release --quiet -- checkpoint-inspect \
		--ckpt $(CKPT_SMOKE_DIR)/resumedq8.bin --dtype f32 --wire q8
	cmp $(CKPT_SMOKE_DIR)/fullq8.bin $(CKPT_SMOKE_DIR)/resumedq8.bin
	@if $(CARGO) run --release --quiet -- train \
		--resume $(CKPT_SMOKE_DIR)/midq8.bin --ranks 3 \
		--out $(CKPT_SMOKE_DIR)/never.bin 2>/dev/null; then \
		echo "resume accepted a mismatched --ranks 3; it must refuse"; \
		exit 1; fi
	@test ! -f $(CKPT_SMOKE_DIR)/never.bin \
		|| { echo "refused resume still wrote an output file"; exit 1; }
	@echo "ckpt-smoke OK: suspend/resume reproduced both dtypes and the q8 wire byte-for-byte; bf16 file under 55% of f32; mismatched --ranks resume refused"

# Chaos lane: ranks killed/revived at random (seed-pinned) step
# boundaries — the elastic engine must stay byte-identical to the
# fixed-membership checkpoint splice (rust/tests/chaos_elastic.rs). On a
# red case the test shrinks the schedule and drops the reproducer into
# target/chaos/, which the CI job uploads as an artifact.
chaos:
	$(CARGO) test --release -q --test chaos_elastic

fmt:
	$(CARGO) fmt --all -- --check

fmt-fix:
	$(CARGO) fmt --all

clippy:
	$(CARGO) clippy --all-targets -- -D warnings

# Rustdoc rot is a lint failure too (broken intra-doc links etc.).
# Scoped to the main crate: the vendored path deps are API mirrors, not
# documentation surfaces.
doc:
	RUSTDOCFLAGS="-D warnings" $(CARGO) doc --no-deps --package adalomo

# Repo-wide static analysis (docs/ANALYSIS.md): no-unsafe, determinism,
# panic-discipline, and cross-artifact consistency over rust/src + the
# Makefile/CI/bench-baseline/docs surfaces. Exits nonzero on any
# unwaivered finding; the JSON report is a CI artifact.
analyze:
	$(CARGO) run --release --quiet -- analyze --json analysis-report.json \
		--sarif analysis-report.sarif

lint: fmt clippy doc analyze

ci-tier1: build test

ci: lint ci-tier1 ckpt-smoke chaos

# Dynamic-analysis companions to `analyze` (nightly toolchain; CI runs
# them as manually-dispatched jobs like `pjrt`). Miri interprets the
# tensor/blob/checkpoint unit tests — the checkpoint read path parses
# untrusted bytes, exactly where UB would hide. Isolation is off so the
# checkpoint tests may touch their temp files.
miri:
	MIRIFLAGS="-Zmiri-disable-isolation" $(CARGO) +nightly miri test -q \
		--lib -- tensor:: runtime::blob:: runtime::checkpoint::

# ThreadSanitizer over the threaded paths (pool / pipeline / engine):
# the producer threads + rank-ordered reductions the determinism rule
# polices statically, checked dynamically. Needs the rust-src component
# (-Zbuild-std rebuilds std with the sanitizer).
tsan:
	RUSTFLAGS="-Zsanitizer=thread" $(CARGO) +nightly test -q --lib \
		-Zbuild-std --target x86_64-unknown-linux-gnu -- \
		optim::pool:: coordinator::pipeline:: coordinator::engine::

# Artifact-gated integration tests (need `make artifacts` + real PJRT —
# run by the workflow's manually-dispatched `pjrt` job).
test-pjrt:
	$(CARGO) test -q -- --ignored

# Python AOT pass: lowers the JAX/Pallas layers to HLO artifacts the Rust
# runtime executes. Requires jax in the environment.
artifacts:
	cd python && python -m compile.aot --out ../artifacts
