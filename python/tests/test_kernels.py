# Layer-1 correctness: every Pallas kernel against its pure-jnp oracle,
# with hypothesis sweeping shapes and magnitudes. This is the CORE
# correctness signal for the compute layer.

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (adafactor_update, adalomo_update, adamw_update,
                             lomo_update, ref, tiles)

SETTINGS = dict(max_examples=20, deadline=None)


def rand(rng, shape, scale=1.0):
    return jnp.asarray(rng.normal(0, scale, shape), jnp.float32)


@st.composite
def matrix_case(draw):
    m = draw(st.integers(2, 96))
    n = draw(st.integers(2, 64))
    t = draw(st.integers(1, 50))
    seed = draw(st.integers(0, 2**31 - 1))
    lr = draw(st.sampled_from([1e-4, 1e-3, 1e-2, 0.3]))
    return m, n, t, seed, lr


@given(matrix_case())
@settings(**SETTINGS)
def test_adalomo_kernel_matches_ref(case):
    m, n, t, seed, lr = case
    rng = np.random.default_rng(seed)
    theta = rand(rng, (m, n), 0.1)
    g = rand(rng, (m, n), 0.02)
    r = jnp.asarray(rng.uniform(0, 1e-4, (m,)), jnp.float32)
    c = jnp.asarray(rng.uniform(0, 1e-4, (n,)), jnp.float32)
    got = adalomo_update.adalomo_update(theta, g, r, c, float(t), lr)
    want = ref.adalomo_ref(theta, g, r, c, float(t), lr)
    for a, b, name in zip(got, want, ["theta", "r", "c"]):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=1e-7, err_msg=name)


@given(matrix_case())
@settings(**SETTINGS)
def test_adamw_kernel_matches_ref(case):
    m, n, t, seed, lr = case
    rng = np.random.default_rng(seed)
    theta = rand(rng, (m, n), 0.1)
    g = rand(rng, (m, n), 0.02)
    mm = rand(rng, (m, n), 0.01)
    vv = jnp.asarray(rng.uniform(0, 1e-4, (m, n)), jnp.float32)
    got = adamw_update.adamw_update(theta, g, mm, vv, float(t), lr, wd=0.01)
    want = ref.adamw_ref(theta, g, mm, vv, float(t), lr, wd=0.01)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=5e-8)


@given(matrix_case())
@settings(**SETTINGS)
def test_adafactor_kernel_matches_ref(case):
    m, n, t, seed, lr = case
    rng = np.random.default_rng(seed)
    theta = rand(rng, (m, n), 0.1)
    g = rand(rng, (m, n), 0.02)
    r = jnp.asarray(rng.uniform(0, 1e-4, (m,)), jnp.float32)
    c = jnp.asarray(rng.uniform(0, 1e-4, (n,)), jnp.float32)
    got = adafactor_update.adafactor_update(theta, g, r, c, float(t), lr)
    want = ref.adafactor_ref(theta, g, r, c, float(t), lr)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=5e-8)


@given(matrix_case())
@settings(**SETTINGS)
def test_lomo_kernel_matches_ref(case):
    m, n, _, seed, lr = case
    rng = np.random.default_rng(seed)
    theta = rand(rng, (m, n), 0.1)
    g = rand(rng, (m, n), 0.02)
    got = lomo_update.lomo_update(theta, g, lr)
    np.testing.assert_allclose(got, ref.lomo_ref(theta, g, lr), rtol=1e-6, atol=1e-8)


@pytest.mark.parametrize("block_m", [1, 2, 16, 37, 128])
def test_adalomo_block_size_invariance(block_m):
    # The kernel result must not depend on the tiling choice. Requested
    # blocks are snapped to divisors of m (non-divisor tiles would hit
    # interpret-mode OOB padding, which is not zero-guaranteed).
    rng = np.random.default_rng(7)
    m, n = 74, 33  # awkward m: snapping must still cover all rows
    theta = rand(rng, (m, n), 0.1)
    g = rand(rng, (m, n), 0.05)
    r = jnp.zeros((m,), jnp.float32)
    c = jnp.zeros((n,), jnp.float32)
    got = adalomo_update.adalomo_update(
        theta, g, r, c, 1.0, 1e-3, block_m=min(block_m, m))
    want = ref.adalomo_ref(theta, g, r, c, 1.0, 1e-3)
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=3e-5, atol=1e-7)


def test_choose_block_m_divides():
    for m in [1, 2, 7, 64, 100, 128, 129, 1000, 4096]:
        b = tiles.choose_block_m(m)
        assert m % b == 0
        assert b <= max(m, tiles.DEFAULT_BLOCK_M)


def test_adalomo_huge_gradient_is_clipped():
    # Grouped normalization caps the applied update at
    # lr * max(eps, RMS(theta)) per RMS unit, whatever the gradient scale.
    rng = np.random.default_rng(3)
    theta = rand(rng, (32, 16), 0.1)
    g = rand(rng, (32, 16), 1e6)
    r = jnp.zeros((32,), jnp.float32)
    c = jnp.zeros((16,), jnp.float32)
    theta_new, _, _ = adalomo_update.adalomo_update(
        theta, g, r, c, 1.0, 1e-3)
    delta = np.asarray(theta_new - theta)
    rms_delta = np.sqrt((delta ** 2).mean())
    rms_theta = float(jnp.sqrt(jnp.mean(theta ** 2)))
    assert rms_delta <= 1e-3 * max(1e-3, rms_theta) * 1.01


def test_adalomo_zero_grad_zero_update():
    rng = np.random.default_rng(4)
    theta = rand(rng, (8, 8), 0.1)
    g = jnp.zeros((8, 8), jnp.float32)
    r = jnp.zeros((8,), jnp.float32)
    c = jnp.zeros((8,), jnp.float32)
    theta_new, r_new, c_new = adalomo_update.adalomo_update(
        theta, g, r, c, 1.0, 1e-2)
    np.testing.assert_allclose(theta_new, theta, atol=1e-7)
    np.testing.assert_allclose(r_new, 0.0)
    np.testing.assert_allclose(c_new, 0.0)
