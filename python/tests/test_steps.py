# Entry-point builders: blob layout round-trips, train-step semantics,
# fused-group equivalence with the monolithic step, toy-2D consistency.

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import layout, losses, model, steps


CFG = model.PRESETS["nano"]


def batch(seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(
        rng.integers(1, 256, (CFG.batch_size, CFG.seq_len)), jnp.int32)
    y = jnp.asarray(
        rng.integers(1, 256, (CFG.batch_size, CFG.seq_len)), jnp.int32)
    return x, y


def sched(lr=1e-3, t=1.0, wd=0.0, clip=1.0):
    return jnp.asarray([lr, t, wd, clip], jnp.float32)


def init_blob(opt, seed=0):
    init, segs = steps.make_init(CFG, opt)
    return jax.jit(init)(jnp.int32(seed)), segs


def test_layout_pack_unpack_roundtrip():
    _, segs = init_blob("adalomo")
    rng = np.random.default_rng(1)
    blob = jnp.asarray(
        rng.normal(0, 1, (layout.blob_len(segs),)), jnp.float32)
    tensors = layout.unpack(blob, segs)
    blob2 = layout.pack(tensors, segs)
    np.testing.assert_array_equal(blob, blob2)


def test_layouts_params_prefix_shared_across_opts():
    # The parameter prefix must be identical for every optimizer so that
    # checkpoints repack across optimizers (runtime/blob.rs relies on it).
    reference = None
    for opt in ["sgd", "adamw", "adafactor", "lomo", "adalomo"]:
        segs = steps.param_layout(CFG, opt)
        params = [(s.name, s.shape, s.offset) for s in segs
                  if s.kind == layout.KIND_PARAM]
        if reference is None:
            reference = params
        assert params == reference, opt


def test_train_step_decreases_loss_over_steps():
    blob, segs = init_blob("adalomo")
    step, _ = steps.make_train_step(CFG, "adalomo")
    jstep = jax.jit(step)
    x, y = batch()
    moff = [s for s in segs if s.kind == layout.KIND_METRIC][0].offset
    losses_seen = []
    for t in range(1, 9):
        blob = jstep(blob, x, y, sched(lr=5e-3, t=float(t)))
        losses_seen.append(float(blob[moff]))
    assert losses_seen[-1] < losses_seen[0] - 0.05, losses_seen


def test_metrics_slots_populated():
    blob, segs = init_blob("adamw")
    step, _ = steps.make_train_step(CFG, "adamw")
    x, y = batch()
    out = jax.jit(step)(blob, x, y, sched())
    moff = [s for s in segs if s.kind == layout.KIND_METRIC][0].offset
    m = np.asarray(out[moff:moff + layout.METRIC_SLOTS])
    assert 0 < m[layout.M_LOSS] < 10
    assert m[layout.M_TOKENS] == CFG.batch_size * CFG.seq_len
    assert 0 <= m[layout.M_CORRECT] <= m[layout.M_TOKENS]
    assert m[layout.M_GNORM] > 0


def test_gnorm_variant_clips_global_norm():
    # With a tiny clip threshold, the gnorm variant's applied update is
    # scaled down; the resulting parameters differ from the unclipped run.
    blob, segs = init_blob("lomo")
    plain, _ = steps.make_train_step(CFG, "lomo")
    gnorm, _ = steps.make_train_step(CFG, "lomo", gnorm=True)
    x, y = batch()
    lr = 1e-2
    out_plain = jax.jit(plain)(blob, x, y, sched(lr=lr))
    out_gnorm = jax.jit(gnorm)(blob, x, y, sched(lr=lr, clip=1e-3))
    plen = layout.params_len(segs)
    d_plain = np.abs(np.asarray(out_plain[:plen] - blob[:plen])).max()
    d_gnorm = np.abs(np.asarray(out_gnorm[:plen] - blob[:plen])).max()
    assert d_gnorm < d_plain / 10


def test_fused_groups_cover_all_trainables_once():
    groups = steps.fused_groups(CFG)
    assert len(groups) == CFG.n_layers + 2
    flat = [name for g in groups for name in g]
    expected = [n for n, _ in model.param_specs(CFG)]
    assert sorted(flat) == sorted(expected)


def test_fused_chain_equals_monolithic_step():
    # The coordinator's chained group programs must reproduce the
    # monolithic train step exactly (all grads at theta_t) — the key
    # fused-backward semantics check.
    opt = "adalomo"
    blob, segs = init_blob(opt)
    x, y = batch(3)
    s = sched(lr=5e-4, t=1.0)
    mono, _ = steps.make_train_step(CFG, opt)
    expected = jax.jit(mono)(blob, x, y, s)

    accum = blob
    for k in range(len(steps.fused_groups(CFG))):
        fstep, _ = steps.make_fused_group_step(CFG, opt, k)
        accum = jax.jit(fstep)(blob, accum, x, y, s)
    plen = layout.params_len(segs)
    np.testing.assert_allclose(
        accum[:plen], expected[:plen], rtol=2e-5, atol=1e-7)
    # Optimizer state matches too.
    moff = [s2 for s2 in segs if s2.kind == layout.KIND_METRIC][0].offset
    np.testing.assert_allclose(
        accum[plen:moff], expected[plen:moff], rtol=2e-5, atol=1e-7)


def test_extract_and_read_metrics():
    blob, segs = init_blob("adalomo")
    extract, _ = steps.make_extract_params(CFG, "adalomo")
    read, _ = steps.make_read_metrics(CFG, "adalomo")
    p = jax.jit(extract)(blob)
    m = jax.jit(read)(blob)
    assert p.shape == (layout.params_len(segs),)
    assert m.shape == (layout.METRIC_SLOTS,)
    np.testing.assert_array_equal(p, blob[:layout.params_len(segs)])


def test_eval_matches_train_loss_at_same_params():
    blob, segs = init_blob("adalomo")
    extract, _ = steps.make_extract_params(CFG, "adalomo")
    ev = steps.make_eval(CFG)
    x, y = batch(5)
    m = jax.jit(ev)(jax.jit(extract)(blob), x, y)
    tensors = layout.unpack(blob, segs)
    logits = model.forward(CFG, tensors, x)
    loss, tokens, correct = losses.lm_loss(logits, y)
    np.testing.assert_allclose(m[layout.M_LOSS], loss, rtol=1e-5)
    np.testing.assert_allclose(m[layout.M_TOKENS], tokens)
    np.testing.assert_allclose(m[layout.M_CORRECT], correct)


def test_seq_loss_consistent_with_eval():
    blob, segs = init_blob("adalomo")
    extract, _ = steps.make_extract_params(CFG, "adalomo")
    params = jax.jit(extract)(blob)
    sl = steps.make_seq_loss(CFG)
    ev = steps.make_eval(CFG)
    x, y = batch(6)
    per_seq = jax.jit(sl)(params, x, y)
    m = jax.jit(ev)(params, x, y)
    total_loss = float(jnp.sum(per_seq[0]))
    total_count = float(jnp.sum(per_seq[1]))
    np.testing.assert_allclose(
        total_loss / total_count, m[layout.M_LOSS], rtol=1e-5)


def test_lora_train_step_freezes_base():
    blob, segs = init_blob_lora()
    step, _ = steps.make_train_step(
        CFG, "adamw", lora_rank=model.LORA_DEFAULT_RANK)
    x, y = batch(7)
    out = jax.jit(step)(blob, x, y, sched(lr=1e-3))
    frozen = [s for s in segs if s.kind == layout.KIND_FROZEN]
    for s in frozen[:5] + frozen[-2:]:
        np.testing.assert_array_equal(
            out[s.offset:s.offset + s.size],
            blob[s.offset:s.offset + s.size], err_msg=s.name)
    # Adapters did move (B starts at 0 but has gradients).
    trainable = [s for s in segs if s.kind == layout.KIND_PARAM]
    moved = any(
        not np.allclose(out[s.offset:s.offset + s.size],
                        blob[s.offset:s.offset + s.size])
        for s in trainable)
    assert moved


def init_blob_lora(seed=0):
    init, segs = steps.make_init(
        CFG, "adamw", lora_rank=model.LORA_DEFAULT_RANK)
    return jax.jit(init)(jnp.int32(seed)), segs


@pytest.mark.parametrize("opt", ["sgd", "sgd_momentum", "sgd_variance",
                                 "adamw", "adafactor", "lomo", "adalomo"])
def test_every_optimizer_one_step_finite(opt):
    blob, segs = init_blob(opt)
    step, _ = steps.make_train_step(CFG, opt)
    x, y = batch(8)
    out = jax.jit(step)(blob, x, y, sched(lr=1e-3))
    assert out.shape == (layout.blob_len(segs),)
    assert bool(jnp.isfinite(out).all()), opt


def test_toy2d_step_matches_closed_form():
    step, segs = steps.make_toy2d_step("sgd")
    blob = jnp.zeros((layout.blob_len(segs),), jnp.float32)
    blob = blob.at[0].set(0.3).at[1].set(0.9)
    out = jax.jit(step)(blob, sched(lr=0.1, t=1.0))
    xy = jnp.array([0.3, 0.9])
    f, grad = jax.value_and_grad(losses.toy2d)(xy)
    np.testing.assert_allclose(out[:2], xy - 0.1 * grad, rtol=1e-5)
    moff = [s for s in segs if s.kind == layout.KIND_METRIC][0].offset
    np.testing.assert_allclose(out[moff], f, rtol=1e-5)
