# AOT pipeline: lowering produces valid single-output HLO text, the entry
# plan covers every experiment, and the manifest stays consistent with the
# layouts the runtime will trust.

import json
import os

import pytest

from compile import aot, layout, model, steps


def test_plan_covers_experiments():
    entries = aot.plan_entries(["nano"], use_kernels=True)
    names = {e[0] for e in entries}
    # Table 2 needs every optimizer + lora; Fig 1 the ablation arms.
    for opt in ["sgd", "sgd_momentum", "sgd_variance", "adamw",
                "adafactor", "lomo", "adalomo", "lora"]:
        assert f"train_step_nano_{opt}" in names
    # Appendix B: gnorm variants.
    assert "train_step_nano_adalomo_gnorm" in names
    assert "train_step_nano_lomo_gnorm" in names
    # Fused groups (nano: L+2 = 4).
    for k in range(4):
        assert f"fused_nano_adalomo_g{k}" in names
    # Shared eval surface.
    for e in ["eval_nano", "seq_loss_nano", "next_logits_nano",
              "merge_lora_nano", "init_nano_adalomo",
              "extract_params_nano_adalomo", "read_metrics_nano_adalomo"]:
        assert e in names
    # Fig 6.
    for opt in aot.TOY2D_OPTS:
        assert f"toy2d_{opt}" in names


def test_lower_entry_produces_hlo_text():
    cfg = model.PRESETS["nano"]
    step_fn, segs = steps.make_toy2d_step("sgd")
    text = aot.lower_entry(
        "toy2d_sgd", lambda: step_fn,
        [{"shape": [layout.blob_len(segs)], "dtype": "f32"},
         {"shape": [4], "dtype": "f32"}])
    assert "HloModule" in text
    assert "ROOT" in text
    del cfg


def test_layouts_json_offsets_tile_blob():
    out = aot.layouts_json(["nano"])
    for key, rec in out.items():
        off = 0
        for seg in rec["segments"]:
            assert seg["offset"] == off, f"{key}/{seg['name']}"
            expect = 1
            for d in seg["shape"]:
                expect *= d
            assert seg["size"] == max(expect, 1)
            off += seg["size"]
        assert off == rec["blob_len"], key
        assert rec["params_len"] <= rec["blob_len"]


def test_presets_json_param_counts():
    out = aot.presets_json(["nano", "micro"])
    for name, rec in out.items():
        assert rec["n_params"] == model.n_params(model.PRESETS[name])
        assert rec["fused_groups"] == rec["n_layers"] + 2


@pytest.mark.skipif(
    not os.path.exists(os.path.join(os.path.dirname(__file__),
                                    "..", "..", "artifacts",
                                    "manifest.json")),
    reason="artifacts not built")
def test_built_manifest_is_consistent():
    path = os.path.join(os.path.dirname(__file__), "..", "..",
                        "artifacts", "manifest.json")
    with open(path) as f:
        manifest = json.load(f)
    assert manifest["entries"], "manifest has entries"
    for name, e in manifest["entries"].items():
        hlo = os.path.join(os.path.dirname(path), e["file"])
        assert os.path.exists(hlo), f"{name}: missing {e['file']}"
        if e["kind"] == "train_step":
            lay = manifest["layouts"][e["layout"]]
            assert e["inputs"][0]["shape"] == [lay["blob_len"]]
            assert e["output"]["shape"] == [lay["blob_len"]]
        if e["kind"] == "init":
            lay = manifest["layouts"][e["layout"]]
            assert e["output"]["shape"] == [lay["blob_len"]]
