# Layer-2 model: shapes, causality, normalization and LoRA semantics.

import jax
import jax.numpy as jnp
import numpy as np

from compile import losses, model


def cfg():
    return model.PRESETS["nano"]


def params(seed=0):
    return model.init_params(cfg(), seed)


def tokens(rng, b, t):
    return jnp.asarray(rng.integers(1, 256, (b, t)), jnp.int32)


def test_forward_shape_and_finite():
    c = cfg()
    rng = np.random.default_rng(0)
    x = tokens(rng, 2, 16)
    logits = model.forward(c, params(), x)
    assert logits.shape == (2, 16, c.vocab)
    assert bool(jnp.isfinite(logits).all())


def test_causality_no_future_leakage():
    # Changing token at position k must not change logits at positions < k.
    c = cfg()
    rng = np.random.default_rng(1)
    p = params()
    x = tokens(rng, 1, 12)
    k = 7
    x2 = x.at[0, k].set((int(x[0, k]) % 255) + 1)
    l1 = model.forward(c, p, x)
    l2 = model.forward(c, p, x2)
    np.testing.assert_allclose(l1[0, :k], l2[0, :k], atol=1e-5)
    assert not np.allclose(l1[0, k:], l2[0, k:], atol=1e-5)


def test_param_specs_order_deterministic():
    s1 = model.param_specs(cfg())
    s2 = model.param_specs(cfg())
    assert s1 == s2
    assert s1[0][0] == "embed"
    assert s1[-1][0] == "head"
    assert model.n_params(cfg()) == sum(
        int(np.prod(shape)) for _, shape in s1)


def test_init_is_seed_deterministic():
    a = model.init_params(cfg(), 5)
    b = model.init_params(cfg(), 5)
    c2 = model.init_params(cfg(), 6)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any(not np.allclose(a[k], c2[k]) for k in a if k != "final_norm")


def test_rms_norm_unit_scale():
    x = jnp.asarray(np.random.default_rng(2).normal(0, 10, (4, 8)),
                    jnp.float32)
    y = model.rms_norm(x, jnp.ones((8,), jnp.float32))
    rms = jnp.sqrt(jnp.mean(y * y, axis=-1))
    np.testing.assert_allclose(rms, 1.0, rtol=1e-3)


def test_rope_preserves_norm():
    c = cfg()
    cos, sin = model.rope_tables(c, 16)
    x = jnp.asarray(
        np.random.default_rng(3).normal(0, 1, (1, c.n_heads, 16, c.d_head)),
        jnp.float32)
    y = model.apply_rope(x, cos[None, None], sin[None, None])
    np.testing.assert_allclose(
        jnp.linalg.norm(y, axis=-1), jnp.linalg.norm(x, axis=-1), rtol=1e-4)


def test_rope_position_zero_is_identity():
    c = cfg()
    cos, sin = model.rope_tables(c, 4)
    x = jnp.asarray(
        np.random.default_rng(4).normal(0, 1, (1, 1, 4, c.d_head)),
        jnp.float32)
    y = model.apply_rope(x, cos[None, None], sin[None, None])
    np.testing.assert_allclose(y[0, 0, 0], x[0, 0, 0], atol=1e-6)


def test_lora_zero_b_is_identity():
    # Freshly initialized LoRA (B = 0) must not change the forward pass.
    c = cfg()
    rng = np.random.default_rng(5)
    p = params()
    lora = model.init_lora(c, 0)
    x = tokens(rng, 1, 8)
    base = model.forward(c, p, x)
    with_lora = model.forward(c, p, x, lora=lora)
    np.testing.assert_allclose(base, with_lora, atol=1e-6)


def test_lora_merge_matches_adapter_forward():
    c = cfg()
    rng = np.random.default_rng(6)
    p = params()
    key = jax.random.PRNGKey(9)
    lora = {
        k: 0.02 * jax.random.normal(jax.random.fold_in(key, i),
                                    v.shape, jnp.float32)
        for i, (k, v) in enumerate(model.init_lora(c, 0).items())
    }
    x = tokens(rng, 1, 8)
    via_adapter = model.forward(c, p, x, lora=lora)
    merged = model.merge_lora(c, p, lora)
    via_merge = model.forward(c, merged, x)
    np.testing.assert_allclose(via_adapter, via_merge, rtol=2e-4, atol=1e-5)


def test_lm_loss_masks_pad():
    logits = jnp.zeros((1, 4, 8), jnp.float32)
    y = jnp.asarray([[1, 2, 0, 0]], jnp.int32)
    loss, n, correct = losses.lm_loss(logits, y)
    assert float(n) == 2.0
    np.testing.assert_allclose(loss, np.log(8.0), rtol=1e-5)
    assert float(correct) <= 2.0


def test_toy2d_landscape_values():
    # Minima depths: f(-1,0) ~ 1 - 3 = -2ish, f(1,0) ~ 1 - 2 = -1ish.
    f_global = losses.toy2d(jnp.array([-0.94, 0.0]))
    f_local = losses.toy2d(jnp.array([0.9, 0.0]))
    assert float(f_global) < float(f_local) < 0.0
