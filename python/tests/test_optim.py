# Optimizer semantics: the paper's mathematical claims about the update
# rules, independent of any kernel.

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import optim
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def test_factorization_identity_rank1():
    # Eq. 5 is exact when the EMA of g^2 is rank-1.
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.1, 1.0, (12,)), jnp.float32)
    b = jnp.asarray(rng.uniform(0.1, 1.0, (7,)), jnp.float32)
    v_true = jnp.outer(a, b)
    r = jnp.sum(v_true, axis=1)
    c = jnp.sum(v_true, axis=0)
    v_rec = ref.factored_v(r, c)
    np.testing.assert_allclose(v_rec, v_true, rtol=1e-5)


@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_factored_v_nonnegative_and_scale(seed):
    rng = np.random.default_rng(seed)
    g2 = jnp.asarray(rng.uniform(0, 1.0, (9, 5)), jnp.float32)
    r = jnp.sum(g2, axis=1)
    c = jnp.sum(g2, axis=0)
    v = ref.factored_v(r, c)
    assert (np.asarray(v) >= 0).all()
    # Total mass is preserved: sum(v) == sum(g2).
    np.testing.assert_allclose(jnp.sum(v), jnp.sum(g2), rtol=1e-4)


def test_bias_correction_first_step():
    # At t=1, v_hat = g^2 exactly, so the sgd_variance update is
    # lr * sign(g) regardless of |g| (the adaptivity the paper leans on).
    for mag in [1e-4, 1.0, 1e4]:
        theta = jnp.zeros((1,), jnp.float32)
        v = jnp.zeros((1,), jnp.float32)
        g = jnp.full((1,), mag, jnp.float32)
        theta_new, _ = ref.sgd_variance_ref(theta, g, v, 1.0, 0.1)
        np.testing.assert_allclose(theta_new, -0.1, rtol=1e-3)


def test_ema_fixed_point():
    # Constant gradients: r converges to rowsum(g^2).
    g = jnp.full((4, 3), 0.5, jnp.float32)
    r = jnp.zeros((4,), jnp.float32)
    c = jnp.zeros((3,), jnp.float32)
    theta = jnp.ones((4, 3), jnp.float32)
    for t in range(1, 200):
        theta, r, c = ref.adalomo_ref(theta, g, r, c, float(t), 0.0)
    np.testing.assert_allclose(r, 3 * 0.25, rtol=1e-3)
    np.testing.assert_allclose(c, 4 * 0.25, rtol=1e-3)


@given(st.integers(0, 10_000))
@settings(**SETTINGS)
def test_grouped_norm_bounds(seed):
    rng = np.random.default_rng(seed)
    u = jnp.asarray(rng.normal(0, 10 ** rng.uniform(-3, 3), (6, 6)),
                    jnp.float32)
    theta = jnp.asarray(rng.normal(0, 0.3, (6, 6)), jnp.float32)
    u_hat = ref.grouped_normalize(u, theta)
    rms_u_hat = float(ref.rms(u_hat))
    bound = max(1e-3, float(ref.rms(theta)))
    assert rms_u_hat <= bound * 1.001
    # Direction preserved.
    assert jnp.sum(u * u_hat) >= 0


def test_grouped_norm_passthrough_for_small_updates():
    # RMS(u) < 1: no clipping, just relative scaling by RMS(theta).
    u = jnp.full((4,), 0.5, jnp.float32)
    theta = jnp.full((4,), 2.0, jnp.float32)
    u_hat = ref.grouped_normalize(u, theta)
    np.testing.assert_allclose(u_hat, 1.0, rtol=1e-5)


def test_no_sqrt_variant_differs_but_same_direction():
    rng = np.random.default_rng(1)
    theta = jnp.asarray(rng.normal(0, 0.1, (8, 8)), jnp.float32)
    g = jnp.asarray(rng.normal(0, 0.01, (8, 8)), jnp.float32)
    r = jnp.zeros((8,), jnp.float32)
    c = jnp.zeros((8,), jnp.float32)
    a, _, _ = ref.adalomo_ref(theta, g, r, c, 1.0, 1e-3, no_sqrt=False)
    b, _, _ = ref.adalomo_ref(theta, g, r, c, 1.0, 1e-3, no_sqrt=True)
    da, db = np.asarray(a - theta), np.asarray(b - theta)
    assert not np.allclose(da, db)
    # Both scale-invariant forms step within the grouped-norm bound.
    for d in (da, db):
        assert np.sqrt((d ** 2).mean()) <= 1e-3 * max(
            1e-3, float(ref.rms(theta))) * 1.01


def test_registry_state_specs():
    specs = [("w", (8, 4)), ("b", (4,))]
    assert optim.state_specs_for("adalomo", specs) == [
        ("w@r", (8,)), ("w@c", (4,)), ("b@v", (4,))]
    assert optim.state_specs_for("adamw", specs) == [
        ("w@m", (8, 4)), ("w@v", (8, 4)), ("b@m", (4,)), ("b@v", (4,))]
    assert optim.state_specs_for("sgd", specs) == []
    assert optim.state_specs_for("lomo", specs) == []


def test_adamw_weight_decay_decoupled():
    # With zero gradient, AdamW still shrinks weights by lr*wd.
    theta = jnp.ones((3,), jnp.float32)
    g = jnp.zeros((3,), jnp.float32)
    m = jnp.zeros((3,), jnp.float32)
    v = jnp.zeros((3,), jnp.float32)
    theta_new, _, _ = ref.adamw_ref(theta, g, m, v, 1.0, 0.1, wd=0.5)
    np.testing.assert_allclose(theta_new, 0.95, rtol=1e-6)


def test_adafactor_relative_step():
    # The applied step scales with RMS(theta) (relative step size).
    rng = np.random.default_rng(2)
    g = jnp.asarray(rng.normal(0, 0.01, (6, 6)), jnp.float32)
    r = jnp.zeros((6,), jnp.float32)
    c = jnp.zeros((6,), jnp.float32)
    small = jnp.full((6, 6), 0.01, jnp.float32)
    big = jnp.full((6, 6), 1.0, jnp.float32)
    s_new, _, _ = ref.adafactor_ref(small, g, r, c, 1.0, 0.1)
    b_new, _, _ = ref.adafactor_ref(big, g, r, c, 1.0, 0.1)
    d_small = float(ref.rms(s_new - small))
    d_big = float(ref.rms(b_new - big))
    assert d_big > d_small * 10
