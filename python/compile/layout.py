# Training-state blob layout.
#
# Every AOT entry point exchanges model state with the Rust coordinator as a
# SINGLE flat f32 array ("blob"): parameters first, then optimizer state,
# then an 8-slot metrics region. A single-array root means PJRT hands Rust
# one non-tuple output buffer per step, which feeds straight back into the
# next step via execute_b — the hot path never leaves the device and never
# decomposes tuples on the host.
#
# The layout (segment name/kind/shape/offset) is serialized into
# artifacts/manifest.json; the Rust side uses it for initialization,
# checkpointing, ZeRO-3 shard planning and the memory simulator.

from dataclasses import dataclass

import jax.numpy as jnp

METRIC_SLOTS = 8
# Metric slot indices (shared contract with rust/src/runtime/metrics).
M_LOSS = 0      # mean loss over counted tokens
M_TOKENS = 1    # number of loss-counted tokens in the batch
M_CORRECT = 2   # correct next-token predictions among counted tokens
M_GNORM = 3     # global gradient norm (pre-clipping)

KIND_PARAM = "param"      # trainable parameter
KIND_FROZEN = "frozen"    # present in the blob, never updated (LoRA base)
KIND_STATE = "state"      # optimizer state
KIND_METRIC = "metric"


@dataclass
class Segment:
    name: str
    kind: str
    shape: tuple
    offset: int

    @property
    def size(self):
        n = 1
        for d in self.shape:
            n *= d
        return n


def build_segments(param_specs, state_specs):
    """Assemble the blob layout.

    param_specs: [(name, shape, kind)] with kind in {param, frozen};
    state_specs: [(name, shape)].
    """
    segs, off = [], 0
    for name, shape, kind in param_specs:
        s = Segment(name, kind, tuple(shape), off)
        segs.append(s)
        off += s.size
    for name, shape in state_specs:
        s = Segment(name, KIND_STATE, tuple(shape), off)
        segs.append(s)
        off += s.size
    segs.append(Segment("metrics", KIND_METRIC, (METRIC_SLOTS,), off))
    return segs


def blob_len(segs):
    last = segs[-1]
    return last.offset + last.size


def params_len(segs):
    """Length of the leading parameter region (param + frozen kinds)."""
    n = 0
    for s in segs:
        if s.kind in (KIND_PARAM, KIND_FROZEN):
            n += s.size
        else:
            break
    return n


def unpack(blob, segs):
    """blob (f32[blob_len]) -> dict name -> array of segment shape."""
    out = {}
    for s in segs:
        flat = jnp.ravel(blob)[s.offset:s.offset + s.size]
        out[s.name] = jnp.reshape(flat, s.shape)
    return out


def pack(tensors, segs):
    """dict name -> array back into the flat blob (inverse of unpack)."""
    parts = [jnp.reshape(tensors[s.name], (-1,)) for s in segs]
    return jnp.concatenate(parts)


def segments_json(segs):
    return [
        {"name": s.name, "kind": s.kind, "shape": list(s.shape),
         "offset": s.offset, "size": s.size}
        for s in segs
    ]
