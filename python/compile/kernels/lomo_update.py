# Layer-1 kernel: the LOMO update (paper Eq. 1) -- plain SGD fused into the
# backward pass. Elementwise, single streaming pass, one (block_m, n) stripe
# per grid step.

import jax.numpy as jnp

from . import ref, tiles


def _lomo_kernel(lr_ref, theta_ref, g_ref, out_ref):
    out_ref[...] = theta_ref[...] - lr_ref[0] * g_ref[...]


def lomo_update(theta, g, lr, block_m=None):
    """theta' = theta - lr * g for a 2-D parameter (Pallas)."""
    if theta.ndim != 2 or theta.size < tiles.MIN_KERNEL_ELEMS:
        return ref.lomo_ref(theta, g, lr)
    m, n = theta.shape
    bm = tiles.choose_block_m(m, block_m or tiles.DEFAULT_BLOCK_M)
    lr_arr = jnp.reshape(jnp.asarray(lr, jnp.float32), (1,))
    return tiles.pallas_call(
        _lomo_kernel,
        grid=tiles.row_grid(m, bm),
        in_specs=[tiles.scalar_spec(1), tiles.stripe_spec(bm, n),
                  tiles.stripe_spec(bm, n)],
        out_specs=tiles.stripe_spec(bm, n),
        out_shape=tiles.f32((m, n)),
    )(lr_arr, theta, g)
