# Pure-jnp correctness oracles for every optimizer update rule.
#
# These are the single source of truth for the math: the Pallas kernels
# (adalomo_update.py, lomo_update.py, adamw_update.py, adafactor_update.py)
# are tested against these functions, and the Rust-native optimizers in
# rust/src/optim/ mirror them (cross-checked by the integration_optim_parity
# test through the AOT artifacts).
#
# Paper: "AdaLomo: Low-memory Optimization with Adaptive Learning Rate"
# (Lv et al., Findings of ACL 2024). Equation references below are to the
# paper; see DESIGN.md "Faithfulness notes" for the Algorithm-1 line-10
# sqrt ambiguity (we default to u = g / sqrt(v_hat + eps_div), matching the
# released OpenLMLab/LOMO code; `no_sqrt=True` gives the literal printed
# form).

import jax.numpy as jnp

# --- default hyper-parameters (released-code defaults) ---------------------
ADALOMO_BETA = 0.85      # EMA decay for the factored second moment
ADALOMO_EPS_RMS = 1e-3   # eps in Algorithm 1 line 11: max(eps, RMS(theta))
ADALOMO_EPS_DIV = 1e-30  # guard inside the sqrt/division
ADAM_BETA1 = 0.9
ADAM_BETA2 = 0.999
ADAM_EPS = 1e-8
ADAFACTOR_DECAY_POW = 0.8   # beta2_t = 1 - t^-0.8  (Shazeer & Stern, 2018)
ADAFACTOR_EPS1 = 1e-30
ADAFACTOR_EPS2 = 1e-3
ADAFACTOR_CLIP_D = 1.0


def rms(x):
    """Root-mean-square over all elements (paper footnote 1)."""
    return jnp.sqrt(jnp.mean(jnp.square(x)))


def factored_v(r, c, eps=ADALOMO_EPS_DIV):
    """Reconstruct the second moment from its NMF factors (paper Eq. 5).

    v = r c / (1^T r); r holds row sums, c holds column sums of the EMA of
    g^2, so dividing by sum(r) restores the magnitude of E[g^2].
    """
    denom = jnp.maximum(jnp.sum(r), eps)
    return jnp.outer(r, c) / denom


def grouped_normalize(u, theta, eps_rms=ADALOMO_EPS_RMS):
    """Grouped update normalization (Algorithm 1, line 11).

    u_hat = u / max(1, RMS(u)) * max(eps, RMS(theta)).
    Per-parameter-matrix: RMS is taken over this parameter only, which is
    what lets AdaLomo normalize inside a single fused backward pass.
    """
    scale = jnp.maximum(eps_rms, rms(theta)) / jnp.maximum(1.0, rms(u))
    return u * scale


def adalomo_ref(theta, g, r, c, t, lr,
                beta=ADALOMO_BETA, eps_rms=ADALOMO_EPS_RMS,
                eps_div=ADALOMO_EPS_DIV, no_sqrt=False):
    """One AdaLomo step (Algorithm 1 lines 7-12) for a 2-D parameter.

    theta, g: (m, n); r: (m,); c: (n,). t is the 1-based step count.
    Returns (theta', r', c').
    """
    g2 = jnp.square(g)
    r_new = beta * r + (1.0 - beta) * jnp.sum(g2, axis=1)   # line 7
    c_new = beta * c + (1.0 - beta) * jnp.sum(g2, axis=0)   # line 8
    v = factored_v(r_new, c_new)                             # line 9
    bias = 1.0 - jnp.power(beta, t)
    v_hat = v / bias
    if no_sqrt:
        u = g / (v_hat + eps_div)                            # literal line 10
    else:
        u = g / jnp.sqrt(v_hat + eps_div)                    # released code
    u_hat = grouped_normalize(u, theta, eps_rms)             # line 11
    theta_new = theta - lr * u_hat                           # line 12
    return theta_new, r_new, c_new


def adalomo_vector_ref(theta, g, v, t, lr,
                       beta=ADALOMO_BETA, eps_rms=ADALOMO_EPS_RMS,
                       eps_div=ADALOMO_EPS_DIV, no_sqrt=False):
    """AdaLomo step for 1-D/0-D parameters: factorization degenerates, so a
    full second moment is kept (same choice as Adafactor)."""
    v_new = beta * v + (1.0 - beta) * jnp.square(g)
    bias = 1.0 - jnp.power(beta, t)
    v_hat = v_new / bias
    if no_sqrt:
        u = g / (v_hat + eps_div)
    else:
        u = g / jnp.sqrt(v_hat + eps_div)
    u_hat = grouped_normalize(u, theta, eps_rms)
    theta_new = theta - lr * u_hat
    return theta_new, v_new


def lomo_ref(theta, g, lr):
    """One LOMO step: plain SGD fused into the backward pass (paper Eq. 1)."""
    return theta - lr * g


def sgd_momentum_ref(theta, g, m, t, lr, beta1=ADAM_BETA1):
    """SGD keeping only the first moment (paper Eq. 3)."""
    m_new = beta1 * m + (1.0 - beta1) * g
    m_hat = m_new / (1.0 - jnp.power(beta1, t))
    return theta - lr * m_hat, m_new


def sgd_variance_ref(theta, g, v, t, lr, beta2=ADAM_BETA2, eps=ADAM_EPS):
    """SGD keeping only the second moment (paper Eq. 4)."""
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
    v_hat = v_new / (1.0 - jnp.power(beta2, t))
    return theta - lr * g / (jnp.sqrt(v_hat) + eps), v_new


def adamw_ref(theta, g, m, v, t, lr,
              beta1=ADAM_BETA1, beta2=ADAM_BETA2, eps=ADAM_EPS, wd=0.0):
    """One AdamW step (paper Eq. 2 + decoupled weight decay).

    wd=0 recovers plain Adam.
    """
    m_new = beta1 * m + (1.0 - beta1) * g
    v_new = beta2 * v + (1.0 - beta2) * jnp.square(g)
    m_hat = m_new / (1.0 - jnp.power(beta1, t))
    v_hat = v_new / (1.0 - jnp.power(beta2, t))
    update = m_hat / (jnp.sqrt(v_hat) + eps)
    theta_new = theta - lr * (update + wd * theta)
    return theta_new, m_new, v_new


def adafactor_ref(theta, g, r, c, t, lr,
                  eps1=ADAFACTOR_EPS1, eps2=ADAFACTOR_EPS2,
                  clip_d=ADAFACTOR_CLIP_D, decay_pow=ADAFACTOR_DECAY_POW):
    """One Adafactor step (Shazeer & Stern, 2018) for a 2-D parameter,
    momentum-less, with relative step size and update clipping.

    `lr` plays the role of rho_t; the applied step is
    alpha_t = max(eps2, RMS(theta)) * lr.
    """
    beta2_t = 1.0 - jnp.power(t, -decay_pow)
    g2 = jnp.square(g) + eps1
    r_new = beta2_t * r + (1.0 - beta2_t) * jnp.sum(g2, axis=1)
    c_new = beta2_t * c + (1.0 - beta2_t) * jnp.sum(g2, axis=0)
    v = factored_v(r_new, c_new, eps1)
    u = g / jnp.sqrt(v + eps1)
    u = u / jnp.maximum(1.0, rms(u) / clip_d)
    alpha = jnp.maximum(eps2, rms(theta)) * lr
    theta_new = theta - alpha * u
    return theta_new, r_new, c_new


def adafactor_vector_ref(theta, g, v, t, lr,
                         eps1=ADAFACTOR_EPS1, eps2=ADAFACTOR_EPS2,
                         clip_d=ADAFACTOR_CLIP_D,
                         decay_pow=ADAFACTOR_DECAY_POW):
    """Adafactor step for 1-D/0-D parameters (full second moment)."""
    beta2_t = 1.0 - jnp.power(t, -decay_pow)
    v_new = beta2_t * v + (1.0 - beta2_t) * (jnp.square(g) + eps1)
    u = g / jnp.sqrt(v_new + eps1)
    u = u / jnp.maximum(1.0, rms(u) / clip_d)
    alpha = jnp.maximum(eps2, rms(theta)) * lr
    theta_new = theta - alpha * u
    return theta_new, v_new
