# Layer-1 kernel: fused AdamW step (paper Eq. 2 + decoupled weight decay).
# Fully elementwise -- both moment EMAs, bias correction, the adaptive
# division and the decayed parameter write happen in one pass over the
# stripe, so g is read exactly once (the GPU version's "update in registers"
# becomes "update in VMEM").

import jax.numpy as jnp

from . import ref, tiles


def _adamw_kernel(aux_ref, theta_ref, g_ref, m_ref, v_ref,
                  theta_out, m_out, v_out):
    # aux = [lr, bias1, bias2, wd]  (bias_i = 1 - beta_i^t, host-side)
    lr, bias1, bias2, wd = aux_ref[0], aux_ref[1], aux_ref[2], aux_ref[3]
    g = g_ref[...]
    m_new = ref.ADAM_BETA1 * m_ref[...] + (1.0 - ref.ADAM_BETA1) * g
    v_new = ref.ADAM_BETA2 * v_ref[...] + (1.0 - ref.ADAM_BETA2) * jnp.square(g)
    update = (m_new / bias1) / (jnp.sqrt(v_new / bias2) + ref.ADAM_EPS)
    theta_out[...] = theta_ref[...] - lr * (update + wd * theta_ref[...])
    m_out[...] = m_new
    v_out[...] = v_new


def adamw_update(theta, g, m, v, t, lr, wd=0.0, block_m=None):
    """AdamW step for a 2-D parameter (Pallas). wd=0 recovers Adam.

    Returns (theta', m', v'); semantics identical to ref.adamw_ref.
    """
    if theta.ndim != 2 or theta.size < tiles.MIN_KERNEL_ELEMS:
        return ref.adamw_ref(theta, g, m, v, t, lr, wd=wd)
    mm, n = theta.shape
    bm = tiles.choose_block_m(mm, block_m or tiles.DEFAULT_BLOCK_M)
    t = jnp.asarray(t, jnp.float32)
    aux = jnp.stack([
        jnp.asarray(lr, jnp.float32),
        1.0 - jnp.power(jnp.float32(ref.ADAM_BETA1), t),
        1.0 - jnp.power(jnp.float32(ref.ADAM_BETA2), t),
        jnp.asarray(wd, jnp.float32),
    ])
    stripe = tiles.stripe_spec(bm, n)
    return tiles.pallas_call(
        _adamw_kernel,
        grid=tiles.row_grid(mm, bm),
        in_specs=[tiles.scalar_spec(4), stripe, stripe, stripe, stripe],
        out_specs=[stripe, stripe, stripe],
        out_shape=[tiles.f32((mm, n))] * 3,
    )(aux, theta, g, m, v)
