# Layer-1 kernel: Adafactor step (Shazeer & Stern, 2018) for 2-D parameters.
# Shares the three-stage structure of the AdaLomo kernel (the AdaLomo paper
# derives its factored second moment from Adafactor); the differences are
# the time-dependent decay beta2_t = 1 - t^-0.8, the eps1 floor added to
# g^2 before factoring, update clipping at d=1.0, and the relative step
# alpha = max(eps2, RMS(theta)) * lr.

import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref, tiles


def _moments_kernel(aux_ref, g_ref, r_ref, c_ref, r_out, c_out):
    beta2t = aux_ref[0]
    g2 = jnp.square(g_ref[...]) + ref.ADAFACTOR_EPS1
    r_out[...] = beta2t * r_ref[...] + (1.0 - beta2t) * jnp.sum(g2, axis=1)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        c_out[...] = beta2t * c_ref[...]

    c_out[...] += (1.0 - beta2t) * jnp.sum(g2, axis=0)


def _u_tile(g, r, c, sum_r):
    v = (r[:, None] * c[None, :]) / jnp.maximum(sum_r, ref.ADAFACTOR_EPS1)
    return g / jnp.sqrt(v + ref.ADAFACTOR_EPS1)


def _stats_kernel(aux_ref, g_ref, r_ref, c_ref, theta_ref, stats_out):
    u = _u_tile(g_ref[...], r_ref[...], c_ref[...], aux_ref[1])

    @pl.when(pl.program_id(0) == 0)
    def _init():
        stats_out[...] = jnp.zeros_like(stats_out)

    stats_out[0] += jnp.sum(jnp.square(u))
    stats_out[1] += jnp.sum(jnp.square(theta_ref[...]))


def _apply_kernel(aux_ref, scale_ref, g_ref, r_ref, c_ref, theta_ref, out_ref):
    u = _u_tile(g_ref[...], r_ref[...], c_ref[...], aux_ref[1])
    out_ref[...] = theta_ref[...] - scale_ref[0] * u


def adafactor_update(theta, g, r, c, t, lr, block_m=None):
    """Adafactor step for a 2-D parameter via the Pallas pipeline.

    Semantics identical to ref.adafactor_ref; returns (theta', r', c').
    """
    m, n = theta.shape
    if m * n < tiles.MIN_KERNEL_ELEMS:
        return ref.adafactor_ref(theta, g, r, c, t, lr)
    bm = tiles.choose_block_m(m, block_m or tiles.DEFAULT_BLOCK_M)
    grid = tiles.row_grid(m, bm)
    t = jnp.asarray(t, jnp.float32)
    beta2t = 1.0 - jnp.power(t, -ref.ADAFACTOR_DECAY_POW)
    aux0 = jnp.stack([beta2t, jnp.float32(0.0)])

    r_new, c_new = tiles.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[tiles.scalar_spec(2), tiles.stripe_spec(bm, n),
                  tiles.rowvec_spec(bm), tiles.colvec_spec(n)],
        out_specs=[tiles.rowvec_spec(bm), tiles.colvec_spec(n)],
        out_shape=[tiles.f32((m,)), tiles.f32((n,))],
    )(aux0, g, r, c)

    aux = jnp.stack([beta2t, jnp.sum(r_new)])
    stats = tiles.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[tiles.scalar_spec(2), tiles.stripe_spec(bm, n),
                  tiles.rowvec_spec(bm), tiles.colvec_spec(n),
                  tiles.stripe_spec(bm, n)],
        out_specs=tiles.scalar_spec(2),
        out_shape=tiles.f32((2,)),
    )(aux, g, r_new, c_new, theta)

    count = jnp.float32(m * n)
    rms_u = jnp.sqrt(stats[0] / count)
    rms_theta = jnp.sqrt(stats[1] / count)
    clip = jnp.maximum(1.0, rms_u / ref.ADAFACTOR_CLIP_D)
    alpha = jnp.maximum(ref.ADAFACTOR_EPS2, rms_theta) * jnp.asarray(lr, jnp.float32)
    scale_arr = jnp.reshape(alpha / clip, (1,))

    theta_new = tiles.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[tiles.scalar_spec(2), tiles.scalar_spec(1),
                  tiles.stripe_spec(bm, n), tiles.rowvec_spec(bm),
                  tiles.colvec_spec(n), tiles.stripe_spec(bm, n)],
        out_specs=tiles.stripe_spec(bm, n),
        out_shape=tiles.f32((m, n)),
    )(aux, scale_arr, g, r_new, c_new, theta)

    return theta_new, r_new, c_new


def adafactor_update_vector(theta, g, v, t, lr, **kw):
    """1-D/0-D parameters keep a full second moment (ref path)."""
    return ref.adafactor_vector_ref(theta, g, v, t, lr, **kw)
