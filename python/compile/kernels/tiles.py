# Shared tiling helpers for the Layer-1 Pallas update kernels.
#
# Hardware adaptation (DESIGN.md §2): the paper's fused CUDA update becomes a
# row-tiled streaming kernel. Each grid step owns a (block_m, n) stripe of
# the parameter/gradient matrix in VMEM; the row-factor r is blocked with the
# stripe, while the column-factor c and the scalar statistics are "revisited"
# blocks accumulated across the sequential grid — the Pallas idiom for the
# cross-threadblock reductions the GPU version would do with atomics.
#
# All kernels run with interpret=True: CPU PJRT cannot execute Mosaic
# custom-calls, and interpret-mode lowering turns the grid into plain HLO
# control flow that the Rust runtime executes directly (see
# /opt/xla-example/README.md).

import jax
from jax.experimental import pallas as pl

# Default row-block target. 128 rows x n cols x 4 B stays well under a 16 MB
# VMEM budget for every matrix shape in our presets (n <= 2048 -> 1 MB/stripe)
# while keeping the sequential grid short in interpret mode.
# ADALOMO_BLOCK_M overrides it for the perf pass's block-shape sweep
# (EXPERIMENTS.md §Perf).
import os

DEFAULT_BLOCK_M = int(os.environ.get("ADALOMO_BLOCK_M", "128"))

# Matrices smaller than this are not worth a kernel launch pipeline; callers
# fall back to the pure-jnp reference (identical math) below this size.
MIN_KERNEL_ELEMS = 2


def choose_block_m(m, target=DEFAULT_BLOCK_M):
    """Largest divisor of m that is <= target.

    Non-divisor blocks would exercise Pallas' out-of-bounds padding
    semantics, which interpret mode does not guarantee to be zero-filled —
    so every caller snaps its requested block to a divisor (kernels pass
    their block_m through this function).
    """
    if m <= target:
        return m
    for d in range(target, 0, -1):
        if m % d == 0:
            return d
    return 1  # unreachable: 1 divides m


def row_grid(m, block_m):
    return (m // block_m,)


def stripe_spec(block_m, n):
    """BlockSpec for a (block_m, n) row stripe of an (m, n) matrix."""
    return pl.BlockSpec((block_m, n), lambda i: (i, 0))


def rowvec_spec(block_m):
    """BlockSpec for the (block_m,) slice of a length-m row vector."""
    return pl.BlockSpec((block_m,), lambda i: (i,))


def colvec_spec(n):
    """BlockSpec for a full length-n column vector, revisited by every grid
    step (index map is constant -> accumulation target)."""
    return pl.BlockSpec((n,), lambda i: (0,))


def scalar_spec(k):
    """BlockSpec for a small (k,) auxiliary/statistics vector, revisited by
    every grid step."""
    return pl.BlockSpec((k,), lambda i: (0,))


def pallas_call(kernel, *, grid, in_specs, out_specs, out_shape):
    """pl.pallas_call pinned to interpret mode (see module docstring)."""
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=True,
    )


def f32(shape):
    import jax.numpy as jnp

    return jax.ShapeDtypeStruct(shape, jnp.float32)
