# Layer-1 flagship kernel: the AdaLomo fused update (Algorithm 1, lines 7-12)
# for a 2-D parameter matrix, as a three-stage Pallas pipeline.
#
# The grouped update normalization (line 11) needs RMS(u) and RMS(theta) over
# the *whole* parameter matrix, so a mathematically-single-pass kernel is
# impossible; the paper's win over LOMO's gradient normalization is that the
# reduction is per-parameter (inside one fused backward), not that it is
# pass-free. We implement the minimal three streaming passes over g:
#
#   K1 moments : g            -> r' = beta r + (1-beta) rowsum(g^2)
#                                c' = beta c + (1-beta) colsum(g^2)
#   K2 stats   : g, r', c'    -> sum(u^2), sum(theta^2)   (u recomputed,
#                                never materialized -- saves an m*n buffer)
#   K3 apply   : theta, g, .. -> theta' = theta - lr * u_hat
#
# Each pass is a 1-D grid over (block_m, n) row stripes; c' and the scalar
# statistics are revisited blocks accumulated across the sequential grid.
# VMEM per grid step: (block_m*n [g] + block_m*n [theta, K3 only] + block_m
# + n + aux) * 4 B -- ~1 MB at the default block for n=2048.

import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref, tiles


def _moments_kernel(beta_ref, g_ref, r_ref, c_ref, r_out, c_out):
    beta = beta_ref[0]
    g2 = jnp.square(g_ref[...])
    # Row blocks are disjoint across the grid: direct EMA write.
    r_out[...] = beta * r_ref[...] + (1.0 - beta) * jnp.sum(g2, axis=1)
    # The column factor is shared by all grid steps: initialize with the
    # decayed old value once, then accumulate each stripe's column sums.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        c_out[...] = beta * c_ref[...]

    c_out[...] += (1.0 - beta) * jnp.sum(g2, axis=0)


def _u_tile(g, r, c, aux):
    """Recompute the raw update u = g / sqrt(v_hat + eps) for one stripe.

    aux = [sum_r, bias_correction, eps_div, _]; v = outer(r, c) / sum_r
    (paper Eq. 5), v_hat = v / (1 - beta^t).
    """
    sum_r = jnp.maximum(aux[0], aux[2])
    bias = aux[1]
    v = (r[:, None] * c[None, :]) / sum_r
    return g / jnp.sqrt(v / bias + aux[2])


def _stats_kernel(aux_ref, g_ref, r_ref, c_ref, theta_ref, stats_out):
    u = _u_tile(g_ref[...], r_ref[...], c_ref[...], aux_ref[...])

    @pl.when(pl.program_id(0) == 0)
    def _init():
        stats_out[...] = jnp.zeros_like(stats_out)

    stats_out[0] += jnp.sum(jnp.square(u))
    stats_out[1] += jnp.sum(jnp.square(theta_ref[...]))


def _apply_kernel(aux_ref, scale_ref, g_ref, r_ref, c_ref, theta_ref, out_ref):
    u = _u_tile(g_ref[...], r_ref[...], c_ref[...], aux_ref[...])
    # scale = lr * max(eps_rms, RMS(theta)) / max(1, RMS(u)), precomputed.
    out_ref[...] = theta_ref[...] - scale_ref[0] * u


def adalomo_update(theta, g, r, c, t, lr,
                   beta=ref.ADALOMO_BETA, eps_rms=ref.ADALOMO_EPS_RMS,
                   eps_div=ref.ADALOMO_EPS_DIV, block_m=None):
    """AdaLomo step for a 2-D parameter via the Pallas pipeline.

    Semantics identical to ref.adalomo_ref (pytest + hypothesis enforce
    this); returns (theta', r', c').
    """
    m, n = theta.shape
    if m * n < tiles.MIN_KERNEL_ELEMS:
        return ref.adalomo_ref(theta, g, r, c, t, lr, beta, eps_rms, eps_div)
    bm = tiles.choose_block_m(m, block_m or tiles.DEFAULT_BLOCK_M)
    grid = tiles.row_grid(m, bm)
    t = jnp.asarray(t, jnp.float32)
    lr = jnp.asarray(lr, jnp.float32)
    beta_arr = jnp.array([beta], jnp.float32)

    r_new, c_new = tiles.pallas_call(
        _moments_kernel,
        grid=grid,
        in_specs=[tiles.scalar_spec(1), tiles.stripe_spec(bm, n),
                  tiles.rowvec_spec(bm), tiles.colvec_spec(n)],
        out_specs=[tiles.rowvec_spec(bm), tiles.colvec_spec(n)],
        out_shape=[tiles.f32((m,)), tiles.f32((n,))],
    )(beta_arr, g, r, c)

    bias = 1.0 - jnp.power(beta, t)
    aux = jnp.stack([jnp.sum(r_new), bias,
                     jnp.float32(eps_div), jnp.float32(0.0)])

    stats = tiles.pallas_call(
        _stats_kernel,
        grid=grid,
        in_specs=[tiles.scalar_spec(4), tiles.stripe_spec(bm, n),
                  tiles.rowvec_spec(bm), tiles.colvec_spec(n),
                  tiles.stripe_spec(bm, n)],
        out_specs=tiles.scalar_spec(2),
        out_shape=tiles.f32((2,)),
    )(aux, g, r_new, c_new, theta)

    count = jnp.float32(m * n)
    rms_u = jnp.sqrt(stats[0] / count)
    rms_theta = jnp.sqrt(stats[1] / count)
    scale = jnp.maximum(eps_rms, rms_theta) / jnp.maximum(1.0, rms_u)
    scale_arr = jnp.reshape(lr * scale, (1,))

    theta_new = tiles.pallas_call(
        _apply_kernel,
        grid=grid,
        in_specs=[tiles.scalar_spec(4), tiles.scalar_spec(1),
                  tiles.stripe_spec(bm, n), tiles.rowvec_spec(bm),
                  tiles.colvec_spec(n), tiles.stripe_spec(bm, n)],
        out_specs=tiles.stripe_spec(bm, n),
        out_shape=tiles.f32((m, n)),
    )(aux, scale_arr, g, r_new, c_new, theta)

    return theta_new, r_new, c_new


def adalomo_update_vector(theta, g, v, t, lr, **kw):
    """1-D/0-D parameters keep a full second moment (ref path; the tensors
    are negligible and the factorization degenerates)."""
    return ref.adalomo_vector_ref(theta, g, v, t, lr, **kw)
