# Layer-2: LLaMA-architecture decoder-only transformer in pure JAX.
#
# The paper trains LLaMA-7B..65B (instruction tuning / further pre-training)
# and a 1.1 B TinyLlama-architecture model (from-scratch pre-training). This
# module implements the same architecture family — RMSNorm, rotary position
# embeddings, causal multi-head attention, SwiGLU FFN, no biases, untied
# output head — parameterized so the experiment presets (DESIGN.md §4
# substitutions) pick laptop-scale sizes while the Rust memory simulator
# uses the analytic 1.1B/7B/13B/30B/65B presets.
#
# Parameters live in a flat {name: array} dict whose deterministic order is
# defined by param_specs(); layout.py packs them into the runtime blob.

from dataclasses import dataclass

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    d_ff: int
    seq_len: int
    batch_size: int
    rope_theta: float = 10000.0

    @property
    def d_head(self):
        return self.d_model // self.n_heads


# Experiment presets (runnable on CPU-PJRT). The four sizes mirror the
# paper's 7B/13B/30B/65B ladder in *relative* scale; vocab 256 = raw bytes.
PRESETS = {
    "nano": ModelConfig("nano", 256, 64, 2, 4, 176, 64, 8),
    "micro": ModelConfig("micro", 256, 128, 4, 4, 352, 128, 8),
    "tiny": ModelConfig("tiny", 256, 256, 6, 8, 704, 128, 8),
    "small": ModelConfig("small", 256, 512, 8, 8, 1408, 256, 4),
    # ~85M-parameter preset for the end-to-end driver; artifacts are built
    # on demand (python -m compile.aot --presets base100m).
    "base100m": ModelConfig("base100m", 256, 768, 12, 12, 2048, 256, 4),
}

# Analytic-only presets (memory simulator / Table 1 / Fig 5 / Table 8):
# (d_model, n_layers, n_heads, d_ff, vocab) of the LLaMA family.
ANALYTIC_PRESETS = {
    "llama1b1": (2048, 22, 32, 5632, 32000),
    "llama7b": (4096, 32, 32, 11008, 32000),
    "llama13b": (5120, 40, 40, 13824, 32000),
    "llama30b": (6656, 60, 52, 17920, 32000),
    "llama65b": (8192, 80, 64, 22016, 32000),
}

LORA_DEFAULT_RANK = 8
LORA_SCALE = 2.0  # alpha / rank with alpha = 16, rank = 8


def param_specs(cfg: ModelConfig):
    """Deterministic [(name, shape)] order for the base model parameters."""
    d, f, v = cfg.d_model, cfg.d_ff, cfg.vocab
    specs = [("embed", (v, d))]
    for l in range(cfg.n_layers):
        p = f"l{l}."
        specs += [
            (p + "attn_norm", (d,)),
            (p + "wq", (d, d)),
            (p + "wk", (d, d)),
            (p + "wv", (d, d)),
            (p + "wo", (d, d)),
            (p + "ffn_norm", (d,)),
            (p + "w_gate", (d, f)),
            (p + "w_up", (d, f)),
            (p + "w_down", (f, d)),
        ]
    specs += [("final_norm", (d,)), ("head", (d, v))]
    return specs


def lora_specs(cfg: ModelConfig, rank=LORA_DEFAULT_RANK):
    """Adapter parameters (applied to wq and wv, the standard LoRA targets)."""
    d = cfg.d_model
    specs = []
    for l in range(cfg.n_layers):
        p = f"l{l}."
        specs += [
            (p + "wq_a", (d, rank)), (p + "wq_b", (rank, d)),
            (p + "wv_a", (d, rank)), (p + "wv_b", (rank, d)),
        ]
    return specs


def n_params(cfg: ModelConfig):
    total = 0
    for _, shape in param_specs(cfg):
        n = 1
        for dim in shape:
            n *= dim
        total += n
    return total


def init_params(cfg: ModelConfig, seed):
    """Initialize parameters from an int32 seed (traceable: used inside the
    AOT init_* entries so the Rust runtime owns reproducibility)."""
    key = jax.random.PRNGKey(seed)
    out = {}
    residual_scale = 1.0 / jnp.sqrt(2.0 * cfg.n_layers)
    for i, (name, shape) in enumerate(param_specs(cfg)):
        k = jax.random.fold_in(key, i)
        if name.endswith("norm"):
            out[name] = jnp.ones(shape, jnp.float32)
        else:
            w = 0.02 * jax.random.normal(k, shape, jnp.float32)
            if name.endswith((".wo", ".w_down")):
                w = w * residual_scale
            out[name] = w
    return out


def init_lora(cfg: ModelConfig, seed, rank=LORA_DEFAULT_RANK):
    """LoRA init: A ~ N(0, 0.02), B = 0 (adapter starts as identity)."""
    key = jax.random.PRNGKey(seed + 1)
    out = {}
    for i, (name, shape) in enumerate(lora_specs(cfg, rank)):
        if name.endswith("_b"):
            out[name] = jnp.zeros(shape, jnp.float32)
        else:
            out[name] = 0.02 * jax.random.normal(
                jax.random.fold_in(key, i), shape, jnp.float32)
    return out


def rms_norm(x, gain, eps=1e-5):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * gain


def rope_tables(cfg: ModelConfig, tt):
    """cos/sin tables of shape (tt, d_head/2)."""
    half = cfg.d_head // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    pos = jnp.arange(tt, dtype=jnp.float32)
    ang = pos[:, None] * freqs[None, :]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: (B, H, T, dh); rotate pairs (x1, x2) -> (x1 cos - x2 sin, ...)."""
    x1, x2 = jnp.split(x, 2, axis=-1)
    return jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _attention(cfg, h, t, prefix, lora, lora_scale):
    b, tt, d = h.shape
    hh, dh = cfg.n_heads, cfg.d_head

    def proj(x, w, a_name, b_name):
        y = x @ t[w]
        if lora is not None and a_name in lora:
            y = y + lora_scale * ((x @ lora[a_name]) @ lora[b_name])
        return y

    q = proj(h, prefix + "wq", prefix + "wq_a", prefix + "wq_b")
    k = h @ t[prefix + "wk"]
    v = proj(h, prefix + "wv", prefix + "wv_a", prefix + "wv_b")

    def heads(x):
        return jnp.transpose(jnp.reshape(x, (b, tt, hh, dh)), (0, 2, 1, 3))

    q, k, v = heads(q), heads(k), heads(v)
    cos, sin = rope_tables(cfg, tt)
    cos, sin = cos[None, None], sin[None, None]
    q, k = apply_rope(q, cos, sin), apply_rope(k, cos, sin)

    scores = jnp.einsum("bhqd,bhkd->bhqk", q, k) / jnp.sqrt(jnp.float32(dh))
    mask = jnp.tril(jnp.ones((tt, tt), jnp.bool_))
    scores = jnp.where(mask[None, None], scores, jnp.float32(-1e30))
    att = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", att, v)
    out = jnp.reshape(jnp.transpose(out, (0, 2, 1, 3)), (b, tt, d))
    return out @ t[prefix + "wo"]


def _ffn(t, h, prefix):
    gate = jax.nn.silu(h @ t[prefix + "w_gate"])
    up = h @ t[prefix + "w_up"]
    return (gate * up) @ t[prefix + "w_down"]


def forward(cfg: ModelConfig, tensors, x, lora=None, lora_scale=LORA_SCALE):
    """Token ids x (B, T) int32 -> logits (B, T, vocab) f32."""
    h = tensors["embed"][x]
    for l in range(cfg.n_layers):
        p = f"l{l}."
        h = h + _attention(cfg, rms_norm(h, tensors[p + "attn_norm"]),
                           tensors, p, lora, lora_scale)
        h = h + _ffn(tensors, rms_norm(h, tensors[p + "ffn_norm"]), p)
    h = rms_norm(h, tensors["final_norm"])
    return h @ tensors["head"]


def merge_lora(cfg: ModelConfig, tensors, lora, lora_scale=LORA_SCALE):
    """Fold adapters into the base weights (wq/wv += scale * A @ B) so the
    shared eval entries can run on a plain parameter blob."""
    merged = dict(tensors)
    for l in range(cfg.n_layers):
        p = f"l{l}."
        merged[p + "wq"] = tensors[p + "wq"] + lora_scale * (
            lora[p + "wq_a"] @ lora[p + "wq_b"])
        merged[p + "wv"] = tensors[p + "wv"] + lora_scale * (
            lora[p + "wv_a"] @ lora[p + "wv_b"])
    return merged
