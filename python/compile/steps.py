# Entry-point builders: the traced functions that aot.py lowers to HLO.
#
# Every entry returns a SINGLE array (no tuples) so the Rust runtime gets
# exactly one non-tuple output buffer per execution — see layout.py.
#
# Entry kinds:
#   init_<preset>_<opt>(seed i32[]) -> blob
#   train_step_<preset>_<opt>[(_gnorm)](blob, x, y, sched f32[4]) -> blob'
#   fused_<preset>_<opt>_g<k>(frozen, accum, x, y, sched) -> accum'
#   extract_params_<preset>_<opt>(blob) -> params_blob
#   read_metrics_<preset>_<opt>(blob) -> f32[8]
#   eval_<preset>(params_blob, x, y) -> f32[8]
#   next_logits_<preset>(params_blob, x) -> f32[B, V]
#   merge_lora_<preset>(blob) -> params_blob
#   toy2d_<opt>(state, sched) -> state'
#
# sched = [lr, t, wd, clip]: the LR schedule, step count, weight decay and
# gradient-clipping threshold all live in the Rust coordinator (Layer 3).

import jax
import jax.numpy as jnp

from . import layout, losses, model, optim

LORA_OPT = "adamw"  # adapters are trained with AdamW (paper Table 3 setup)


def param_layout(cfg, opt_name, lora_rank=0):
    """Blob segments for (preset, optimizer). LoRA freezes the base model
    and appends adapters + their AdamW state."""
    if lora_rank:
        base = [(n, s, layout.KIND_FROZEN) for n, s in model.param_specs(cfg)]
        adapters = [(n, s, layout.KIND_PARAM)
                    for n, s in model.lora_specs(cfg, lora_rank)]
        states = optim.state_specs_for(LORA_OPT, model.lora_specs(cfg, lora_rank))
        return layout.build_segments(base + adapters, states)
    params = [(n, s, layout.KIND_PARAM) for n, s in model.param_specs(cfg)]
    states = optim.state_specs_for(opt_name, model.param_specs(cfg))
    return layout.build_segments(params, states)


def _trainable(segs):
    return [s for s in segs if s.kind == layout.KIND_PARAM]


def _states_of(segs, pname):
    prefix = pname + "@"
    return [s for s in segs
            if s.kind == layout.KIND_STATE and s.name.startswith(prefix)]


def _global_norm2(grads):
    return sum(jnp.sum(jnp.square(g)) for g in grads.values())


def _apply_updates(opt_name, segs, tensors, grads, t, lr, wd,
                   use_kernels=True, no_sqrt=False, only=None):
    """Run the optimizer over every trainable leaf; returns updated tensor
    dict (params + states)."""
    mod = optim.get(opt_name)
    new = dict(tensors)
    for seg in _trainable(segs):
        if only is not None and seg.name not in only:
            continue
        sstates = _states_of(segs, seg.name)
        states = [tensors[s.name] for s in sstates]
        kwargs = {"use_kernels": use_kernels}
        if opt_name == "adalomo":
            kwargs["no_sqrt"] = no_sqrt
        theta_new, states_new = mod.update(
            tensors[seg.name], grads[seg.name], states, t, lr, wd, **kwargs)
        new[seg.name] = theta_new
        for s, arr in zip(sstates, states_new):
            new[s.name] = arr
    return new


def make_init(cfg, opt_name, lora_rank=0, seed_offset=0):
    segs = param_layout(cfg, opt_name, lora_rank)

    def init(seed):
        seed = seed + seed_offset
        tensors = {}
        base = model.init_params(cfg, seed)
        tensors.update(base)
        if lora_rank:
            tensors.update(model.init_lora(cfg, seed, lora_rank))
        for s in segs:
            if s.kind == layout.KIND_STATE:
                tensors[s.name] = jnp.zeros(s.shape, jnp.float32)
        tensors["metrics"] = jnp.zeros((layout.METRIC_SLOTS,), jnp.float32)
        return layout.pack(tensors, segs)

    return init, segs


def _loss_and_grads(cfg, segs, tensors, x, y, lora_rank):
    """value_and_grad over the trainable leaves only."""
    trainable = _trainable(segs)
    tr0 = {s.name: tensors[s.name] for s in trainable}

    def loss_fn(tr):
        full = dict(tensors)
        full.update(tr)
        if lora_rank:
            lora = {n: full[n] for n, _ in model.lora_specs(cfg, lora_rank)}
            logits = model.forward(cfg, full, x, lora=lora)
        else:
            logits = model.forward(cfg, full, x)
        loss, tokens, correct = losses.lm_loss(logits, y)
        return loss, (tokens, correct)

    (loss, (tokens, correct)), grads = jax.value_and_grad(
        loss_fn, has_aux=True)(tr0)
    return loss, tokens, correct, grads


def make_train_step(cfg, opt_name, *, gnorm=False, lora_rank=0,
                    use_kernels=True, no_sqrt=False):
    """The monolithic train step (semantically identical to LOMO's fused
    backward: all gradients taken at theta_t — see DESIGN.md §4)."""
    segs = param_layout(cfg, opt_name, lora_rank)
    upd_opt = LORA_OPT if lora_rank else opt_name

    def step(blob, x, y, sched):
        lr, t, wd, clip = sched[0], sched[1], sched[2], sched[3]
        tensors = layout.unpack(blob, segs)
        loss, tokens, correct, grads = _loss_and_grads(
            cfg, segs, tensors, x, y, lora_rank)
        gn2 = _global_norm2(grads)
        gn = jnp.sqrt(gn2)
        if gnorm:
            # Global gradient-norm clipping: the two-backward-pass LOMO path
            # (paper §2.1). Numerically one program; the memory/time cost of
            # the second backward is accounted by memsim + the coordinator.
            scale = clip / jnp.maximum(gn, clip)
            grads = {k: g * scale for k, g in grads.items()}
        new = _apply_updates(upd_opt, segs, tensors, grads, t, lr, wd,
                             use_kernels=use_kernels, no_sqrt=no_sqrt)
        m = jnp.zeros((layout.METRIC_SLOTS,), jnp.float32)
        m = m.at[layout.M_LOSS].set(loss)
        m = m.at[layout.M_TOKENS].set(tokens)
        m = m.at[layout.M_CORRECT].set(correct)
        m = m.at[layout.M_GNORM].set(gn)
        new["metrics"] = m
        return layout.pack(new, segs)

    return step, segs


def fused_groups(cfg):
    """Parameter groups in backward order: head block, layers L-1..0, embed.
    Mirrors the order LOMO visits gradients during backpropagation."""
    groups = [["head", "final_norm"]]
    for l in reversed(range(cfg.n_layers)):
        p = f"l{l}."
        groups.append([p + n for n in (
            "attn_norm", "wq", "wk", "wv", "wo",
            "ffn_norm", "w_gate", "w_up", "w_down")])
    groups.append(["embed"])
    return groups


def make_fused_group_step(cfg, opt_name, group_index, use_kernels=True):
    """One fused-backward group program.

    Gradients are computed from `frozen` (theta_t, constant across the whole
    fused step) and updates are written into `accum`; the Rust coordinator
    chains the G programs and then drops the frozen buffer. Because every
    group's gradient is evaluated at theta_t, the chained result is exactly
    the monolithic step (integration_coordinator asserts this), while XLA
    dead-code-eliminates every other group's weight gradients from each
    program — reproducing LOMO's "at most one group's gradients live"
    memory profile at program granularity.
    """
    segs = param_layout(cfg, opt_name)
    group = set(fused_groups(cfg)[group_index])

    def step(frozen, accum, x, y, sched):
        lr, t, wd = sched[0], sched[1], sched[2]
        tensors = layout.unpack(frozen, segs)
        acc = layout.unpack(accum, segs)
        trainable = [s for s in _trainable(segs) if s.name in group]
        tr0 = {s.name: tensors[s.name] for s in trainable}

        def loss_fn(tr):
            full = dict(tensors)
            full.update(tr)
            logits = model.forward(cfg, full, x)
            loss, tokens, correct = losses.lm_loss(logits, y)
            return loss, (tokens, correct)

        (loss, (tokens, correct)), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(tr0)
        new = _apply_updates(opt_name, segs, tensors, grads, t, lr, wd,
                             use_kernels=use_kernels, only=group)
        out = dict(acc)
        for s in trainable:
            out[s.name] = new[s.name]
            for st in _states_of(segs, s.name):
                out[st.name] = new[st.name]
        m = acc["metrics"]
        m = m.at[layout.M_LOSS].set(loss)
        m = m.at[layout.M_TOKENS].set(tokens)
        m = m.at[layout.M_CORRECT].set(correct)
        out["metrics"] = m
        return layout.pack(out, segs)

    return step, segs


def make_extract_params(cfg, opt_name, lora_rank=0):
    segs = param_layout(cfg, opt_name, lora_rank)
    plen = layout.params_len(segs)

    def extract(blob):
        return jax.lax.slice(blob, (0,), (plen,))

    return extract, segs


def make_read_metrics(cfg, opt_name, lora_rank=0):
    segs = param_layout(cfg, opt_name, lora_rank)
    moff = [s for s in segs if s.kind == layout.KIND_METRIC][0].offset

    def read(blob):
        return jax.lax.slice(blob, (moff,), (moff + layout.METRIC_SLOTS,))

    return read, segs


def params_only_segments(cfg):
    return layout.build_segments(
        [(n, s, layout.KIND_PARAM) for n, s in model.param_specs(cfg)], [])


def make_eval(cfg):
    """Validation step on a bare parameter blob: [mean_loss, tokens, correct,
    0...] — the Rust side aggregates sums for perplexity/accuracy."""
    specs = model.param_specs(cfg)

    def ev(params_blob, x, y):
        tensors = _unpack_params(params_blob, specs)
        logits = model.forward(cfg, tensors, x)
        loss, tokens, correct = losses.lm_loss(logits, y)
        m = jnp.zeros((layout.METRIC_SLOTS,), jnp.float32)
        m = m.at[layout.M_LOSS].set(loss)
        m = m.at[layout.M_TOKENS].set(tokens)
        m = m.at[layout.M_CORRECT].set(correct)
        return m

    return ev


def make_seq_loss(cfg):
    """Per-sequence scores for likelihood-based benchmark scoring
    (lm-eval-harness style): returns (2, B) with row 0 = summed loss over
    counted tokens and row 1 = counted-token counts, per batch row."""
    specs = model.param_specs(cfg)

    def sl(params_blob, x, y):
        tensors = _unpack_params(params_blob, specs)
        logits = model.forward(cfg, tensors, x)
        mask = (y != losses.PAD_ID).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits, axis=-1)
        picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
        loss_sums = -jnp.sum(picked * mask, axis=-1)
        counts = jnp.sum(mask, axis=-1)
        return jnp.stack([loss_sums, counts])

    return sl


def make_next_logits(cfg):
    """Last-position logits (B, V) for greedy decoding in the Rust eval
    harness (synthetic benchmark suite)."""
    specs = model.param_specs(cfg)

    def nl(params_blob, x):
        tensors = _unpack_params(params_blob, specs)
        logits = model.forward(cfg, tensors, x)
        return logits[:, -1, :]

    return nl


def make_merge_lora(cfg, lora_rank):
    segs = param_layout(cfg, "adamw", lora_rank)
    specs = model.param_specs(cfg)

    def merge(blob):
        tensors = layout.unpack(blob, segs)
        lora = {n: tensors[n] for n, _ in model.lora_specs(cfg, lora_rank)}
        merged = model.merge_lora(cfg, tensors, lora)
        flat = [jnp.reshape(merged[n], (-1,)) for n, _ in specs]
        return jnp.concatenate(flat)

    return merge


def _unpack_params(params_blob, specs):
    out, off = {}, 0
    for name, shape in specs:
        n = 1
        for d in shape:
            n *= d
        out[name] = jnp.reshape(
            jax.lax.slice(params_blob, (off,), (off + n,)), shape)
        off += n
    return out


# ---------------------------------------------------------------------------
# Toy 2-D landscape (paper Appendix A / Fig. 6)
# ---------------------------------------------------------------------------

def toy2d_layout(opt_name):
    params = [("xy", (2,), layout.KIND_PARAM)]
    states = optim.state_specs_for(opt_name, [("xy", (2,))])
    return layout.build_segments(params, states)


def make_toy2d_step(opt_name):
    segs = toy2d_layout(opt_name)

    def step(blob, sched):
        lr, t = sched[0], sched[1]
        tensors = layout.unpack(blob, segs)
        f, grad = jax.value_and_grad(losses.toy2d)(tensors["xy"])
        new = _apply_updates(opt_name, segs, tensors, {"xy": grad},
                             t, lr, 0.0, use_kernels=False)
        m = jnp.zeros((layout.METRIC_SLOTS,), jnp.float32)
        m = m.at[layout.M_LOSS].set(f)
        new["metrics"] = m
        return layout.pack(new, segs)

    return step, segs
