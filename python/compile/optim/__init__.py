# Functional per-parameter optimizer library (Layer 2).
#
# Each optimizer module exposes:
#   state_specs(shape) -> [(suffix, shape)]      optimizer-state layout
#   update(theta, g, states, t, lr, wd, use_kernels) -> (theta', states')
# with `states` a list in state_specs order, `t` the 1-based f32 step and
# `lr` the already-scheduled learning rate (schedules live in the Rust
# coordinator, Layer 3). 2-D parameters route through the Pallas kernels
# when use_kernels=True; vectors use the jnp reference math.

from . import (adafactor, adalomo, adamw, lomo, sgd, sgd_momentum,
               sgd_variance)

REGISTRY = {
    "sgd": sgd,
    "sgd_momentum": sgd_momentum,
    "sgd_variance": sgd_variance,
    "adamw": adamw,
    "adafactor": adafactor,
    "lomo": lomo,
    "adalomo": adalomo,
}

# Optimizers whose fused-backward formulation needs no other parameter's
# gradient (the LOMO family property, paper §2.1/§3.2).
FUSABLE = {"sgd", "sgd_variance", "lomo", "adalomo", "adafactor",
           "sgd_momentum", "adamw"}


def get(name):
    if name not in REGISTRY:
        raise KeyError(f"unknown optimizer {name!r}; have {sorted(REGISTRY)}")
    return REGISTRY[name]


def state_specs_for(opt_name, param_specs):
    """Flattened optimizer-state specs for a list of (name, shape) params."""
    mod = get(opt_name)
    out = []
    for pname, shape in param_specs:
        for suffix, sshape in mod.state_specs(shape):
            out.append((f"{pname}@{suffix}", sshape))
    return out
