# AdamW (paper Eq. 2 + decoupled weight decay; Loshchilov & Hutter, 2019) —
# the prevailing LLM optimizer the paper benchmarks against. wd = 0 gives
# plain Adam (the Fig. 1 / Fig. 6 arm).

from ..kernels import adamw_update, ref


def state_specs(shape):
    return [("m", shape), ("v", shape)]


def update(theta, g, states, t, lr, wd, use_kernels=True):
    m, v = states
    if use_kernels and theta.ndim == 2:
        theta_new, m_new, v_new = adamw_update.adamw_update(
            theta, g, m, v, t, lr, wd=wd)
    else:
        theta_new, m_new, v_new = ref.adamw_ref(theta, g, m, v, t, lr, wd=wd)
    return theta_new, [m_new, v_new]
