# LOMO (Lv et al., 2023): plain SGD fused into the backward pass (paper
# Eq. 1). Optimizer-state-free; the memory baseline AdaLomo improves on.

from ..kernels import lomo_update, ref


def state_specs(shape):
    return []


def update(theta, g, states, t, lr, wd, use_kernels=True):
    del states, t, wd
    if use_kernels and theta.ndim == 2:
        return lomo_update.lomo_update(theta, g, lr), []
    return ref.lomo_ref(theta, g, lr), []
