# SGD with first-moment estimation only (paper Eq. 3) — the "momentum"
# ablation arm of Fig. 1 / Fig. 6.

from ..kernels import ref


def state_specs(shape):
    return [("m", shape)]


def update(theta, g, states, t, lr, wd, use_kernels=True):
    del wd, use_kernels
    theta_new, m_new = ref.sgd_momentum_ref(theta, g, states[0], t, lr)
    return theta_new, [m_new]
