# Adafactor (Shazeer & Stern, 2018): factored second moment, momentum-less,
# update clipping, relative step size. The memory-efficient baseline of
# paper Table 5 / Fig. 4 / Fig. 9-10, and the source of AdaLomo's NMF
# factorization. `lr` is rho_t (the schedule), applied relative to RMS(theta).

from ..kernels import adafactor_update, ref


def state_specs(shape):
    if len(shape) == 2:
        return [("r", (shape[0],)), ("c", (shape[1],))]
    return [("v", shape)]


def update(theta, g, states, t, lr, wd, use_kernels=True):
    del wd
    if theta.ndim == 2:
        r, c = states
        if use_kernels:
            theta_new, r_new, c_new = adafactor_update.adafactor_update(
                theta, g, r, c, t, lr)
        else:
            theta_new, r_new, c_new = ref.adafactor_ref(theta, g, r, c, t, lr)
        return theta_new, [r_new, c_new]
    theta_new, v_new = ref.adafactor_vector_ref(theta, g, states[0], t, lr)
    return theta_new, [v_new]
