# SGD with second-moment estimation only (paper Eq. 4) — the "variance"
# ablation arm of Fig. 1 / Fig. 6, the analysis that motivates AdaLomo.

from ..kernels import ref


def state_specs(shape):
    return [("v", shape)]


def update(theta, g, states, t, lr, wd, use_kernels=True):
    del wd, use_kernels
    theta_new, v_new = ref.sgd_variance_ref(theta, g, states[0], t, lr)
    return theta_new, [v_new]
