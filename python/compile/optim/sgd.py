# Plain SGD: theta' = theta - lr * g. No optimizer state. The from-scratch
# pre-training baseline in paper Fig. 4 / Table 7.


def state_specs(shape):
    return []


def update(theta, g, states, t, lr, wd, use_kernels=True):
    del states, t, wd, use_kernels
    return theta - lr * g, []
