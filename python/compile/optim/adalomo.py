# AdaLomo (the paper's contribution, Algorithm 1): factored second moment
# (r, c per matrix), adaptive per-parameter learning rate, grouped update
# normalization — all computable per-parameter inside one fused backward.
#
# `no_sqrt=True` switches to the literal Algorithm-1 line-10 form
# u = g / v_hat (see DESIGN.md "Faithfulness notes").

from ..kernels import adalomo_update, ref


def state_specs(shape):
    if len(shape) == 2:
        return [("r", (shape[0],)), ("c", (shape[1],))]
    return [("v", shape)]


def update(theta, g, states, t, lr, wd, use_kernels=True, no_sqrt=False):
    del wd
    if theta.ndim == 2:
        r, c = states
        if use_kernels and not no_sqrt:
            theta_new, r_new, c_new = adalomo_update.adalomo_update(
                theta, g, r, c, t, lr)
        else:
            theta_new, r_new, c_new = ref.adalomo_ref(
                theta, g, r, c, t, lr, no_sqrt=no_sqrt)
        return theta_new, [r_new, c_new]
    theta_new, v_new = ref.adalomo_vector_ref(
        theta, g, states[0], t, lr, no_sqrt=no_sqrt)
    return theta_new, [v_new]
