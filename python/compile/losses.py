# Loss / metric functions shared by the train, eval and toy-2D entries.

import jax
import jax.numpy as jnp

PAD_ID = 0  # byte 0 never occurs in the synthetic corpora; used as ignore-id


def lm_loss(logits, y):
    """Causal LM cross-entropy with PAD_ID masking.

    logits: (B, T, V); y: (B, T) int32 targets (next tokens).
    Returns (mean_loss, counted_tokens, correct) — all f32 scalars.
    """
    mask = (y != PAD_ID).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    picked = jnp.take_along_axis(logp, y[..., None], axis=-1)[..., 0]
    n = jnp.maximum(jnp.sum(mask), 1.0)
    loss = -jnp.sum(picked * mask) / n
    pred = jnp.argmax(logits, axis=-1).astype(y.dtype)
    correct = jnp.sum((pred == y).astype(jnp.float32) * mask)
    return loss, jnp.sum(mask), correct


def toy2d(xy):
    """The Appendix-A landscape:
    f(x, y) = x^2 + y^2 - 2 exp(-5[(x-1)^2 + y^2]) - 3 exp(-5[(x+1)^2 + y^2]).

    Global optimum near (-1, 0) (the deeper well), local optimum near (1, 0).
    """
    x, y = xy[0], xy[1]
    return (x * x + y * y
            - 2.0 * jnp.exp(-5.0 * ((x - 1.0) ** 2 + y * y))
            - 3.0 * jnp.exp(-5.0 * ((x + 1.0) ** 2 + y * y)))
