# AOT driver: lowers every entry point to HLO *text* + writes the manifest.
#
# HLO text (NOT HloModuleProto.serialize()) is the interchange format: jax
# >= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
# (the version behind the Rust `xla` crate) rejects; the text parser
# reassigns ids and round-trips cleanly. See /opt/xla-example/README.md.
#
# Usage:
#   python -m compile.aot --out ../artifacts
#       [--presets nano,micro,tiny,small] [--only REGEX]
#       [--kernels pallas|jnp] [--list] [--force] [--report]
#
# Python runs ONCE at build time (make artifacts); the Rust binary is
# self-contained afterwards.

import argparse
import json
import os
import re
import sys
import time

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import layout, model, steps

# Which optimizers get artifacts per preset (paper experiment needs; the
# larger presets skip the ablation-only arms to bound build time).
OPTS_FULL = ["sgd", "sgd_momentum", "sgd_variance", "adamw", "adafactor",
             "lomo", "adalomo"]
OPTS_SMALL = ["sgd", "adamw", "adafactor", "lomo", "adalomo"]
PRESET_OPTS = {
    "nano": OPTS_FULL,
    "micro": OPTS_FULL,
    "tiny": OPTS_FULL,
    "small": OPTS_SMALL,
    "base100m": ["adamw", "adalomo"],
}
DEFAULT_PRESETS = ["nano", "micro", "tiny", "small"]
GNORM_OPTS = ["lomo", "adalomo"]   # Appendix-B ablation arms
FUSED_PRESETS = ["nano", "micro"]  # fused-backward group programs (demo)
TOY2D_OPTS = ["sgd", "sgd_momentum", "sgd_variance", "adamw"]


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=False)
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def plan_entries(presets, use_kernels):
    """Yield (entry_name, build_fn, arg_specs, meta). build_fn() -> traced fn."""
    entries = []

    def add(name, fn_builder, arg_specs, out_shape, meta):
        entries.append((name, fn_builder, arg_specs, out_shape, meta))

    for pname in presets:
        cfg = model.PRESETS[pname]
        b, t, v = cfg.batch_size, cfg.seq_len, cfg.vocab
        x_spec = _spec((b, t), jnp.int32)
        y_spec = _spec((b, t), jnp.int32)
        sched_spec = _spec((4,))
        seed_spec = _spec((), jnp.int32)
        psegs = steps.params_only_segments(cfg)
        plen = layout.params_len(psegs)

        # Shared per-preset entries on the bare parameter blob.
        add(f"eval_{pname}", lambda cfg=cfg: steps.make_eval(cfg),
            [_io("params", (plen,), "f32"), _io("x", (b, t), "i32"),
             _io("y", (b, t), "i32")],
            (layout.METRIC_SLOTS,),
            {"preset": pname, "kind": "eval"})
        add(f"seq_loss_{pname}", lambda cfg=cfg: steps.make_seq_loss(cfg),
            [_io("params", (plen,), "f32"), _io("x", (b, t), "i32"),
             _io("y", (b, t), "i32")],
            (2, b),
            {"preset": pname, "kind": "seq_loss"})
        add(f"next_logits_{pname}", lambda cfg=cfg: steps.make_next_logits(cfg),
            [_io("params", (plen,), "f32"), _io("x", (b, t), "i32")],
            (b, v),
            {"preset": pname, "kind": "next_logits"})

        variants = []
        for opt in PRESET_OPTS[pname]:
            variants.append((opt, opt, {}))
            if opt in GNORM_OPTS:
                variants.append((f"{opt}_gnorm", opt, {"gnorm": True}))
        variants.append(("lora", "adamw",
                         {"lora_rank": model.LORA_DEFAULT_RANK}))

        seen_layout = set()
        for vname, opt, kw in variants:
            lora_rank = kw.get("lora_rank", 0)
            segs = steps.param_layout(cfg, opt, lora_rank)
            blob = layout.blob_len(segs)
            blob_spec = _spec((blob,))
            layout_key = (opt, lora_rank)

            add(f"train_step_{pname}_{vname}",
                lambda cfg=cfg, opt=opt, kw=kw: steps.make_train_step(
                    cfg, opt, use_kernels=use_kernels, **kw)[0],
                [_io("blob", (blob,), "f32"), _io("x", (b, t), "i32"),
                 _io("y", (b, t), "i32"), _io("sched", (4,), "f32")],
                (blob,),
                {"preset": pname, "kind": "train_step", "opt": vname,
                 "layout": f"{pname}/{vname}"})

            if layout_key in seen_layout:
                continue
            seen_layout.add(layout_key)
            add(f"init_{pname}_{vname}",
                lambda cfg=cfg, opt=opt, lr=lora_rank:
                    steps.make_init(cfg, opt, lora_rank=lr)[0],
                [_io("seed", (), "i32")], (blob,),
                {"preset": pname, "kind": "init", "opt": vname,
                 "layout": f"{pname}/{vname}"})
            add(f"extract_params_{pname}_{vname}",
                lambda cfg=cfg, opt=opt, lr=lora_rank:
                    steps.make_extract_params(cfg, opt, lr)[0],
                [_io("blob", (blob,), "f32")],
                (layout.params_len(segs),),
                {"preset": pname, "kind": "extract_params", "opt": vname,
                 "layout": f"{pname}/{vname}"})
            add(f"read_metrics_{pname}_{vname}",
                lambda cfg=cfg, opt=opt, lr=lora_rank:
                    steps.make_read_metrics(cfg, opt, lr)[0],
                [_io("blob", (blob,), "f32")], (layout.METRIC_SLOTS,),
                {"preset": pname, "kind": "read_metrics", "opt": vname,
                 "layout": f"{pname}/{vname}"})

        # LoRA merge (adapters folded for the shared eval entries).
        lsegs = steps.param_layout(cfg, "adamw", model.LORA_DEFAULT_RANK)
        add(f"merge_lora_{pname}",
            lambda cfg=cfg: steps.make_merge_lora(cfg, model.LORA_DEFAULT_RANK),
            [_io("blob", (layout.blob_len(lsegs),), "f32")], (plen,),
            {"preset": pname, "kind": "merge_lora"})

        # Fused-backward group programs (coordinator demo + tests).
        if pname in FUSED_PRESETS:
            segs = steps.param_layout(cfg, "adalomo")
            blob = layout.blob_len(segs)
            groups = steps.fused_groups(cfg)
            for k in range(len(groups)):
                add(f"fused_{pname}_adalomo_g{k}",
                    lambda cfg=cfg, k=k: steps.make_fused_group_step(
                        cfg, "adalomo", k, use_kernels=use_kernels)[0],
                    [_io("frozen", (blob,), "f32"), _io("accum", (blob,), "f32"),
                     _io("x", (b, t), "i32"), _io("y", (b, t), "i32"),
                     _io("sched", (4,), "f32")],
                    (blob,),
                    {"preset": pname, "kind": "fused_group", "opt": "adalomo",
                     "group": k, "n_groups": len(groups),
                     "layout": f"{pname}/adalomo"})

    # Toy 2-D landscape (Appendix A / Fig 6) — preset-independent.
    for opt in TOY2D_OPTS:
        segs = steps.toy2d_layout(opt)
        blob = layout.blob_len(segs)
        add(f"toy2d_{opt}",
            lambda opt=opt: steps.make_toy2d_step(opt)[0],
            [_io("state", (blob,), "f32"), _io("sched", (4,), "f32")],
            (blob,),
            {"kind": "toy2d", "opt": opt, "layout": f"toy2d/{opt}"})

    return entries


def layouts_json(presets):
    out = {}
    for pname in presets:
        cfg = model.PRESETS[pname]
        for opt in PRESET_OPTS[pname] + ["lora"]:
            lora_rank = model.LORA_DEFAULT_RANK if opt == "lora" else 0
            base_opt = "adamw" if opt == "lora" else opt
            segs = steps.param_layout(cfg, base_opt, lora_rank)
            out[f"{pname}/{opt}"] = {
                "blob_len": layout.blob_len(segs),
                "params_len": layout.params_len(segs),
                "segments": layout.segments_json(segs),
            }
            if opt in GNORM_OPTS:
                out[f"{pname}/{opt}_gnorm"] = out[f"{pname}/{opt}"]
    for opt in TOY2D_OPTS:
        segs = steps.toy2d_layout(opt)
        out[f"toy2d/{opt}"] = {
            "blob_len": layout.blob_len(segs),
            "params_len": layout.params_len(segs),
            "segments": layout.segments_json(segs),
        }
    return out


def presets_json(presets):
    out = {}
    for pname in presets:
        cfg = model.PRESETS[pname]
        out[pname] = {
            "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "seq_len": cfg.seq_len,
            "batch_size": cfg.batch_size,
            "n_params": model.n_params(cfg),
            "fused_groups": len(steps.fused_groups(cfg)),
            "opts": PRESET_OPTS[pname],
        }
    return out


DTYPES = {"f32": jnp.float32, "i32": jnp.int32}


def lower_entry(name, fn_builder, arg_specs):
    fn = fn_builder()
    specs = [_spec(tuple(a["shape"]), DTYPES[a["dtype"]]) for a in arg_specs]
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--presets", default=",".join(DEFAULT_PRESETS))
    ap.add_argument("--only", default=None, help="regex filter on entry name")
    ap.add_argument("--kernels", default="pallas", choices=["pallas", "jnp"],
                    help="2-D updates via Pallas kernels or jnp reference")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--force", action="store_true",
                    help="re-lower even if the artifact file exists")
    args = ap.parse_args()

    presets = [p for p in args.presets.split(",") if p]
    entries = plan_entries(presets, use_kernels=(args.kernels == "pallas"))
    if args.only:
        rx = re.compile(args.only)
        entries = [e for e in entries if rx.search(e[0])]

    if args.list:
        for name, _, arg_specs, out_shape, meta in entries:
            print(f"{name:48s} {meta.get('kind', ''):>14s} -> {out_shape}")
        print(f"{len(entries)} entries")
        return

    os.makedirs(args.out, exist_ok=True)
    manifest_path = os.path.join(args.out, "manifest.json")
    manifest = {"version": 1, "kernel_impl": args.kernels,
                "presets": {}, "layouts": {}, "entries": {}}
    if os.path.exists(manifest_path):
        with open(manifest_path) as f:
            manifest = json.load(f)
        manifest["kernel_impl"] = args.kernels

    manifest["presets"].update(presets_json(presets))
    manifest["layouts"].update(layouts_json(presets))

    t_all = time.time()
    for i, (name, fn_builder, arg_specs, out_shape, meta) in enumerate(entries):
        path = os.path.join(args.out, f"{name}.hlo.txt")
        rec = {"file": f"{name}.hlo.txt", "inputs": arg_specs,
               "output": {"shape": list(out_shape), "dtype": "f32"}, **meta}
        if os.path.exists(path) and not args.force:
            manifest["entries"][name] = rec
            continue
        t0 = time.time()
        text = lower_entry(name, fn_builder, arg_specs)
        with open(path, "w") as f:
            f.write(text)
        manifest["entries"][name] = rec
        print(f"[{i + 1}/{len(entries)}] {name}: "
              f"{len(text) / 1e6:.2f} MB HLO in {time.time() - t0:.1f}s",
              flush=True)
        # Persist incrementally so an interrupted build resumes.
        with open(manifest_path, "w") as f:
            json.dump(manifest, f, indent=1)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"manifest: {len(manifest['entries'])} entries "
          f"({time.time() - t_all:.1f}s total)")


if __name__ == "__main__":
    main()
