//! Memory-simulator integration: the analytic model against (a) the
//! paper's published measurements and (b) the real artifact layouts.

use adalomo::experiments as exp;
use adalomo::memsim::{liveness, memory, paper, throughput, Arch};

#[test]
fn table1_reproduced_exactly() {
    // Paper Table 1 (mixed precision, per-parameter bytes): AdamW
    // 2+2+12 = 16M; AdaLomo ~ 2M; LoRA ~ 2M.
    let arch = Arch::analytic("llama7b").unwrap();
    let adamw = memory::table1_bytes_per_param(&arch, memory::Method::AdamW);
    let adalomo =
        memory::table1_bytes_per_param(&arch, memory::Method::AdaLomo);
    let lora =
        memory::table1_bytes_per_param(&arch, memory::Method::LoRA { rank: 8 });
    assert_eq!(adamw, 16.0);
    assert!(adalomo < 2.1 && adalomo > 2.0);
    assert!(lora < 2.1 && lora > 2.0);
    // The headline ratio: AdamW needs ~8x AdaLomo's model-state memory.
    assert!(adamw / adalomo > 7.5);
}

#[test]
fn fig5_memory_ordering_and_magnitudes() {
    let act = memory::calibrate();
    for &(arch_name, _, n_gpus, mb, _, _) in paper::TABLE8.iter().step_by(5) {
        let arch = Arch::analytic(arch_name).unwrap();
        let total = |method| {
            memory::estimate(
                &memory::TrainSetup {
                    arch: arch.clone(),
                    method,
                    n_gpus,
                    micro_batch: mb,
                    seq_len: paper::PROFILE_SEQ_LEN,
                },
                act,
            )
            .total_gb()
        };
        let adamw = total(memory::Method::AdamW);
        let adafactor = total(memory::Method::Adafactor);
        let lora = total(memory::Method::LoRA { rank: 8 });
        let lomo = total(memory::Method::Lomo);
        let adalomo = total(memory::Method::AdaLomo);
        assert!(adamw > adafactor, "{arch_name}");
        assert!(adafactor > lora, "{arch_name}");
        assert!(lomo <= adalomo * 1.01, "{arch_name}");
        assert!(adalomo < lora * 1.1, "{arch_name}");
    }
}

#[test]
fn table8_tgs_shape() {
    // The paper's ordering at each size: LoRA fastest, AdaLomo slowest,
    // AdamW/Adafactor/LOMO in between.
    let hw = throughput::Hardware::default();
    let eff = throughput::calibrate();
    for &(arch_name, _, n_gpus, mb, _, _) in paper::TABLE8.iter().step_by(5) {
        let arch = Arch::analytic(arch_name).unwrap();
        let tgs = |method| {
            throughput::tgs(
                &memory::TrainSetup {
                    arch: arch.clone(),
                    method,
                    n_gpus,
                    micro_batch: mb,
                    seq_len: paper::PROFILE_SEQ_LEN,
                },
                hw,
                eff,
            )
        };
        let lora = tgs(memory::Method::LoRA { rank: 8 });
        let adamw = tgs(memory::Method::AdamW);
        let lomo = tgs(memory::Method::Lomo);
        let adalomo = tgs(memory::Method::AdaLomo);
        assert!(lora > adamw, "{arch_name}: lora fastest");
        assert!(adalomo < lomo, "{arch_name}: adalomo pays update cost");
        // "the throughput of these methods is at the same level" (§4.4).
        assert!(adalomo > lora * 0.5, "{arch_name}: same level");
    }
}

#[test]
fn adalomo_lomo_gap_widens_with_scale() {
    // Table 8: 7% at 7B/4GPU -> ~21% at 65B/32GPU.
    let hw = throughput::Hardware::default();
    let eff = throughput::calibrate();
    let gap = |arch_name: &str, g: usize, mb: usize| {
        let arch = Arch::analytic(arch_name).unwrap();
        let t = |method| {
            throughput::tgs(
                &memory::TrainSetup {
                    arch: arch.clone(),
                    method,
                    n_gpus: g,
                    micro_batch: mb,
                    seq_len: paper::PROFILE_SEQ_LEN,
                },
                hw,
                eff,
            )
        };
        (t(memory::Method::Lomo) - t(memory::Method::AdaLomo))
            / t(memory::Method::Lomo)
    };
    let g7 = gap("llama7b", 4, 8);
    let g65 = gap("llama65b", 32, 2);
    assert!(g65 > g7, "gap widens: {g7} -> {g65}");
}

#[test]
fn liveness_matches_artifact_layouts() {
    // The analytic liveness walk and the real fused-group layout agree on
    // total gradient volume for the experiment presets.
    if !exp::artifacts_available() {
        return;
    }
    let s = exp::open_session().unwrap();
    for preset in ["nano", "micro"] {
        let arch = Arch::preset(preset).unwrap();
        let r = liveness::simulate(&arch, liveness::BackwardMode::Standard);
        assert_eq!(r.peak_bytes, 2 * arch.n_params());
        let manifest_params = s.manifest.preset(preset).unwrap().n_params;
        assert_eq!(arch.n_params(), manifest_params, "{preset}");
    }
}

#[test]
fn fused_liveness_scales_sublinearly() {
    // O(1)-style claim: peak fused gradient bytes grow ~sqrt(params)
    // (largest matrix), not linearly.
    let small = Arch::analytic("llama7b").unwrap();
    let big = Arch::analytic("llama65b").unwrap();
    let peak = |a: &Arch| {
        liveness::simulate(a, liveness::BackwardMode::Fused).peak_bytes as f64
    };
    let params_ratio = big.n_params() as f64 / small.n_params() as f64; // ~9.7
    let peak_ratio = peak(&big) / peak(&small);
    assert!(peak_ratio < params_ratio / 2.0, "{peak_ratio} vs {params_ratio}");
}
