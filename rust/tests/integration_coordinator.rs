//! Coordinator integration: fused-backward scheduling and the worker pool
//! against the real artifacts.

use adalomo::config::{Phase, RunConfig};
use adalomo::coordinator::{fused, workers};
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::runtime::{Manifest, Session};

fn session() -> Option<Session> {
    if !exp::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(exp::open_session().expect("session"))
}

#[test]
fn fused_chain_equals_monolithic_step() {
    // The coordinator-side half of the fused-backward semantics check
    // (the python side asserts it at trace level; this asserts it through
    // PJRT with the real artifacts).
    let Some(s) = session() else { return };
    let p = s.manifest.preset("nano").unwrap().clone();
    let layout = s.manifest.layout("nano/adalomo").unwrap().clone();
    let (b, t) = (p.batch_size, p.seq_len);

    let seed = s.upload_i32(&[17], &[]).unwrap();
    let blob = s
        .execute_buf(&Manifest::init_name("nano", "adalomo"), &[&seed])
        .unwrap();
    let mut loader = DataLoader::lm(Domain::C4, 17, b, t, 40_000);
    let batch = loader.next_batch();
    let x = s.upload_i32(&batch.x, &[b, t]).unwrap();
    let y = s.upload_i32(&batch.y, &[b, t]).unwrap();
    let sched = s.upload_f32(&[5e-4, 1.0, 0.0, 1.0], &[4]).unwrap();

    let mono = s
        .execute_buf("train_step_nano_adalomo", &[&blob, &x, &y, &sched])
        .unwrap();
    let fused_out =
        fused::fused_step(&s, "nano", "adalomo", &blob, &x, &y, &sched)
            .unwrap();

    let a = s.fetch_f32_raw(&mono, layout.blob_len).unwrap();
    let bb = s.fetch_f32_raw(&fused_out, layout.blob_len).unwrap();
    let metrics_off = layout.metrics_offset();
    for i in 0..metrics_off {
        assert!(
            (a[i] - bb[i]).abs() <= 1e-5 + 3e-5 * a[i].abs(),
            "fused != monolithic at {i}: {} vs {}",
            a[i],
            bb[i]
        );
    }
}

#[test]
fn fused_group_sizes_cover_model() {
    let Some(s) = session() else { return };
    let sizes = fused::group_grad_sizes(&s, "nano", "adalomo").unwrap();
    let p = s.manifest.preset("nano").unwrap();
    assert_eq!(sizes.len(), p.fused_groups);
    let total: usize = sizes.iter().sum();
    assert_eq!(total, p.n_params);
    // Peak group << total: the liveness win at program granularity.
    assert!(*sizes.iter().max().unwrap() < total / 2);
}

#[test]
fn fused_training_reduces_loss() {
    let Some(s) = session() else { return };
    let p = s.manifest.preset("nano").unwrap().clone();
    let (b, t) = (p.batch_size, p.seq_len);
    let seed = s.upload_i32(&[23], &[]).unwrap();
    let mut blob = s
        .execute_buf(&Manifest::init_name("nano", "adalomo"), &[&seed])
        .unwrap();
    let mut loader = DataLoader::lm(Domain::C4, 23, b, t, 80_000);
    let mut first = None;
    let mut last = 0f32;
    for step in 1..=8 {
        let batch = loader.next_batch();
        let x = s.upload_i32(&batch.x, &[b, t]).unwrap();
        let y = s.upload_i32(&batch.y, &[b, t]).unwrap();
        let sched = s
            .upload_f32(&[1e-2, step as f32, 0.0, 1.0], &[4])
            .unwrap();
        blob = fused::fused_step(&s, "nano", "adalomo", &blob, &x, &y, &sched)
            .unwrap();
        let m = s
            .execute_buf(
                &Manifest::read_metrics_name("nano", "adalomo"),
                &[&blob],
            )
            .unwrap();
        let slots = s.fetch_f32_raw(&m, 8).unwrap();
        last = slots[0];
        first.get_or_insert(slots[0]);
    }
    assert!(last < first.unwrap(), "{:?} -> {last}", first);
}

#[test]
fn worker_pool_local_sgd_improves_over_init() {
    if !exp::artifacts_available() {
        return;
    }
    let mut cfg = RunConfig::new("nano", "adalomo", Phase::Scratch, 8);
    cfg.lr = 1e-2;
    cfg.seed = 31;
    let report = workers::run_local_sgd(
        exp::artifacts_dir(),
        cfg,
        Domain::C4,
        2, // ranks
        2, // rounds
        8, // steps per round
    )
    .unwrap();
    assert_eq!(report.n_ranks, 2);
    assert_eq!(report.per_rank_final_loss.len(), 2);
    for loss in &report.per_rank_final_loss {
        assert!(loss.is_finite() && *loss < 5.6, "{loss}");
    }
    // ln(256) = 5.545 is the uniform-prediction loss; averaged model must
    // beat it after 2 rounds.
    assert!(
        report.averaged_eval_loss < 5.54,
        "{}",
        report.averaged_eval_loss
    );
}

#[test]
fn worker_pool_state_survives_rounds() {
    // Regression for the state-retention bug: every rank's optimizer state
    // (AdaLomo second-moment factors) must be non-zero after round 2 — the
    // old implementation adopted the leader's state-zeroed blob at every
    // round boundary, wiping the factors each `sync_every` steps.
    if !exp::artifacts_available() {
        return;
    }
    let mut cfg = RunConfig::new("nano", "adalomo", Phase::Scratch, 4);
    cfg.lr = 1e-2;
    cfg.seed = 37;
    let report = workers::run_local_sgd(
        exp::artifacts_dir(),
        cfg,
        Domain::C4,
        2, // ranks
        2, // rounds
        4, // steps per round
    )
    .unwrap();
    assert_eq!(report.per_rank_state_sumsq.len(), 2);
    for (rank, sumsq) in report.per_rank_state_sumsq.iter().enumerate() {
        assert!(
            sumsq.is_finite() && *sumsq > 0.0,
            "rank {rank}: optimizer state wiped across rounds (sumsq {sumsq})"
        );
    }
}
