//! Data substrate integration: corpora statistics, instruction pipeline,
//! and the benchmark-suite scorer against real artifacts.

use adalomo::data::corpus::{byte_histogram, tv_distance, CorpusGen};
use adalomo::data::instruct::{self, Family};
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::eval::seq_mean_losses;
use adalomo::experiments as exp;
use adalomo::runtime::Manifest;

#[test]
fn domain_distances_drive_fig2_fig3() {
    // The substitution contract (DESIGN.md §4): chinese is far from the
    // pre-training mix, python is near, general ~ c4.
    let hist = |d: Domain| {
        byte_histogram(&CorpusGen::new(d, 1).stream(60_000))
    };
    let c4 = hist(Domain::C4);
    let d_zh = tv_distance(&c4, &hist(Domain::Chinese));
    let d_py = tv_distance(&c4, &hist(Domain::PythonCode));
    let d_gen = tv_distance(&c4, &hist(Domain::General));
    assert!(d_gen < d_py && d_py < d_zh);
}

#[test]
fn train_and_val_streams_are_disjoint_but_same_language() {
    let train = CorpusGen::new(Domain::Chinese, 1).stream(20_000);
    let val = CorpusGen::new(Domain::Chinese, 2).stream(20_000);
    assert_ne!(train[..200], val[..200]);
    let d = tv_distance(&byte_histogram(&train), &byte_histogram(&val));
    assert!(d < 0.05, "same language, same distribution: {d}");
}

#[test]
fn instruction_batches_fit_model_shapes() {
    let examples: Vec<_> = instruct::training_set(7, 64)
        .iter()
        .map(|e| e.tokenize())
        .collect();
    let mut dl = DataLoader::from_examples(examples, 7, 8, 64);
    for _ in 0..4 {
        let b = dl.next_batch();
        assert_eq!(b.x.len(), 8 * 64);
        assert!(b.x.iter().all(|&v| (0..256).contains(&v)));
        assert!(b.counted_tokens() > 0, "every batch needs loss targets");
    }
}

#[test]
fn mc_items_regenerate_deterministically() {
    for fam in [Family::Knowledge, Family::Arithmetic] {
        let a = instruct::eval_items(fam, 3, 10);
        let b = instruct::eval_items(fam, 3, 10);
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.options, y.options);
            assert_eq!(x.answer, y.answer);
        }
    }
}

#[test]
fn seq_loss_scorer_prefers_trained_continuations() {
    // Sanity of the scoring path itself: for a random model, per-sequence
    // losses are ~uniform(ln 256); shorter/longer options both score, and
    // the scorer is deterministic.
    if !exp::artifacts_available() {
        return;
    }
    let s = exp::open_session().unwrap();
    let seed = s.upload_i32(&[5], &[]).unwrap();
    let blob = s
        .execute_buf(&Manifest::init_name("nano", "adalomo"), &[&seed])
        .unwrap();
    let params = s
        .execute_buf(
            &Manifest::extract_params_name("nano", "adalomo"),
            &[&blob],
        )
        .unwrap();
    let rows: Vec<(Vec<i32>, Vec<i32>)> = vec![
        {
            let x = adalomo::data::tokenizer::encode("What is the capital? A");
            let mut y = vec![0; x.len()];
            y[x.len() - 2] = x[x.len() - 1];
            (x, y)
        },
        {
            let x = adalomo::data::tokenizer::encode("Some other prompt. BB");
            let mut y = vec![0; x.len()];
            y[x.len() - 3] = x[x.len() - 2];
            y[x.len() - 2] = x[x.len() - 1];
            (x, y)
        },
    ];
    let a = seq_mean_losses(&s, "nano", &params, &rows).unwrap();
    let b = seq_mean_losses(&s, "nano", &params, &rows).unwrap();
    assert_eq!(a, b, "scoring must be deterministic");
    for loss in &a {
        assert!(*loss > 2.0 && *loss < 9.0, "{loss}");
    }
}
