//! Trainer integration: real training loops over the nano artifacts.

use adalomo::config::{Phase, RunConfig};
use adalomo::coordinator::Trainer;
use adalomo::data::{loader::DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::runtime::Session;

fn session() -> Option<Session> {
    if !exp::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(exp::open_session().expect("session"))
}

fn loaders(s: &Session, domain: Domain, seed: u64) -> (DataLoader, DataLoader) {
    let p = s.manifest.preset("nano").unwrap();
    let (b, t) = (p.batch_size, p.seq_len);
    (
        DataLoader::lm(domain, seed, b, t, 120_000),
        DataLoader::lm(domain, seed + 1, b, t, 12_000),
    )
}

#[test]
fn adalomo_training_reduces_loss_and_ppl() {
    let Some(s) = session() else { return };
    let mut cfg = RunConfig::new("nano", "adalomo", Phase::Scratch, 25);
    cfg.lr = 1e-2;
    cfg.log_every = 5;
    cfg.eval_every = 25;
    let (train, val) = loaders(&s, Domain::C4, 11);
    let mut trainer = Trainer::new(&s, cfg, train, Some(val)).unwrap();
    let report = trainer.train().unwrap();
    assert!(report.curve.len() >= 5);
    let first = report.curve[0].1;
    let last = report.curve.last().unwrap().1;
    assert!(last < first - 0.1, "loss {first} -> {last}");
    let (_, ppl, acc) = report.eval_curve[0];
    assert!(ppl < 256.0, "ppl below uniform");
    assert!(acc > 0.02);
}

#[test]
fn training_is_seed_reproducible() {
    let Some(s) = session() else { return };
    let run = |seed: u64| {
        let mut cfg = RunConfig::new("nano", "adalomo", Phase::Scratch, 6);
        cfg.lr = 1e-2;
        cfg.log_every = 2;
        cfg.eval_every = 0;
        cfg.seed = seed;
        let (train, _) = loaders(&s, Domain::C4, seed);
        let mut trainer = Trainer::new(&s, cfg, train, None).unwrap();
        trainer.train().unwrap();
        trainer.host_blob().unwrap().data
    };
    let a = run(5);
    let b = run(5);
    let c = run(6);
    assert_eq!(a, b, "identical seeds must replay bit-identically");
    assert_ne!(a, c);
}

#[test]
fn checkpoint_repack_roundtrip_preserves_params() {
    let Some(s) = session() else { return };
    let mut cfg = RunConfig::new("nano", "adamw", Phase::Scratch, 4);
    cfg.lr = 1e-3;
    cfg.log_every = 2;
    cfg.eval_every = 0;
    let (train, _) = loaders(&s, Domain::C4, 3);
    let mut trainer = Trainer::new(&s, cfg, train, None).unwrap();
    trainer.train().unwrap();
    let adamw_blob = trainer.host_blob().unwrap();

    let repacked =
        exp::repack_checkpoint(&s, &adamw_blob, "nano", "adalomo").unwrap();
    let from = s.manifest.layout("nano/adamw").unwrap();
    let to = s.manifest.layout("nano/adalomo").unwrap();
    assert_eq!(repacked.data.len(), to.blob_len);
    assert_eq!(
        repacked.data[..to.params_len],
        adamw_blob.data[..from.params_len]
    );
    assert!(repacked.data[to.params_len..].iter().all(|&v| v == 0.0));

    // The repacked blob must actually train.
    let mut cfg2 = RunConfig::new("nano", "adalomo", Phase::Scratch, 3);
    cfg2.lr = 1e-2;
    cfg2.log_every = 1;
    cfg2.eval_every = 0;
    let (train2, _) = loaders(&s, Domain::C4, 4);
    let mut t2 = Trainer::new(&s, cfg2, train2, None).unwrap();
    t2.set_host_blob(&repacked).unwrap();
    let report = t2.train().unwrap();
    assert!(report.final_loss.is_finite());
}

#[test]
fn gnorm_variant_trains() {
    let Some(s) = session() else { return };
    let mut cfg = RunConfig::new("nano", "adalomo_gnorm", Phase::Scratch, 6);
    cfg.lr = 1e-2;
    cfg.log_every = 2;
    cfg.eval_every = 0;
    let (train, _) = loaders(&s, Domain::C4, 9);
    let mut trainer = Trainer::new(&s, cfg, train, None).unwrap();
    let report = trainer.train().unwrap();
    assert!(report.final_loss.is_finite());
}

#[test]
fn lora_trains_and_freezes_base() {
    let Some(s) = session() else { return };
    let layout = s.manifest.layout("nano/lora").unwrap().clone();
    let mut cfg = RunConfig::new("nano", "lora", Phase::Instruct, 5);
    cfg.lr = 3e-3;
    cfg.log_every = 5;
    cfg.eval_every = 0;
    let (train, _) = loaders(&s, Domain::C4, 13);
    let mut trainer = Trainer::new(&s, cfg, train, None).unwrap();
    trainer.init_from_seed().unwrap();
    let before = trainer.host_blob().unwrap();
    trainer.train().unwrap();
    let after = trainer.host_blob().unwrap();
    // Frozen base identical; at least one adapter changed.
    let mut base_same = true;
    let mut adapter_moved = false;
    for seg in &layout.segments {
        let range = seg.offset..seg.offset + seg.size;
        match seg.kind.as_str() {
            "frozen" => {
                base_same &=
                    before.data[range.clone()] == after.data[range.clone()];
            }
            "param" => {
                adapter_moved |= before.data[range.clone()]
                    != after.data[range.clone()];
            }
            _ => {}
        }
    }
    assert!(base_same, "base weights must stay frozen under LoRA");
    assert!(adapter_moved, "adapters must update");
}

#[test]
fn all_optimizer_entries_run_one_step() {
    let Some(s) = session() else { return };
    for opt in [
        "sgd",
        "sgd_momentum",
        "sgd_variance",
        "adamw",
        "adafactor",
        "lomo",
        "adalomo",
        "lomo_gnorm",
        "adalomo_gnorm",
        "lora",
    ] {
        let mut cfg = RunConfig::new("nano", opt, Phase::Scratch, 1);
        cfg.lr = 1e-3;
        cfg.log_every = 1;
        cfg.eval_every = 0;
        let (train, _) = loaders(&s, Domain::C4, 21);
        let mut trainer = Trainer::new(&s, cfg, train, None).unwrap();
        let report = trainer.train().unwrap();
        assert!(report.final_loss.is_finite(), "{opt}");
    }
}
