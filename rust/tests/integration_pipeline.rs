//! Async rank pipeline integration: the pipelined trainer against the
//! lockstep flat-engine path on the host mirror (always runs), plus the
//! artifact-gated real-PJRT determinism check for the slim-broadcast
//! local-SGD protocol (run via `cargo test -- --ignored`).

use adalomo::config::{Phase, RunConfig};
use adalomo::coordinator::pipeline::{self, PipelineConfig};
use adalomo::coordinator::workers;
use adalomo::data::{DataLoader, Domain};
use adalomo::experiments as exp;
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, ShardMode,
};
use adalomo::optim::OptKind;
use adalomo::runtime::Layout;

fn model_layout(kind: OptKind) -> Layout {
    let params: Vec<(&str, &[usize])> = vec![
        ("embed", &[32, 16][..]),
        ("l0.attn_norm", &[16][..]),
        ("l0.wq", &[16, 16][..]),
        ("l0.w_down", &[24, 16][..]),
        ("l1.wq", &[16, 16][..]),
        ("final_norm", &[16][..]),
        ("head", &[16, 32][..]),
    ];
    synthetic_layout(kind, &params)
}

#[test]
fn pipelined_eval_losses_match_sequential_exactly() {
    // Train with data-conditioned gradients on both paths, then score the
    // final parameters on the FIXED validation set: losses must agree to
    // the last bit. That follows from (a) the pipeline's bitwise-identity
    // guarantee and (b) `DataLoader::reset` replaying the identical batch
    // sequence inside `host_eval_loss` (PR 1's determinism fix) — a
    // regression in either breaks this test.
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 21);
    let mut cfg = PipelineConfig::new(6, layout.params_len / 5);
    cfg.n_shards = 2;
    let sources =
        || pipeline::token_sources(Domain::C4, 51, 2, 2, 16, 4_000, 5e-3);
    let (pipe, _) = pipeline::run_pipelined(
        &layout,
        kind,
        ShardMode::Contiguous,
        &blob0,
        sources(),
        &cfg,
    )
    .unwrap();
    let (seq, _) = pipeline::run_sequential(
        &layout,
        kind,
        ShardMode::Contiguous,
        &blob0,
        sources(),
        &cfg,
    )
    .unwrap();
    assert_eq!(pipe.len(), seq.len());
    for (i, (a, b)) in pipe.iter().zip(&seq).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "elem {i}: {a} vs {b}");
    }
    let mut val = DataLoader::lm(Domain::C4, 999, 2, 16, 4_000);
    let lp =
        pipeline::host_eval_loss(&pipe[..layout.params_len], &mut val, 4);
    let ls =
        pipeline::host_eval_loss(&seq[..layout.params_len], &mut val, 4);
    assert_eq!(lp.to_bits(), ls.to_bits(), "{lp} vs {ls}");
    // The comparison is not vacuous: training moved the parameters.
    assert!(pipe[..layout.params_len]
        .iter()
        .zip(&blob0[..layout.params_len])
        .any(|(a, b)| a != b));
}

#[test]
fn overlap_report_beats_lockstep_exposure() {
    // On >= 2 ranks the modeled critical path must sit strictly below the
    // fully-exposed compute + comm sum (the acceptance bar for the
    // pipeline actually hiding exchange behind stepping), while never
    // beating the physical floor of max(compute, comm).
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 23);
    let mut cfg = PipelineConfig::new(4, layout.params_len.div_ceil(8));
    cfg.n_shards = 2;
    let sources = pipeline::synthetic_sources(2, 7, 0.05);
    let (_, report) = pipeline::run_pipelined(
        &layout,
        kind,
        ShardMode::Segments,
        &blob0,
        sources,
        &cfg,
    )
    .unwrap();
    assert_eq!(report.n_ranks, 2);
    assert_eq!(report.n_buckets, 8);
    assert!(report.comm_secs > 0.0);
    assert!(report.compute_secs > 0.0);
    let sum = report.comm_secs + report.compute_secs;
    assert!(
        report.exposed_secs < sum,
        "no overlap achieved: exposed {} vs compute+comm {sum}",
        report.exposed_secs
    );
    let floor = report.comm_secs.max(report.compute_secs);
    assert!(
        report.exposed_secs >= floor * (1.0 - 1e-9),
        "exposed {} below the physical floor {floor}",
        report.exposed_secs
    );
    assert!(report.overlap_efficiency > 1.0);
}

/// Real-PJRT path (run via `cargo test -- --ignored` after `make
/// artifacts`, e.g. in the CI `pjrt` job): two identical local-SGD runs
/// over the slim [`workers::Broadcast`] protocol must agree exactly — the
/// whole multi-threaded round loop, including the params-only sync, is
/// deterministic.
#[test]
#[ignore = "requires AOT artifacts + real PJRT (make artifacts)"]
fn local_sgd_slim_broadcast_is_deterministic() {
    if !exp::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return;
    }
    let run = || {
        let mut cfg = RunConfig::new("nano", "adalomo", Phase::Scratch, 4);
        cfg.lr = 1e-2;
        cfg.seed = 43;
        workers::run_local_sgd(exp::artifacts_dir(), cfg, Domain::C4, 2, 2, 4)
            .unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(
        a.averaged_eval_loss.to_bits(),
        b.averaged_eval_loss.to_bits(),
        "{} vs {}",
        a.averaged_eval_loss,
        b.averaged_eval_loss
    );
    assert_eq!(a.per_rank_final_loss, b.per_rank_final_loss);
    for (x, y) in a
        .per_rank_state_sumsq
        .iter()
        .zip(&b.per_rank_state_sumsq)
    {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}
