//! End-to-end tests for the `analyze` static-analysis pass: the library
//! API over the real checkout, and the `adalomo analyze` binary's exit
//! codes over seeded-violation fixture trees (one per rule) and the
//! clean tree.

use std::path::{Path, PathBuf};
use std::process::Command;

use adalomo::analysis;
use adalomo::util::json::Json;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// The tree as committed must carry zero unwaivered findings — this is
/// the library-level twin of the `make analyze` gate.
#[test]
fn clean_tree_has_no_violations() {
    let report = analysis::run(&repo_root()).expect("analyze runs");
    let violations = report.violations();
    assert!(
        violations.is_empty(),
        "unwaivered findings on the committed tree: {violations:#?}"
    );
    assert!(report.files_scanned > 20, "tree walk looks too small");
}

/// The consistency rule must independently re-derive the bench-metric
/// name set that `bench-check` gates against: exactly the keys of
/// bench/baseline.json.
#[test]
fn consistency_rederives_bench_metric_set() {
    let report = analysis::run(&repo_root()).expect("analyze runs");
    let baseline_text =
        std::fs::read_to_string(repo_root().join("bench/baseline.json"))
            .expect("baseline exists");
    let baseline = Json::parse(&baseline_text).expect("baseline parses");
    let keys: Vec<String> =
        baseline.as_obj().expect("object").keys().cloned().collect();
    assert_eq!(
        report.bench_metrics, keys,
        "statically derived metric set != baseline keys"
    );
    assert!(
        report.bench_metrics.len() >= 13,
        "expected the full tracked-metric set, got {:?}",
        report.bench_metrics
    );
}

/// Scratch area for fixture trees. Unique per test (no clocks/randomness:
/// pid + test name), cleaned up on entry so reruns start fresh.
fn fixture_root(test: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("adalomo-analyze-{}-{test}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("rust/src")).expect("mkdir fixture");
    dir
}

fn write(root: &Path, rel: &str, text: &str) {
    let path = root.join(rel);
    std::fs::create_dir_all(path.parent().expect("parent")).expect("mkdir");
    std::fs::write(path, text).expect("write fixture file");
}

/// Run `adalomo analyze --root <root>` and return (exit_code, stdout).
fn run_analyze(root: &Path) -> (i32, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_adalomo"))
        .args(["analyze", "--root"])
        .arg(root)
        .arg("--json")
        .arg(root.join("report.json"))
        .output()
        .expect("spawn adalomo analyze");
    (
        out.status.code().expect("exit code"),
        String::from_utf8_lossy(&out.stdout).into_owned(),
    )
}

/// Every rule's seeded violation must drive a nonzero exit, and the
/// fixed fixture must come back clean — the binary-level acceptance
/// criterion for the gate.
#[test]
fn binary_exits_nonzero_on_each_seeded_rule_violation() {
    // (rule, file, content) — one minimal violation per registry rule.
    let seeds: &[(&str, &str, &str)] = &[
        (
            "waiver-syntax",
            "rust/src/coordinator/x.rs",
            "// ANALYZE-WAIVE(determinism) missing colon\nfn f() {}\n",
        ),
        ("no-unsafe", "rust/src/coordinator/x.rs", "unsafe fn f() {}\n"),
        (
            "determinism",
            "rust/src/coordinator/x.rs",
            "use std::collections::HashMap;\n",
        ),
        (
            "panic-discipline",
            "rust/src/coordinator/x.rs",
            "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        ),
        (
            "consistency",
            "rust/src/runtime/checkpoint.rs",
            "pub const VERSION: u32 = 2;\n", // no docs pin anywhere
        ),
    ];
    for (rule, file, content) in seeds {
        let root = fixture_root(&format!("seed-{rule}"));
        write(&root, file, content);
        let (code, stdout) = run_analyze(&root);
        assert_eq!(
            code, 1,
            "{rule}: seeded violation must exit 1; stdout:\n{stdout}"
        );
        assert!(
            stdout.contains(&format!("VIOLATION [{rule}]")),
            "{rule}: violation line missing from output:\n{stdout}"
        );
        // The JSON report is written even on failure and attributes the
        // violation to the right rule.
        let report =
            std::fs::read_to_string(root.join("report.json")).expect("json");
        let j = Json::parse(&report).expect("report parses");
        assert!(
            j.get("rules")
                .and_then(|r| r.get(rule))
                .and_then(|r| r.get("violations"))
                .and_then(|v| v.as_usize())
                .expect("rule counter")
                >= 1,
            "{rule}: JSON report counter not bumped"
        );
        let _ = std::fs::remove_dir_all(&root);
    }
}

/// One minimal seeded violation per concurrency-protocol rule. Kept out
/// of the flat `seeds` table above because each fixture is a small
/// multi-line program, not a one-liner.
#[test]
fn binary_exits_nonzero_on_each_seeded_conc_violation() {
    let seeds: &[(&str, &str)] = &[
        (
            // Two functions acquire the same two mutexes in opposite
            // orders — the canonical static deadlock witness.
            "lock-order",
            r#"use std::sync::Mutex;
pub struct S { pub a: Mutex<u32>, pub b: Mutex<u32> }
pub fn fwd(s: &S) {
    let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());
    let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());
    drop(gb);
    drop(ga);
}
pub fn rev(s: &S) {
    let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());
    let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());
    drop(ga);
    drop(gb);
}
"#,
        ),
        (
            // A wait outside a predicate loop whose condvar is never
            // notified anywhere in the tree.
            "condvar-discipline",
            r#"use std::sync::{Condvar, Mutex};
pub struct S { pub m: Mutex<bool>, pub cv: Condvar }
pub fn bad(s: &S) {
    let g = s.m.lock().unwrap_or_else(|e| e.into_inner());
    let g = s.cv.wait(g).unwrap_or_else(|e| e.into_inner());
    drop(g);
}
"#,
        ),
        (
            // The receiver half of a channel is created and then never
            // touched again — sends go nowhere.
            "channel-topology",
            r#"use std::sync::mpsc::channel;
pub fn orphan() -> u32 {
    let (tx, rx) = channel::<u32>();
    let _ = tx.send(1);
    7
}
"#,
        ),
        (
            // Buffers drained off the ring are never handed back on the
            // ret_* endpoint — the alloc-free steady state leaks.
            "channel-topology",
            r#"use std::sync::mpsc::{Receiver, Sender};
pub fn drain(rx: &Receiver<Vec<f32>>, ret_tx: &Sender<Vec<f32>>) -> usize {
    let mut n = 0;
    while let Ok(buf) = rx.try_recv() {
        n += buf.len();
    }
    let _keep = ret_tx;
    n
}
"#,
        ),
        (
            // unwrap() while a MutexGuard is live (and not the waived
            // lock().unwrap() acquisition idiom).
            "lock-held-panic",
            r#"use std::sync::Mutex;
pub fn bad(m: &Mutex<Vec<u32>>) -> u32 {
    let g = m.lock().unwrap_or_else(|e| e.into_inner());
    g.first().copied().unwrap()
}
"#,
        ),
    ];
    for (i, (rule, content)) in seeds.iter().enumerate() {
        let root = fixture_root(&format!("conc-{i}-{rule}"));
        write(&root, "rust/src/optim/x.rs", content);
        let (code, stdout) = run_analyze(&root);
        assert_eq!(
            code, 1,
            "{rule}: seeded violation must exit 1; stdout:\n{stdout}"
        );
        assert!(
            stdout.contains(&format!("VIOLATION [{rule}]")),
            "{rule}: violation line missing from output:\n{stdout}"
        );
        let report =
            std::fs::read_to_string(root.join("report.json")).expect("json");
        let j = Json::parse(&report).expect("report parses");
        assert!(
            j.get("rules")
                .and_then(|r| r.get(rule))
                .and_then(|r| r.get("violations"))
                .and_then(|v| v.as_usize())
                .expect("rule counter")
                >= 1,
            "{rule}: JSON report counter not bumped"
        );
        let _ = std::fs::remove_dir_all(&root);
    }
}

/// The lock-order inversion from the seed table, with the anchoring
/// acquisition explicitly waived — exits 0 and reports the waiver, the
/// same contract the committed tree relies on.
#[test]
fn binary_exits_zero_on_waived_conc_fixture() {
    let root = fixture_root("conc-waived");
    write(
        &root,
        "rust/src/optim/x.rs",
        r#"use std::sync::Mutex;
pub struct S { pub a: Mutex<u32>, pub b: Mutex<u32> }
pub fn fwd(s: &S) {
    let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());
    // ANALYZE-WAIVE(lock-order): fixture inversion kept on purpose
    let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());
    drop(gb);
    drop(ga);
}
pub fn rev(s: &S) {
    let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());
    let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());
    drop(ga);
    drop(gb);
}
"#,
    );
    let (code, stdout) = run_analyze(&root);
    assert_eq!(
        code, 0,
        "waived inversion must exit 0; stdout:\n{stdout}"
    );
    assert!(
        stdout.contains("1 waived"),
        "waived cycle should be reported:\n{stdout}"
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// `--sarif` writes a parseable SARIF 2.1.0 report even on failure, and
/// `--bless-waivers` prints the removal diff for stale waivers.
#[test]
fn sarif_output_and_stale_waiver_blessing() {
    let root = fixture_root("sarif");
    write(
        &root,
        "rust/src/coordinator/x.rs",
        "// ANALYZE-WAIVE(determinism): long-gone HashMap\n\
         pub fn f() -> u32 {\n    7\n}\n",
    );
    let out = Command::new(env!("CARGO_BIN_EXE_adalomo"))
        .args(["analyze", "--root"])
        .arg(&root)
        .arg("--sarif")
        .arg(root.join("report.sarif"))
        .output()
        .expect("spawn adalomo analyze");
    // The waiver no longer matches any finding, so it is itself a
    // violation now — but the SARIF report must still be written.
    assert_eq!(out.status.code(), Some(1));
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("stale waiver"),
        "stale waiver must surface as a violation:\n{stdout}"
    );
    let sarif =
        std::fs::read_to_string(root.join("report.sarif")).expect("sarif");
    assert!(Json::parse(&sarif).is_ok(), "SARIF must be valid JSON");
    assert!(sarif.contains("\"2.1.0\""), "SARIF version pin missing");
    assert!(sarif.contains("adalomo-analyze"), "driver name missing");

    let bless = Command::new(env!("CARGO_BIN_EXE_adalomo"))
        .args(["analyze", "--root"])
        .arg(&root)
        .arg("--bless-waivers")
        .output()
        .expect("spawn adalomo analyze --bless-waivers");
    assert_eq!(bless.status.code(), Some(1));
    let bstdout = String::from_utf8_lossy(&bless.stdout);
    assert!(
        bstdout.contains("rust/src/coordinator/x.rs:1"),
        "removal diff must name the stale waiver line:\n{bstdout}"
    );
    assert!(
        bstdout.contains("ANALYZE-WAIVE(determinism)"),
        "removal diff must echo the line to delete:\n{bstdout}"
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// A fixture with the violation fixed (or waived) exits 0 — the gate
/// passes clean trees, not just fails dirty ones.
#[test]
fn binary_exits_zero_on_clean_fixture() {
    let root = fixture_root("clean");
    write(
        &root,
        "rust/src/coordinator/x.rs",
        "use std::collections::BTreeMap;\n\
         pub fn f() -> BTreeMap<u32, u32> {\n    BTreeMap::new()\n}\n",
    );
    write(
        &root,
        "rust/src/runtime/y.rs",
        "pub fn t() -> std::time::Instant {\n    \
         // ANALYZE-WAIVE(determinism): report-only timing\n    \
         std::time::Instant::now()\n}\n",
    );
    let (code, stdout) = run_analyze(&root);
    assert_eq!(code, 0, "clean fixture must exit 0; stdout:\n{stdout}");
    assert!(
        stdout.contains("1 waived"),
        "waived finding should be reported:\n{stdout}"
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// The shipped binary exits 0 on the shipped tree — the exact command
/// `make analyze` runs in CI.
#[test]
fn binary_exits_zero_on_real_tree() {
    let root = fixture_root("real");
    let out = Command::new(env!("CARGO_BIN_EXE_adalomo"))
        .args(["analyze", "--root"])
        .arg(repo_root())
        .arg("--json")
        .arg(root.join("report.json"))
        .output()
        .expect("spawn adalomo analyze");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(
        out.status.code(),
        Some(0),
        "analyze must pass on the committed tree; stdout:\n{stdout}"
    );
    let report =
        std::fs::read_to_string(root.join("report.json")).expect("json");
    let j = Json::parse(&report).expect("report parses");
    assert_eq!(
        j.get("violations").and_then(|v| v.as_usize()).expect("count"),
        0
    );
    let _ = std::fs::remove_dir_all(&root);
}
