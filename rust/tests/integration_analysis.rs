//! End-to-end tests for the `analyze` static-analysis pass: the library
//! API over the real checkout, and the `adalomo analyze` binary's exit
//! codes over seeded-violation fixture trees (one per rule) and the
//! clean tree.

use std::path::{Path, PathBuf};
use std::process::Command;

use adalomo::analysis;
use adalomo::util::json::Json;

fn repo_root() -> PathBuf {
    PathBuf::from(env!("CARGO_MANIFEST_DIR"))
}

/// The tree as committed must carry zero unwaivered findings — this is
/// the library-level twin of the `make analyze` gate.
#[test]
fn clean_tree_has_no_violations() {
    let report = analysis::run(&repo_root()).expect("analyze runs");
    let violations = report.violations();
    assert!(
        violations.is_empty(),
        "unwaivered findings on the committed tree: {violations:#?}"
    );
    assert!(report.files_scanned > 20, "tree walk looks too small");
}

/// The consistency rule must independently re-derive the bench-metric
/// name set that `bench-check` gates against: exactly the keys of
/// bench/baseline.json.
#[test]
fn consistency_rederives_bench_metric_set() {
    let report = analysis::run(&repo_root()).expect("analyze runs");
    let baseline_text =
        std::fs::read_to_string(repo_root().join("bench/baseline.json"))
            .expect("baseline exists");
    let baseline = Json::parse(&baseline_text).expect("baseline parses");
    let keys: Vec<String> =
        baseline.as_obj().expect("object").keys().cloned().collect();
    assert_eq!(
        report.bench_metrics, keys,
        "statically derived metric set != baseline keys"
    );
    assert!(
        report.bench_metrics.len() >= 13,
        "expected the full tracked-metric set, got {:?}",
        report.bench_metrics
    );
}

/// Scratch area for fixture trees. Unique per test (no clocks/randomness:
/// pid + test name), cleaned up on entry so reruns start fresh.
fn fixture_root(test: &str) -> PathBuf {
    let dir = std::env::temp_dir()
        .join(format!("adalomo-analyze-{}-{test}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(dir.join("rust/src")).expect("mkdir fixture");
    dir
}

fn write(root: &Path, rel: &str, text: &str) {
    let path = root.join(rel);
    std::fs::create_dir_all(path.parent().expect("parent")).expect("mkdir");
    std::fs::write(path, text).expect("write fixture file");
}

/// Run `adalomo analyze --root <root>` and return (exit_code, stdout).
fn run_analyze(root: &Path) -> (i32, String) {
    let out = Command::new(env!("CARGO_BIN_EXE_adalomo"))
        .args(["analyze", "--root"])
        .arg(root)
        .arg("--json")
        .arg(root.join("report.json"))
        .output()
        .expect("spawn adalomo analyze");
    (
        out.status.code().expect("exit code"),
        String::from_utf8_lossy(&out.stdout).into_owned(),
    )
}

/// Every rule's seeded violation must drive a nonzero exit, and the
/// fixed fixture must come back clean — the binary-level acceptance
/// criterion for the gate.
#[test]
fn binary_exits_nonzero_on_each_seeded_rule_violation() {
    // (rule, file, content) — one minimal violation per registry rule.
    let seeds: &[(&str, &str, &str)] = &[
        (
            "waiver-syntax",
            "rust/src/coordinator/x.rs",
            "// ANALYZE-WAIVE(determinism) missing colon\nfn f() {}\n",
        ),
        ("no-unsafe", "rust/src/coordinator/x.rs", "unsafe fn f() {}\n"),
        (
            "determinism",
            "rust/src/coordinator/x.rs",
            "use std::collections::HashMap;\n",
        ),
        (
            "panic-discipline",
            "rust/src/coordinator/x.rs",
            "fn f(v: Option<u32>) -> u32 { v.unwrap() }\n",
        ),
        (
            "consistency",
            "rust/src/runtime/checkpoint.rs",
            "pub const VERSION: u32 = 2;\n", // no docs pin anywhere
        ),
    ];
    for (rule, file, content) in seeds {
        let root = fixture_root(&format!("seed-{rule}"));
        write(&root, file, content);
        let (code, stdout) = run_analyze(&root);
        assert_eq!(
            code, 1,
            "{rule}: seeded violation must exit 1; stdout:\n{stdout}"
        );
        assert!(
            stdout.contains(&format!("VIOLATION [{rule}]")),
            "{rule}: violation line missing from output:\n{stdout}"
        );
        // The JSON report is written even on failure and attributes the
        // violation to the right rule.
        let report =
            std::fs::read_to_string(root.join("report.json")).expect("json");
        let j = Json::parse(&report).expect("report parses");
        assert!(
            j.get("rules")
                .and_then(|r| r.get(rule))
                .and_then(|r| r.get("violations"))
                .and_then(|v| v.as_usize())
                .expect("rule counter")
                >= 1,
            "{rule}: JSON report counter not bumped"
        );
        let _ = std::fs::remove_dir_all(&root);
    }
}

/// A fixture with the violation fixed (or waived) exits 0 — the gate
/// passes clean trees, not just fails dirty ones.
#[test]
fn binary_exits_zero_on_clean_fixture() {
    let root = fixture_root("clean");
    write(
        &root,
        "rust/src/coordinator/x.rs",
        "use std::collections::BTreeMap;\n\
         pub fn f() -> BTreeMap<u32, u32> {\n    BTreeMap::new()\n}\n",
    );
    write(
        &root,
        "rust/src/runtime/y.rs",
        "pub fn t() -> std::time::Instant {\n    \
         // ANALYZE-WAIVE(determinism): report-only timing\n    \
         std::time::Instant::now()\n}\n",
    );
    let (code, stdout) = run_analyze(&root);
    assert_eq!(code, 0, "clean fixture must exit 0; stdout:\n{stdout}");
    assert!(
        stdout.contains("1 waived"),
        "waived finding should be reported:\n{stdout}"
    );
    let _ = std::fs::remove_dir_all(&root);
}

/// The shipped binary exits 0 on the shipped tree — the exact command
/// `make analyze` runs in CI.
#[test]
fn binary_exits_zero_on_real_tree() {
    let root = fixture_root("real");
    let out = Command::new(env!("CARGO_BIN_EXE_adalomo"))
        .args(["analyze", "--root"])
        .arg(repo_root())
        .arg("--json")
        .arg(root.join("report.json"))
        .output()
        .expect("spawn adalomo analyze");
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert_eq!(
        out.status.code(),
        Some(0),
        "analyze must pass on the committed tree; stdout:\n{stdout}"
    );
    let report =
        std::fs::read_to_string(root.join("report.json")).expect("json");
    let j = Json::parse(&report).expect("report parses");
    assert_eq!(
        j.get("violations").and_then(|v| v.as_usize()).expect("count"),
        0
    );
    let _ = std::fs::remove_dir_all(&root);
}
