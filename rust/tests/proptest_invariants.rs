//! Property-based tests over hand-rolled generators (the proptest crate is
//! not in the offline registry). Each property runs across a deterministic
//! sweep of random cases; failures print the case seed.

use adalomo::coordinator::collective::WireCodec;
use adalomo::coordinator::engine::{Engine, ExecPlan, RankSources};
use adalomo::coordinator::fused_host::{self, FusedHostGrads, GroupGradSource};
use adalomo::coordinator::pipeline::GradSource;
use adalomo::coordinator::{pipeline, sharding};
use adalomo::data::loader::DataLoader;
use adalomo::memsim::{liveness, memory, Arch};
use adalomo::optim::flat::{synthetic_layout, FlatOptimizer, ShardMode};
use adalomo::optim::{grouped_normalize, Hyper, OptKind, ParamOpt, ALL_OPTS};
use adalomo::runtime::{Layout, Segment};
use adalomo::tensor::{Dtype, Tensor};
use adalomo::util::rng::Pcg32;

const CASES: u64 = 60;

fn rand_tensor(rng: &mut Pcg32, shape: &[usize], scale: f32) -> Tensor {
    Tensor::from_fn(shape, |_| rng.normal() * scale)
}

#[test]
fn prop_grouped_norm_rms_bound() {
    // After grouped normalization, RMS(u) <= max(eps, RMS(theta)) and the
    // scale is finite-positive — for any magnitudes.
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(seed);
        let m = 1 + rng.below(20);
        let n = 1 + rng.below(20);
        let mag = 10f32.powf(rng.f32() * 8.0 - 4.0);
        let mut u = rand_tensor(&mut rng, &[m, n], mag);
        let theta = rand_tensor(&mut rng, &[m, n], 0.3);
        let stats = grouped_normalize(&mut u, &theta, 1e-3);
        let bound = 1e-3f32.max(stats.rms_theta);
        assert!(
            u.rms() <= bound * 1.001,
            "seed {seed}: rms {} bound {bound}",
            u.rms()
        );
        assert!(stats.scale.is_finite() && stats.scale > 0.0, "seed {seed}");
    }
}

#[test]
fn prop_adalomo_factors_stay_nonnegative() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(seed);
        let m = 2 + rng.below(12);
        let n = 2 + rng.below(12);
        let mut theta = rand_tensor(&mut rng, &[m, n], 0.2);
        let mut opt = ParamOpt::new(OptKind::AdaLomo, &[m, n]);
        for t in 1..12 {
            let g = rand_tensor(&mut rng, &[m, n], 0.1);
            opt.step(&mut theta, &g, t, 1e-3, 0.0);
            let (r, c) = opt.factored_state().unwrap();
            assert!(
                r.data().iter().all(|&x| x >= 0.0)
                    && c.data().iter().all(|&x| x >= 0.0),
                "seed {seed} t {t}"
            );
        }
        assert!(theta.data().iter().all(|v| v.is_finite()), "seed {seed}");
    }
}

#[test]
fn prop_adalomo_step_bounded_by_relative_lr() {
    // |Δθ|_rms <= lr * max(eps, RMS(θ)) — the stability property grouped
    // normalization buys (paper §3.2), for any gradient scale.
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(1000 + seed);
        let m = 2 + rng.below(10);
        let n = 2 + rng.below(10);
        let mag = 10f32.powf(rng.f32() * 10.0 - 5.0);
        let mut theta = rand_tensor(&mut rng, &[m, n], 0.2);
        let before = theta.clone();
        let g = rand_tensor(&mut rng, &[m, n], mag);
        let lr = 0.01;
        let mut opt = ParamOpt::new(OptKind::AdaLomo, &[m, n]);
        opt.step(&mut theta, &g, 1, lr, 0.0);
        let delta = theta.sub(&before);
        let bound = lr * 1e-3f32.max(before.rms());
        assert!(
            delta.rms() <= bound * 1.01,
            "seed {seed}: step {} bound {bound} (grad mag {mag})",
            delta.rms()
        );
    }
}

#[test]
fn prop_state_floats_match_allocation() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(2000 + seed);
        let shape: Vec<usize> = if rng.below(2) == 0 {
            vec![1 + rng.below(40), 1 + rng.below(40)]
        } else {
            vec![1 + rng.below(200)]
        };
        for kind in adalomo::optim::ALL_OPTS {
            let opt = ParamOpt::new(kind, &shape);
            assert_eq!(
                opt.state_floats(),
                kind.state_floats(&shape),
                "seed {seed} {kind:?} {shape:?}"
            );
        }
    }
}

#[test]
fn prop_sharding_partitions_exactly() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(3000 + seed);
        let n_segs = 1 + rng.below(12);
        let mut segments = Vec::new();
        let mut off = 0usize;
        for i in 0..n_segs {
            let size = 1 + rng.below(500);
            segments.push(Segment {
                name: format!("s{i}"),
                kind: if rng.below(2) == 0 { "param" } else { "state" }
                    .to_string(),
                shape: vec![size],
                offset: off,
                size,
                dtype: Dtype::F32,
            });
            off += size;
        }
        segments.push(Segment {
            name: "metrics".into(),
            kind: "metric".into(),
            shape: vec![8],
            offset: off,
            size: 8,
            dtype: Dtype::F32,
        });
        let layout = Layout {
            blob_len: off + 8,
            params_len: off,
            segments,
        };
        let n_ranks = 1 + rng.below(9);
        let plan = sharding::plan_contiguous(&layout, n_ranks);
        sharding::validate_contiguous(&layout, &plan)
            .unwrap_or_else(|e| panic!("seed {seed}: {e}"));
        // Segment plan covers each non-metric segment exactly once.
        let splan = sharding::plan_segments(&layout, n_ranks);
        let total: usize = splan.iter().map(|s| s.floats).sum();
        assert_eq!(total, off, "seed {seed}");
    }
}

#[test]
fn prop_dataloader_windows_valid() {
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(4000 + seed);
        let t = 4 + rng.below(30);
        let b = 1 + rng.below(4);
        let len = b * (t + 1) + rng.below(5000);
        let stream: Vec<u8> =
            (0..len).map(|_| (1 + rng.below(255)) as u8).collect();
        let mut dl = DataLoader::from_stream(stream.clone(), seed, b, t);
        for _ in 0..3 {
            let batch = dl.next_batch();
            // Every row must be a contiguous window with y = shift(x).
            for row in 0..b {
                for j in 0..t - 1 {
                    assert_eq!(
                        batch.x[row * t + j + 1],
                        batch.y[row * t + j],
                        "seed {seed}"
                    );
                }
            }
        }
    }
}

#[test]
fn prop_memsim_monotonicity() {
    // More parameters -> more memory, for every method; AdaLomo total is
    // never above AdamW.
    let act = memory::calibrate();
    let archs = ["llama1b1", "llama7b", "llama13b", "llama30b", "llama65b"];
    for method in memory::PROFILE_METHODS {
        let mut prev = 0.0;
        for arch in archs {
            let setup = memory::TrainSetup {
                arch: Arch::analytic(arch).unwrap(),
                method,
                n_gpus: 8,
                micro_batch: 4,
                seq_len: 2048,
            };
            let total = memory::estimate(&setup, act).total();
            assert!(total > prev, "{method:?} {arch}");
            prev = total;
            let adamw = memory::estimate(
                &memory::TrainSetup {
                    method: memory::Method::AdamW,
                    ..setup.clone()
                },
                act,
            )
            .total();
            if method == memory::Method::AdaLomo {
                assert!(total < adamw, "{arch}");
            }
        }
    }
}

#[test]
fn prop_liveness_peak_bounds() {
    // Fused peak <= 2 * largest matrix; standard peak == total; for any
    // architecture shape.
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(5000 + seed);
        let arch = Arch::new(
            "fuzz",
            64 + rng.below(512),
            8 * (1 + rng.below(64)),
            1 + rng.below(12),
            4,
            8 * (1 + rng.below(128)),
        );
        let fused = liveness::simulate(&arch, liveness::BackwardMode::Fused);
        let std = liveness::simulate(&arch, liveness::BackwardMode::Standard);
        assert!(fused.peak_bytes <= 2 * 2 * arch.max_matrix(), "seed {seed}");
        assert_eq!(std.peak_bytes, 2 * arch.n_params(), "seed {seed}");
        assert!(fused.peak_bytes <= std.peak_bytes);
    }
}

/// Reference path for the engine parity tests: one [`ParamOpt`] + one
/// [`Tensor`] per trainable segment, stepped over the same gradient images.
fn param_opt_reference(
    layout: &Layout,
    kind: OptKind,
    blob0: &[f32],
    grads: &[Vec<f32>],
    lr: f32,
    wd: f32,
) -> Vec<(usize, usize, Tensor)> {
    let mut params: Vec<(usize, usize, Tensor, ParamOpt)> = layout
        .trainable()
        .map(|s| {
            let theta = Tensor::new(
                &s.shape,
                blob0[s.offset..s.offset + s.size].to_vec(),
            )
            .unwrap();
            (s.offset, s.size, theta, ParamOpt::new(kind, &s.shape))
        })
        .collect();
    for (step, g) in grads.iter().enumerate() {
        for (off, size, theta, opt) in params.iter_mut() {
            let gt =
                Tensor::new(theta.shape(), g[*off..*off + *size].to_vec())
                    .unwrap();
            opt.step(theta, &gt, (step + 1) as u64, lr, wd);
        }
    }
    params.into_iter().map(|(off, size, theta, _)| (off, size, theta)).collect()
}

#[test]
fn prop_flat_engine_matches_param_opt() {
    // The flat-blob engine must agree with the per-tensor path within 1e-6
    // for every optimizer, both shard plans, and 1/2/4 shards.
    let (lr, wd) = (0.01f32, 0.01f32);
    for kind in ALL_OPTS {
        for seed in 0..6u64 {
            let mut rng = Pcg32::seeded(7000 + seed);
            let d = 3 + rng.below(6);
            let v = 4 + rng.below(8);
            let f = 3 + rng.below(5);
            let shapes: Vec<(&str, Vec<usize>)> = vec![
                ("embed", vec![v, d]),
                ("l0.attn_norm", vec![d]),
                ("l0.wq", vec![d, d]),
                ("l0.w_down", vec![f, d]),
                ("l1.wq", vec![d, d]),
                ("final_norm", vec![d]),
                ("head", vec![d, v]),
            ];
            let specs: Vec<(&str, &[usize])> =
                shapes.iter().map(|(n, s)| (*n, s.as_slice())).collect();
            let layout = synthetic_layout(kind, &specs);
            let mut blob0 = vec![0f32; layout.blob_len];
            for x in blob0[..layout.params_len].iter_mut() {
                *x = rng.normal() * 0.2;
            }
            let grads: Vec<Vec<f32>> = (0..3)
                .map(|_| {
                    (0..layout.params_len)
                        .map(|_| rng.normal() * 0.05)
                        .collect()
                })
                .collect();
            let reference =
                param_opt_reference(&layout, kind, &blob0, &grads, lr, wd);
            for shards in [1usize, 2, 4] {
                for mode in [ShardMode::Segments, ShardMode::Contiguous] {
                    let mut blob = blob0.clone();
                    let mut engine =
                        FlatOptimizer::new(kind, &layout, shards, mode)
                            .unwrap();
                    for (step, g) in grads.iter().enumerate() {
                        engine
                            .step(&mut blob, g, (step + 1) as u64, lr, wd)
                            .unwrap();
                    }
                    for (off, size, theta) in &reference {
                        for (i, (&a, &b)) in theta
                            .data()
                            .iter()
                            .zip(&blob[*off..*off + *size])
                            .enumerate()
                        {
                            assert!(
                                (a - b).abs() <= 1e-6,
                                "{kind:?} {mode:?} shards={shards} \
                                 seed={seed} elem {off}+{i}: {a} vs {b}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_flat_contiguous_shard_count_stays_close() {
    // Different shard counts only re-associate the reductions; parameters
    // must stay within fp noise of each other after several steps.
    for kind in [OptKind::AdaLomo, OptKind::Adafactor] {
        let mut rng = Pcg32::seeded(42);
        let shapes: Vec<(&str, Vec<usize>)> =
            vec![("embed", vec![12, 7]), ("l0.wq", vec![7, 7]), ("final_norm", vec![7])];
        let specs: Vec<(&str, &[usize])> =
            shapes.iter().map(|(n, s)| (*n, s.as_slice())).collect();
        let layout = synthetic_layout(kind, &specs);
        let mut blob0 = vec![0f32; layout.blob_len];
        for x in blob0[..layout.params_len].iter_mut() {
            *x = rng.normal() * 0.2;
        }
        let grads: Vec<Vec<f32>> = (0..5)
            .map(|_| {
                (0..layout.params_len).map(|_| rng.normal() * 0.05).collect()
            })
            .collect();
        let run = |shards: usize| {
            let mut blob = blob0.clone();
            let mut engine =
                FlatOptimizer::new(kind, &layout, shards, ShardMode::Contiguous)
                    .unwrap();
            for (step, g) in grads.iter().enumerate() {
                engine
                    .step(&mut blob, g, (step + 1) as u64, 0.02, 0.0)
                    .unwrap();
            }
            blob
        };
        let one = run(1);
        for shards in [2usize, 3, 4] {
            let multi = run(shards);
            for (i, (a, b)) in one.iter().zip(&multi).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6,
                    "{kind:?} shards={shards} elem {i}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn prop_pipelined_matches_sequential_bitwise() {
    // The async rank pipeline (bucketed gradient exchange overlapped with
    // per-task engine steps) must be BITWISE identical to the lockstep
    // flat-engine path under the fixed reduction order — swept over
    // ranks × bucket sizes × shard plans × optimizers.
    for kind in [
        OptKind::AdaLomo,
        OptKind::AdamW,
        OptKind::Adafactor,
        OptKind::SgdMomentum,
    ] {
        for seed in 0..3u64 {
            let mut rng = Pcg32::seeded(9000 + seed);
            let d = 3 + rng.below(6);
            let v = 4 + rng.below(8);
            let f = 3 + rng.below(5);
            let shapes: Vec<(&str, Vec<usize>)> = vec![
                ("embed", vec![v, d]),
                ("l0.attn_norm", vec![d]),
                ("l0.wq", vec![d, d]),
                ("l0.w_down", vec![f, d]),
                ("l1.wq", vec![d, d]),
                ("final_norm", vec![d]),
                ("head", vec![d, v]),
            ];
            let specs: Vec<(&str, &[usize])> =
                shapes.iter().map(|(n, s)| (*n, s.as_slice())).collect();
            let layout = synthetic_layout(kind, &specs);
            let mut blob0 = vec![0f32; layout.blob_len];
            for x in blob0[..layout.params_len].iter_mut() {
                *x = rng.normal() * 0.2;
            }
            for n_ranks in [1usize, 2, 3] {
                let buckets = [
                    1 + rng.below(layout.params_len),
                    7,
                    layout.params_len + 5, // single bucket covers all
                ];
                for bucket_elems in buckets {
                    for (mode, n_shards) in [
                        (ShardMode::Segments, 2usize),
                        (ShardMode::Contiguous, 1),
                        (ShardMode::Contiguous, 3),
                    ] {
                        let mut cfg =
                            pipeline::PipelineConfig::new(3, bucket_elems);
                        cfg.n_shards = n_shards;
                        let srcs = || {
                            pipeline::synthetic_sources(
                                n_ranks,
                                77 + seed,
                                0.05,
                            )
                        };
                        let (a, _) = pipeline::run_pipelined(
                            &layout, kind, mode, &blob0, srcs(), &cfg,
                        )
                        .unwrap();
                        let (b, _) = pipeline::run_sequential(
                            &layout, kind, mode, &blob0, srcs(), &cfg,
                        )
                        .unwrap();
                        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                            assert!(
                                x.to_bits() == y.to_bits(),
                                "{kind:?} {mode:?} ranks={n_ranks} \
                                 bucket={bucket_elems} shards={n_shards} \
                                 seed={seed} elem {i}: {x} vs {y}"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_fused_host_matches_monolith_and_lockstep_bitwise() {
    // Fused-host group-by-group stepping must agree BITWISE with (a) the
    // monolithic whole-image FlatOptimizer step (via the lockstep
    // `run_sequential` reference) and (b) the full-image async pipeline,
    // when all three consume identical gradient values — swept over
    // ranks × bucket sizes × both shard plans. The fused pipeline also
    // has to come in UNDER the full gradient image on the producing side:
    // that is the whole point of group-granular production.
    for kind in [OptKind::AdaLomo, OptKind::AdamW] {
        for seed in 0..3u64 {
            let mut rng = Pcg32::seeded(11_000 + seed);
            let d = 3 + rng.below(6);
            let v = 4 + rng.below(8);
            let f = 3 + rng.below(5);
            let shapes: Vec<(&str, Vec<usize>)> = vec![
                ("embed", vec![v, d]),
                ("l0.attn_norm", vec![d]),
                ("l0.wq", vec![d, d]),
                ("l0.w_down", vec![f, d]),
                ("l1.wq", vec![d, d]),
                ("final_norm", vec![d]),
                ("head", vec![d, v]),
            ];
            let specs: Vec<(&str, &[usize])> =
                shapes.iter().map(|(n, s)| (*n, s.as_slice())).collect();
            let layout = synthetic_layout(kind, &specs);
            let mut blob0 = vec![0f32; layout.blob_len];
            for x in blob0[..layout.params_len].iter_mut() {
                *x = rng.normal() * 0.2;
            }
            let probe =
                FlatOptimizer::new(kind, &layout, 1, ShardMode::Segments)
                    .unwrap();
            let extents = probe.group_extents();
            let max_group_bytes = 4 * extents
                .iter()
                .map(|&(lo, hi)| hi - lo)
                .max()
                .unwrap();
            for n_ranks in [1usize, 2, 3] {
                let buckets = [
                    1 + rng.below(layout.params_len),
                    7,
                    layout.params_len + 5, // single bucket covers all
                ];
                for bucket_elems in buckets {
                    for (mode, n_shards) in [
                        (ShardMode::Segments, 2usize),
                        (ShardMode::Contiguous, 3),
                    ] {
                        let mut cfg =
                            pipeline::PipelineConfig::new(3, bucket_elems);
                        cfg.n_shards = n_shards;
                        let grouped: Vec<Box<dyn GroupGradSource>> = (0
                            ..n_ranks)
                            .map(|r| {
                                Box::new(FusedHostGrads::new(
                                    extents.clone(),
                                    500 + seed,
                                    r,
                                    0.05,
                                ))
                                    as Box<dyn GroupGradSource>
                            })
                            .collect();
                        let full = || -> Vec<Box<dyn GradSource>> {
                            (0..n_ranks)
                                .map(|r| {
                                    Box::new(FusedHostGrads::new(
                                        extents.clone(),
                                        500 + seed,
                                        r,
                                        0.05,
                                    ))
                                        as Box<dyn GradSource>
                                })
                                .collect()
                        };
                        let (a, ra) = pipeline::run_pipelined_fused(
                            &layout, kind, mode, &blob0, grouped, &cfg,
                        )
                        .unwrap();
                        let (b, _) = pipeline::run_pipelined(
                            &layout, kind, mode, &blob0, full(), &cfg,
                        )
                        .unwrap();
                        let (c, _) = pipeline::run_sequential(
                            &layout, kind, mode, &blob0, full(), &cfg,
                        )
                        .unwrap();
                        let ctx = format!(
                            "{kind:?} {mode:?} ranks={n_ranks} \
                             bucket={bucket_elems} shards={n_shards} \
                             seed={seed}"
                        );
                        for (i, ((x, y), z)) in
                            a.iter().zip(&b).zip(&c).enumerate()
                        {
                            assert!(
                                x.to_bits() == y.to_bits()
                                    && x.to_bits() == z.to_bits(),
                                "{ctx} elem {i}: fused {x} vs piped {y} \
                                 vs lockstep {z}"
                            );
                        }
                        // Producer-side liveness: never the full image
                        // when more than one bucket ships, never below
                        // the largest single group.
                        assert!(
                            ra.peak_live_grad_bytes >= max_group_bytes,
                            "{ctx}: {ra:?}"
                        );
                        assert!(
                            ra.peak_live_grad_bytes <= ra.full_grad_bytes,
                            "{ctx}: {ra:?}"
                        );
                    }
                }
            }
        }
    }
}

#[test]
fn prop_engine_matches_legacy_bitwise() {
    // Every legacy entry point — run_sequential, run_pipelined,
    // run_pipelined_fused, run_fused_host — must be BITWISE identical to
    // an explicitly-constructed ExecPlan driven through the unified
    // Engine, and (fed the same step-keyed gradient values) the four
    // cells must also agree with each other — swept over ranks × bucket
    // sizes × both shard plans × AdaLomo/AdamW. This is the refactor's
    // parity pin: one leader loop, four thin constructors.
    for kind in [OptKind::AdaLomo, OptKind::AdamW] {
        for seed in 0..2u64 {
            let mut rng = Pcg32::seeded(13_000 + seed);
            let d = 3 + rng.below(6);
            let v = 4 + rng.below(8);
            let f = 3 + rng.below(5);
            let shapes: Vec<(&str, Vec<usize>)> = vec![
                ("embed", vec![v, d]),
                ("l0.attn_norm", vec![d]),
                ("l0.wq", vec![d, d]),
                ("l0.w_down", vec![f, d]),
                ("l1.wq", vec![d, d]),
                ("final_norm", vec![d]),
                ("head", vec![d, v]),
            ];
            let specs: Vec<(&str, &[usize])> =
                shapes.iter().map(|(n, s)| (*n, s.as_slice())).collect();
            let layout = synthetic_layout(kind, &specs);
            let mut blob0 = vec![0f32; layout.blob_len];
            for x in blob0[..layout.params_len].iter_mut() {
                *x = rng.normal() * 0.2;
            }
            let probe =
                FlatOptimizer::new(kind, &layout, 1, ShardMode::Segments)
                    .unwrap();
            let extents = probe.group_extents();
            let grouped = |n_ranks: usize| {
                FusedHostGrads::per_rank_extents(
                    extents.clone(),
                    n_ranks,
                    900 + seed,
                    0.05,
                )
            };
            let full = |n_ranks: usize| -> Vec<Box<dyn GradSource>> {
                (0..n_ranks)
                    .map(|r| {
                        Box::new(FusedHostGrads::new(
                            extents.clone(),
                            900 + seed,
                            r,
                            0.05,
                        )) as Box<dyn GradSource>
                    })
                    .collect()
            };
            for n_ranks in [1usize, 2, 3] {
                let buckets =
                    [1 + rng.below(layout.params_len), layout.params_len + 5];
                for bucket_elems in buckets {
                    for (mode, n_shards, dtype, wire) in [
                        (ShardMode::Segments, 2usize, Dtype::F32, None),
                        (ShardMode::Contiguous, 3, Dtype::F32, None),
                        // The dtype axis: at FIXED bf16 storage every cell
                        // must still agree bitwise — per-task widen→
                        // kernel→round is partition-independent.
                        (ShardMode::Segments, 2, Dtype::Bf16, None),
                        (ShardMode::Contiguous, 3, Dtype::Bf16, None),
                        // The wire axis: a bf16 wire on f32 storage
                        // decouples the two. The rung is element-wise
                        // (tiling-independent), so at a FIXED wire every
                        // cell must still agree bitwise.
                        (
                            ShardMode::Segments,
                            2,
                            Dtype::F32,
                            Some(WireCodec::Bf16),
                        ),
                        (
                            ShardMode::Contiguous,
                            3,
                            Dtype::F32,
                            Some(WireCodec::Bf16),
                        ),
                    ] {
                        let mut cfg =
                            pipeline::PipelineConfig::new(3, bucket_elems);
                        cfg.n_shards = n_shards;
                        cfg.dtype = dtype;
                        cfg.wire = wire;
                        let ctx = format!(
                            "{kind:?} {mode:?} ranks={n_ranks} \
                             bucket={bucket_elems} shards={n_shards} \
                             {dtype:?} wire={wire:?} seed={seed}"
                        );
                        // Wrapper results for the four legacy paths.
                        let (w_seq, _) = pipeline::run_sequential(
                            &layout,
                            kind,
                            mode,
                            &blob0,
                            full(n_ranks),
                            &cfg,
                        )
                        .unwrap();
                        let (w_pipe, _) = pipeline::run_pipelined(
                            &layout,
                            kind,
                            mode,
                            &blob0,
                            full(n_ranks),
                            &cfg,
                        )
                        .unwrap();
                        let (w_fpipe, _) = pipeline::run_pipelined_fused(
                            &layout,
                            kind,
                            mode,
                            &blob0,
                            grouped(n_ranks),
                            &cfg,
                        )
                        .unwrap();
                        let (w_mirror, _) = fused_host::run_fused_host(
                            &layout,
                            kind,
                            mode,
                            &blob0,
                            grouped(n_ranks),
                            &cfg,
                        )
                        .unwrap();
                        // The same four cells, constructed as explicit
                        // ExecPlans on the Engine.
                        let run_plan = |plan: ExecPlan,
                                        sources: RankSources|
                         -> Vec<f32> {
                            let mut eng =
                                Engine::new(&layout, &blob0, plan).unwrap();
                            eng.run(sources).unwrap();
                            eng.into_blob()
                        };
                        let e_seq = run_plan(
                            ExecPlan::sequential(kind, mode, n_ranks, &cfg),
                            RankSources::Full(full(n_ranks)),
                        );
                        let e_pipe = run_plan(
                            ExecPlan::pipelined(kind, mode, n_ranks, &cfg),
                            RankSources::Full(full(n_ranks)),
                        );
                        let e_fpipe = run_plan(
                            ExecPlan::pipelined_fused(
                                kind, mode, n_ranks, &cfg,
                            ),
                            RankSources::Grouped(grouped(n_ranks)),
                        );
                        let e_mirror = run_plan(
                            ExecPlan::fused_host(kind, mode, n_ranks, &cfg),
                            RankSources::Grouped(grouped(n_ranks)),
                        );
                        let pairs: [(&str, &[f32], &[f32]); 7] = [
                            ("seq vs engine", w_seq.as_slice(), e_seq.as_slice()),
                            ("pipe vs engine", w_pipe.as_slice(), e_pipe.as_slice()),
                            ("fpipe vs engine", w_fpipe.as_slice(), e_fpipe.as_slice()),
                            ("mirror vs engine", w_mirror.as_slice(), e_mirror.as_slice()),
                            ("pipe vs seq", w_pipe.as_slice(), w_seq.as_slice()),
                            ("fpipe vs seq", w_fpipe.as_slice(), w_seq.as_slice()),
                            ("mirror vs seq", w_mirror.as_slice(), w_seq.as_slice()),
                        ];
                        for (label, a, b) in pairs {
                            for (i, (x, y)) in
                                a.iter().zip(b.iter()).enumerate()
                            {
                                assert!(
                                    x.to_bits() == y.to_bits(),
                                    "{ctx} [{label}] elem {i}: {x} vs {y}"
                                );
                            }
                        }
                        // The f32 wire rung is the identity: requesting
                        // it EXPLICITLY must reproduce this cell's
                        // default (pre-ladder) exchange bit for bit.
                        if wire.is_none() && dtype == Dtype::F32 {
                            let mut cfg_w = pipeline::PipelineConfig::new(
                                3,
                                bucket_elems,
                            );
                            cfg_w.n_shards = n_shards;
                            cfg_w.dtype = dtype;
                            cfg_w.wire = Some(WireCodec::F32);
                            let e_explicit = run_plan(
                                ExecPlan::pipelined(
                                    kind, mode, n_ranks, &cfg_w,
                                ),
                                RankSources::Full(full(n_ranks)),
                            );
                            for (i, (x, y)) in e_pipe
                                .iter()
                                .zip(e_explicit.iter())
                                .enumerate()
                            {
                                assert!(
                                    x.to_bits() == y.to_bits(),
                                    "{ctx} [explicit f32 wire] elem {i}: \
                                     {x} vs {y}"
                                );
                            }
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn prop_no_sqrt_variant_also_bounded() {
    // The literal Algorithm-1 form stays within the grouped-norm bound too.
    let hyper = Hyper { no_sqrt: true, ..Hyper::default() };
    for seed in 0..CASES {
        let mut rng = Pcg32::seeded(6000 + seed);
        let mut theta = rand_tensor(&mut rng, &[6, 6], 0.2);
        let before = theta.clone();
        let g = rand_tensor(&mut rng, &[6, 6], 0.05);
        let mut opt = ParamOpt::with_hyper(OptKind::AdaLomo, &[6, 6], hyper);
        opt.step(&mut theta, &g, 1, 0.01, 0.0);
        let delta = theta.sub(&before);
        let bound = 0.01 * 1e-3f32.max(before.rms());
        assert!(delta.rms() <= bound * 1.01, "seed {seed}");
    }
}
