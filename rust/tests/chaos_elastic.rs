//! Chaos lane: ranks are killed and revived at random step boundaries
//! (a `Pcg32`-seeded membership schedule) and the elastic engine run must
//! stay **byte-identical** to the equivalent sequence of fixed-membership
//! runs spliced together through checkpoint files at the same boundaries.
//!
//! The reference side is deliberately built the slow, boring way — one
//! engine per epoch, `suspend_at` the boundary, rewrite the checkpoint
//! with the next epoch's rank count (exactly the re-plan `--ranks-schedule`
//! spells), `Engine::resume` — so the invariant being pinned is "elastic
//! execution is pure sugar over deterministic re-sharding, not a new
//! numeric path".
//!
//! The offline crate registry has no `proptest`, so the sweep is a
//! hand-rolled seed matrix (the same style as `proptest_invariants.rs`)
//! with a greedy schedule shrinker. On a red case the failing seed plus
//! the minimized schedule are written to `target/chaos/failure.txt`
//! before panicking — the CI `chaos` job uploads that directory as an
//! artifact.

use std::path::PathBuf;

use adalomo::coordinator::collective::WireCodec;
use adalomo::coordinator::engine::{Engine, ExecPlan};
use adalomo::coordinator::fused_host;
use adalomo::coordinator::pipeline::PipelineConfig;
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, ShardMode,
};
use adalomo::optim::OptKind;
use adalomo::runtime::{checkpoint, Layout};
use adalomo::util::rng::Pcg32;

/// Steps per run: small enough to keep the matrix fast, large enough
/// that every boundary position 1..=5 is exercisable.
const STEPS: usize = 6;
const SCALE: f32 = 0.05;
/// Fixed seed matrix — the CI lane must be reproducible, so chaos here
/// means "adversarial but pinned", not wall-clock entropy.
const SEEDS: [u64; 6] = [1, 2, 3, 4, 5, 6];

fn model_layout(kind: OptKind) -> Layout {
    let params: Vec<(&str, &[usize])> = vec![
        ("embed", &[16, 8][..]),
        ("l0.attn_norm", &[8][..]),
        ("l0.wq", &[8, 8][..]),
        ("l1.wq", &[8, 8][..]),
        ("final_norm", &[8][..]),
        ("head", &[8, 16][..]),
    ];
    synthetic_layout(kind, &params)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("adalomo_chaos_{}_{name}.bin", std::process::id()))
}

/// Build the plan for one case. Even seeds take the grouped-backward
/// producer, odd seeds the fused one, so both production axes face
/// membership churn.
fn plan_for(
    seed: u64,
    mode: ShardMode,
    wire: WireCodec,
    schedule: &[(u64, u32)],
) -> (Layout, Vec<f32>, ExecPlan) {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 17 + seed);
    let mut cfg = PipelineConfig::new(STEPS, layout.params_len.div_ceil(5));
    cfg.n_shards = 2;
    cfg.wire = Some(wire);
    let mut plan = if seed % 2 == 0 {
        ExecPlan::pipelined(kind, mode, 2, &cfg)
    } else {
        ExecPlan::pipelined_fused(kind, mode, 2, &cfg)
    };
    plan.seed = 1000 + seed;
    plan.ranks_schedule = schedule.to_vec();
    (layout, blob0, plan)
}

/// Each inner boundary is killed-or-revived with probability 1/2; the
/// surviving fleet size is 1..=4 ranks. Drawn from the case seed only.
fn random_schedule(rng: &mut Pcg32) -> Vec<(u64, u32)> {
    let mut out = Vec::new();
    for s in 1..STEPS as u64 {
        if rng.below(2) == 0 {
            out.push((s, 1 + rng.below(4) as u32));
        }
    }
    out
}

/// Straight-through elastic run: one engine, the full schedule, final
/// blob bits out.
fn run_elastic(
    seed: u64,
    mode: ShardMode,
    wire: WireCodec,
    schedule: &[(u64, u32)],
) -> Vec<f32> {
    let (layout, blob0, plan) = plan_for(seed, mode, wire, schedule);
    let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
    let extents = eng.group_extents();
    let report = eng
        .run_elastic(|seg| fused_host::plan_sources(seg, extents.clone(), SCALE))
        .unwrap();
    assert_eq!(report.steps as usize, STEPS);
    assert!(eng.is_finished());
    eng.blob()
}

/// Reference: chained fixed-membership engines. At every boundary the
/// checkpoint is rewritten with the next epoch's rank count and a
/// flushed error-feedback bank (the exact splice `run_elastic` performs
/// in memory), then resumed as if a fresh fleet picked it up.
fn run_reference(
    seed: u64,
    mode: ShardMode,
    wire: WireCodec,
    schedule: &[(u64, u32)],
) -> Vec<f32> {
    let (layout, blob0, plan) = plan_for(seed, mode, wire, &[]);
    let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
    for (i, &(s, r)) in schedule.iter().enumerate() {
        eng.suspend_at(s);
        let srcs =
            fused_host::plan_sources(eng.plan(), eng.group_extents(), SCALE);
        eng.run(srcs).unwrap();
        assert_eq!(eng.step(), s);
        let path = tmp(&format!("ref_{seed}_{i}"));
        eng.save(&path).unwrap();
        let ck = checkpoint::load(&path).unwrap();
        let mut rec = ck.plan.clone();
        rec.n_ranks = r;
        let ef: Vec<Vec<f32>> = if wire.uses_error_feedback() {
            vec![vec![0.0f32; ck.layout.params_len]; r as usize]
        } else {
            Vec::new()
        };
        checkpoint::write(
            &path,
            &ck.layout_key,
            &ck.layout,
            ck.step,
            &rec,
            &ef,
            &ck.blob,
        )
        .unwrap();
        eng = Engine::resume(&path).unwrap();
        std::fs::remove_file(&path).ok();
    }
    let srcs = fused_host::plan_sources(eng.plan(), eng.group_extents(), SCALE);
    eng.run(srcs).unwrap();
    assert!(eng.is_finished());
    eng.blob()
}

fn case_matches(
    seed: u64,
    mode: ShardMode,
    wire: WireCodec,
    schedule: &[(u64, u32)],
) -> bool {
    let a = run_elastic(seed, mode, wire, schedule);
    let b = run_reference(seed, mode, wire, schedule);
    a.len() == b.len()
        && a.iter().zip(&b).all(|(x, y)| x.to_bits() == y.to_bits())
}

/// Greedy delta-debugging on the schedule: drop any entry whose removal
/// keeps the case red, so the artifact names the smallest reproducer.
fn shrink(
    seed: u64,
    mode: ShardMode,
    wire: WireCodec,
    mut schedule: Vec<(u64, u32)>,
) -> Vec<(u64, u32)> {
    let mut i = 0;
    while i < schedule.len() {
        let mut cand = schedule.clone();
        cand.remove(i);
        if !case_matches(seed, mode, wire, &cand) {
            schedule = cand;
        } else {
            i += 1;
        }
    }
    schedule
}

/// The chaos gate itself: every (seed, shard plan, wire rung) cell draws
/// its kill/revive schedule and must match the fixed-membership splice
/// bitwise. Covers both shard plans and the f32 + q8 wire rungs as the
/// acceptance criteria demand.
#[test]
fn chaos_kill_revive_matches_fixed_membership_bitwise() {
    for mode in [ShardMode::Segments, ShardMode::Contiguous] {
        for wire in [WireCodec::F32, WireCodec::Q8Block] {
            for seed in SEEDS {
                let mut rng = Pcg32::seeded(0xC4A0_5000 + seed);
                let schedule = random_schedule(&mut rng);
                if case_matches(seed, mode, wire, &schedule) {
                    continue;
                }
                let minimized =
                    shrink(seed, mode, wire, schedule.clone());
                let report = format!(
                    "seed {seed} mode {mode:?} wire {} \
                     schedule {schedule:?} minimized {minimized:?}\n",
                    wire.name(),
                );
                std::fs::create_dir_all("target/chaos").ok();
                std::fs::write("target/chaos/failure.txt", &report).ok();
                panic!(
                    "elastic run diverged from fixed-membership splice \
                     (reproducer in target/chaos/failure.txt): {report}"
                );
            }
        }
    }
}

/// An elastic run suspended mid-flight checkpoints its remaining
/// schedule (ADCP v4 epoch records) and resumes to the same final bits
/// as the uninterrupted elastic run — fault tolerance on top of
/// elasticity.
#[test]
fn elastic_run_suspends_and_resumes_bit_exactly() {
    let mode = ShardMode::Segments;
    let wire = WireCodec::Q8Block;
    let schedule = [(2u64, 3u32), (4, 1)];

    let full = run_elastic(9, mode, wire, &schedule);

    let (layout, blob0, plan) = plan_for(9, mode, wire, &schedule);
    let mut part = Engine::new(&layout, &blob0, plan).unwrap();
    part.suspend_at(3);
    let extents = part.group_extents();
    let r = part
        .run_elastic(|seg| fused_host::plan_sources(seg, extents.clone(), SCALE))
        .unwrap();
    assert_eq!(r.steps, 3);
    assert!(!part.is_finished());
    let mid = tmp("elastic_mid");
    part.save(&mid).unwrap();

    // The epoch section must round-trip through the file.
    let ck = checkpoint::load(&mid).unwrap();
    assert_eq!(ck.plan.epochs, schedule.to_vec());
    assert_eq!(ck.plan.ranks_at(3), 3, "step 3 runs inside epoch 1");
    assert_eq!(ck.plan.current_ranks(ck.step), 3);

    let mut resumed = Engine::resume(&mid).unwrap();
    assert_eq!(resumed.step(), 3);
    let extents = resumed.group_extents();
    resumed
        .run_elastic(|seg| fused_host::plan_sources(seg, extents.clone(), SCALE))
        .unwrap();
    assert!(resumed.is_finished());
    let b = resumed.blob();
    for (i, (x, y)) in full.iter().zip(&b).enumerate() {
        assert!(x.to_bits() == y.to_bits(), "elem {i}: {x} vs {y}");
    }
    std::fs::remove_file(mid).ok();
}
