//! Unified-engine integration: checkpoint/suspend/resume must reproduce
//! an uninterrupted run bitwise — final blob, checkpoint bytes, and the
//! fixed-validation-set eval loss — for every `ExecPlan` cell the four
//! legacy entry points map to.

use std::path::PathBuf;

use adalomo::coordinator::engine::{Engine, ExecPlan, RankSources};
use adalomo::coordinator::fused_host;
use adalomo::coordinator::pipeline::{self, PipelineConfig};
use adalomo::data::{DataLoader, Domain};
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, ShardMode,
};
use adalomo::optim::OptKind;
use adalomo::runtime::{checkpoint, Layout};

fn model_layout(kind: OptKind) -> Layout {
    let params: Vec<(&str, &[usize])> = vec![
        ("embed", &[32, 16][..]),
        ("l0.attn_norm", &[16][..]),
        ("l0.wq", &[16, 16][..]),
        ("l0.w_down", &[24, 16][..]),
        ("l1.wq", &[16, 16][..]),
        ("final_norm", &[16][..]),
        ("head", &[16, 32][..]),
    ];
    synthetic_layout(kind, &params)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("adalomo_it_{}_{name}.bin", std::process::id()))
}

/// Sources seeded like the engine plan's — the canonical
/// `fused_host::plan_sources` reconstruction the CLI uses, so this test
/// pins the exact stream a `--resume` rebuilds.
fn sources_for(eng: &Engine) -> RankSources {
    fused_host::plan_sources(eng.plan(), eng.group_extents(), 0.05)
}

/// Suspend at step k, checkpoint, resume "in a new process", finish: the
/// final blob, the final checkpoint bytes and the fixed-val-set eval loss
/// must all equal the uninterrupted run's — for all four plan cells.
#[test]
fn suspend_resume_reproduces_uninterrupted_run() {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 61);
    let mut cfg = PipelineConfig::new(6, layout.params_len.div_ceil(7));
    cfg.n_shards = 2;
    let mode = ShardMode::Contiguous;
    let plans: Vec<(&str, ExecPlan)> = vec![
        ("sequential", ExecPlan::sequential(kind, mode, 2, &cfg)),
        ("pipelined", ExecPlan::pipelined(kind, mode, 2, &cfg)),
        (
            "pipelined-fused",
            ExecPlan::pipelined_fused(kind, mode, 2, &cfg),
        ),
        ("fused-host", ExecPlan::fused_host(kind, mode, 2, &cfg)),
    ];
    for (name, plan) in plans {
        let mut plan = plan;
        plan.seed = 17;

        // Uninterrupted reference.
        let mut full = Engine::new(&layout, &blob0, plan.clone()).unwrap();
        let srcs = sources_for(&full);
        let r_full = full.run(srcs).unwrap();
        assert_eq!(r_full.steps, 6, "{name}");
        assert!(full.is_finished(), "{name}");

        // Interrupted at step 3 + resumed from the file.
        let mid = tmp(&format!("{name}_mid"));
        let mut part = Engine::new(&layout, &blob0, plan.clone()).unwrap();
        part.suspend_at(3);
        let srcs = sources_for(&part);
        let r_part = part.run(srcs).unwrap();
        assert_eq!(r_part.steps, 3, "{name}");
        assert!(!part.is_finished(), "{name}");
        part.save(&mid).unwrap();
        drop(part);

        let mut resumed = Engine::resume(&mid).unwrap();
        assert_eq!(resumed.step(), 3, "{name}");
        assert_eq!(resumed.layout(), &layout, "{name}");
        let srcs = sources_for(&resumed);
        let r_rest = resumed.run(srcs).unwrap();
        assert_eq!(r_rest.steps, 3, "{name}");
        assert!(resumed.is_finished(), "{name}");

        // Bitwise-equal final blobs...
        let blob_full = full.blob();
        let blob_res = resumed.blob();
        for (i, (a, b)) in blob_full.iter().zip(blob_res.iter()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{name} elem {i}: {a} vs {b}"
            );
        }
        // ...bitwise-equal fixed-val-set eval losses...
        let params_len = layout.params_len;
        let mut val = DataLoader::lm(Domain::C4, 999, 2, 16, 4_000);
        let la =
            pipeline::host_eval_loss(&blob_full[..params_len], &mut val, 4);
        let lb =
            pipeline::host_eval_loss(&blob_res[..params_len], &mut val, 4);
        assert!(la > 0.0, "{name}");
        assert_eq!(la.to_bits(), lb.to_bits(), "{name}: {la} vs {lb}");
        // ...and byte-equal final checkpoint files (what `make
        // ckpt-smoke` asserts end to end with `cmp`).
        let p_full = tmp(&format!("{name}_full"));
        let p_rest = tmp(&format!("{name}_rest"));
        full.save(&p_full).unwrap();
        resumed.save(&p_rest).unwrap();
        assert_eq!(
            std::fs::read(&p_full).unwrap(),
            std::fs::read(&p_rest).unwrap(),
            "{name}"
        );
        for p in [mid, p_full, p_rest] {
            std::fs::remove_file(p).ok();
        }
    }
}

/// The checkpoint file itself: everything the engine wrote comes back
/// verbatim — layout (via the new `Layout: PartialEq`), plan axes, step
/// counter, blob bits — and the recorded plan re-validates.
#[test]
fn checkpoint_file_preserves_engine_state_exactly() {
    let kind = OptKind::AdamW;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 71);
    let mut cfg = PipelineConfig::new(4, layout.params_len.div_ceil(3));
    cfg.n_shards = 3;
    cfg.wd = 0.01;
    let mut plan = ExecPlan::pipelined(kind, ShardMode::Segments, 3, &cfg);
    plan.seed = 23;
    let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
    eng.set_layout_key("it/adamw");
    eng.suspend_at(2);
    let srcs = sources_for(&eng);
    eng.run(srcs).unwrap();
    let path = tmp("roundtrip");
    eng.save(&path).unwrap();

    let ck = checkpoint::load(&path).unwrap();
    assert_eq!(ck.layout_key, "it/adamw");
    assert_eq!(ck.layout, layout);
    assert_eq!(ck.step, 2);
    assert_eq!(ck.plan.opt, "adamw");
    assert_eq!(ck.plan.n_ranks, 3);
    assert_eq!(ck.plan.steps, 4);
    assert_eq!(ck.plan.wd.to_bits(), 0.01f32.to_bits());
    assert_eq!(ck.plan.seed, 23);
    assert_eq!(ck.plan.cursor_group, 0);
    assert_eq!(ck.plan.cursor_task, 0);
    assert_eq!(ck.blob.len(), layout.blob_len);
    let eng_blob = eng.blob();
    let ck_blob = ck.blob.to_f32();
    for (a, b) in eng_blob.iter().zip(&ck_blob) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    let back = ExecPlan::from_record(&ck.plan).unwrap();
    assert_eq!(back.kind, kind);
    assert_eq!(back.mode, ShardMode::Segments);
    std::fs::remove_file(path).ok();
}

/// A resumed engine whose plan says "already finished" runs zero further
/// steps and leaves the blob untouched — restart-loop safety.
#[test]
fn resuming_a_finished_run_is_a_noop() {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 81);
    let cfg = PipelineConfig::new(2, layout.params_len);
    let mut plan =
        ExecPlan::fused_host(kind, ShardMode::Contiguous, 1, &cfg);
    plan.seed = 5;
    let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
    let srcs = sources_for(&eng);
    eng.run(srcs).unwrap();
    assert!(eng.is_finished());
    let path = tmp("finished");
    eng.save(&path).unwrap();

    let mut again = Engine::resume(&path).unwrap();
    assert!(again.is_finished());
    let srcs = sources_for(&again);
    let r = again.run(srcs).unwrap();
    assert_eq!(r.steps, 0);
    let a_blob = eng.blob();
    let b_blob = again.blob();
    for (a, b) in a_blob.iter().zip(b_blob.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    std::fs::remove_file(path).ok();
}

/// A PR-4-era (version-1, all-f32, tagless) checkpoint file still loads
/// AND resumes bit-exactly: the v1 bytes are written by hand here —
/// replicating the legacy layout exactly — then `Engine::resume` carries
/// the run to the same final state as an uninterrupted one.
#[test]
fn v1_checkpoint_resumes_bit_exactly() {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 91);
    let mut cfg = PipelineConfig::new(5, layout.params_len.div_ceil(4));
    cfg.n_shards = 2;
    let mut plan = ExecPlan::pipelined(kind, ShardMode::Segments, 2, &cfg);
    plan.seed = 33;

    // Uninterrupted reference.
    let mut full = Engine::new(&layout, &blob0, plan.clone()).unwrap();
    let srcs = sources_for(&full);
    full.run(srcs).unwrap();

    // Suspend at step 2, save (v3), then transcode the checkpoint to the
    // legacy v1 byte layout by hand.
    let mut part = Engine::new(&layout, &blob0, plan).unwrap();
    part.suspend_at(2);
    let srcs = sources_for(&part);
    part.run(srcs).unwrap();
    let p2 = tmp("v1_src");
    part.save(&p2).unwrap();
    let ck = checkpoint::load(&p2).unwrap();
    // Transcode through the shared legacy encoder (whose byte stream the
    // checkpoint unit tests pin against an independent hand-rolled copy).
    let v1 = checkpoint::to_bytes_v1(&ck).unwrap();

    let p1 = tmp("v1_file");
    std::fs::write(&p1, &v1).unwrap();
    let mut resumed = Engine::resume(&p1).unwrap();
    assert_eq!(resumed.step(), 2);
    let srcs = sources_for(&resumed);
    resumed.run(srcs).unwrap();
    assert!(resumed.is_finished());
    let a_blob = full.blob();
    let b_blob = resumed.blob();
    for (i, (a, b)) in a_blob.iter().zip(b_blob.iter()).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "elem {i}: {a} vs {b}");
    }
    for p in [p1, p2] {
        std::fs::remove_file(p).ok();
    }
}

/// A PR-5/6-era (version-2, dtype-aware, pre-wire-ladder) checkpoint
/// still loads AND resumes bit-exactly, for both storage dtypes: the v2
/// file is produced through the shared legacy encoder (pinned by hand in
/// the checkpoint unit tests), loads with the wire rung defaulted to the
/// storage dtype, and carries the run to the uninterrupted final state.
#[test]
fn v2_checkpoint_resumes_bit_exactly() {
    use adalomo::tensor::Dtype;
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 93);
    for dtype in [Dtype::F32, Dtype::Bf16] {
        let mut cfg = PipelineConfig::new(5, layout.params_len.div_ceil(4));
        cfg.n_shards = 2;
        cfg.dtype = dtype;
        let mut plan =
            ExecPlan::pipelined(kind, ShardMode::Segments, 2, &cfg);
        plan.seed = 37;

        // Uninterrupted reference.
        let mut full = Engine::new(&layout, &blob0, plan.clone()).unwrap();
        let srcs = sources_for(&full);
        full.run(srcs).unwrap();

        // Suspend at step 2, save (v3), transcode to the legacy v2 bytes.
        let mut part = Engine::new(&layout, &blob0, plan).unwrap();
        part.suspend_at(2);
        let srcs = sources_for(&part);
        part.run(srcs).unwrap();
        let p3 = tmp(&format!("v2_src_{}", dtype.name()));
        part.save(&p3).unwrap();
        let ck = checkpoint::load(&p3).unwrap();
        let v2 = checkpoint::to_bytes_v2(&ck).unwrap();
        // The transcoding dropped exactly the wire byte and the empty
        // error-feedback + membership-epoch counts — nothing else.
        assert_eq!(std::fs::read(&p3).unwrap().len(), v2.len() + 9);

        let p2 = tmp(&format!("v2_file_{}", dtype.name()));
        std::fs::write(&p2, &v2).unwrap();
        let mut resumed = Engine::resume(&p2).unwrap();
        assert_eq!(resumed.step(), 2);
        let srcs = sources_for(&resumed);
        resumed.run(srcs).unwrap();
        assert!(resumed.is_finished());
        let a_blob = full.blob();
        let b_blob = resumed.blob();
        for (i, (a, b)) in a_blob.iter().zip(b_blob.iter()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "{} elem {i}: {a} vs {b}",
                dtype.name()
            );
        }
        for p in [p2, p3] {
            std::fs::remove_file(p).ok();
        }
    }
}

/// The q8 wire's error-feedback accumulators survive a checkpoint:
/// suspend/resume of a quantized exchange matches the uninterrupted run
/// bitwise, which can only happen if the per-rank residuals resume
/// exactly (a fresh engine would re-inject zeros instead).
#[test]
fn q8_wire_suspend_resume_is_bit_exact() {
    use adalomo::coordinator::collective::WireCodec;
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 95);
    let mut cfg = PipelineConfig::new(6, layout.params_len.div_ceil(7));
    cfg.n_shards = 2;
    cfg.wire = Some(WireCodec::Q8Block);
    let mut plan = ExecPlan::pipelined(kind, ShardMode::Contiguous, 2, &cfg);
    plan.seed = 41;

    // Uninterrupted reference.
    let mut full = Engine::new(&layout, &blob0, plan.clone()).unwrap();
    let srcs = sources_for(&full);
    full.run(srcs).unwrap();
    assert!(full.is_finished());

    // Suspend mid-run: the residual accumulators are non-trivial here.
    let mid = tmp("q8_mid");
    let mut part = Engine::new(&layout, &blob0, plan).unwrap();
    part.suspend_at(3);
    let srcs = sources_for(&part);
    part.run(srcs).unwrap();
    part.save(&mid).unwrap();
    let ck = checkpoint::load(&mid).unwrap();
    assert_eq!(ck.plan.wire, checkpoint::WIRE_Q8);
    assert_eq!(ck.ef.len(), 2);
    assert!(
        ck.ef.iter().flatten().any(|&x| x != 0.0),
        "a quantized run should have banked non-zero residuals"
    );

    let mut resumed = Engine::resume(&mid).unwrap();
    assert_eq!(resumed.step(), 3);
    let srcs = sources_for(&resumed);
    resumed.run(srcs).unwrap();
    assert!(resumed.is_finished());
    let a_blob = full.blob();
    let b_blob = resumed.blob();
    for (i, (a, b)) in a_blob.iter().zip(b_blob.iter()).enumerate() {
        assert!(a.to_bits() == b.to_bits(), "elem {i}: {a} vs {b}");
    }
    // Final checkpoints (including the final residual state) byte-equal.
    let p_full = tmp("q8_full");
    let p_rest = tmp("q8_rest");
    full.save(&p_full).unwrap();
    resumed.save(&p_rest).unwrap();
    assert_eq!(
        std::fs::read(&p_full).unwrap(),
        std::fs::read(&p_rest).unwrap()
    );
    for p in [mid, p_full, p_rest] {
        std::fs::remove_file(p).ok();
    }
}
