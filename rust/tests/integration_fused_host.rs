//! Fused-backward host mirror integration: group-by-group stepping parity
//! against the monolithic flat engine for every optimizer, and the paper
//! §2.1 liveness claim — the mirror's MEASURED peak live-gradient bytes
//! must equal the analytic `memsim::liveness` prediction for the same
//! preset, and sit far below the full-gradient baseline.

use adalomo::coordinator::fused_host::{
    fused_host_step, run_fused_host, FusedHostGrads, GroupGradSource,
};
use adalomo::coordinator::pipeline::{self, GradSource, PipelineConfig};
use adalomo::memsim::{liveness, Arch};
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode,
};
use adalomo::optim::{OptKind, ALL_OPTS};
use adalomo::runtime::Layout;

fn model_layout(kind: OptKind) -> Layout {
    let params: Vec<(&str, &[usize])> = vec![
        ("embed", &[32, 16][..]),
        ("l0.attn_norm", &[16][..]),
        ("l0.wq", &[16, 16][..]),
        ("l0.w_down", &[24, 16][..]),
        ("l1.attn_norm", &[16][..]),
        ("l1.wq", &[16, 16][..]),
        ("l1.w_down", &[24, 16][..]),
        ("final_norm", &[16][..]),
        ("head", &[16, 32][..]),
    ];
    synthetic_layout(kind, &params)
}

/// Fused-host vs monolithic step parity for ALL SEVEN optimizers, both
/// shard plans: the group walk must land bit-identically to whole-image
/// steps fed the same gradient values.
#[test]
fn fused_host_parity_holds_for_all_seven_optimizers() {
    for kind in ALL_OPTS {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let layout = model_layout(kind);
            let (blob0, _) = seeded_blob_and_grads(&layout, 31);
            let probe = FlatOptimizer::new(kind, &layout, 2, mode).unwrap();
            let mut cfg = PipelineConfig::new(2, 1);
            cfg.n_shards = 2;
            cfg.lr = 5e-3;
            cfg.wd = 0.01;
            let sources = FusedHostGrads::per_rank_extents(
                probe.group_extents(),
                1,
                19,
                0.05,
            );
            let (mirror, report) =
                run_fused_host(&layout, kind, mode, &blob0, sources, &cfg)
                    .unwrap();
            let mut engine2 =
                FlatOptimizer::new(kind, &layout, 2, mode).unwrap();
            let mut src2 =
                FusedHostGrads::new(engine2.group_extents(), 19, 0, 0.05);
            let mut reference = blob0.clone();
            let mut grad = vec![0f32; layout.params_len];
            for t in 1..=2u64 {
                GradSource::fill(&mut src2, t, &mut grad);
                engine2.step(&mut reference, &grad, t, 5e-3, 0.01).unwrap();
            }
            for (i, (a, b)) in mirror.iter().zip(&reference).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{kind:?} {mode:?} elem {i}: {a} vs {b}"
                );
            }
            // Liveness held: head block, l1, l0, embed — 4 groups, peak
            // strictly under the full image.
            assert_eq!(report.n_groups, 4, "{kind:?}");
            assert!(
                report.peak_live_grad_bytes < report.full_grad_bytes,
                "{kind:?} {mode:?}: {report:?}"
            );
        }
    }
}

/// The liveness claim, measured against predicted: stepping the DEFAULT
/// preset's layout group-by-group must hold exactly the bytes
/// `memsim::liveness::simulate_grouped` predicts — curve and peak — and
/// the peak must undercut the full-gradient baseline by more than the
/// L/2 acceptance bound.
#[test]
fn measured_peak_live_bytes_match_liveness_prediction() {
    let arch = Arch::preset("tiny").unwrap();
    let params = arch.param_specs();
    let specs: Vec<(&str, &[usize])> = params
        .iter()
        .map(|(n, s)| (n.as_str(), s.as_slice()))
        .collect();
    let layout = synthetic_layout(OptKind::AdaLomo, &specs);
    let mut engine = FlatOptimizer::new(
        OptKind::AdaLomo,
        &layout,
        2,
        ShardMode::Contiguous,
    )
    .unwrap();

    // Engine-derived group sizes == analytic group sizes, element for
    // element (three independent derivations of the same schedule; the
    // manifest-derived fused.rs variant is pinned by the pjrt job).
    assert_eq!(engine.group_grad_sizes(), liveness::group_elems(&arch));

    let predicted = liveness::simulate_grouped(&arch, 4);
    let (mut blob, _) = seeded_blob_and_grads(&layout, 41);
    let mut src = FusedHostGrads::new(engine.group_extents(), 23, 0, 0.02);
    let report =
        fused_host_step(&mut engine, &mut blob, &mut src, 1, 1e-3, 0.0)
            .unwrap();

    // Measured == predicted, not merely close.
    assert_eq!(report.curve_bytes, predicted.curve);
    assert_eq!(report.peak_live_grad_bytes, predicted.peak_bytes);

    // The acceptance bound: peak live gradient < full image / (L/2).
    let bound = report.full_grad_bytes / (arch.n_layers / 2);
    assert!(
        report.peak_live_grad_bytes < bound,
        "peak {} vs bound {bound} (full {}, L {})",
        report.peak_live_grad_bytes,
        report.full_grad_bytes,
        arch.n_layers
    );
    assert!(report.live_fraction() < 2.0 / arch.n_layers as f64);
}

/// The grouped pipeline inherits the liveness win: the producing side
/// retains only the group buffers the shipped region has not yet covered,
/// not the image — while still beating the lockstep exposure like the
/// full-image pipeline does.
#[test]
fn fused_pipeline_overlaps_with_sub_image_liveness() {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 47);
    let mut cfg = PipelineConfig::new(4, layout.params_len.div_ceil(8));
    cfg.n_shards = 2;
    let probe =
        FlatOptimizer::new(kind, &layout, 1, ShardMode::Segments).unwrap();
    let sources: Vec<Box<dyn GroupGradSource>> =
        FusedHostGrads::per_rank(&probe, 2, 53, 0.05)
            .into_iter()
            .map(|s| Box::new(s) as Box<dyn GroupGradSource>)
            .collect();
    let (_, report) = pipeline::run_pipelined_fused(
        &layout,
        kind,
        ShardMode::Segments,
        &blob0,
        sources,
        &cfg,
    )
    .unwrap();
    assert_eq!(report.n_ranks, 2);
    assert_eq!(report.n_buckets, 8);
    let sum = report.comm_secs + report.compute_secs;
    assert!(
        report.exposed_secs < sum,
        "no overlap achieved: exposed {} vs compute+comm {sum}",
        report.exposed_secs
    );
    assert!(report.overlap_efficiency > 1.0);
    // Producer-side liveness: strictly below the full gradient image.
    assert!(
        report.peak_live_grad_bytes < report.full_grad_bytes,
        "{report:?}"
    );
}
