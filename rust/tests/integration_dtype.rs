//! Dtype-axis integration: bf16 storage end to end.
//!
//! * bf16 conversion properties over random sweeps (round∘widen identity,
//!   rounding error bound, monotonicity) — the kernel-level contract;
//! * an all-7-optimizer bf16-vs-f32 parity-tolerance sweep mirroring
//!   `integration_fused_host.rs` — the DOCUMENTED tolerance is
//!   `|Δθ| <= 5e-3 + 5% * |θ_f32|` per element after the 3-step runs
//!   below (storage rounds at 2^-9 relative per write; compute stays
//!   f32, so the divergence is storage-rounding accumulation only);
//! * the measured byte claims: blob bytes and checkpoint file bytes at
//!   or under 55% of the f32 baseline, modeled exchange bytes exactly
//!   halved, bounded per-task scratch (measured == analytic, far below
//!   a full-image mirror);
//! * bf16 suspend/checkpoint/resume reproducing an uninterrupted bf16
//!   run bit-for-bit (raw u16 prefixes included);
//! * the wire-ladder twin of the optimizer sweep: bf16/q8 exchange rungs
//!   on f32 storage tracking the f32-wire run within DOCUMENTED
//!   tolerances (see `WIRE_*_TOL_*` below and docs/EXCHANGE.md).

use std::path::PathBuf;

use adalomo::coordinator::collective::WireCodec;
use adalomo::coordinator::engine::{Engine, ExecPlan, RankSources};
use adalomo::coordinator::fused_host;
use adalomo::coordinator::pipeline::PipelineConfig;
use adalomo::optim::flat::{
    seeded_blob_and_grads, synthetic_layout, FlatOptimizer, ShardMode,
};
use adalomo::optim::{OptKind, ALL_OPTS};
use adalomo::runtime::{Layout, TypedBlob};
use adalomo::tensor::{bf16_to_f32, f32_to_bf16, snap_bf16, Dtype};
use adalomo::util::rng::Pcg32;

/// Documented bf16-vs-f32 parity tolerance (see module docs).
const BF16_TOL_ABS: f32 = 5e-3;
const BF16_TOL_REL: f32 = 0.05;

/// Documented wire-rung tolerances against the f32-wire reference at
/// fixed f32 storage (see docs/EXCHANGE.md for the derivation):
///
/// * bf16 wire rounds each shipped gradient element at 2^-9 relative —
///   the same error model as bf16 storage, so it inherits the bf16
///   tolerance above;
/// * q8 wire quantizes each 64-element block at ~max|g|/254 absolute,
///   so near-zero elements in a live block can see their whole update
///   direction perturbed for the adaptive-ratio optimizers. Error
///   feedback re-injects the residual next exchange, bounding the drift
///   by roughly 2·steps·lr (= 3e-2 at lr 5e-3, 3 steps) in that
///   worst case; the pin below adds headroom on top.
const WIRE_Q8_TOL_ABS: f32 = 4e-2;
const WIRE_Q8_TOL_REL: f32 = 0.10;

fn model_layout(kind: OptKind) -> Layout {
    let params: Vec<(&str, &[usize])> = vec![
        ("embed", &[32, 16][..]),
        ("l0.attn_norm", &[16][..]),
        ("l0.wq", &[16, 16][..]),
        ("l0.w_down", &[24, 16][..]),
        ("l1.attn_norm", &[16][..]),
        ("l1.wq", &[16, 16][..]),
        ("l1.w_down", &[24, 16][..]),
        ("final_norm", &[16][..]),
        ("head", &[16, 32][..]),
    ];
    synthetic_layout(kind, &params)
}

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir()
        .join(format!("adalomo_dt_{}_{name}.bin", std::process::id()))
}

/// Random-sweep bf16 conversion properties (the unit tests in `tensor`
/// pin hand values; this sweeps wide magnitude ranges).
#[test]
fn bf16_conversion_properties_hold_over_random_sweeps() {
    let mut rng = Pcg32::seeded(2024);
    for case in 0..4000 {
        let mag = 10f32.powf(rng.f32() * 12.0 - 6.0);
        let x = rng.normal() * mag;
        let s = snap_bf16(x);
        // round∘widen is the identity on representable values.
        assert_eq!(
            f32_to_bf16(s),
            f32_to_bf16(bf16_to_f32(f32_to_bf16(x))),
            "case {case}: {x}"
        );
        assert_eq!(snap_bf16(s).to_bits(), s.to_bits(), "case {case}: {x}");
        // Half-ULP error bound for normal values.
        assert!(
            (x - s).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
            "case {case}: {x} -> {s}"
        );
        // Monotone rounding: ordered inputs stay ordered after rounding.
        let y = x + x.abs() * (rng.f32() * 0.1);
        assert!(
            snap_bf16(y.max(x)) >= snap_bf16(x.min(y)),
            "case {case}: {x} vs {y}"
        );
    }
}

/// All seven optimizers, both shard plans: a bf16-stored run must track
/// its f32 twin within the documented tolerance on the parameter region —
/// same engine plan, same gradient values, only the storage dtype differs.
/// Mirrors `integration_fused_host.rs`'s all-optimizer sweep.
#[test]
fn bf16_tracks_f32_within_tolerance_for_all_seven_optimizers() {
    for kind in ALL_OPTS {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let layout = model_layout(kind);
            let (blob0, _) = seeded_blob_and_grads(&layout, 31);
            let mut cfg = PipelineConfig::new(3, layout.params_len.div_ceil(6));
            cfg.n_shards = 2;
            cfg.lr = 5e-3;
            cfg.wd = 0.01;
            let run = |dtype: Dtype| -> Vec<f32> {
                let mut cfg = cfg.clone();
                cfg.dtype = dtype;
                let mut plan =
                    ExecPlan::pipelined_fused(kind, mode, 2, &cfg);
                plan.seed = 19;
                let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
                let sources = fused_host::plan_sources(
                    eng.plan(),
                    eng.group_extents(),
                    0.05,
                );
                eng.run(sources).unwrap();
                eng.into_blob()
            };
            let a = run(Dtype::F32);
            let b = run(Dtype::Bf16);
            for (i, (&x, &y)) in a[..layout.params_len]
                .iter()
                .zip(&b[..layout.params_len])
                .enumerate()
            {
                assert!(
                    (x - y).abs() <= BF16_TOL_ABS + BF16_TOL_REL * x.abs(),
                    "{kind:?} {mode:?} param {i}: f32 {x} vs bf16 {y}"
                );
            }
            // bf16 params are genuinely bf16-representable bits.
            for (i, &y) in b[..layout.params_len].iter().enumerate() {
                assert_eq!(
                    y.to_bits(),
                    snap_bf16(y).to_bits(),
                    "{kind:?} {mode:?} param {i} not bf16-representable"
                );
            }
        }
    }
}

/// The tentpole's byte claims, measured: blob storage and checkpoint file
/// at or under 55% of the f32 baseline; modeled exchange bytes exactly
/// halved (same tiling, half the wire width); per-task conversion scratch
/// measured == analytic bound and far below a full-image f32 mirror.
#[test]
fn bf16_halves_blob_checkpoint_and_comm_bytes() {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 7);
    let mut cfg = PipelineConfig::new(2, layout.params_len.div_ceil(8));
    cfg.n_shards = 2;
    let mut reports = Vec::new();
    let mut files = Vec::new();
    for dtype in [Dtype::F32, Dtype::Bf16] {
        let mut cfg = cfg.clone();
        cfg.dtype = dtype;
        let mut plan = ExecPlan::pipelined(kind, ShardMode::Segments, 2, &cfg);
        plan.seed = 3;
        let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
        let sources =
            fused_host::plan_sources(eng.plan(), eng.group_extents(), 0.05);
        let r = eng.run(sources).unwrap();
        assert_eq!(r.dtype, dtype);
        assert_eq!(eng.typed_blob().storage_bytes(), r.blob_bytes);
        assert_eq!(eng.layout().storage_dtype().unwrap(), dtype);
        let path = tmp(&format!("bytes_{}", dtype.name()));
        eng.save(&path).unwrap();
        files.push(std::fs::metadata(&path).unwrap().len());
        std::fs::remove_file(&path).ok();
        reports.push(r);
    }
    let (r32, r16) = (&reports[0], &reports[1]);
    // Blob bytes: bf16 prefix is exactly half; the 8-float f32 metrics
    // tail keeps the total a hair above 50%, well under the 55% bar.
    assert_eq!(r32.blob_bytes, 4 * layout.blob_len);
    assert_eq!(
        r16.blob_bytes,
        2 * layout.shardable_len() + 4 * (layout.blob_len - layout.shardable_len())
    );
    assert!(
        (r16.blob_bytes as f64) <= 0.55 * r32.blob_bytes as f64,
        "blob {} vs {}",
        r16.blob_bytes,
        r32.blob_bytes
    );
    // Checkpoint file: same 55% bar.
    assert!(
        (files[1] as f64) <= 0.55 * files[0] as f64,
        "checkpoint {} vs {}",
        files[1],
        files[0]
    );
    // Exchange: identical tiling, exactly half the wire bytes — and the
    // modeled fabric time drops with it.
    assert_eq!(r32.n_buckets, r16.n_buckets);
    assert_eq!(2 * r16.comm_bytes_per_step, r32.comm_bytes_per_step);
    assert_eq!(2 * r16.peak_comm_bytes, r32.peak_comm_bytes);
    assert!(r16.comm_secs < r32.comm_secs);

    // Bounded scratch: measured == analytic, and far below a mirror.
    let l16 = layout.with_storage_dtype(Dtype::Bf16);
    let mut opt =
        FlatOptimizer::new(kind, &l16, 2, ShardMode::Segments).unwrap();
    let mut blob =
        TypedBlob::from_f32(&l16, &blob0, Dtype::Bf16).unwrap();
    let (_, grads) = seeded_blob_and_grads(&l16, 7);
    opt.step_typed(&mut blob, &grads, 1, 1e-2, 0.0).unwrap();
    assert_eq!(
        opt.bf16_peak_scratch_elems(),
        opt.bf16_scratch_bound_elems()
    );
    assert!(opt.bf16_peak_scratch_elems() < l16.shardable_len() / 2);
}

/// bf16 suspend/checkpoint/resume: the resumed run must reproduce the
/// uninterrupted bf16 run bit-for-bit, including the raw u16 storage, and
/// the two final checkpoint files must be byte-identical (the ckpt-smoke
/// contract at the second dtype).
#[test]
fn bf16_suspend_resume_is_bit_exact() {
    let kind = OptKind::AdaLomo;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 61);
    let mut cfg = PipelineConfig::new(6, layout.params_len.div_ceil(7));
    cfg.n_shards = 2;
    cfg.dtype = Dtype::Bf16;
    let mut plan = ExecPlan::pipelined_fused(kind, ShardMode::Contiguous, 2, &cfg);
    plan.seed = 17;

    let srcs = |eng: &Engine| -> RankSources {
        fused_host::plan_sources(eng.plan(), eng.group_extents(), 0.05)
    };

    let mut full = Engine::new(&layout, &blob0, plan.clone()).unwrap();
    let sources = srcs(&full);
    full.run(sources).unwrap();
    assert!(full.is_finished());

    let mid = tmp("bf16_mid");
    let mut part = Engine::new(&layout, &blob0, plan).unwrap();
    part.suspend_at(3);
    let sources = srcs(&part);
    part.run(sources).unwrap();
    part.save(&mid).unwrap();

    let mut resumed = Engine::resume(&mid).unwrap();
    assert_eq!(resumed.step(), 3);
    assert_eq!(resumed.plan().dtype, Dtype::Bf16);
    let sources = srcs(&resumed);
    resumed.run(sources).unwrap();
    assert!(resumed.is_finished());

    // Raw storage bits equal — stronger than widened-value equality.
    assert_eq!(
        full.typed_blob().prefix_bits(),
        resumed.typed_blob().prefix_bits()
    );
    assert_eq!(full.typed_blob(), resumed.typed_blob());

    let p_full = tmp("bf16_full");
    let p_rest = tmp("bf16_rest");
    full.save(&p_full).unwrap();
    resumed.save(&p_rest).unwrap();
    assert_eq!(
        std::fs::read(&p_full).unwrap(),
        std::fs::read(&p_rest).unwrap()
    );
    for p in [mid, p_full, p_rest] {
        std::fs::remove_file(p).ok();
    }
}

/// The dtype is checkpointed, not guessed: a bf16 run's file carries the
/// tag on the plan, on every non-metric segment, and on the blob itself,
/// and a resume continues at exactly that dtype (tampered tags are
/// rejected by the reader — covered by the checkpoint fuzz tests).
#[test]
fn dtype_is_checkpointed_not_guessed() {
    let kind = OptKind::AdamW;
    let layout = model_layout(kind);
    let (blob0, _) = seeded_blob_and_grads(&layout, 5);
    let mut cfg = PipelineConfig::new(2, layout.params_len);
    cfg.dtype = Dtype::Bf16;
    let mut eng = Engine::new(
        &layout,
        &blob0,
        ExecPlan::sequential(kind, ShardMode::Segments, 1, &cfg),
    )
    .unwrap();
    let sources =
        fused_host::plan_sources(eng.plan(), eng.group_extents(), 0.05);
    eng.run(sources).unwrap();
    let path = tmp("tagged");
    eng.save(&path).unwrap();
    let ck = adalomo::runtime::checkpoint::load(&path).unwrap();
    assert_eq!(ck.layout.storage_dtype().unwrap(), Dtype::Bf16);
    assert_eq!(ck.blob.dtype(), Dtype::Bf16);
    assert_eq!(
        ck.plan.dtype,
        adalomo::runtime::checkpoint::DT_BF16
    );
    // Every non-metric segment carries the tag; metrics stay f32.
    for s in &ck.layout.segments {
        if s.kind == "metric" {
            assert_eq!(s.dtype, Dtype::F32, "{}", s.name);
        } else {
            assert_eq!(s.dtype, Dtype::Bf16, "{}", s.name);
        }
    }
    let resumed = Engine::resume(&path).unwrap();
    assert_eq!(resumed.plan().dtype, Dtype::Bf16);
    std::fs::remove_file(path).ok();
}

/// All seven optimizers, both shard plans, at fixed f32 storage: the
/// bf16 and q8 wire rungs must track the f32-wire reference within their
/// documented tolerances (`WIRE_*` consts above). Same plan, same
/// gradient values — only the exchange encoding differs, so this is the
/// convergence-bound half of the wire ladder's acceptance criteria.
#[test]
fn compressed_wire_rungs_track_f32_wire_for_all_seven_optimizers() {
    for kind in ALL_OPTS {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let layout = model_layout(kind);
            let (blob0, _) = seeded_blob_and_grads(&layout, 31);
            let mut cfg = PipelineConfig::new(3, layout.params_len.div_ceil(6));
            cfg.n_shards = 2;
            cfg.lr = 5e-3;
            cfg.wd = 0.01;
            let run = |wire: Option<WireCodec>| -> Vec<f32> {
                let mut cfg = cfg.clone();
                cfg.wire = wire;
                let mut plan =
                    ExecPlan::pipelined_fused(kind, mode, 2, &cfg);
                plan.seed = 19;
                let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
                let sources = fused_host::plan_sources(
                    eng.plan(),
                    eng.group_extents(),
                    0.05,
                );
                eng.run(sources).unwrap();
                eng.into_blob()
            };
            // f32 storage with no override resolves to the f32 wire.
            let reference = run(None);
            for (wire, abs, rel) in [
                (WireCodec::Bf16, BF16_TOL_ABS, BF16_TOL_REL),
                (WireCodec::Q8Block, WIRE_Q8_TOL_ABS, WIRE_Q8_TOL_REL),
            ] {
                let b = run(Some(wire));
                for (i, (&x, &y)) in reference[..layout.params_len]
                    .iter()
                    .zip(&b[..layout.params_len])
                    .enumerate()
                {
                    assert!(
                        (x - y).abs() <= abs + rel * x.abs(),
                        "{kind:?} {mode:?} {} wire param {i}: \
                         f32-wire {x} vs {y}",
                        wire.name()
                    );
                }
            }
        }
    }
}
