//! Runtime integration: the manifest + PJRT session against the real
//! artifacts. Skips gracefully when `make artifacts` has not run.

use adalomo::experiments as exp;
use adalomo::runtime::{Manifest, Session};

fn session() -> Option<Session> {
    if !exp::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(exp::open_session().expect("session"))
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(s) = session() else { return };
    let layout = s.manifest.layout("nano/adalomo").unwrap();
    let run = |seed: i32| {
        let seed = s.upload_i32(&[seed], &[]).unwrap();
        let blob = s
            .execute_buf(&Manifest::init_name("nano", "adalomo"), &[&seed])
            .unwrap();
        s.fetch_f32_raw(&blob, layout.blob_len).unwrap()
    };
    let a = run(42);
    let b = run(42);
    let c = run(43);
    assert_eq!(a, b, "same seed, same blob");
    assert_ne!(a, c, "different seed, different params");
    // Optimizer state + metrics start at zero.
    assert!(a[layout.params_len..].iter().all(|&v| v == 0.0));
}

#[test]
fn init_norm_gains_are_one() {
    let Some(s) = session() else { return };
    let layout = s.manifest.layout("nano/adalomo").unwrap();
    let seed = s.upload_i32(&[1], &[]).unwrap();
    let blob = s
        .execute_buf(&Manifest::init_name("nano", "adalomo"), &[&seed])
        .unwrap();
    let data = s.fetch_f32_raw(&blob, layout.blob_len).unwrap();
    let seg = layout.segment("final_norm").unwrap();
    assert!(data[seg.offset..seg.offset + seg.size]
        .iter()
        .all(|&v| v == 1.0));
}

#[test]
fn train_step_roundtrip_shapes() {
    let Some(s) = session() else { return };
    let p = s.manifest.preset("nano").unwrap().clone();
    let layout = s.manifest.layout("nano/adalomo").unwrap().clone();
    let seed = s.upload_i32(&[7], &[]).unwrap();
    let blob = s
        .execute_buf(&Manifest::init_name("nano", "adalomo"), &[&seed])
        .unwrap();
    let n = p.batch_size * p.seq_len;
    let x = s
        .upload_i32(&vec![65i32; n], &[p.batch_size, p.seq_len])
        .unwrap();
    let y = s
        .upload_i32(&vec![66i32; n], &[p.batch_size, p.seq_len])
        .unwrap();
    let sched = s.upload_f32(&[1e-3, 1.0, 0.0, 1.0], &[4]).unwrap();
    let out = s
        .execute_buf("train_step_nano_adalomo", &[&blob, &x, &y, &sched])
        .unwrap();
    let data = s.fetch_f32_raw(&out, layout.blob_len).unwrap();
    assert_eq!(data.len(), layout.blob_len);
    assert!(data.iter().all(|v| v.is_finite()));
    // Metrics populated.
    let m = &data[layout.metrics_offset()..];
    assert!(m[0] > 0.0 && m[0] < 10.0, "loss {}", m[0]);
    assert_eq!(m[1], n as f32);
}

#[test]
fn wrong_arity_is_rejected() {
    let Some(s) = session() else { return };
    let seed = s.upload_i32(&[7], &[]).unwrap();
    let err = s.execute_buf("train_step_nano_adalomo", &[&seed]);
    assert!(err.is_err());
}

#[test]
fn unknown_entry_is_rejected() {
    let Some(s) = session() else { return };
    assert!(s.compile("no_such_entry").is_err());
}

#[test]
fn extract_params_is_prefix() {
    let Some(s) = session() else { return };
    let layout = s.manifest.layout("nano/adamw").unwrap();
    let seed = s.upload_i32(&[3], &[]).unwrap();
    let blob = s
        .execute_buf(&Manifest::init_name("nano", "adamw"), &[&seed])
        .unwrap();
    let params = s
        .execute_buf(
            &Manifest::extract_params_name("nano", "adamw"),
            &[&blob],
        )
        .unwrap();
    let full = s.fetch_f32_raw(&blob, layout.blob_len).unwrap();
    let got = s.fetch_f32_raw(&params, layout.params_len).unwrap();
    assert_eq!(got, full[..layout.params_len]);
}

#[test]
fn compile_cache_hits() {
    let Some(s) = session() else { return };
    s.compile("eval_nano").unwrap();
    let before = s.stats().compiles;
    s.compile("eval_nano").unwrap();
    assert_eq!(s.stats().compiles, before, "second compile must be cached");
}

#[test]
fn every_nano_entry_compiles() {
    let Some(s) = session() else { return };
    // Compiling everything is the strongest artifact smoke test we have.
    for name in s.entries_for_preset("nano") {
        s.compile(&name).unwrap_or_else(|e| panic!("{name}: {e:#}"));
    }
}
