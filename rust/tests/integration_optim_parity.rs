//! Cross-layer parity: the Rust-native optimizers must agree with the AOT
//! toy2d artifacts step-for-step — the same update math flowing through
//! (a) rust/src/optim and (b) Pallas/jnp -> HLO -> PJRT.

use adalomo::experiments as exp;
use adalomo::optim::OptKind;
use adalomo::runtime::Session;
use adalomo::tensor::Tensor;

fn session() -> Option<Session> {
    if !exp::artifacts_available() {
        eprintln!("skipping: artifacts not built");
        return None;
    }
    Some(exp::open_session().expect("session"))
}

/// Drive the toy2d_<opt> artifact for `steps` steps from (x0, y0).
fn artifact_trajectory(
    s: &Session,
    opt: &str,
    lr: f32,
    steps: usize,
    start: (f32, f32),
) -> Vec<(f32, f32)> {
    let layout_key = format!("toy2d/{opt}");
    let layout = s.manifest.layout(&layout_key).unwrap();
    let mut blob = vec![0f32; layout.blob_len];
    blob[0] = start.0;
    blob[1] = start.1;
    let mut buf = s.upload_f32(&blob, &[layout.blob_len]).unwrap();
    let entry = format!("toy2d_{opt}");
    let mut out = vec![start];
    for t in 1..=steps {
        let sched = s
            .upload_f32(&[lr, t as f32, 0.0, 1.0], &[4])
            .unwrap();
        buf = s.execute_buf(&entry, &[&buf, &sched]).unwrap();
        let data = s.fetch_f32_raw(&buf, 2).unwrap();
        out.push((data[0], data[1]));
    }
    out
}

/// Native trajectory with the same update rule.
fn native_trajectory(
    kind: OptKind,
    lr: f32,
    steps: usize,
    start: (f32, f32),
) -> Vec<(f32, f32)> {
    let mut theta = Tensor::new(&[2], vec![start.0, start.1]).unwrap();
    let mut opt = adalomo::optim::ParamOpt::new(kind, &[2]);
    let mut out = vec![start];
    for t in 1..=steps {
        let (_, (dx, dy)) =
            exp::toy2d_value_grad(theta.data()[0], theta.data()[1]);
        let g = Tensor::new(&[2], vec![dx, dy]).unwrap();
        opt.step(&mut theta, &g, t as u64, lr, 0.0);
        out.push((theta.data()[0], theta.data()[1]));
    }
    out
}

fn assert_trajectories_close(a: &[(f32, f32)], b: &[(f32, f32)], tol: f32, label: &str) {
    assert_eq!(a.len(), b.len());
    for (i, (pa, pb)) in a.iter().zip(b).enumerate() {
        assert!(
            (pa.0 - pb.0).abs() < tol && (pa.1 - pb.1).abs() < tol,
            "{label} diverges at step {i}: {pa:?} vs {pb:?}"
        );
    }
}

#[test]
fn sgd_parity() {
    let Some(s) = session() else { return };
    let a = artifact_trajectory(&s, "sgd", 0.02, 60, (0.3, 0.9));
    let b = native_trajectory(OptKind::Sgd, 0.02, 60, (0.3, 0.9));
    assert_trajectories_close(&a, &b, 5e-4, "sgd");
}

#[test]
fn sgd_momentum_parity() {
    let Some(s) = session() else { return };
    let a = artifact_trajectory(&s, "sgd_momentum", 0.02, 60, (0.3, 0.9));
    let b = native_trajectory(OptKind::SgdMomentum, 0.02, 60, (0.3, 0.9));
    assert_trajectories_close(&a, &b, 5e-4, "sgd_momentum");
}

#[test]
fn sgd_variance_parity() {
    let Some(s) = session() else { return };
    let a = artifact_trajectory(&s, "sgd_variance", 0.02, 60, (0.3, 0.9));
    let b = native_trajectory(OptKind::SgdVariance, 0.02, 60, (0.3, 0.9));
    assert_trajectories_close(&a, &b, 2e-3, "sgd_variance");
}

#[test]
fn adamw_parity() {
    let Some(s) = session() else { return };
    let a = artifact_trajectory(&s, "adamw", 0.02, 60, (0.3, 0.9));
    let b = native_trajectory(OptKind::AdamW, 0.02, 60, (0.3, 0.9));
    assert_trajectories_close(&a, &b, 2e-3, "adamw");
}

#[test]
fn fig6_basins_through_artifacts() {
    // The Appendix-A result must hold through the AOT path too.
    let Some(s) = session() else { return };
    let basin = |opt: &str| {
        let traj = artifact_trajectory(
            &s,
            opt,
            exp::TOY2D_LR,
            exp::TOY2D_STEPS.min(600),
            exp::TOY2D_START,
        );
        traj.last().unwrap().0 < 0.0
    };
    assert!(!basin("sgd"), "sgd -> local well");
    assert!(!basin("sgd_momentum"), "momentum -> local well");
    assert!(basin("sgd_variance"), "variance -> global well");
    assert!(basin("adamw"), "adam -> global well");
}
