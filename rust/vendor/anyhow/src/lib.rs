//! Offline stand-in for the `anyhow` crate: the exact API subset this
//! repository uses (`Result`, `Error`, `anyhow!`, `bail!`, `ensure!`, the
//! `Context` trait), implemented over a plain message + cause chain.
//!
//! Why vendored: the build environment has no crates.io access, and the
//! coordinator only needs string-y error propagation — no downcasting, no
//! backtraces. The surface is source-compatible with real anyhow, so
//! swapping the path dependency back to the registry crate is a one-line
//! Cargo.toml change.

use std::fmt;

/// `anyhow::Result<T>` — `E` defaults to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// A string-backed error with an ordered cause chain (outermost first).
///
/// Deliberately does NOT implement `std::error::Error`, exactly like real
/// anyhow — that is what keeps the blanket `From<E: std::error::Error>`
/// conversion coherent.
pub struct Error {
    msg: String,
    causes: Vec<String>,
}

impl Error {
    /// Build an error from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), causes: Vec::new() }
    }

    /// Wrap this error with an outer context message.
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        let mut causes = Vec::with_capacity(self.causes.len() + 1);
        causes.push(self.msg);
        causes.extend(self.causes);
        Error { msg: c.to_string(), causes }
    }

    /// The cause chain, outermost context first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        std::iter::once(self.msg.as_str())
            .chain(self.causes.iter().map(String::as_str))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the whole chain on one line, like anyhow.
            write!(f, "{}", self.msg)?;
            for c in &self.causes {
                write!(f, ": {c}")?;
            }
            Ok(())
        } else {
            write!(f, "{}", self.msg)
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        if !self.causes.is_empty() {
            write!(f, "\n\nCaused by:")?;
            for c in &self.causes {
                write!(f, "\n    {c}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut causes = Vec::new();
        let mut src = e.source();
        while let Some(s) = src {
            causes.push(s.to_string());
            src = s.source();
        }
        Error { msg: e.to_string(), causes }
    }
}

/// Attach context to `Result` and `Option` values (anyhow's `Context`).
pub trait Context<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E: Into<Error>> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.map_err(|e| e.into().context(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| e.into().context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Early-return with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Assert-or-return-error.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::anyhow!(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)+) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)+));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails_io() -> Result<()> {
        std::fs::read("/definitely/not/here/ever")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = fails_io().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(anyhow!("inner {}", 7));
        let e = e.context("outer").unwrap_err();
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 7");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn option_context() {
        let v: Option<u8> = None;
        let e = v.with_context(|| "missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }

    #[test]
    fn ensure_and_bail() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            if x > 100 {
                bail!("too big");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert!(f(-1).unwrap_err().to_string().contains("positive"));
        assert!(f(200).unwrap_err().to_string().contains("too big"));
    }
}
