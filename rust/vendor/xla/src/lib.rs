//! Stub of the `xla` (xla-rs) PJRT bindings.
//!
//! The coordinator's runtime layer (`rust/src/runtime/session.rs`) is the
//! only consumer of this crate. In environments without the XLA runtime
//! libraries this stub lets the whole workspace build, test and bench; the
//! artifact-gated paths degrade gracefully because every integration test
//! checks `experiments::artifacts_available()` before opening a session,
//! and [`PjRtClient::cpu`] — the first call on any real-execution path —
//! returns a descriptive error.
//!
//! To run the AOT artifacts for real, point the `xla` path dependency in
//! the root `Cargo.toml` at the actual xla-rs checkout; the type and
//! method surface here matches the subset the runtime uses.

use std::fmt;

/// Error type mirroring xla-rs's (only its `Debug`/`Display` are relied on).
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "xla stub: {what} is unavailable — this build uses the vendored \
         PJRT stub (rust/vendor/xla); link the real xla-rs bindings to \
         execute AOT artifacts"
    )))
}

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait Element: Copy {}
impl Element for f32 {}
impl Element for i32 {}

/// Parsed HLO module (stub: never successfully constructed).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer handle.
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

/// Host-side literal value.
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

/// A compiled executable bound to a client.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, _args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }

    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute")
    }
}

/// PJRT client handle.
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu (no PJRT runtime linked)")
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Element>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_open_reports_stub() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("stub"));
    }
}
