//! Experiment drivers shared by the CLI, the examples and the benches —
//! one function per paper experiment family, so every surface regenerates
//! the same numbers.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::config::{paper_lr, Phase, RunConfig, SMALL_MODEL_LR_SCALE};
use crate::coordinator::{Trainer, TrainReport};
use crate::data::{instruct, loader::DataLoader, Domain};
use crate::eval::{run_suite, SuiteResult};
use crate::optim::{OptKind, ParamOpt};
use crate::runtime::{HostBlob, Manifest, Session};
use crate::tensor::Tensor;

/// Default token budgets (tokens of train stream per step-budget unit).
fn lm_loader(
    session: &Session,
    preset: &str,
    domain: Domain,
    seed: u64,
    steps: usize,
) -> Result<(DataLoader, DataLoader)> {
    let p = session.manifest.preset(preset)?;
    let (b, t) = (p.batch_size, p.seq_len);
    // Enough stream for the run without epoch-cycling too aggressively.
    let train_tokens = (steps * b * t).clamp(b * (t + 1) * 2, 8_000_000);
    let train = DataLoader::lm(domain, seed, b, t, train_tokens);
    let val = DataLoader::lm(domain, seed + 104_729, b, t, 16 * b * (t + 1));
    Ok((train, val))
}

/// Effective LR for a (opt, phase) on our scaled-down models.
///
/// AdaLomo and Adafactor keep the PAPER's values untouched: their steps
/// are relative to RMS(theta) (grouped normalization / relative step
/// size), so the LRs transfer across model scales — one of the paper's
/// selling points, demonstrated here by construction. Absolute-step
/// optimizers need small-model retuning (tiny models tolerate and require
/// larger steps): SGD-family gets the generic x10 rescale; AdamW's 2e-5,
/// tuned for 7B+, is lifted to the standard small-transformer 1e-3.
pub fn effective_lr(opt: &str, phase: Phase) -> f32 {
    let base = paper_lr(opt, phase);
    match opt {
        // From-scratch is step-budget-compressed (paper: 8000 steps of
        // 1e-3 relative movement; our runs: 150-400 steps). Matching the
        // TOTAL relative movement gives 1e-3 * 8000 / ~250 ≈ 3e-2.
        // Fine-tuning phases keep the paper values verbatim.
        "adalomo" | "adalomo_gnorm" | "adafactor"
            if phase == Phase::Scratch =>
        {
            3e-2
        }
        "adalomo" | "adalomo_gnorm" | "adafactor" => base,
        "adamw" | "adam" => 1e-3,
        "lora" => 3e-3, // paper 3e-4, same x10 as the SGD family
        // LOMO is plain SGD: x10 like SGD but capped where the paper's
        // already-large 1e-2 would overshoot on tiny models.
        "lomo" | "lomo_gnorm" => (base * SMALL_MODEL_LR_SCALE).min(2e-2),
        _ => base * SMALL_MODEL_LR_SCALE,
    }
}

/// From-scratch pre-training (paper §4.3 / Fig. 4).
pub fn scratch_run(
    session: &Session,
    preset: &str,
    opt: &str,
    steps: usize,
    seed: u64,
    out_dir: &str,
) -> Result<TrainReport> {
    let mut cfg = RunConfig::new(preset, opt, Phase::Scratch, steps);
    cfg.lr = effective_lr(opt, Phase::Scratch);
    cfg.seed = seed;
    cfg.out_dir = out_dir.to_string();
    cfg.eval_every = (steps / 8).max(1);
    cfg.log_every = (steps / 50).max(1);
    let (train, val) = lm_loader(session, preset, Domain::C4, seed, steps)?;
    let mut trainer =
        Trainer::new(session, cfg, train, Some(val))?.with_logging()?;
    trainer.train()
}

/// Build (or load from cache) the "pre-trained LLaMA" stand-in: a short
/// AdamW pre-train on the C4 mixture. Further pre-training and instruction
/// tuning start from this checkpoint, as the paper starts from LLaMA.
pub fn ensure_base_checkpoint(
    session: &Session,
    preset: &str,
    steps: usize,
    seed: u64,
    cache_dir: &str,
) -> Result<HostBlob> {
    let path = PathBuf::from(cache_dir)
        .join(format!("base_{preset}_{steps}_{seed}.ckpt"));
    if path.exists() {
        if let Ok(blob) = HostBlob::load(&path) {
            return Ok(blob);
        }
    }
    let mut cfg = RunConfig::new(preset, "adamw", Phase::Scratch, steps);
    cfg.lr = effective_lr("adamw", Phase::Scratch);
    cfg.seed = seed;
    cfg.eval_every = 0;
    cfg.log_every = (steps / 10).max(1);
    let (train, _) = lm_loader(session, preset, Domain::C4, seed, steps)?;
    let mut trainer = Trainer::new(session, cfg, train, None)?;
    trainer.train()?;
    let blob = trainer.host_blob()?;
    std::fs::create_dir_all(cache_dir).ok();
    blob.save(&path).context("saving base checkpoint")?;
    Ok(blob)
}

/// Repack a checkpoint into another optimizer's layout (params carry over,
/// optimizer state restarts at zero).
pub fn repack_checkpoint(
    session: &Session,
    blob: &HostBlob,
    preset: &str,
    opt: &str,
) -> Result<HostBlob> {
    let from = session.manifest.layout(&blob.layout_key)?;
    let to_key = Manifest::layout_key(preset, opt);
    let to = session.manifest.layout(&to_key)?;
    blob.repack(from, to, &to_key)
}

/// Further pre-training on a domain from the base checkpoint
/// (paper §4.2 / Figs. 2-3; with `opt = "*_gnorm"`, Appendix B Figs. 7-8).
pub fn further_pretrain(
    session: &Session,
    preset: &str,
    opt: &str,
    domain: Domain,
    steps: usize,
    base: &HostBlob,
    seed: u64,
    out_dir: &str,
) -> Result<TrainReport> {
    let mut cfg = RunConfig::new(preset, opt, Phase::FurtherPretrain, steps);
    cfg.lr = effective_lr(opt, Phase::FurtherPretrain);
    cfg.seed = seed;
    cfg.domain = domain.name().to_string();
    cfg.out_dir = out_dir.to_string();
    cfg.eval_every = (steps / 10).max(1);
    cfg.log_every = (steps / 50).max(1);
    let (train, val) = lm_loader(session, preset, domain, seed, steps)?;
    let mut trainer =
        Trainer::new(session, cfg, train, Some(val))?.with_logging()?;
    let repacked = repack_checkpoint(session, base, preset, opt)?;
    trainer.set_host_blob(&repacked)?;
    trainer.train()
}

#[derive(Debug, Clone)]
pub struct InstructOutcome {
    pub report: Option<TrainReport>,
    pub suite: SuiteResult,
}

/// Instruction tuning from the base checkpoint + five-benchmark scores
/// (paper §4.1 / Tables 2 & 5). `opt = "none"` evaluates the raw base
/// model (the paper's "N/A" row).
pub fn instruction_tune(
    session: &Session,
    preset: &str,
    opt: &str,
    steps: usize,
    base: &HostBlob,
    seed: u64,
    out_dir: &str,
    n_eval_items: usize,
) -> Result<InstructOutcome> {
    let p = session.manifest.preset(preset)?.clone();
    let (b, t) = (p.batch_size, p.seq_len);

    // Base ("N/A") parameters for the reference side of the win rate.
    let base_adamw = repack_checkpoint(session, base, preset, "adamw")?;
    let base_params = {
        let layout_key = Manifest::layout_key(preset, "adamw");
        let layout = session.manifest.layout(&layout_key)?;
        let buf = session.upload_f32(&base_adamw.data, &[layout.blob_len])?;
        session.execute_buf(
            &Manifest::extract_params_name(preset, "adamw"),
            &[&buf],
        )?
    };

    if opt == "none" {
        let suite = run_suite(
            session, preset, &base_params, &base_params, n_eval_items, seed,
        )?;
        return Ok(InstructOutcome { report: None, suite });
    }

    let examples: Vec<_> = instruct::training_set(seed, 512)
        .iter()
        .map(|e| e.tokenize())
        .collect();
    let loader = DataLoader::from_examples(examples, seed, b, t);
    let mut cfg = RunConfig::new(preset, opt, Phase::Instruct, steps);
    cfg.lr = effective_lr(opt, Phase::Instruct);
    cfg.seed = seed;
    cfg.domain = "instruct".into();
    cfg.out_dir = out_dir.to_string();
    cfg.eval_every = 0;
    cfg.log_every = (steps / 20).max(1);
    let mut trainer = Trainer::new(session, cfg, loader, None)?.with_logging()?;
    let repacked = if opt == "lora" {
        // Repacking zeroes the optimizer state AND the adapters — but LoRA
        // needs A ~ N(0, 0.02) (with A = B = 0 both adapter gradients
        // vanish identically and nothing trains). Take a fresh seeded LoRA
        // init and overlay the base checkpoint onto its frozen region.
        let layout_key = Manifest::layout_key(preset, "lora");
        let layout = session.manifest.layout(&layout_key)?.clone();
        let seed_buf = session.upload_i32(&[seed as i32], &[])?;
        let init_buf = session
            .execute_buf(&Manifest::init_name(preset, "lora"), &[&seed_buf])?;
        let mut data = session.fetch_f32_raw(&init_buf, layout.blob_len)?;
        let from = session.manifest.layout(&base.layout_key)?;
        let ncopy = from.params_len.min(layout.params_len);
        data[..ncopy].copy_from_slice(&base.data[..ncopy]);
        HostBlob::new(data, &layout_key, &layout)?
    } else {
        repack_checkpoint(session, base, preset, opt)?
    };
    trainer.set_host_blob(&repacked)?;
    let report = trainer.train()?;

    // LoRA evaluates through the merged weights; others extract directly.
    let params = if opt == "lora" {
        let layout_key = Manifest::layout_key(preset, "lora");
        let layout = session.manifest.layout(&layout_key)?;
        let blob = trainer.host_blob()?;
        let buf = session.upload_f32(&blob.data, &[layout.blob_len])?;
        session.execute_buf(&format!("merge_lora_{preset}"), &[&buf])?
    } else {
        trainer.params_buffer()?
    };
    let suite = run_suite(
        session, preset, &params, &base_params, n_eval_items, seed,
    )?;
    Ok(InstructOutcome { report: Some(report), suite })
}

/// Canonical Fig-6 configuration: from this start, SGD and SGD+momentum
/// descend into the local well at (+1, 0) while SGD+variance and Adam
/// reach the global optimum at (-1, 0) — the paper's Appendix-A result.
pub const TOY2D_START: (f32, f32) = (0.3, 0.9);
pub const TOY2D_LR: f32 = 0.02;
pub const TOY2D_STEPS: usize = 1000;

/// Rust-native toy-2D trajectory (paper Appendix A / Fig. 6). Cross-checked
/// against the `toy2d_*` artifacts by integration tests.
pub fn toy2d_trajectory(
    opt: OptKind,
    lr: f32,
    steps: usize,
    start: (f32, f32),
) -> Vec<(f32, f32, f32)> {
    let mut theta = Tensor::new(&[2], vec![start.0, start.1]).unwrap();
    let mut popt = ParamOpt::new(opt, &[2]);
    let mut out = Vec::with_capacity(steps + 1);
    for t in 1..=steps {
        let (f, g) = toy2d_value_grad(theta.data()[0], theta.data()[1]);
        out.push((theta.data()[0], theta.data()[1], f));
        let grad = Tensor::new(&[2], vec![g.0, g.1]).unwrap();
        popt.step(&mut theta, &grad, t as u64, lr, 0.0);
    }
    let (f, _) = toy2d_value_grad(theta.data()[0], theta.data()[1]);
    out.push((theta.data()[0], theta.data()[1], f));
    out
}

/// f(x, y) = x^2 + y^2 - 2 e^{-5[(x-1)^2+y^2]} - 3 e^{-5[(x+1)^2+y^2]}
/// and its analytic gradient.
pub fn toy2d_value_grad(x: f32, y: f32) -> (f32, (f32, f32)) {
    let e1 = (-5.0 * ((x - 1.0).powi(2) + y * y)).exp();
    let e2 = (-5.0 * ((x + 1.0).powi(2) + y * y)).exp();
    let f = x * x + y * y - 2.0 * e1 - 3.0 * e2;
    let dx = 2.0 * x + 20.0 * (x - 1.0) * e1 + 30.0 * (x + 1.0) * e2;
    let dy = 2.0 * y + 20.0 * y * e1 + 30.0 * y * e2;
    (f, (dx, dy))
}

/// Which minimum a trajectory ends in: the global well near (-1, 0) or the
/// local well near (+1, 0).
pub fn toy2d_basin(traj: &[(f32, f32, f32)]) -> &'static str {
    let last = traj.last().expect("non-empty trajectory");
    if last.0 < 0.0 {
        "global(-1,0)"
    } else {
        "local(+1,0)"
    }
}

/// Run a family of optimizers through the same scratch workload and return
/// name -> loss curve (paper Fig. 1 ablation / Fig. 4 comparison).
pub fn optimizer_comparison(
    session: &Session,
    preset: &str,
    opts: &[&str],
    steps: usize,
    seed: u64,
    out_dir: &str,
) -> Result<BTreeMap<String, TrainReport>> {
    let mut out = BTreeMap::new();
    for opt in opts {
        let report = scratch_run(session, preset, opt, steps, seed, out_dir)?;
        out.insert(opt.to_string(), report);
    }
    Ok(out)
}

/// Default artifacts directory (respects ADALOMO_ARTIFACTS).
pub fn artifacts_dir() -> PathBuf {
    PathBuf::from(
        std::env::var("ADALOMO_ARTIFACTS")
            .unwrap_or_else(|_| "artifacts".to_string()),
    )
}

/// True when the artifacts (and hence Session) are available — lets tests
/// and benches degrade gracefully before `make artifacts`.
pub fn artifacts_available() -> bool {
    artifacts_dir().join("manifest.json").exists()
}

pub fn open_session() -> Result<Session> {
    Session::open(Path::new(&artifacts_dir()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn toy2d_gradient_matches_finite_difference() {
        let (x, y) = (0.3, -0.4);
        let eps = 1e-3;
        let (_, (dx, dy)) = toy2d_value_grad(x, y);
        let fd_x = (toy2d_value_grad(x + eps, y).0
            - toy2d_value_grad(x - eps, y).0)
            / (2.0 * eps);
        let fd_y = (toy2d_value_grad(x, y + eps).0
            - toy2d_value_grad(x, y - eps).0)
            / (2.0 * eps);
        assert!((dx - fd_x).abs() < 1e-2, "{dx} vs {fd_x}");
        assert!((dy - fd_y).abs() < 1e-2, "{dy} vs {fd_y}");
    }

    #[test]
    fn toy2d_fig6_basins() {
        // Paper Fig. 6: from the same start, SGD and SGD+momentum fall into
        // the local well; Adam and SGD+variance reach the global one.
        let (start, lr, n) = (TOY2D_START, TOY2D_LR, TOY2D_STEPS);
        let sgd = toy2d_trajectory(OptKind::Sgd, lr, n, start);
        let mom = toy2d_trajectory(OptKind::SgdMomentum, lr, n, start);
        let var = toy2d_trajectory(OptKind::SgdVariance, lr, n, start);
        let adam = toy2d_trajectory(OptKind::AdamW, lr, n, start);
        assert_eq!(toy2d_basin(&sgd), "local(+1,0)");
        assert_eq!(toy2d_basin(&mom), "local(+1,0)");
        assert_eq!(toy2d_basin(&var), "global(-1,0)");
        assert_eq!(toy2d_basin(&adam), "global(-1,0)");
    }

    #[test]
    fn effective_lr_scales_absolute_not_relative() {
        // AdamW: small-model retune; AdaLomo: the paper value verbatim.
        assert_eq!(effective_lr("adamw", Phase::Instruct), 1e-3);
        assert_eq!(effective_lr("adalomo", Phase::Instruct), 5e-4);
        assert_eq!(effective_lr("adalomo", Phase::FurtherPretrain), 3e-1);
        assert_eq!(
            effective_lr("sgd", Phase::Scratch),
            1e-3 * SMALL_MODEL_LR_SCALE
        );
    }
}
