//! Run metrics: structured logging (JSONL + CSV) and training/eval
//! aggregation. Every experiment writes `runs/<name>/metrics.jsonl`, which
//! the benches and EXPERIMENTS.md tables are regenerated from.

use std::fs;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::Result;

use crate::util::json::{self, Json};

/// Metric slot indices in the 8-float blob region — must mirror
/// `python/compile/layout.py`.
pub const M_LOSS: usize = 0;
pub const M_TOKENS: usize = 1;
pub const M_CORRECT: usize = 2;
pub const M_GNORM: usize = 3;

/// One training/eval observation.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub tokens: f32,
    pub correct: f32,
    pub grad_norm: f32,
    pub lr: f32,
    pub step_time_s: f64,
}

impl StepMetrics {
    pub fn from_slots(step: usize, slots: &[f32], lr: f32, dt: f64) -> Self {
        StepMetrics {
            step,
            loss: slots[M_LOSS],
            tokens: slots[M_TOKENS],
            correct: slots[M_CORRECT],
            grad_norm: slots[M_GNORM],
            lr,
            step_time_s: dt,
        }
    }

    pub fn accuracy(&self) -> f32 {
        if self.tokens > 0.0 {
            self.correct / self.tokens
        } else {
            0.0
        }
    }

    pub fn perplexity(&self) -> f32 {
        self.loss.exp()
    }
}

/// Aggregate a set of eval batches into corpus-level loss/ppl/accuracy
/// (sum-weighted by token counts, matching the paper's validation curves).
#[derive(Debug, Default, Clone, Copy)]
pub struct EvalAccum {
    pub loss_sum: f64,
    pub tokens: f64,
    pub correct: f64,
}

impl EvalAccum {
    pub fn add_slots(&mut self, slots: &[f32]) {
        self.loss_sum += slots[M_LOSS] as f64 * slots[M_TOKENS] as f64;
        self.tokens += slots[M_TOKENS] as f64;
        self.correct += slots[M_CORRECT] as f64;
    }

    pub fn mean_loss(&self) -> f64 {
        if self.tokens > 0.0 {
            self.loss_sum / self.tokens
        } else {
            0.0
        }
    }

    pub fn perplexity(&self) -> f64 {
        self.mean_loss().exp()
    }

    pub fn accuracy(&self) -> f64 {
        if self.tokens > 0.0 {
            self.correct / self.tokens
        } else {
            0.0
        }
    }
}

/// JSONL run log.
pub struct RunLog {
    dir: PathBuf,
    file: fs::File,
}

impl RunLog {
    pub fn create(out_dir: &str, run_name: &str) -> Result<RunLog> {
        let dir = Path::new(out_dir).join(run_name);
        fs::create_dir_all(&dir)?;
        let file = fs::File::create(dir.join("metrics.jsonl"))?;
        Ok(RunLog { dir, file })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn log(&mut self, record: Json) -> Result<()> {
        writeln!(self.file, "{}", record.to_string())?;
        Ok(())
    }

    pub fn log_train(&mut self, m: &StepMetrics) -> Result<()> {
        self.log(json::obj(vec![
            ("kind", json::s("train")),
            ("step", json::num(m.step as f64)),
            ("loss", json::num(m.loss as f64)),
            ("acc", json::num(m.accuracy() as f64)),
            ("grad_norm", json::num(m.grad_norm as f64)),
            ("lr", json::num(m.lr as f64)),
            ("dt", json::num(m.step_time_s)),
        ]))
    }

    pub fn log_eval(&mut self, step: usize, e: &EvalAccum) -> Result<()> {
        self.log(json::obj(vec![
            ("kind", json::s("eval")),
            ("step", json::num(step as f64)),
            ("loss", json::num(e.mean_loss())),
            ("ppl", json::num(e.perplexity())),
            ("acc", json::num(e.accuracy())),
        ]))
    }
}

/// Load the loss curve (train records) back from a metrics.jsonl.
pub fn load_curve(path: &Path) -> Result<Vec<(usize, f64)>> {
    let text = fs::read_to_string(path)?;
    let mut out = Vec::new();
    for line in text.lines() {
        if line.trim().is_empty() {
            continue;
        }
        let j = Json::parse(line)?;
        if j.get("kind")?.as_str()? == "train" {
            out.push((
                j.get("step")?.as_usize()?,
                j.get("loss")?.as_f64()?,
            ));
        }
    }
    Ok(out)
}

/// Render a loss curve as a compact ASCII sparkline block for the console.
pub fn ascii_curve(points: &[(usize, f64)], width: usize, height: usize) -> String {
    if points.is_empty() {
        return String::from("(no data)\n");
    }
    let lo = points.iter().map(|p| p.1).fold(f64::INFINITY, f64::min);
    let hi = points.iter().map(|p| p.1).fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-9);
    let mut grid = vec![vec![b' '; width]; height];
    for (i, &(_, y)) in points.iter().enumerate() {
        let col = i * (width - 1) / points.len().max(1);
        let row = ((hi - y) / span * (height - 1) as f64).round() as usize;
        grid[row.min(height - 1)][col.min(width - 1)] = b'*';
    }
    let mut out = String::new();
    out.push_str(&format!("{hi:>10.4} ┐\n"));
    for row in grid {
        out.push_str("           │");
        out.push_str(std::str::from_utf8(&row).unwrap());
        out.push('\n');
    }
    out.push_str(&format!("{lo:>10.4} ┘ ({} points)\n", points.len()));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accum_weights_by_tokens() {
        let mut e = EvalAccum::default();
        e.add_slots(&[2.0, 10.0, 5.0, 0.0]); // loss 2 over 10 tokens
        e.add_slots(&[4.0, 30.0, 15.0, 0.0]); // loss 4 over 30 tokens
        assert!((e.mean_loss() - 3.5).abs() < 1e-9);
        assert!((e.accuracy() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn step_metrics_derived() {
        let m = StepMetrics::from_slots(3, &[1.0, 8.0, 4.0, 0.5], 1e-3, 0.1);
        assert_eq!(m.step, 3);
        assert_eq!(m.accuracy(), 0.5);
        assert!((m.perplexity() - std::f32::consts::E).abs() < 1e-4);
    }

    #[test]
    fn runlog_roundtrip() {
        let tmp = std::env::temp_dir().join(format!(
            "adalomo_test_{}",
            std::process::id()
        ));
        let mut log =
            RunLog::create(tmp.to_str().unwrap(), "unit").unwrap();
        for step in 0..3 {
            log.log_train(&StepMetrics {
                step,
                loss: 5.0 - step as f32,
                tokens: 10.0,
                correct: 1.0,
                grad_norm: 0.1,
                lr: 1e-3,
                step_time_s: 0.01,
            })
            .unwrap();
        }
        let curve =
            load_curve(&tmp.join("unit").join("metrics.jsonl")).unwrap();
        assert_eq!(curve.len(), 3);
        assert_eq!(curve[2], (2, 3.0));
        fs::remove_dir_all(tmp).ok();
    }

    #[test]
    fn ascii_curve_renders() {
        let pts: Vec<(usize, f64)> =
            (0..20).map(|i| (i, 5.0 - 0.2 * i as f64)).collect();
        let s = ascii_curve(&pts, 40, 8);
        assert!(s.contains('*'));
        assert!(s.lines().count() >= 8);
    }
}
