//! Minimal row-major f32 tensor.
//!
//! Used by the Rust-native optimizer mirrors ([`crate::optim`]), the toy-2D
//! experiment, the synthetic benchmark scoring and the property tests.
//! All heavy model compute runs inside the AOT XLA programs; this type only
//! needs the handful of operations the coordinator does on the host.

use anyhow::{bail, Result};

/// Storage dtype of a blob region (parameters / optimizer state).
///
/// Training compute always runs in f32; `Dtype` selects only how a
/// region's bits are *stored* — and, for the cost-modeled exchange, how
/// many bytes an element occupies on the wire. `Bf16` keeps f32's 8-bit
/// exponent and truncates the mantissa to 7 bits, so widening back to
/// f32 ([`bf16_to_f32`]) is exact and rounding ([`f32_to_bf16`],
/// round-to-nearest-even) is the only lossy direction.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Dtype {
    /// IEEE-754 binary32 — the compute precision; storage is lossless.
    F32,
    /// bfloat16 storage: round-to-nearest-even on write, exact widen on
    /// read. Halves parameter/state/exchange bytes at ~2-3 significant
    /// decimal digits.
    Bf16,
}

impl Dtype {
    /// Storage bytes per element.
    pub fn bytes(self) -> usize {
        match self {
            Dtype::F32 => 4,
            Dtype::Bf16 => 2,
        }
    }

    /// Canonical spelling (CLI flags, bench metric suffixes).
    pub fn name(self) -> &'static str {
        match self {
            Dtype::F32 => "f32",
            Dtype::Bf16 => "bf16",
        }
    }

    /// Parse the canonical spelling (accepts `bfloat16` for `bf16`).
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" | "float32" => Dtype::F32,
            "bf16" | "bfloat16" => Dtype::Bf16,
            other => bail!("unknown dtype {other:?} (f32|bf16)"),
        })
    }
}

/// Round an f32 to bfloat16 bits, round-to-nearest-even: the write half
/// of the storage conversion. NaNs are quieted with their sign kept;
/// values beyond bf16 range round to the infinities, exactly as hardware
/// bf16 conversion units behave.
pub fn f32_to_bf16(x: f32) -> u16 {
    let bits = x.to_bits();
    if x.is_nan() {
        // Force a payload bit that survives the truncation so the result
        // stays a (quiet) NaN rather than collapsing to an infinity.
        return ((bits >> 16) as u16) | 0x0040;
    }
    let lsb = (bits >> 16) & 1;
    (bits.wrapping_add(0x7FFF + lsb) >> 16) as u16
}

/// Widen bfloat16 bits back to f32 — exact, since every bf16 value is
/// representable in f32 (the read half of the storage conversion).
pub fn bf16_to_f32(bits: u16) -> f32 {
    f32::from_bits((bits as u32) << 16)
}

/// Round `x` through bf16 storage and back: the value a bf16-stored blob
/// actually holds after a write of `x`.
pub fn snap_bf16(x: f32) -> f32 {
    bf16_to_f32(f32_to_bf16(x))
}

/// Widen a bf16 slice into `dst`, clearing it first (capacity is reused
/// across calls — the scratch-buffer pattern the flat engine relies on).
pub fn widen_bf16_into(src: &[u16], dst: &mut Vec<f32>) {
    dst.clear();
    dst.extend(src.iter().map(|&b| bf16_to_f32(b)));
}

/// Round an f32 slice into equally-sized bf16 storage (the in-place
/// write-back kernel; `dst.len()` must equal `src.len()`).
pub fn round_bf16_slice(src: &[f32], dst: &mut [u16]) {
    debug_assert_eq!(src.len(), dst.len());
    for (d, &s) in dst.iter_mut().zip(src) {
        *d = f32_to_bf16(s);
    }
}

/// Sum of squares over a raw slice — THE parity-critical reduction. Single
/// definition: [`Tensor`], [`TensorView`] and the optimizer slice kernels
/// (`optim::update`) all delegate here so the implementations cannot drift.
pub fn sum_sq(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum()
}

/// Root-mean-square over a raw slice (paper footnote 1); 0 for empty input.
pub fn rms(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (sum_sq(xs) / xs.len() as f32).sqrt()
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|i| f(i)).collect(),
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    // --- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// In-place a += b * s (the optimizer hot path — no allocation).
    pub fn axpy(&mut self, s: f32, b: &Tensor) {
        assert_eq!(self.shape, b.shape);
        for (x, &y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    // --- reductions --------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn sum_sq(&self) -> f32 {
        sum_sq(&self.data)
    }

    /// Root-mean-square over all elements (paper footnote 1).
    pub fn rms(&self) -> f32 {
        rms(&self.data)
    }

    /// Row sums of a 2-D tensor -> (m,).
    pub fn row_sums(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m];
        for i in 0..m {
            out[i] = self.data[i * n..(i + 1) * n].iter().sum();
        }
        Tensor { shape: vec![m], data: out }
    }

    /// Column sums of a 2-D tensor -> (n,).
    pub fn col_sums(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; n];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += x;
            }
        }
        Tensor { shape: vec![n], data: out }
    }

    // --- linear algebra (small matrices only) ------------------------------

    /// Naive (i, k, j)-ordered matmul; adequate for the host-side sizes.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert_eq!(other.ndim(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Outer product of two vectors -> (m, n).
    pub fn outer(a: &Tensor, b: &Tensor) -> Tensor {
        assert_eq!(a.ndim(), 1);
        assert_eq!(b.ndim(), 1);
        let (m, n) = (a.len(), b.len());
        let mut out = Vec::with_capacity(m * n);
        for &x in &a.data {
            for &y in &b.data {
                out.push(x * y);
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    // --- borrowed views -----------------------------------------------------

    /// Zero-copy read-only view of this tensor.
    pub fn view(&self) -> TensorView<'_> {
        TensorView { shape: &self.shape, data: &self.data }
    }
}

/// Borrowed, shape-carrying, read-only view over an `f32` slice — the
/// zero-copy counterpart of [`Tensor`] used by the flat optimizer engine
/// and blob segment accessors. Neither constructor copies or allocates.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    shape: &'a [usize],
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn from_slice(shape: &'a [usize], data: &'a [f32]) -> Result<TensorView<'a>> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorView { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn sum_sq(&self) -> f32 {
        sum_sq(self.data)
    }

    /// Root-mean-square over all elements (paper footnote 1) — same
    /// arithmetic as [`Tensor::rms`] (both delegate to [`rms`]).
    pub fn rms(&self) -> f32 {
        rms(self.data)
    }

    /// Materialize an owned [`Tensor`] (the one copying escape hatch).
    pub fn to_tensor(&self) -> Tensor {
        Tensor { shape: self.shape.to_vec(), data: self.data.to_vec() }
    }
}

/// Borrowed mutable view — shape-aware in-place access to a blob segment
/// without constructing a [`Tensor`]. The flat engine's inner loops work
/// on raw `&mut [f32]` slices directly; this type is the shaped accessor
/// for coordinator-level callers ([`crate::runtime::HostBlob`]'s
/// `segment_view_mut`) and the substrate the async-rank work builds on.
#[derive(Debug)]
pub struct TensorViewMut<'a> {
    shape: &'a [usize],
    data: &'a mut [f32],
}

impl<'a> TensorViewMut<'a> {
    pub fn from_slice_mut(
        shape: &'a [usize],
        data: &'a mut [f32],
    ) -> Result<TensorViewMut<'a>> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorViewMut { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        self.data
    }

    pub fn as_view(&self) -> TensorView<'_> {
        TensorView { shape: self.shape, data: self.data }
    }

    /// In-place self += b * s over the raw data (the optimizer hot path).
    pub fn axpy(&mut self, s: f32, b: &[f32]) {
        assert_eq!(self.data.len(), b.len());
        for (x, &y) in self.data.iter_mut().zip(b) {
            *x += s * y;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn elementwise() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::full(&[2, 2], 2.0);
        assert_eq!(a.add(&b).data(), &[3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.mul(&b).data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.scale(0.5).data(), &[0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn axpy_matches_scale_add() {
        let mut a = Tensor::new(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        let expect = a.add(&b.scale(0.1));
        a.axpy(0.1, &b);
        assert_eq!(a, expect);
    }

    #[test]
    fn reductions() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(a.sum(), 21.0);
        assert_eq!(a.row_sums().data(), &[6.0, 15.0]);
        assert_eq!(a.col_sums().data(), &[5.0, 7.0, 9.0]);
        let r = a.rms();
        assert!((r - (91.0f32 / 6.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn matmul_hand_values() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        assert_eq!(a.matmul(&b).data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn outer_product() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[3], vec![3.0, 4.0, 5.0]).unwrap();
        let o = Tensor::outer(&a, &b);
        assert_eq!(o.shape(), &[2, 3]);
        assert_eq!(o.data(), &[3., 4., 5., 6., 8., 10.]);
    }

    #[test]
    fn rms_of_zeros_is_zero() {
        assert_eq!(Tensor::zeros(&[4]).rms(), 0.0);
    }

    #[test]
    fn views_are_zero_copy_and_shape_checked() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let v = t.view();
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.sum(), t.sum());
        assert!((v.rms() - t.rms()).abs() < 1e-7);
        let shape = [4usize];
        assert!(TensorView::from_slice(&shape, &[0.0; 3]).is_err());
        let back = TensorView::from_slice(&shape, &[1.0; 4]).unwrap();
        assert_eq!(back.to_tensor().shape(), &[4]);
    }

    #[test]
    fn mut_view_updates_in_place() {
        let mut buf = vec![1.0f32; 6];
        let shape = [2usize, 3];
        let mut v = TensorViewMut::from_slice_mut(&shape, &mut buf).unwrap();
        v.axpy(0.5, &[2.0; 6]);
        assert_eq!(v.as_view().sum(), 12.0);
        drop(v);
        assert!(buf.iter().all(|&x| (x - 2.0).abs() < 1e-7));
    }

    #[test]
    fn dtype_basics() {
        assert_eq!(Dtype::F32.bytes(), 4);
        assert_eq!(Dtype::Bf16.bytes(), 2);
        assert_eq!(Dtype::parse("f32").unwrap(), Dtype::F32);
        assert_eq!(Dtype::parse("bf16").unwrap(), Dtype::Bf16);
        assert_eq!(Dtype::parse("bfloat16").unwrap(), Dtype::Bf16);
        assert!(Dtype::parse("f16").is_err());
        assert_eq!(Dtype::parse(Dtype::Bf16.name()).unwrap(), Dtype::Bf16);
    }

    #[test]
    fn bf16_round_trip_is_identity_on_representable_values() {
        // round(widen(bits)) == bits for every value that IS a bf16
        // (sweep all finite bf16 bit patterns): widening is exact and
        // rounding a representable value must not move it.
        for hi in 0..=0xFFFFu32 {
            let bits = hi as u16;
            let x = bf16_to_f32(bits);
            if x.is_nan() {
                assert!(bf16_to_f32(f32_to_bf16(x)).is_nan());
                continue;
            }
            assert_eq!(f32_to_bf16(x), bits, "bits {bits:#06x}");
        }
    }

    #[test]
    fn bf16_rounds_to_nearest_even() {
        // 1 + 2^-8 sits exactly halfway between bf16(1.0) and the next
        // value up; RNE resolves the tie toward the even mantissa.
        assert_eq!(f32_to_bf16(1.0 + 2.0f32.powi(-8)), 0x3F80); // -> 1.0
        assert_eq!(f32_to_bf16(1.0 + 3.0 * 2.0f32.powi(-8)), 0x3F82);
        // Non-ties go to the nearest value.
        assert_eq!(snap_bf16(1.001), 1.0);
        assert!((snap_bf16(1.006) - 1.0078125).abs() < 1e-7);
        // Sign, zero and infinities survive.
        assert_eq!(snap_bf16(-0.0).to_bits(), (-0.0f32).to_bits());
        assert_eq!(snap_bf16(f32::INFINITY), f32::INFINITY);
        assert_eq!(snap_bf16(f32::NEG_INFINITY), f32::NEG_INFINITY);
        // Overflow past bf16's max finite value rounds to infinity.
        assert_eq!(snap_bf16(3.4e38), f32::INFINITY);
        // NaN stays NaN with its sign.
        assert!(snap_bf16(f32::NAN).is_nan());
        assert!(snap_bf16(-f32::NAN).is_sign_negative());
    }

    #[test]
    fn bf16_error_bound_and_monotonicity() {
        // |x - snap(x)| <= |x| * 2^-8 for normal values (half a bf16 ULP),
        // and rounding is monotone: x <= y => snap(x) <= snap(y).
        let mut prev_x = f32::NEG_INFINITY;
        let mut prev_s = f32::NEG_INFINITY;
        for i in -2000i32..2000 {
            let x = (i as f32) * 0.37 + (i as f32).powi(2) * 1.3e-4;
            let s = snap_bf16(x);
            assert!(
                (x - s).abs() <= x.abs() / 256.0 + f32::MIN_POSITIVE,
                "x {x} -> {s}"
            );
            if x >= prev_x {
                assert!(s >= prev_s, "monotonicity broke at {prev_x}->{x}");
                prev_x = x;
                prev_s = s;
            }
        }
    }

    #[test]
    fn bf16_slice_kernels_match_scalar_conversion() {
        let src: Vec<f32> =
            (0..257).map(|i| (i as f32 - 128.0) * 0.013).collect();
        let mut bits = vec![0u16; src.len()];
        round_bf16_slice(&src, &mut bits);
        let mut widened = Vec::new();
        widen_bf16_into(&bits, &mut widened);
        assert_eq!(widened.len(), src.len());
        for ((&x, &b), &w) in src.iter().zip(&bits).zip(&widened) {
            assert_eq!(b, f32_to_bf16(x));
            assert_eq!(w.to_bits(), snap_bf16(x).to_bits());
        }
        // The widen buffer is cleared, not appended to.
        widen_bf16_into(&bits[..3], &mut widened);
        assert_eq!(widened.len(), 3);
    }
}
