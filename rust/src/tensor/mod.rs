//! Minimal row-major f32 tensor.
//!
//! Used by the Rust-native optimizer mirrors ([`crate::optim`]), the toy-2D
//! experiment, the synthetic benchmark scoring and the property tests.
//! All heavy model compute runs inside the AOT XLA programs; this type only
//! needs the handful of operations the coordinator does on the host.

use anyhow::{bail, Result};

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|i| f(i)).collect(),
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    // --- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// In-place a += b * s (the optimizer hot path — no allocation).
    pub fn axpy(&mut self, s: f32, b: &Tensor) {
        assert_eq!(self.shape, b.shape);
        for (x, &y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    // --- reductions --------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn sum_sq(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum()
    }

    /// Root-mean-square over all elements (paper footnote 1).
    pub fn rms(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        (self.sum_sq() / self.data.len() as f32).sqrt()
    }

    /// Row sums of a 2-D tensor -> (m,).
    pub fn row_sums(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m];
        for i in 0..m {
            out[i] = self.data[i * n..(i + 1) * n].iter().sum();
        }
        Tensor { shape: vec![m], data: out }
    }

    /// Column sums of a 2-D tensor -> (n,).
    pub fn col_sums(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; n];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += x;
            }
        }
        Tensor { shape: vec![n], data: out }
    }

    // --- linear algebra (small matrices only) ------------------------------

    /// Naive (i, k, j)-ordered matmul; adequate for the host-side sizes.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert_eq!(other.ndim(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Outer product of two vectors -> (m, n).
    pub fn outer(a: &Tensor, b: &Tensor) -> Tensor {
        assert_eq!(a.ndim(), 1);
        assert_eq!(b.ndim(), 1);
        let (m, n) = (a.len(), b.len());
        let mut out = Vec::with_capacity(m * n);
        for &x in &a.data {
            for &y in &b.data {
                out.push(x * y);
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn elementwise() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::full(&[2, 2], 2.0);
        assert_eq!(a.add(&b).data(), &[3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.mul(&b).data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.scale(0.5).data(), &[0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn axpy_matches_scale_add() {
        let mut a = Tensor::new(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        let expect = a.add(&b.scale(0.1));
        a.axpy(0.1, &b);
        assert_eq!(a, expect);
    }

    #[test]
    fn reductions() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(a.sum(), 21.0);
        assert_eq!(a.row_sums().data(), &[6.0, 15.0]);
        assert_eq!(a.col_sums().data(), &[5.0, 7.0, 9.0]);
        let r = a.rms();
        assert!((r - (91.0f32 / 6.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn matmul_hand_values() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        assert_eq!(a.matmul(&b).data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn outer_product() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[3], vec![3.0, 4.0, 5.0]).unwrap();
        let o = Tensor::outer(&a, &b);
        assert_eq!(o.shape(), &[2, 3]);
        assert_eq!(o.data(), &[3., 4., 5., 6., 8., 10.]);
    }

    #[test]
    fn rms_of_zeros_is_zero() {
        assert_eq!(Tensor::zeros(&[4]).rms(), 0.0);
    }
}
