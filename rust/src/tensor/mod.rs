//! Minimal row-major f32 tensor.
//!
//! Used by the Rust-native optimizer mirrors ([`crate::optim`]), the toy-2D
//! experiment, the synthetic benchmark scoring and the property tests.
//! All heavy model compute runs inside the AOT XLA programs; this type only
//! needs the handful of operations the coordinator does on the host.

use anyhow::{bail, Result};

/// Sum of squares over a raw slice — THE parity-critical reduction. Single
/// definition: [`Tensor`], [`TensorView`] and the optimizer slice kernels
/// (`optim::update`) all delegate here so the implementations cannot drift.
pub fn sum_sq(xs: &[f32]) -> f32 {
    xs.iter().map(|x| x * x).sum()
}

/// Root-mean-square over a raw slice (paper footnote 1); 0 for empty input.
pub fn rms(xs: &[f32]) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    (sum_sq(xs) / xs.len() as f32).sqrt()
}

#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: &[usize], data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape: shape.to_vec(), data })
    }

    pub fn zeros(shape: &[usize]) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![0.0; shape.iter().product()],
        }
    }

    pub fn full(shape: &[usize], v: f32) -> Tensor {
        Tensor {
            shape: shape.to_vec(),
            data: vec![v; shape.iter().product()],
        }
    }

    pub fn from_fn(shape: &[usize], mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n = shape.iter().product();
        Tensor {
            shape: shape.to_vec(),
            data: (0..n).map(|i| f(i)).collect(),
        }
    }

    pub fn scalar(v: f32) -> Tensor {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn at2(&self, i: usize, j: usize) -> f32 {
        debug_assert_eq!(self.ndim(), 2);
        self.data[i * self.shape[1] + j]
    }

    // --- elementwise -------------------------------------------------------

    pub fn map(&self, f: impl Fn(f32) -> f32) -> Tensor {
        Tensor {
            shape: self.shape.clone(),
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    pub fn zip(&self, other: &Tensor, f: impl Fn(f32, f32) -> f32) -> Tensor {
        assert_eq!(self.shape, other.shape, "shape mismatch");
        Tensor {
            shape: self.shape.clone(),
            data: self
                .data
                .iter()
                .zip(&other.data)
                .map(|(&a, &b)| f(a, b))
                .collect(),
        }
    }

    pub fn add(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a + b)
    }

    pub fn sub(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a - b)
    }

    pub fn mul(&self, other: &Tensor) -> Tensor {
        self.zip(other, |a, b| a * b)
    }

    pub fn scale(&self, s: f32) -> Tensor {
        self.map(|x| x * s)
    }

    /// In-place a += b * s (the optimizer hot path — no allocation).
    pub fn axpy(&mut self, s: f32, b: &Tensor) {
        assert_eq!(self.shape, b.shape);
        for (x, &y) in self.data.iter_mut().zip(&b.data) {
            *x += s * y;
        }
    }

    // --- reductions --------------------------------------------------------

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn sum_sq(&self) -> f32 {
        sum_sq(&self.data)
    }

    /// Root-mean-square over all elements (paper footnote 1).
    pub fn rms(&self) -> f32 {
        rms(&self.data)
    }

    /// Row sums of a 2-D tensor -> (m,).
    pub fn row_sums(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; m];
        for i in 0..m {
            out[i] = self.data[i * n..(i + 1) * n].iter().sum();
        }
        Tensor { shape: vec![m], data: out }
    }

    /// Column sums of a 2-D tensor -> (n,).
    pub fn col_sums(&self) -> Tensor {
        assert_eq!(self.ndim(), 2);
        let (m, n) = (self.shape[0], self.shape[1]);
        let mut out = vec![0.0f32; n];
        for i in 0..m {
            let row = &self.data[i * n..(i + 1) * n];
            for (o, &x) in out.iter_mut().zip(row) {
                *o += x;
            }
        }
        Tensor { shape: vec![n], data: out }
    }

    // --- linear algebra (small matrices only) ------------------------------

    /// Naive (i, k, j)-ordered matmul; adequate for the host-side sizes.
    pub fn matmul(&self, other: &Tensor) -> Tensor {
        assert_eq!(self.ndim(), 2);
        assert_eq!(other.ndim(), 2);
        let (m, k) = (self.shape[0], self.shape[1]);
        let (k2, n) = (other.shape[0], other.shape[1]);
        assert_eq!(k, k2, "matmul inner dims {k} vs {k2}");
        let mut out = vec![0.0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let a = self.data[i * k + kk];
                if a == 0.0 {
                    continue;
                }
                let brow = &other.data[kk * n..(kk + 1) * n];
                let orow = &mut out[i * n..(i + 1) * n];
                for (o, &b) in orow.iter_mut().zip(brow) {
                    *o += a * b;
                }
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    /// Outer product of two vectors -> (m, n).
    pub fn outer(a: &Tensor, b: &Tensor) -> Tensor {
        assert_eq!(a.ndim(), 1);
        assert_eq!(b.ndim(), 1);
        let (m, n) = (a.len(), b.len());
        let mut out = Vec::with_capacity(m * n);
        for &x in &a.data {
            for &y in &b.data {
                out.push(x * y);
            }
        }
        Tensor { shape: vec![m, n], data: out }
    }

    // --- borrowed views -----------------------------------------------------

    /// Zero-copy read-only view of this tensor.
    pub fn view(&self) -> TensorView<'_> {
        TensorView { shape: &self.shape, data: &self.data }
    }
}

/// Borrowed, shape-carrying, read-only view over an `f32` slice — the
/// zero-copy counterpart of [`Tensor`] used by the flat optimizer engine
/// and blob segment accessors. Neither constructor copies or allocates.
#[derive(Debug, Clone, Copy)]
pub struct TensorView<'a> {
    shape: &'a [usize],
    data: &'a [f32],
}

impl<'a> TensorView<'a> {
    pub fn from_slice(shape: &'a [usize], data: &'a [f32]) -> Result<TensorView<'a>> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorView { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &'a [f32] {
        self.data
    }

    pub fn sum(&self) -> f32 {
        self.data.iter().sum()
    }

    pub fn sum_sq(&self) -> f32 {
        sum_sq(self.data)
    }

    /// Root-mean-square over all elements (paper footnote 1) — same
    /// arithmetic as [`Tensor::rms`] (both delegate to [`rms`]).
    pub fn rms(&self) -> f32 {
        rms(self.data)
    }

    /// Materialize an owned [`Tensor`] (the one copying escape hatch).
    pub fn to_tensor(&self) -> Tensor {
        Tensor { shape: self.shape.to_vec(), data: self.data.to_vec() }
    }
}

/// Borrowed mutable view — shape-aware in-place access to a blob segment
/// without constructing a [`Tensor`]. The flat engine's inner loops work
/// on raw `&mut [f32]` slices directly; this type is the shaped accessor
/// for coordinator-level callers ([`crate::runtime::HostBlob`]'s
/// `segment_view_mut`) and the substrate the async-rank work builds on.
#[derive(Debug)]
pub struct TensorViewMut<'a> {
    shape: &'a [usize],
    data: &'a mut [f32],
}

impl<'a> TensorViewMut<'a> {
    pub fn from_slice_mut(
        shape: &'a [usize],
        data: &'a mut [f32],
    ) -> Result<TensorViewMut<'a>> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elems, got {}", shape, n, data.len());
        }
        Ok(TensorViewMut { shape, data })
    }

    pub fn shape(&self) -> &[usize] {
        self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        self.data
    }

    pub fn as_view(&self) -> TensorView<'_> {
        TensorView { shape: self.shape, data: self.data }
    }

    /// In-place self += b * s over the raw data (the optimizer hot path).
    pub fn axpy(&mut self, s: f32, b: &[f32]) {
        assert_eq!(self.data.len(), b.len());
        for (x, &y) in self.data.iter_mut().zip(b) {
            *x += s * y;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_checks_shape() {
        assert!(Tensor::new(&[2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(&[2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn elementwise() {
        let a = Tensor::new(&[2, 2], vec![1.0, 2.0, 3.0, 4.0]).unwrap();
        let b = Tensor::full(&[2, 2], 2.0);
        assert_eq!(a.add(&b).data(), &[3.0, 4.0, 5.0, 6.0]);
        assert_eq!(a.mul(&b).data(), &[2.0, 4.0, 6.0, 8.0]);
        assert_eq!(a.scale(0.5).data(), &[0.5, 1.0, 1.5, 2.0]);
    }

    #[test]
    fn axpy_matches_scale_add() {
        let mut a = Tensor::new(&[3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = Tensor::new(&[3], vec![10.0, 20.0, 30.0]).unwrap();
        let expect = a.add(&b.scale(0.1));
        a.axpy(0.1, &b);
        assert_eq!(a, expect);
    }

    #[test]
    fn reductions() {
        let a = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        assert_eq!(a.sum(), 21.0);
        assert_eq!(a.row_sums().data(), &[6.0, 15.0]);
        assert_eq!(a.col_sums().data(), &[5.0, 7.0, 9.0]);
        let r = a.rms();
        assert!((r - (91.0f32 / 6.0).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn matmul_hand_values() {
        let a = Tensor::new(&[2, 2], vec![1., 2., 3., 4.]).unwrap();
        let b = Tensor::new(&[2, 2], vec![5., 6., 7., 8.]).unwrap();
        assert_eq!(a.matmul(&b).data(), &[19., 22., 43., 50.]);
    }

    #[test]
    fn outer_product() {
        let a = Tensor::new(&[2], vec![1.0, 2.0]).unwrap();
        let b = Tensor::new(&[3], vec![3.0, 4.0, 5.0]).unwrap();
        let o = Tensor::outer(&a, &b);
        assert_eq!(o.shape(), &[2, 3]);
        assert_eq!(o.data(), &[3., 4., 5., 6., 8., 10.]);
    }

    #[test]
    fn rms_of_zeros_is_zero() {
        assert_eq!(Tensor::zeros(&[4]).rms(), 0.0);
    }

    #[test]
    fn views_are_zero_copy_and_shape_checked() {
        let t = Tensor::new(&[2, 3], vec![1., 2., 3., 4., 5., 6.]).unwrap();
        let v = t.view();
        assert_eq!(v.shape(), &[2, 3]);
        assert_eq!(v.sum(), t.sum());
        assert!((v.rms() - t.rms()).abs() < 1e-7);
        let shape = [4usize];
        assert!(TensorView::from_slice(&shape, &[0.0; 3]).is_err());
        let back = TensorView::from_slice(&shape, &[1.0; 4]).unwrap();
        assert_eq!(back.to_tensor().shape(), &[4]);
    }

    #[test]
    fn mut_view_updates_in_place() {
        let mut buf = vec![1.0f32; 6];
        let shape = [2usize, 3];
        let mut v = TensorViewMut::from_slice_mut(&shape, &mut buf).unwrap();
        v.axpy(0.5, &[2.0; 6]);
        assert_eq!(v.as_view().sum(), 12.0);
        drop(v);
        assert!(buf.iter().all(|&x| (x - 2.0).abs() < 1e-7));
    }
}
