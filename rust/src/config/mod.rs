//! Run configuration + the paper's hyper-parameter presets
//! (Tables 3, 6 and 7).

use anyhow::Result;

use crate::util::cli::Args;

/// Training phase — the three experiment families of §4.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Phase {
    /// §4.1: instruction tuning on Alpaca-style data (Table 3 LRs).
    Instruct,
    /// §4.2: further pre-training on a new domain (Table 6 LRs).
    FurtherPretrain,
    /// §4.3: from-scratch pre-training (Table 7 LRs).
    Scratch,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Instruct => "instruct",
            Phase::FurtherPretrain => "further_pretrain",
            Phase::Scratch => "scratch",
        }
    }
}

/// Paper learning rates per optimizer and phase.
///
/// Table 3 (instruction tuning): LoRA 3e-4, AdamW 2e-5, LOMO 1e-2,
/// AdaLomo 5e-4. Table 6 (further pre-training): AdamW 1e-5, AdaLomo 3e-1
/// (3e-1 is the *relative* step rho_t). Table 7 (scratch): SGD 1e-3,
/// Adafactor 1e-3, AdamW 2e-5, AdaLomo 1e-3.
pub fn paper_lr(opt: &str, phase: Phase) -> f32 {
    match (opt, phase) {
        ("lora", Phase::Instruct) => 3e-4,
        ("adamw", Phase::Instruct) => 2e-5,
        ("lomo", Phase::Instruct) | ("lomo_gnorm", Phase::Instruct) => 1e-2,
        ("adalomo", Phase::Instruct)
        | ("adalomo_gnorm", Phase::Instruct) => 5e-4,
        ("adafactor", Phase::Instruct) => 5e-4,

        ("adamw", Phase::FurtherPretrain) => 1e-5,
        ("adalomo", Phase::FurtherPretrain)
        | ("adalomo_gnorm", Phase::FurtherPretrain) => 3e-1,
        ("adafactor", Phase::FurtherPretrain) => 3e-1,
        ("lomo", Phase::FurtherPretrain)
        | ("lomo_gnorm", Phase::FurtherPretrain) => 1e-2,
        ("sgd", Phase::FurtherPretrain) => 1e-3,

        ("sgd", Phase::Scratch) => 1e-3,
        ("adafactor", Phase::Scratch) => 1e-3,
        ("adamw", Phase::Scratch) => 2e-5,
        ("adalomo", Phase::Scratch) => 1e-3,

        // Ablation arms (Fig. 1): Adam-family defaults.
        ("sgd_momentum", _) => 1e-3,
        ("sgd_variance", _) => 5e-4,
        ("adam", _) => 2e-5,
        _ => 1e-3,
    }
}

/// The paper's scaled-down LRs translate directly because grouped update
/// normalization makes AdaLomo's step *relative*; for the tiny-model
/// experiments the absolute-LR optimizers (SGD/AdamW/LOMO) need a modest
/// upward rescale (small models tolerate larger steps). One shared factor
/// keeps comparisons fair; benches document it.
pub const SMALL_MODEL_LR_SCALE: f32 = 10.0;

/// Weight decay for AdamW in the scratch phase (paper Appendix E).
pub const ADAMW_SCRATCH_WD: f32 = 0.01;

/// Warmup fraction (all phases: 0.03 * total steps, Tables 3/6).
pub const WARMUP_FRAC: f32 = 0.03;

#[derive(Debug, Clone)]
pub struct RunConfig {
    pub preset: String,
    /// Entry variant: optimizer name, "lora", or "<opt>_gnorm".
    pub opt: String,
    pub phase: Phase,
    pub lr: f32,
    pub wd: f32,
    pub clip: f32,
    pub steps: usize,
    pub warmup_steps: usize,
    pub seed: u64,
    pub domain: String,
    pub eval_every: usize,
    pub log_every: usize,
    pub out_dir: String,
}

impl RunConfig {
    pub fn new(preset: &str, opt: &str, phase: Phase, steps: usize) -> Self {
        let lr = paper_lr(opt, phase);
        let wd = if opt == "adamw" && phase == Phase::Scratch {
            ADAMW_SCRATCH_WD
        } else {
            0.0
        };
        RunConfig {
            preset: preset.to_string(),
            opt: opt.to_string(),
            phase,
            lr,
            wd,
            clip: 1.0,
            steps,
            warmup_steps: ((steps as f32 * WARMUP_FRAC) as usize).max(1),
            seed: 42,
            domain: "c4".to_string(),
            eval_every: 100,
            log_every: 10,
            out_dir: "runs".to_string(),
        }
    }

    /// Apply common CLI overrides (--lr, --steps, --seed, --domain, ...).
    pub fn override_from(mut self, args: &Args) -> Result<Self> {
        self.lr = args.f32_or("lr", self.lr)?;
        self.wd = args.f32_or("wd", self.wd)?;
        self.clip = args.f32_or("clip", self.clip)?;
        self.steps = args.usize_or("steps", self.steps)?;
        self.warmup_steps = args.usize_or(
            "warmup",
            ((self.steps as f32 * WARMUP_FRAC) as usize).max(1),
        )?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.domain = args.str_or("domain", &self.domain);
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.log_every = args.usize_or("log-every", self.log_every)?;
        self.out_dir = args.str_or("out", &self.out_dir);
        Ok(self)
    }

    pub fn run_name(&self) -> String {
        format!(
            "{}_{}_{}_{}",
            self.phase.name(),
            self.preset,
            self.opt,
            self.domain
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_lrs_match_tables() {
        assert_eq!(paper_lr("adamw", Phase::Instruct), 2e-5);
        assert_eq!(paper_lr("lomo", Phase::Instruct), 1e-2);
        assert_eq!(paper_lr("adalomo", Phase::Instruct), 5e-4);
        assert_eq!(paper_lr("lora", Phase::Instruct), 3e-4);
        assert_eq!(paper_lr("adalomo", Phase::FurtherPretrain), 3e-1);
        assert_eq!(paper_lr("adamw", Phase::Scratch), 2e-5);
        assert_eq!(paper_lr("sgd", Phase::Scratch), 1e-3);
    }

    #[test]
    fn warmup_is_3pct() {
        let cfg = RunConfig::new("tiny", "adalomo", Phase::Scratch, 1000);
        assert_eq!(cfg.warmup_steps, 30);
    }

    #[test]
    fn overrides_apply() {
        let args = Args::parse(
            ["--lr", "0.5", "--steps", "7", "--domain", "chinese"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let cfg = RunConfig::new("nano", "adalomo", Phase::Instruct, 100)
            .override_from(&args)
            .unwrap();
        assert_eq!(cfg.lr, 0.5);
        assert_eq!(cfg.steps, 7);
        assert_eq!(cfg.domain, "chinese");
    }

    #[test]
    fn scratch_adamw_gets_weight_decay() {
        let cfg = RunConfig::new("tiny", "adamw", Phase::Scratch, 10);
        assert_eq!(cfg.wd, ADAMW_SCRATCH_WD);
        let cfg2 = RunConfig::new("tiny", "adamw", Phase::Instruct, 10);
        assert_eq!(cfg2.wd, 0.0);
    }
}
