//! The update rules themselves — line-for-line mirrors of
//! `python/compile/kernels/ref.py` (see that file for paper equation
//! references). Kept free-standing so property tests can exercise them
//! without constructing [`super::ParamOpt`].

use crate::tensor::Tensor;

use super::Hyper;

/// Statistics produced by grouped update normalization — exposed so tests
/// can assert the paper's invariants (RMS bound, scale positivity).
#[derive(Debug, Clone, Copy)]
pub struct GroupedNormStats {
    pub rms_u: f32,
    pub rms_theta: f32,
    pub scale: f32,
}

/// Grouped update normalization (Algorithm 1 line 11), in place:
/// u <- u / max(1, RMS(u)) * max(eps_rms, RMS(theta)).
pub fn grouped_normalize(u: &mut Tensor, theta: &Tensor, eps_rms: f32) -> GroupedNormStats {
    let rms_u = u.rms();
    let rms_theta = theta.rms();
    let scale = eps_rms.max(rms_theta) / 1.0f32.max(rms_u);
    for x in u.data_mut() {
        *x *= scale;
    }
    GroupedNormStats { rms_u, rms_theta, scale }
}

/// theta <- theta - lr * g  (SGD; also the LOMO rule, paper Eq. 1).
pub fn sgd(theta: &mut Tensor, g: &Tensor, lr: f32) {
    theta.axpy(-lr, g);
}

/// SGD + first moment only (paper Eq. 3).
pub fn sgd_momentum(theta: &mut Tensor, g: &Tensor, m: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    let bias = 1.0 - h.beta1.powi(t as i32);
    for ((th, &gi), mi) in theta
        .data_mut()
        .iter_mut()
        .zip(g.data())
        .zip(m.data_mut())
    {
        *mi = h.beta1 * *mi + (1.0 - h.beta1) * gi;
        *th -= lr * (*mi / bias);
    }
}

/// SGD + second moment only (paper Eq. 4).
pub fn sgd_variance(theta: &mut Tensor, g: &Tensor, v: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    let bias = 1.0 - h.beta2.powi(t as i32);
    for ((th, &gi), vi) in theta
        .data_mut()
        .iter_mut()
        .zip(g.data())
        .zip(v.data_mut())
    {
        *vi = h.beta2 * *vi + (1.0 - h.beta2) * gi * gi;
        *th -= lr * gi / ((*vi / bias).sqrt() + h.adam_eps);
    }
}

/// AdamW (paper Eq. 2 + decoupled weight decay).
pub fn adamw(
    theta: &mut Tensor,
    g: &Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    t: u64,
    lr: f32,
    wd: f32,
    h: Hyper,
) {
    let bias1 = 1.0 - h.beta1.powi(t as i32);
    let bias2 = 1.0 - h.beta2.powi(t as i32);
    let n = theta.len();
    let th = theta.data_mut();
    let gd = g.data();
    let md = m.data_mut();
    let vd = v.data_mut();
    for i in 0..n {
        md[i] = h.beta1 * md[i] + (1.0 - h.beta1) * gd[i];
        vd[i] = h.beta2 * vd[i] + (1.0 - h.beta2) * gd[i] * gd[i];
        let update = (md[i] / bias1) / ((vd[i] / bias2).sqrt() + h.adam_eps);
        th[i] -= lr * (update + wd * th[i]);
    }
}

/// Factored second-moment EMA shared by AdaLomo (fixed beta) and Adafactor
/// (time-dependent beta2_t): r/c <- beta * r/c + (1-beta) row/col sums of
/// g^2 (+ floor). Single pass over g, no temporaries (perf pass:
/// EXPERIMENTS.md §Perf L3 iteration 1 — the map+row_sums+col_sums version
/// allocated three m*n/m/n buffers and read g twice).
fn update_factors(g: &Tensor, r: &mut Tensor, c: &mut Tensor, beta: f32, floor: f32) {
    let (m, n) = (g.shape()[0], g.shape()[1]);
    let gd = g.data();
    let rd = r.data_mut();
    let cd = c.data_mut();
    let one_minus = 1.0 - beta;
    for ci in cd.iter_mut() {
        *ci *= beta;
    }
    for i in 0..m {
        let row = &gd[i * n..(i + 1) * n];
        let mut rsum = 0.0f32;
        for (ci, &x) in cd.iter_mut().zip(row) {
            let g2 = x * x + floor;
            rsum += g2;
            *ci += one_minus * g2;
        }
        rd[i] = beta * rd[i] + one_minus * rsum;
    }
}

/// Raw AdaLomo update u = g / sqrt(v_hat + eps) with v = r c / sum(r)
/// (paper Eq. 5 + Algorithm 1 lines 9-10). Row-hoisted: the per-row factor
/// and bias correction fold into one multiplier, so the inner loop is one
/// mul + sqrt + div per element (sqrt(a*b) = sqrt(a)*sqrt(b) does NOT hold
/// with the +eps guard, so the sqrt stays inside).
fn adalomo_raw_u(g: &Tensor, r: &Tensor, c: &Tensor, bias: f32, h: Hyper) -> Tensor {
    let (m, n) = (g.shape()[0], g.shape()[1]);
    let sum_r = r.sum().max(h.eps_div);
    let mut u = Tensor::zeros(&[m, n]);
    let gd = g.data();
    let cd = c.data();
    let ud = u.data_mut();
    let inv_bias_sum = 1.0 / (sum_r * bias);
    for i in 0..m {
        let row_scale = r.data()[i] * inv_bias_sum; // v_hat = row_scale * c[j]
        let grow = &gd[i * n..(i + 1) * n];
        let urow = &mut ud[i * n..(i + 1) * n];
        // Iterator zips elide bounds checks -> LLVM vectorizes the
        // mul/sqrt/div chain (perf pass iteration 2).
        if h.no_sqrt {
            for ((u, &gv), &cv) in
                urow.iter_mut().zip(grow).zip(cd.iter())
            {
                *u = gv / (row_scale * cv + h.eps_div);
            }
        } else {
            for ((u, &gv), &cv) in
                urow.iter_mut().zip(grow).zip(cd.iter())
            {
                *u = gv / (row_scale * cv + h.eps_div).sqrt();
            }
        }
    }
    u
}

/// AdaLomo step for a 2-D parameter (Algorithm 1 lines 7-12).
pub fn adalomo_2d(
    theta: &mut Tensor,
    g: &Tensor,
    r: &mut Tensor,
    c: &mut Tensor,
    t: u64,
    lr: f32,
    h: Hyper,
) {
    update_factors(g, r, c, h.adalomo_beta, 0.0);
    let bias = 1.0 - h.adalomo_beta.powi(t as i32);
    let mut u = adalomo_raw_u(g, r, c, bias, h);
    grouped_normalize(&mut u, theta, h.eps_rms);
    theta.axpy(-lr, &u);
}

/// AdaLomo step for vectors (full second moment).
pub fn adalomo_vec(theta: &mut Tensor, g: &Tensor, v: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    let bias = 1.0 - h.adalomo_beta.powi(t as i32);
    let mut u = Tensor::zeros(theta.shape());
    for ((ud, &gi), vi) in u
        .data_mut()
        .iter_mut()
        .zip(g.data())
        .zip(v.data_mut())
    {
        *vi = h.adalomo_beta * *vi + (1.0 - h.adalomo_beta) * gi * gi;
        let v_hat = *vi / bias;
        let denom = if h.no_sqrt {
            v_hat + h.eps_div
        } else {
            (v_hat + h.eps_div).sqrt()
        };
        *ud = gi / denom;
    }
    grouped_normalize(&mut u, theta, h.eps_rms);
    theta.axpy(-lr, &u);
}

/// Adafactor step for a 2-D parameter (momentum-less, update clipping,
/// relative step size; lr = rho_t).
pub fn adafactor_2d(
    theta: &mut Tensor,
    g: &Tensor,
    r: &mut Tensor,
    c: &mut Tensor,
    t: u64,
    lr: f32,
    h: Hyper,
) {
    let beta2t = 1.0 - (t as f32).powf(-h.adafactor_decay_pow);
    update_factors(g, r, c, beta2t, h.adafactor_eps1);
    let (m, n) = (g.shape()[0], g.shape()[1]);
    let sum_r = r.sum().max(h.adafactor_eps1);
    let mut u = Tensor::zeros(&[m, n]);
    let gd = g.data();
    let cd = c.data();
    let ud = u.data_mut();
    let inv_sum = 1.0 / sum_r;
    for i in 0..m {
        let row_scale = r.data()[i] * inv_sum;
        let grow = &gd[i * n..(i + 1) * n];
        let urow = &mut ud[i * n..(i + 1) * n];
        for ((u, &gv), &cv) in urow.iter_mut().zip(grow).zip(cd.iter()) {
            *u = gv / (row_scale * cv + h.adafactor_eps1).sqrt();
        }
    }
    let clip = 1.0f32.max(u.rms() / h.adafactor_clip_d);
    let alpha = h.adafactor_eps2.max(theta.rms()) * lr;
    theta.axpy(-alpha / clip, &u);
}

/// Adafactor step for vectors.
pub fn adafactor_vec(theta: &mut Tensor, g: &Tensor, v: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    let beta2t = 1.0 - (t as f32).powf(-h.adafactor_decay_pow);
    let mut u = Tensor::zeros(theta.shape());
    for ((ud, &gi), vi) in u
        .data_mut()
        .iter_mut()
        .zip(g.data())
        .zip(v.data_mut())
    {
        *vi = beta2t * *vi + (1.0 - beta2t) * (gi * gi + h.adafactor_eps1);
        *ud = gi / (*vi + h.adafactor_eps1).sqrt();
    }
    let clip = 1.0f32.max(u.rms() / h.adafactor_clip_d);
    let alpha = h.adafactor_eps2.max(theta.rms()) * lr;
    theta.axpy(-alpha / clip, &u);
}

/// Global gradient norm over a set of gradients — the quantity LOMO's
/// two-backward-pass gradient normalization needs (paper §2.1).
pub fn global_grad_norm(grads: &[&Tensor]) -> f32 {
    grads.iter().map(|g| g.sum_sq()).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> Hyper {
        Hyper::default()
    }

    #[test]
    fn grouped_norm_caps_rms() {
        // After normalization, RMS(u) <= max(eps, RMS(theta)).
        let mut u = Tensor::full(&[8, 8], 50.0);
        let theta = Tensor::full(&[8, 8], 0.2);
        let stats = grouped_normalize(&mut u, &theta, 1e-3);
        assert!((stats.rms_u - 50.0).abs() < 1e-4);
        assert!((u.rms() - 0.2).abs() < 1e-4);
    }

    #[test]
    fn grouped_norm_small_update_not_amplified_beyond_theta_rms() {
        // RMS(u) < 1 -> divide by 1, multiply by RMS(theta).
        let mut u = Tensor::full(&[4], 0.5);
        let theta = Tensor::full(&[4], 2.0);
        grouped_normalize(&mut u, &theta, 1e-3);
        assert!((u.rms() - 1.0).abs() < 1e-5); // 0.5 * 2.0
    }

    #[test]
    fn adalomo_first_step_unit_rms_direction() {
        // At t=1 with zero state, v_hat = g^2 exactly (bias correction
        // cancels (1-beta)), so u = sign(g)-ish with |u|=1 per element up
        // to the factored approximation; for a rank-1 |g| it is exact.
        let mut theta = Tensor::full(&[2, 2], 1.0);
        let g = Tensor::new(&[2, 2], vec![0.3, 0.3, 0.3, 0.3]).unwrap();
        let mut r = Tensor::zeros(&[2]);
        let mut c = Tensor::zeros(&[2]);
        adalomo_2d(&mut theta, &g, &mut r, &mut c, 1, 0.1, hyper());
        // u = 1 everywhere -> grouped norm: RMS(u)=1, RMS(theta)=1 -> scale 1
        // theta' = 1 - 0.1.
        for &x in theta.data() {
            assert!((x - 0.9).abs() < 1e-4, "{x}");
        }
        // Factors hold (1-beta) * rowsums of g^2.
        assert!((r.data()[0] - 0.15 * 2.0 * 0.09).abs() < 1e-6);
    }

    #[test]
    fn adalomo_factors_nonnegative() {
        let mut theta = Tensor::full(&[3, 4], 0.5);
        let g = Tensor::from_fn(&[3, 4], |i| (i as f32 - 5.0) * 0.01);
        let mut r = Tensor::zeros(&[3]);
        let mut c = Tensor::zeros(&[4]);
        for t in 1..20 {
            adalomo_2d(&mut theta, &g, &mut r, &mut c, t, 0.01, hyper());
        }
        assert!(r.data().iter().all(|&x| x >= 0.0));
        assert!(c.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn adamw_decay_pulls_to_zero() {
        let mut theta = Tensor::full(&[4], 1.0);
        let g = Tensor::zeros(&[4]);
        let mut m = Tensor::zeros(&[4]);
        let mut v = Tensor::zeros(&[4]);
        adamw(&mut theta, &g, &mut m, &mut v, 1, 0.1, 0.5, hyper());
        for &x in theta.data() {
            assert!((x - 0.95).abs() < 1e-6); // 1 - 0.1*0.5*1
        }
    }

    #[test]
    fn sgd_variance_normalizes_scale() {
        // With variance normalization, the first-step update size is
        // ~lr * sign(g) regardless of |g| (paper's argument for adaptivity).
        let h = hyper();
        for &mag in &[1e-4f32, 1.0, 1e4] {
            let mut theta = Tensor::zeros(&[1]);
            let g = Tensor::full(&[1], mag);
            let mut v = Tensor::zeros(&[1]);
            sgd_variance(&mut theta, &g, &mut v, 1, 0.1, h);
            assert!(
                (theta.data()[0] + 0.1).abs() < 1e-3,
                "mag {mag} -> {}",
                theta.data()[0]
            );
        }
    }

    #[test]
    fn global_norm() {
        let a = Tensor::full(&[4], 1.0);
        let b = Tensor::full(&[9], 1.0);
        let n = global_grad_norm(&[&a, &b]);
        assert!((n - (13.0f32).sqrt()).abs() < 1e-6);
    }
}
