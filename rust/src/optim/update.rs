//! The update rules themselves — line-for-line mirrors of
//! `python/compile/kernels/ref.py` (see that file for paper equation
//! references). Two API levels:
//!
//! * **slice kernels** (`*_slice`, plus the row/phase primitives
//!   [`factor_rows`], [`raw_u_rows`], [`adalomo_vec_raw`],
//!   [`adafactor_vec_raw`]) — operate on borrowed `&[f32]`/`&mut [f32]`
//!   segment views with zero allocation; this is what the flat-blob engine
//!   ([`super::flat`]) dispatches to;
//! * **[`Tensor`] wrappers** with the original signatures, used by
//!   [`super::ParamOpt`], the toy-2D experiments and the property tests.
//!   The factored wrappers still allocate one `u` temporary per call; the
//!   flat engine instead passes a persistent per-worker scratch buffer.
//!
//! This file (with [`super::flat`]) is a blessed float-kernel file under
//! the `analyze` determinism rule (docs/ANALYSIS.md): transcendentals and
//! `f32` reductions are allowed *here*, in a fixed and tested evaluation
//! order, and flagged everywhere else in the watched tree — bitwise
//! parity across ExecPlan cells depends on that order never forking.
//!
//! Bias corrections use `powf(t as f32)` rather than `powi(t as i32)`:
//! the latter wraps for steps beyond `i32::MAX` and produces a garbage
//! (possibly negative) correction; `powf` saturates cleanly to 0 for
//! beta < 1 (see `bias_correction_survives_huge_t`).

use crate::tensor::Tensor;

use super::Hyper;

/// Statistics produced by grouped update normalization — exposed so tests
/// can assert the paper's invariants (RMS bound, scale positivity).
#[derive(Debug, Clone, Copy)]
pub struct GroupedNormStats {
    pub rms_u: f32,
    pub rms_theta: f32,
    pub scale: f32,
}

/// Overflow-safe `1 - beta^t`. `t` is the 1-based u64 step counter; the
/// old `beta.powi(t as i32)` form wrapped negative past `i32::MAX` steps.
pub fn bias_correction(beta: f32, t: u64) -> f32 {
    1.0 - beta.powf(t as f32)
}

/// Adafactor's step-dependent decay `beta2_t = 1 - t^(-decay_pow)`,
/// clamped to the 1-based step domain. An unguarded `t = 0` evaluates
/// `(0)^(-p) = inf`, making `beta2_t = -inf` and poisoning the factored
/// state (`r`/`c`/`v` go to `-inf`/NaN on the very first accumulate);
/// clamping to `t = 1` yields the correct first-step value 0 instead.
/// Regression: `adafactor_t0_is_clamped`.
pub fn adafactor_beta2t(decay_pow: f32, t: u64) -> f32 {
    1.0 - (t.max(1) as f32).powf(-decay_pow)
}

// The parity-critical reductions have a single definition in
// `crate::tensor` (Tensor, TensorView and these kernels all share it);
// re-exported here because the kernels are their hottest consumer.
pub use crate::tensor::{rms, sum_sq};

// --- chunked elementwise iteration -----------------------------------------
//
// The elementwise kernels walk their slices in fixed-width chunks with a
// scalar remainder: the `chunks_exact` family hands LLVM loops whose trip
// count is the constant `LANES`, with every bounds check elided, which is
// exactly the shape the autovectorizer turns into SIMD. Crucially this is
// a pure ITERATION restructure — each element still sees the identical
// arithmetic expression in the identical order, and elementwise rules
// carry no cross-element state, so the results are bit-identical to the
// straightforward scalar loop (pinned by
// `chunked_kernels_match_scalar_reference_bitwise`). Reductions (`rms`,
// `sum_sq`, the `factor_rows` row sums) are NOT chunked: their
// accumulation order is parity-critical and stays strictly sequential.

const LANES: usize = 8;

/// Chunked `zip` over (mut, const) slice pairs; applies `f` to the first
/// `min(len)` elements in order, exactly like `a.iter_mut().zip(b)`.
#[inline(always)]
fn zip2_chunked(a: &mut [f32], b: &[f32], mut f: impl FnMut(&mut f32, f32)) {
    let n = a.len().min(b.len());
    let mut ac = a[..n].chunks_exact_mut(LANES);
    let mut bc = b[..n].chunks_exact(LANES);
    for (av, bv) in (&mut ac).zip(&mut bc) {
        for (x, &y) in av.iter_mut().zip(bv) {
            f(x, y);
        }
    }
    for (x, &y) in ac.into_remainder().iter_mut().zip(bc.remainder()) {
        f(x, y);
    }
}

/// Chunked `zip` over (mut, const, mut) slice triples.
#[inline(always)]
fn zip3_chunked(
    a: &mut [f32],
    b: &[f32],
    c: &mut [f32],
    mut f: impl FnMut(&mut f32, f32, &mut f32),
) {
    let n = a.len().min(b.len()).min(c.len());
    let mut ac = a[..n].chunks_exact_mut(LANES);
    let mut bc = b[..n].chunks_exact(LANES);
    let mut cc = c[..n].chunks_exact_mut(LANES);
    for ((av, bv), cv) in (&mut ac).zip(&mut bc).zip(&mut cc) {
        for ((x, &y), z) in av.iter_mut().zip(bv).zip(cv.iter_mut()) {
            f(x, y, z);
        }
    }
    for ((x, &y), z) in ac
        .into_remainder()
        .iter_mut()
        .zip(bc.remainder())
        .zip(cc.into_remainder().iter_mut())
    {
        f(x, y, z);
    }
}

/// Chunked `zip` over (mut, const, mut, mut) slice quadruples.
#[inline(always)]
fn zip4_chunked(
    a: &mut [f32],
    b: &[f32],
    c: &mut [f32],
    d: &mut [f32],
    mut f: impl FnMut(&mut f32, f32, &mut f32, &mut f32),
) {
    let n = a.len().min(b.len()).min(c.len()).min(d.len());
    let mut ac = a[..n].chunks_exact_mut(LANES);
    let mut bc = b[..n].chunks_exact(LANES);
    let mut cc = c[..n].chunks_exact_mut(LANES);
    let mut dc = d[..n].chunks_exact_mut(LANES);
    for (((av, bv), cv), dv) in
        (&mut ac).zip(&mut bc).zip(&mut cc).zip(&mut dc)
    {
        for (((x, &y), z), u) in
            av.iter_mut().zip(bv).zip(cv.iter_mut()).zip(dv.iter_mut())
        {
            f(x, y, z, u);
        }
    }
    for (((x, &y), z), u) in ac
        .into_remainder()
        .iter_mut()
        .zip(bc.remainder())
        .zip(cc.into_remainder().iter_mut())
        .zip(dc.into_remainder().iter_mut())
    {
        f(x, y, z, u);
    }
}

// --- slice kernels ---------------------------------------------------------

/// Grouped update normalization (Algorithm 1 line 11), in place:
/// u <- u / max(1, RMS(u)) * max(eps_rms, RMS(theta)).
pub fn grouped_normalize_slice(
    u: &mut [f32],
    theta: &[f32],
    eps_rms: f32,
) -> GroupedNormStats {
    let rms_u = rms(u);
    let rms_theta = rms(theta);
    let scale = eps_rms.max(rms_theta) / 1.0f32.max(rms_u);
    for x in u.iter_mut() {
        *x *= scale;
    }
    GroupedNormStats { rms_u, rms_theta, scale }
}

/// theta <- theta - lr * g  (SGD; also the LOMO rule, paper Eq. 1).
pub fn sgd_slice(theta: &mut [f32], g: &[f32], lr: f32) {
    zip2_chunked(theta, g, |th, gi| {
        *th += -lr * gi;
    });
}

/// SGD + first moment only (paper Eq. 3). Elementwise: valid on any
/// aligned (theta, g, m) sub-range, which is what lets the flat engine
/// chunk it across workers with no synchronization.
pub fn sgd_momentum_slice(
    theta: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    t: u64,
    lr: f32,
    h: Hyper,
) {
    let bias = bias_correction(h.beta1, t);
    zip3_chunked(theta, g, m, |th, gi, mi| {
        *mi = h.beta1 * *mi + (1.0 - h.beta1) * gi;
        *th -= lr * (*mi / bias);
    });
}

/// SGD + second moment only (paper Eq. 4). Elementwise.
pub fn sgd_variance_slice(
    theta: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    t: u64,
    lr: f32,
    h: Hyper,
) {
    let bias = bias_correction(h.beta2, t);
    zip3_chunked(theta, g, v, |th, gi, vi| {
        *vi = h.beta2 * *vi + (1.0 - h.beta2) * gi * gi;
        *th -= lr * gi / ((*vi / bias).sqrt() + h.adam_eps);
    });
}

/// AdamW (paper Eq. 2 + decoupled weight decay). Elementwise. The old
/// index-based loop re-checked four slice bounds per element, which kept
/// LLVM from vectorizing the body; the chunked zip runs the identical
/// per-element expression with no bounds checks.
#[allow(clippy::too_many_arguments)]
pub fn adamw_slice(
    theta: &mut [f32],
    g: &[f32],
    m: &mut [f32],
    v: &mut [f32],
    t: u64,
    lr: f32,
    wd: f32,
    h: Hyper,
) {
    let bias1 = bias_correction(h.beta1, t);
    let bias2 = bias_correction(h.beta2, t);
    zip4_chunked(theta, g, m, v, |th, gi, mi, vi| {
        *mi = h.beta1 * *mi + (1.0 - h.beta1) * gi;
        *vi = h.beta2 * *vi + (1.0 - h.beta2) * gi * gi;
        let update = (*mi / bias1) / ((*vi / bias2).sqrt() + h.adam_eps);
        *th -= lr * (update + wd * *th);
    });
}

/// Factored second-moment accumulation over a block of rows:
/// `r[i] <- beta * r[i] + (1-beta) * Σ_j (g_ij² + floor)` and
/// `c_acc[j] += (1-beta) * (g_ij² + floor)`.
///
/// `g` holds `r.len()` rows of width `n`. Callers either pre-scale the full
/// `c` by beta and pass it as `c_acc` (sequential path — identical
/// arithmetic to the original fused loop), or pass a zeroed per-worker
/// accumulator and combine `beta * c + Σ_w acc_w` afterwards (the flat
/// engine's parallel path). Single pass over g, no temporaries (perf pass:
/// EXPERIMENTS.md §Perf L3 iteration 1).
pub fn factor_rows(
    g: &[f32],
    n: usize,
    r: &mut [f32],
    c_acc: &mut [f32],
    beta: f32,
    floor: f32,
) {
    debug_assert_eq!(g.len(), r.len() * n);
    debug_assert_eq!(c_acc.len(), n);
    let one_minus = 1.0 - beta;
    for (i, ri) in r.iter_mut().enumerate() {
        let row = &g[i * n..(i + 1) * n];
        let mut rsum = 0.0f32;
        for (cj, &x) in c_acc.iter_mut().zip(row) {
            let g2 = x * x + floor;
            rsum += g2;
            *cj += one_minus * g2;
        }
        *ri = beta * *ri + one_minus * rsum;
    }
}

/// Raw factored update u for a block of rows:
/// `u_ij = g_ij / f(r_i * inv_sum * c_j + eps)` with f = sqrt (default) or
/// identity (`no_sqrt`, the literal Algorithm-1 line-10 form). Row-hoisted:
/// the per-row factor and bias correction fold into `inv_sum`, so the inner
/// loop is one mul + sqrt + div per element (sqrt(a*b) = sqrt(a)*sqrt(b)
/// does NOT hold with the +eps guard, so the sqrt stays inside). Iterator
/// zips elide bounds checks -> LLVM vectorizes (perf pass iteration 2).
#[allow(clippy::too_many_arguments)]
pub fn raw_u_rows(
    g: &[f32],
    n: usize,
    r: &[f32],
    c: &[f32],
    inv_sum: f32,
    eps: f32,
    no_sqrt: bool,
    u: &mut [f32],
) {
    debug_assert_eq!(g.len(), r.len() * n);
    debug_assert_eq!(u.len(), g.len());
    debug_assert_eq!(c.len(), n);
    for (i, &ri) in r.iter().enumerate() {
        let row_scale = ri * inv_sum; // v_hat = row_scale * c[j]
        let grow = &g[i * n..(i + 1) * n];
        let urow = &mut u[i * n..(i + 1) * n];
        if no_sqrt {
            for ((ui, &gv), &cv) in urow.iter_mut().zip(grow).zip(c.iter()) {
                *ui = gv / (row_scale * cv + eps);
            }
        } else {
            for ((ui, &gv), &cv) in urow.iter_mut().zip(grow).zip(c.iter()) {
                *ui = gv / (row_scale * cv + eps).sqrt();
            }
        }
    }
}

/// AdaLomo vector phase kernel: update the full second moment `v` and
/// write the raw (pre-normalization) update into `u`. Elementwise.
pub fn adalomo_vec_raw(g: &[f32], v: &mut [f32], bias: f32, h: Hyper, u: &mut [f32]) {
    zip3_chunked(u, g, v, |ui, gi, vi| {
        *vi = h.adalomo_beta * *vi + (1.0 - h.adalomo_beta) * gi * gi;
        let v_hat = *vi / bias;
        let denom = if h.no_sqrt {
            v_hat + h.eps_div
        } else {
            (v_hat + h.eps_div).sqrt()
        };
        *ui = gi / denom;
    });
}

/// Adafactor vector phase kernel (no bias correction; +eps1 floor).
/// Elementwise.
pub fn adafactor_vec_raw(g: &[f32], v: &mut [f32], beta2t: f32, h: Hyper, u: &mut [f32]) {
    zip3_chunked(u, g, v, |ui, gi, vi| {
        *vi = beta2t * *vi + (1.0 - beta2t) * (gi * gi + h.adafactor_eps1);
        *ui = gi / (*vi + h.adafactor_eps1).sqrt();
    });
}

/// AdaLomo step for a 2-D parameter (Algorithm 1 lines 7-12), on borrowed
/// views. `n` is the row width; `u` is caller-provided scratch of
/// `theta.len()` elements.
#[allow(clippy::too_many_arguments)]
pub fn adalomo_2d_slice(
    theta: &mut [f32],
    g: &[f32],
    n: usize,
    r: &mut [f32],
    c: &mut [f32],
    t: u64,
    lr: f32,
    h: Hyper,
    u: &mut [f32],
) -> GroupedNormStats {
    for cj in c.iter_mut() {
        *cj *= h.adalomo_beta;
    }
    factor_rows(g, n, r, c, h.adalomo_beta, 0.0);
    let bias = bias_correction(h.adalomo_beta, t);
    let sum_r = r.iter().sum::<f32>().max(h.eps_div);
    raw_u_rows(g, n, r, c, 1.0 / (sum_r * bias), h.eps_div, h.no_sqrt, u);
    let stats = grouped_normalize_slice(u, theta, h.eps_rms);
    zip2_chunked(theta, u, |th, ui| {
        *th += -lr * ui;
    });
    stats
}

/// AdaLomo step for vectors (full second moment), on borrowed views.
#[allow(clippy::too_many_arguments)]
pub fn adalomo_vec_slice(
    theta: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    t: u64,
    lr: f32,
    h: Hyper,
    u: &mut [f32],
) -> GroupedNormStats {
    let bias = bias_correction(h.adalomo_beta, t);
    adalomo_vec_raw(g, v, bias, h, u);
    let stats = grouped_normalize_slice(u, theta, h.eps_rms);
    zip2_chunked(theta, u, |th, ui| {
        *th += -lr * ui;
    });
    stats
}

/// Adafactor step for a 2-D parameter (momentum-less, update clipping,
/// relative step size; lr = rho_t), on borrowed views.
#[allow(clippy::too_many_arguments)]
pub fn adafactor_2d_slice(
    theta: &mut [f32],
    g: &[f32],
    n: usize,
    r: &mut [f32],
    c: &mut [f32],
    t: u64,
    lr: f32,
    h: Hyper,
    u: &mut [f32],
) {
    let beta2t = adafactor_beta2t(h.adafactor_decay_pow, t);
    for cj in c.iter_mut() {
        *cj *= beta2t;
    }
    factor_rows(g, n, r, c, beta2t, h.adafactor_eps1);
    let sum_r = r.iter().sum::<f32>().max(h.adafactor_eps1);
    raw_u_rows(g, n, r, c, 1.0 / sum_r, h.adafactor_eps1, false, u);
    let clip = 1.0f32.max(rms(u) / h.adafactor_clip_d);
    let alpha = h.adafactor_eps2.max(rms(theta)) * lr;
    zip2_chunked(theta, u, |th, ui| {
        *th += (-alpha / clip) * ui;
    });
}

/// Adafactor step for vectors, on borrowed views.
pub fn adafactor_vec_slice(
    theta: &mut [f32],
    g: &[f32],
    v: &mut [f32],
    t: u64,
    lr: f32,
    h: Hyper,
    u: &mut [f32],
) {
    let beta2t = adafactor_beta2t(h.adafactor_decay_pow, t);
    adafactor_vec_raw(g, v, beta2t, h, u);
    let clip = 1.0f32.max(rms(u) / h.adafactor_clip_d);
    let alpha = h.adafactor_eps2.max(rms(theta)) * lr;
    zip2_chunked(theta, u, |th, ui| {
        *th += (-alpha / clip) * ui;
    });
}

// --- Tensor wrappers -------------------------------------------------------

/// Grouped update normalization (Algorithm 1 line 11), in place.
pub fn grouped_normalize(u: &mut Tensor, theta: &Tensor, eps_rms: f32) -> GroupedNormStats {
    grouped_normalize_slice(u.data_mut(), theta.data(), eps_rms)
}

/// theta <- theta - lr * g  (SGD; also the LOMO rule, paper Eq. 1).
pub fn sgd(theta: &mut Tensor, g: &Tensor, lr: f32) {
    theta.axpy(-lr, g);
}

/// SGD + first moment only (paper Eq. 3).
pub fn sgd_momentum(theta: &mut Tensor, g: &Tensor, m: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    sgd_momentum_slice(theta.data_mut(), g.data(), m.data_mut(), t, lr, h);
}

/// SGD + second moment only (paper Eq. 4).
pub fn sgd_variance(theta: &mut Tensor, g: &Tensor, v: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    sgd_variance_slice(theta.data_mut(), g.data(), v.data_mut(), t, lr, h);
}

/// AdamW (paper Eq. 2 + decoupled weight decay).
#[allow(clippy::too_many_arguments)]
pub fn adamw(
    theta: &mut Tensor,
    g: &Tensor,
    m: &mut Tensor,
    v: &mut Tensor,
    t: u64,
    lr: f32,
    wd: f32,
    h: Hyper,
) {
    adamw_slice(
        theta.data_mut(),
        g.data(),
        m.data_mut(),
        v.data_mut(),
        t,
        lr,
        wd,
        h,
    );
}

/// AdaLomo step for a 2-D parameter (Algorithm 1 lines 7-12).
pub fn adalomo_2d(
    theta: &mut Tensor,
    g: &Tensor,
    r: &mut Tensor,
    c: &mut Tensor,
    t: u64,
    lr: f32,
    h: Hyper,
) {
    let n = g.shape()[1];
    let mut u = vec![0f32; g.len()];
    adalomo_2d_slice(
        theta.data_mut(),
        g.data(),
        n,
        r.data_mut(),
        c.data_mut(),
        t,
        lr,
        h,
        &mut u,
    );
}

/// AdaLomo step for vectors (full second moment).
pub fn adalomo_vec(theta: &mut Tensor, g: &Tensor, v: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    let mut u = vec![0f32; g.len()];
    adalomo_vec_slice(
        theta.data_mut(),
        g.data(),
        v.data_mut(),
        t,
        lr,
        h,
        &mut u,
    );
}

/// Adafactor step for a 2-D parameter.
pub fn adafactor_2d(
    theta: &mut Tensor,
    g: &Tensor,
    r: &mut Tensor,
    c: &mut Tensor,
    t: u64,
    lr: f32,
    h: Hyper,
) {
    let n = g.shape()[1];
    let mut u = vec![0f32; g.len()];
    adafactor_2d_slice(
        theta.data_mut(),
        g.data(),
        n,
        r.data_mut(),
        c.data_mut(),
        t,
        lr,
        h,
        &mut u,
    );
}

/// Adafactor step for vectors.
pub fn adafactor_vec(theta: &mut Tensor, g: &Tensor, v: &mut Tensor, t: u64, lr: f32, h: Hyper) {
    let mut u = vec![0f32; g.len()];
    adafactor_vec_slice(
        theta.data_mut(),
        g.data(),
        v.data_mut(),
        t,
        lr,
        h,
        &mut u,
    );
}

/// Global gradient norm over a set of gradients — the quantity LOMO's
/// two-backward-pass gradient normalization needs (paper §2.1).
pub fn global_grad_norm(grads: &[&Tensor]) -> f32 {
    grads.iter().map(|g| g.sum_sq()).sum::<f32>().sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hyper() -> Hyper {
        Hyper::default()
    }

    #[test]
    fn grouped_norm_caps_rms() {
        // After normalization, RMS(u) <= max(eps, RMS(theta)).
        let mut u = Tensor::full(&[8, 8], 50.0);
        let theta = Tensor::full(&[8, 8], 0.2);
        let stats = grouped_normalize(&mut u, &theta, 1e-3);
        assert!((stats.rms_u - 50.0).abs() < 1e-4);
        assert!((u.rms() - 0.2).abs() < 1e-4);
    }

    #[test]
    fn grouped_norm_small_update_not_amplified_beyond_theta_rms() {
        // RMS(u) < 1 -> divide by 1, multiply by RMS(theta).
        let mut u = Tensor::full(&[4], 0.5);
        let theta = Tensor::full(&[4], 2.0);
        grouped_normalize(&mut u, &theta, 1e-3);
        assert!((u.rms() - 1.0).abs() < 1e-5); // 0.5 * 2.0
    }

    #[test]
    fn adalomo_first_step_unit_rms_direction() {
        // At t=1 with zero state, v_hat = g^2 exactly (bias correction
        // cancels (1-beta)), so u = sign(g)-ish with |u|=1 per element up
        // to the factored approximation; for a rank-1 |g| it is exact.
        let mut theta = Tensor::full(&[2, 2], 1.0);
        let g = Tensor::new(&[2, 2], vec![0.3, 0.3, 0.3, 0.3]).unwrap();
        let mut r = Tensor::zeros(&[2]);
        let mut c = Tensor::zeros(&[2]);
        adalomo_2d(&mut theta, &g, &mut r, &mut c, 1, 0.1, hyper());
        // u = 1 everywhere -> grouped norm: RMS(u)=1, RMS(theta)=1 -> scale 1
        // theta' = 1 - 0.1.
        for &x in theta.data() {
            assert!((x - 0.9).abs() < 1e-4, "{x}");
        }
        // Factors hold (1-beta) * rowsums of g^2.
        assert!((r.data()[0] - 0.15 * 2.0 * 0.09).abs() < 1e-6);
    }

    #[test]
    fn adalomo_factors_nonnegative() {
        let mut theta = Tensor::full(&[3, 4], 0.5);
        let g = Tensor::from_fn(&[3, 4], |i| (i as f32 - 5.0) * 0.01);
        let mut r = Tensor::zeros(&[3]);
        let mut c = Tensor::zeros(&[4]);
        for t in 1..20 {
            adalomo_2d(&mut theta, &g, &mut r, &mut c, t, 0.01, hyper());
        }
        assert!(r.data().iter().all(|&x| x >= 0.0));
        assert!(c.data().iter().all(|&x| x >= 0.0));
    }

    #[test]
    fn adamw_decay_pulls_to_zero() {
        let mut theta = Tensor::full(&[4], 1.0);
        let g = Tensor::zeros(&[4]);
        let mut m = Tensor::zeros(&[4]);
        let mut v = Tensor::zeros(&[4]);
        adamw(&mut theta, &g, &mut m, &mut v, 1, 0.1, 0.5, hyper());
        for &x in theta.data() {
            assert!((x - 0.95).abs() < 1e-6); // 1 - 0.1*0.5*1
        }
    }

    #[test]
    fn sgd_variance_normalizes_scale() {
        // With variance normalization, the first-step update size is
        // ~lr * sign(g) regardless of |g| (paper's argument for adaptivity).
        let h = hyper();
        for &mag in &[1e-4f32, 1.0, 1e4] {
            let mut theta = Tensor::zeros(&[1]);
            let g = Tensor::full(&[1], mag);
            let mut v = Tensor::zeros(&[1]);
            sgd_variance(&mut theta, &g, &mut v, 1, 0.1, h);
            assert!(
                (theta.data()[0] + 0.1).abs() < 1e-3,
                "mag {mag} -> {}",
                theta.data()[0]
            );
        }
    }

    #[test]
    fn global_norm() {
        let a = Tensor::full(&[4], 1.0);
        let b = Tensor::full(&[9], 1.0);
        let n = global_grad_norm(&[&a, &b]);
        assert!((n - (13.0f32).sqrt()).abs() < 1e-6);
    }

    #[test]
    fn bias_correction_survives_huge_t() {
        // Regression: `beta.powi(t as i32)` wraps for t > i32::MAX and
        // yields a negative exponent, blowing the correction up instead of
        // saturating it toward 1.
        let t = (i32::MAX as u64) + 7;
        for beta in [0.85f32, 0.9, 0.999] {
            let b = bias_correction(beta, t);
            assert!(b.is_finite() && b > 0.0 && b <= 1.0, "beta {beta} -> {b}");
            assert!((b - 1.0).abs() < 1e-6, "beta {beta}: correction ~1 at huge t");
        }
        // A full step at huge t stays finite for every stateful rule.
        let h = hyper();
        let g = Tensor::full(&[3, 2], 0.1);
        let mut theta = Tensor::full(&[3, 2], 1.0);
        let mut m = Tensor::zeros(&[3, 2]);
        let mut v = Tensor::zeros(&[3, 2]);
        adamw(&mut theta, &g, &mut m, &mut v, t, 1e-3, 0.01, h);
        let mut r = Tensor::zeros(&[3]);
        let mut c = Tensor::zeros(&[2]);
        adalomo_2d(&mut theta, &g, &mut r, &mut c, t, 1e-3, h);
        let mut vv = Tensor::zeros(&[3, 2]);
        sgd_variance(&mut theta, &g, &mut vv, t, 1e-3, h);
        sgd_momentum(&mut theta, &g, &mut m, t, 1e-3, h);
        assert!(theta.data().iter().all(|x| x.is_finite()));
    }

    #[test]
    fn adafactor_t0_is_clamped() {
        // Regression: `1 - (0f32).powf(-p)` is `-inf`; the clamp makes
        // t = 0 behave exactly like the first real step.
        let h = hyper();
        let b0 = adafactor_beta2t(h.adafactor_decay_pow, 0);
        let b1 = adafactor_beta2t(h.adafactor_decay_pow, 1);
        assert!(b0.is_finite());
        assert_eq!(b0.to_bits(), b1.to_bits());
        assert_eq!(b1, 0.0); // 1 - 1^(-p)
        // A full factored step at t = 0 stays finite instead of poisoning
        // the r/c/v state for every step after it.
        let mut theta = Tensor::full(&[3, 4], 0.5);
        let g = Tensor::from_fn(&[3, 4], |i| (i as f32 - 5.0) * 0.01);
        let mut r = Tensor::zeros(&[3]);
        let mut c = Tensor::zeros(&[4]);
        let mut u = vec![0f32; 12];
        adafactor_2d_slice(
            theta.data_mut(),
            g.data(),
            4,
            r.data_mut(),
            c.data_mut(),
            0,
            0.01,
            h,
            &mut u,
        );
        assert!(theta.data().iter().all(|x| x.is_finite()));
        assert!(r.data().iter().all(|x| x.is_finite() && *x >= 0.0));
        assert!(c.data().iter().all(|x| x.is_finite() && *x >= 0.0));
        let mut v = Tensor::zeros(&[5]);
        let g1 = Tensor::full(&[5], 0.1);
        let mut theta1 = Tensor::full(&[5], 1.0);
        let mut u1 = vec![0f32; 5];
        adafactor_vec_slice(
            theta1.data_mut(),
            g1.data(),
            v.data_mut(),
            0,
            0.01,
            h,
            &mut u1,
        );
        assert!(theta1.data().iter().all(|x| x.is_finite()));
        assert!(v.data().iter().all(|x| x.is_finite() && *x >= 0.0));
    }

    #[test]
    fn chunked_kernels_match_scalar_reference_bitwise() {
        // The LANES-chunked iteration is a pure loop restructure: every
        // length (below, at, just above, and far above one chunk) must
        // produce bit-identical results to the naive indexed loops the
        // kernels used before the autovectorization pass.
        let h = hyper();
        for n in [1usize, 7, 8, 9, 64, 103] {
            let g: Vec<f32> = (0..n)
                .map(|i| ((i * 37 % 19) as f32 - 9.0) * 0.013)
                .collect();
            let th0: Vec<f32> =
                (0..n).map(|i| 0.3 + i as f32 * 0.001).collect();

            // sgd
            let mut a = th0.clone();
            let mut b = th0.clone();
            sgd_slice(&mut a, &g, 0.05);
            for i in 0..n {
                b[i] += -0.05 * g[i];
            }
            assert_eq!(a, b, "sgd n={n}");

            // momentum
            let (mut a, mut b) = (th0.clone(), th0.clone());
            let mut ma = vec![0.01f32; n];
            let mut mb = ma.clone();
            for t in 1..4u64 {
                sgd_momentum_slice(&mut a, &g, &mut ma, t, 0.05, h);
                let bias = bias_correction(h.beta1, t);
                for i in 0..n {
                    mb[i] = h.beta1 * mb[i] + (1.0 - h.beta1) * g[i];
                    b[i] -= 0.05 * (mb[i] / bias);
                }
            }
            assert_eq!(a, b, "momentum n={n}");
            assert_eq!(ma, mb, "momentum state n={n}");

            // variance
            let (mut a, mut b) = (th0.clone(), th0.clone());
            let mut va = vec![0.02f32; n];
            let mut vb = va.clone();
            for t in 1..4u64 {
                sgd_variance_slice(&mut a, &g, &mut va, t, 0.05, h);
                let bias = bias_correction(h.beta2, t);
                for i in 0..n {
                    vb[i] = h.beta2 * vb[i] + (1.0 - h.beta2) * g[i] * g[i];
                    b[i] -=
                        0.05 * g[i] / ((vb[i] / bias).sqrt() + h.adam_eps);
                }
            }
            assert_eq!(a, b, "variance n={n}");
            assert_eq!(va, vb, "variance state n={n}");

            // adamw
            let (mut a, mut b) = (th0.clone(), th0.clone());
            let mut ma = vec![0.01f32; n];
            let mut mb = ma.clone();
            let mut va = vec![0.02f32; n];
            let mut vb = va.clone();
            for t in 1..4u64 {
                adamw_slice(&mut a, &g, &mut ma, &mut va, t, 0.05, 0.01, h);
                let b1 = bias_correction(h.beta1, t);
                let b2 = bias_correction(h.beta2, t);
                for i in 0..n {
                    mb[i] = h.beta1 * mb[i] + (1.0 - h.beta1) * g[i];
                    vb[i] = h.beta2 * vb[i] + (1.0 - h.beta2) * g[i] * g[i];
                    let update =
                        (mb[i] / b1) / ((vb[i] / b2).sqrt() + h.adam_eps);
                    b[i] -= 0.05 * (update + 0.01 * b[i]);
                }
            }
            assert_eq!(a, b, "adamw n={n}");
            assert_eq!(ma, mb, "adamw m n={n}");
            assert_eq!(va, vb, "adamw v n={n}");

            // adalomo vector raw phase
            let mut va = vec![0.02f32; n];
            let mut vb = va.clone();
            let mut ua = vec![0f32; n];
            let mut ub = vec![0f32; n];
            let bias = bias_correction(h.adalomo_beta, 2);
            adalomo_vec_raw(&g, &mut va, bias, h, &mut ua);
            for i in 0..n {
                vb[i] = h.adalomo_beta * vb[i]
                    + (1.0 - h.adalomo_beta) * g[i] * g[i];
                let v_hat = vb[i] / bias;
                let denom = if h.no_sqrt {
                    v_hat + h.eps_div
                } else {
                    (v_hat + h.eps_div).sqrt()
                };
                ub[i] = g[i] / denom;
            }
            assert_eq!(ua, ub, "adalomo_vec_raw u n={n}");
            assert_eq!(va, vb, "adalomo_vec_raw v n={n}");
        }
    }

    #[test]
    fn slice_kernels_match_tensor_wrappers() {
        // The wrappers ARE the slice kernels; this guards against the two
        // levels drifting apart if one is edited without the other.
        let h = hyper();
        let g = Tensor::from_fn(&[4, 3], |i| (i as f32 - 6.0) * 0.02);
        let mut theta_a = Tensor::from_fn(&[4, 3], |i| 0.1 + i as f32 * 0.01);
        let mut theta_b = theta_a.clone();
        let mut r = Tensor::zeros(&[4]);
        let mut c = Tensor::zeros(&[3]);
        let (mut r2, mut c2) = (r.clone(), c.clone());
        for t in 1..4 {
            adalomo_2d(&mut theta_a, &g, &mut r, &mut c, t, 0.01, h);
            let mut u = vec![0f32; 12];
            adalomo_2d_slice(
                theta_b.data_mut(),
                g.data(),
                3,
                r2.data_mut(),
                c2.data_mut(),
                t,
                0.01,
                h,
                &mut u,
            );
        }
        for (a, b) in theta_a.data().iter().zip(theta_b.data()) {
            assert_eq!(a, b, "wrapper and slice kernel must be bit-identical");
        }
    }
}
