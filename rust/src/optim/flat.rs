//! Flat-blob parallel optimizer engine.
//!
//! [`FlatOptimizer`] steps a runtime [`Layout`]/blob **in place**: it walks
//! the trainable segments in fused-backward order (head, layers L-1..0,
//! embedding — mirroring `coordinator/fused.rs::group_grad_sizes`) and
//! dispatches each to the slice kernels in [`super::update`] through
//! zero-copy segment views. No per-tensor [`crate::tensor::Tensor`]
//! allocation, no per-step `u` temporary — each worker keeps persistent
//! scratch buffers and the blob spans are precomputed at construction, so
//! a step's only transient allocations are the small per-worker view
//! tables. That is the host-side embodiment of the paper's memory story
//! (AdaLomo Alg. 1; factored second moments à la Anil et al. 2019):
//! operate on contiguous state with minimal temporaries.
//!
//! Like [`super::update`], this is a blessed float-kernel file under the
//! `analyze` determinism rule (docs/ANALYSIS.md): the norm/trust-ratio
//! reductions here run in a fixed order regardless of shard plan, which
//! is exactly what the byte-identity guarantees below rest on.
//!
//! Parallelism comes in two shard plans (see [`ShardMode`]):
//!
//! * **`Segments`** — whole-tensor ownership balanced by greedy LPT (the
//!   `SegmentShard` granularity of `coordinator/sharding.rs`). Workers
//!   never synchronize; every update is byte-identical to the sequential
//!   [`super::ParamOpt`] path because both run the same slice kernels.
//! * **`Contiguous`** — every worker owns a contiguous range of the
//!   trainable region (the `ContiguousShard` granularity, row-aligned for
//!   2-D parameters) and all workers cooperate on every segment. Grouped
//!   update normalization becomes a two-pass parallel reduction: each
//!   worker posts its range's sum-of-squares, a barrier, one combine in
//!   worker order, a barrier, then a single scale pass — the same math,
//!   merely re-associated, so results for a fixed shard count are
//!   deterministic and agree with the sequential path to f32 rounding
//!   (the parity proptests pin this to 1e-6).
//!
//! The engine is the substrate for sharded/async execution: the
//! coordinator's local-SGD round averaging and the micro benches already
//! run on it, and a rank pipeline can hand each worker an actual rank's
//! shard without changing the update code.
//!
//! # Storage dtype
//!
//! The typed entry points ([`FlatOptimizer::step_typed`],
//! [`FlatOptimizer::step_tasks_typed`], [`FlatOptimizer::step_group_typed`])
//! accept a [`TypedBlob`]: f32 storage routes to the zero-copy in-place
//! paths above; bf16 storage steps each task by widening its parameter
//! and state slices into per-worker f32 scratch, running the SAME slice
//! kernels, and rounding back (round-to-nearest-even). The scratch is
//! bounded by the largest single task — never a full-image f32 mirror —
//! and the peak is MEASURED ([`FlatOptimizer::bf16_peak_scratch_elems`])
//! and pinned against the analytic bound
//! ([`FlatOptimizer::bf16_scratch_bound_elems`]) by the dtype tests.
//! Because each task's widen→kernel→round is self-contained and depends
//! only on that task's stored bits and its gradient slice, any partition
//! of the tasks (buckets, groups, whole image) lands bit-identically —
//! the same property the f32 pipelines rest on, which is what keeps every
//! `ExecPlan` cell bitwise-reproducible at fixed dtype. Under bf16 both
//! shard plans use whole-task (Segments-style) ownership: the conversion
//! pass dominates, and intra-task cooperation would change the arithmetic
//! without buying bandwidth.

use std::sync::{Barrier, Mutex, RwLock};

use anyhow::{ensure, Context, Result};

use crate::runtime::{BlobPartsMut, HostBlob, Layout, Segment, TypedBlob};
use crate::tensor::{round_bf16_slice, widen_bf16_into, Dtype};

use super::update::sum_sq;
use super::{pool, update, Hyper, OptKind};

/// How the trainable region is split across workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardMode {
    /// Whole-segment ownership (greedy LPT). Zero synchronization;
    /// bit-identical to the per-tensor path.
    Segments,
    /// Contiguous row-aligned ranges; workers cooperate on every segment
    /// through two-pass reductions.
    Contiguous,
}

/// Layer-member order inside one fused-backward group
/// (mirror of `coordinator/fused.rs::group_grad_sizes`).
const LAYER_MEMBERS: [&str; 9] = [
    "attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate", "w_up",
    "w_down",
];

#[derive(Debug, Clone, Copy)]
struct SegRef {
    offset: usize,
    size: usize,
}

#[derive(Debug, Clone)]
enum StateSpec {
    None,
    /// First moment (sgd_momentum).
    M(SegRef),
    /// Full second moment (sgd_variance; adalomo/adafactor vectors).
    V(SegRef),
    /// AdamW first + second moment.
    Mv(SegRef, SegRef),
    /// Factored second moment (adalomo/adafactor matrices).
    Rc(SegRef, SegRef),
}

#[derive(Debug, Clone)]
struct TaskSpec {
    name: String,
    offset: usize,
    size: usize,
    /// Row width for 2-D parameters; 0 for vectors/scalars.
    cols: usize,
    state: StateSpec,
    /// Contiguous-mode per-worker element ranges within the task
    /// (row-aligned for 2-D parameters).
    ranges: Vec<(usize, usize)>,
}

/// One fused-backward *group*: a contiguous run of fused-order task
/// indices (the head block, one transformer layer, or the embedding) plus
/// the blob extent its gradients occupy. This is the host-side unit of
/// gradient liveness — the twin of one `fused_*_g<k>` XLA program
/// (`coordinator::fused::group_grad_sizes`).
#[derive(Debug, Clone, Copy)]
struct GroupSpec {
    /// Half-open range into the fused-order task list.
    tasks: (usize, usize),
    /// Blob extent `[lo, hi)` covering every task in the group.
    lo: usize,
    hi: usize,
    /// Sum of the member task sizes (== `hi - lo` when the extent has no
    /// non-trainable gaps, as in the standard parameter packing).
    elems: usize,
}

/// Per-worker persistent scratch: the only buffers the engine ever
/// allocates, reused across steps.
#[derive(Debug, Clone, Default)]
struct Scratch {
    /// Raw update u for the worker's range of the current segment.
    u: Vec<f32>,
    /// Per-worker column-factor accumulator (2-D factored phase A).
    cvec: Vec<f32>,
    /// Local copy of the combined column factor (2-D factored phase B).
    cbuf: Vec<f32>,
}

impl Scratch {
    fn ensure_u(&mut self, n: usize) {
        if self.u.len() < n {
            self.u.resize(n, 0.0);
        }
    }

    fn zero_cvec(&mut self, n: usize) {
        self.cvec.clear();
        self.cvec.resize(n, 0.0);
    }
}

/// Cross-worker reduction state for the contiguous plan. Partials are
/// stored per worker and always combined in ascending worker order, so a
/// fixed shard count gives bit-deterministic results.
///
/// Caveat: barrier-coordinated workers assume their peers reach every
/// barrier. Construction-time validation rules out the panic sources the
/// engine controls (missing/misshaped state segments), but a panic
/// injected into a kernel between barriers would leave peers waiting
/// rather than propagating — the no-hang guarantee of
/// [`pool::run_jobs`] only applies to independent (Segments-mode) jobs.
struct SyncState {
    barrier: Barrier,
    slots: Mutex<Slots>,
}

struct Slots {
    /// Per-worker scalar partial A (sum-of-squares of u, or sum of r).
    pa: Vec<f32>,
    /// Per-worker scalar partial B (sum-of-squares of theta).
    pb: Vec<f32>,
    /// Per-worker column-factor partials.
    cvecs: Vec<Vec<f32>>,
    /// Combined column factor, published by worker 0.
    c_combined: Vec<f32>,
    /// Broadcast slot: final apply factor.
    scale: f32,
    /// Broadcast slot: inv_sum for the raw-u pass.
    aux: f32,
}

impl SyncState {
    fn new(n_workers: usize) -> SyncState {
        SyncState {
            barrier: Barrier::new(n_workers),
            slots: Mutex::new(Slots {
                pa: vec![0.0; n_workers],
                pb: vec![0.0; n_workers],
                cvecs: vec![Vec::new(); n_workers],
                c_combined: Vec::new(),
                scale: 0.0,
                aux: 0.0,
            }),
        }
    }

    fn wait(&self) {
        self.barrier.wait();
    }

    fn post_scalars(&self, w: usize, a: f32, b: f32) {
        let mut sl = self.slots.lock().unwrap();
        // ANALYZE-WAIVE(lock-held-panic): w < n_workers by construction
        sl.pa[w] = a;
        // ANALYZE-WAIVE(lock-held-panic): w < n_workers by construction
        sl.pb[w] = b;
    }

    fn swap_cvec(&self, w: usize, v: &mut Vec<f32>) {
        let mut sl = self.slots.lock().unwrap();
        // ANALYZE-WAIVE(lock-held-panic): w < n_workers by construction
        std::mem::swap(&mut sl.cvecs[w], v);
    }

    fn with_slots<R>(&self, f: impl FnOnce(&mut Slots) -> R) -> R {
        f(&mut self.slots.lock().unwrap())
    }

    fn read_scale(&self) -> f32 {
        self.slots.lock().unwrap().scale
    }

    fn read_aux(&self) -> f32 {
        self.slots.lock().unwrap().aux
    }

    fn copy_combined_c(&self, dst: &mut Vec<f32>) {
        let sl = self.slots.lock().unwrap();
        dst.clear();
        dst.extend_from_slice(&sl.c_combined);
    }
}

/// Zero-copy per-(worker, task) views into the blob, produced by
/// [`distribute`]. `a`/`b` are the state views (m/v/r rows, v/c). The
/// element type is `f32` for in-place stepping and `u16` (raw bf16 bits)
/// for the widen/round path.
#[derive(Default)]
struct TaskPart<'b, T = f32> {
    theta: Option<&'b mut [T]>,
    a: Option<&'b mut [T]>,
    b: Option<&'b mut [T]>,
}

impl<T> TaskPart<'_, T> {
    /// Fresh short-lived views of the same slots. Unlike `mem::take`,
    /// this leaves the part intact, which is what lets a persistent
    /// [`StepSession`] re-dispatch the distributed parts round after
    /// round without redoing (or re-allocating) the blob split.
    fn reborrow(&mut self) -> TaskPart<'_, T> {
        TaskPart {
            theta: self.theta.as_deref_mut(),
            a: self.a.as_deref_mut(),
            b: self.b.as_deref_mut(),
        }
    }
}

/// Per-worker widen/round scratch for bf16-stored blobs: f32 staging for
/// one task's parameter + state slices (plus the kernels' own `u`
/// scratch), reused across tasks and steps. `peak_elems` records the
/// largest combined staging any task ever needed — the measured
/// "bounded scratch, no full-image mirror" claim.
#[derive(Debug, Clone, Default)]
struct Bf16Scratch {
    theta: Vec<f32>,
    a: Vec<f32>,
    b: Vec<f32>,
    inner: Scratch,
    peak_elems: usize,
}

const ROLE_THETA: u8 = 0;
const ROLE_A: u8 = 1;
const ROLE_B: u8 = 2;

struct Span {
    offset: usize,
    len: usize,
    task: usize,
    worker: usize,
    role: u8,
}

/// The engine. Construct once per (layout, shard plan); `step` any number
/// of blobs that share the layout.
pub struct FlatOptimizer {
    kind: OptKind,
    hyper: Hyper,
    mode: ShardMode,
    n_shards: usize,
    blob_len: usize,
    params_len: usize,
    /// Length of the shardable (params + state) region — the prefix a
    /// bf16 blob stores as raw bits.
    shardable_len: usize,
    tasks: Vec<TaskSpec>,
    /// Fused-backward groups over `tasks` (head block, layers L-1..0,
    /// embedding; out-of-convention segments become singleton groups).
    groups: Vec<GroupSpec>,
    /// Segments mode: fused-order task indices per shard (greedy LPT).
    shard_tasks: Vec<Vec<usize>>,
    /// Blob spans for the configured mode, precomputed and offset-sorted —
    /// `step` only re-splits the borrowed blob along them.
    spans: Vec<Span>,
    /// Whole-task (Segments-style) spans for the bf16 widen/round path,
    /// which always steps whole tasks regardless of `mode`.
    bf16_spans: Vec<Span>,
    /// Reusable cross-worker reduction state (contiguous mode).
    sync: SyncState,
    scratch: Vec<Scratch>,
    /// Per-worker widen/round staging for bf16 blobs (empty cost when
    /// unused: the Vecs only grow on the first bf16 step).
    bf16_scratch: Vec<Bf16Scratch>,
}

impl FlatOptimizer {
    pub fn new(
        kind: OptKind,
        layout: &Layout,
        n_shards: usize,
        mode: ShardMode,
    ) -> Result<FlatOptimizer> {
        Self::with_hyper(kind, layout, n_shards, mode, Hyper::default())
    }

    pub fn with_hyper(
        kind: OptKind,
        layout: &Layout,
        n_shards: usize,
        mode: ShardMode,
        hyper: Hyper,
    ) -> Result<FlatOptimizer> {
        let n_shards = n_shards.max(1);
        let params: Vec<&Segment> = layout.trainable().collect();
        ensure!(!params.is_empty(), "layout has no trainable segments");

        // Fused-backward ordering over the trainable segments.
        let n_layers = params
            .iter()
            .filter_map(|s| parse_layer(&s.name).map(|(l, _)| l + 1))
            .max()
            .unwrap_or(0);
        let mut order: Vec<usize> = (0..params.len()).collect();
        order.sort_by_key(|&i| order_key(&params[i].name, n_layers, i));

        // Resolve each parameter's state segments and build the specs.
        let mut tasks = Vec::with_capacity(params.len());
        for &i in &order {
            let seg = params[i];
            ensure!(
                seg.shape.len() <= 2,
                "segment {} has rank {} > 2",
                seg.name,
                seg.shape.len()
            );
            ensure!(
                seg.offset + seg.size <= layout.params_len,
                "trainable segment {} outside the parameter region",
                seg.name
            );
            let cols = if seg.shape.len() == 2 { seg.shape[1] } else { 0 };
            let need = |suffix: &str| -> Result<SegRef> {
                let s = layout
                    .state_segment(&seg.name, suffix)
                    .with_context(|| {
                        format!(
                            "segment {} is missing optimizer state @{suffix}",
                            seg.name
                        )
                    })?;
                Ok(SegRef { offset: s.offset, size: s.size })
            };
            let state = match kind {
                OptKind::Sgd | OptKind::Lomo => StateSpec::None,
                OptKind::SgdMomentum => {
                    let m = need("m")?;
                    ensure!(m.size == seg.size, "{}@m size mismatch", seg.name);
                    StateSpec::M(m)
                }
                OptKind::SgdVariance => {
                    let v = need("v")?;
                    ensure!(v.size == seg.size, "{}@v size mismatch", seg.name);
                    StateSpec::V(v)
                }
                OptKind::AdamW => {
                    let m = need("m")?;
                    let v = need("v")?;
                    ensure!(
                        m.size == seg.size && v.size == seg.size,
                        "{}@m/@v size mismatch",
                        seg.name
                    );
                    StateSpec::Mv(m, v)
                }
                OptKind::Adafactor | OptKind::AdaLomo => {
                    if cols > 0 {
                        let r = need("r")?;
                        let c = need("c")?;
                        ensure!(
                            r.size == seg.shape[0] && c.size == cols,
                            "{}@r/@c size mismatch",
                            seg.name
                        );
                        StateSpec::Rc(r, c)
                    } else {
                        let v = need("v")?;
                        ensure!(
                            v.size == seg.size,
                            "{}@v size mismatch",
                            seg.name
                        );
                        StateSpec::V(v)
                    }
                }
            };
            tasks.push(TaskSpec {
                name: seg.name.clone(),
                offset: seg.offset,
                size: seg.size,
                cols,
                state,
                ranges: Vec::new(),
            });
        }

        // Fused-backward groups: consecutive tasks sharing a group key
        // (head block / same layer / embedding) collapse into one group.
        let mut groups: Vec<GroupSpec> = Vec::new();
        let mut prev_key: Option<(usize, usize)> = None;
        for (ti, task) in tasks.iter().enumerate() {
            let key = group_key(&task.name, n_layers, ti);
            if prev_key == Some(key) {
                let g = groups.last_mut().expect("group exists for prev_key");
                g.tasks.1 = ti + 1;
                g.lo = g.lo.min(task.offset);
                g.hi = g.hi.max(task.offset + task.size);
                g.elems += task.size;
            } else {
                groups.push(GroupSpec {
                    tasks: (ti, ti + 1),
                    lo: task.offset,
                    hi: task.offset + task.size,
                    elems: task.size,
                });
            }
            prev_key = Some(key);
        }

        // Contiguous plan: balanced global element boundaries over the
        // trainable region in fused order, snapped to row starts for 2-D
        // parameters so row-factor updates stay worker-disjoint.
        let total: usize = tasks.iter().map(|t| t.size).sum();
        let mut start = 0usize;
        for task in tasks.iter_mut() {
            let mut ranges = Vec::with_capacity(n_shards);
            for w in 0..n_shards {
                let b_lo = pool::range_bound(total, n_shards, w);
                let b_hi = pool::range_bound(total, n_shards, w + 1);
                let range = if task.cols > 0 {
                    let m = task.size / task.cols;
                    let r_lo = row_bound(start, task.cols, b_lo, m);
                    let r_hi = row_bound(start, task.cols, b_hi, m);
                    (r_lo * task.cols, r_hi * task.cols)
                } else {
                    let lo = b_lo.clamp(start, start + task.size) - start;
                    let hi = b_hi.clamp(start, start + task.size) - start;
                    (lo, hi)
                };
                ranges.push(range);
            }
            task.ranges = ranges;
            start += task.size;
        }

        // Segments plan: greedy LPT by task load (param + state floats),
        // each shard's list kept in fused order.
        let mut by_load: Vec<usize> = (0..tasks.len()).collect();
        let load = |t: &TaskSpec| {
            t.size
                + match &t.state {
                    StateSpec::None => 0,
                    StateSpec::M(s) | StateSpec::V(s) => s.size,
                    StateSpec::Mv(a, b) | StateSpec::Rc(a, b) => {
                        a.size + b.size
                    }
                }
        };
        by_load.sort_by_key(|&i| std::cmp::Reverse((load(&tasks[i]), i)));
        let mut shard_tasks: Vec<Vec<usize>> = vec![Vec::new(); n_shards];
        let mut shard_load = vec![0usize; n_shards];
        let mut owner = vec![0usize; tasks.len()];
        for i in by_load {
            let (w, _) = shard_load
                .iter()
                .enumerate()
                .min_by_key(|&(w, &l)| (l, w))
                .expect("n_shards >= 1");
            shard_load[w] += load(&tasks[i]);
            shard_tasks[w].push(i);
            owner[i] = w;
        }
        for list in shard_tasks.iter_mut() {
            list.sort_unstable();
        }

        let mut spans = build_spans(mode, &tasks, &owner);
        spans.retain(|s| s.len > 0);
        spans.sort_by_key(|s| s.offset);

        // The bf16 path needs every span inside the shardable prefix (the
        // region stored as raw bits) and always walks whole tasks, so its
        // spans are Segments-style whatever the configured mode.
        let shardable_len = layout.shardable_len();
        for task in &tasks {
            let (a, b) = state_refs(&task.state);
            for s in [a, b].into_iter().flatten() {
                ensure!(
                    s.offset + s.size <= shardable_len,
                    "state of segment {} reaches into the metrics region",
                    task.name
                );
            }
        }
        let mut bf16_spans = build_spans(ShardMode::Segments, &tasks, &owner);
        bf16_spans.retain(|s| s.len > 0);
        bf16_spans.sort_by_key(|s| s.offset);

        Ok(FlatOptimizer {
            kind,
            hyper,
            mode,
            n_shards,
            blob_len: layout.blob_len,
            params_len: layout.params_len,
            shardable_len,
            tasks,
            groups,
            shard_tasks,
            spans,
            bf16_spans,
            sync: SyncState::new(n_shards),
            scratch: vec![Scratch::default(); n_shards],
            bf16_scratch: vec![Bf16Scratch::default(); n_shards],
        })
    }

    pub fn kind(&self) -> OptKind {
        self.kind
    }

    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    pub fn mode(&self) -> ShardMode {
        self.mode
    }

    /// Trainable segment names in the order the engine visits them
    /// (fused-backward order).
    pub fn task_order(&self) -> Vec<&str> {
        self.tasks.iter().map(|t| t.name.as_str()).collect()
    }

    /// Number of trainable tasks (valid indices for [`Self::step_tasks`]).
    pub fn n_tasks(&self) -> usize {
        self.tasks.len()
    }

    /// `(blob offset, size)` of every task, indexed in fused-backward walk
    /// order — what a bucket scheduler needs to map reduced gradient
    /// ranges onto steppable tasks ([`crate::coordinator::pipeline`]).
    pub fn task_extents(&self) -> Vec<(usize, usize)> {
        self.tasks.iter().map(|t| (t.offset, t.size)).collect()
    }

    /// Trainable floats (the gradient-image length the step kernels read).
    pub fn params_len(&self) -> usize {
        self.params_len
    }

    /// Number of fused-backward groups: head block, layers L-1..0,
    /// embedding (G = L + 2 for a full transformer layout).
    pub fn n_groups(&self) -> usize {
        self.groups.len()
    }

    /// Fused-order task indices of group `g` (always a contiguous range —
    /// valid input for [`Self::step_tasks`]).
    pub fn group_tasks(&self, g: usize) -> std::ops::Range<usize> {
        let (a, b) = self.groups[g].tasks;
        a..b
    }

    /// Blob extent `[lo, hi)` of every fused-backward group, in walk
    /// order. For model-shaped layouts (the packing `synthetic_layout`
    /// and the AOT layouts use) these tile the trainable region in
    /// descending offset order — the invariant the fused-host pipeline
    /// checks before streaming buckets against group production.
    pub fn group_extents(&self) -> Vec<(usize, usize)> {
        self.groups.iter().map(|g| (g.lo, g.hi)).collect()
    }

    /// Per-group live-gradient sizes in f32 elements — the host-engine
    /// twin of `coordinator::fused::group_grad_sizes` (which derives the
    /// same numbers from a manifest) and of
    /// `memsim::liveness::group_elems` (which derives them analytically).
    pub fn group_grad_sizes(&self) -> Vec<usize> {
        self.groups.iter().map(|g| g.elems).collect()
    }

    /// First fused-order task index of group `g` — the task cursor a
    /// checkpoint taken at that group boundary records (`g == n_groups()`
    /// maps to the one-past-the-end cursor, i.e. a completed step). The
    /// engine's resume path validates a restored (group, task) cursor
    /// pair against this before trusting it.
    pub fn group_cursor_task(&self, g: usize) -> usize {
        if g >= self.groups.len() {
            self.tasks.len()
        } else {
            self.groups[g].tasks.0
        }
    }

    /// Step ONE fused-backward group from a gradient slice covering only
    /// that group's blob extent (`group_extents()[g]`). Because per-task
    /// arithmetic is self-contained, walking `step_group` over `0..
    /// n_groups()` with the same gradient values is bit-identical to one
    /// whole-image [`Self::step`] — but the caller never materializes more
    /// than one group's gradient, which is the paper's §2.1 liveness story
    /// on the host path (`coordinator::fused_host`).
    pub fn step_group(
        &mut self,
        blob: &mut [f32],
        g: usize,
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        ensure!(
            g < self.groups.len(),
            "group {g} out of range ({} groups)",
            self.groups.len()
        );
        let spec = self.groups[g];
        ensure!(
            blob.len() == self.blob_len,
            "blob len {} != layout {}",
            blob.len(),
            self.blob_len
        );
        ensure!(
            grads.len() == spec.hi - spec.lo,
            "group {g} grads len {} != extent {}",
            grads.len(),
            spec.hi - spec.lo
        );
        let subset: Vec<usize> = (spec.tasks.0..spec.tasks.1).collect();
        match self.mode {
            ShardMode::Segments => self.step_segments(
                blob,
                grads,
                spec.lo,
                t,
                lr,
                wd,
                Some(subset.as_slice()),
            ),
            ShardMode::Contiguous => self.step_contiguous(
                blob,
                grads,
                spec.lo,
                t,
                lr,
                wd,
                Some(subset.as_slice()),
            ),
        }
        Ok(())
    }

    /// One optimizer step over the flat blob, in place. `grads` is the
    /// gradient image of the parameter region (>= `params_len` floats,
    /// indexed by segment offset); `t` is the 1-based step, `lr` the
    /// scheduled rate, `wd` decoupled decay (AdamW only).
    pub fn step(
        &mut self,
        blob: &mut [f32],
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        self.validate(blob, grads)?;
        match self.mode {
            ShardMode::Segments => {
                self.step_segments(blob, grads, 0, t, lr, wd, None)
            }
            ShardMode::Contiguous => {
                self.step_contiguous(blob, grads, 0, t, lr, wd, None)
            }
        }
        Ok(())
    }

    /// Step only the tasks in `subset` (strictly-increasing indices into
    /// the fused-order task list, as reported by [`Self::task_extents`]).
    /// Each task's update is self-contained — grouped normalization and
    /// the factored reductions never cross task boundaries — so stepping a
    /// partition of the tasks across several calls is bit-identical to one
    /// whole-image [`Self::step`] with the same gradient values. That is
    /// the property the async rank pipeline rests on: a task becomes
    /// steppable the moment the last gradient bucket covering it has been
    /// reduced, while later buckets are still in flight.
    pub fn step_tasks(
        &mut self,
        blob: &mut [f32],
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
        subset: &[usize],
    ) -> Result<()> {
        self.validate(blob, grads)?;
        if !self.validate_subset(subset)? {
            return Ok(()); // empty subset: nothing to do, spawn no workers
        }
        match self.mode {
            ShardMode::Segments => {
                self.step_segments(blob, grads, 0, t, lr, wd, Some(subset))
            }
            ShardMode::Contiguous => {
                self.step_contiguous(blob, grads, 0, t, lr, wd, Some(subset))
            }
        }
        Ok(())
    }

    fn validate(&self, blob: &[f32], grads: &[f32]) -> Result<()> {
        ensure!(
            blob.len() == self.blob_len,
            "blob len {} != layout {}",
            blob.len(),
            self.blob_len
        );
        ensure!(
            grads.len() >= self.params_len,
            "grads len {} < params_len {}",
            grads.len(),
            self.params_len
        );
        Ok(())
    }

    /// Shared subset checks; `Ok(false)` means an empty (no-op) subset.
    fn validate_subset(&self, subset: &[usize]) -> Result<bool> {
        ensure!(
            subset.windows(2).all(|w| w[0] < w[1]),
            "task subset must be strictly increasing"
        );
        let Some(&last) = subset.last() else {
            return Ok(false);
        };
        ensure!(
            last < self.tasks.len(),
            "task index {last} out of range ({} tasks)",
            self.tasks.len()
        );
        Ok(true)
    }

    /// Convenience wrapper for [`HostBlob`]s.
    pub fn step_blob(
        &mut self,
        blob: &mut HostBlob,
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        self.step(&mut blob.data, grads, t, lr, wd)
    }

    // --- dtype-aware entry points -------------------------------------

    /// [`Self::step`] on a [`TypedBlob`]: f32 storage steps in place
    /// through the zero-copy paths; bf16 storage widens per task into
    /// bounded scratch, runs the identical slice kernels, and rounds the
    /// results back (see the module docs' dtype section).
    pub fn step_typed(
        &mut self,
        blob: &mut TypedBlob,
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        match blob.parts_mut() {
            BlobPartsMut::F32(data) => self.step(data, grads, t, lr, wd),
            BlobPartsMut::Bf16 { bits, tail } => {
                self.validate_bits(bits, tail.len(), grads)?;
                self.step_bf16(bits, grads, 0, t, lr, wd, None);
                Ok(())
            }
        }
    }

    /// [`Self::step_tasks`] on a [`TypedBlob`]. Per-task widen→kernel→
    /// round is self-contained, so any bucket partition of the tasks is
    /// bit-identical to one whole-image [`Self::step_typed`] — the same
    /// contract the async pipeline relies on at f32.
    pub fn step_tasks_typed(
        &mut self,
        blob: &mut TypedBlob,
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
        subset: &[usize],
    ) -> Result<()> {
        match blob.parts_mut() {
            BlobPartsMut::F32(data) => {
                self.step_tasks(data, grads, t, lr, wd, subset)
            }
            BlobPartsMut::Bf16 { bits, tail } => {
                self.validate_bits(bits, tail.len(), grads)?;
                if self.validate_subset(subset)? {
                    self.step_bf16(bits, grads, 0, t, lr, wd, Some(subset));
                }
                Ok(())
            }
        }
    }

    /// [`Self::step_group`] on a [`TypedBlob`] (gradient slice covering
    /// exactly the group's extent).
    pub fn step_group_typed(
        &mut self,
        blob: &mut TypedBlob,
        g: usize,
        grads: &[f32],
        t: u64,
        lr: f32,
        wd: f32,
    ) -> Result<()> {
        match blob.parts_mut() {
            BlobPartsMut::F32(data) => {
                self.step_group(data, g, grads, t, lr, wd)
            }
            BlobPartsMut::Bf16 { bits, tail } => {
                ensure!(
                    g < self.groups.len(),
                    "group {g} out of range ({} groups)",
                    self.groups.len()
                );
                let spec = self.groups[g];
                self.check_bits_len(bits, tail.len())?;
                ensure!(
                    grads.len() == spec.hi - spec.lo,
                    "group {g} grads len {} != extent {}",
                    grads.len(),
                    spec.hi - spec.lo
                );
                let subset: Vec<usize> =
                    (spec.tasks.0..spec.tasks.1).collect();
                self.step_bf16(
                    bits,
                    grads,
                    spec.lo,
                    t,
                    lr,
                    wd,
                    Some(subset.as_slice()),
                );
                Ok(())
            }
        }
    }

    /// The one spelling of the bf16 storage-shape check (`tail_len` is
    /// the f32 metrics tail the storage carries alongside the bits).
    fn check_bits_len(&self, bits: &[u16], tail_len: usize) -> Result<()> {
        ensure!(
            bits.len() == self.shardable_len
                && bits.len() + tail_len == self.blob_len,
            "bf16 blob ({} + {} elems) does not match the layout \
             (shardable {}, total {})",
            bits.len(),
            tail_len,
            self.shardable_len,
            self.blob_len
        );
        Ok(())
    }

    fn validate_bits(&self, bits: &[u16], tail_len: usize, grads: &[f32]) -> Result<()> {
        self.check_bits_len(bits, tail_len)?;
        ensure!(
            grads.len() >= self.params_len,
            "grads len {} < params_len {}",
            grads.len(),
            self.params_len
        );
        Ok(())
    }

    /// The bf16 walk: whole-task (Segments-style) LPT ownership whatever
    /// the configured mode; each worker widens its task's slices into its
    /// own scratch, steps, and rounds back.
    #[allow(clippy::too_many_arguments)]
    fn step_bf16(
        &mut self,
        bits: &mut [u16],
        grads: &[f32],
        grad_base: usize,
        t: u64,
        lr: f32,
        wd: f32,
        subset: Option<&[usize]>,
    ) {
        let parts = distribute(
            bits,
            &self.bf16_spans,
            self.n_shards,
            self.tasks.len(),
        );
        let kind = self.kind;
        let h = self.hyper;
        let tasks = &self.tasks;
        let shard_tasks = &self.shard_tasks;
        let mask = task_mask(self.tasks.len(), subset);
        let mask = &mask;
        let mut jobs = Vec::with_capacity(self.n_shards);
        for ((w, mut my_parts), scratch) in parts
            .into_iter()
            .enumerate()
            .zip(self.bf16_scratch.iter_mut())
        {
            let my = &shard_tasks[w];
            jobs.push(move || {
                for &ti in my {
                    if !mask[ti] {
                        continue;
                    }
                    let part = my_parts[ti].reborrow();
                    run_task_bf16(
                        &tasks[ti], part, grads, grad_base, kind, h, t, lr,
                        wd, scratch,
                    );
                }
            });
        }
        pool::run_jobs(jobs);
    }

    /// Measured peak widen/round scratch (f32 elements) any worker ever
    /// staged for one bf16 task — parameter + state slices plus the
    /// kernels' `u` buffer. Grows monotonically across steps.
    ///
    /// Precisely: this is the largest SINGLE-TASK staging. The per-slot
    /// buffers (`theta`/`a`/`b`/`u`) are reused across tasks, so a
    /// worker's resident scratch is the per-slot high-water marks — each
    /// individually bounded by this peak, and in model-shaped layouts
    /// all dominated by the same largest task, so resident ≈ peak. What
    /// can never happen is a full-image f32 mirror: every buffer is
    /// task-sized.
    pub fn bf16_peak_scratch_elems(&self) -> usize {
        self.bf16_scratch.iter().map(|s| s.peak_elems).max().unwrap_or(0)
    }

    /// Analytic bound the measured peak is pinned against. Factored
    /// kinds (AdaLomo/Adafactor) stage the largest single task whole —
    /// `theta + state + u` — because their rms/factor reductions need
    /// all of `u` at once. Elementwise kinds step in [`BF16_TILE`]-sized
    /// cache blocks, so their staging is one tile per live slice
    /// regardless of task size. Either way the bound sits far below a
    /// full-image f32 mirror (`shardable_len` elements) for model-shaped
    /// layouts — the "bounded scratch" half of the bf16 memory claim.
    pub fn bf16_scratch_bound_elems(&self) -> usize {
        self.tasks
            .iter()
            .map(|task| {
                let (a, b) = state_refs(&task.state);
                match self.kind {
                    OptKind::AdaLomo | OptKind::Adafactor => {
                        let state = a.map_or(0, |s| s.size)
                            + b.map_or(0, |s| s.size);
                        task.size + state + task.size
                    }
                    _ => {
                        let slices = 1
                            + usize::from(a.is_some())
                            + usize::from(b.is_some());
                        task.size.min(BF16_TILE) * slices
                    }
                }
            })
            .max()
            .unwrap_or(0)
    }

    /// `grad_base` is the blob offset `grads[0]` corresponds to: 0 for the
    /// whole-image entry points, the group extent start for `step_group`.
    #[allow(clippy::too_many_arguments)]
    fn step_segments(
        &mut self,
        blob: &mut [f32],
        grads: &[f32],
        grad_base: usize,
        t: u64,
        lr: f32,
        wd: f32,
        subset: Option<&[usize]>,
    ) {
        let parts =
            distribute(blob, &self.spans, self.n_shards, self.tasks.len());
        let kind = self.kind;
        let h = self.hyper;
        let tasks = &self.tasks;
        let shard_tasks = &self.shard_tasks;
        let mask = task_mask(self.tasks.len(), subset);
        let mask = &mask;
        let mut jobs = Vec::with_capacity(self.n_shards);
        for ((w, mut my_parts), scratch) in
            parts.into_iter().enumerate().zip(self.scratch.iter_mut())
        {
            let my = &shard_tasks[w];
            jobs.push(move || {
                for &ti in my {
                    if !mask[ti] {
                        continue;
                    }
                    let part = my_parts[ti].reborrow();
                    run_task_sequential(
                        &tasks[ti], part, grads, grad_base, kind, h, t, lr,
                        wd, scratch,
                    );
                }
            });
        }
        pool::run_jobs(jobs);
    }

    #[allow(clippy::too_many_arguments)]
    fn step_contiguous(
        &mut self,
        blob: &mut [f32],
        grads: &[f32],
        grad_base: usize,
        t: u64,
        lr: f32,
        wd: f32,
        subset: Option<&[usize]>,
    ) {
        let parts =
            distribute(blob, &self.spans, self.n_shards, self.tasks.len());
        let sync_ref = &self.sync;
        let kind = self.kind;
        let h = self.hyper;
        let tasks = &self.tasks;
        let mut jobs = Vec::with_capacity(self.n_shards);
        for ((w, mut my_parts), scratch) in
            parts.into_iter().enumerate().zip(self.scratch.iter_mut())
        {
            jobs.push(move || {
                run_worker_contiguous(
                    tasks, &mut my_parts, subset, grads, grad_base, kind, h,
                    t, lr, wd, w, sync_ref, scratch,
                );
            });
        }
        pool::run_jobs(jobs);
    }

    // --- persistent step sessions -------------------------------------

    /// Run `body` with a persistent [`StepSession`]: the blob is split
    /// across workers ONCE, a [`pool::crew`] parks one worker per shard,
    /// and every [`StepSession::step`] is then a zero-allocation,
    /// zero-spawn dispatch round. Workers re-read `grads` at the start of
    /// each round, so the caller refills the gradient buffer between
    /// steps through the `RwLock`; the crew's control handshake orders
    /// those writes before the next round's reads. Results are
    /// bit-identical to calling [`Self::step`] in a loop — partitioning,
    /// kernel dispatch, and arithmetic are all shared with the classic
    /// path, only the thread/allocation choreography differs.
    pub fn session<R>(
        &mut self,
        blob: &mut [f32],
        grads: &RwLock<Vec<f32>>,
        body: impl FnOnce(&mut StepSession<'_, '_>) -> R,
    ) -> Result<R> {
        {
            let g = grads.read().unwrap_or_else(|e| e.into_inner());
            self.validate(blob, &g[..])?;
        }
        let parts =
            distribute(blob, &self.spans, self.n_shards, self.tasks.len());
        let mode = self.mode;
        let kind = self.kind;
        let h = self.hyper;
        let tasks = &self.tasks;
        let shard_tasks = &self.shard_tasks;
        let sync_ref = &self.sync;
        let cmd = Mutex::new(StepCmd::default());
        let cmd_ref = &cmd;
        let mut jobs: Vec<Box<dyn FnMut() + Send + '_>> =
            Vec::with_capacity(self.n_shards);
        for ((w, mut my_parts), scratch) in
            parts.into_iter().enumerate().zip(self.scratch.iter_mut())
        {
            let my = &shard_tasks[w];
            jobs.push(Box::new(move || {
                // ANALYZE-HOT: session worker round (f32)
                let c = *cmd_ref.lock().unwrap_or_else(|e| e.into_inner());
                let g = grads.read().unwrap_or_else(|e| e.into_inner());
                let g = &g[..];
                match mode {
                    ShardMode::Segments => {
                        for &ti in my {
                            run_task_sequential(
                                &tasks[ti],
                                my_parts[ti].reborrow(),
                                g,
                                0,
                                kind,
                                h,
                                c.t,
                                c.lr,
                                c.wd,
                                scratch,
                            );
                        }
                    }
                    ShardMode::Contiguous => {
                        run_worker_contiguous(
                            tasks, &mut my_parts, None, g, 0, kind, h, c.t,
                            c.lr, c.wd, w, sync_ref, scratch,
                        );
                    }
                }
                // ANALYZE-HOT-END
            }));
        }
        Ok(pool::crew(jobs, move |crew| {
            let mut s = StepSession { crew, cmd: cmd_ref };
            body(&mut s)
        }))
    }

    /// [`Self::session`] on a [`TypedBlob`]: f32 storage reuses the
    /// zero-copy session above; bf16 storage parks the crew over the bit
    /// spans and runs the fused widen→step→round path every round.
    /// Bit-identical to looping [`Self::step_typed`].
    pub fn session_typed<R>(
        &mut self,
        blob: &mut TypedBlob,
        grads: &RwLock<Vec<f32>>,
        body: impl FnOnce(&mut StepSession<'_, '_>) -> R,
    ) -> Result<R> {
        match blob.parts_mut() {
            BlobPartsMut::F32(data) => self.session(data, grads, body),
            BlobPartsMut::Bf16 { bits, tail } => {
                {
                    let g = grads.read().unwrap_or_else(|e| e.into_inner());
                    self.validate_bits(bits, tail.len(), &g[..])?;
                }
                let parts = distribute(
                    bits,
                    &self.bf16_spans,
                    self.n_shards,
                    self.tasks.len(),
                );
                let kind = self.kind;
                let h = self.hyper;
                let tasks = &self.tasks;
                let shard_tasks = &self.shard_tasks;
                let cmd = Mutex::new(StepCmd::default());
                let cmd_ref = &cmd;
                let mut jobs: Vec<Box<dyn FnMut() + Send + '_>> =
                    Vec::with_capacity(self.n_shards);
                for ((w, mut my_parts), scratch) in parts
                    .into_iter()
                    .enumerate()
                    .zip(self.bf16_scratch.iter_mut())
                {
                    let my = &shard_tasks[w];
                    jobs.push(Box::new(move || {
                        // ANALYZE-HOT: session worker round (bf16)
                        let c = *cmd_ref
                            .lock()
                            .unwrap_or_else(|e| e.into_inner());
                        let g =
                            grads.read().unwrap_or_else(|e| e.into_inner());
                        for &ti in my {
                            run_task_bf16(
                                &tasks[ti],
                                my_parts[ti].reborrow(),
                                &g[..],
                                0,
                                kind,
                                h,
                                c.t,
                                c.lr,
                                c.wd,
                                scratch,
                            );
                        }
                        // ANALYZE-HOT-END
                    }));
                }
                Ok(pool::crew(jobs, move |crew| {
                    let mut s = StepSession { crew, cmd: cmd_ref };
                    body(&mut s)
                }))
            }
        }
    }
}

/// One step's scalar knobs, broadcast to the crew through a mutex the
/// leader writes before each dispatch round.
#[derive(Clone, Copy, Default)]
struct StepCmd {
    t: u64,
    lr: f32,
    wd: f32,
}

/// Handle the [`FlatOptimizer::session`] body drives: each
/// [`StepSession::step`] publishes the step knobs and runs one crew
/// round over the already-distributed blob parts — no allocation, no
/// thread spawn, no re-splitting of the blob.
pub struct StepSession<'c, 'env> {
    crew: &'c mut pool::Crew<'env>,
    cmd: &'c Mutex<StepCmd>,
}

impl StepSession<'_, '_> {
    /// One optimizer step (same contract as [`FlatOptimizer::step`]:
    /// `t` is the 1-based step index). Errors if any worker panicked;
    /// the crew stays usable for later rounds either way.
    pub fn step(&mut self, t: u64, lr: f32, wd: f32) -> Result<()> {
        // ANALYZE-HOT: session step dispatch
        {
            let mut c = self.cmd.lock().unwrap_or_else(|e| e.into_inner());
            *c = StepCmd { t, lr, wd };
        }
        self.crew.round()
        // ANALYZE-HOT-END
    }
}

/// Dense membership mask for a task subset (`None` = every task).
fn task_mask(n_tasks: usize, subset: Option<&[usize]>) -> Vec<bool> {
    match subset {
        None => vec![true; n_tasks],
        Some(list) => {
            let mut mask = vec![false; n_tasks];
            for &ti in list {
                mask[ti] = true;
            }
            mask
        }
    }
}

fn state_refs(state: &StateSpec) -> (Option<SegRef>, Option<SegRef>) {
    match state {
        StateSpec::None => (None, None),
        StateSpec::M(s) | StateSpec::V(s) => (Some(*s), None),
        StateSpec::Mv(a, b) | StateSpec::Rc(a, b) => (Some(*a), Some(*b)),
    }
}

/// Layout-static blob spans for a shard mode — computed once at
/// construction; `step` re-splits each borrowed blob along them.
fn build_spans(mode: ShardMode, tasks: &[TaskSpec], owner: &[usize]) -> Vec<Span> {
    let mut spans = Vec::new();
    match mode {
        ShardMode::Segments => {
            for (ti, task) in tasks.iter().enumerate() {
                let w = owner[ti];
                spans.push(Span {
                    offset: task.offset,
                    len: task.size,
                    task: ti,
                    worker: w,
                    role: ROLE_THETA,
                });
                let (a, b) = state_refs(&task.state);
                if let Some(s) = a {
                    spans.push(Span {
                        offset: s.offset,
                        len: s.size,
                        task: ti,
                        worker: w,
                        role: ROLE_A,
                    });
                }
                if let Some(s) = b {
                    spans.push(Span {
                        offset: s.offset,
                        len: s.size,
                        task: ti,
                        worker: w,
                        role: ROLE_B,
                    });
                }
            }
        }
        ShardMode::Contiguous => {
            for (ti, task) in tasks.iter().enumerate() {
                for (w, &(lo, hi)) in task.ranges.iter().enumerate() {
                    if hi > lo {
                        spans.push(Span {
                            offset: task.offset + lo,
                            len: hi - lo,
                            task: ti,
                            worker: w,
                            role: ROLE_THETA,
                        });
                    }
                    match &task.state {
                        StateSpec::None => {}
                        StateSpec::M(s) | StateSpec::V(s) => {
                            if hi > lo {
                                spans.push(Span {
                                    offset: s.offset + lo,
                                    len: hi - lo,
                                    task: ti,
                                    worker: w,
                                    role: ROLE_A,
                                });
                            }
                        }
                        StateSpec::Mv(m, v) => {
                            if hi > lo {
                                spans.push(Span {
                                    offset: m.offset + lo,
                                    len: hi - lo,
                                    task: ti,
                                    worker: w,
                                    role: ROLE_A,
                                });
                                spans.push(Span {
                                    offset: v.offset + lo,
                                    len: hi - lo,
                                    task: ti,
                                    worker: w,
                                    role: ROLE_B,
                                });
                            }
                        }
                        StateSpec::Rc(r, c) => {
                            let n = task.cols;
                            if hi > lo {
                                spans.push(Span {
                                    offset: r.offset + lo / n,
                                    len: (hi - lo) / n,
                                    task: ti,
                                    worker: w,
                                    role: ROLE_A,
                                });
                            }
                            if w == 0 {
                                spans.push(Span {
                                    offset: c.offset,
                                    len: c.size,
                                    task: ti,
                                    worker: 0,
                                    role: ROLE_B,
                                });
                            }
                        }
                    }
                }
            }
        }
    }
    spans
}

/// First row of the task whose start element (global index `s + r*n`) is
/// at or past the boundary `b`, clamped to `m` rows.
fn row_bound(s: usize, n: usize, b: usize, m: usize) -> usize {
    if b <= s {
        0
    } else {
        ((b - s + n - 1) / n).min(m)
    }
}

fn parse_layer(name: &str) -> Option<(usize, &str)> {
    let rest = name.strip_prefix('l')?;
    let dot = rest.find('.')?;
    let layer: usize = rest[..dot].parse().ok()?;
    Some((layer, &rest[dot + 1..]))
}

/// Sort key realizing the fused-backward walk: head (+final_norm), layers
/// L-1..0 (members in `LAYER_MEMBERS` order), embedding; segments outside
/// the model naming convention follow in their layout order.
fn order_key(name: &str, n_layers: usize, fallback: usize) -> (usize, usize, usize) {
    match name {
        "head" => (0, 0, 0),
        "final_norm" => (0, 1, 0),
        "embed" => (2, 0, 0),
        _ => match parse_layer(name) {
            Some((layer, member)) => {
                let mi = LAYER_MEMBERS
                    .iter()
                    .position(|&m| m == member)
                    .unwrap_or(LAYER_MEMBERS.len());
                (1, n_layers - 1 - layer, mi)
            }
            None => (3, fallback, 0),
        },
    }
}

/// Group identity for the fused-backward walk: the head block (head +
/// final_norm) is one group, each layer is one group, the embedding is one
/// group; segments outside the naming convention become singleton groups
/// (keyed by their unique fused-order index).
fn group_key(name: &str, n_layers: usize, fallback: usize) -> (usize, usize) {
    let (tier, sub, _) = order_key(name, n_layers, fallback);
    match tier {
        0 => (0, 0),
        3 => (3, fallback),
        t => (t, sub),
    }
}

/// Split `blob` into disjoint mutable views at the given spans (already
/// offset-sorted, zero-length-free) and hand each to its (worker, task,
/// role) slot. Generic over the element type: `f32` blobs for the
/// in-place paths, raw bf16 bits (`u16`) for the widen/round path.
fn distribute<'b, T: Default>(
    blob: &'b mut [T],
    spans: &[Span],
    n_workers: usize,
    n_tasks: usize,
) -> Vec<Vec<TaskPart<'b, T>>> {
    let mut parts: Vec<Vec<TaskPart<'b, T>>> = (0..n_workers)
        .map(|_| (0..n_tasks).map(|_| TaskPart::default()).collect())
        .collect();
    let mut rest: &'b mut [T] = blob;
    let mut cursor = 0usize;
    for s in spans {
        assert!(s.offset >= cursor, "overlapping blob spans");
        let tmp = rest;
        let (_, after) = tmp.split_at_mut(s.offset - cursor);
        let (piece, tail) = after.split_at_mut(s.len);
        rest = tail;
        cursor = s.offset + s.len;
        let slot = &mut parts[s.worker][s.task];
        match s.role {
            ROLE_THETA => slot.theta = Some(piece),
            ROLE_A => slot.a = Some(piece),
            _ => slot.b = Some(piece),
        }
    }
    parts
}


/// Segments-mode task runner: the whole tensor on one worker, via the
/// full slice kernels (identical arithmetic to `ParamOpt::step`).
#[allow(clippy::too_many_arguments)]
fn run_task_sequential(
    spec: &TaskSpec,
    part: TaskPart<'_>,
    grads: &[f32],
    grad_base: usize,
    kind: OptKind,
    h: Hyper,
    t: u64,
    lr: f32,
    wd: f32,
    scratch: &mut Scratch,
) {
    let base = spec.offset - grad_base;
    let g = &grads[base..base + spec.size];
    let theta = part.theta.expect("theta view assigned to owner");
    let a = part.a;
    let b = part.b;
    // ANALYZE-HOT: flat kernel dispatch
    match kind {
        OptKind::Sgd | OptKind::Lomo => update::sgd_slice(theta, g, lr),
        OptKind::SgdMomentum => {
            update::sgd_momentum_slice(theta, g, a.unwrap(), t, lr, h);
        }
        OptKind::SgdVariance => {
            update::sgd_variance_slice(theta, g, a.unwrap(), t, lr, h);
        }
        OptKind::AdamW => {
            update::adamw_slice(theta, g, a.unwrap(), b.unwrap(), t, lr, wd, h);
        }
        OptKind::AdaLomo => {
            scratch.ensure_u(spec.size);
            let u = &mut scratch.u[..spec.size];
            if spec.cols > 0 {
                update::adalomo_2d_slice(
                    theta,
                    g,
                    spec.cols,
                    a.unwrap(),
                    b.unwrap(),
                    t,
                    lr,
                    h,
                    u,
                );
            } else {
                update::adalomo_vec_slice(theta, g, a.unwrap(), t, lr, h, u);
            }
        }
        OptKind::Adafactor => {
            scratch.ensure_u(spec.size);
            let u = &mut scratch.u[..spec.size];
            if spec.cols > 0 {
                update::adafactor_2d_slice(
                    theta,
                    g,
                    spec.cols,
                    a.unwrap(),
                    b.unwrap(),
                    t,
                    lr,
                    h,
                    u,
                );
            } else {
                update::adafactor_vec_slice(theta, g, a.unwrap(), t, lr, h, u);
            }
        }
    }
    // ANALYZE-HOT-END
}

/// Cache-block size (f32 elements) for the fused bf16
/// widen→step→round path. 4096 elements keeps the staged tile plus its
/// state slices inside L1/L2 while amortizing loop overhead; every tile
/// boundary is a pure data-position split, so tiling cannot move any
/// element to a different arithmetic order.
pub const BF16_TILE: usize = 4096;

/// bf16-mode task runner. Elementwise kinds fuse widen→step→round into
/// [`BF16_TILE`]-sized cache blocks — the staged f32 working set per
/// tile is one tile per live slice instead of the whole task. Factored
/// kinds (AdaLomo/Adafactor) stage the whole task, because their
/// rms/factor reductions consume all of `u` at once and splitting them
/// would change the blessed reduction order. Both paths run identical
/// arithmetic to the Segments-mode f32 path and round back with
/// round-to-nearest-even; the measured scratch peak tracks whichever
/// staging the task actually used.
#[allow(clippy::too_many_arguments)]
fn run_task_bf16(
    spec: &TaskSpec,
    part: TaskPart<'_, u16>,
    grads: &[f32],
    grad_base: usize,
    kind: OptKind,
    h: Hyper,
    t: u64,
    lr: f32,
    wd: f32,
    scratch: &mut Bf16Scratch,
) {
    let theta_bits = part.theta.expect("theta bits assigned to owner");
    match kind {
        OptKind::AdaLomo | OptKind::Adafactor => run_task_bf16_whole(
            spec, theta_bits, part.a, part.b, grads, grad_base, kind, h, t,
            lr, wd, scratch,
        ),
        _ => run_task_bf16_tiled(
            spec, theta_bits, part.a, part.b, grads, grad_base, kind, h, t,
            lr, wd, scratch,
        ),
    }
}

/// Whole-task staging (factored kinds): widen every slice, run the
/// ordinary whole-task kernel, round everything back.
#[allow(clippy::too_many_arguments)]
fn run_task_bf16_whole(
    spec: &TaskSpec,
    theta_bits: &mut [u16],
    mut a_bits: Option<&mut [u16]>,
    mut b_bits: Option<&mut [u16]>,
    grads: &[f32],
    grad_base: usize,
    kind: OptKind,
    h: Hyper,
    t: u64,
    lr: f32,
    wd: f32,
    scratch: &mut Bf16Scratch,
) {
    let Bf16Scratch { theta, a, b, inner, peak_elems } = scratch;

    let an = a_bits.as_deref().map_or(0, |s| s.len());
    let bn = b_bits.as_deref().map_or(0, |s| s.len());
    let u_elems = match kind {
        OptKind::AdaLomo | OptKind::Adafactor => spec.size,
        _ => 0,
    };
    *peak_elems = (*peak_elems).max(spec.size + an + bn + u_elems);

    // Widen-on-read into the reusable staging buffers.
    widen_bf16_into(theta_bits, theta);
    let mut fa: Option<&mut [f32]> = None;
    if let Some(src) = a_bits.as_deref() {
        widen_bf16_into(src, a);
        fa = Some(&mut a[..]);
    }
    let mut fb: Option<&mut [f32]> = None;
    if let Some(src) = b_bits.as_deref() {
        widen_bf16_into(src, b);
        fb = Some(&mut b[..]);
    }

    run_task_sequential(
        spec,
        TaskPart { theta: Some(&mut theta[..]), a: fa, b: fb },
        grads,
        grad_base,
        kind,
        h,
        t,
        lr,
        wd,
        inner,
    );

    // Round-to-nearest-even on write-back.
    round_bf16_slice(theta, theta_bits);
    if let Some(dst) = a_bits.as_deref_mut() {
        round_bf16_slice(&a[..dst.len()], dst);
    }
    if let Some(dst) = b_bits.as_deref_mut() {
        round_bf16_slice(&b[..dst.len()], dst);
    }
}

/// Fused tile staging (elementwise kinds): per cache block, widen the
/// theta/state tiles, dispatch the slice kernel directly on them, and
/// round the same tiles straight back. Elementwise kernels touch each
/// index independently, so per-tile dispatch is bit-identical to the
/// whole-task call — the tile boundary is a data-position split, never
/// an arithmetic one.
#[allow(clippy::too_many_arguments)]
fn run_task_bf16_tiled(
    spec: &TaskSpec,
    theta_bits: &mut [u16],
    mut a_bits: Option<&mut [u16]>,
    mut b_bits: Option<&mut [u16]>,
    grads: &[f32],
    grad_base: usize,
    kind: OptKind,
    h: Hyper,
    t: u64,
    lr: f32,
    wd: f32,
    scratch: &mut Bf16Scratch,
) {
    let Bf16Scratch { theta, a, b, inner: _, peak_elems } = scratch;
    let n = spec.size;
    let slices = 1
        + usize::from(a_bits.is_some())
        + usize::from(b_bits.is_some());
    *peak_elems = (*peak_elems).max(n.min(BF16_TILE) * slices);
    let base = spec.offset - grad_base;

    // ANALYZE-HOT: fused bf16 tile loop
    let mut lo = 0usize;
    while lo < n {
        let hi = (lo + BF16_TILE).min(n);
        let g = &grads[base + lo..base + hi];
        widen_bf16_into(&theta_bits[lo..hi], theta);
        let mut fa: Option<&mut [f32]> = None;
        if let Some(src) = a_bits.as_deref() {
            widen_bf16_into(&src[lo..hi], a);
            fa = Some(&mut a[..]);
        }
        let mut fb: Option<&mut [f32]> = None;
        if let Some(src) = b_bits.as_deref() {
            widen_bf16_into(&src[lo..hi], b);
            fb = Some(&mut b[..]);
        }
        match kind {
            OptKind::Sgd | OptKind::Lomo => {
                update::sgd_slice(theta, g, lr);
            }
            OptKind::SgdMomentum => {
                if let Some(m) = fa {
                    update::sgd_momentum_slice(theta, g, m, t, lr, h);
                }
            }
            OptKind::SgdVariance => {
                if let Some(v) = fa {
                    update::sgd_variance_slice(theta, g, v, t, lr, h);
                }
            }
            OptKind::AdamW => {
                if let (Some(m), Some(v)) = (fa, fb) {
                    update::adamw_slice(theta, g, m, v, t, lr, wd, h);
                }
            }
            // Routed to `run_task_bf16_whole` by the dispatcher.
            OptKind::AdaLomo | OptKind::Adafactor => {
                debug_assert!(false, "factored kind on the tiled bf16 path");
            }
        }
        round_bf16_slice(theta, &mut theta_bits[lo..hi]);
        if let Some(dst) = a_bits.as_deref_mut() {
            round_bf16_slice(&a[..], &mut dst[lo..hi]);
        }
        if let Some(dst) = b_bits.as_deref_mut() {
            round_bf16_slice(&b[..], &mut dst[lo..hi]);
        }
        lo = hi;
    }
    // ANALYZE-HOT-END
}

/// Contiguous-mode worker: walks the selected tasks in fused order
/// (`subset: None` = all of them); elementwise rules need no
/// synchronization, factored rules run the two-pass reductions described
/// in the module docs. Every worker walks the identical task sequence and
/// executes the same barrier sequence per task (empty ranges included), so
/// the barrier counts always line up.
#[allow(clippy::too_many_arguments)]
fn run_worker_contiguous(
    specs: &[TaskSpec],
    parts: &mut [TaskPart<'_>],
    subset: Option<&[usize]>,
    grads: &[f32],
    grad_base: usize,
    kind: OptKind,
    h: Hyper,
    t: u64,
    lr: f32,
    wd: f32,
    w: usize,
    sync: &SyncState,
    scratch: &mut Scratch,
) {
    match subset {
        None => {
            for (spec, part) in specs.iter().zip(parts.iter_mut()) {
                contiguous_task(
                    spec, part.reborrow(), grads, grad_base, kind, h, t, lr,
                    wd, w, sync, scratch,
                );
            }
        }
        Some(list) => {
            for &ti in list {
                let part = parts[ti].reborrow();
                contiguous_task(
                    &specs[ti],
                    part,
                    grads,
                    grad_base,
                    kind,
                    h,
                    t,
                    lr,
                    wd,
                    w,
                    sync,
                    scratch,
                );
            }
        }
    }
}

/// One contiguous-mode task on one worker (the body shared by the full
/// walk and the subset walk).
#[allow(clippy::too_many_arguments)]
fn contiguous_task(
    spec: &TaskSpec,
    part: TaskPart<'_>,
    grads: &[f32],
    grad_base: usize,
    kind: OptKind,
    h: Hyper,
    t: u64,
    lr: f32,
    wd: f32,
    w: usize,
    sync: &SyncState,
    scratch: &mut Scratch,
) {
    let (lo, hi) = spec.ranges[w];
    let len = hi - lo;
    let base = spec.offset - grad_base;
    let g = &grads[base + lo..base + hi];
    let theta = part.theta.unwrap_or_default();
    let a = part.a.unwrap_or_default();
    let b = part.b.unwrap_or_default();
    match kind {
        OptKind::Sgd | OptKind::Lomo => {
            if len > 0 {
                update::sgd_slice(theta, g, lr);
            }
        }
        OptKind::SgdMomentum => {
            if len > 0 {
                update::sgd_momentum_slice(theta, g, a, t, lr, h);
            }
        }
        OptKind::SgdVariance => {
            if len > 0 {
                update::sgd_variance_slice(theta, g, a, t, lr, h);
            }
        }
        OptKind::AdamW => {
            if len > 0 {
                update::adamw_slice(theta, g, a, b, t, lr, wd, h);
            }
        }
        OptKind::AdaLomo | OptKind::Adafactor if spec.cols == 0 => {
            // Factored-vector path: full second moment `v` in `a`.
            scratch.ensure_u(len);
            let u = &mut scratch.u[..len];
            if len > 0 {
                if kind == OptKind::AdaLomo {
                    let bias = update::bias_correction(h.adalomo_beta, t);
                    update::adalomo_vec_raw(g, a, bias, h, u);
                } else {
                    let beta2t =
                        update::adafactor_beta2t(h.adafactor_decay_pow, t);
                    update::adafactor_vec_raw(g, a, beta2t, h, u);
                }
            }
            sync.post_scalars(w, sum_sq(u), sum_sq(theta));
            sync.wait();
            if w == 0 {
                sync.with_slots(|sl| {
                    let f = apply_factor(kind, h, lr, spec.size, sl);
                    sl.scale = f;
                });
            }
            sync.wait();
            let f = sync.read_scale();
            for (thi, &ui) in theta.iter_mut().zip(u.iter()) {
                *thi -= f * ui;
            }
        }
        OptKind::AdaLomo | OptKind::Adafactor => {
            // Factored 2-D path: r rows in `a`, whole c on worker 0
            // in `b`.
            let n = spec.cols;
            let (beta, floor) = if kind == OptKind::AdaLomo {
                (h.adalomo_beta, 0.0)
            } else {
                (
                    update::adafactor_beta2t(h.adafactor_decay_pow, t),
                    h.adafactor_eps1,
                )
            };
            // Phase A: disjoint row-factor updates + per-worker column
            // accumulators.
            scratch.zero_cvec(n);
            if len > 0 {
                update::factor_rows(g, n, a, &mut scratch.cvec, beta, floor);
            }
            let sum_r_part: f32 = a.iter().sum();
            sync.swap_cvec(w, &mut scratch.cvec);
            sync.post_scalars(w, sum_r_part, 0.0);
            sync.wait();
            // Combine (worker 0): c <- beta*c + Σ_w acc_w, publish it,
            // and fold sum_r + bias into the raw-u multiplier.
            if w == 0 {
                sync.with_slots(|sl| {
                    for (j, cj) in b.iter_mut().enumerate() {
                        let mut acc = beta * *cj;
                        for cv in &sl.cvecs {
                            acc += cv[j];
                        }
                        *cj = acc;
                    }
                    sl.c_combined.clear();
                    sl.c_combined.extend_from_slice(b);
                    let sum_r: f32 = sl.pa.iter().sum();
                    sl.aux = if kind == OptKind::AdaLomo {
                        let bias =
                            update::bias_correction(h.adalomo_beta, t);
                        1.0 / (sum_r.max(h.eps_div) * bias)
                    } else {
                        1.0 / sum_r.max(h.adafactor_eps1)
                    };
                });
            }
            sync.wait();
            // Phase B: raw u over the worker's rows + RMS partials.
            let inv_sum = sync.read_aux();
            sync.copy_combined_c(&mut scratch.cbuf);
            scratch.ensure_u(len);
            let u = &mut scratch.u[..len];
            if len > 0 {
                let (eps, no_sqrt) = if kind == OptKind::AdaLomo {
                    (h.eps_div, h.no_sqrt)
                } else {
                    (h.adafactor_eps1, false)
                };
                update::raw_u_rows(
                    g,
                    n,
                    a,
                    &scratch.cbuf,
                    inv_sum,
                    eps,
                    no_sqrt,
                    u,
                );
            }
            sync.post_scalars(w, sum_sq(u), sum_sq(theta));
            sync.wait();
            if w == 0 {
                sync.with_slots(|sl| {
                    let f = apply_factor(kind, h, lr, spec.size, sl);
                    sl.scale = f;
                });
            }
            sync.wait();
            // Phase C: single scale-and-apply pass.
            let f = sync.read_scale();
            for (thi, &ui) in theta.iter_mut().zip(u.iter()) {
                *thi -= f * ui;
            }
        }
    }
}

/// Final apply factor from the combined RMS partials: grouped update
/// normalization (AdaLomo, Algorithm 1 line 11) or update clipping +
/// relative step (Adafactor).
fn apply_factor(kind: OptKind, h: Hyper, lr: f32, size: usize, sl: &Slots) -> f32 {
    let size = size as f32;
    let rms_u = (sl.pa.iter().sum::<f32>() / size).sqrt();
    let rms_theta = (sl.pb.iter().sum::<f32>() / size).sqrt();
    if kind == OptKind::AdaLomo {
        lr * (h.eps_rms.max(rms_theta) / 1.0f32.max(rms_u))
    } else {
        let clip = 1.0f32.max(rms_u / h.adafactor_clip_d);
        h.adafactor_eps2.max(rms_theta) * lr / clip
    }
}

/// Build a synthetic [`Layout`] for `kind` over `params` — segment naming
/// and packing exactly as `python/compile/layout.py`: parameters first,
/// then per-parameter state with `@m/@v/@r/@c` suffixes, then the 8-slot
/// metrics region. Benches, examples and the parity proptests use this to
/// exercise the engine without AOT artifacts.
pub fn synthetic_layout(kind: OptKind, params: &[(&str, &[usize])]) -> Layout {
    let mut segments = Vec::new();
    let mut off = 0usize;
    for &(name, shape) in params {
        let size: usize = shape.iter().product();
        segments.push(Segment {
            name: name.to_string(),
            kind: "param".to_string(),
            shape: shape.to_vec(),
            offset: off,
            size,
            dtype: Dtype::F32,
        });
        off += size;
    }
    let params_len = off;
    for &(name, shape) in params {
        let states: Vec<(&str, Vec<usize>)> = match kind {
            OptKind::Sgd | OptKind::Lomo => vec![],
            OptKind::SgdMomentum => vec![("m", shape.to_vec())],
            OptKind::SgdVariance => vec![("v", shape.to_vec())],
            OptKind::AdamW => {
                vec![("m", shape.to_vec()), ("v", shape.to_vec())]
            }
            OptKind::Adafactor | OptKind::AdaLomo => {
                if shape.len() == 2 {
                    vec![("r", vec![shape[0]]), ("c", vec![shape[1]])]
                } else {
                    vec![("v", shape.to_vec())]
                }
            }
        };
        for (suffix, sshape) in states {
            let ssize: usize = sshape.iter().product();
            segments.push(Segment {
                name: format!("{name}@{suffix}"),
                kind: "state".to_string(),
                shape: sshape,
                offset: off,
                size: ssize,
                dtype: Dtype::F32,
            });
            off += ssize;
        }
    }
    segments.push(Segment {
        name: "metrics".to_string(),
        kind: "metric".to_string(),
        shape: vec![8],
        offset: off,
        size: 8,
        dtype: Dtype::F32,
    });
    Layout { blob_len: off + 8, params_len, segments }
}

/// Random-ish but deterministic blob/grads pair for a layout — shared by
/// benches and the example so they exercise identical inputs.
pub fn seeded_blob_and_grads(layout: &Layout, seed: u64) -> (Vec<f32>, Vec<f32>) {
    let mut rng = crate::util::rng::Pcg32::seeded(seed);
    let mut blob = vec![0f32; layout.blob_len];
    for x in blob[..layout.params_len].iter_mut() {
        *x = rng.normal() * 0.1;
    }
    let mut grads = vec![0f32; layout.params_len];
    for x in grads.iter_mut() {
        *x = rng.normal() * 0.02;
    }
    (blob, grads)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model_params() -> Vec<(&'static str, Vec<usize>)> {
        vec![
            ("embed", vec![16, 8]),
            ("l0.attn_norm", vec![8]),
            ("l0.wq", vec![8, 8]),
            ("l0.w_down", vec![6, 8]),
            ("l1.attn_norm", vec![8]),
            ("l1.wq", vec![8, 8]),
            ("l1.w_down", vec![6, 8]),
            ("final_norm", vec![8]),
            ("head", vec![8, 16]),
        ]
    }

    fn layout_for(kind: OptKind) -> Layout {
        let params = model_params();
        let specs: Vec<(&str, &[usize])> =
            params.iter().map(|(n, s)| (*n, s.as_slice())).collect();
        synthetic_layout(kind, &specs)
    }

    #[test]
    fn synthetic_layout_is_consistent() {
        for kind in super::super::ALL_OPTS {
            let l = layout_for(kind);
            let mut off = 0;
            for s in &l.segments {
                assert_eq!(s.offset, off, "{}", s.name);
                assert_eq!(s.size, s.shape.iter().product::<usize>());
                off += s.size;
            }
            assert_eq!(off, l.blob_len);
            assert_eq!(l.metrics_offset() + 8, l.blob_len);
        }
    }

    #[test]
    fn fused_backward_order_matches_coordinator() {
        let l = layout_for(OptKind::AdaLomo);
        let opt =
            FlatOptimizer::new(OptKind::AdaLomo, &l, 2, ShardMode::Segments)
                .unwrap();
        assert_eq!(
            opt.task_order(),
            vec![
                "head",
                "final_norm",
                "l1.attn_norm",
                "l1.wq",
                "l1.w_down",
                "l0.attn_norm",
                "l0.wq",
                "l0.w_down",
                "embed",
            ]
        );
    }

    #[test]
    fn contiguous_ranges_tile_each_task() {
        for shards in [1usize, 2, 3, 5] {
            let l = layout_for(OptKind::AdaLomo);
            let opt = FlatOptimizer::new(
                OptKind::AdaLomo,
                &l,
                shards,
                ShardMode::Contiguous,
            )
            .unwrap();
            for task in &opt.tasks {
                let mut prev = 0usize;
                for &(lo, hi) in &task.ranges {
                    assert_eq!(lo, prev, "{}", task.name);
                    assert!(hi >= lo);
                    if task.cols > 0 {
                        assert_eq!(lo % task.cols, 0);
                        assert_eq!(hi % task.cols, 0);
                    }
                    prev = hi;
                }
                assert_eq!(prev, task.size, "{}", task.name);
            }
        }
    }

    #[test]
    fn segments_plan_covers_every_task_once() {
        let l = layout_for(OptKind::AdamW);
        let opt =
            FlatOptimizer::new(OptKind::AdamW, &l, 3, ShardMode::Segments)
                .unwrap();
        let mut seen: Vec<usize> =
            opt.shard_tasks.iter().flatten().copied().collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..opt.tasks.len()).collect::<Vec<_>>());
    }

    #[test]
    fn step_tasks_partition_matches_full_step() {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let l = layout_for(OptKind::AdaLomo);
            let (blob0, grads) = seeded_blob_and_grads(&l, 17);
            let mut full = blob0.clone();
            let mut opt =
                FlatOptimizer::new(OptKind::AdaLomo, &l, 3, mode).unwrap();
            opt.step(&mut full, &grads, 1, 1e-2, 0.0).unwrap();
            // The same step delivered as three interleaved task subsets
            // must land bit-identically: per-task arithmetic is
            // self-contained, which is what the bucket pipeline relies on.
            let mut by_parts = blob0.clone();
            let mut opt2 =
                FlatOptimizer::new(OptKind::AdaLomo, &l, 3, mode).unwrap();
            let n = opt2.n_tasks();
            for k in 0..3usize {
                let subset: Vec<usize> = (k..n).step_by(3).collect();
                opt2.step_tasks(&mut by_parts, &grads, 1, 1e-2, 0.0, &subset)
                    .unwrap();
            }
            for (i, (a, b)) in full.iter().zip(&by_parts).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{mode:?} elem {i}: {a} vs {b}"
                );
            }
            // Empty subset is a no-op; malformed subsets are rejected.
            opt2.step_tasks(&mut by_parts, &grads, 2, 1e-2, 0.0, &[])
                .unwrap();
            assert_eq!(full, by_parts);
            assert!(opt2
                .step_tasks(&mut by_parts, &grads, 2, 1e-2, 0.0, &[1, 0])
                .is_err());
            assert!(opt2
                .step_tasks(&mut by_parts, &grads, 2, 1e-2, 0.0, &[n])
                .is_err());
        }
    }

    #[test]
    fn groups_follow_fused_walk() {
        let l = layout_for(OptKind::AdaLomo);
        let opt =
            FlatOptimizer::new(OptKind::AdaLomo, &l, 2, ShardMode::Segments)
                .unwrap();
        // head block, l1, l0, embed.
        assert_eq!(opt.n_groups(), 4);
        let order = opt.task_order();
        let names = |r: std::ops::Range<usize>| -> Vec<&str> {
            r.map(|ti| order[ti]).collect()
        };
        assert_eq!(names(opt.group_tasks(0)), vec!["head", "final_norm"]);
        assert_eq!(
            names(opt.group_tasks(1)),
            vec!["l1.attn_norm", "l1.wq", "l1.w_down"]
        );
        assert_eq!(
            names(opt.group_tasks(2)),
            vec!["l0.attn_norm", "l0.wq", "l0.w_down"]
        );
        assert_eq!(names(opt.group_tasks(3)), vec!["embed"]);
        // Sizes: what each fused group keeps live (the coordinator twin).
        assert_eq!(
            opt.group_grad_sizes(),
            vec![8 * 16 + 8, 8 + 64 + 48, 8 + 64 + 48, 16 * 8]
        );
        // Extents tile the trainable region in DESCENDING offset order
        // (the invariant the fused-host pipeline relies on).
        let extents = opt.group_extents();
        let mut hi_expect = l.params_len;
        for (g, &(lo, hi)) in extents.iter().enumerate() {
            assert_eq!(hi, hi_expect, "group {g}");
            assert!(lo < hi);
            assert_eq!(hi - lo, opt.group_grad_sizes()[g], "group {g}");
            hi_expect = lo;
        }
        assert_eq!(hi_expect, 0);
    }

    #[test]
    fn step_group_walk_matches_full_step() {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let l = layout_for(OptKind::AdaLomo);
            let (blob0, grads) = seeded_blob_and_grads(&l, 29);
            let mut full = blob0.clone();
            let mut opt =
                FlatOptimizer::new(OptKind::AdaLomo, &l, 3, mode).unwrap();
            opt.step(&mut full, &grads, 1, 1e-2, 0.0).unwrap();
            // The same step delivered group-by-group from extent-sized
            // gradient slices must land bit-identically — the fused-host
            // mirror's contract.
            let mut by_groups = blob0.clone();
            let mut opt2 =
                FlatOptimizer::new(OptKind::AdaLomo, &l, 3, mode).unwrap();
            for (g, (lo, hi)) in opt2.group_extents().into_iter().enumerate()
            {
                opt2.step_group(
                    &mut by_groups,
                    g,
                    &grads[lo..hi],
                    1,
                    1e-2,
                    0.0,
                )
                .unwrap();
            }
            for (i, (a, b)) in full.iter().zip(&by_groups).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{mode:?} elem {i}: {a} vs {b}"
                );
            }
            // Wrong-length slices and bad indices are rejected loudly.
            assert!(opt2
                .step_group(&mut by_groups, 0, &grads[0..1], 2, 1e-2, 0.0)
                .is_err());
            let n = opt2.n_groups();
            let (lo, hi) = opt2.group_extents()[0];
            assert!(opt2
                .step_group(&mut by_groups, n, &grads[lo..hi], 2, 1e-2, 0.0)
                .is_err());
        }
    }

    #[test]
    fn task_extents_cover_trainable_region() {
        let l = layout_for(OptKind::AdaLomo);
        let opt =
            FlatOptimizer::new(OptKind::AdaLomo, &l, 2, ShardMode::Segments)
                .unwrap();
        let extents = opt.task_extents();
        assert_eq!(extents.len(), opt.n_tasks());
        let total: usize = extents.iter().map(|&(_, size)| size).sum();
        let trainable: usize = l.trainable().map(|s| s.size).sum();
        assert_eq!(total, trainable);
        for &(off, size) in &extents {
            assert!(off + size <= l.params_len);
        }
    }

    /// bf16 storage: any task partition — whole image, interleaved
    /// subsets, the group walk — must land bit-identically, because each
    /// task's widen→kernel→round is self-contained. Also pins the
    /// measured scratch peak to the analytic bound and far below a
    /// full-image mirror.
    #[test]
    fn bf16_partitions_and_groups_match_whole_step() {
        for kind in [OptKind::AdaLomo, OptKind::AdamW] {
            for mode in [ShardMode::Segments, ShardMode::Contiguous] {
                let l = layout_for(kind).with_storage_dtype(Dtype::Bf16);
                let (image, grads) = seeded_blob_and_grads(&l, 23);
                let blob0 =
                    TypedBlob::from_f32(&l, &image, Dtype::Bf16).unwrap();

                let mut full = blob0.clone();
                let mut opt =
                    FlatOptimizer::new(kind, &l, 3, mode).unwrap();
                opt.step_typed(&mut full, &grads, 1, 1e-2, 0.01).unwrap();
                // Scratch: measured == analytic bound, and far below a
                // full-image f32 mirror.
                assert_eq!(
                    opt.bf16_peak_scratch_elems(),
                    opt.bf16_scratch_bound_elems(),
                    "{kind:?} {mode:?}"
                );
                assert!(
                    opt.bf16_scratch_bound_elems() < l.shardable_len() / 2,
                    "{kind:?} {mode:?}: scratch bound {} vs shardable {}",
                    opt.bf16_scratch_bound_elems(),
                    l.shardable_len()
                );

                // Interleaved task subsets.
                let mut by_parts = blob0.clone();
                let mut opt2 =
                    FlatOptimizer::new(kind, &l, 3, mode).unwrap();
                let n = opt2.n_tasks();
                for k in 0..3usize {
                    let subset: Vec<usize> = (k..n).step_by(3).collect();
                    opt2.step_tasks_typed(
                        &mut by_parts, &grads, 1, 1e-2, 0.01, &subset,
                    )
                    .unwrap();
                }
                assert_eq!(full, by_parts, "{kind:?} {mode:?} subsets");

                // Group walk from extent-sized gradient slices.
                let mut by_groups = blob0.clone();
                let mut opt3 =
                    FlatOptimizer::new(kind, &l, 3, mode).unwrap();
                for (g, (lo, hi)) in
                    opt3.group_extents().into_iter().enumerate()
                {
                    opt3.step_group_typed(
                        &mut by_groups, g, &grads[lo..hi], 1, 1e-2, 0.01,
                    )
                    .unwrap();
                }
                assert_eq!(full, by_groups, "{kind:?} {mode:?} groups");

                // bf16 stepping genuinely moved the stored bits.
                assert_ne!(full, blob0, "{kind:?} {mode:?}");
                // The f32 typed path defers to the in-place engine: one
                // f32 TypedBlob step equals the raw-slice step bitwise.
                let mut typed32 =
                    TypedBlob::from_f32(&l, &image, Dtype::F32).unwrap();
                let mut raw32 = image.clone();
                let mut opt4 =
                    FlatOptimizer::new(kind, &l, 3, mode).unwrap();
                let mut opt5 =
                    FlatOptimizer::new(kind, &l, 3, mode).unwrap();
                opt4.step_typed(&mut typed32, &grads, 1, 1e-2, 0.01)
                    .unwrap();
                opt5.step(&mut raw32, &grads, 1, 1e-2, 0.01).unwrap();
                assert_eq!(typed32.to_f32(), raw32, "{kind:?} {mode:?} f32");
            }
        }
    }

    /// Persistent-session stepping must be bit-identical to looping the
    /// classic per-call entry points, across worker counts, both shard
    /// plans, and both storage dtypes — the pool swap may not fork a
    /// single bit. Gradients are rewritten between rounds through the
    /// session `RwLock` to prove the crew observes fresh values.
    #[test]
    fn session_matches_scoped_spawn_bitwise() {
        for kind in [OptKind::AdaLomo, OptKind::AdamW] {
            for mode in [ShardMode::Segments, ShardMode::Contiguous] {
                for shards in [1usize, 2, 4, 7] {
                    // f32 blobs through `session`.
                    let l = layout_for(kind);
                    let (blob0, g0) = seeded_blob_and_grads(&l, 29);
                    let mut classic = blob0.clone();
                    let mut opt_c =
                        FlatOptimizer::new(kind, &l, shards, mode).unwrap();
                    let mut g = g0.clone();
                    for t in 1..=3u64 {
                        opt_c.step(&mut classic, &g, t, 1e-2, 0.01).unwrap();
                        for x in g.iter_mut() {
                            *x *= 1.25;
                        }
                    }
                    let mut pooled = blob0.clone();
                    let mut opt_s =
                        FlatOptimizer::new(kind, &l, shards, mode).unwrap();
                    let grads = RwLock::new(g0.clone());
                    opt_s
                        .session(&mut pooled, &grads, |s| {
                            for t in 1..=3u64 {
                                s.step(t, 1e-2, 0.01).unwrap();
                                let mut gw = grads.write().unwrap();
                                for x in gw.iter_mut() {
                                    *x *= 1.25;
                                }
                            }
                        })
                        .unwrap();
                    assert_eq!(
                        classic, pooled,
                        "{kind:?} {mode:?} shards={shards} f32"
                    );

                    // bf16 blobs through `session_typed` (fused tiles).
                    let lb =
                        layout_for(kind).with_storage_dtype(Dtype::Bf16);
                    let (image, gb0) = seeded_blob_and_grads(&lb, 31);
                    let typed0 =
                        TypedBlob::from_f32(&lb, &image, Dtype::Bf16)
                            .unwrap();
                    let mut classic_b = typed0.clone();
                    let mut opt_cb =
                        FlatOptimizer::new(kind, &lb, shards, mode)
                            .unwrap();
                    let mut gb = gb0.clone();
                    for t in 1..=3u64 {
                        opt_cb
                            .step_typed(&mut classic_b, &gb, t, 1e-2, 0.01)
                            .unwrap();
                        for x in gb.iter_mut() {
                            *x *= 1.25;
                        }
                    }
                    let mut pooled_b = typed0.clone();
                    let mut opt_sb =
                        FlatOptimizer::new(kind, &lb, shards, mode)
                            .unwrap();
                    let gradsb = RwLock::new(gb0.clone());
                    opt_sb
                        .session_typed(&mut pooled_b, &gradsb, |s| {
                            for t in 1..=3u64 {
                                s.step(t, 1e-2, 0.01).unwrap();
                                let mut gw = gradsb.write().unwrap();
                                for x in gw.iter_mut() {
                                    *x *= 1.25;
                                }
                            }
                        })
                        .unwrap();
                    assert_eq!(
                        classic_b, pooled_b,
                        "{kind:?} {mode:?} shards={shards} bf16"
                    );
                }
            }
        }
    }

    #[test]
    fn bf16_rejects_malformed_inputs() {
        let l = layout_for(OptKind::AdaLomo).with_storage_dtype(Dtype::Bf16);
        let (image, grads) = seeded_blob_and_grads(&l, 5);
        let mut blob =
            TypedBlob::from_f32(&l, &image, Dtype::Bf16).unwrap();
        let mut opt =
            FlatOptimizer::new(OptKind::AdaLomo, &l, 2, ShardMode::Segments)
                .unwrap();
        // Short gradient image.
        assert!(opt
            .step_typed(&mut blob, &grads[..3], 1, 1e-2, 0.0)
            .is_err());
        // Malformed subsets (same contract as the f32 path).
        assert!(opt
            .step_tasks_typed(&mut blob, &grads, 1, 1e-2, 0.0, &[1, 0])
            .is_err());
        let n = opt.n_tasks();
        assert!(opt
            .step_tasks_typed(&mut blob, &grads, 1, 1e-2, 0.0, &[n])
            .is_err());
        // Empty subset is a no-op.
        let before = blob.clone();
        opt.step_tasks_typed(&mut blob, &grads, 1, 1e-2, 0.0, &[])
            .unwrap();
        assert_eq!(blob, before);
        // Group slice of the wrong length / bad group index.
        assert!(opt
            .step_group_typed(&mut blob, 0, &grads[0..1], 1, 1e-2, 0.0)
            .is_err());
        let g = opt.n_groups();
        let (lo, hi) = opt.group_extents()[0];
        assert!(opt
            .step_group_typed(&mut blob, g, &grads[lo..hi], 1, 1e-2, 0.0)
            .is_err());
    }

    #[test]
    fn missing_state_is_reported() {
        // An AdamW engine over an SGD layout (no @m/@v segments) must fail
        // loudly, not step garbage.
        let l = layout_for(OptKind::Sgd);
        let err = FlatOptimizer::new(OptKind::AdamW, &l, 1, ShardMode::Segments)
            .unwrap_err();
        assert!(format!("{err:#}").contains("@m"));
    }

    #[test]
    fn step_moves_parameters_and_state() {
        let l = layout_for(OptKind::AdaLomo);
        let (mut blob, grads) = seeded_blob_and_grads(&l, 3);
        let before = blob.clone();
        let mut opt =
            FlatOptimizer::new(OptKind::AdaLomo, &l, 2, ShardMode::Contiguous)
                .unwrap();
        opt.step(&mut blob, &grads, 1, 1e-2, 0.0).unwrap();
        let moved = blob[..l.params_len]
            .iter()
            .zip(&before[..l.params_len])
            .filter(|(a, b)| a != b)
            .count();
        assert!(moved > l.params_len / 2, "params should move");
        let state = &blob[l.params_len..l.metrics_offset()];
        assert!(state.iter().any(|&x| x != 0.0), "state should update");
        // Metrics region untouched.
        assert!(blob[l.metrics_offset()..].iter().all(|&x| x == 0.0));
    }
}
