//! Worker-thread helpers for the flat optimizer engine and the
//! coordinator — the zero-dependency slice-parallel substrate (`rayon` is
//! not in the offline registry, and the engine only needs fork/join over
//! borrowed slices, which `std::thread::scope` provides since Rust 1.63).
//!
//! Two dispatch shapes:
//!
//! * [`run_jobs`] — spawn/join scoped threads for one fork/join round;
//!   the right tool for cold or once-per-span work.
//! * [`crew`] — a persistent session: workers are spawned ONCE, then
//!   parked on a condvar between rounds; [`Crew::round`] re-dispatches
//!   the same jobs with zero thread spawns and zero heap allocations per
//!   round. The steady-state stepping paths (`flat::FlatOptimizer`
//!   sessions, the bench loops) run on crews; the
//!   `steady_state_thread_spawns_per_step` bench-gate metric pins the
//!   per-round spawn count at exactly 0 via [`spawn_count`].
//!
//! Everything here is deterministic by construction: work is partitioned by
//! *data position*, never by thread arrival order, so a result never
//! depends on scheduling.
//!
//! This module is the tree's one blessed thread home: the `analyze`
//! determinism rule (docs/ANALYSIS.md) flags `thread::spawn` everywhere
//! else in coordinator/optim/runtime, so new parallelism either lands
//! here or carries an explicit waiver with a schedule-independence
//! argument.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use anyhow::{ensure, Result};

/// Default shard/worker count: one per available hardware thread.
pub fn default_shards() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Shard count for an engine sharing the machine with `reserved` other
/// busy threads (e.g. the async pipeline's rank threads): the default
/// count minus the reservation, never below 1.
pub fn shards_with_reserved(reserved: usize) -> usize {
    default_shards().saturating_sub(reserved).max(1)
}

/// Total OS threads this module has ever spawned (both [`run_jobs`] and
/// [`crew`] sessions). Monotone; the bench binaries difference it across
/// the steady-state loop to prove a step spawns nothing.
pub fn spawn_count() -> u64 {
    SPAWNS.load(Ordering::Relaxed)
}

static SPAWNS: AtomicU64 = AtomicU64::new(0);

/// Run one job per worker on scoped threads and join them all. Jobs may
/// borrow from the caller's stack (scoped). A single job runs inline on the
/// calling thread — no spawn cost for the 1-shard configuration.
///
/// Panics propagate to the caller after all jobs finish — provided the
/// jobs are independent. Jobs that rendezvous on a shared barrier (the
/// flat engine's contiguous mode) can instead hang peers at the barrier
/// if one of them panics between waits; see `flat::SyncState`.
pub fn run_jobs<J: FnOnce() + Send>(jobs: Vec<J>) {
    let mut jobs = jobs;
    if jobs.len() <= 1 {
        if let Some(job) = jobs.pop() {
            job();
        }
        return;
    }
    SPAWNS.fetch_add(jobs.len() as u64, Ordering::Relaxed);
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs.drain(..).map(|j| s.spawn(j)).collect();
        for h in handles {
            h.join().expect("pool worker panicked");
        }
    });
}

/// Contiguous range boundaries splitting `n` items into `parts` balanced
/// pieces: piece k is `[bounds(k), bounds(k+1))` with sizes differing by at
/// most one (same balancing rule as `sharding::plan_contiguous`). The
/// product is taken in `u128` so huge `n × parts` never wraps (regression:
/// `range_bound_survives_huge_products`).
pub fn range_bound(n: usize, parts: usize, k: usize) -> usize {
    debug_assert!(parts > 0);
    ((n as u128 * k as u128) / parts as u128) as usize
}

/// Parallel element-wise average: `dst[i] = (Σ_s sources[s][i]) * scale`,
/// with `dst` split into `n_workers` contiguous ranges. Per element the
/// sources are summed in source order, so the result is bit-identical to
/// the sequential loop for ANY worker count — this is what lets the
/// local-SGD coordinator shard round averaging, and the async pipelines
/// reduce their exchange buckets in ANY bucket order (ascending for the
/// full-image path, descending for the fused-host path), without
/// perturbing the bitwise-identity guarantees they are pinned to.
///
/// Generic over the source container so callers can pass owned recycled
/// buffers (`&[Vec<f32>]`) directly — no per-call `Vec<&[f32]>` rebuild on
/// the hot path.
pub fn par_average<S: AsRef<[f32]> + Sync>(
    dst: &mut [f32],
    sources: &[S],
    scale: f32,
    n_workers: usize,
) {
    let n = dst.len();
    for s in sources {
        assert!(s.as_ref().len() >= n, "source shorter than destination");
    }
    let w = n_workers.clamp(1, n.max(1));
    let mut jobs = Vec::with_capacity(w);
    let mut rest = dst;
    let mut start = 0usize;
    for k in 0..w {
        let end = range_bound(n, w, k + 1);
        let (piece, tail) = rest.split_at_mut(end - start);
        rest = tail;
        let base = start;
        jobs.push(move || {
            for (i, d) in piece.iter_mut().enumerate() {
                let gi = base + i;
                let mut acc = 0.0f32;
                for src in sources {
                    acc += src.as_ref()[gi];
                }
                *d = acc * scale;
            }
        });
        start = end;
    }
    run_jobs(jobs);
}

// --- persistent crew sessions ----------------------------------------------

/// Round control shared between the crew leader and its parked workers.
/// One generation number is the only dispatch signal: a worker runs its
/// job exactly once per generation it observes, so every round executes
/// every job exactly once — same fork/join semantics as [`run_jobs`],
/// minus the per-round spawns.
struct Ctrl {
    generation: u64,
    completed: usize,
    panicked: usize,
    shutdown: bool,
}

struct CrewState {
    ctrl: Mutex<Ctrl>,
    /// Leader -> workers: a new generation (or shutdown) is posted.
    go: Condvar,
    /// Workers -> leader: another job finished the current generation.
    done: Condvar,
}

/// Poison-immune lock: a panicked peer makes the data no less valid here
/// (every field is a plain counter/flag written under the lock), and
/// panicking again would turn one failed round into a hung session.
fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) -> MutexGuard<'a, T> {
    cv.wait(g).unwrap_or_else(|e| e.into_inner())
}

fn worker_loop(state: &CrewState, job: &mut (dyn FnMut() + Send)) {
    let mut seen = 0u64;
    loop {
        let mut ctrl = lock(&state.ctrl);
        while !ctrl.shutdown && ctrl.generation == seen {
            ctrl = wait(&state.go, ctrl);
        }
        if ctrl.shutdown {
            return;
        }
        seen = ctrl.generation;
        drop(ctrl);
        // A panicking job must fail the caller's round, not kill this
        // worker: catch it, report it, and stay parked for the next
        // round (the panic counter is reset per round, so one failure
        // never poisons later dispatches).
        let ok = catch_unwind(AssertUnwindSafe(&mut *job)).is_ok();
        let mut ctrl = lock(&state.ctrl);
        if !ok {
            ctrl.panicked += 1;
        }
        ctrl.completed += 1;
        state.done.notify_all();
    }
}

/// Unblocks parked workers when the leader scope ends — including by
/// panic, so a failing leader closure propagates instead of deadlocking
/// the scope join.
struct ShutdownGuard<'a>(&'a CrewState);

impl Drop for ShutdownGuard<'_> {
    fn drop(&mut self) {
        let mut ctrl = lock(&self.0.ctrl);
        ctrl.shutdown = true;
        self.0.go.notify_all();
    }
}

/// Handle the `leader` closure of [`crew`] drives rounds through.
pub struct Crew<'env> {
    n: usize,
    state: Option<Arc<CrewState>>,
    inline: Option<Box<dyn FnMut() + Send + 'env>>,
}

impl Crew<'_> {
    /// Number of jobs dispatched per round.
    pub fn n_jobs(&self) -> usize {
        self.n
    }

    /// Run every job once and wait for all of them — one fork/join round
    /// with no spawns and no allocations. Returns an error (instead of
    /// panicking) if any job panicked this round; the crew stays usable
    /// for further rounds either way.
    pub fn round(&mut self) -> Result<()> {
        // ANALYZE-HOT: crew round dispatch — one step per round
        if let Some(job) = self.inline.as_mut() {
            let ok = catch_unwind(AssertUnwindSafe(&mut **job)).is_ok();
            ensure!(ok, "crew job panicked");
            return Ok(());
        }
        let Some(state) = self.state.as_ref() else {
            return Ok(()); // zero jobs: a round is a no-op
        };
        let mut ctrl = lock(&state.ctrl);
        ctrl.generation += 1;
        ctrl.completed = 0;
        ctrl.panicked = 0;
        state.go.notify_all();
        while ctrl.completed < self.n {
            ctrl = wait(&state.done, ctrl);
        }
        let panicked = ctrl.panicked;
        drop(ctrl);
        ensure!(panicked == 0, "{panicked} crew worker job(s) panicked");
        Ok(())
        // ANALYZE-HOT-END
    }
}

/// Spawn one parked worker per job ONCE, hand the `leader` closure a
/// [`Crew`] whose [`Crew::round`] re-runs every job with zero spawns and
/// zero allocations, and join the workers when the leader returns. Jobs
/// may borrow from the caller's stack (the workers live inside a
/// `thread::scope`). With zero or one job no thread is spawned at all —
/// the single job runs inline on the calling thread, mirroring
/// [`run_jobs`]'s 1-shard shortcut.
///
/// Same caveat as [`run_jobs`]: panic containment assumes independent
/// jobs; jobs that rendezvous on a shared barrier can hang peers at the
/// barrier if one of them panics between waits.
pub fn crew<'env, R>(
    mut jobs: Vec<Box<dyn FnMut() + Send + 'env>>,
    leader: impl FnOnce(&mut Crew<'env>) -> R,
) -> R {
    if jobs.len() <= 1 {
        let mut c = Crew { n: jobs.len(), state: None, inline: jobs.pop() };
        return leader(&mut c);
    }
    let n = jobs.len();
    let state = Arc::new(CrewState {
        ctrl: Mutex::new(Ctrl {
            generation: 0,
            completed: 0,
            panicked: 0,
            shutdown: false,
        }),
        go: Condvar::new(),
        done: Condvar::new(),
    });
    SPAWNS.fetch_add(n as u64, Ordering::Relaxed);
    std::thread::scope(|s| {
        for mut job in jobs {
            let st = Arc::clone(&state);
            s.spawn(move || worker_loop(&st, &mut *job));
        }
        let _guard = ShutdownGuard(&state);
        let mut c = Crew { n, state: Some(Arc::clone(&state)), inline: None };
        leader(&mut c)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn run_jobs_executes_all() {
        let hits = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..8)
            .map(|_| {
                let hits = &hits;
                move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_jobs(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn run_jobs_empty_and_single() {
        let jobs: Vec<fn()> = Vec::new();
        run_jobs(jobs); // no-op, no panic
        let mut x = 0;
        run_jobs(vec![|| x += 1]);
        assert_eq!(x, 1);
    }

    #[test]
    fn reserved_shards_never_drop_below_one() {
        assert_eq!(shards_with_reserved(0), default_shards());
        assert_eq!(shards_with_reserved(usize::MAX), 1);
        assert!(shards_with_reserved(default_shards()) >= 1);
    }

    #[test]
    fn bounds_tile_exactly() {
        for n in [0usize, 1, 7, 100] {
            for parts in [1usize, 2, 3, 8] {
                let mut prev = 0;
                let mut total = 0;
                for k in 0..parts {
                    let lo = range_bound(n, parts, k);
                    let hi = range_bound(n, parts, k + 1);
                    assert_eq!(lo, prev);
                    assert!(hi >= lo);
                    total += hi - lo;
                    prev = hi;
                }
                assert_eq!(prev, n);
                assert_eq!(total, n);
            }
        }
    }

    #[test]
    fn range_bound_survives_huge_products() {
        // Regression: `(n * k) / parts` in usize wraps as soon as
        // n * parts overflows — boundary sizes near usize::MAX used to
        // come back tiny (and non-monotone), silently shredding the
        // partition. u128 arithmetic keeps the exact quotient.
        let n = usize::MAX - 7;
        for parts in [2usize, 3, 7, 64] {
            assert_eq!(range_bound(n, parts, 0), 0);
            assert_eq!(range_bound(n, parts, parts), n);
            let mut prev = 0;
            for k in 0..=parts {
                let b = range_bound(n, parts, k);
                assert!(b >= prev, "bounds must be monotone at n={n}");
                prev = b;
            }
        }
        // The exact case that wrapped before: n * 2 > usize::MAX.
        assert_eq!(range_bound(usize::MAX, 2, 1), usize::MAX / 2);
    }

    #[test]
    fn par_average_matches_sequential_any_worker_count() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..103).map(|i| 103.0 - i as f32).collect();
        let c: Vec<f32> = (0..103).map(|i| (i as f32).sin()).collect();
        let sources = [a.as_slice(), b.as_slice(), c.as_slice()];
        let mut expect = vec![0f32; 103];
        for (i, e) in expect.iter_mut().enumerate() {
            *e = (a[i] + b[i] + c[i]) * (1.0 / 3.0);
        }
        for w in [1usize, 2, 4, 7] {
            let mut dst = vec![0f32; 103];
            par_average(&mut dst, &sources, 1.0 / 3.0, w);
            assert_eq!(dst, expect, "workers={w} must be bit-identical");
        }
        // Owned containers work without a ref-slice rebuild.
        let owned = vec![a.clone(), b.clone(), c.clone()];
        let mut dst = vec![0f32; 103];
        par_average(&mut dst, &owned, 1.0 / 3.0, 4);
        assert_eq!(dst, expect, "owned sources must be bit-identical");
    }

    #[test]
    fn crew_rounds_execute_all_jobs_each_round() {
        let hits = AtomicUsize::new(0);
        for n_jobs in [0usize, 1, 4] {
            hits.store(0, Ordering::SeqCst);
            let jobs: Vec<Box<dyn FnMut() + Send + '_>> = (0..n_jobs)
                .map(|_| {
                    let hits = &hits;
                    Box::new(move || {
                        hits.fetch_add(1, Ordering::SeqCst);
                    }) as Box<dyn FnMut() + Send + '_>
                })
                .collect();
            crew(jobs, |c| {
                assert_eq!(c.n_jobs(), n_jobs);
                for r in 1..=3u64 {
                    c.round().unwrap();
                    assert_eq!(
                        hits.load(Ordering::SeqCst) as u64,
                        n_jobs as u64 * r,
                        "every job must run exactly once per round"
                    );
                }
            });
        }
    }

    #[test]
    fn crew_spawns_workers_once_not_per_round() {
        let before = spawn_count();
        let jobs: Vec<Box<dyn FnMut() + Send + '_>> =
            (0..4).map(|_| Box::new(|| ()) as Box<dyn FnMut() + Send + '_>).collect();
        crew(jobs, |c| {
            let after_setup = spawn_count();
            for _ in 0..100 {
                c.round().unwrap();
            }
            // Other tests may spawn concurrently, so assert only on THIS
            // crew's contribution: rounds add nothing beyond setup.
            assert!(after_setup >= before + 4);
            assert_eq!(
                spawn_count(),
                after_setup,
                "rounds must not spawn threads"
            );
        });
    }

    #[test]
    fn crew_panics_fail_the_round_not_later_dispatches() {
        let hits = AtomicUsize::new(0);
        let boom = AtomicUsize::new(1);
        let mut jobs: Vec<Box<dyn FnMut() + Send + '_>> = Vec::new();
        for w in 0..3usize {
            let hits = &hits;
            let boom = &boom;
            jobs.push(Box::new(move || {
                if w == 2 && boom.load(Ordering::SeqCst) == 1 {
                    panic!("injected crew panic");
                }
                hits.fetch_add(1, Ordering::SeqCst);
            }));
        }
        crew(jobs, |c| {
            assert!(
                c.round().is_err(),
                "a panicking job must fail the caller"
            );
            // Peers were not hung: both non-panicking jobs completed.
            assert_eq!(hits.load(Ordering::SeqCst), 2);
            boom.store(0, Ordering::SeqCst);
            c.round().unwrap();
            assert_eq!(
                hits.load(Ordering::SeqCst),
                5,
                "a failed round must not poison later dispatches"
            );
        });
    }

    #[test]
    fn crew_inline_single_job_panic_is_contained() {
        let boom = AtomicUsize::new(1);
        let hits = AtomicUsize::new(0);
        let job: Box<dyn FnMut() + Send + '_> = Box::new(|| {
            if boom.load(Ordering::SeqCst) == 1 {
                panic!("inline crew panic");
            }
            hits.fetch_add(1, Ordering::SeqCst);
        });
        crew(vec![job], |c| {
            assert!(c.round().is_err());
            boom.store(0, Ordering::SeqCst);
            c.round().unwrap();
            assert_eq!(hits.load(Ordering::SeqCst), 1);
        });
    }
}
