//! Scoped worker-thread helpers for the flat optimizer engine and the
//! coordinator — the zero-dependency slice-parallel substrate (`rayon` is
//! not in the offline registry, and the engine only needs fork/join over
//! borrowed slices, which `std::thread::scope` provides since Rust 1.63).
//!
//! Everything here is deterministic by construction: work is partitioned by
//! *data position*, never by thread arrival order, so a result never
//! depends on scheduling.
//!
//! This module is the tree's one blessed thread home: the `analyze`
//! determinism rule (docs/ANALYSIS.md) flags `thread::spawn` everywhere
//! else in coordinator/optim/runtime, so new parallelism either lands
//! here or carries an explicit waiver with a schedule-independence
//! argument.

/// Default shard/worker count: one per available hardware thread.
pub fn default_shards() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Shard count for an engine sharing the machine with `reserved` other
/// busy threads (e.g. the async pipeline's rank threads): the default
/// count minus the reservation, never below 1.
pub fn shards_with_reserved(reserved: usize) -> usize {
    default_shards().saturating_sub(reserved).max(1)
}

/// Run one job per worker on scoped threads and join them all. Jobs may
/// borrow from the caller's stack (scoped). A single job runs inline on the
/// calling thread — no spawn cost for the 1-shard configuration.
///
/// Panics propagate to the caller after all jobs finish — provided the
/// jobs are independent. Jobs that rendezvous on a shared barrier (the
/// flat engine's contiguous mode) can instead hang peers at the barrier
/// if one of them panics between waits; see `flat::SyncState`.
pub fn run_jobs<J: FnOnce() + Send>(jobs: Vec<J>) {
    let mut jobs = jobs;
    if jobs.len() <= 1 {
        if let Some(job) = jobs.pop() {
            job();
        }
        return;
    }
    std::thread::scope(|s| {
        let handles: Vec<_> = jobs.drain(..).map(|j| s.spawn(j)).collect();
        for h in handles {
            h.join().expect("pool worker panicked");
        }
    });
}

/// Contiguous range boundaries splitting `n` items into `parts` balanced
/// pieces: piece k is `[bounds(k), bounds(k+1))` with sizes differing by at
/// most one (same balancing rule as `sharding::plan_contiguous`).
pub fn range_bound(n: usize, parts: usize, k: usize) -> usize {
    debug_assert!(parts > 0);
    (n * k) / parts
}

/// Parallel element-wise average: `dst[i] = (Σ_s sources[s][i]) * scale`,
/// with `dst` split into `n_workers` contiguous ranges. Per element the
/// sources are summed in source order, so the result is bit-identical to
/// the sequential loop for ANY worker count — this is what lets the
/// local-SGD coordinator shard round averaging, and the async pipelines
/// reduce their exchange buckets in ANY bucket order (ascending for the
/// full-image path, descending for the fused-host path), without
/// perturbing the bitwise-identity guarantees they are pinned to.
pub fn par_average(dst: &mut [f32], sources: &[&[f32]], scale: f32, n_workers: usize) {
    let n = dst.len();
    for s in sources {
        assert!(s.len() >= n, "source shorter than destination");
    }
    let w = n_workers.clamp(1, n.max(1));
    let mut jobs = Vec::with_capacity(w);
    let mut rest = dst;
    let mut start = 0usize;
    for k in 0..w {
        let end = range_bound(n, w, k + 1);
        let (piece, tail) = rest.split_at_mut(end - start);
        rest = tail;
        let base = start;
        jobs.push(move || {
            for (i, d) in piece.iter_mut().enumerate() {
                let gi = base + i;
                let mut acc = 0.0f32;
                for src in sources {
                    acc += src[gi];
                }
                *d = acc * scale;
            }
        });
        start = end;
    }
    run_jobs(jobs);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn run_jobs_executes_all() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let hits = AtomicUsize::new(0);
        let jobs: Vec<_> = (0..8)
            .map(|_| {
                let hits = &hits;
                move || {
                    hits.fetch_add(1, Ordering::SeqCst);
                }
            })
            .collect();
        run_jobs(jobs);
        assert_eq!(hits.load(Ordering::SeqCst), 8);
    }

    #[test]
    fn run_jobs_empty_and_single() {
        let jobs: Vec<fn()> = Vec::new();
        run_jobs(jobs); // no-op, no panic
        let mut x = 0;
        run_jobs(vec![|| x += 1]);
        assert_eq!(x, 1);
    }

    #[test]
    fn reserved_shards_never_drop_below_one() {
        assert_eq!(shards_with_reserved(0), default_shards());
        assert_eq!(shards_with_reserved(usize::MAX), 1);
        assert!(shards_with_reserved(default_shards()) >= 1);
    }

    #[test]
    fn bounds_tile_exactly() {
        for n in [0usize, 1, 7, 100] {
            for parts in [1usize, 2, 3, 8] {
                let mut prev = 0;
                let mut total = 0;
                for k in 0..parts {
                    let lo = range_bound(n, parts, k);
                    let hi = range_bound(n, parts, k + 1);
                    assert_eq!(lo, prev);
                    assert!(hi >= lo);
                    total += hi - lo;
                    prev = hi;
                }
                assert_eq!(prev, n);
                assert_eq!(total, n);
            }
        }
    }

    #[test]
    fn par_average_matches_sequential_any_worker_count() {
        let a: Vec<f32> = (0..103).map(|i| i as f32 * 0.5).collect();
        let b: Vec<f32> = (0..103).map(|i| 103.0 - i as f32).collect();
        let c: Vec<f32> = (0..103).map(|i| (i as f32).sin()).collect();
        let sources = [a.as_slice(), b.as_slice(), c.as_slice()];
        let mut expect = vec![0f32; 103];
        for (i, e) in expect.iter_mut().enumerate() {
            *e = (a[i] + b[i] + c[i]) * (1.0 / 3.0);
        }
        for w in [1usize, 2, 4, 7] {
            let mut dst = vec![0f32; 103];
            par_average(&mut dst, &sources, 1.0 / 3.0, w);
            assert_eq!(dst, expect, "workers={w} must be bit-identical");
        }
    }
}
