//! Rust-native optimizer mirrors.
//!
//! Exactly the math of `python/compile/kernels/ref.py`, re-implemented on
//! the host [`Tensor`]. Four consumers:
//! * cross-layer parity tests — one step here must match one step of the
//!   AOT train-step artifact (integration_optim_parity);
//! * the memory simulator — [`OptKind::state_floats`] is the per-parameter
//!   optimizer-state footprint of paper Table 1;
//! * host-side experiments (toy-2D trajectories, micro-benches) that don't
//!   need XLA;
//! * the flat-blob parallel engine ([`flat::FlatOptimizer`]) that steps a
//!   runtime blob in place over the same slice kernels ([`update`]),
//!   sharded across scoped worker threads ([`pool`]).

use crate::tensor::Tensor;

pub mod flat;
pub mod pool;
pub mod update;

pub use flat::{FlatOptimizer, ShardMode};
pub use update::{grouped_normalize, GroupedNormStats};

/// Optimizer identifiers. Order matches the paper's comparison set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OptKind {
    Sgd,
    SgdMomentum,
    SgdVariance,
    AdamW,
    Adafactor,
    Lomo,
    AdaLomo,
}

pub const ALL_OPTS: [OptKind; 7] = [
    OptKind::Sgd,
    OptKind::SgdMomentum,
    OptKind::SgdVariance,
    OptKind::AdamW,
    OptKind::Adafactor,
    OptKind::Lomo,
    OptKind::AdaLomo,
];

impl OptKind {
    pub fn parse(name: &str) -> anyhow::Result<OptKind> {
        Ok(match name {
            "sgd" => OptKind::Sgd,
            "sgd_momentum" => OptKind::SgdMomentum,
            "sgd_variance" => OptKind::SgdVariance,
            "adam" | "adamw" => OptKind::AdamW,
            "adafactor" => OptKind::Adafactor,
            "lomo" => OptKind::Lomo,
            "adalomo" => OptKind::AdaLomo,
            other => anyhow::bail!("unknown optimizer {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            OptKind::Sgd => "sgd",
            OptKind::SgdMomentum => "sgd_momentum",
            OptKind::SgdVariance => "sgd_variance",
            OptKind::AdamW => "adamw",
            OptKind::Adafactor => "adafactor",
            OptKind::Lomo => "lomo",
            OptKind::AdaLomo => "adalomo",
        }
    }

    /// f32 optimizer-state elements for a parameter of `shape` — the
    /// quantity behind paper Table 1's "Optimizer State" column.
    pub fn state_floats(&self, shape: &[usize]) -> usize {
        let n: usize = shape.iter().product();
        match self {
            OptKind::Sgd | OptKind::Lomo => 0,
            OptKind::SgdMomentum | OptKind::SgdVariance => n,
            OptKind::AdamW => 2 * n,
            OptKind::Adafactor | OptKind::AdaLomo => {
                if shape.len() == 2 {
                    shape[0] + shape[1] // factored: r (m,) + c (n,)
                } else {
                    n // vectors keep a full second moment
                }
            }
        }
    }

    /// Whether the update of one parameter needs no other parameter's
    /// gradient — the property that lets LOMO/AdaLomo fuse the update into
    /// the backward pass and free gradients immediately (paper §3.2).
    /// AdamW et al. are per-parameter too, but *with* gradient clipping by
    /// global norm (their standard recipe) they lose the property; the
    /// memory simulator models that distinction.
    pub fn fused_backward(&self) -> bool {
        matches!(self, OptKind::Lomo | OptKind::AdaLomo)
    }

    /// Uses an adaptive (second-moment) per-parameter learning rate.
    pub fn adaptive(&self) -> bool {
        !matches!(self, OptKind::Sgd | OptKind::SgdMomentum | OptKind::Lomo)
    }
}

/// Hyper-parameters shared across parameters (ref.py defaults).
#[derive(Debug, Clone, Copy)]
pub struct Hyper {
    pub beta1: f32,
    pub beta2: f32,
    pub adam_eps: f32,
    pub adalomo_beta: f32,
    pub eps_rms: f32,
    pub eps_div: f32,
    pub adafactor_eps1: f32,
    pub adafactor_eps2: f32,
    pub adafactor_clip_d: f32,
    pub adafactor_decay_pow: f32,
    /// Literal Algorithm-1 line-10 form u = g / v_hat (no sqrt).
    pub no_sqrt: bool,
}

impl Default for Hyper {
    fn default() -> Self {
        Hyper {
            beta1: 0.9,
            beta2: 0.999,
            adam_eps: 1e-8,
            adalomo_beta: 0.85,
            eps_rms: 1e-3,
            eps_div: 1e-30,
            adafactor_eps1: 1e-30,
            adafactor_eps2: 1e-3,
            adafactor_clip_d: 1.0,
            adafactor_decay_pow: 0.8,
            no_sqrt: false,
        }
    }
}

/// Per-parameter optimizer state.
#[derive(Debug, Clone)]
enum State {
    None,
    M(Tensor),
    V(Tensor),
    MV(Tensor, Tensor),
    RC(Tensor, Tensor),
}

/// One parameter's optimizer instance.
#[derive(Debug, Clone)]
pub struct ParamOpt {
    pub kind: OptKind,
    hyper: Hyper,
    state: State,
}

impl ParamOpt {
    pub fn new(kind: OptKind, shape: &[usize]) -> ParamOpt {
        Self::with_hyper(kind, shape, Hyper::default())
    }

    pub fn with_hyper(kind: OptKind, shape: &[usize], hyper: Hyper) -> ParamOpt {
        let state = match kind {
            OptKind::Sgd | OptKind::Lomo => State::None,
            OptKind::SgdMomentum => State::M(Tensor::zeros(shape)),
            OptKind::SgdVariance => State::V(Tensor::zeros(shape)),
            OptKind::AdamW => {
                State::MV(Tensor::zeros(shape), Tensor::zeros(shape))
            }
            OptKind::Adafactor | OptKind::AdaLomo => {
                if shape.len() == 2 {
                    State::RC(
                        Tensor::zeros(&[shape[0]]),
                        Tensor::zeros(&[shape[1]]),
                    )
                } else {
                    State::V(Tensor::zeros(shape))
                }
            }
        };
        ParamOpt { kind, hyper, state }
    }

    pub fn state_floats(&self) -> usize {
        match &self.state {
            State::None => 0,
            State::M(t) | State::V(t) => t.len(),
            State::MV(a, b) | State::RC(a, b) => a.len() + b.len(),
        }
    }

    /// Access the factored state (r, c) if present — for invariants tests.
    pub fn factored_state(&self) -> Option<(&Tensor, &Tensor)> {
        match &self.state {
            State::RC(r, c) => Some((r, c)),
            _ => None,
        }
    }

    /// Apply one update. `t` is the 1-based step, `lr` the scheduled
    /// learning rate (rho_t for Adafactor/AdaLomo), `wd` decoupled decay
    /// (AdamW only — others ignore it, matching the paper's setups).
    pub fn step(&mut self, theta: &mut Tensor, g: &Tensor, t: u64, lr: f32, wd: f32) {
        let h = self.hyper;
        match (self.kind, &mut self.state) {
            (OptKind::Sgd, State::None) | (OptKind::Lomo, State::None) => {
                update::sgd(theta, g, lr);
            }
            (OptKind::SgdMomentum, State::M(m)) => {
                update::sgd_momentum(theta, g, m, t, lr, h);
            }
            (OptKind::SgdVariance, State::V(v)) => {
                update::sgd_variance(theta, g, v, t, lr, h);
            }
            (OptKind::AdamW, State::MV(m, v)) => {
                update::adamw(theta, g, m, v, t, lr, wd, h);
            }
            (OptKind::Adafactor, State::RC(r, c)) => {
                update::adafactor_2d(theta, g, r, c, t, lr, h);
            }
            (OptKind::Adafactor, State::V(v)) => {
                update::adafactor_vec(theta, g, v, t, lr, h);
            }
            (OptKind::AdaLomo, State::RC(r, c)) => {
                update::adalomo_2d(theta, g, r, c, t, lr, h);
            }
            (OptKind::AdaLomo, State::V(v)) => {
                update::adalomo_vec(theta, g, v, t, lr, h);
            }
            (kind, state) => unreachable!(
                "optimizer {kind:?} with mismatched state {:?}",
                std::mem::discriminant(state)
            ),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        for k in ALL_OPTS {
            assert_eq!(OptKind::parse(k.name()).unwrap(), k);
        }
        assert_eq!(OptKind::parse("adam").unwrap(), OptKind::AdamW);
        assert!(OptKind::parse("nope").is_err());
    }

    #[test]
    fn state_floats_table1() {
        // Paper Table 1: AdamW keeps 2 state tensors; AdaLomo keeps m+n.
        let shape = [128, 64];
        assert_eq!(OptKind::AdamW.state_floats(&shape), 2 * 128 * 64);
        assert_eq!(OptKind::AdaLomo.state_floats(&shape), 128 + 64);
        assert_eq!(OptKind::Adafactor.state_floats(&shape), 128 + 64);
        assert_eq!(OptKind::Lomo.state_floats(&shape), 0);
        // Vectors degenerate to a full second moment.
        assert_eq!(OptKind::AdaLomo.state_floats(&[64]), 64);
    }

    #[test]
    fn param_opt_state_allocated() {
        let p = ParamOpt::new(OptKind::AdaLomo, &[16, 8]);
        assert_eq!(p.state_floats(), 24);
        let (r, c) = p.factored_state().unwrap();
        assert_eq!(r.len(), 16);
        assert_eq!(c.len(), 8);
    }

    #[test]
    fn sgd_step_direction() {
        let mut theta = Tensor::full(&[4], 1.0);
        let g = Tensor::full(&[4], 0.5);
        let mut opt = ParamOpt::new(OptKind::Sgd, &[4]);
        opt.step(&mut theta, &g, 1, 0.1, 0.0);
        for &x in theta.data() {
            assert!((x - 0.95).abs() < 1e-7);
        }
    }

    #[test]
    fn fused_and_adaptive_flags() {
        assert!(OptKind::AdaLomo.fused_backward());
        assert!(OptKind::Lomo.fused_backward());
        assert!(!OptKind::AdamW.fused_backward());
        assert!(OptKind::AdaLomo.adaptive());
        assert!(!OptKind::Lomo.adaptive());
    }
}
