//! AdaLomo: Low-memory Optimization with Adaptive Learning Rate —
//! full-system reproduction of Lv et al., Findings of ACL 2024.
//!
//! Three-layer architecture (DESIGN.md):
//! - **Layer 1** (build time): Pallas update kernels, `python/compile/kernels/`.
//! - **Layer 2** (build time): JAX LLaMA-style model + functional optimizer
//!   library, lowered once to HLO text by `python -m compile.aot`.
//! - **Layer 3** (this crate): the runtime coordinator. Loads the AOT
//!   artifacts through PJRT ([`runtime`]), drives training ([`coordinator`]),
//!   generates the synthetic workloads ([`data`]), evaluates the benchmark
//!   suite ([`eval`]), and reproduces every table/figure of the paper through
//!   the analytic memory/throughput simulator ([`memsim`]) and the bench
//!   harness ([`util::bench`]).
//!
//! Python never runs on the request path: after `make artifacts` the
//! `adalomo` binary is self-contained.
//!
//! The tree is 100% safe Rust, and the `analyze` static pass ([`analysis`])
//! keeps it that way — the forbid below makes any future `unsafe` a
//! compile error until it is explicitly, visibly waived.
#![forbid(unsafe_code)]

pub mod analysis;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod experiments;
pub mod memsim;
pub mod metrics;
pub mod optim;
pub mod runtime;
pub mod tensor;
pub mod util;
