//! Synthetic five-benchmark evaluation suite (paper §4.1 / Table 2
//! substitution — see `data::instruct` for the task families and what each
//! stands in for).
//!
//! Scoring is likelihood-based (lm-eval-harness style) through the
//! `seq_loss_<preset>` artifact: a multiple-choice item is correct when
//! the gold option has the lowest length-normalized loss; the writing task
//! is a win rate of the tuned model against the untuned base model on gold
//! responses. Scores are 0-100, directly comparable to Table 2's rows.

use std::collections::BTreeMap;

use anyhow::Result;
use xla::PjRtBuffer;

use crate::data::instruct::{eval_items, Family, McItem, FAMILIES};
use crate::data::tokenizer::{encode, PAD};
use crate::runtime::Session;

#[derive(Debug, Clone)]
pub struct SuiteResult {
    pub scores: BTreeMap<&'static str, f64>,
    pub avg: f64,
}

impl SuiteResult {
    pub fn score(&self, family: Family) -> f64 {
        self.scores[family.name()]
    }
}

/// Tokenize prompt+continuation with the loss mask on the continuation
/// (same recipe as instruct::Example::tokenize).
fn rows_for(prompt: &str, continuation: &str) -> (Vec<i32>, Vec<i32>) {
    let p = encode(prompt);
    let c = encode(continuation);
    let mut x = p.clone();
    x.extend_from_slice(&c);
    let mut y = vec![PAD; x.len()];
    for i in 0..c.len() {
        y[p.len() - 1 + i] = c[i];
    }
    (x, y)
}

/// Mean per-token loss for each (x, y) row, batched through seq_loss.
pub fn seq_mean_losses(
    session: &Session,
    preset: &str,
    params: &PjRtBuffer,
    rows: &[(Vec<i32>, Vec<i32>)],
) -> Result<Vec<f64>> {
    let info = session.manifest.preset(preset)?;
    let (b, t) = (info.batch_size, info.seq_len);
    let entry = format!("seq_loss_{preset}");
    let mut out = Vec::with_capacity(rows.len());
    for chunk in rows.chunks(b) {
        let mut x = vec![PAD; b * t];
        let mut y = vec![PAD; b * t];
        for (row, (rx, ry)) in chunk.iter().enumerate() {
            // Left-truncate (keep the scored continuation) when the prompt
            // exceeds the context window — mirrors the training loader.
            let start = rx.len().saturating_sub(t);
            let n = rx.len() - start;
            x[row * t..row * t + n].copy_from_slice(&rx[start..]);
            y[row * t..row * t + n].copy_from_slice(&ry[start..]);
        }
        let xb = session.upload_i32(&x, &[b, t])?;
        let yb = session.upload_i32(&y, &[b, t])?;
        let res = session.execute_buf(&entry, &[params, &xb, &yb])?;
        let flat = session.fetch_f32(&res)?; // (2, b): loss sums; counts
        for row in 0..chunk.len() {
            let loss_sum = flat[row] as f64;
            let count = flat[b + row] as f64;
            out.push(if count > 0.0 { loss_sum / count } else { f64::MAX });
        }
    }
    Ok(out)
}

/// Score one MC family: % of items whose gold option minimizes loss.
fn score_mc(
    session: &Session,
    preset: &str,
    params: &PjRtBuffer,
    items: &[McItem],
) -> Result<f64> {
    let mut rows = Vec::new();
    for item in items {
        for opt in &item.options {
            rows.push(rows_for(&item.prompt, opt));
        }
    }
    let losses = seq_mean_losses(session, preset, params, &rows)?;
    let mut correct = 0usize;
    let mut cursor = 0;
    for item in items {
        let k = item.options.len();
        let slice = &losses[cursor..cursor + k];
        cursor += k;
        let best = slice
            .iter()
            .enumerate()
            .min_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        if best == item.answer {
            correct += 1;
        }
    }
    Ok(100.0 * correct as f64 / items.len() as f64)
}

/// Writing win rate: tuned model beats the base model on gold-response
/// likelihood (the AlpacaFarm-vs-reference substitution).
fn score_winrate(
    session: &Session,
    preset: &str,
    params: &PjRtBuffer,
    base: &PjRtBuffer,
    items: &[McItem],
) -> Result<f64> {
    let rows: Vec<_> = items
        .iter()
        .map(|i| rows_for(&i.prompt, &i.options[0]))
        .collect();
    let tuned = seq_mean_losses(session, preset, params, &rows)?;
    let reference = seq_mean_losses(session, preset, base, &rows)?;
    let wins = tuned
        .iter()
        .zip(&reference)
        .filter(|(t, r)| t < r)
        .count();
    Ok(100.0 * wins as f64 / items.len() as f64)
}

/// Run the full five-benchmark suite.
pub fn run_suite(
    session: &Session,
    preset: &str,
    params: &PjRtBuffer,
    base_params: &PjRtBuffer,
    n_items: usize,
    seed: u64,
) -> Result<SuiteResult> {
    let mut scores = BTreeMap::new();
    for family in FAMILIES {
        let items = eval_items(family, seed, n_items);
        let score = match family {
            Family::Writing => {
                score_winrate(session, preset, params, base_params, &items)?
            }
            _ => score_mc(session, preset, params, &items)?,
        };
        scores.insert(family.name(), score);
    }
    let avg = scores.values().sum::<f64>() / scores.len() as f64;
    Ok(SuiteResult { scores, avg })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rows_mask_prompt_only() {
        let (x, y) = rows_for("ab", "cd");
        assert_eq!(x, encode("abcd"));
        assert_eq!(y[0], 0);
        assert_eq!(y[1], 'c' as i32);
        assert_eq!(y[2], 'd' as i32);
        assert_eq!(y[3], 0);
    }
}
