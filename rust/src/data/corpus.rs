//! Domain corpus generators (DESIGN.md §4 substitutions).
//!
//! Each domain is a deterministic generative process over bytes whose
//! distance from the pre-training mixture encodes the paper's setup:
//!
//! * `general` — synthetic English-like prose (syllabic words, Zipf-ish
//!   frequencies): the bulk of the pre-training mix.
//! * `c4` — the pre-training mixture itself: mostly `general` plus a
//!   sprinkle of code and numerals (paper §4.3 trains from scratch on C4).
//! * `chinese` — GB2312-style two-byte symbols, no ASCII words: maximal
//!   distance from the mix, so further pre-training shows a large
//!   perplexity drop (paper Fig. 2).
//! * `python_code` — grammar-generated Python: shares ASCII with the mix,
//!   so the initial perplexity is lower and the improvement smaller
//!   (paper Fig. 3's contrast with Fig. 2).
//!
//! The *language* of each domain (word banks, grammar tables) is fixed by
//! internal constants; user seeds only vary which documents are sampled —
//! so train/validation splits from different seeds share a language.

use crate::util::rng::Pcg32;

use super::tokenizer::DOC_SEP;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    General,
    Chinese,
    PythonCode,
    C4,
}

impl Domain {
    pub fn parse(name: &str) -> anyhow::Result<Domain> {
        Ok(match name {
            "general" => Domain::General,
            "chinese" => Domain::Chinese,
            "python_code" | "python" => Domain::PythonCode,
            "c4" => Domain::C4,
            other => anyhow::bail!("unknown domain {other:?}"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Domain::General => "general",
            Domain::Chinese => "chinese",
            Domain::PythonCode => "python_code",
            Domain::C4 => "c4",
        }
    }
}

/// Fixed internal seed for language construction (NOT document sampling).
const LANG_SEED: u64 = 0xADA1030;

const CONSONANTS: &[&str] = &[
    "b", "c", "d", "f", "g", "h", "j", "k", "l", "m", "n", "p", "r", "s",
    "t", "v", "w", "z", "st", "tr", "ch", "sh",
];
const VOWELS: &[&str] = &["a", "e", "i", "o", "u", "ai", "ou", "ea"];

/// Deterministic word bank shared by every `general`/`c4` generator.
fn word_bank(n: usize) -> Vec<String> {
    let mut rng = Pcg32::new(LANG_SEED, 1);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let syllables = 1 + rng.below(3);
        let mut w = String::new();
        for _ in 0..syllables {
            w.push_str(*rng.choose(CONSONANTS));
            w.push_str(*rng.choose(VOWELS));
        }
        if rng.f32() < 0.3 {
            w.push_str(*rng.choose(CONSONANTS));
        }
        if !out.contains(&w) {
            out.push(w);
        }
    }
    out
}

/// GB2312-style symbol bank: two-byte codes in 0xB0..0xE0 x 0xA1..0xF0.
fn symbol_bank(n: usize) -> Vec<[u8; 2]> {
    let mut rng = Pcg32::new(LANG_SEED, 2);
    let mut out = Vec::with_capacity(n);
    while out.len() < n {
        let s = [
            0xB0 + rng.below(0x30) as u8,
            0xA1 + rng.below(0x4F) as u8,
        ];
        if !out.contains(&s) {
            out.push(s);
        }
    }
    out
}

const PY_IDENTS: &[&str] = &[
    "x", "y", "n", "acc", "total", "data", "item", "value", "count", "idx",
    "result", "buf", "key", "node", "left", "right",
];
const PY_FUNCS: &[&str] = &[
    "process", "compute", "merge", "split_items", "reduce_all", "scan",
    "lookup", "apply_fn", "normalize", "pack",
];

/// Zipf-ish rank sampling: weight 1/(rank + 3).
fn zipf(rng: &mut Pcg32, n: usize) -> usize {
    // Inverse-CDF-free rejection-ish approach: few iterations, cheap.
    loop {
        let r = rng.below(n);
        if rng.f32() < 3.0 / (r as f32 + 3.0) {
            return r;
        }
    }
}

/// Streaming document generator for one domain.
pub struct CorpusGen {
    pub domain: Domain,
    rng: Pcg32,
    words: Vec<String>,
    symbols: Vec<[u8; 2]>,
}

impl CorpusGen {
    pub fn new(domain: Domain, seed: u64) -> CorpusGen {
        CorpusGen {
            domain,
            rng: Pcg32::new(seed, domain as u64 + 10),
            words: word_bank(512),
            symbols: symbol_bank(384),
        }
    }

    /// One document (sentence/paragraph/function), as bytes. Never
    /// contains NUL (PAD) or DOC_SEP.
    pub fn doc(&mut self) -> Vec<u8> {
        match self.domain {
            Domain::General => {
                let n = 2 + self.rng.below(3);
                self.general_paragraph(n)
            }
            Domain::Chinese => self.chinese_paragraph(),
            Domain::PythonCode => self.python_function(),
            Domain::C4 => {
                let roll = self.rng.f32();
                if roll < 0.85 {
                    let n = 1 + self.rng.below(4);
                    self.general_paragraph(n)
                } else if roll < 0.95 {
                    self.python_function()
                } else {
                    self.numeric_fragment()
                }
            }
        }
    }

    /// Pack documents (joined by DOC_SEP) until at least `n_bytes`.
    pub fn stream(&mut self, n_bytes: usize) -> Vec<u8> {
        let mut out = Vec::with_capacity(n_bytes + 256);
        while out.len() < n_bytes {
            out.extend_from_slice(&self.doc());
            out.push(DOC_SEP);
        }
        out
    }

    fn sentence(&mut self) -> String {
        let n = 4 + self.rng.below(9);
        let mut parts = Vec::with_capacity(n);
        for _ in 0..n {
            let idx = zipf(&mut self.rng, self.words.len());
            parts.push(self.words[idx].clone());
        }
        let mut s = parts.join(" ");
        // Capitalize first letter; safe: bank words are ASCII.
        s[..1].make_ascii_uppercase();
        s.push('.');
        s
    }

    fn general_paragraph(&mut self, sentences: usize) -> Vec<u8> {
        let mut out = String::new();
        for i in 0..sentences {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&self.sentence());
        }
        out.into_bytes()
    }

    fn chinese_paragraph(&mut self) -> Vec<u8> {
        let mut out = Vec::new();
        let sentences = 1 + self.rng.below(4);
        for _ in 0..sentences {
            let chars = 6 + self.rng.below(18);
            for _ in 0..chars {
                let idx = zipf(&mut self.rng, self.symbols.len());
                out.extend_from_slice(&self.symbols[idx]);
            }
            // GB2312 full-width period 0xA1 0xA3.
            out.extend_from_slice(&[0xA1, 0xA3]);
        }
        out
    }

    fn python_function(&mut self) -> Vec<u8> {
        let fname = *self.rng.choose(PY_FUNCS);
        let arg = *self.rng.choose(PY_IDENTS);
        let mut out = format!("def {fname}({arg}):");
        let body_lines = 1 + self.rng.below(4);
        for _ in 0..body_lines {
            let v = *self.rng.choose(PY_IDENTS);
            let w = *self.rng.choose(PY_IDENTS);
            let stmt = match self.rng.below(4) {
                0 => format!("    {v} = {w} + {}", self.rng.below(100)),
                1 => format!("    if {v} > {}: {w} = {v}", self.rng.below(10)),
                2 => format!("    {v} = [{w} for {w} in range({})]",
                             1 + self.rng.below(20)),
                _ => format!("    {v} = {w} * {}", 1 + self.rng.below(9)),
            };
            out.push('\r'); // avoid DOC_SEP inside docs; '\r' plays newline
            out.push_str(&stmt);
        }
        let ret = *self.rng.choose(PY_IDENTS);
        out.push('\r');
        out.push_str(&format!("    return {ret}"));
        out.into_bytes()
    }

    fn numeric_fragment(&mut self) -> Vec<u8> {
        let n = 3 + self.rng.below(8);
        let nums: Vec<String> = (0..n)
            .map(|_| format!("{}", self.rng.below(10_000)))
            .collect();
        nums.join(", ").into_bytes()
    }
}

/// Byte histogram (for the distribution-distance tests and DESIGN claims).
pub fn byte_histogram(bytes: &[u8]) -> [f64; 256] {
    let mut h = [0f64; 256];
    for &b in bytes {
        h[b as usize] += 1.0;
    }
    let total: f64 = h.iter().sum::<f64>().max(1.0);
    for v in h.iter_mut() {
        *v /= total;
    }
    h
}

/// Total-variation distance between two byte distributions.
pub fn tv_distance(a: &[f64; 256], b: &[f64; 256]) -> f64 {
    0.5 * a.iter().zip(b).map(|(x, y)| (x - y).abs()).sum::<f64>()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let d1 = CorpusGen::new(Domain::General, 7).stream(1000);
        let d2 = CorpusGen::new(Domain::General, 7).stream(1000);
        let d3 = CorpusGen::new(Domain::General, 8).stream(1000);
        assert_eq!(d1, d2);
        assert_ne!(d1, d3);
    }

    #[test]
    fn no_pad_bytes_emitted() {
        for domain in [
            Domain::General,
            Domain::Chinese,
            Domain::PythonCode,
            Domain::C4,
        ] {
            let s = CorpusGen::new(domain, 1).stream(5000);
            assert!(!s.contains(&0u8), "{domain:?} emitted NUL");
        }
    }

    #[test]
    fn chinese_is_far_python_is_near() {
        // The domain-distance ordering that drives Fig. 2 vs Fig. 3.
        let c4 = byte_histogram(&CorpusGen::new(Domain::C4, 1).stream(40_000));
        let zh =
            byte_histogram(&CorpusGen::new(Domain::Chinese, 1).stream(40_000));
        let py = byte_histogram(
            &CorpusGen::new(Domain::PythonCode, 1).stream(40_000),
        );
        let d_zh = tv_distance(&c4, &zh);
        let d_py = tv_distance(&c4, &py);
        assert!(d_zh > 0.9, "chinese should be almost disjoint: {d_zh}");
        assert!(d_py < 0.6, "python shares ASCII: {d_py}");
        assert!(d_zh > d_py + 0.3);
    }

    #[test]
    fn python_docs_look_like_code() {
        let doc = CorpusGen::new(Domain::PythonCode, 3).doc();
        let text = String::from_utf8(doc).unwrap();
        assert!(text.starts_with("def "));
        assert!(text.contains("return "));
    }

    #[test]
    fn chinese_uses_two_byte_symbols() {
        let doc = CorpusGen::new(Domain::Chinese, 3).doc();
        assert!(doc.iter().all(|&b| b >= 0xA1), "{doc:?}");
        assert_eq!(doc.len() % 2, 0);
    }

    #[test]
    fn stream_reaches_length_and_separates_docs() {
        let s = CorpusGen::new(Domain::C4, 5).stream(10_000);
        assert!(s.len() >= 10_000);
        assert!(s.iter().filter(|&&b| b == DOC_SEP).count() > 3);
    }
}
