//! Byte-level tokenizer. Vocab = 256 raw bytes; id 0 is PAD/ignore (the
//! generators never emit NUL), so loss masks are just `y != 0`.

pub const VOCAB: usize = 256;
pub const PAD: i32 = 0;
/// Document separator in packed streams.
pub const DOC_SEP: u8 = b'\n';

pub fn encode(text: &str) -> Vec<i32> {
    text.bytes().map(|b| b as i32).collect()
}

pub fn encode_bytes(bytes: &[u8]) -> Vec<i32> {
    bytes.iter().map(|&b| b as i32).collect()
}

pub fn decode(ids: &[i32]) -> String {
    let bytes: Vec<u8> = ids
        .iter()
        .filter(|&&t| t != PAD)
        .map(|&t| (t & 0xff) as u8)
        .collect();
    String::from_utf8_lossy(&bytes).into_owned()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_ascii() {
        let ids = encode("hello, world");
        assert_eq!(decode(&ids), "hello, world");
        assert!(ids.iter().all(|&t| t > 0 && t < 256));
    }

    #[test]
    fn pad_dropped_on_decode() {
        assert_eq!(decode(&[104, 0, 105]), "hi");
    }

    #[test]
    fn bytes_roundtrip() {
        let ids = encode_bytes(&[200, 201, 10]);
        assert_eq!(ids, vec![200, 201, 10]);
    }
}
