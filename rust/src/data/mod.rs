//! Synthetic data substrate.
//!
//! The paper's corpora (GPT-4-Alpaca, Baidu-baike, StarCoder-Python, C4)
//! are substituted with deterministic generators whose *statistics* encode
//! what each experiment needs (DESIGN.md §4): domain distance drives the
//! further-pre-training story, instruction structure drives the tuning
//! story. Everything is byte-level (vocab 256, pad/ignore id 0).

pub mod corpus;
pub mod instruct;
pub mod loader;
pub mod tokenizer;

pub use corpus::Domain;
pub use loader::{Batch, DataLoader};
