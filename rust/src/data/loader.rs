//! Batching: packed LM streams (pre-training) and padded example batches
//! (instruction tuning), with deterministic per-epoch shuffling.

use crate::util::rng::Pcg32;

use super::corpus::{CorpusGen, Domain};
use super::tokenizer::PAD;

/// One (x, y) batch of token ids, row-major (b, t). y is the next-token
/// target with PAD (=0) marking ignored positions.
#[derive(Debug, Clone)]
pub struct Batch {
    pub x: Vec<i32>,
    pub y: Vec<i32>,
    pub b: usize,
    pub t: usize,
}

impl Batch {
    pub fn counted_tokens(&self) -> usize {
        self.y.iter().filter(|&&v| v != PAD).count()
    }
}

enum Source {
    /// Contiguous token stream; windows of t+1 tokens at shuffled offsets.
    Stream(Vec<u8>),
    /// Explicit (x, y) examples padded to t.
    Examples(Vec<(Vec<i32>, Vec<i32>)>),
}

/// Deterministic batch iterator. Reshuffles at each epoch boundary from a
/// per-epoch PRNG stream, so any (seed, epoch, index) is reproducible.
pub struct DataLoader {
    source: Source,
    order: Vec<usize>,
    cursor: usize,
    pub epoch: usize,
    pub b: usize,
    pub t: usize,
    rng: Pcg32,
    /// Pristine copy of `rng` from construction time — what [`reset`]
    /// rewinds to so a validation loader replays the identical batch
    /// sequence at every evaluation point.
    ///
    /// [`reset`]: DataLoader::reset
    rng0: Pcg32,
}

impl DataLoader {
    /// Language-model loader over `n_tokens` of a generated domain stream.
    pub fn lm(domain: Domain, seed: u64, b: usize, t: usize, n_tokens: usize) -> DataLoader {
        let stream = CorpusGen::new(domain, seed).stream(n_tokens.max(b * (t + 1)));
        Self::from_stream(stream, seed, b, t)
    }

    pub fn from_stream(stream: Vec<u8>, seed: u64, b: usize, t: usize) -> DataLoader {
        let n_windows = (stream.len().saturating_sub(1)) / t;
        assert!(
            n_windows >= b,
            "stream too short: {} windows for batch {b}",
            n_windows
        );
        let mut dl = DataLoader {
            source: Source::Stream(stream),
            order: (0..n_windows).collect(),
            cursor: 0,
            epoch: 0,
            b,
            t,
            rng: Pcg32::new(seed, 77),
            rng0: Pcg32::new(seed, 77),
        };
        dl.shuffle();
        dl
    }

    /// Instruction-tuning loader over explicit (x, y) examples (already
    /// tokenized; y PAD-masked on prompt positions). Examples longer than
    /// t are truncated from the LEFT (keeping the response, whose tokens
    /// carry the loss — the standard recipe when prompts exceed the
    /// context); shorter ones are right-padded.
    pub fn from_examples(
        examples: Vec<(Vec<i32>, Vec<i32>)>,
        seed: u64,
        b: usize,
        t: usize,
    ) -> DataLoader {
        assert!(examples.len() >= b, "need at least one batch of examples");
        let n = examples.len();
        let mut dl = DataLoader {
            source: Source::Examples(examples),
            order: (0..n).collect(),
            cursor: 0,
            epoch: 0,
            b,
            t,
            rng: Pcg32::new(seed, 78),
            rng0: Pcg32::new(seed, 78),
        };
        dl.shuffle();
        dl
    }

    /// Rewind to the exact post-construction state: epoch 0, cursor 0, the
    /// epoch-0 shuffle order. Two loaders with the same seed — or one
    /// loader reset between uses — yield identical batch sequences, which
    /// is what makes eval-curve points comparable (the trainer resets its
    /// validation loader before every evaluation).
    pub fn reset(&mut self) {
        self.cursor = 0;
        self.epoch = 0;
        self.rng = self.rng0.clone();
        let n = self.order.len();
        self.order = (0..n).collect();
        self.shuffle();
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    pub fn batches_per_epoch(&self) -> usize {
        self.order.len() / self.b
    }

    fn shuffle(&mut self) {
        let mut epoch_rng = self.rng.fork(self.epoch as u64);
        epoch_rng.shuffle(&mut self.order);
    }

    pub fn next_batch(&mut self) -> Batch {
        if self.cursor + self.b > self.order.len() {
            self.cursor = 0;
            self.epoch += 1;
            self.shuffle();
        }
        let idxs: Vec<usize> =
            self.order[self.cursor..self.cursor + self.b].to_vec();
        self.cursor += self.b;
        let (b, t) = (self.b, self.t);
        let mut x = vec![PAD; b * t];
        let mut y = vec![PAD; b * t];
        match &self.source {
            Source::Stream(stream) => {
                for (row, &w) in idxs.iter().enumerate() {
                    let start = w * t;
                    for j in 0..t {
                        x[row * t + j] = stream[start + j] as i32;
                        y[row * t + j] = stream[start + j + 1] as i32;
                    }
                }
            }
            Source::Examples(examples) => {
                for (row, &e) in idxs.iter().enumerate() {
                    let (ex, ey) = &examples[e];
                    let start = ex.len().saturating_sub(t);
                    let n = ex.len() - start;
                    x[row * t..row * t + n].copy_from_slice(&ex[start..]);
                    y[row * t..row * t + n].copy_from_slice(&ey[start..]);
                }
            }
        }
        Batch { x, y, b, t }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lm_batches_shift_by_one() {
        let stream: Vec<u8> = (1..=101).collect();
        let mut dl = DataLoader::from_stream(stream, 1, 2, 10);
        let batch = dl.next_batch();
        for row in 0..2 {
            for j in 0..9 {
                assert_eq!(
                    batch.x[row * 10 + j + 1],
                    batch.y[row * 10 + j],
                    "y must be x shifted by one"
                );
            }
        }
    }

    #[test]
    fn epochs_reshuffle_deterministically() {
        let stream: Vec<u8> = (0..2001).map(|i| (i % 255 + 1) as u8).collect();
        let mut a = DataLoader::from_stream(stream.clone(), 9, 4, 16);
        let mut b = DataLoader::from_stream(stream, 9, 4, 16);
        let per_epoch = a.batches_per_epoch();
        let mut first_epoch = Vec::new();
        for _ in 0..per_epoch {
            first_epoch.push(a.next_batch().x);
            b.next_batch();
        }
        // Second epoch differs in order but not content (same windows).
        let second = a.next_batch();
        assert_eq!(a.epoch, 1);
        assert!(first_epoch.iter().any(|x| *x != second.x));
        // Two loaders with the same seed agree step-for-step.
        assert_eq!(a.next_batch().x, {
            b.next_batch();
            b.next_batch().x
        });
    }

    #[test]
    fn example_batches_pad_and_left_truncate() {
        // One long example whose loss targets sit at the END (instruction
        // tuning shape): left-truncation must keep them.
        let mut long_x = vec![9i32; 20];
        let mut long_y = vec![0i32; 20];
        long_x[18] = 3;
        long_x[19] = 4;
        long_y[18] = 4;
        long_y[19] = 5;
        let examples = vec![
            (vec![1, 2, 3], vec![0, 2, 3]),
            (long_x, long_y),
            (vec![6], vec![6]),
            (vec![7, 8], vec![0, 8]),
        ];
        let mut dl = DataLoader::from_examples(examples, 1, 4, 8);
        let mut seen_tail = false;
        for _ in 0..2 {
            let batch = dl.next_batch();
            assert_eq!(batch.x.len(), 32);
            assert!(batch.counted_tokens() > 0);
            for row in 0..4 {
                let yr = &batch.y[row * 8..(row + 1) * 8];
                // If this row is the long example, its response survived.
                if yr[6] == 4 && yr[7] == 5 {
                    seen_tail = true;
                }
            }
        }
        assert!(seen_tail, "left-truncation must keep the response tokens");
    }

    #[test]
    #[should_panic]
    fn too_short_stream_panics() {
        DataLoader::from_stream(vec![1, 2, 3], 0, 4, 16);
    }

    #[test]
    fn reset_replays_identical_batches() {
        let stream: Vec<u8> = (0..3001).map(|i| (i % 255 + 1) as u8).collect();
        let mut dl = DataLoader::from_stream(stream, 13, 2, 16);
        let first: Vec<_> = (0..5).map(|_| dl.next_batch().x).collect();
        // Drift deep into the stream (across an epoch boundary).
        for _ in 0..(2 * dl.batches_per_epoch()) {
            dl.next_batch();
        }
        assert!(dl.epoch > 0);
        dl.reset();
        assert_eq!(dl.epoch, 0);
        let replay: Vec<_> = (0..5).map(|_| dl.next_batch().x).collect();
        assert_eq!(first, replay, "reset must replay the same fixed set");
    }
}
