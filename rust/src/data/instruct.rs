//! Synthetic instruction-tuning data + the five-benchmark evaluation
//! questions (paper §4.1 substitution, DESIGN.md §4).
//!
//! Five task families probe the same axes as the paper's benchmarks:
//!
//! | paper      | here                                           |
//! |------------|------------------------------------------------|
//! | MMLU       | Knowledge: synthetic atlas facts, 4-way MC     |
//! | BBH        | Reasoning: periodic pattern continuation, MC   |
//! | GSM8K      | Arithmetic: 2-digit add/sub, MC over numbers   |
//! | HumanEval  | Code: bracket-sequence completion, MC          |
//! | AlpacaFarm | Writing: instruction-following win rate        |
//!
//! Training examples are rendered through the paper's exact Alpaca
//! templates (Table 4); answers are scored by per-sequence likelihood
//! (lm-eval-harness style), so evaluation shares the AOT `seq loss` path
//! with training and needs no sampling loop.

use crate::util::rng::Pcg32;

use super::tokenizer::{encode, PAD};

/// Alpaca template WITH input (paper Table 4, verbatim).
pub const TEMPLATE_WITH_INPUT: &str = "Below is an instruction that describes a task, paired with an input that provides further context. Write a response that appropriately completes the request.\n\n### Instruction:\n{instruction}\n\n### Input:\n{input}\n\n### Response: ";
/// Alpaca template WITHOUT input (paper Table 4, verbatim).
pub const TEMPLATE_NO_INPUT: &str = "Below is an instruction that describes a task. Write a response that appropriately completes the request.\n\n### Instruction:\n{instruction}\n\n### Response: ";

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    Knowledge,
    Reasoning,
    Arithmetic,
    Code,
    Writing,
}

pub const FAMILIES: [Family; 5] = [
    Family::Knowledge,
    Family::Reasoning,
    Family::Arithmetic,
    Family::Code,
    Family::Writing,
];

impl Family {
    pub fn name(&self) -> &'static str {
        match self {
            Family::Knowledge => "knowledge",
            Family::Reasoning => "reasoning",
            Family::Arithmetic => "arithmetic",
            Family::Code => "code",
            Family::Writing => "writing",
        }
    }

    /// The paper benchmark this family stands in for.
    pub fn paper_benchmark(&self) -> &'static str {
        match self {
            Family::Knowledge => "MMLU",
            Family::Reasoning => "BBH",
            Family::Arithmetic => "GSM8K",
            Family::Code => "HumanEval",
            Family::Writing => "AlpacaFarm",
        }
    }
}

#[derive(Debug, Clone)]
pub struct Example {
    pub instruction: String,
    pub input: String,
    pub response: String,
}

impl Example {
    pub fn prompt(&self) -> String {
        if self.input.is_empty() {
            TEMPLATE_NO_INPUT.replace("{instruction}", &self.instruction)
        } else {
            TEMPLATE_WITH_INPUT
                .replace("{instruction}", &self.instruction)
                .replace("{input}", &self.input)
        }
    }

    /// Tokenize to (x, y) with the prompt masked out of the loss (standard
    /// instruction-tuning recipe).
    pub fn tokenize(&self) -> (Vec<i32>, Vec<i32>) {
        let prompt = encode(&self.prompt());
        let response = encode(&self.response);
        let mut x = prompt.clone();
        x.extend_from_slice(&response);
        // y[i] predicts x[i+1]; prompt positions are PAD-masked, response
        // tokens (and nothing after) are counted.
        let mut y = vec![PAD; x.len()];
        for i in 0..response.len() {
            y[prompt.len() - 1 + i] = response[i];
        }
        (x, y)
    }
}

/// A 4-way multiple-choice evaluation item.
#[derive(Debug, Clone)]
pub struct McItem {
    pub family: Family,
    pub prompt: String,
    pub options: Vec<String>,
    pub answer: usize,
}

/// Synthetic knowledge base: a fictional atlas (regions -> capitals),
/// fixed by an internal seed so training and evaluation agree on facts.
pub struct Kb {
    pub regions: Vec<String>,
    pub capitals: Vec<String>,
}

const KB_SEED: u64 = 0xFAC75;
pub const KB_SIZE: usize = 48;

impl Kb {
    pub fn build() -> Kb {
        let mut rng = Pcg32::new(KB_SEED, 3);
        let syll = ["var", "men", "dor", "kal", "ith", "pra", "zun", "bel",
                    "tor", "ash", "gla", "nim"];
        let mut mk = |suffix: &str, cap: bool| {
            let n = 2 + rng.below(2);
            let mut w = String::new();
            for _ in 0..n {
                w.push_str(syll[rng.below(syll.len())]);
            }
            w.push_str(suffix);
            if cap {
                w[..1].make_ascii_uppercase();
            }
            w
        };
        let mut regions = Vec::new();
        let mut capitals = Vec::new();
        while regions.len() < KB_SIZE {
            let r = mk("ia", true);
            let c = mk("grad", true);
            if !regions.contains(&r) && !capitals.contains(&c) {
                regions.push(r);
                capitals.push(c);
            }
        }
        Kb { regions, capitals }
    }
}

/// Pattern alphabets for the reasoning family.
const PATTERN_TOKENS: &[&str] = &["red", "blue", "gold", "iron", "moss"];

/// Writing-task topics.
const TOPICS: &[&str] = &[
    "rivers", "lanterns", "gardens", "engines", "harbors", "orchards",
    "mirrors", "bridges", "clocks", "meadows",
];

fn knowledge_example(kb: &Kb, i: usize) -> Example {
    Example {
        instruction: format!(
            "What is the capital of {}?",
            kb.regions[i % kb.regions.len()]
        ),
        input: String::new(),
        response: format!(
            "The capital of {} is {}.",
            kb.regions[i % kb.regions.len()],
            kb.capitals[i % kb.capitals.len()]
        ),
    }
}

fn reasoning_example(rng: &mut Pcg32) -> (Example, usize, Vec<String>) {
    // Periodic pattern a b c a b c ... -> next element.
    let period = 2 + rng.below(3);
    let offset = rng.below(PATTERN_TOKENS.len());
    let pattern: Vec<&str> = (0..period)
        .map(|k| PATTERN_TOKENS[(offset + k) % PATTERN_TOKENS.len()])
        .collect();
    let shown = period * 2 + rng.below(period);
    let seq: Vec<&str> = (0..shown).map(|k| pattern[k % period]).collect();
    let answer_tok = pattern[shown % period];
    let ex = Example {
        instruction: "Continue the repeating pattern with the next word."
            .to_string(),
        input: seq.join(" "),
        response: answer_tok.to_string(),
    };
    let answer_idx = PATTERN_TOKENS.iter().position(|&t| t == answer_tok).unwrap();
    let options: Vec<String> =
        PATTERN_TOKENS.iter().take(4).map(|s| s.to_string()).collect();
    // Ensure the right answer is among the first 4 tokens.
    let (options, answer) = if answer_idx < 4 {
        (options, answer_idx)
    } else {
        let mut o = options;
        o[0] = answer_tok.to_string();
        (o, 0)
    };
    (ex, answer, options)
}

fn arithmetic_example(rng: &mut Pcg32) -> (Example, i64) {
    let a = 10 + rng.below(90) as i64;
    let b = 10 + rng.below(90) as i64;
    let (text, val) = if rng.f32() < 0.5 {
        (format!("{a} + {b}"), a + b)
    } else {
        (format!("{} - {b}", a + b), a)
    };
    (
        Example {
            instruction: format!("Compute {text}."),
            input: String::new(),
            response: format!("{val}"),
        },
        val,
    )
}

fn code_example(rng: &mut Pcg32) -> (Example, String) {
    // Close an open bracket sequence (HumanEval-in-miniature: syntactic
    // completion with an exact checkable answer).
    let depth = 1 + rng.below(4);
    let kinds = ["()", "[]", "{}"];
    let mut open = String::new();
    let mut close = String::new();
    for _ in 0..depth {
        let k = kinds[rng.below(3)];
        open.push(k.as_bytes()[0] as char);
        close.insert(0, k.as_bytes()[1] as char);
    }
    (
        Example {
            instruction: "Write the closing brackets that complete the sequence.".to_string(),
            input: open,
            response: close.clone(),
        },
        close,
    )
}

fn writing_example(rng: &mut Pcg32, kb: &Kb) -> Example {
    let topic = TOPICS[rng.below(TOPICS.len())];
    let region = &kb.regions[rng.below(kb.regions.len())];
    Example {
        instruction: format!("Write one sentence about the {topic} of {region}."),
        input: String::new(),
        response: format!(
            "The {topic} of {region} are known across the land for their quiet beauty."
        ),
    }
}

/// Instruction-tuning training set: a balanced mixture of all families
/// rendered through the Alpaca templates (the 52k GPT-4-Alpaca stand-in).
pub fn training_set(seed: u64, n: usize) -> Vec<Example> {
    let kb = Kb::build();
    let mut rng = Pcg32::new(seed, 21);
    let mut out = Vec::with_capacity(n);
    for i in 0..n {
        let ex = match i % 5 {
            0 => knowledge_example(&kb, rng.below(KB_SIZE)),
            1 => reasoning_example(&mut rng).0,
            2 => arithmetic_example(&mut rng).0,
            3 => code_example(&mut rng).0,
            _ => writing_example(&mut rng, &kb),
        };
        out.push(ex);
    }
    out
}

/// Evaluation items for one family. `seed` controls instance sampling;
/// reasoning/arithmetic/code items generalize (fresh instances), knowledge
/// items probe the shared KB.
pub fn eval_items(family: Family, seed: u64, n: usize) -> Vec<McItem> {
    let kb = Kb::build();
    let mut rng = Pcg32::new(seed, 31);
    let mut out = Vec::with_capacity(n);
    for _ in 0..n {
        let item = match family {
            Family::Knowledge => {
                let i = rng.below(KB_SIZE);
                let mut options = vec![kb.capitals[i].clone()];
                while options.len() < 4 {
                    let d = kb.capitals[rng.below(KB_SIZE)].clone();
                    if !options.contains(&d) {
                        options.push(d);
                    }
                }
                rng.shuffle(&mut options);
                let answer =
                    options.iter().position(|c| *c == kb.capitals[i]).unwrap();
                McItem {
                    family,
                    prompt: knowledge_example(&kb, i).prompt(),
                    options: options
                        .iter()
                        .map(|c| format!("The capital of {} is {c}.", kb.regions[i]))
                        .collect(),
                    answer,
                }
            }
            Family::Reasoning => {
                let (ex, answer, options) = reasoning_example(&mut rng);
                McItem { family, prompt: ex.prompt(), options, answer }
            }
            Family::Arithmetic => {
                let (ex, val) = arithmetic_example(&mut rng);
                let mut options = vec![format!("{val}")];
                for delta in [-10i64, 1, 10] {
                    options.push(format!("{}", val + delta));
                }
                let answer = 0;
                // Keep answer position fixed at 0 then rotate by rng for
                // balance.
                let rot = rng.below(4);
                options.rotate_right(rot);
                McItem {
                    family,
                    prompt: ex.prompt(),
                    options,
                    answer: (answer + rot) % 4,
                }
            }
            Family::Code => {
                let (ex, close) = code_example(&mut rng);
                let mut options = vec![close.clone()];
                while options.len() < 4 {
                    let (_, alt) = code_example(&mut rng);
                    if !options.contains(&alt) {
                        options.push(alt);
                    }
                }
                rng.shuffle(&mut options);
                let answer =
                    options.iter().position(|o| *o == close).unwrap();
                McItem { family, prompt: ex.prompt(), options, answer }
            }
            Family::Writing => {
                // Writing is scored as win-rate, not MC; represented as a
                // 1-option item holding the gold response.
                let ex = writing_example(&mut rng, &kb);
                McItem {
                    family,
                    prompt: ex.prompt(),
                    options: vec![ex.response],
                    answer: 0,
                }
            }
        };
        out.push(item);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn templates_match_table4() {
        assert!(TEMPLATE_WITH_INPUT.contains("### Instruction:"));
        assert!(TEMPLATE_WITH_INPUT.contains("### Input:"));
        assert!(TEMPLATE_NO_INPUT.contains("### Response:"));
        assert!(!TEMPLATE_NO_INPUT.contains("### Input:"));
    }

    #[test]
    fn tokenize_masks_prompt() {
        let ex = Example {
            instruction: "Say hi.".into(),
            input: String::new(),
            response: "hi".into(),
        };
        let (x, y) = ex.tokenize();
        assert_eq!(x.len(), y.len());
        let counted = y.iter().filter(|&&v| v != 0).count();
        assert_eq!(counted, 2); // exactly the response bytes
        // The first response target sits at prompt_len - 1.
        let plen = encode(&ex.prompt()).len();
        assert_eq!(y[plen - 1], 'h' as i32);
    }

    #[test]
    fn kb_is_stable_and_unique() {
        let a = Kb::build();
        let b = Kb::build();
        assert_eq!(a.regions, b.regions);
        assert_eq!(a.capitals.len(), KB_SIZE);
        let mut caps = a.capitals.clone();
        caps.dedup();
        assert_eq!(caps.len(), KB_SIZE);
    }

    #[test]
    fn training_set_mixes_families() {
        let set = training_set(1, 50);
        assert_eq!(set.len(), 50);
        assert!(set.iter().any(|e| e.instruction.contains("capital")));
        assert!(set.iter().any(|e| e.instruction.contains("Compute")));
        assert!(set.iter().any(|e| e.instruction.contains("closing brackets")));
    }

    #[test]
    fn eval_items_have_valid_answers() {
        for family in FAMILIES {
            let items = eval_items(family, 9, 20);
            for item in items {
                assert!(item.answer < item.options.len(), "{family:?}");
                if family != Family::Writing {
                    assert_eq!(item.options.len(), 4);
                    // Options must be distinct for MC scoring to make sense.
                    let mut o = item.options.clone();
                    o.sort();
                    o.dedup();
                    assert_eq!(o.len(), 4, "{family:?}: {:?}", item.options);
                }
            }
        }
    }

    #[test]
    fn arithmetic_options_contain_answer() {
        let items = eval_items(Family::Arithmetic, 3, 30);
        for item in items {
            // Reconstruct: correct answer is options[answer]; verify it
            // differs from distractors and parses as integer.
            let v: i64 = item.options[item.answer].parse().unwrap();
            for (i, o) in item.options.iter().enumerate() {
                if i != item.answer {
                    assert_ne!(o.parse::<i64>().unwrap(), v);
                }
            }
        }
    }
}
