//! Thread-per-rank data-parallel execution.
//!
//! Each rank owns its own PJRT CPU session and trains on an independent
//! data stream (forked PRNG); every `sync_every` steps the leader gathers
//! the ranks' parameter regions, averages them (local-SGD synchronization
//! — the collective our artifacts support without exposing raw gradients),
//! and broadcasts the average back. Optimizer state stays rank-local, as
//! in DeepSpeed's ZeRO-3 where state is sharded anyway.
//!
//! This is the "runs for real" half of the distributed story; the
//! analytic half (exact ZeRO-3 memory and NCCL timing) lives in `memsim`
//! and [`super::collective`].

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::data::{loader::DataLoader, Domain};
use crate::runtime::{HostBlob, Manifest, Session};
use crate::util::rng::Pcg32;

use super::schedule::Schedule;
use super::trainer::Trainer;

#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub n_ranks: usize,
    pub rounds: usize,
    pub per_rank_final_loss: Vec<f32>,
    /// Validation loss of the averaged model after the final round.
    pub averaged_eval_loss: f64,
    pub wall_secs: f64,
    pub aggregate_tokens_per_sec: f64,
}

/// Run `rounds` x `sync_every` steps on `n_ranks` threads with parameter
/// averaging between rounds.
pub fn run_local_sgd(
    artifacts_dir: PathBuf,
    base_cfg: RunConfig,
    domain: Domain,
    n_ranks: usize,
    rounds: usize,
    sync_every: usize,
) -> Result<WorkerReport> {
    let started = std::time::Instant::now();
    let layout_key = Manifest::layout_key(&base_cfg.preset, &base_cfg.opt);

    // Rank threads live for the whole run; channel pairs carry blobs
    // leader <-> rank between rounds.
    let mut to_ranks = Vec::new();
    let mut from_ranks = Vec::new();
    let mut handles = Vec::new();
    for rank in 0..n_ranks {
        let (tx_cmd, rx_cmd) = mpsc::channel::<Option<HostBlob>>();
        let (tx_res, rx_res) = mpsc::channel::<Result<(HostBlob, f32)>>();
        to_ranks.push(tx_cmd);
        from_ranks.push(rx_res);
        let cfg = {
            let mut c = base_cfg.clone();
            c.steps = sync_every;
            c.seed = base_cfg.seed + 1000 * rank as u64;
            c.eval_every = 0;
            c.log_every = sync_every;
            c
        };
        let dir = artifacts_dir.clone();
        handles.push(thread::spawn(move || -> Result<()> {
            let session = Session::open(&dir)?;
            let mut stream_rng = Pcg32::new(cfg.seed, 7);
            let preset = session.manifest.preset(&cfg.preset)?.clone();
            let (b, t) = (preset.batch_size, preset.seq_len);
            let schedule =
                Schedule::constant(cfg.lr * 0.5); // stable for local-SGD
            while let Ok(cmd) = rx_cmd.recv() {
                // None is the shutdown signal from the leader.
                let Some(start_blob) = cmd else { break };
                let loader = DataLoader::lm(
                    domain,
                    stream_rng.next_u64(),
                    b,
                    t,
                    sync_every * b * t + b * (t + 1),
                );
                let mut trainer =
                    Trainer::new(&session, cfg.clone(), loader, None)?;
                trainer.set_host_blob(&start_blob)?;
                let report = trainer.train_with_schedule(schedule)?;
                let blob = trainer.host_blob()?;
                tx_res.send(Ok((blob, report.final_loss)))?;
            }
            Ok(())
        }));
    }

    // Leader: init once, then rounds of (broadcast, train, gather, average).
    let leader_session = Session::open(&artifacts_dir)?;
    let layout = leader_session.manifest.layout(&layout_key)?.clone();
    let mut leader_cfg = base_cfg.clone();
    leader_cfg.steps = 1;
    let preset = leader_session.manifest.preset(&base_cfg.preset)?;
    let (b, t) = (preset.batch_size, preset.seq_len);
    let seed_loader = DataLoader::lm(domain, base_cfg.seed, b, t, 2 * b * (t + 1));
    let mut init_trainer =
        Trainer::new(&leader_session, leader_cfg, seed_loader, None)?;
    init_trainer.init_from_seed()?;
    let mut global = init_trainer.host_blob()?;

    let mut per_rank_final_loss = vec![0f32; n_ranks];
    for _round in 0..rounds {
        for tx in &to_ranks {
            tx.send(Some(global.clone()))
                .map_err(|e| anyhow!("send: {e}"))?;
        }
        let mut blobs = Vec::with_capacity(n_ranks);
        for (rank, rx) in from_ranks.iter().enumerate() {
            let (blob, loss) = rx.recv().map_err(|e| anyhow!("recv: {e}"))??;
            per_rank_final_loss[rank] = loss;
            blobs.push(blob);
        }
        // Average the parameter region; keep leader's metrics/state zeroed
        // (state is rank-local by design).
        let plen = layout.params_len;
        let mut avg = vec![0f32; layout.blob_len];
        for blob in &blobs {
            for i in 0..plen {
                avg[i] += blob.data[i];
            }
        }
        let scale = 1.0 / n_ranks as f32;
        for v in avg[..plen].iter_mut() {
            *v *= scale;
        }
        global = HostBlob::new(avg, &layout_key, &layout)?;
    }
    for tx in &to_ranks {
        let _ = tx.send(None);
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    // Evaluate the averaged model.
    let val_loader =
        DataLoader::lm(domain, base_cfg.seed + 999, b, t, 4 * b * (t + 1));
    let mut eval_cfg = base_cfg.clone();
    eval_cfg.steps = 0;
    let mut eval_trainer = Trainer::new(
        &leader_session,
        eval_cfg,
        DataLoader::lm(domain, base_cfg.seed, b, t, 2 * b * (t + 1)),
        Some(val_loader),
    )?;
    eval_trainer.set_host_blob(&global)?;
    let accum = eval_trainer.evaluate()?;

    let wall = started.elapsed().as_secs_f64();
    let tokens = (n_ranks * rounds * sync_every * b * t) as f64;
    Ok(WorkerReport {
        n_ranks,
        rounds,
        per_rank_final_loss,
        averaged_eval_loss: accum.mean_loss(),
        wall_secs: wall,
        aggregate_tokens_per_sec: tokens / wall,
    })
}
