//! Thread-per-rank data-parallel execution.
//!
//! Each rank owns its own PJRT CPU session and trains on an independent
//! data stream (forked PRNG); every `sync_every` steps the leader gathers
//! the ranks' parameter regions, averages them (local-SGD synchronization
//! — the collective our artifacts support without exposing raw gradients),
//! and broadcasts the average back. Optimizer state stays rank-local, as
//! in DeepSpeed's ZeRO-3 where state is sharded anyway: each rank keeps
//! its full blob across rounds and splices ONLY the averaged `params_len`
//! region in ([`splice_params`]); second-moment estimates therefore keep
//! accumulating across the whole run instead of being wiped at every sync
//! point, and the kernel-side step counter continues across rounds
//! (`Trainer::set_step_offset`) so bias corrections match the warm state.
//! Round averaging itself runs on the flat-engine worker pool
//! ([`crate::optim::pool::par_average`]) — element-parallel and
//! bit-identical to the sequential loop for any worker count.
//!
//! This is the "runs for real" half of the distributed story; the
//! analytic half (exact ZeRO-3 memory and NCCL timing) lives in `memsim`
//! and [`super::collective`].

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, Result};

use crate::config::RunConfig;
use crate::data::{loader::DataLoader, Domain};
use crate::optim::pool;
use crate::runtime::{HostBlob, Manifest, Session};
use crate::util::rng::Pcg32;

use super::schedule::Schedule;
use super::trainer::Trainer;

#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub n_ranks: usize,
    pub rounds: usize,
    pub per_rank_final_loss: Vec<f32>,
    /// Sum of squares of each rank's optimizer-state region after the last
    /// round — the observable for "state survives rounds" (zero would mean
    /// the round boundary wiped it).
    pub per_rank_state_sumsq: Vec<f32>,
    /// Validation loss of the averaged model after the final round.
    pub averaged_eval_loss: f64,
    pub wall_secs: f64,
    pub aggregate_tokens_per_sec: f64,
}

/// Resume blob for the next round: keep the rank's own optimizer state and
/// metrics, splice in only the averaged parameter region. The first round
/// (no retained blob yet) adopts the broadcast wholesale.
pub fn splice_params(
    prev: Option<HostBlob>,
    broadcast: HostBlob,
    params_len: usize,
) -> HostBlob {
    match prev {
        Some(mut blob) => {
            blob.data[..params_len]
                .copy_from_slice(&broadcast.data[..params_len]);
            blob
        }
        None => broadcast,
    }
}

/// Run `rounds` x `sync_every` steps on `n_ranks` threads with parameter
/// averaging between rounds.
pub fn run_local_sgd(
    artifacts_dir: PathBuf,
    base_cfg: RunConfig,
    domain: Domain,
    n_ranks: usize,
    rounds: usize,
    sync_every: usize,
) -> Result<WorkerReport> {
    let started = std::time::Instant::now();
    let layout_key = Manifest::layout_key(&base_cfg.preset, &base_cfg.opt);

    // Rank threads live for the whole run; channel pairs carry blobs
    // leader <-> rank between rounds.
    let mut to_ranks = Vec::new();
    let mut from_ranks = Vec::new();
    let mut handles = Vec::new();
    for rank in 0..n_ranks {
        let (tx_cmd, rx_cmd) = mpsc::channel::<Option<HostBlob>>();
        let (tx_res, rx_res) = mpsc::channel::<Result<(HostBlob, f32)>>();
        to_ranks.push(tx_cmd);
        from_ranks.push(rx_res);
        let cfg = {
            let mut c = base_cfg.clone();
            c.steps = sync_every;
            c.seed = base_cfg.seed + 1000 * rank as u64;
            c.eval_every = 0;
            c.log_every = sync_every;
            c
        };
        let dir = artifacts_dir.clone();
        let rank_layout_key = layout_key.clone();
        handles.push(thread::spawn(move || -> Result<()> {
            let session = Session::open(&dir)?;
            let params_len =
                session.manifest.layout(&rank_layout_key)?.params_len;
            let mut stream_rng = Pcg32::new(cfg.seed, 7);
            let preset = session.manifest.preset(&cfg.preset)?.clone();
            let (b, t) = (preset.batch_size, preset.seq_len);
            let schedule =
                Schedule::constant(cfg.lr * 0.5); // stable for local-SGD
            // Rank-local blob retained across rounds (optimizer state must
            // survive; only params are refreshed from the average).
            let mut resume: Option<HostBlob> = None;
            let mut rounds_done = 0usize;
            while let Ok(cmd) = rx_cmd.recv() {
                // None is the shutdown signal from the leader.
                let Some(broadcast) = cmd else { break };
                let start_blob =
                    splice_params(resume.take(), broadcast, params_len);
                let loader = DataLoader::lm(
                    domain,
                    stream_rng.next_u64(),
                    b,
                    t,
                    sync_every * b * t + b * (t + 1),
                );
                let mut trainer =
                    Trainer::new(&session, cfg.clone(), loader, None)?;
                // The optimizer state is warm from previous rounds, so the
                // kernel's step counter must keep counting — restarting at
                // t=1 would re-apply the t=1 bias correction to a
                // converged second-moment EMA.
                trainer.set_step_offset(rounds_done * sync_every);
                trainer.set_host_blob(&start_blob)?;
                let report = trainer.train_with_schedule(schedule)?;
                let blob = trainer.host_blob()?;
                resume = Some(blob.clone());
                rounds_done += 1;
                tx_res.send(Ok((blob, report.final_loss)))?;
            }
            Ok(())
        }));
    }

    // Leader: init once, then rounds of (broadcast, train, gather, average).
    let leader_session = Session::open(&artifacts_dir)?;
    let layout = leader_session.manifest.layout(&layout_key)?.clone();
    let mut leader_cfg = base_cfg.clone();
    leader_cfg.steps = 1;
    let preset = leader_session.manifest.preset(&base_cfg.preset)?;
    let (b, t) = (preset.batch_size, preset.seq_len);
    let seed_loader = DataLoader::lm(domain, base_cfg.seed, b, t, 2 * b * (t + 1));
    let mut init_trainer =
        Trainer::new(&leader_session, leader_cfg, seed_loader, None)?;
    init_trainer.init_from_seed()?;
    let mut global = init_trainer.host_blob()?;

    let mut per_rank_final_loss = vec![0f32; n_ranks];
    let mut last_blobs: Vec<HostBlob> = Vec::new();
    for _round in 0..rounds {
        for tx in &to_ranks {
            tx.send(Some(global.clone()))
                .map_err(|e| anyhow!("send: {e}"))?;
        }
        let mut blobs = Vec::with_capacity(n_ranks);
        for (rank, rx) in from_ranks.iter().enumerate() {
            let (blob, loss) = rx.recv().map_err(|e| anyhow!("recv: {e}"))??;
            per_rank_final_loss[rank] = loss;
            blobs.push(blob);
        }
        // Average the parameter region on the flat-engine pool; keep the
        // leader's state/metrics zeroed — ranks never read them back (each
        // splices only the params region into its retained blob).
        let plen = layout.params_len;
        let mut avg = vec![0f32; layout.blob_len];
        let sources: Vec<&[f32]> =
            blobs.iter().map(|blob| &blob.data[..plen]).collect();
        pool::par_average(
            &mut avg[..plen],
            &sources,
            1.0 / n_ranks as f32,
            pool::default_shards(),
        );
        drop(sources);
        last_blobs = blobs;
        global = HostBlob::new(avg, &layout_key, &layout)?;
    }
    for tx in &to_ranks {
        let _ = tx.send(None);
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    let per_rank_state_sumsq: Vec<f32> = last_blobs
        .iter()
        .map(|blob| crate::optim::update::sum_sq(blob.state_region(&layout)))
        .collect();

    // Evaluate the averaged model.
    let val_loader =
        DataLoader::lm(domain, base_cfg.seed + 999, b, t, 4 * b * (t + 1));
    let mut eval_cfg = base_cfg.clone();
    eval_cfg.steps = 0;
    let mut eval_trainer = Trainer::new(
        &leader_session,
        eval_cfg,
        DataLoader::lm(domain, base_cfg.seed, b, t, 2 * b * (t + 1)),
        Some(val_loader),
    )?;
    eval_trainer.set_host_blob(&global)?;
    let accum = eval_trainer.evaluate()?;

    let wall = started.elapsed().as_secs_f64();
    let tokens = (n_ranks * rounds * sync_every * b * t) as f64;
    Ok(WorkerReport {
        n_ranks,
        rounds,
        per_rank_final_loss,
        per_rank_state_sumsq,
        averaged_eval_loss: accum.mean_loss(),
        wall_secs: wall,
        aggregate_tokens_per_sec: tokens / wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Layout, Segment};

    fn layout() -> Layout {
        let mk = |name: &str, kind: &str, size: usize, offset: usize| Segment {
            name: name.into(),
            kind: kind.into(),
            shape: vec![size],
            offset,
            size,
        };
        Layout {
            blob_len: 20,
            params_len: 6,
            segments: vec![
                mk("w", "param", 6, 0),
                mk("w@v", "state", 6, 6),
                mk("metrics", "metric", 8, 12),
            ],
        }
    }

    #[test]
    fn splice_keeps_rank_local_state() {
        let l = layout();
        // A rank blob with non-zero optimizer state from earlier rounds.
        let prev = HostBlob::new(
            (0..20).map(|i| i as f32 + 1.0).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        // The broadcast average: fresh params, zeroed state (the leader
        // never trains, so its state region is all zeros).
        let mut bdata = vec![0f32; 20];
        for (i, v) in bdata.iter_mut().enumerate().take(6) {
            *v = 100.0 + i as f32;
        }
        let broadcast = HostBlob::new(bdata, "t/x", &l).unwrap();
        let spliced =
            splice_params(Some(prev.clone()), broadcast.clone(), l.params_len);
        // Params come from the broadcast...
        assert_eq!(spliced.params(&l), broadcast.params(&l));
        // ...but the optimizer state survives from the rank's own blob —
        // the module-doc promise ("optimizer state stays rank-local") that
        // the old implementation violated by adopting the zeroed blob.
        assert_eq!(spliced.state_region(&l), prev.state_region(&l));
        assert!(spliced.state_region(&l).iter().all(|&x| x != 0.0));
        // First round: no retained blob yet -> broadcast adopted wholesale.
        let first = splice_params(None, broadcast.clone(), l.params_len);
        assert_eq!(first.data, broadcast.data);
    }
}
