//! Thread-per-rank data-parallel execution.
//!
//! Each rank owns its own PJRT CPU session and trains on an independent
//! data stream (forked PRNG); every `sync_every` steps the leader gathers
//! the ranks' parameter regions, averages them (local-SGD synchronization
//! — the collective our artifacts support without exposing raw gradients),
//! and broadcasts the average back. Optimizer state stays rank-local, as
//! in DeepSpeed's ZeRO-3 where state is sharded anyway: each rank keeps
//! its full blob across rounds and splices ONLY the averaged `params_len`
//! region in ([`splice_params`]); second-moment estimates therefore keep
//! accumulating across the whole run instead of being wiped at every sync
//! point, and the kernel-side step counter continues across rounds
//! (`Trainer::set_step_offset`) so bias corrections match the warm state.
//!
//! Sync-round traffic is slim in both directions ([`Broadcast`]): only
//! round 1 ships a full blob (ranks have no state yet); afterwards the
//! leader broadcasts just the averaged parameter region and ranks return
//! just their parameter region plus two scalars — the old protocol's
//! O(ranks × blob_len) clones per round shrink to O(ranks × params_len).
//! Those `params_len` payloads ride a recycled ring (rank → leader →
//! refilled with the average → rank), so steady-state rounds perform no
//! heap allocation at all on the sync path.
//! Round averaging itself runs on the flat-engine worker pool
//! ([`crate::optim::pool::par_average`]) — element-parallel and
//! bit-identical to the sequential loop for any worker count.
//!
//! This is the "runs for real" half of the distributed story; the
//! analytic half (exact ZeRO-3 memory and NCCL timing) lives in `memsim`
//! and [`super::collective`]. Gradient-granular execution — lockstep,
//! pipelined, fused — is the unified engine's job ([`super::engine`]),
//! entered through the [`super::pipeline`]/[`super::fused_host`] plan
//! constructors; this module stays PJRT-session-granular because each
//! rank here owns a real device session rather than a host gradient
//! stream.

use std::path::PathBuf;
use std::sync::mpsc;
use std::thread;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::RunConfig;
use crate::data::{loader::DataLoader, Domain};
use crate::optim::pool;
use crate::optim::update::sum_sq;
use crate::runtime::{HostBlob, Manifest, Session};
use crate::util::rng::Pcg32;

use super::schedule::Schedule;
use super::trainer::Trainer;

#[derive(Debug, Clone)]
pub struct WorkerReport {
    pub n_ranks: usize,
    pub rounds: usize,
    pub per_rank_final_loss: Vec<f32>,
    /// Sum of squares of each rank's optimizer-state region after the last
    /// round — the observable for "state survives rounds" (zero would mean
    /// the round boundary wiped it).
    pub per_rank_state_sumsq: Vec<f32>,
    /// Validation loss of the averaged model after the final round.
    pub averaged_eval_loss: f64,
    pub wall_secs: f64,
    pub aggregate_tokens_per_sec: f64,
}

/// Leader -> rank sync payload. Round 1 must ship the whole blob (the
/// rank has no retained state yet); every later round ships only the
/// averaged parameter region — ranks splice exactly that region anyway,
/// so the full-blob clone per rank per round was pure waste.
#[derive(Debug, Clone)]
pub enum Broadcast {
    /// Full initial blob (first round only).
    Init(HostBlob),
    /// Averaged parameter region (`params_len` floats), later rounds.
    Params(Vec<f32>),
}

/// One rank's round result: its parameter region, the round's final train
/// loss, and the sum of squares of its optimizer-state region (the
/// state-survival observable). The full blob stays rank-local.
#[derive(Debug, Clone)]
struct RankRound {
    params: Vec<f32>,
    final_loss: f32,
    state_sumsq: f32,
}

/// Resume blob for the next round: keep the rank's own optimizer state and
/// metrics, splice in only the averaged parameter region. The first round
/// (no retained blob yet) adopts the broadcast wholesale.
pub fn splice_params(
    prev: Option<HostBlob>,
    broadcast: HostBlob,
    params_len: usize,
) -> HostBlob {
    match prev {
        Some(mut blob) => {
            blob.data[..params_len]
                .copy_from_slice(&broadcast.data[..params_len]);
            blob
        }
        None => broadcast,
    }
}

/// Apply one leader [`Broadcast`] to the rank's retained blob. The
/// params-only form requires a retained blob — receiving it cold is a
/// protocol violation, not something to paper over.
pub fn apply_broadcast(
    prev: Option<HostBlob>,
    msg: Broadcast,
    params_len: usize,
) -> Result<HostBlob> {
    Ok(apply_broadcast_recycled(prev, msg, params_len)?.0)
}

/// [`apply_broadcast`] that also hands back the spent `Params` payload
/// (empty for `Init` rounds). Steady-state rounds refill that Vec with
/// the rank's own parameter region and ship it back — the recycled-ring
/// seam that makes a sync round allocation-free on both sides.
pub fn apply_broadcast_recycled(
    prev: Option<HostBlob>,
    msg: Broadcast,
    params_len: usize,
) -> Result<(HostBlob, Vec<f32>)> {
    match msg {
        Broadcast::Init(blob) => {
            Ok((splice_params(prev, blob, params_len), Vec::new()))
        }
        Broadcast::Params(avg) => {
            ensure!(
                avg.len() == params_len,
                "params broadcast of {} != params_len {params_len}",
                avg.len()
            );
            let Some(mut blob) = prev else {
                bail!("params-only broadcast before any full init");
            };
            blob.data[..params_len].copy_from_slice(&avg);
            Ok((blob, avg))
        }
    }
}

/// Run `rounds` x `sync_every` steps on `n_ranks` threads with parameter
/// averaging between rounds.
pub fn run_local_sgd(
    artifacts_dir: PathBuf,
    base_cfg: RunConfig,
    domain: Domain,
    n_ranks: usize,
    rounds: usize,
    sync_every: usize,
) -> Result<WorkerReport> {
    // ANALYZE-WAIVE(determinism): wall-clock report fields only
    let started = std::time::Instant::now();
    let layout_key = Manifest::layout_key(&base_cfg.preset, &base_cfg.opt);

    // Rank threads live for the whole run; channel pairs carry sync
    // payloads leader <-> rank between rounds.
    let mut to_ranks = Vec::new();
    let mut from_ranks = Vec::new();
    let mut handles = Vec::new();
    for rank in 0..n_ranks {
        let (tx_cmd, rx_cmd) = mpsc::channel::<Option<Broadcast>>();
        let (tx_res, rx_res) = mpsc::channel::<Result<RankRound>>();
        to_ranks.push(tx_cmd);
        from_ranks.push(rx_res);
        let cfg = {
            let mut c = base_cfg.clone();
            c.steps = sync_every;
            c.seed = base_cfg.seed + 1000 * rank as u64;
            c.eval_every = 0;
            c.log_every = sync_every;
            c
        };
        let dir = artifacts_dir.clone();
        let rank_layout_key = layout_key.clone();
        // ANALYZE-WAIVE(determinism): rank threads sync on rank-ordered channels
        handles.push(thread::spawn(move || -> Result<()> {
            let session = Session::open(&dir)?;
            let layout =
                session.manifest.layout(&rank_layout_key)?.clone();
            let params_len = layout.params_len;
            let mut stream_rng = Pcg32::new(cfg.seed, 7);
            let preset = session.manifest.preset(&cfg.preset)?.clone();
            let (b, t) = (preset.batch_size, preset.seq_len);
            let schedule =
                Schedule::constant(cfg.lr * 0.5); // stable for local-SGD
            // Rank-local blob retained across rounds (optimizer state must
            // survive; only params are refreshed from the average).
            let mut resume: Option<HostBlob> = None;
            let mut rounds_done = 0usize;
            while let Ok(cmd) = rx_cmd.recv() {
                // None is the shutdown signal from the leader.
                let Some(msg) = cmd else { break };
                let (start_blob, mut send_buf) =
                    apply_broadcast_recycled(resume.take(), msg, params_len)?;
                let loader = DataLoader::lm(
                    domain,
                    stream_rng.next_u64(),
                    b,
                    t,
                    sync_every * b * t + b * (t + 1),
                );
                let mut trainer =
                    Trainer::new(&session, cfg.clone(), loader, None)?;
                // The optimizer state is warm from previous rounds, so the
                // kernel's step counter must keep counting — restarting at
                // t=1 would re-apply the t=1 bias correction to a
                // converged second-moment EMA.
                trainer.set_step_offset(rounds_done * sync_every);
                trainer.set_host_blob(&start_blob)?;
                let report = trainer.train_with_schedule(schedule)?;
                let blob = trainer.host_blob()?;
                // Refill the recycled broadcast buffer instead of
                // materializing a fresh params copy every round.
                send_buf.clear();
                send_buf.extend_from_slice(&blob.data[..params_len]);
                let round = RankRound {
                    params: send_buf,
                    final_loss: report.final_loss,
                    state_sumsq: sum_sq(blob.state_region(&layout)),
                };
                resume = Some(blob);
                rounds_done += 1;
                tx_res.send(Ok(round))?;
            }
            Ok(())
        }));
    }

    // Leader: init once, then rounds of (broadcast, train, gather, average).
    let leader_session = Session::open(&artifacts_dir)?;
    let layout = leader_session.manifest.layout(&layout_key)?.clone();
    let mut leader_cfg = base_cfg.clone();
    leader_cfg.steps = 1;
    let preset = leader_session.manifest.preset(&base_cfg.preset)?;
    let (b, t) = (preset.batch_size, preset.seq_len);
    let seed_loader = DataLoader::lm(domain, base_cfg.seed, b, t, 2 * b * (t + 1));
    let mut init_trainer =
        Trainer::new(&leader_session, leader_cfg, seed_loader, None)?;
    init_trainer.init_from_seed()?;
    let mut global = init_trainer.host_blob()?;

    let plen = layout.params_len;
    let mut per_rank_final_loss = vec![0f32; n_ranks];
    let mut per_rank_state_sumsq = vec![0f32; n_ranks];
    let mut avg_params = vec![0f32; plen];
    // Gathered rank buffers double as the next round's broadcast
    // payloads: rank -> leader -> (refilled with the average) -> rank.
    // After the cold first round the ring is primed and sync rounds
    // stop allocating on the leader side too.
    let mut rank_params: Vec<Vec<f32>> = Vec::with_capacity(n_ranks);
    for round in 0..rounds {
        for tx in &to_ranks {
            // Round 1: full blob (ranks are cold). Later rounds: only the
            // averaged parameter region — the slim-broadcast protocol.
            let msg = if round == 0 {
                Broadcast::Init(global.clone())
            } else {
                let mut buf = rank_params.pop().unwrap_or_default();
                buf.clear();
                buf.extend_from_slice(&avg_params);
                Broadcast::Params(buf)
            };
            tx.send(Some(msg)).map_err(|e| anyhow!("send: {e}"))?;
        }
        rank_params.clear();
        for (rank, rx) in from_ranks.iter().enumerate() {
            let round_res =
                rx.recv().map_err(|e| anyhow!("recv: {e}"))??;
            per_rank_final_loss[rank] = round_res.final_loss;
            per_rank_state_sumsq[rank] = round_res.state_sumsq;
            rank_params.push(round_res.params);
        }
        // Average the parameter regions on the flat-engine pool in rank
        // order (the Vec order above); the leader's own state/metrics
        // stay untouched — ranks never read them back.
        pool::par_average(
            &mut avg_params,
            &rank_params,
            1.0 / n_ranks as f32,
            pool::default_shards(),
        );
        global.data[..plen].copy_from_slice(&avg_params);
    }
    for tx in &to_ranks {
        let _ = tx.send(None);
    }
    for h in handles {
        h.join().map_err(|_| anyhow!("worker panicked"))??;
    }

    // Evaluate the averaged model.
    let val_loader =
        DataLoader::lm(domain, base_cfg.seed + 999, b, t, 4 * b * (t + 1));
    let mut eval_cfg = base_cfg.clone();
    eval_cfg.steps = 0;
    let mut eval_trainer = Trainer::new(
        &leader_session,
        eval_cfg,
        DataLoader::lm(domain, base_cfg.seed, b, t, 2 * b * (t + 1)),
        Some(val_loader),
    )?;
    let accum = eval_trainer.evaluate_blob(&global)?;

    let wall = started.elapsed().as_secs_f64();
    let tokens = (n_ranks * rounds * sync_every * b * t) as f64;
    Ok(WorkerReport {
        n_ranks,
        rounds,
        per_rank_final_loss,
        per_rank_state_sumsq,
        averaged_eval_loss: accum.mean_loss(),
        wall_secs: wall,
        aggregate_tokens_per_sec: tokens / wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Layout, Segment};

    fn layout() -> Layout {
        let mk = |name: &str, kind: &str, size: usize, offset: usize| Segment {
            name: name.into(),
            kind: kind.into(),
            shape: vec![size],
            offset,
            size,
            dtype: crate::tensor::Dtype::F32,
        };
        Layout {
            blob_len: 20,
            params_len: 6,
            segments: vec![
                mk("w", "param", 6, 0),
                mk("w@v", "state", 6, 6),
                mk("metrics", "metric", 8, 12),
            ],
        }
    }

    #[test]
    fn splice_keeps_rank_local_state() {
        let l = layout();
        // A rank blob with non-zero optimizer state from earlier rounds.
        let prev = HostBlob::new(
            (0..20).map(|i| i as f32 + 1.0).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        // The broadcast average: fresh params, zeroed state (the leader
        // never trains, so its state region is all zeros).
        let mut bdata = vec![0f32; 20];
        for (i, v) in bdata.iter_mut().enumerate().take(6) {
            *v = 100.0 + i as f32;
        }
        let broadcast = HostBlob::new(bdata, "t/x", &l).unwrap();
        let spliced =
            splice_params(Some(prev.clone()), broadcast.clone(), l.params_len);
        // Params come from the broadcast...
        assert_eq!(spliced.params(&l), broadcast.params(&l));
        // ...but the optimizer state survives from the rank's own blob —
        // the module-doc promise ("optimizer state stays rank-local") that
        // the old implementation violated by adopting the zeroed blob.
        assert_eq!(spliced.state_region(&l), prev.state_region(&l));
        assert!(spliced.state_region(&l).iter().all(|&x| x != 0.0));
        // First round: no retained blob yet -> broadcast adopted wholesale.
        let first = splice_params(None, broadcast.clone(), l.params_len);
        assert_eq!(first.data, broadcast.data);
    }

    #[test]
    fn slim_broadcast_splices_params_only() {
        let l = layout();
        let prev = HostBlob::new(
            (0..20).map(|i| i as f32 + 1.0).collect(),
            "t/x",
            &l,
        )
        .unwrap();
        let avg: Vec<f32> = (0..6).map(|i| 200.0 + i as f32).collect();
        let next = apply_broadcast(
            Some(prev.clone()),
            Broadcast::Params(avg.clone()),
            l.params_len,
        )
        .unwrap();
        assert_eq!(next.params(&l), avg.as_slice());
        assert_eq!(next.state_region(&l), prev.state_region(&l));
        assert_eq!(next.metrics(&l), prev.metrics(&l));
        // Protocol violations fail loudly: params-only before init, and a
        // wrong-length params region.
        assert!(apply_broadcast(
            None,
            Broadcast::Params(avg.clone()),
            l.params_len
        )
        .is_err());
        assert!(apply_broadcast(
            Some(prev.clone()),
            Broadcast::Params(vec![0.0; 3]),
            l.params_len
        )
        .is_err());
        // Init behaves exactly like splice_params.
        let init = apply_broadcast(
            Some(prev.clone()),
            Broadcast::Init(prev.clone()),
            l.params_len,
        )
        .unwrap();
        assert_eq!(init.data, prev.data);
        let cold = apply_broadcast(
            None,
            Broadcast::Init(prev.clone()),
            l.params_len,
        )
        .unwrap();
        assert_eq!(cold.data, prev.data);
    }
}
