//! ZeRO-3 shard planner (Rajbhandari et al., 2020 — the paper's
//! distributed substrate).
//!
//! Partitions the blob's parameter + optimizer-state region across ranks.
//! Two granularities:
//! * `plan_contiguous` — equal byte ranges (what DeepSpeed's flat ZeRO-3
//!   partitioning does); used by the memory simulator per-GPU numbers.
//! * `plan_segments` — whole-tensor assignment balancing bytes (greedy
//!   LPT), used by the worker pool to decide ownership for averaging and
//!   by reports that show per-rank tensor lists.

use anyhow::Result;

use crate::runtime::{Layout, Segment};

#[derive(Debug, Clone)]
pub struct ContiguousShard {
    pub rank: usize,
    pub offset: usize,
    pub len: usize,
}

/// Equal contiguous ranges over [0, shardable_len). The metrics region is
/// never sharded (it is replicated coordinator state).
pub fn plan_contiguous(layout: &Layout, n_ranks: usize) -> Vec<ContiguousShard> {
    let shardable = layout.metrics_offset();
    let base = shardable / n_ranks;
    let rem = shardable % n_ranks;
    let mut shards = Vec::with_capacity(n_ranks);
    let mut off = 0;
    for rank in 0..n_ranks {
        let len = base + usize::from(rank < rem);
        shards.push(ContiguousShard { rank, offset: off, len });
        off += len;
    }
    shards
}

#[derive(Debug, Clone)]
pub struct SegmentShard {
    pub rank: usize,
    pub segments: Vec<Segment>,
    pub floats: usize,
}

/// Greedy longest-processing-time assignment of whole segments to ranks.
pub fn plan_segments(layout: &Layout, n_ranks: usize) -> Vec<SegmentShard> {
    let mut shards: Vec<SegmentShard> = (0..n_ranks)
        .map(|rank| SegmentShard { rank, segments: Vec::new(), floats: 0 })
        .collect();
    let mut segs: Vec<&Segment> = layout
        .segments
        .iter()
        .filter(|s| s.kind != "metric")
        .collect();
    segs.sort_by_key(|s| std::cmp::Reverse(s.size));
    for seg in segs {
        let lightest = shards
            .iter_mut()
            .min_by_key(|s| s.floats)
            .expect("n_ranks >= 1");
        lightest.floats += seg.size;
        lightest.segments.push(seg.clone());
    }
    shards
}

/// Validate that a contiguous plan exactly tiles the shardable region.
pub fn validate_contiguous(layout: &Layout, shards: &[ContiguousShard]) -> Result<()> {
    let mut expect = 0usize;
    for (i, s) in shards.iter().enumerate() {
        anyhow::ensure!(s.rank == i, "rank order");
        anyhow::ensure!(s.offset == expect, "gap/overlap at rank {i}");
        expect += s.len;
    }
    anyhow::ensure!(
        expect == layout.metrics_offset(),
        "plan covers {} of {}",
        expect,
        layout.metrics_offset()
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn layout() -> Layout {
        let mk = |name: &str, kind: &str, size: usize, offset: usize| Segment {
            name: name.into(),
            kind: kind.into(),
            shape: vec![size],
            offset,
            size,
            dtype: crate::tensor::Dtype::F32,
        };
        Layout {
            blob_len: 108,
            params_len: 70,
            segments: vec![
                mk("a", "param", 40, 0),
                mk("b", "param", 30, 40),
                mk("a@r", "state", 20, 70),
                mk("b@c", "state", 10, 90),
                mk("metrics", "metric", 8, 100),
            ],
        }
    }

    #[test]
    fn contiguous_tiles_exactly() {
        let l = layout();
        for n in [1, 2, 3, 7] {
            let plan = plan_contiguous(&l, n);
            validate_contiguous(&l, &plan).unwrap();
            let total: usize = plan.iter().map(|s| s.len).sum();
            assert_eq!(total, 100);
            // Balance: lengths differ by at most 1.
            let max = plan.iter().map(|s| s.len).max().unwrap();
            let min = plan.iter().map(|s| s.len).min().unwrap();
            assert!(max - min <= 1);
        }
    }

    #[test]
    fn segment_plan_covers_all_once() {
        let l = layout();
        let plan = plan_segments(&l, 2);
        let mut names: Vec<String> = plan
            .iter()
            .flat_map(|s| s.segments.iter().map(|g| g.name.clone()))
            .collect();
        names.sort();
        assert_eq!(names, vec!["a", "a@r", "b", "b@c"]);
        // LPT puts the 40 alone vs 30+20+10.
        let loads: Vec<usize> = plan.iter().map(|s| s.floats).collect();
        assert_eq!(loads.iter().sum::<usize>(), 100);
        assert!(loads.iter().all(|&f| f >= 40));
    }

    #[test]
    fn more_ranks_less_per_rank() {
        let l = layout();
        let p2 = plan_contiguous(&l, 2);
        let p5 = plan_contiguous(&l, 5);
        assert!(p5[0].len < p2[0].len);
    }
}
