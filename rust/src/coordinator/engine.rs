//! Unified execution engine: one `ExecPlan`-driven leader loop for every
//! training path.
//!
//! Before this module the repo realized the paper's step schedule —
//! grouped gradient production, bucketed exchange, fused in-place update
//! (AdaLomo §3) — four separate times: the lockstep reference
//! ([`super::pipeline::run_sequential`]), the full-image async pipeline
//! ([`super::pipeline::run_pipelined`]), the group-granular pipeline
//! ([`super::pipeline::run_pipelined_fused`]) and the fused-backward host
//! mirror ([`super::fused_host::run_fused_host`]), each with its own
//! hand-rolled leader loop, report struct and invariants. All four are
//! now thin constructors over one [`ExecPlan`]:
//!
//! * **grad production** — [`GradProduction`]: every rank materializes
//!   the full gradient image per step, or produces it group by group in
//!   fused-backward order (never holding the whole image);
//! * **exchange order** — [`ExchangeOrder`]: buckets land in ascending
//!   offset order (natural for a materialized image) or descending
//!   (the order backward production covers the image);
//! * **step granularity** — [`StepGranularity`]: one whole-image
//!   [`FlatOptimizer::step`] per training step, per-bucket
//!   [`FlatOptimizer::step_tasks`] the moment a task's last (or, in the
//!   descending walk, first) element lands, or per-group
//!   [`FlatOptimizer::step_group`] walks;
//! * plus ranks × fabric model ([`Fabric`]), the storage dtype and the
//!   exchange wire rung ([`WireCodec`] — see `docs/EXCHANGE.md`), and
//!   the shared optimizer hyper-surface (`lr`/`wd`/shards).
//!
//! One generic leader loop executes any plan over any
//! [`GradSource`]/[`GroupGradSource`] set, so bitwise parity between the
//! paths is structural (same gradient values, same rank-order reduction,
//! same self-contained per-task arithmetic) rather than re-proven per
//! path — the `prop_engine_matches_legacy_bitwise` proptest pins it.
//!
//! # Checkpoint / suspend / resume
//!
//! [`Engine`] owns the blob and the completed-step counter, so any plan
//! can stop mid-run and continue bitwise-identically: [`Engine::suspend_at`]
//! halts the loop after step *k*, [`Engine::save`] serializes Layout +
//! blob + step counter + plan position through
//! [`crate::runtime::checkpoint`], and [`Engine::resume`] rebuilds the
//! engine from the file alone (no manifest needed). Sources are re-wound
//! by the producer threads via [`GradSource::skip`] /
//! [`GroupGradSource::skip_step`], so a resumed run consumes exactly the
//! gradient stream the uninterrupted run would have.

use std::collections::VecDeque;
use std::path::Path;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, bail, ensure, Result};

use crate::optim::flat::{FlatOptimizer, ShardMode};
use crate::optim::{pool, OptKind};
use crate::runtime::checkpoint::{self, PlanRecord};
use crate::runtime::{Layout, TypedBlob};
use crate::tensor::Dtype;

use super::collective::{
    allreduce_bucket_time, hier_allreduce_bucket_time, Fabric, HierFabric,
    WireCodec,
};
use super::fused_host::GroupGradSource;
use super::pipeline::{BucketPlan, GradSource, PipelineConfig};

/// How each rank produces its per-step gradient.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GradProduction {
    /// The rank materializes the full gradient image every step
    /// ([`GradSource`]).
    FullImage,
    /// The rank produces one fused-backward group at a time
    /// ([`GroupGradSource`]) and ships exchange buckets as production
    /// covers them — the paper's §2.1 liveness story on the host path.
    GroupedBackward,
}

/// The offset order in which exchange buckets move over the fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ExchangeOrder {
    Ascending,
    Descending,
}

/// What the leader steps as reductions land.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StepGranularity {
    /// One whole-image [`FlatOptimizer::step`] after the full reduction —
    /// the lockstep reference.
    WholeImage,
    /// Per-bucket [`FlatOptimizer::step_tasks`]: a task steps the moment
    /// the bucket completing it lands, while later buckets ride the
    /// fabric.
    Tasks,
    /// Per-group [`FlatOptimizer::step_group`]: the fused-host mirror's
    /// walk, one group extent reduced and stepped at a time.
    Groups,
}

/// A complete execution schedule: which of the (production × order ×
/// granularity) cell to run, over how many ranks/steps, on which
/// optimizer/shard plan, against which fabric model, at which storage
/// dtype and exchange wire rung.
///
/// ```
/// use adalomo::coordinator::engine::ExecPlan;
/// use adalomo::coordinator::pipeline::PipelineConfig;
/// use adalomo::optim::flat::ShardMode;
/// use adalomo::optim::OptKind;
///
/// let cfg = PipelineConfig::new(3, 64);
/// let plan = ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Segments, 2, &cfg);
/// assert!(plan.validate().is_ok());
/// assert!(plan.describe().contains("f32 storage, f32 wire"));
/// ```
#[derive(Debug, Clone)]
pub struct ExecPlan {
    pub production: GradProduction,
    pub order: ExchangeOrder,
    pub granularity: StepGranularity,
    pub kind: OptKind,
    pub mode: ShardMode,
    pub n_ranks: usize,
    pub steps: usize,
    /// Exchange bucket size in f32 elements ([`StepGranularity::Tasks`];
    /// the other granularities derive their tiling from the image or the
    /// fused groups).
    pub bucket_elems: usize,
    pub lr: f32,
    pub wd: f32,
    pub n_shards: usize,
    pub fabric: Fabric,
    /// Storage dtype of the params+state blob AND the modeled exchange
    /// payloads: [`Dtype::Bf16`] halves blob bytes, checkpoint bytes and
    /// the fabric's per-tile wire bytes. Compute stays f32 (the optimizer
    /// widens per task through bounded scratch), and every `ExecPlan`
    /// cell remains bitwise-identical at a FIXED dtype.
    pub dtype: Dtype,
    /// Wire rung of the gradient exchange ([`WireCodec`]): what bucket
    /// payloads are round-tripped through before the leader's f32
    /// reduction tree, independent of the storage dtype axis. The f32
    /// rung is the identity (bitwise-identical to the pre-ladder
    /// exchange); [`WireCodec::Q8Block`] adds per-rank error-feedback
    /// state that checkpoints alongside the blob (ADCP v3).
    pub wire: WireCodec,
    /// Seed for deterministic host-mirror gradient sources. The engine
    /// itself never reads it — it rides along (and through checkpoints)
    /// so a resumed CLI run can reconstruct identical rank streams.
    pub seed: u64,
    /// Membership schedule for elastic runs: `(s, r)` means "after
    /// completed step `s`, the run continues with `r` ranks" (steps
    /// `s+1..` form a new membership epoch). [`ExecPlan::n_ranks`] stays
    /// the epoch-0 count; empty means fixed membership for the whole run.
    /// Serialized as the ADCP v4 epoch section and driven by
    /// [`Engine::run_elastic`] (see `docs/FAULTS.md`).
    pub ranks_schedule: Vec<(u64, u32)>,
    /// Optional hierarchical fabric overlay ([`HierFabric`]): when set,
    /// exchange tiles are costed through
    /// [`hier_allreduce_bucket_time`] (intra-node reduce-scatter /
    /// broadcast around an inter-node ring) instead of the flat
    /// [`Fabric`] ring. Cost-model only — gradient values are
    /// unaffected — and deliberately NOT checkpointed: [`Self::fabric`]
    /// remains the serialized pair, and a resume re-applies the overlay
    /// from the CLI (`--fabric hier:...`).
    pub topology: Option<HierFabric>,
}

impl ExecPlan {
    fn from_cfg(
        production: GradProduction,
        order: ExchangeOrder,
        granularity: StepGranularity,
        kind: OptKind,
        mode: ShardMode,
        n_ranks: usize,
        cfg: &PipelineConfig,
    ) -> ExecPlan {
        ExecPlan {
            production,
            order,
            granularity,
            kind,
            mode,
            n_ranks,
            steps: cfg.steps,
            bucket_elems: cfg.bucket_elems,
            lr: cfg.lr,
            wd: cfg.wd,
            n_shards: cfg.n_shards,
            fabric: cfg.fabric,
            dtype: cfg.dtype,
            wire: cfg.wire_codec(),
            seed: 0,
            ranks_schedule: Vec::new(),
            topology: cfg.topology,
        }
    }

    /// The lockstep reference: full-image production, one monolithic
    /// exchange, one whole-image step.
    pub fn sequential(
        kind: OptKind,
        mode: ShardMode,
        n_ranks: usize,
        cfg: &PipelineConfig,
    ) -> ExecPlan {
        Self::from_cfg(
            GradProduction::FullImage,
            ExchangeOrder::Ascending,
            StepGranularity::WholeImage,
            kind,
            mode,
            n_ranks,
            cfg,
        )
    }

    /// The full-image async pipeline: ascending buckets overlapped with
    /// per-task stepping.
    pub fn pipelined(
        kind: OptKind,
        mode: ShardMode,
        n_ranks: usize,
        cfg: &PipelineConfig,
    ) -> ExecPlan {
        Self::from_cfg(
            GradProduction::FullImage,
            ExchangeOrder::Ascending,
            StepGranularity::Tasks,
            kind,
            mode,
            n_ranks,
            cfg,
        )
    }

    /// The group-granular pipeline: descending buckets shipped against
    /// group-by-group production, per-task stepping.
    pub fn pipelined_fused(
        kind: OptKind,
        mode: ShardMode,
        n_ranks: usize,
        cfg: &PipelineConfig,
    ) -> ExecPlan {
        Self::from_cfg(
            GradProduction::GroupedBackward,
            ExchangeOrder::Descending,
            StepGranularity::Tasks,
            kind,
            mode,
            n_ranks,
            cfg,
        )
    }

    /// The fused-backward host mirror: group-by-group production, one
    /// group extent reduced and stepped at a time.
    pub fn fused_host(
        kind: OptKind,
        mode: ShardMode,
        n_ranks: usize,
        cfg: &PipelineConfig,
    ) -> ExecPlan {
        Self::from_cfg(
            GradProduction::GroupedBackward,
            ExchangeOrder::Descending,
            StepGranularity::Groups,
            kind,
            mode,
            n_ranks,
            cfg,
        )
    }

    /// Reject plans the producers cannot execute.
    pub fn validate(&self) -> Result<()> {
        ensure!(self.n_ranks >= 1, "plan needs at least one rank");
        ensure!(self.n_shards >= 1, "plan needs at least one shard");
        if self.granularity == StepGranularity::Tasks {
            ensure!(
                self.bucket_elems >= 1,
                "tasks granularity needs a positive bucket size"
            );
        }
        if self.production == GradProduction::GroupedBackward {
            ensure!(
                self.order == ExchangeOrder::Descending,
                "grouped-backward production covers the image top-down, so \
                 buckets can only ship in descending offset order"
            );
        }
        // Membership schedule: same invariants `checkpoint::from_bytes`
        // enforces on the ADCP v4 epoch section.
        let mut prev = 0u64;
        for &(s, r) in &self.ranks_schedule {
            ensure!(
                r >= 1,
                "membership epoch at step {s} needs at least one rank"
            );
            ensure!(
                s >= 1 && s < self.steps as u64,
                "membership boundary {s} must lie strictly inside the run \
                 (1..{})",
                self.steps
            );
            ensure!(
                s > prev,
                "membership boundaries must be strictly increasing \
                 ({s} after {prev})"
            );
            prev = s;
        }
        Ok(())
    }

    /// Effective rank count executing optimizer step `t` (1-based) under
    /// the membership schedule: the last epoch whose boundary lies
    /// strictly before `t`, falling back to the epoch-0
    /// [`ExecPlan::n_ranks`].
    pub fn ranks_for_step(&self, t: u64) -> u32 {
        let mut ranks = self.n_ranks as u32;
        for &(s, r) in &self.ranks_schedule {
            if s < t {
                ranks = r;
            }
        }
        ranks
    }

    /// One-line human description (the `checkpoint-inspect` output).
    pub fn describe(&self) -> String {
        let prod = match self.production {
            GradProduction::FullImage => "full-image",
            GradProduction::GroupedBackward => "grouped-backward",
        };
        let ord = match self.order {
            ExchangeOrder::Ascending => "ascending",
            ExchangeOrder::Descending => "descending",
        };
        let gran = match self.granularity {
            StepGranularity::WholeImage => "whole-image",
            StepGranularity::Tasks => "step_tasks",
            StepGranularity::Groups => "step_group",
        };
        let mut out = format!(
            "{prod} production, {ord} exchange, {gran} steps; {} x {} \
             ({:?}, {} shards), {} steps, bucket {} elems, {} storage, \
             {} wire",
            self.n_ranks,
            self.kind.name(),
            self.mode,
            self.n_shards,
            self.steps,
            self.bucket_elems,
            self.dtype.name(),
            self.wire.name()
        );
        if !self.ranks_schedule.is_empty() {
            out.push_str(&format!(
                ", {} membership epochs",
                self.ranks_schedule.len() + 1
            ));
        }
        out
    }

    /// Serialize to the runtime-layer [`PlanRecord`] (cursors zero: the
    /// engine only checkpoints at step boundaries).
    pub fn to_record(&self) -> PlanRecord {
        PlanRecord {
            production: match self.production {
                GradProduction::FullImage => checkpoint::PROD_FULL_IMAGE,
                GradProduction::GroupedBackward => checkpoint::PROD_GROUPED,
            },
            order: match self.order {
                ExchangeOrder::Ascending => checkpoint::ORD_ASCENDING,
                ExchangeOrder::Descending => checkpoint::ORD_DESCENDING,
            },
            granularity: match self.granularity {
                StepGranularity::WholeImage => checkpoint::GRAN_WHOLE_IMAGE,
                StepGranularity::Tasks => checkpoint::GRAN_TASKS,
                StepGranularity::Groups => checkpoint::GRAN_GROUPS,
            },
            mode: match self.mode {
                ShardMode::Segments => checkpoint::MODE_SEGMENTS,
                ShardMode::Contiguous => checkpoint::MODE_CONTIGUOUS,
            },
            dtype: checkpoint::dtype_code(self.dtype),
            wire: match self.wire {
                WireCodec::F32 => checkpoint::WIRE_F32,
                WireCodec::Bf16 => checkpoint::WIRE_BF16,
                WireCodec::Q8Block => checkpoint::WIRE_Q8,
            },
            opt: self.kind.name().to_string(),
            steps: self.steps as u64,
            bucket_elems: self.bucket_elems as u64,
            n_ranks: self.n_ranks as u32,
            n_shards: self.n_shards as u32,
            lr: self.lr,
            wd: self.wd,
            fabric_alpha: self.fabric.alpha,
            fabric_bw: self.fabric.bw,
            seed: self.seed,
            cursor_group: 0,
            cursor_task: 0,
            epochs: self.ranks_schedule.clone(),
        }
    }

    /// Deserialize from a [`PlanRecord`], rejecting unknown codes.
    pub fn from_record(r: &PlanRecord) -> Result<ExecPlan> {
        let production = match r.production {
            checkpoint::PROD_FULL_IMAGE => GradProduction::FullImage,
            checkpoint::PROD_GROUPED => GradProduction::GroupedBackward,
            other => bail!("unknown production code {other}"),
        };
        let order = match r.order {
            checkpoint::ORD_ASCENDING => ExchangeOrder::Ascending,
            checkpoint::ORD_DESCENDING => ExchangeOrder::Descending,
            other => bail!("unknown exchange-order code {other}"),
        };
        let granularity = match r.granularity {
            checkpoint::GRAN_WHOLE_IMAGE => StepGranularity::WholeImage,
            checkpoint::GRAN_TASKS => StepGranularity::Tasks,
            checkpoint::GRAN_GROUPS => StepGranularity::Groups,
            other => bail!("unknown granularity code {other}"),
        };
        let mode = match r.mode {
            checkpoint::MODE_SEGMENTS => ShardMode::Segments,
            checkpoint::MODE_CONTIGUOUS => ShardMode::Contiguous,
            other => bail!("unknown shard-mode code {other}"),
        };
        let plan = ExecPlan {
            production,
            order,
            granularity,
            kind: OptKind::parse(&r.opt)?,
            mode,
            n_ranks: r.n_ranks as usize,
            steps: r.steps as usize,
            bucket_elems: r.bucket_elems as usize,
            lr: r.lr,
            wd: r.wd,
            n_shards: r.n_shards as usize,
            fabric: Fabric { alpha: r.fabric_alpha, bw: r.fabric_bw },
            dtype: checkpoint::dtype_from_code(r.dtype)?,
            wire: match r.wire {
                checkpoint::WIRE_F32 => WireCodec::F32,
                checkpoint::WIRE_BF16 => WireCodec::Bf16,
                checkpoint::WIRE_Q8 => WireCodec::Q8Block,
                other => bail!("unknown wire-codec code {other}"),
            },
            seed: r.seed,
            ranks_schedule: r.epochs.clone(),
            // The hierarchical overlay is a per-process cost model, not
            // plan state: a resume re-applies it from the CLI.
            topology: None,
        };
        plan.validate()?;
        Ok(plan)
    }
}

/// Per-rank gradient sources for one run: the variant must match the
/// plan's [`GradProduction`] axis.
pub enum RankSources {
    Full(Vec<Box<dyn GradSource>>),
    Grouped(Vec<Box<dyn GroupGradSource>>),
}

impl RankSources {
    pub fn len(&self) -> usize {
        match self {
            RankSources::Full(v) => v.len(),
            RankSources::Grouped(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// What one [`Engine::run`] measured/modeled — the union of the old
/// `PipelineReport` and `FusedHostReport` surfaces, so every path (and
/// every bench/example/CI metric) reads the same struct.
#[derive(Debug, Clone)]
pub struct EngineReport {
    pub n_ranks: usize,
    /// Optimizer steps this run executed (after a resume, only the
    /// remaining steps).
    pub steps: usize,
    /// Exchange tiles per step (fixed-size buckets, or one per fused
    /// group under [`StepGranularity::Groups`]).
    pub n_buckets: usize,
    /// Fused-backward groups, when production or stepping is
    /// group-granular; 0 for purely full-image plans.
    pub n_groups: usize,
    /// Measured wall time inside the optimizer step calls.
    pub compute_secs: f64,
    /// Simulated fabric cost of the bucketed ring all-reduces.
    pub comm_secs: f64,
    /// Modeled critical path: comm serialized on the fabric, each tile's
    /// optimizer work starting once its reduction lands and the previous
    /// tile's work has finished.
    pub exposed_secs: f64,
    /// `(compute + comm) / exposed` — 1.0 means nothing overlapped;
    /// higher is better (2.0 would mean perfect hiding of the smaller
    /// side).
    pub overlap_efficiency: f64,
    pub wall_secs: f64,
    /// Peak gradient bytes live on a producing rank: the full image for
    /// full-image production; MEASURED produced-but-unshipped group-buffer
    /// bytes for grouped production (never above the image — in-flight
    /// exchange payloads are the fabric's, not the producer's).
    pub peak_live_grad_bytes: usize,
    /// The full-gradient-image baseline in bytes (`params_len` × 4).
    pub full_grad_bytes: usize,
    /// Per-group live-gradient bytes in walk order under
    /// [`StepGranularity::Groups`] (the measured liveness curve
    /// `memsim::liveness::simulate_grouped` predicts); empty otherwise.
    pub curve_bytes: Vec<usize>,
    /// Storage dtype of the blob.
    pub dtype: Dtype,
    /// Wire rung the exchange payloads were round-tripped through
    /// (independent of [`Self::dtype`] since the compression ladder).
    pub wire: WireCodec,
    /// Actual storage bytes of the params+state+metrics blob at
    /// [`Self::dtype`] — bf16 halves the params+state share (the
    /// `blob_bytes_*` bench metrics).
    pub blob_bytes: usize,
    /// Modeled wire bytes one training step ships over the fabric: the
    /// sum of [`WireCodec::payload_bytes`] over all exchange tiles
    /// (q8 includes the per-block scale words; 0 for a single rank,
    /// which exchanges nothing — matching the fabric time model).
    pub comm_bytes_per_step: usize,
    /// Largest single exchange tile on the wire, in
    /// [`WireCodec::payload_bytes`] (the `peak_comm_bytes_*` bench
    /// metrics; 0 for one rank).
    pub peak_comm_bytes: usize,
    /// Exchange tiles the [`StragglerPolicy`] moved off late ranks,
    /// summed over every step this run executed (0 without a policy).
    /// Modeled-timeline accounting only — gradient values never move.
    pub reassigned_tiles: usize,
}

impl EngineReport {
    /// Peak live gradient as a fraction of the full-image baseline.
    pub fn live_fraction(&self) -> f64 {
        self.peak_live_grad_bytes as f64 / self.full_grad_bytes.max(1) as f64
    }

    /// Fold a later epoch segment's report into this one: step counts,
    /// modeled times and reassignment counts add; peaks take the max;
    /// per-step shape fields (tiles, bytes, rank count) follow the later
    /// segment, which is the membership the run ended on.
    fn absorb(self, later: EngineReport) -> EngineReport {
        let compute = self.compute_secs + later.compute_secs;
        let comm = self.comm_secs + later.comm_secs;
        let exposed = self.exposed_secs + later.exposed_secs;
        EngineReport {
            n_ranks: later.n_ranks,
            steps: self.steps + later.steps,
            n_buckets: later.n_buckets,
            n_groups: later.n_groups,
            compute_secs: compute,
            comm_secs: comm,
            exposed_secs: exposed,
            overlap_efficiency: if exposed > 0.0 {
                (compute + comm) / exposed
            } else {
                1.0
            },
            wall_secs: self.wall_secs + later.wall_secs,
            peak_live_grad_bytes: self
                .peak_live_grad_bytes
                .max(later.peak_live_grad_bytes),
            full_grad_bytes: later.full_grad_bytes,
            curve_bytes: later.curve_bytes,
            dtype: later.dtype,
            wire: later.wire,
            blob_bytes: later.blob_bytes,
            comm_bytes_per_step: later.comm_bytes_per_step,
            peak_comm_bytes: self.peak_comm_bytes.max(later.peak_comm_bytes),
            reassigned_tiles: self.reassigned_tiles + later.reassigned_tiles,
        }
    }
}

/// Deterministic straggler handling for the modeled exchange timeline.
///
/// `slowdown[r]` is rank `r`'s modeled fabric-cost multiplier (`1.0` =
/// on time; ranks beyond the vector, and non-finite or sub-1.0 entries,
/// are treated as nominal). A rank is LATE when its slowdown exceeds
/// `threshold ×` the fleet minimum. Exchange tiles are owned round-robin
/// (tile `b` → rank `b % n_ranks`); every tile a late rank owns is
/// reassigned round-robin across the on-time ranks in ascending rank
/// order, and each tile's modeled comm time is scaled by its final
/// owner's slowdown. Entirely a cost-model overlay: gradient values, the
/// rank-order reduction and the blob are untouched, so the policy can
/// never perturb bitwise parity — and like the fabric constants it is
/// NOT checkpointed (see `docs/FAULTS.md`).
#[derive(Debug, Clone)]
pub struct StragglerPolicy {
    /// Per-rank modeled slowdown factors (1.0 = nominal).
    pub slowdown: Vec<f64>,
    /// Late when `slowdown[r] > threshold * min(slowdown)`; values
    /// `>= 1.0` make sense (1.5 = "50% slower than the fastest rank").
    pub threshold: f64,
}

impl StragglerPolicy {
    /// Scale each tile's modeled comm time by its (possibly reassigned)
    /// owner's slowdown. Returns the adjusted times plus how many tiles
    /// moved off late ranks.
    fn apply(
        &self,
        mut tile_comm: Vec<f64>,
        n_ranks: usize,
    ) -> (Vec<f64>, usize) {
        if n_ranks <= 1 || self.slowdown.is_empty() {
            return (tile_comm, 0);
        }
        let slow = |r: usize| -> f64 {
            let s = self.slowdown.get(r).copied().unwrap_or(1.0);
            if s.is_finite() && s >= 1.0 {
                s
            } else {
                1.0
            }
        };
        let mut fleet_min = f64::INFINITY;
        for r in 0..n_ranks {
            fleet_min = fleet_min.min(slow(r));
        }
        let on_time: Vec<usize> = (0..n_ranks)
            .filter(|&r| slow(r) <= self.threshold * fleet_min)
            .collect();
        let mut reassigned = 0usize;
        for (b, t) in tile_comm.iter_mut().enumerate() {
            let mut owner = b % n_ranks;
            if !on_time.is_empty() && !on_time.contains(&owner) {
                owner = on_time[b % on_time.len()];
                reassigned += 1;
            }
            *t *= slow(owner);
        }
        (tile_comm, reassigned)
    }
}

/// The unified engine: a [`FlatOptimizer`] plus the blob, the
/// completed-step counter and the [`ExecPlan`] being executed. Construct
/// with [`Engine::new`] (or [`Engine::resume`]), drive with
/// [`Engine::run`], snapshot with [`Engine::save`].
pub struct Engine {
    layout: Layout,
    layout_key: String,
    plan: ExecPlan,
    opt: FlatOptimizer,
    /// The training blob in its STORAGE dtype (the plan's dtype axis).
    blob: TypedBlob,
    /// Per-rank error-feedback accumulators for lossy-with-residual wire
    /// rungs ([`WireCodec::uses_error_feedback`]): `ef[r]` holds rank
    /// `r`'s unsent quantization residual per parameter, re-injected into
    /// that rank's next payload for the same region. Empty for f32/bf16
    /// wires. Checkpointed (ADCP v3) so a resume replays the exact
    /// residual stream.
    ef: Vec<Vec<f32>>,
    /// Optional straggler overlay for the modeled timeline
    /// ([`Engine::set_straggler`]); never serialized.
    straggler: Option<StragglerPolicy>,
    done_steps: u64,
    suspend_at: Option<u64>,
    /// Set when a run aborted mid-step: the blob may hold a partially
    /// applied step, so checkpointing it would corrupt a resume.
    /// [`Engine::save`] refuses while this is set.
    poisoned: bool,
}

impl Engine {
    /// Build an engine from an f32 image: the layout is retagged to the
    /// plan's storage dtype and the image rounded into it (the one lossy
    /// moment of a bf16 run — identical for every plan cell, which is
    /// what keeps fixed-dtype parity bitwise).
    pub fn new(layout: &Layout, blob0: &[f32], plan: ExecPlan) -> Result<Engine> {
        plan.validate()?;
        ensure!(
            blob0.len() == layout.blob_len,
            "blob len {} != layout {}",
            blob0.len(),
            layout.blob_len
        );
        let layout = layout.with_storage_dtype(plan.dtype);
        let opt =
            FlatOptimizer::new(plan.kind, &layout, plan.n_shards, plan.mode)?;
        let blob = TypedBlob::from_f32(&layout, blob0, plan.dtype)?;
        let ef = if plan.wire.uses_error_feedback() {
            vec![vec![0.0f32; layout.params_len]; plan.n_ranks]
        } else {
            Vec::new()
        };
        Ok(Engine {
            layout,
            layout_key: format!("engine/{}", plan.kind.name()),
            plan,
            opt,
            blob,
            ef,
            straggler: None,
            done_steps: 0,
            suspend_at: None,
            poisoned: false,
        })
    }

    pub fn plan(&self) -> &ExecPlan {
        &self.plan
    }

    pub fn layout(&self) -> &Layout {
        &self.layout
    }

    /// Layout key recorded into checkpoints (`preset/opt` spelling for
    /// manifest-backed runs; defaults to `engine/<opt>`).
    pub fn set_layout_key(&mut self, key: &str) {
        self.layout_key = key.to_string();
    }

    /// Widen-on-read snapshot of the full blob at compute precision
    /// (exact: bf16 ⊂ f32). For storage-level access — dtype, actual
    /// bytes, raw bits — use [`Engine::typed_blob`].
    pub fn blob(&self) -> Vec<f32> {
        self.blob.to_f32()
    }

    /// The blob in its storage dtype.
    pub fn typed_blob(&self) -> &TypedBlob {
        &self.blob
    }

    pub fn into_blob(self) -> Vec<f32> {
        self.blob.into_f32()
    }

    /// Completed optimizer steps.
    pub fn step(&self) -> u64 {
        self.done_steps
    }

    pub fn is_finished(&self) -> bool {
        self.done_steps >= self.plan.steps as u64
    }

    /// Fused-backward group extents of the underlying flat optimizer —
    /// what host-mirror sources are constructed over.
    pub fn group_extents(&self) -> Vec<(usize, usize)> {
        self.opt.group_extents()
    }

    /// Halt [`Engine::run`] once `step` optimizer steps have completed
    /// (a no-op if the plan stops earlier anyway). The engine can then be
    /// [`Engine::save`]d and later [`Engine::resume`]d bitwise-exactly.
    pub fn suspend_at(&mut self, step: u64) {
        self.suspend_at = Some(step);
    }

    /// Serialize Layout + blob + step counter + plan position. The blob
    /// is streamed from the engine's own buffer
    /// ([`checkpoint::write`]) — no clone of the largest object on the
    /// checkpoint path. Refuses while the engine is poisoned (a run
    /// aborted mid-step), because the blob may hold a partially applied
    /// step and a resume from it would silently diverge.
    pub fn save(&self, path: &Path) -> Result<()> {
        ensure!(
            !self.poisoned,
            "engine aborted mid-step; its blob may hold a partially \
             applied step and cannot be checkpointed"
        );
        checkpoint::write(
            path,
            &self.layout_key,
            &self.layout,
            self.done_steps,
            &self.plan.to_record(),
            &self.ef,
            &self.blob,
        )
    }

    /// Rebuild an engine from a checkpoint file alone. The resumed engine
    /// continues from the recorded step counter; feed it sources seeded
    /// like the original run's (the producer threads fast-forward them
    /// past the already-completed steps).
    pub fn resume(path: &Path) -> Result<Engine> {
        let ck = checkpoint::load(path)?;
        let plan = ExecPlan::from_record(&ck.plan)?;
        ensure!(
            ck.step <= plan.steps as u64,
            "checkpoint is {} steps in, but the plan only runs {}",
            ck.step,
            plan.steps
        );
        ensure!(
            ck.layout.storage_dtype()? == plan.dtype,
            "checkpoint layout stores {} but the plan says {}",
            ck.layout.storage_dtype()?.name(),
            plan.dtype.name()
        );
        let opt =
            FlatOptimizer::new(plan.kind, &ck.layout, plan.n_shards, plan.mode)?;
        // Step-boundary checkpoints have zero cursors; validate the
        // recorded (group, task) cursor pair against the rebuilt
        // optimizer's walk anyway, so a future mid-step writer cannot
        // hand us an inconsistent position silently.
        ensure!(
            opt.group_cursor_task(ck.plan.cursor_group as usize)
                == ck.plan.cursor_task as usize,
            "checkpoint cursor (group {}, task {}) does not lie on the \
             rebuilt optimizer's fused walk",
            ck.plan.cursor_group,
            ck.plan.cursor_task
        );
        // Error feedback is sized to the membership epoch the run resumes
        // INTO (`ranks_for_step(step + 1)`), not the epoch-0 rank count:
        // `run_elastic` flushes + resizes the residuals at every epoch
        // boundary, so a boundary checkpoint already carries the next
        // epoch's shape.
        let eff_ranks =
            plan.ranks_for_step(ck.step.saturating_add(1)) as usize;
        let ef = if plan.wire.uses_error_feedback() {
            if ck.ef.is_empty() {
                // A q8 plan saved before ADCP v3 could exist only by
                // hand-construction; start its residuals from zero.
                vec![vec![0.0f32; ck.layout.params_len]; eff_ranks]
            } else {
                ensure!(
                    ck.ef.len() == eff_ranks,
                    "checkpoint carries error-feedback for {} ranks, but \
                     the membership epoch resuming at step {} runs {}",
                    ck.ef.len(),
                    ck.step.saturating_add(1),
                    eff_ranks
                );
                for (r, e) in ck.ef.iter().enumerate() {
                    ensure!(
                        e.len() == ck.layout.params_len,
                        "rank {r} error-feedback length {} != params {}",
                        e.len(),
                        ck.layout.params_len
                    );
                }
                ck.ef
            }
        } else {
            ensure!(
                ck.ef.is_empty(),
                "checkpoint carries error-feedback state, but the plan's \
                 {} wire rung keeps none",
                plan.wire.name()
            );
            Vec::new()
        };
        Ok(Engine {
            layout_key: ck.layout_key,
            layout: ck.layout,
            plan,
            opt,
            blob: ck.blob,
            ef,
            straggler: None,
            done_steps: ck.step,
            suspend_at: None,
            poisoned: false,
        })
    }

    /// Install (or clear) the deterministic [`StragglerPolicy`] overlay
    /// for subsequent runs. Cost-model only; never serialized.
    pub fn set_straggler(&mut self, policy: Option<StragglerPolicy>) {
        self.straggler = policy;
    }

    /// Re-apply a hierarchical fabric overlay (e.g. after
    /// [`Engine::resume`], which deliberately drops it — topology is a
    /// per-process cost model, not checkpoint state).
    pub fn set_topology(&mut self, topology: Option<HierFabric>) {
        self.plan.topology = topology;
    }

    /// Execute a fixed-membership plan from the current step counter up
    /// to the plan's step budget (or the [`Engine::suspend_at`] point,
    /// whichever comes first), updating the blob in place. Returns the
    /// report for the steps this call executed. Plans carrying a
    /// membership schedule must go through [`Engine::run_elastic`], which
    /// knows where the epoch boundaries are.
    pub fn run(&mut self, sources: RankSources) -> Result<EngineReport> {
        ensure!(
            self.plan.ranks_schedule.is_empty(),
            "plan carries a membership schedule ({} epochs); drive it \
             with Engine::run_elastic",
            self.plan.ranks_schedule.len() + 1
        );
        let plan = self.plan.clone();
        let stop = (plan.steps as u64)
            .min(self.suspend_at.unwrap_or(u64::MAX))
            .max(self.done_steps);
        self.run_span(&plan, sources, stop)
    }

    /// Execute an elastic plan across its membership epochs: each epoch
    /// segment runs with that epoch's rank count under the otherwise
    /// unchanged plan, and `sources_for` is called once per segment with
    /// the segment's effective plan (its `n_ranks` is the epoch count,
    /// its `ranks_schedule` empty) to build matching rank streams — the
    /// producers fast-forward past completed steps, so every segment
    /// consumes exactly the gradient stream a fixed-membership run over
    /// the same span would.
    ///
    /// At every epoch boundary the per-rank error-feedback residuals are
    /// flushed to zero and resized to the incoming membership (the
    /// deterministic splice rule — `docs/FAULTS.md`). A checkpoint saved
    /// exactly at a boundary therefore carries EF sized to the epoch it
    /// resumes INTO, which is what [`Engine::resume`] (and the ADCP v4
    /// reader) validate.
    pub fn run_elastic(
        &mut self,
        mut sources_for: impl FnMut(&ExecPlan) -> RankSources,
    ) -> Result<EngineReport> {
        let stop = (self.plan.steps as u64)
            .min(self.suspend_at.unwrap_or(u64::MAX))
            .max(self.done_steps);
        let schedule = self.plan.ranks_schedule.clone();
        let mut merged: Option<EngineReport> = None;
        loop {
            // Segment end: the first boundary past the cursor, capped by
            // the overall stop.
            let seg_stop = schedule
                .iter()
                .map(|&(s, _)| s)
                .find(|&s| s > self.done_steps)
                .map_or(stop, |s| s.min(stop));
            let mut seg_plan = self.plan.clone();
            seg_plan.n_ranks =
                self.plan.ranks_for_step(self.done_steps + 1) as usize;
            seg_plan.ranks_schedule = Vec::new();
            let sources = sources_for(&seg_plan);
            let report = self.run_span(&seg_plan, sources, seg_stop)?;
            merged = Some(match merged {
                None => report,
                Some(acc) => acc.absorb(report),
            });
            if self.plan.wire.uses_error_feedback()
                && schedule.iter().any(|&(s, _)| s == self.done_steps)
            {
                let next =
                    self.plan.ranks_for_step(self.done_steps + 1) as usize;
                self.ef =
                    vec![vec![0.0f32; self.layout.params_len]; next];
            }
            if self.done_steps >= stop {
                break;
            }
        }
        merged.ok_or_else(|| anyhow!("run_elastic executed no segment"))
    }

    /// One fixed-membership span: the single leader-loop body every path
    /// (and every epoch segment) runs through. `plan` carries the
    /// effective rank count for this span; `stop` is the absolute step
    /// to halt after.
    fn run_span(
        &mut self,
        plan: &ExecPlan,
        sources: RankSources,
        stop: u64,
    ) -> Result<EngineReport> {
        // ANALYZE-WAIVE(determinism): wall-clock report fields only
        let started = Instant::now();
        ensure!(!sources.is_empty(), "need at least one rank");
        ensure!(
            sources.len() == plan.n_ranks,
            "plan expects {} ranks, got {} sources",
            plan.n_ranks,
            sources.len()
        );
        let params_len = self.layout.params_len;
        let start = self.done_steps;
        let stop = stop.max(start);

        // Exchange tiling + what each tile's landing makes steppable.
        let extents = self.opt.task_extents();
        let group_extents = self.opt.group_extents();
        let (tiles, visit, ready) = build_schedule(
            &plan,
            params_len,
            &extents,
            &group_extents,
        )?;
        // Per-tile fabric cost (ragged tiles costed by their own bytes —
        // identical tiling to `collective::bucketed_allreduce_times`).
        // Payload bytes follow the plan's wire rung: bf16 ships half the
        // f32 bytes, q8 just over a quarter (elements + block scales) —
        // which the overlap/efficiency numbers reflect. A hierarchical
        // topology overlay swaps the flat ring for the two-level model;
        // the straggler overlay then rescales tiles by their (possibly
        // reassigned) owner's slowdown.
        let tile_comm: Vec<f64> = tiles
            .iter()
            .map(|&(lo, hi)| {
                let bytes = plan.wire.payload_bytes(hi - lo) as f64;
                match plan.topology {
                    Some(h) => {
                        hier_allreduce_bucket_time(bytes, plan.n_ranks, h)
                    }
                    None => {
                        allreduce_bucket_time(bytes, plan.n_ranks, plan.fabric)
                    }
                }
            })
            .collect();
        let (tile_comm, reassigned_per_step) = match &self.straggler {
            Some(pol) => pol.apply(tile_comm, plan.n_ranks),
            None => (tile_comm, 0),
        };

        // Producers: one thread per rank, streaming tiles over bounded
        // channels (the fixed depth is the backpressure a real exchange
        // fabric applies). Each returns its measured peak live gradient
        // elements.
        let (handles, rx_ranks, ret_ranks) = match sources {
            RankSources::Full(srcs) => {
                ensure!(
                    plan.production == GradProduction::FullImage,
                    "plan produces grouped-backward gradients; wrap the \
                     sources as RankSources::Grouped"
                );
                let ship: Vec<(usize, usize)> =
                    visit.iter().map(|&b| tiles[b]).collect();
                spawn_full_producers(srcs, ship, params_len, start, stop)
            }
            RankSources::Grouped(srcs) => {
                ensure!(
                    plan.production == GradProduction::GroupedBackward,
                    "plan produces full-image gradients; wrap the sources \
                     as RankSources::Full"
                );
                validate_grouped(&srcs, &group_extents, params_len)?;
                spawn_grouped_producers(
                    srcs,
                    tiles.clone(),
                    group_extents.clone(),
                    start,
                    stop,
                )
            }
        };

        let outcome = leader_loop(
            &mut self.opt,
            &mut self.blob,
            &mut self.ef,
            plan,
            &tiles,
            &visit,
            &ready,
            &tile_comm,
            &rx_ranks,
            &ret_ranks,
            start,
            stop,
        );
        // Unblock any rank still parked on a bounded send before joining
        // (the error path stops receiving mid-stream).
        drop(rx_ranks);
        drop(ret_ranks);
        let mut peak_elems = 0usize;
        let mut join_err = None;
        for h in handles {
            match h.join() {
                Ok(rank_peak) => peak_elems = peak_elems.max(rank_peak),
                Err(_) => join_err = Some(anyhow!("rank thread panicked")),
            }
        }
        let (compute_secs, comm_secs, exposed_secs) = match (outcome, join_err)
        {
            (Ok(v), None) => v,
            (Err(e), _) | (Ok(_), Some(e)) => {
                // The blob may hold a partially applied step and the
                // step counter was not advanced: refuse to checkpoint
                // this state ever again.
                self.poisoned = true;
                return Err(e);
            }
        };
        self.done_steps = stop;

        let overlap_efficiency = if exposed_secs > 0.0 {
            (compute_secs + comm_secs) / exposed_secs
        } else {
            1.0
        };
        let grouped = plan.production == GradProduction::GroupedBackward
            || plan.granularity == StepGranularity::Groups;
        let curve_bytes = if plan.granularity == StepGranularity::Groups {
            group_extents.iter().map(|&(lo, hi)| 4 * (hi - lo)).collect()
        } else {
            Vec::new()
        };
        // Wire accounting at the plan's wire rung (exact integers; the
        // bench gate pins them two-sided). A single rank ships nothing —
        // the byte metrics agree with the fabric model, which charges
        // such a plan zero time.
        let (comm_bytes_per_step, peak_comm_bytes) = if plan.n_ranks > 1 {
            let mut total = 0usize;
            let mut peak = 0usize;
            for &(lo, hi) in &tiles {
                let b = plan.wire.payload_bytes(hi - lo);
                total += b;
                peak = peak.max(b);
            }
            (total, peak)
        } else {
            (0, 0)
        };
        Ok(EngineReport {
            n_ranks: plan.n_ranks,
            steps: (stop - start) as usize,
            n_buckets: tiles.len(),
            n_groups: if grouped { group_extents.len() } else { 0 },
            compute_secs,
            comm_secs,
            exposed_secs,
            overlap_efficiency,
            wall_secs: started.elapsed().as_secs_f64(),
            peak_live_grad_bytes: 4 * peak_elems,
            full_grad_bytes: 4 * params_len,
            curve_bytes,
            dtype: plan.dtype,
            wire: plan.wire,
            blob_bytes: self.blob.storage_bytes(),
            comm_bytes_per_step,
            peak_comm_bytes,
            reassigned_tiles: reassigned_per_step
                * (stop - start) as usize,
        })
    }
}

/// Tile the gradient image for a plan and compute, per tile, what its
/// landing makes steppable. Returns `(tiles, visit, ready)`: tile ranges
/// indexed in ascending-offset order, the order the leader (and the
/// producers) visit them in, and — for tasks granularity — the per-tile
/// lists of completed task indices.
#[allow(clippy::type_complexity)]
fn build_schedule(
    plan: &ExecPlan,
    params_len: usize,
    extents: &[(usize, usize)],
    group_extents: &[(usize, usize)],
) -> Result<(Vec<(usize, usize)>, Vec<usize>, Vec<Vec<usize>>)> {
    match plan.granularity {
        StepGranularity::WholeImage => {
            Ok((vec![(0, params_len)], vec![0], vec![Vec::new()]))
        }
        StepGranularity::Tasks => {
            let bp = BucketPlan::new(params_len, plan.bucket_elems);
            let ready = match plan.order {
                ExchangeOrder::Ascending => bp.ready_schedule(extents),
                ExchangeOrder::Descending => {
                    bp.ready_schedule_backward(extents)
                }
            };
            let visit: Vec<usize> = match plan.order {
                ExchangeOrder::Ascending => (0..bp.n_buckets()).collect(),
                ExchangeOrder::Descending => {
                    (0..bp.n_buckets()).rev().collect()
                }
            };
            Ok((bp.buckets, visit, ready))
        }
        StepGranularity::Groups => {
            // One tile per fused group. Group extents arrive in walk
            // (descending-offset) order; tiles are stored ascending so
            // the grouped producers' cover logic can walk them from the
            // top, and tile b maps back to group `G - 1 - b`.
            ensure_descending_tiling(group_extents, params_len)?;
            let tiles: Vec<(usize, usize)> =
                group_extents.iter().rev().copied().collect();
            let visit: Vec<usize> = (0..tiles.len()).rev().collect();
            let ready = vec![Vec::new(); tiles.len()];
            Ok((tiles, visit, ready))
        }
    }
}

/// The grouped walk ships buckets against a production frontier moving
/// down from `params_len`: the groups must tile the image top-down.
fn ensure_descending_tiling(
    group_extents: &[(usize, usize)],
    params_len: usize,
) -> Result<()> {
    let mut hi_expect = params_len;
    for (g, &(lo, hi)) in group_extents.iter().enumerate() {
        ensure!(
            hi == hi_expect && lo < hi,
            "group {g} extent [{lo}, {hi}) breaks the descending tiling \
             (expected hi = {hi_expect}); fused-backward execution needs \
             a model-shaped layout"
        );
        hi_expect = lo;
    }
    ensure!(hi_expect == 0, "fused groups must cover the gradient image");
    Ok(())
}

/// Every grouped source must agree with the engine's fused groups.
fn validate_grouped(
    sources: &[Box<dyn GroupGradSource>],
    group_extents: &[(usize, usize)],
    params_len: usize,
) -> Result<()> {
    ensure_descending_tiling(group_extents, params_len)?;
    for (r, src) in sources.iter().enumerate() {
        ensure!(
            src.n_groups() == group_extents.len(),
            "rank {r}: source has {} groups, engine {}",
            src.n_groups(),
            group_extents.len()
        );
        for (g, &e) in group_extents.iter().enumerate() {
            ensure!(
                src.group_extent(g) == e,
                "rank {r} group {g}: source extent {:?} != engine {:?}",
                src.group_extent(g),
                e
            );
        }
    }
    Ok(())
}

/// Full-image producers: fast-forward past completed steps, then per step
/// fill the whole gradient image and ship the tiles in visit order. Every
/// rank holds the full image, so its peak is `params_len` elements.
///
/// Shipped tile payloads ride a recycled buffer ring: the leader sends
/// spent chunk Vecs back on a per-rank return channel and the producer
/// refills them, so the steady state allocates nothing — only the first
/// in-flight chunks (bounded by the channel depth) are ever created.
#[allow(clippy::type_complexity)]
fn spawn_full_producers(
    sources: Vec<Box<dyn GradSource>>,
    ship: Vec<(usize, usize)>,
    params_len: usize,
    start: u64,
    stop: u64,
) -> (
    Vec<thread::JoinHandle<usize>>,
    Vec<mpsc::Receiver<Vec<f32>>>,
    Vec<mpsc::Sender<Vec<f32>>>,
) {
    let mut handles = Vec::with_capacity(sources.len());
    let mut rx_ranks = Vec::with_capacity(sources.len());
    let mut ret_ranks = Vec::with_capacity(sources.len());
    for mut src in sources {
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        rx_ranks.push(rx);
        let (ret_tx, ret_rx) = mpsc::channel::<Vec<f32>>();
        ret_ranks.push(ret_tx);
        let ship = ship.clone();
        // ANALYZE-WAIVE(determinism): producers feed per-rank channels drained in rank order
        handles.push(thread::spawn(move || -> usize {
            let mut grad = vec![0f32; params_len];
            for s in 1..=start {
                src.skip(s, &mut grad);
            }
            // Peak is the full image once any step materializes it —
            // and 0 for an empty (already-finished) run, matching the
            // grouped producers' measured semantics.
            let mut peak_elems = 0usize;
            for step in start + 1..=stop {
                peak_elems = params_len;
                src.fill(step, &mut grad);
                // ANALYZE-HOT: full producer ship loop
                for &(lo, hi) in &ship {
                    let mut buf = ret_rx.try_recv().unwrap_or_default();
                    buf.clear();
                    buf.extend_from_slice(&grad[lo..hi]);
                    if tx.send(buf).is_err() {
                        return peak_elems; // leader bailed; stop producing
                    }
                }
                // ANALYZE-HOT-END
            }
            peak_elems
        }));
    }
    (handles, rx_ranks, ret_ranks)
}

/// Grouped producers: interleave group production with tile shipping.
/// Produced-but-unshipped group buffers are retained oldest (highest
/// extent) first; each is freed the moment the shipped region covers it,
/// so only the groups overlapping the unshipped span stay allocated —
/// with tiles no larger than a group that is at most two groups, the
/// host-path twin of the paper's two-consecutive-gradients bound (§2.1),
/// and it can never exceed the full image. Each thread returns its
/// measured peak live gradient elements.
/// Like the full producers, chunk payloads ride the leader's recycled
/// buffer ring, and retired group buffers go to a local free list that
/// the next group draws from — the per-step `vec![0f32; ..]` churn of
/// the original implementation is gone after warm-up. The liveness
/// *accounting* (peak live gradient elements) is unchanged: a buffer
/// parked on the free list holds no live gradient data.
#[allow(clippy::type_complexity)]
fn spawn_grouped_producers(
    sources: Vec<Box<dyn GroupGradSource>>,
    tiles: Vec<(usize, usize)>,
    extents: Vec<(usize, usize)>,
    start: u64,
    stop: u64,
) -> (
    Vec<thread::JoinHandle<usize>>,
    Vec<mpsc::Receiver<Vec<f32>>>,
    Vec<mpsc::Sender<Vec<f32>>>,
) {
    let mut handles = Vec::with_capacity(sources.len());
    let mut rx_ranks = Vec::with_capacity(sources.len());
    let mut ret_ranks = Vec::with_capacity(sources.len());
    for mut src in sources {
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        rx_ranks.push(rx);
        let (ret_tx, ret_rx) = mpsc::channel::<Vec<f32>>();
        ret_ranks.push(ret_tx);
        let tiles = tiles.clone();
        let extents = extents.clone();
        // ANALYZE-WAIVE(determinism): producers feed per-rank channels drained in rank order
        handles.push(thread::spawn(move || -> usize {
            let mut scratch = Vec::new();
            for s in 1..=start {
                src.skip_step(s, &mut scratch);
            }
            drop(scratch);
            let mut peak_elems = 0usize;
            let mut segs: VecDeque<(usize, Vec<f32>)> = VecDeque::new();
            let mut free: Vec<Vec<f32>> = Vec::new();
            for step in start + 1..=stop {
                let mut live = 0usize;
                let mut next_tile = tiles.len();
                // ANALYZE-HOT: grouped producer fill/ship loop
                for (g, &(lo, hi)) in extents.iter().enumerate() {
                    let mut gbuf = free.pop().unwrap_or_default();
                    gbuf.clear();
                    gbuf.resize(hi - lo, 0f32);
                    src.fill_group(step, g, &mut gbuf);
                    live += gbuf.len();
                    peak_elems = peak_elems.max(live);
                    segs.push_back((lo, gbuf));
                    // Ship every tile production now covers; each send
                    // assembles the tile payload from the overlapping
                    // buffers (the one copy the exchange itself needs).
                    while next_tile > 0 && tiles[next_tile - 1].0 >= lo {
                        let (blo, bhi) = tiles[next_tile - 1];
                        let mut chunk =
                            ret_rx.try_recv().unwrap_or_default();
                        chunk.clear();
                        chunk.resize(bhi - blo, 0f32);
                        for (slo, sbuf) in segs.iter() {
                            let slo = *slo;
                            let shi = slo + sbuf.len();
                            let olo = blo.max(slo);
                            let ohi = bhi.min(shi);
                            if olo < ohi {
                                chunk[olo - blo..ohi - blo]
                                    .copy_from_slice(
                                        &sbuf[olo - slo..ohi - slo],
                                    );
                            }
                        }
                        if tx.send(chunk).is_err() {
                            return peak_elems; // leader bailed; stop
                        }
                        // Retire every buffer the shipped region covers
                        // to the free list for the next group's fill.
                        loop {
                            match segs.front() {
                                Some(&(slo, _)) if slo >= blo => {
                                    if let Some((_, sbuf)) =
                                        segs.pop_front()
                                    {
                                        live -= sbuf.len();
                                        free.push(sbuf);
                                    }
                                }
                                _ => break,
                            }
                        }
                        next_tile -= 1;
                    }
                }
                // ANALYZE-HOT-END
                debug_assert!(segs.is_empty() && next_tile == 0);
            }
            peak_elems
        }));
    }
    (handles, rx_ranks, ret_ranks)
}

/// THE leader loop — the single copy that used to exist per path: receive
/// each tile's per-rank contribution, round-trip it through the plan's
/// wire codec (with that rank's error-feedback slice, for rungs that keep
/// one), then reduce in rank order on an f32 tree (the fixed reduction
/// order determinism rests on), step whatever the plan's granularity
/// makes ready, and advance the modeled timeline. A single rank exchanges
/// nothing, so the codec is bypassed there — every wire rung is exact at
/// `n_ranks == 1`, matching the zero-byte/zero-time fabric accounting.
/// Returns `(compute, comm, exposed)` seconds.
#[allow(clippy::too_many_arguments)]
fn leader_loop(
    opt: &mut FlatOptimizer,
    blob: &mut TypedBlob,
    ef: &mut [Vec<f32>],
    plan: &ExecPlan,
    tiles: &[(usize, usize)],
    visit: &[usize],
    ready: &[Vec<usize>],
    tile_comm: &[f64],
    rx_ranks: &[mpsc::Receiver<Vec<f32>>],
    ret_ranks: &[mpsc::Sender<Vec<f32>>],
    start: u64,
    stop: u64,
) -> Result<(f64, f64, f64)> {
    let n_ranks = rx_ranks.len();
    let wire_active = n_ranks > 1;
    let inv = 1.0 / n_ranks as f32;
    let params_len = tiles.iter().map(|&(_, hi)| hi).max().unwrap_or(0);
    let mut grad = vec![0f32; params_len];
    // Chunk holder reused across tiles and steps; spent payloads go back
    // to their producer's recycle ring, so the steady-state exchange
    // allocates nothing on the leader side.
    let mut chunks: Vec<Vec<f32>> = Vec::with_capacity(n_ranks);
    let (mut compute, mut comm, mut exposed) = (0.0f64, 0.0f64, 0.0f64);
    let last_visit = visit.last().copied();
    for t in start + 1..=stop {
        // Modeled per-step timeline: comm is serialized on the fabric
        // (`comm_front`); tile b's optimizer work starts at max(its
        // reduction landing, previous work finishing).
        let mut comm_front = 0.0f64;
        let mut work_front = 0.0f64;
        // ANALYZE-HOT: engine leader tile loop
        for &b in visit {
            let (lo, hi) = tiles[b];
            // Accumulate: one contribution per rank, received in rank
            // order and round-tripped through the wire codec — exactly
            // what a real fabric would deliver after decode. Error-
            // feedback rungs fold rank r's residual slice for this
            // region into the payload before quantizing and bank the
            // new residual for the next step's same-region send.
            chunks.clear();
            for (r, rx) in rx_ranks.iter().enumerate() {
                let mut chunk = rx.recv().map_err(|_| {
                    anyhow!("rank gradient stream ended early")
                })?;
                ensure!(chunk.len() == hi - lo, "tile size mismatch");
                if wire_active {
                    let residual: &mut [f32] = match ef.get_mut(r) {
                        Some(e) => &mut e[lo..hi],
                        None => &mut [],
                    };
                    plan.wire.encode_decode(&mut chunk, residual);
                }
                chunks.push(chunk);
            }
            // Reduce: mean in rank order, element-parallel on the pool
            // (bit-identical for any worker count).
            pool::par_average(&mut grad[lo..hi], &chunks, inv, plan.n_shards);
            // Hand the spent payloads back to their producers' rings
            // (a closed ring just means that rank already exited).
            for (r, chunk) in chunks.drain(..).enumerate() {
                let _ = ret_ranks[r].send(chunk);
            }
            comm_front += tile_comm[b];
            comm += tile_comm[b];
            // Step: whatever this tile's landing makes ready.
            let dt = match plan.granularity {
                StepGranularity::Tasks if !ready[b].is_empty() => {
                    // ANALYZE-WAIVE(determinism): step-time report metric only
                    let t0 = Instant::now();
                    opt.step_tasks_typed(
                        blob, &grad, t, plan.lr, plan.wd, &ready[b],
                    )?;
                    t0.elapsed().as_secs_f64()
                }
                StepGranularity::Tasks => 0.0,
                StepGranularity::Groups => {
                    let g = tiles.len() - 1 - b;
                    // ANALYZE-WAIVE(determinism): step-time report metric only
                    let t0 = Instant::now();
                    opt.step_group_typed(
                        blob,
                        g,
                        &grad[lo..hi],
                        t,
                        plan.lr,
                        plan.wd,
                    )?;
                    t0.elapsed().as_secs_f64()
                }
                StepGranularity::WholeImage if Some(b) == last_visit => {
                    // ANALYZE-WAIVE(determinism): step-time report metric only
                    let t0 = Instant::now();
                    opt.step_typed(blob, &grad, t, plan.lr, plan.wd)?;
                    t0.elapsed().as_secs_f64()
                }
                StepGranularity::WholeImage => 0.0,
            };
            compute += dt;
            work_front = comm_front.max(work_front) + dt;
        }
        // ANALYZE-HOT-END
        exposed += comm_front.max(work_front);
    }
    Ok((compute, comm, exposed))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::fused_host::FusedHostGrads;
    use crate::coordinator::pipeline::synthetic_sources;
    use crate::optim::flat::{seeded_blob_and_grads, synthetic_layout};

    fn model_layout(kind: OptKind) -> Layout {
        let params: Vec<(&str, &[usize])> = vec![
            ("embed", &[16, 8][..]),
            ("l0.attn_norm", &[8][..]),
            ("l0.wq", &[8, 8][..]),
            ("l1.wq", &[8, 8][..]),
            ("final_norm", &[8][..]),
            ("head", &[8, 16][..]),
        ];
        synthetic_layout(kind, &params)
    }

    fn cfg(steps: usize, bucket: usize) -> PipelineConfig {
        let mut c = PipelineConfig::new(steps, bucket);
        c.n_shards = 2;
        c
    }

    #[test]
    fn plan_validation_rejects_impossible_combos() {
        let c = cfg(2, 16);
        let mut plan =
            ExecPlan::pipelined_fused(OptKind::AdaLomo, ShardMode::Segments, 2, &c);
        plan.order = ExchangeOrder::Ascending;
        assert!(plan.validate().is_err());
        let mut plan =
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Segments, 2, &c);
        plan.bucket_elems = 0;
        assert!(plan.validate().is_err());
        plan.bucket_elems = 16;
        plan.n_ranks = 0;
        assert!(plan.validate().is_err());
    }

    #[test]
    fn plan_record_round_trip() {
        let c = cfg(5, 32);
        for plan in [
            ExecPlan::sequential(OptKind::AdamW, ShardMode::Contiguous, 3, &c),
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Segments, 2, &c),
            ExecPlan::pipelined_fused(
                OptKind::Adafactor,
                ShardMode::Contiguous,
                4,
                &c,
            ),
            ExecPlan::fused_host(OptKind::AdaLomo, ShardMode::Segments, 1, &c),
        ] {
            for dtype in [Dtype::F32, Dtype::Bf16] {
                for wire in
                    [WireCodec::F32, WireCodec::Bf16, WireCodec::Q8Block]
                {
                    let mut plan = plan.clone();
                    plan.seed = 99;
                    plan.dtype = dtype;
                    plan.wire = wire;
                    let back =
                        ExecPlan::from_record(&plan.to_record()).unwrap();
                    assert_eq!(back.production, plan.production);
                    assert_eq!(back.order, plan.order);
                    assert_eq!(back.granularity, plan.granularity);
                    assert_eq!(back.kind, plan.kind);
                    assert_eq!(back.mode, plan.mode);
                    assert_eq!(back.n_ranks, plan.n_ranks);
                    assert_eq!(back.steps, plan.steps);
                    assert_eq!(back.bucket_elems, plan.bucket_elems);
                    assert_eq!(back.lr.to_bits(), plan.lr.to_bits());
                    assert_eq!(back.wd.to_bits(), plan.wd.to_bits());
                    assert_eq!(back.n_shards, plan.n_shards);
                    assert_eq!(back.dtype, dtype);
                    assert_eq!(back.wire, wire);
                    assert_eq!(back.seed, plan.seed);
                }
            }
        }
        // Unknown codes are rejected.
        let mut rec = ExecPlan::sequential(
            OptKind::AdaLomo,
            ShardMode::Segments,
            1,
            &c,
        )
        .to_record();
        rec.granularity = 99;
        assert!(ExecPlan::from_record(&rec).is_err());
        rec.granularity = checkpoint::GRAN_WHOLE_IMAGE;
        rec.wire = 99;
        assert!(ExecPlan::from_record(&rec).is_err());
    }

    #[test]
    fn q8_wire_shrinks_payloads_and_stays_deterministic() {
        let kind = OptKind::AdaLomo;
        let layout = model_layout(kind);
        let (blob0, _) = seeded_blob_and_grads(&layout, 21);
        let mut c = cfg(4, layout.params_len.div_ceil(5));
        c.wire = Some(WireCodec::Q8Block);
        let plan = ExecPlan::pipelined(kind, ShardMode::Segments, 2, &c);
        assert_eq!(plan.wire, WireCodec::Q8Block);
        let run = |plan: &ExecPlan| {
            let mut eng =
                Engine::new(&layout, &blob0, plan.clone()).unwrap();
            let r = eng
                .run(RankSources::Full(synthetic_sources(2, 17, 0.05)))
                .unwrap();
            (eng.blob(), r)
        };
        let (blob_a, ra) = run(&plan);
        let (blob_b, _) = run(&plan);
        assert_eq!(ra.wire, WireCodec::Q8Block);
        // Quantized exchange is still exactly reproducible run to run.
        for (x, y) in blob_a.iter().zip(blob_b.iter()) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Payload accounting follows the codec: elements + one f32 scale
        // per 64-element block, summed over the exact exchange tiling.
        let bp = BucketPlan::new(layout.params_len, plan.bucket_elems);
        let expect: usize = bp
            .buckets
            .iter()
            .map(|&(lo, hi)| WireCodec::Q8Block.payload_bytes(hi - lo))
            .sum();
        assert_eq!(ra.comm_bytes_per_step, expect);
        // ... and the codec really touched the exchanged values: the q8
        // run diverges from the identical schedule on the f32 wire.
        let plan_f32 =
            ExecPlan::pipelined(kind, ShardMode::Segments, 2, &cfg(4, c.bucket_elems));
        assert_eq!(plan_f32.wire, WireCodec::F32);
        let (blob_f, rf) = run(&plan_f32);
        assert!(ra.comm_bytes_per_step * 100 <= rf.comm_bytes_per_step * 30);
        assert!(blob_a
            .iter()
            .zip(blob_f.iter())
            .any(|(a, b)| a.to_bits() != b.to_bits()));
    }

    #[test]
    fn source_variant_must_match_production() {
        let layout = model_layout(OptKind::AdaLomo);
        let (blob0, _) = seeded_blob_and_grads(&layout, 3);
        let c = cfg(1, layout.params_len);
        let plan = ExecPlan::pipelined_fused(
            OptKind::AdaLomo,
            ShardMode::Segments,
            2,
            &c,
        );
        let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
        assert!(eng
            .run(RankSources::Full(synthetic_sources(2, 1, 0.1)))
            .is_err());
        // Wrong rank count is rejected too.
        let grouped: Vec<Box<dyn GroupGradSource>> =
            FusedHostGrads::per_rank_extents(eng.group_extents(), 3, 1, 0.1);
        assert!(eng.run(RankSources::Grouped(grouped)).is_err());
    }

    #[test]
    fn suspend_resume_matches_uninterrupted_bitwise() {
        let kind = OptKind::AdaLomo;
        let layout = model_layout(kind);
        let (blob0, _) = seeded_blob_and_grads(&layout, 11);
        let c = cfg(6, layout.params_len.div_ceil(5));
        let plan =
            ExecPlan::pipelined_fused(kind, ShardMode::Contiguous, 2, &c);
        let srcs = |eng: &Engine| -> RankSources {
            RankSources::Grouped(FusedHostGrads::per_rank_extents(
                eng.group_extents(),
                2,
                7,
                0.05,
            ))
        };

        // Uninterrupted reference.
        let mut full = Engine::new(&layout, &blob0, plan.clone()).unwrap();
        let sources = srcs(&full);
        full.run(sources).unwrap();
        assert!(full.is_finished());

        // Suspend after 3 steps, checkpoint, resume in a "new process".
        let path = std::env::temp_dir().join(format!(
            "adalomo_engine_suspend_{}.bin",
            std::process::id()
        ));
        let mut part = Engine::new(&layout, &blob0, plan).unwrap();
        part.suspend_at(3);
        let sources = srcs(&part);
        let r1 = part.run(sources).unwrap();
        assert_eq!(r1.steps, 3);
        assert_eq!(part.step(), 3);
        assert!(!part.is_finished());
        part.save(&path).unwrap();

        let mut resumed = Engine::resume(&path).unwrap();
        assert_eq!(resumed.step(), 3);
        let sources = srcs(&resumed);
        let r2 = resumed.run(sources).unwrap();
        assert_eq!(r2.steps, 3);
        assert!(resumed.is_finished());

        let blob_full = full.blob();
        let blob_res = resumed.blob();
        for (i, (a, b)) in blob_full.iter().zip(blob_res.iter()).enumerate() {
            assert!(
                a.to_bits() == b.to_bits(),
                "elem {i}: {a} vs {b}"
            );
        }
        // The resumed engine's checkpoint equals the uninterrupted one's
        // byte for byte — what `make ckpt-smoke` asserts end to end.
        let p_full = std::env::temp_dir().join(format!(
            "adalomo_engine_full_{}.bin",
            std::process::id()
        ));
        let p_res = std::env::temp_dir().join(format!(
            "adalomo_engine_res_{}.bin",
            std::process::id()
        ));
        full.save(&p_full).unwrap();
        resumed.save(&p_res).unwrap();
        let a = std::fs::read(&p_full).unwrap();
        let b = std::fs::read(&p_res).unwrap();
        assert_eq!(a, b);
        for p in [path, p_full, p_res] {
            std::fs::remove_file(p).ok();
        }
    }

    /// A rank stream that dies mid-run (panicking backward, dropped
    /// connection) — the failure mode that must poison the engine.
    struct DoomedGrads {
        fail_at: u64,
    }

    impl GradSource for DoomedGrads {
        fn fill(&mut self, step: u64, out: &mut [f32]) {
            assert!(step < self.fail_at, "synthetic rank failure");
            for x in out.iter_mut() {
                *x = 0.01;
            }
        }
    }

    #[test]
    fn mid_step_failure_poisons_the_engine() {
        let layout = model_layout(OptKind::AdaLomo);
        let (blob0, _) = seeded_blob_and_grads(&layout, 9);
        let c = cfg(4, 16);
        let plan =
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Segments, 1, &c);
        let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
        let sources: Vec<Box<dyn GradSource>> =
            vec![Box::new(DoomedGrads { fail_at: 3 })];
        assert!(eng.run(RankSources::Full(sources)).is_err());
        // The blob may hold a partially applied step: checkpointing must
        // refuse rather than hand a resume a corrupted state.
        let path = std::env::temp_dir().join(format!(
            "adalomo_engine_poison_{}.bin",
            std::process::id()
        ));
        let err = eng.save(&path).unwrap_err();
        assert!(format!("{err:#}").contains("cannot be checkpointed"));
        assert!(!path.exists());
        // Pre-loop validation failures do NOT poison: the blob was never
        // touched, so a later checkpoint stays legal.
        let plan = ExecPlan::pipelined(
            OptKind::AdaLomo,
            ShardMode::Segments,
            2,
            &cfg(2, 16),
        );
        let mut clean = Engine::new(&layout, &blob0, plan).unwrap();
        assert!(clean
            .run(RankSources::Full(synthetic_sources(1, 3, 0.1)))
            .is_err()); // rank-count mismatch, caught before any step
        clean.save(&path).unwrap();
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn whole_image_plan_reports_lockstep_shape() {
        let kind = OptKind::AdamW;
        let layout = model_layout(kind);
        let (blob0, _) = seeded_blob_and_grads(&layout, 5);
        let c = cfg(2, 7);
        let plan = ExecPlan::sequential(kind, ShardMode::Segments, 2, &c);
        let mut eng = Engine::new(&layout, &blob0, plan).unwrap();
        let report = eng
            .run(RankSources::Full(synthetic_sources(2, 13, 0.05)))
            .unwrap();
        assert_eq!(report.n_buckets, 1);
        assert_eq!(report.n_groups, 0);
        assert_eq!(report.peak_live_grad_bytes, report.full_grad_bytes);
        assert!(report.curve_bytes.is_empty());
        // Lockstep: nothing overlaps.
        assert!((report.overlap_efficiency - 1.0).abs() < 1e-9);
    }

    #[test]
    fn membership_schedule_validates_and_resolves_epochs() {
        let c = cfg(6, 16);
        let mut plan =
            ExecPlan::pipelined(OptKind::AdaLomo, ShardMode::Segments, 3, &c);
        plan.ranks_schedule = vec![(2, 1), (4, 2)];
        plan.validate().unwrap();
        assert!(plan.describe().contains("3 membership epochs"));
        // Step → rank-count lookup: the r of the last boundary passed.
        for (t, want) in [(1, 3), (2, 3), (3, 1), (4, 1), (5, 2), (6, 2)] {
            assert_eq!(plan.ranks_for_step(t), want, "step {t}");
        }
        // The schedule rides the plan record (ADCP v4 epoch section).
        let back = ExecPlan::from_record(&plan.to_record()).unwrap();
        assert_eq!(back.ranks_schedule, plan.ranks_schedule);
        // Degenerate schedules are rejected up front.
        for bad in [
            vec![(2u64, 0u32)],  // zero ranks
            vec![(0, 2)],        // boundary before the first step
            vec![(6, 2)],        // boundary at/after the run's end
            vec![(3, 2), (3, 1)] // not strictly increasing
        ] {
            let mut p = plan.clone();
            p.ranks_schedule = bad.clone();
            assert!(p.validate().is_err(), "{bad:?}");
        }
        // And run() refuses to silently ignore a schedule.
        let layout = model_layout(OptKind::AdaLomo);
        let (blob0, _) = seeded_blob_and_grads(&layout, 13);
        let mut p = ExecPlan::pipelined(
            OptKind::AdaLomo,
            ShardMode::Segments,
            2,
            &cfg(4, 16),
        );
        p.ranks_schedule = vec![(2, 1)];
        let mut eng = Engine::new(&layout, &blob0, p).unwrap();
        let err = eng
            .run(RankSources::Full(synthetic_sources(2, 5, 0.05)))
            .unwrap_err();
        assert!(format!("{err:#}").contains("run_elastic"));
    }

    #[test]
    fn elastic_run_is_deterministic_and_reports_merged_shape() {
        let kind = OptKind::AdaLomo;
        let layout = model_layout(kind);
        let (blob0, _) = seeded_blob_and_grads(&layout, 29);
        let c = cfg(6, layout.params_len.div_ceil(5));
        let mut plan = ExecPlan::pipelined(kind, ShardMode::Segments, 3, &c);
        plan.seed = 51;
        plan.ranks_schedule = vec![(2, 1), (4, 2)];
        let run = || {
            let mut eng =
                Engine::new(&layout, &blob0, plan.clone()).unwrap();
            let extents = eng.group_extents();
            let r = eng
                .run_elastic(|seg| {
                    crate::coordinator::fused_host::plan_sources(
                        seg,
                        extents.clone(),
                        0.05,
                    )
                })
                .unwrap();
            assert!(eng.is_finished());
            (eng.blob(), r)
        };
        let (a, ra) = run();
        let (b, _) = run();
        assert_eq!(ra.steps, 6);
        // The merged report carries the LAST epoch's fleet shape.
        assert_eq!(ra.n_ranks, 2);
        assert_eq!(ra.reassigned_tiles, 0);
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert!(x.to_bits() == y.to_bits(), "elem {i}: {x} vs {y}");
        }
    }

    #[test]
    fn straggler_policy_rescales_the_timeline_without_touching_bits() {
        let kind = OptKind::AdaLomo;
        let layout = model_layout(kind);
        let (blob0, _) = seeded_blob_and_grads(&layout, 31);
        let c = cfg(4, layout.params_len.div_ceil(6));
        let plan = ExecPlan::pipelined(kind, ShardMode::Segments, 2, &c);
        let run = |policy: Option<StragglerPolicy>| {
            let mut eng =
                Engine::new(&layout, &blob0, plan.clone()).unwrap();
            eng.set_straggler(policy);
            let r = eng
                .run(RankSources::Full(synthetic_sources(2, 23, 0.05)))
                .unwrap();
            (eng.blob(), r)
        };
        let (blob_plain, plain) = run(None);
        // Rank 1 is 4x late; the 2.0 threshold trips, so its tiles move
        // to the on-time rank 0 and cost rank-0 time again.
        let (blob_moved, moved) = run(Some(StragglerPolicy {
            slowdown: vec![1.0, 4.0],
            threshold: 2.0,
        }));
        // Same slowdown but a threshold nothing trips: the late rank
        // keeps its tiles and the exchange eats the full 4x.
        let (blob_kept, kept) = run(Some(StragglerPolicy {
            slowdown: vec![1.0, 4.0],
            threshold: 10.0,
        }));
        // The policy is a cost-model overlay: bits never move.
        for (x, y) in blob_plain.iter().zip(&blob_moved) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        for (x, y) in blob_plain.iter().zip(&blob_kept) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // Reassignment accounting: every odd tile (owned by rank 1 under
        // round-robin) moves, every step.
        assert_eq!(plain.reassigned_tiles, 0);
        assert_eq!(kept.reassigned_tiles, 0);
        assert_eq!(
            moved.reassigned_tiles,
            (plain.n_buckets / 2) * plain.steps
        );
        assert!(moved.reassigned_tiles > 0);
        // And the modeled timeline orders exactly as the policy says:
        // keeping tiles on a 4x rank costs more than rebalancing them.
        assert!(kept.comm_secs > moved.comm_secs);
        assert!(moved.comm_secs <= plain.comm_secs + 1e-12);
    }

    #[test]
    fn hier_topology_swaps_the_fabric_model_without_touching_bits() {
        let kind = OptKind::AdaLomo;
        let layout = model_layout(kind);
        let (blob0, _) = seeded_blob_and_grads(&layout, 37);
        let c = cfg(3, layout.params_len.div_ceil(4));
        let plan = ExecPlan::pipelined(kind, ShardMode::Contiguous, 4, &c);
        let run = |topology: Option<HierFabric>| {
            let mut eng =
                Engine::new(&layout, &blob0, plan.clone()).unwrap();
            eng.set_topology(topology);
            let r = eng
                .run(RankSources::Full(synthetic_sources(4, 43, 0.05)))
                .unwrap();
            (eng.blob(), r)
        };
        let (blob_flat, flat) = run(None);
        // Two nodes of two ranks over a slow inter-node link.
        let (blob_hier, hier) = run(Some(HierFabric {
            intra: plan.fabric,
            inter: Fabric { alpha: 15e-6, bw: 25e9 },
            ranks_per_node: 2,
        }));
        for (x, y) in blob_flat.iter().zip(&blob_hier) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        // The modeled exchange time changed (the slow inter ring is in
        // the path), the exchanged bytes did not.
        assert!((flat.comm_secs - hier.comm_secs).abs() > 1e-12);
        assert_eq!(flat.comm_bytes_per_step, hier.comm_bytes_per_step);
    }
}
