//! Layer-3 coordinator: the training orchestrator.
//!
//! * [`engine`] — the unified execution engine: one `ExecPlan`-driven
//!   leader loop behind every host-mirror training path, with
//!   checkpoint/suspend/resume.
//! * [`schedule`] — warmup + cosine LR (the schedules live here, not in the
//!   HLO: every train-step artifact takes the scheduled LR as an input).
//! * [`trainer`] — the step loop over the device-resident state blob.
//! * [`fused`] — fused-backward group scheduler (LOMO/AdaLomo liveness at
//!   program granularity; chains `fused_*_g<k>` artifacts).
//! * [`fused_host`] — group-granular gradient sources + the fused-host
//!   mirror entry points (now `ExecPlan` constructors), with peak
//!   live-gradient bytes measured and checked against `memsim::liveness`.
//! * [`sharding`] — ZeRO-3 shard planner over manifest segments.
//! * [`collective`] — ring-collective cost model used by the throughput
//!   simulation and the worker pool.
//! * [`workers`] — thread-per-rank data-parallel execution (local-SGD
//!   periodic parameter averaging; each rank owns a PJRT session).
//! * [`pipeline`] — bucket plans, gradient sources and the pipelined
//!   entry points (now `ExecPlan` constructors over [`engine`]).

pub mod collective;
pub mod engine;
pub mod fused;
pub mod fused_host;
pub mod pipeline;
pub mod schedule;
pub mod sharding;
pub mod trainer;
pub mod workers;

pub use engine::{Engine, EngineReport, ExecPlan, RankSources};
pub use schedule::Schedule;
pub use trainer::{TrainReport, Trainer};
