//! Layer-3 coordinator: the training orchestrator.
//!
//! * [`schedule`] — warmup + cosine LR (the schedules live here, not in the
//!   HLO: every train-step artifact takes the scheduled LR as an input).
//! * [`trainer`] — the step loop over the device-resident state blob.
//! * [`fused`] — fused-backward group scheduler (LOMO/AdaLomo liveness at
//!   program granularity; chains `fused_*_g<k>` artifacts).
//! * [`fused_host`] — the same schedule on the host fast path: group-by-
//!   group gradient production driving `FlatOptimizer::step_group`, with
//!   peak live-gradient bytes measured and checked against
//!   `memsim::liveness`.
//! * [`sharding`] — ZeRO-3 shard planner over manifest segments.
//! * [`collective`] — ring-collective cost model used by the throughput
//!   simulation and the worker pool.
//! * [`workers`] — thread-per-rank data-parallel execution (local-SGD
//!   periodic parameter averaging; each rank owns a PJRT session).
//! * [`pipeline`] — async rank pipeline: bucketed gradient exchange
//!   overlapped with flat-engine task stepping (host mirror).

pub mod collective;
pub mod fused;
pub mod fused_host;
pub mod pipeline;
pub mod schedule;
pub mod sharding;
pub mod trainer;
pub mod workers;

pub use schedule::Schedule;
pub use trainer::{TrainReport, Trainer};
