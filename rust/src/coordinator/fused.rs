//! Fused-backward group scheduler.
//!
//! LOMO/AdaLomo's memory contribution is that parameter gradients die
//! immediately after their update (paper §2.1). Inside a single XLA
//! program the compiler owns buffer lifetimes, so the coordinator
//! reproduces the schedule at *program granularity*: the step is split
//! into G = L+2 group programs (`fused_<preset>_<opt>_g<k>`, backward
//! order: head block, layers L-1..0, embedding), each computing gradients
//! **from the frozen theta_t blob** and updating only its group. XLA
//! dead-code-eliminates every other group's weight gradients from program
//! k, so at most one group's gradients are ever materialized — and because
//! every group's gradient is evaluated at theta_t, the chained result is
//! *exactly* the monolithic train step (integration test asserts this).
//!
//! Cost: one full forward+backward per group (G× compute) + a second blob
//! buffer — this mode is a scheduling/liveness demonstrator and test rig,
//! not the fast path. The analytic story lives in `memsim::liveness`.

use anyhow::{bail, Result};
use xla::PjRtBuffer;

use crate::runtime::{Manifest, Session};

/// Number of fused group programs available for (preset, opt), if any.
pub fn fused_groups(session: &Session, preset: &str, opt: &str) -> Option<usize> {
    let name = Manifest::fused_name(preset, opt, 0);
    session
        .manifest
        .entries
        .get(&name)
        .and_then(|e| e.group.map(|(_, n)| n))
}

/// One fused-backward step: chains the G group programs.
///
/// `frozen` holds theta_t (and its optimizer state); the returned buffer is
/// the fully-updated blob theta_{t+1}.
pub fn fused_step(
    session: &Session,
    preset: &str,
    opt: &str,
    frozen: &PjRtBuffer,
    x: &PjRtBuffer,
    y: &PjRtBuffer,
    sched: &PjRtBuffer,
) -> Result<PjRtBuffer> {
    let Some(n_groups) = fused_groups(session, preset, opt) else {
        bail!("no fused artifacts for {preset}/{opt} (see aot.py FUSED_PRESETS)")
    };
    let mut accum: Option<PjRtBuffer> = None;
    for k in 0..n_groups {
        let entry = Manifest::fused_name(preset, opt, k);
        let acc_ref = accum.as_ref().unwrap_or(frozen);
        let next =
            session.execute_buf(&entry, &[frozen, acc_ref, x, y, sched])?;
        accum = Some(next);
    }
    Ok(accum.expect("n_groups >= 1"))
}

/// Per-group *live gradient* sizes in f32 elements — what each fused
/// program materializes. Mirrors `steps.fused_groups` grouping: head block,
/// layers in reverse, embedding.
pub fn group_grad_sizes(session: &Session, preset: &str, opt: &str) -> Result<Vec<usize>> {
    let layout = session
        .manifest
        .layout(&Manifest::layout_key(preset, opt))?;
    let n_layers = session.manifest.preset(preset)?.n_layers;
    let size_of = |name: &str| -> usize {
        layout.segment(name).map(|s| s.size).unwrap_or(0)
    };
    let mut groups =
        vec![size_of("head") + size_of("final_norm")];
    for l in (0..n_layers).rev() {
        let p = format!("l{l}.");
        groups.push(
            ["attn_norm", "wq", "wk", "wv", "wo", "ffn_norm", "w_gate",
             "w_up", "w_down"]
            .iter()
            .map(|n| size_of(&format!("{p}{n}")))
            .sum(),
        );
    }
    groups.push(size_of("embed"));
    Ok(groups)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fused_name_format() {
        assert_eq!(
            Manifest::fused_name("nano", "adalomo", 2),
            "fused_nano_adalomo_g2"
        );
    }
}
