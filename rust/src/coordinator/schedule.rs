//! Learning-rate schedules (paper setup: linear warmup over 3% of steps,
//! then cosine decay — Tables 3/6 and §4.3).

#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Decay {
    Constant,
    Cosine,
    Linear,
}

#[derive(Debug, Clone, Copy)]
pub struct Schedule {
    pub base_lr: f32,
    pub warmup_steps: usize,
    pub total_steps: usize,
    pub decay: Decay,
    /// Final LR as a fraction of base (cosine floor).
    pub min_factor: f32,
}

impl Schedule {
    pub fn cosine(base_lr: f32, warmup_steps: usize, total_steps: usize) -> Self {
        Schedule {
            base_lr,
            warmup_steps,
            total_steps,
            decay: Decay::Cosine,
            min_factor: 0.1,
        }
    }

    pub fn constant(base_lr: f32) -> Self {
        Schedule {
            base_lr,
            warmup_steps: 0,
            total_steps: 1,
            decay: Decay::Constant,
            min_factor: 1.0,
        }
    }

    /// LR at 1-based step t.
    pub fn lr_at(&self, t: usize) -> f32 {
        debug_assert!(t >= 1);
        if self.warmup_steps > 0 && t <= self.warmup_steps {
            return self.base_lr * t as f32 / self.warmup_steps as f32;
        }
        let total = self.total_steps.max(t);
        let progress = (t - self.warmup_steps) as f32
            / (total - self.warmup_steps).max(1) as f32;
        let factor = match self.decay {
            Decay::Constant => 1.0,
            Decay::Linear => 1.0 - (1.0 - self.min_factor) * progress,
            Decay::Cosine => {
                let cos =
                    0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
                self.min_factor + (1.0 - self.min_factor) * cos
            }
        };
        self.base_lr * factor
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_ramps_linearly() {
        let s = Schedule::cosine(1.0, 10, 100);
        assert!((s.lr_at(1) - 0.1).abs() < 1e-6);
        assert!((s.lr_at(5) - 0.5).abs() < 1e-6);
        assert!((s.lr_at(10) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = Schedule::cosine(1.0, 10, 100);
        assert!(s.lr_at(11) > s.lr_at(50));
        assert!(s.lr_at(50) > s.lr_at(100));
        assert!((s.lr_at(100) - 0.1).abs() < 1e-3);
    }

    #[test]
    fn constant_is_constant() {
        let s = Schedule::constant(0.5);
        assert_eq!(s.lr_at(1), 0.5);
        assert_eq!(s.lr_at(1000), 0.5);
    }

    #[test]
    fn monotone_decrease_after_warmup() {
        let s = Schedule::cosine(3e-4, 3, 50);
        let mut prev = f32::INFINITY;
        for t in 4..=50 {
            let lr = s.lr_at(t);
            assert!(lr <= prev + 1e-9, "t={t}");
            prev = lr;
        }
    }
}
