//! Ring-collective cost model (the NVLink fabric substitute) and the
//! wire-compression ladder.
//!
//! Standard alpha-beta model on a ring of `n` ranks: each of the (n-1)
//! steps moves `bytes/n` per rank, so
//! `time = (n-1) * (alpha + bytes / (n * bw))`.
//! All-reduce = reduce-scatter + all-gather. Used by the throughput report
//! and by the worker pool to model what real NCCL collectives would cost
//! alongside the measured local step times.
//!
//! [`WireCodec`] is the second half of this module: the encoding bucket
//! payloads ride the wire in during the engine's gradient exchange
//! (f32 identity | bf16 round-trip | blockwise int8 with error
//! feedback). The codec decides both the *bytes* a tile costs on the
//! fabric model and the *values* the leader's f32 reduction tree sees —
//! docs/EXCHANGE.md specifies the format per rung.

use anyhow::{bail, Result};

use crate::tensor::{bf16_to_f32, f32_to_bf16, Dtype};

#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    /// Per-hop latency, seconds.
    pub alpha: f64,
    /// Per-link bandwidth, bytes/second.
    pub bw: f64,
}

/// Wire bytes of one element at `dtype` — THE single definition every
/// fabric-cost caller derives payload sizes from (the engine's per-tile
/// costs, [`crate::coordinator::pipeline::adaptive_bucket_elems`]'s
/// bandwidth term). Hard-coding 4-byte elements anywhere else is a bug:
/// bf16 exchanges ship half the bytes, and bucket sizing must see that.
pub fn elem_bytes(dtype: Dtype) -> f64 {
    dtype.bytes() as f64
}

/// Wire bytes of an `elems`-element payload at `dtype` (the form the
/// engine feeds [`allreduce_bucket_time`]).
pub fn wire_bytes(elems: usize, dtype: Dtype) -> f64 {
    elems as f64 * elem_bytes(dtype)
}

impl Default for Fabric {
    fn default() -> Self {
        // NVLink-class: ~8 µs hop latency, 170 GB/s effective per link.
        Fabric { alpha: 8e-6, bw: 170e9 }
    }
}

/// Elements per q8 quantization block. Each block ships one f32 scale
/// next to its `Q8_BLOCK` signed bytes, so the q8 wire cost is
/// `1 + 4/64 = 1.0625` bytes/element — documented as the block-size pin
/// in docs/EXCHANGE.md (the analysis pass cross-checks the two).
pub const Q8_BLOCK: usize = 64;

/// One rung of the wire-compression ladder: how a bucket payload is
/// encoded for the exchange, independent of the *storage* dtype the
/// parameters and optimizer state live at.
///
/// The engine round-trips every received per-rank chunk through the
/// codec (encode + immediate decode — the host mirror never keeps the
/// encoded form) and then reduces the decoded values in an f32 tree in
/// rank order, so the reduction stays deterministic at every rung.
///
/// ```
/// use adalomo::coordinator::collective::WireCodec;
///
/// // 128 q8 elements = 128 payload bytes + 2 block scales of 4 bytes.
/// assert_eq!(WireCodec::Q8Block.payload_bytes(128), 128 + 8);
/// assert_eq!(WireCodec::F32.payload_bytes(128), 512);
/// assert_eq!(WireCodec::parse("bf16").unwrap(), WireCodec::Bf16);
/// ```
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireCodec {
    /// Raw IEEE-754 f32 — the identity rung. Bitwise-identical to the
    /// pre-ladder exchange (no value ever changes).
    F32,
    /// Element-wise bf16 round-trip (round-to-nearest-even on encode,
    /// exact widening on decode). Tiling-independent, so cross-plan
    /// bitwise parity at a fixed wire dtype is preserved.
    Bf16,
    /// Blockwise int8: each [`Q8_BLOCK`]-element block is scaled by
    /// `max|x| / 127` and rounded to a signed byte, with per-rank
    /// error-feedback residuals re-injecting the quantization error
    /// into that rank's next bucket.
    Q8Block,
}

impl WireCodec {
    /// Short rung name (`f32` | `bf16` | `q8`) — the `--wire` CLI
    /// vocabulary, the bench-metric suffix, and [`parse`](Self::parse)'s
    /// inverse.
    pub fn name(self) -> &'static str {
        match self {
            WireCodec::F32 => "f32",
            WireCodec::Bf16 => "bf16",
            WireCodec::Q8Block => "q8",
        }
    }

    /// Parse a rung name as printed by [`name`](Self::name).
    pub fn parse(s: &str) -> Result<WireCodec> {
        match s {
            "f32" => Ok(WireCodec::F32),
            "bf16" => Ok(WireCodec::Bf16),
            "q8" => Ok(WireCodec::Q8Block),
            other => bail!("unknown wire codec {other:?} (f32|bf16|q8)"),
        }
    }

    /// The rung a plan defaults to when none is chosen explicitly: the
    /// wire follows the storage dtype (bf16 storage already shipped
    /// bf16-sized buckets before the ladder existed; q8 is always an
    /// explicit opt-in).
    pub fn default_for(dtype: Dtype) -> WireCodec {
        match dtype {
            Dtype::F32 => WireCodec::F32,
            Dtype::Bf16 => WireCodec::Bf16,
        }
    }

    /// Average wire bytes per element (fractional for q8, whose scale
    /// overhead amortizes over each block) — what
    /// [`crate::coordinator::pipeline::adaptive_bucket_elems`] feeds the
    /// fabric bandwidth term, so compressed rungs pick finer buckets.
    pub fn elem_bytes(self) -> f64 {
        match self {
            WireCodec::F32 => 4.0,
            WireCodec::Bf16 => 2.0,
            WireCodec::Q8Block => 1.0 + 4.0 / Q8_BLOCK as f64,
        }
    }

    /// Exact wire bytes of an `elems`-element payload (q8 includes one
    /// 4-byte scale per started block) — the integer form
    /// `EngineReport::{comm_bytes_per_step,peak_comm_bytes}` pins in the
    /// bench gate.
    pub fn payload_bytes(self, elems: usize) -> usize {
        match self {
            WireCodec::F32 => 4 * elems,
            WireCodec::Bf16 => 2 * elems,
            WireCodec::Q8Block => elems + 4 * elems.div_ceil(Q8_BLOCK),
        }
    }

    /// Whether the rung keeps per-rank error-feedback accumulators
    /// (only q8 is lossy enough to need them; they are checkpointed in
    /// ADCP v3 so suspend/resume stays bit-exact).
    pub fn uses_error_feedback(self) -> bool {
        matches!(self, WireCodec::Q8Block)
    }

    /// Round-trip one received chunk through the codec in place.
    ///
    /// `residual` is the owning rank's error-feedback slice for the
    /// same parameter range; it must be the same length as `buf` when
    /// [`uses_error_feedback`](Self::uses_error_feedback) and is
    /// untouched (may be empty) otherwise. For q8 each block adds the
    /// carried residual *before* quantizing and stores the new
    /// quantization error back, so nothing is lost across buckets —
    /// only delayed. Block boundaries are chunk-relative, which makes
    /// the q8 rung tiling-dependent (same plan ⇒ same bits; different
    /// bucket sizes ⇒ different rounding), unlike the element-wise f32
    /// and bf16 rungs.
    pub fn encode_decode(self, buf: &mut [f32], residual: &mut [f32]) {
        match self {
            WireCodec::F32 => {}
            WireCodec::Bf16 => {
                for x in buf.iter_mut() {
                    *x = bf16_to_f32(f32_to_bf16(*x));
                }
            }
            WireCodec::Q8Block => {
                debug_assert_eq!(buf.len(), residual.len());
                for (block, res) in buf
                    .chunks_mut(Q8_BLOCK)
                    .zip(residual.chunks_mut(Q8_BLOCK))
                {
                    // Carried error re-enters the signal first, so the
                    // scale sees the corrected values.
                    for (x, r) in block.iter_mut().zip(res.iter()) {
                        *x += *r;
                    }
                    // Fixed-order max (fold, not a float reduction the
                    // determinism rule forbids): the scan order is the
                    // slice order, always.
                    let max_abs = block
                        .iter()
                        .fold(0.0f32, |m, &x| if x.abs() > m { x.abs() } else { m });
                    if max_abs == 0.0 || !max_abs.is_finite() {
                        // All-zero block ships zeros exactly; a
                        // non-finite block passes through undamaged
                        // (quantizing infinities would turn them into
                        // finite garbage).
                        for r in res.iter_mut() {
                            *r = 0.0;
                        }
                        continue;
                    }
                    let scale = max_abs / 127.0;
                    let inv = 127.0 / max_abs;
                    for (x, r) in block.iter_mut().zip(res.iter_mut()) {
                        let q = (*x * inv).round().clamp(-127.0, 127.0);
                        let deq = q * scale;
                        *r = *x - deq;
                        *x = deq;
                    }
                }
            }
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    AllGather,
    ReduceScatter,
    AllReduce,
    Broadcast,
}

pub fn time(op: Op, bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    if n_ranks <= 1 {
        return 0.0;
    }
    let n = n_ranks as f64;
    let ring = |b: f64| (n - 1.0) * (fabric.alpha + b / (n * fabric.bw));
    match op {
        Op::AllGather | Op::ReduceScatter => ring(bytes),
        Op::AllReduce => 2.0 * ring(bytes),
        // Pipelined ring broadcast ~= one all-gather of the full payload.
        Op::Broadcast => ring(bytes),
    }
}

/// Total collective time for one ZeRO-3 training step (params gathered for
/// fwd and bwd, gradients reduce-scattered).
pub fn zero3_step_time(param_bytes: f64, grad_bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    2.0 * time(Op::AllGather, param_bytes, n_ranks, fabric)
        + time(Op::ReduceScatter, grad_bytes, n_ranks, fabric)
}

/// Cost of reducing ONE `bucket_bytes` bucket of a larger all-reduce that
/// is executed bucket-by-bucket (the async pipeline's exchange grain).
/// Each bucket is a complete ring all-reduce of its own payload: the
/// bandwidth term covers only the bucket's bytes, but every bucket re-pays
/// the full `2(n-1)` hop latencies. That latency tax is why callers must
/// NOT approximate per-bucket cost by dividing `time(AllReduce, total)` by
/// the bucket count — the division drops the extra `alpha` terms entirely.
pub fn allreduce_bucket_time(bucket_bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    time(Op::AllReduce, bucket_bytes, n_ranks, fabric)
}

/// Two-level fabric: a fast ring *inside* each node and a slower ring
/// *between* node leaders. `ranks_per_node` ranks share one node; the
/// remaining cost parameters are ordinary [`Fabric`]s, so every flat
/// helper above keeps working on either level.
///
/// The model is a cost overlay only — it never changes what bytes mean
/// or what values the reduction tree sees, so it is deliberately NOT
/// part of [`crate::runtime::checkpoint::PlanRecord`]: resuming a
/// checkpoint under a different fabric spec is always bit-exact.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HierFabric {
    /// Intra-node ring (e.g. NVLink class).
    pub intra: Fabric,
    /// Inter-node ring over one leader per node (e.g. IB/ethernet class).
    pub inter: Fabric,
    /// Ranks sharing a node; the hierarchy collapses to a flat ring when
    /// this reaches the world size (all ranks on one node) or 1 (one
    /// rank per node).
    pub ranks_per_node: usize,
}

impl Default for HierFabric {
    fn default() -> Self {
        HierFabric {
            intra: Fabric::default(),
            // IB-class inter-node: ~15 µs hop latency, 25 GB/s per link.
            inter: Fabric { alpha: 15e-6, bw: 25e9 },
            ranks_per_node: 4,
        }
    }
}

/// A parsed `--fabric` CLI spec: either a flat single-ring fabric or a
/// hierarchical two-level one. Grammar (docs/FAULTS.md):
///
/// - `flat` | `flat:<alpha_s>:<bw_Bps>`
/// - `hier:<ranks_per_node>` |
///   `hier:<ranks_per_node>:<intra_alpha>:<intra_bw>:<inter_alpha>:<inter_bw>`
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FabricSpec {
    Flat(Fabric),
    Hier(HierFabric),
}

impl FabricSpec {
    /// Parse the `--fabric` grammar above. Short forms take the model
    /// defaults ([`Fabric::default`] / [`HierFabric::default`]).
    pub fn parse(s: &str) -> Result<FabricSpec> {
        let parts: Vec<&str> = s.split(':').collect();
        let num = |p: &str, what: &str| -> Result<f64> {
            let v: f64 = p
                .parse()
                .map_err(|_| anyhow::anyhow!("bad {what} {p:?} in fabric spec {s:?}"))?;
            if !v.is_finite() || v <= 0.0 {
                bail!("{what} must be finite and positive in fabric spec {s:?}, got {p:?}");
            }
            Ok(v)
        };
        match parts.as_slice() {
            ["flat"] => Ok(FabricSpec::Flat(Fabric::default())),
            ["flat", a, b] => Ok(FabricSpec::Flat(Fabric {
                alpha: num(a, "alpha")?,
                bw: num(b, "bandwidth")?,
            })),
            ["hier", m] | ["hier", m, ..] if parts.len() == 2 || parts.len() == 6 => {
                let ranks_per_node: usize = m.parse().map_err(|_| {
                    anyhow::anyhow!("bad ranks_per_node {m:?} in fabric spec {s:?}")
                })?;
                if ranks_per_node == 0 {
                    bail!("ranks_per_node must be >= 1 in fabric spec {s:?}");
                }
                let mut h = HierFabric { ranks_per_node, ..HierFabric::default() };
                if let ["hier", _, ia, ibw, ea, ebw] = parts.as_slice() {
                    h.intra = Fabric {
                        alpha: num(ia, "intra alpha")?,
                        bw: num(ibw, "intra bandwidth")?,
                    };
                    h.inter = Fabric {
                        alpha: num(ea, "inter alpha")?,
                        bw: num(ebw, "inter bandwidth")?,
                    };
                }
                Ok(FabricSpec::Hier(h))
            }
            _ => bail!(
                "unknown fabric spec {s:?} \
                 (flat | flat:<alpha>:<bw> | hier:<ranks_per_node>[:<intra_alpha>:<intra_bw>:<inter_alpha>:<inter_bw>])"
            ),
        }
    }

    /// The flat fabric the plan's serialized `(alpha, bw)` pair carries:
    /// the intra-node ring for hierarchical specs (checkpoint
    /// compatibility — the hierarchy itself is a runtime overlay).
    pub fn base(self) -> Fabric {
        match self {
            FabricSpec::Flat(f) => f,
            FabricSpec::Hier(h) => h.intra,
        }
    }

    /// The hierarchical overlay, when the spec is hierarchical.
    pub fn topology(self) -> Option<HierFabric> {
        match self {
            FabricSpec::Flat(_) => None,
            FabricSpec::Hier(h) => Some(h),
        }
    }
}

/// Hierarchical all-reduce cost of one bucket: intra-node reduce-scatter
/// (over the `m = ranks_per_node` ranks of each node, concurrently across
/// nodes), inter-node ring all-reduce over the `k = ceil(n/m)` node
/// leaders of the `bytes/m` shard each leader owns, then intra-node
/// all-gather. Degenerates to the flat ring on the matching level when
/// the hierarchy collapses (`m >= n` ⇒ pure intra, `m == 1` ⇒ pure
/// inter), so this is a strict generalization of
/// [`allreduce_bucket_time`].
pub fn hier_allreduce_bucket_time(bucket_bytes: f64, n_ranks: usize, h: HierFabric) -> f64 {
    if n_ranks <= 1 {
        return 0.0;
    }
    let m = h.ranks_per_node.max(1);
    if m >= n_ranks {
        return time(Op::AllReduce, bucket_bytes, n_ranks, h.intra);
    }
    if m == 1 {
        return time(Op::AllReduce, bucket_bytes, n_ranks, h.inter);
    }
    let nodes = n_ranks.div_ceil(m);
    let intra_phase = time(Op::ReduceScatter, bucket_bytes, m, h.intra);
    let inter_phase = time(Op::AllReduce, bucket_bytes / m as f64, nodes, h.inter);
    // reduce-scatter in + all-gather out cost the same ring pass.
    2.0 * intra_phase + inter_phase
}

/// Total bytes crossing *inter-node* links when a flat ring of `n_ranks`
/// (laid out `ranks_per_node` per node, ring order grouped by node) all-
/// reduces one `bytes` payload: the ring crosses a node boundary once per
/// node, and every link carries `2 (n-1) bytes / n`.
pub fn inter_node_bytes_flat(bytes: f64, n_ranks: usize, ranks_per_node: usize) -> f64 {
    let m = ranks_per_node.max(1);
    let nodes = n_ranks.div_ceil(m);
    if n_ranks <= 1 || nodes <= 1 {
        return 0.0;
    }
    let n = n_ranks as f64;
    nodes as f64 * 2.0 * (n - 1.0) * bytes / n
}

/// Total inter-node bytes of the hierarchical all-reduce of the same
/// payload: only the `k = ceil(n/m)` node leaders talk across nodes, each
/// link carrying `2 (k-1) (bytes/m) / k`, for `2 (k-1) bytes / m` across
/// all `k` links. The flat/hier ratio `k·m·(n−1) / (n·(k−1))` is the
/// exact `hier_allreduce_speedup` pin in the bench gate.
pub fn inter_node_bytes_hier(bytes: f64, n_ranks: usize, ranks_per_node: usize) -> f64 {
    let m = ranks_per_node.max(1);
    let nodes = n_ranks.div_ceil(m);
    if n_ranks <= 1 || nodes <= 1 {
        return 0.0;
    }
    2.0 * (nodes as f64 - 1.0) * bytes / m as f64
}

/// Per-bucket times for an all-reduce of `total_bytes` executed in
/// `bucket_bytes` grains (last bucket partial). The sum is what a bucketed
/// exchange pays end-to-end; each element is the grain the pipeline can
/// hide behind optimizer compute.
pub fn bucketed_allreduce_times(
    total_bytes: f64,
    bucket_bytes: f64,
    n_ranks: usize,
    fabric: Fabric,
) -> Vec<f64> {
    assert!(bucket_bytes > 0.0, "bucket_bytes must be positive");
    let n = (total_bytes / bucket_bytes).ceil().max(0.0) as usize;
    (0..n)
        .map(|i| {
            let lo = i as f64 * bucket_bytes;
            let b = (total_bytes - lo).min(bucket_bytes);
            allreduce_bucket_time(b, n_ranks, fabric)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(time(Op::AllReduce, 1e9, 1, Fabric::default()), 0.0);
    }

    #[test]
    fn allreduce_is_double_allgather() {
        let f = Fabric::default();
        let ag = time(Op::AllGather, 1e9, 8, f);
        let ar = time(Op::AllReduce, 1e9, 8, f);
        assert!((ar - 2.0 * ag).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_term_saturates_with_ranks() {
        // For large payloads, ring time tends to bytes/bw regardless of n.
        let f = Fabric { alpha: 0.0, bw: 100e9 };
        let t4 = time(Op::AllGather, 1e10, 4, f);
        let t32 = time(Op::AllGather, 1e10, 32, f);
        assert!((t4 - 0.075).abs() < 1e-3);
        assert!((t32 - 0.0969).abs() < 1e-3);
        assert!(t32 < 0.1 / 100e9 * 1e12); // bounded by bytes/bw
    }

    #[test]
    fn latency_term_grows_with_ranks() {
        let f = Fabric { alpha: 1e-5, bw: 1e30 };
        assert!(
            time(Op::AllGather, 8.0, 32, f)
                > time(Op::AllGather, 8.0, 4, f)
        );
    }

    #[test]
    fn bucketed_allreduce_pays_latency_per_bucket() {
        let f = Fabric::default();
        let total = 64e6;
        let times = bucketed_allreduce_times(total, 8e6, 8, f);
        assert_eq!(times.len(), 8);
        let sum: f64 = times.iter().sum();
        let mono = time(Op::AllReduce, total, 8, f);
        // Bucketing never beats the monolithic exchange on raw fabric
        // time: the bandwidth terms are identical, the latency terms
        // multiply by the bucket count.
        assert!(sum > mono, "{sum} vs {mono}");
        let extra_alpha = 7.0 * 2.0 * (8.0 - 1.0) * f.alpha;
        assert!((sum - mono - extra_alpha).abs() < 1e-12);
        // One bucket >= total degenerates to the monolithic cost.
        let one = bucketed_allreduce_times(total, total, 8, f);
        assert_eq!(one.len(), 1);
        assert!((one[0] - mono).abs() < 1e-15);
        // A partial last bucket is costed by its own bytes.
        let ragged = bucketed_allreduce_times(10e6, 4e6, 4, f);
        assert_eq!(ragged.len(), 3);
        assert!((ragged[2] - allreduce_bucket_time(2e6, 4, f)).abs() < 1e-15);
        // Single rank: every bucket is free, like the monolithic op.
        assert!(bucketed_allreduce_times(1e6, 1e5, 1, f)
            .iter()
            .all(|&t| t == 0.0));
    }

    #[test]
    fn wire_bytes_tracks_the_dtype() {
        assert_eq!(elem_bytes(Dtype::F32), 4.0);
        assert_eq!(elem_bytes(Dtype::Bf16), 2.0);
        assert_eq!(wire_bytes(100, Dtype::F32), 400.0);
        assert_eq!(wire_bytes(100, Dtype::Bf16), 200.0);
        // A bf16 bucket of the same element count costs what an f32
        // bucket of half the elements costs: the bandwidth term is pure
        // bytes, the latency term is payload-independent.
        let f = Fabric::default();
        for n_ranks in [2usize, 4, 8] {
            let b16 =
                allreduce_bucket_time(wire_bytes(4096, Dtype::Bf16), n_ranks, f);
            let f32_half =
                allreduce_bucket_time(wire_bytes(2048, Dtype::F32), n_ranks, f);
            assert_eq!(b16, f32_half);
            let f32_full =
                allreduce_bucket_time(wire_bytes(4096, Dtype::F32), n_ranks, f);
            assert!(b16 < f32_full);
        }
    }

    #[test]
    fn zero3_composition() {
        let f = Fabric::default();
        let t = zero3_step_time(2e9, 2e9, 8, f);
        let expect = 2.0 * time(Op::AllGather, 2e9, 8, f)
            + time(Op::ReduceScatter, 2e9, 8, f);
        assert_eq!(t, expect);
    }

    #[test]
    fn codec_names_round_trip() {
        for w in [WireCodec::F32, WireCodec::Bf16, WireCodec::Q8Block] {
            assert_eq!(WireCodec::parse(w.name()).unwrap(), w);
        }
        assert!(WireCodec::parse("int4").is_err());
        assert_eq!(WireCodec::default_for(Dtype::F32), WireCodec::F32);
        assert_eq!(WireCodec::default_for(Dtype::Bf16), WireCodec::Bf16);
        assert!(WireCodec::Q8Block.uses_error_feedback());
        assert!(!WireCodec::F32.uses_error_feedback());
        assert!(!WireCodec::Bf16.uses_error_feedback());
    }

    #[test]
    fn codec_payload_bytes_are_exact() {
        assert_eq!(WireCodec::F32.payload_bytes(100), 400);
        assert_eq!(WireCodec::Bf16.payload_bytes(100), 200);
        // 100 elems = 2 started blocks of 64 -> 100 + 2 scales.
        assert_eq!(WireCodec::Q8Block.payload_bytes(100), 108);
        assert_eq!(WireCodec::Q8Block.payload_bytes(64), 64 + 4);
        assert_eq!(WireCodec::Q8Block.payload_bytes(65), 65 + 8);
        assert_eq!(WireCodec::Q8Block.payload_bytes(0), 0);
        // elem_bytes is the exact per-element cost at block multiples.
        for w in [WireCodec::F32, WireCodec::Bf16, WireCodec::Q8Block] {
            let elems = 4 * Q8_BLOCK;
            let exact = w.payload_bytes(elems) as f64;
            assert!((exact - w.elem_bytes() * elems as f64).abs() < 1e-9);
        }
    }

    #[test]
    fn f32_rung_is_the_identity() {
        let vals = [1.0f32, -0.3333, 1e-30, f32::MAX, -0.0];
        let mut buf = vals;
        WireCodec::F32.encode_decode(&mut buf, &mut []);
        for (a, b) in buf.iter().zip(vals.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    #[test]
    fn bf16_rung_matches_the_tensor_kernels() {
        let vals = [1.0f32, -0.3333, 2.5e-3, 1234.567, -7e-8];
        let mut buf = vals;
        WireCodec::Bf16.encode_decode(&mut buf, &mut []);
        for (a, b) in buf.iter().zip(vals.iter()) {
            assert_eq!(a.to_bits(), bf16_to_f32(f32_to_bf16(*b)).to_bits());
        }
    }

    #[test]
    fn q8_rung_bounds_per_block_error() {
        // Without error feedback carried in, each element's error is at
        // most half a quantization step = max|x| / 254 per block.
        let mut buf: Vec<f32> =
            (0..3 * Q8_BLOCK).map(|i| ((i * 37 % 200) as f32 - 100.0) * 0.01).collect();
        let orig = buf.clone();
        let mut res = vec![0.0f32; buf.len()];
        WireCodec::Q8Block.encode_decode(&mut buf, &mut res);
        for (block, (dec, src)) in
            orig.chunks(Q8_BLOCK).zip(buf.chunks(Q8_BLOCK)).enumerate()
        {
            let max_abs = orig[block * Q8_BLOCK..block * Q8_BLOCK + Q8_BLOCK]
                .iter()
                .fold(0.0f32, |m, &x| if x.abs() > m { x.abs() } else { m });
            for (d, s) in dec.iter().zip(src.iter()) {
                assert!((d - s).abs() <= max_abs / 254.0 + 1e-7);
            }
        }
        // The residual is exactly what the wire dropped.
        for ((d, s), r) in buf.iter().zip(orig.iter()).zip(res.iter()) {
            assert!((s - (d + r)).abs() < 1e-7);
        }
    }

    #[test]
    fn q8_error_feedback_reinjects_residuals() {
        // A constant signal too small to survive one quantization round
        // still gets through over repeated buckets: the residual
        // accumulates until it crosses a quantization step.
        let n = Q8_BLOCK;
        let mut res = vec![0.0f32; n];
        let mut shipped_sum = vec![0.0f32; n];
        let rounds = 100;
        for _ in 0..rounds {
            let mut buf = vec![0.003f32; n - 1];
            buf.push(1.0); // one big element sets the block scale
            WireCodec::Q8Block.encode_decode(&mut buf, &mut res);
            for (s, b) in shipped_sum.iter_mut().zip(buf.iter()) {
                *s += b;
            }
        }
        // 0.003 < half a step (1/254 of the scale-setting 1.0) so a
        // feedback-free codec would ship 0 forever; with EF the
        // long-run average converges to the true signal.
        let avg = shipped_sum[0] / rounds as f32;
        assert!((avg - 0.003).abs() < 1e-3, "EF average drifted: {avg}");
        // Zero blocks ship zeros and clear the residual.
        let mut z = vec![0.0f32; n];
        let mut zr = vec![0.5f32; n];
        WireCodec::Q8Block.encode_decode(&mut z, &mut zr);
        // (0 + 0.5 residual) is quantized against its own max: exact.
        assert!(z.iter().all(|&x| (x - 0.5).abs() < 1e-6));
        let mut truly_zero = vec![0.0f32; n];
        let mut no_res = vec![0.0f32; n];
        WireCodec::Q8Block.encode_decode(&mut truly_zero, &mut no_res);
        assert!(truly_zero.iter().all(|&x| x == 0.0));
        assert!(no_res.iter().all(|&x| x == 0.0));
    }

    #[test]
    fn q8_is_deterministic_per_chunk() {
        let mk = || -> (Vec<f32>, Vec<f32>) {
            let buf: Vec<f32> =
                (0..130).map(|i| (i as f32 * 0.7).sin_approx()).collect();
            (buf, vec![0.0; 130])
        };
        // Same input, same residuals -> identical bits.
        let (mut a, mut ra) = mk();
        let (mut b, mut rb) = mk();
        WireCodec::Q8Block.encode_decode(&mut a, &mut ra);
        WireCodec::Q8Block.encode_decode(&mut b, &mut rb);
        assert_eq!(
            a.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            ra.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            rb.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn hier_allreduce_degenerates_to_flat() {
        let h = HierFabric::default();
        // Single rank: free, like the flat model.
        assert_eq!(hier_allreduce_bucket_time(1e9, 1, h), 0.0);
        // All ranks on one node: exactly the intra flat ring.
        let one_node = HierFabric { ranks_per_node: 8, ..h };
        assert_eq!(
            hier_allreduce_bucket_time(1e8, 8, one_node),
            allreduce_bucket_time(1e8, 8, h.intra)
        );
        // One rank per node: exactly the inter flat ring.
        let leaders_only = HierFabric { ranks_per_node: 1, ..h };
        assert_eq!(
            hier_allreduce_bucket_time(1e8, 8, leaders_only),
            allreduce_bucket_time(1e8, 8, h.inter)
        );
    }

    #[test]
    fn hier_allreduce_beats_flat_on_a_slow_inter_ring() {
        // 8 ranks, 4 per node: the hierarchy moves 1/m of the payload
        // across the slow ring instead of the whole thing.
        let h = HierFabric::default();
        let hier = hier_allreduce_bucket_time(64e6, 8, h);
        let flat_over_inter = allreduce_bucket_time(64e6, 8, h.inter);
        assert!(hier < flat_over_inter, "{hier} vs {flat_over_inter}");
        // And it decomposes exactly into its three phases.
        let intra = time(Op::ReduceScatter, 64e6, 4, h.intra);
        let inter = time(Op::AllReduce, 64e6 / 4.0, 2, h.inter);
        assert!((hier - (2.0 * intra + inter)).abs() < 1e-15);
    }

    #[test]
    fn inter_node_byte_ratio_is_exact() {
        // n=8 ranks, m=4 per node, k=2 nodes: flat crosses node
        // boundaries with 2·(n−1)/n of the payload per link over k links;
        // hier ships 2·(k−1)/m. Ratio = k·m·(n−1)/(n·(k−1)) = 7 exactly —
        // the bench gate's `hier_allreduce_speedup` pin.
        let bytes = 1 << 20;
        let flat = inter_node_bytes_flat(bytes as f64, 8, 4);
        let hier = inter_node_bytes_hier(bytes as f64, 8, 4);
        assert_eq!(flat / hier, 7.0);
        // Single node: no inter-node traffic on either path.
        assert_eq!(inter_node_bytes_flat(1e6, 4, 4), 0.0);
        assert_eq!(inter_node_bytes_hier(1e6, 4, 4), 0.0);
        assert_eq!(inter_node_bytes_flat(1e6, 1, 1), 0.0);
    }

    #[test]
    fn fabric_spec_parses_the_cli_grammar() {
        assert_eq!(
            FabricSpec::parse("flat").unwrap(),
            FabricSpec::Flat(Fabric::default())
        );
        match FabricSpec::parse("flat:1e-6:200e9").unwrap() {
            FabricSpec::Flat(f) => {
                assert_eq!(f.alpha, 1e-6);
                assert_eq!(f.bw, 200e9);
            }
            other => panic!("expected flat, got {other:?}"),
        }
        let h = match FabricSpec::parse("hier:4").unwrap() {
            FabricSpec::Hier(h) => h,
            other => panic!("expected hier, got {other:?}"),
        };
        assert_eq!(h.ranks_per_node, 4);
        assert_eq!(h.intra.bw, Fabric::default().bw);
        let full = FabricSpec::parse("hier:2:1e-6:100e9:2e-5:10e9").unwrap();
        match full {
            FabricSpec::Hier(h) => {
                assert_eq!(h.ranks_per_node, 2);
                assert_eq!(h.intra.alpha, 1e-6);
                assert_eq!(h.inter.bw, 10e9);
                assert_eq!(full.base().alpha, 1e-6);
                assert_eq!(full.topology(), Some(h));
            }
            other => panic!("expected hier, got {other:?}"),
        }
        assert_eq!(FabricSpec::parse("flat").unwrap().topology(), None);
        for bad in [
            "mesh", "flat:1e-6", "hier", "hier:0", "hier:4:1:2:3",
            "hier:4:-1:2:3:4", "flat:nan:1e9", "flat:0:1e9",
        ] {
            assert!(FabricSpec::parse(bad).is_err(), "{bad} should fail");
        }
    }

    /// Cheap deterministic pseudo-sine for test data (no libm calls in
    /// the test vectors keeps the expected values platform-pinned).
    trait SinApprox {
        fn sin_approx(self) -> f32;
    }
    impl SinApprox for f32 {
        fn sin_approx(self) -> f32 {
            let x = self - (self / 6.2832).floor() * 6.2832 - 3.1416;
            x * (1.0 - x.abs() / 3.1416) * 1.2732
        }
    }
}
