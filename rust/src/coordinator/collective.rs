//! Ring-collective cost model (the NVLink fabric substitute).
//!
//! Standard alpha-beta model on a ring of `n` ranks: each of the (n-1)
//! steps moves `bytes/n` per rank, so
//! `time = (n-1) * (alpha + bytes / (n * bw))`.
//! All-reduce = reduce-scatter + all-gather. Used by the throughput report
//! and by the worker pool to model what real NCCL collectives would cost
//! alongside the measured local step times.

use crate::tensor::Dtype;

#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    /// Per-hop latency, seconds.
    pub alpha: f64,
    /// Per-link bandwidth, bytes/second.
    pub bw: f64,
}

/// Wire bytes of one element at `dtype` — THE single definition every
/// fabric-cost caller derives payload sizes from (the engine's per-tile
/// costs, [`crate::coordinator::pipeline::adaptive_bucket_elems`]'s
/// bandwidth term). Hard-coding 4-byte elements anywhere else is a bug:
/// bf16 exchanges ship half the bytes, and bucket sizing must see that.
pub fn elem_bytes(dtype: Dtype) -> f64 {
    dtype.bytes() as f64
}

/// Wire bytes of an `elems`-element payload at `dtype` (the form the
/// engine feeds [`allreduce_bucket_time`]).
pub fn wire_bytes(elems: usize, dtype: Dtype) -> f64 {
    elems as f64 * elem_bytes(dtype)
}

impl Default for Fabric {
    fn default() -> Self {
        // NVLink-class: ~8 µs hop latency, 170 GB/s effective per link.
        Fabric { alpha: 8e-6, bw: 170e9 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    AllGather,
    ReduceScatter,
    AllReduce,
    Broadcast,
}

pub fn time(op: Op, bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    if n_ranks <= 1 {
        return 0.0;
    }
    let n = n_ranks as f64;
    let ring = |b: f64| (n - 1.0) * (fabric.alpha + b / (n * fabric.bw));
    match op {
        Op::AllGather | Op::ReduceScatter => ring(bytes),
        Op::AllReduce => 2.0 * ring(bytes),
        // Pipelined ring broadcast ~= one all-gather of the full payload.
        Op::Broadcast => ring(bytes),
    }
}

/// Total collective time for one ZeRO-3 training step (params gathered for
/// fwd and bwd, gradients reduce-scattered).
pub fn zero3_step_time(param_bytes: f64, grad_bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    2.0 * time(Op::AllGather, param_bytes, n_ranks, fabric)
        + time(Op::ReduceScatter, grad_bytes, n_ranks, fabric)
}

/// Cost of reducing ONE `bucket_bytes` bucket of a larger all-reduce that
/// is executed bucket-by-bucket (the async pipeline's exchange grain).
/// Each bucket is a complete ring all-reduce of its own payload: the
/// bandwidth term covers only the bucket's bytes, but every bucket re-pays
/// the full `2(n-1)` hop latencies. That latency tax is why callers must
/// NOT approximate per-bucket cost by dividing `time(AllReduce, total)` by
/// the bucket count — the division drops the extra `alpha` terms entirely.
pub fn allreduce_bucket_time(bucket_bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    time(Op::AllReduce, bucket_bytes, n_ranks, fabric)
}

/// Per-bucket times for an all-reduce of `total_bytes` executed in
/// `bucket_bytes` grains (last bucket partial). The sum is what a bucketed
/// exchange pays end-to-end; each element is the grain the pipeline can
/// hide behind optimizer compute.
pub fn bucketed_allreduce_times(
    total_bytes: f64,
    bucket_bytes: f64,
    n_ranks: usize,
    fabric: Fabric,
) -> Vec<f64> {
    assert!(bucket_bytes > 0.0, "bucket_bytes must be positive");
    let n = (total_bytes / bucket_bytes).ceil().max(0.0) as usize;
    (0..n)
        .map(|i| {
            let lo = i as f64 * bucket_bytes;
            let b = (total_bytes - lo).min(bucket_bytes);
            allreduce_bucket_time(b, n_ranks, fabric)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(time(Op::AllReduce, 1e9, 1, Fabric::default()), 0.0);
    }

    #[test]
    fn allreduce_is_double_allgather() {
        let f = Fabric::default();
        let ag = time(Op::AllGather, 1e9, 8, f);
        let ar = time(Op::AllReduce, 1e9, 8, f);
        assert!((ar - 2.0 * ag).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_term_saturates_with_ranks() {
        // For large payloads, ring time tends to bytes/bw regardless of n.
        let f = Fabric { alpha: 0.0, bw: 100e9 };
        let t4 = time(Op::AllGather, 1e10, 4, f);
        let t32 = time(Op::AllGather, 1e10, 32, f);
        assert!((t4 - 0.075).abs() < 1e-3);
        assert!((t32 - 0.0969).abs() < 1e-3);
        assert!(t32 < 0.1 / 100e9 * 1e12); // bounded by bytes/bw
    }

    #[test]
    fn latency_term_grows_with_ranks() {
        let f = Fabric { alpha: 1e-5, bw: 1e30 };
        assert!(
            time(Op::AllGather, 8.0, 32, f)
                > time(Op::AllGather, 8.0, 4, f)
        );
    }

    #[test]
    fn bucketed_allreduce_pays_latency_per_bucket() {
        let f = Fabric::default();
        let total = 64e6;
        let times = bucketed_allreduce_times(total, 8e6, 8, f);
        assert_eq!(times.len(), 8);
        let sum: f64 = times.iter().sum();
        let mono = time(Op::AllReduce, total, 8, f);
        // Bucketing never beats the monolithic exchange on raw fabric
        // time: the bandwidth terms are identical, the latency terms
        // multiply by the bucket count.
        assert!(sum > mono, "{sum} vs {mono}");
        let extra_alpha = 7.0 * 2.0 * (8.0 - 1.0) * f.alpha;
        assert!((sum - mono - extra_alpha).abs() < 1e-12);
        // One bucket >= total degenerates to the monolithic cost.
        let one = bucketed_allreduce_times(total, total, 8, f);
        assert_eq!(one.len(), 1);
        assert!((one[0] - mono).abs() < 1e-15);
        // A partial last bucket is costed by its own bytes.
        let ragged = bucketed_allreduce_times(10e6, 4e6, 4, f);
        assert_eq!(ragged.len(), 3);
        assert!((ragged[2] - allreduce_bucket_time(2e6, 4, f)).abs() < 1e-15);
        // Single rank: every bucket is free, like the monolithic op.
        assert!(bucketed_allreduce_times(1e6, 1e5, 1, f)
            .iter()
            .all(|&t| t == 0.0));
    }

    #[test]
    fn wire_bytes_tracks_the_dtype() {
        assert_eq!(elem_bytes(Dtype::F32), 4.0);
        assert_eq!(elem_bytes(Dtype::Bf16), 2.0);
        assert_eq!(wire_bytes(100, Dtype::F32), 400.0);
        assert_eq!(wire_bytes(100, Dtype::Bf16), 200.0);
        // A bf16 bucket of the same element count costs what an f32
        // bucket of half the elements costs: the bandwidth term is pure
        // bytes, the latency term is payload-independent.
        let f = Fabric::default();
        for n_ranks in [2usize, 4, 8] {
            let b16 =
                allreduce_bucket_time(wire_bytes(4096, Dtype::Bf16), n_ranks, f);
            let f32_half =
                allreduce_bucket_time(wire_bytes(2048, Dtype::F32), n_ranks, f);
            assert_eq!(b16, f32_half);
            let f32_full =
                allreduce_bucket_time(wire_bytes(4096, Dtype::F32), n_ranks, f);
            assert!(b16 < f32_full);
        }
    }

    #[test]
    fn zero3_composition() {
        let f = Fabric::default();
        let t = zero3_step_time(2e9, 2e9, 8, f);
        let expect = 2.0 * time(Op::AllGather, 2e9, 8, f)
            + time(Op::ReduceScatter, 2e9, 8, f);
        assert_eq!(t, expect);
    }
}
