//! Ring-collective cost model (the NVLink fabric substitute).
//!
//! Standard alpha-beta model on a ring of `n` ranks: each of the (n-1)
//! steps moves `bytes/n` per rank, so
//! `time = (n-1) * (alpha + bytes / (n * bw))`.
//! All-reduce = reduce-scatter + all-gather. Used by the throughput report
//! and by the worker pool to model what real NCCL collectives would cost
//! alongside the measured local step times.

#[derive(Debug, Clone, Copy)]
pub struct Fabric {
    /// Per-hop latency, seconds.
    pub alpha: f64,
    /// Per-link bandwidth, bytes/second.
    pub bw: f64,
}

impl Default for Fabric {
    fn default() -> Self {
        // NVLink-class: ~8 µs hop latency, 170 GB/s effective per link.
        Fabric { alpha: 8e-6, bw: 170e9 }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Op {
    AllGather,
    ReduceScatter,
    AllReduce,
    Broadcast,
}

pub fn time(op: Op, bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    if n_ranks <= 1 {
        return 0.0;
    }
    let n = n_ranks as f64;
    let ring = |b: f64| (n - 1.0) * (fabric.alpha + b / (n * fabric.bw));
    match op {
        Op::AllGather | Op::ReduceScatter => ring(bytes),
        Op::AllReduce => 2.0 * ring(bytes),
        // Pipelined ring broadcast ~= one all-gather of the full payload.
        Op::Broadcast => ring(bytes),
    }
}

/// Total collective time for one ZeRO-3 training step (params gathered for
/// fwd and bwd, gradients reduce-scattered).
pub fn zero3_step_time(param_bytes: f64, grad_bytes: f64, n_ranks: usize, fabric: Fabric) -> f64 {
    2.0 * time(Op::AllGather, param_bytes, n_ranks, fabric)
        + time(Op::ReduceScatter, grad_bytes, n_ranks, fabric)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_rank_is_free() {
        assert_eq!(time(Op::AllReduce, 1e9, 1, Fabric::default()), 0.0);
    }

    #[test]
    fn allreduce_is_double_allgather() {
        let f = Fabric::default();
        let ag = time(Op::AllGather, 1e9, 8, f);
        let ar = time(Op::AllReduce, 1e9, 8, f);
        assert!((ar - 2.0 * ag).abs() < 1e-12);
    }

    #[test]
    fn bandwidth_term_saturates_with_ranks() {
        // For large payloads, ring time tends to bytes/bw regardless of n.
        let f = Fabric { alpha: 0.0, bw: 100e9 };
        let t4 = time(Op::AllGather, 1e10, 4, f);
        let t32 = time(Op::AllGather, 1e10, 32, f);
        assert!((t4 - 0.075).abs() < 1e-3);
        assert!((t32 - 0.0969).abs() < 1e-3);
        assert!(t32 < 0.1 / 100e9 * 1e12); // bounded by bytes/bw
    }

    #[test]
    fn latency_term_grows_with_ranks() {
        let f = Fabric { alpha: 1e-5, bw: 1e30 };
        assert!(
            time(Op::AllGather, 8.0, 32, f)
                > time(Op::AllGather, 8.0, 4, f)
        );
    }

    #[test]
    fn zero3_composition() {
        let f = Fabric::default();
        let t = zero3_step_time(2e9, 2e9, 8, f);
        let expect = 2.0 * time(Op::AllGather, 2e9, 8, f)
            + time(Op::ReduceScatter, 2e9, 8, f);
        assert_eq!(t, expect);
    }
}
