//! Group-granular fused-backward mirror on the host fast path.
//!
//! # Relation to `coordinator::fused`
//!
//! [`super::fused`] demonstrates LOMO/AdaLomo's liveness schedule (paper
//! §2.1) at *XLA-program granularity*: the step is split into G = L+2
//! chained group programs, each of which re-runs the whole forward so the
//! compiler dead-code-eliminates every other group's weight gradients.
//! That demonstrator needs AOT artifacts and pays G× compute — it proves
//! the schedule, it is not the fast path.
//!
//! This module is the *same schedule on the host engine*: a
//! [`GroupGradSource`] produces each fused-backward group's gradient
//! (head block, layers L-1..0, embedding — the grouping
//! [`FlatOptimizer::group_grad_sizes`] shares with
//! `fused::group_grad_sizes`), [`fused_host_step`] steps exactly that
//! group through [`FlatOptimizer::step_group`], and the gradient buffer
//! is freed *before* the next group is produced. Peak live-gradient bytes
//! are therefore **measured** (the largest group extent) rather than
//! assumed, and the integration tests pin them to the analytic prediction
//! of [`crate::memsim::liveness::simulate_grouped`] — the paper's memory
//! argument enforced by a test instead of narrated.
//!
//! Multi-step (and multi-rank) execution lives in the unified engine:
//! [`run_fused_host`] is a thin [`ExecPlan::fused_host`] constructor —
//! grouped-backward production, descending exchange, `step_group`
//! granularity — over the same leader loop every other path runs.
//! [`fused_host_step`] remains as the single-step, single-rank primitive
//! the benches and liveness tests drive directly.
//!
//! Because every task's update arithmetic is self-contained, the
//! group-by-group walk is bit-identical to one whole-image
//! [`FlatOptimizer::step`] with the same gradient values; the proptests
//! pin that, for all seven optimizers and both shard plans.
//!
//! [`FusedHostGrads`] is the deterministic stand-in backward: its values
//! depend only on (rank, step, group, position), never on production
//! order, so the same source can feed the grouped mirror, the grouped
//! async pipeline ([`super::pipeline::run_pipelined_fused`], which
//! overlaps the bucket exchange with group *production*), and the
//! full-image lockstep paths — and all of them must agree bitwise.

use std::time::Instant;

use anyhow::{ensure, Result};

use crate::optim::flat::{FlatOptimizer, ShardMode};
use crate::optim::OptKind;
use crate::runtime::Layout;
use crate::tensor::Dtype;
use crate::util::rng::Pcg32;

use super::collective::WireCodec;
use super::engine::{
    Engine, EngineReport, ExecPlan, GradProduction, RankSources,
};
use super::pipeline::{GradSource, PipelineConfig};

/// Per-rank *group-granular* gradient producer: the backward-order
/// counterpart of [`GradSource`], emitting one fused group at a time so a
/// consumer never needs the full gradient image.
///
/// `fill_group` must be deterministic in (source state, `step`, `g`) and
/// independent of the interleaving in which groups are requested — that
/// is what lets the grouped and full-image execution paths agree bitwise.
pub trait GroupGradSource: Send {
    /// Number of backward groups produced per step.
    fn n_groups(&self) -> usize;

    /// Blob extent `[lo, hi)` of group `g` (walk order: head block,
    /// layers L-1..0, embedding).
    fn group_extent(&self, g: usize) -> (usize, usize);

    /// Fill group `g`'s gradient for `step`; `out` covers exactly the
    /// group's extent.
    fn fill_group(&mut self, step: u64, g: usize, out: &mut [f32]);

    /// Advance past `step` without consuming it — how a resumed run
    /// fast-forwards a stream-stateful source to the checkpointed
    /// position. The default produces-and-discards every group into
    /// `scratch`; step-keyed sources override it with a no-op.
    fn skip_step(&mut self, step: u64, scratch: &mut Vec<f32>) {
        for g in 0..self.n_groups() {
            let (lo, hi) = self.group_extent(g);
            scratch.resize(hi - lo, 0.0);
            self.fill_group(step, g, &mut scratch[..hi - lo]);
        }
    }
}

/// Deterministic synthetic *grouped* gradients: each (rank, step, group)
/// triple seeds its own PRNG stream, so values depend only on the
/// position being filled — never on whether the caller materializes one
/// group at a time (the mirror, the grouped pipeline) or the whole image
/// (the lockstep reference, via the [`GradSource`] impl).
pub struct FusedHostGrads {
    seed: u64,
    rank: usize,
    scale: f32,
    /// Group extents in walk order; must tile the gradient image for the
    /// full-image `fill` to cover every slot.
    groups: Vec<(usize, usize)>,
}

impl FusedHostGrads {
    pub fn new(
        groups: Vec<(usize, usize)>,
        seed: u64,
        rank: usize,
        scale: f32,
    ) -> FusedHostGrads {
        FusedHostGrads { seed, rank, scale, groups }
    }

    /// One source per rank over `engine`'s fused-backward groups (same
    /// rank-seed spacing as the local-SGD workers' data streams).
    pub fn per_rank(
        engine: &FlatOptimizer,
        n_ranks: usize,
        seed: u64,
        scale: f32,
    ) -> Vec<FusedHostGrads> {
        (0..n_ranks)
            .map(|r| {
                FusedHostGrads::new(engine.group_extents(), seed, r, scale)
            })
            .collect()
    }

    /// [`Self::per_rank`] from raw group extents, pre-boxed for
    /// [`RankSources::Grouped`] — the shape the unified engine consumes.
    pub fn per_rank_extents(
        groups: Vec<(usize, usize)>,
        n_ranks: usize,
        seed: u64,
        scale: f32,
    ) -> Vec<Box<dyn GroupGradSource>> {
        (0..n_ranks)
            .map(|r| {
                Box::new(FusedHostGrads::new(groups.clone(), seed, r, scale))
                    as Box<dyn GroupGradSource>
            })
            .collect()
    }
}

impl GroupGradSource for FusedHostGrads {
    fn n_groups(&self) -> usize {
        self.groups.len()
    }

    fn group_extent(&self, g: usize) -> (usize, usize) {
        self.groups[g]
    }

    fn fill_group(&mut self, step: u64, g: usize, out: &mut [f32]) {
        let (lo, hi) = self.groups[g];
        debug_assert_eq!(out.len(), hi - lo);
        // Stream keyed by (rank, step); one PCG stream per group.
        let mut rng = Pcg32::new(
            self.seed
                .wrapping_add(1000 * self.rank as u64)
                .wrapping_add(step.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            g as u64,
        );
        for x in out.iter_mut() {
            *x = rng.normal() * self.scale;
        }
    }

    /// Values are keyed by (rank, step, group): skipping a step consumes
    /// no state.
    fn skip_step(&mut self, _step: u64, _scratch: &mut Vec<f32>) {}
}

/// The full-image view of the same values: fill every group's slice of
/// `out`. Lets the identical gradients drive [`super::pipeline`]'s
/// materialized paths for the bitwise comparisons.
impl GradSource for FusedHostGrads {
    fn fill(&mut self, step: u64, out: &mut [f32]) {
        for g in 0..self.groups.len() {
            let (lo, hi) = self.groups[g];
            self.fill_group(step, g, &mut out[lo..hi]);
        }
    }

    /// Step-keyed: nothing to fast-forward.
    fn skip(&mut self, _step: u64, _scratch: &mut [f32]) {}
}

/// The canonical host-mirror [`RankSources`] for `plan`: one
/// [`FusedHostGrads`] per rank over `groups`, seeded from `plan.seed`
/// and wrapped to match the plan's production axis. The CLI, the
/// suspend/resume tests and the examples all reconstruct their gradient
/// streams through THIS function, so a checkpointed plan is sufficient
/// to rebuild byte-identical sources everywhere (the step-keyed values
/// are the same whichever axis consumes them).
pub fn plan_sources(
    plan: &ExecPlan,
    groups: Vec<(usize, usize)>,
    scale: f32,
) -> RankSources {
    match plan.production {
        GradProduction::GroupedBackward => {
            RankSources::Grouped(FusedHostGrads::per_rank_extents(
                groups,
                plan.n_ranks,
                plan.seed,
                scale,
            ))
        }
        GradProduction::FullImage => RankSources::Full(
            (0..plan.n_ranks)
                .map(|r| {
                    Box::new(FusedHostGrads::new(
                        groups.clone(),
                        plan.seed,
                        r,
                        scale,
                    )) as Box<dyn GradSource>
                })
                .collect(),
        ),
    }
}

/// One fused-backward optimizer step, group by group: produce group g's
/// gradient into a buffer sized for its extent, step exactly that group,
/// and free the buffer before group g+1 is produced. Bit-identical to one
/// whole-image [`FlatOptimizer::step`] with the same gradient values.
/// This is the single-step primitive under [`run_fused_host`]'s engine
/// plan; the returned [`EngineReport`] carries the measured liveness
/// curve and peak.
pub fn fused_host_step(
    engine: &mut FlatOptimizer,
    blob: &mut [f32],
    src: &mut dyn GroupGradSource,
    t: u64,
    lr: f32,
    wd: f32,
) -> Result<EngineReport> {
    // ANALYZE-WAIVE(determinism): wall-clock report fields only
    let started = Instant::now();
    let extents = engine.group_extents();
    ensure!(
        src.n_groups() == extents.len(),
        "source has {} groups, engine {}",
        src.n_groups(),
        extents.len()
    );
    let mut curve = Vec::with_capacity(extents.len());
    let mut peak = 0usize;
    let mut compute = 0.0f64;
    for (g, &(lo, hi)) in extents.iter().enumerate() {
        ensure!(
            src.group_extent(g) == (lo, hi),
            "group {g}: source extent {:?} != engine extent {:?}",
            src.group_extent(g),
            (lo, hi)
        );
        // The step's ONLY gradient allocation: this group's extent. It is
        // dropped at the bottom of the loop, before the next group exists
        // — the measured embodiment of the §2.1 liveness claim.
        let mut gbuf = vec![0f32; hi - lo];
        src.fill_group(t, g, &mut gbuf);
        let live = 4 * gbuf.len();
        peak = peak.max(live);
        curve.push(live);
        // ANALYZE-WAIVE(determinism): compute-time report metric only
        let t0 = Instant::now();
        engine.step_group(blob, g, &gbuf, t, lr, wd)?;
        compute += t0.elapsed().as_secs_f64();
    }
    Ok(EngineReport {
        n_ranks: 1,
        steps: 1,
        n_buckets: extents.len(),
        n_groups: extents.len(),
        compute_secs: compute,
        comm_secs: 0.0,
        exposed_secs: compute,
        overlap_efficiency: 1.0,
        wall_secs: started.elapsed().as_secs_f64(),
        peak_live_grad_bytes: peak,
        full_grad_bytes: 4 * engine.params_len(),
        curve_bytes: curve,
        // The single-rank mirror primitive steps a raw f32 slice and
        // touches no fabric; the dtype/wire-aware numbers come from the
        // engine-driven paths.
        dtype: Dtype::F32,
        wire: WireCodec::F32,
        blob_bytes: 4 * blob.len(),
        comm_bytes_per_step: 0,
        peak_comm_bytes: 0,
        reassigned_tiles: 0,
    })
}

/// Drive the fused-backward host mirror for `cfg.steps` steps from
/// `blob0`: one rank source per entry in `sources`, each group extent
/// reduced (rank order) and stepped as its production lands. Thin wrapper
/// over [`ExecPlan::fused_host`] on the unified engine; returns the final
/// blob and the liveness/overlap report (`cfg.bucket_elems` is unused —
/// the tiling is one tile per fused group).
pub fn run_fused_host(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GroupGradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, EngineReport)> {
    ensure!(cfg.steps >= 1, "steps must be >= 1");
    let plan = ExecPlan::fused_host(kind, mode, sources.len(), cfg);
    let mut engine = Engine::new(layout, blob0, plan)?;
    let report = engine.run(RankSources::Grouped(sources))?;
    Ok((engine.into_blob(), report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::flat::{seeded_blob_and_grads, synthetic_layout};

    fn model_layout(kind: OptKind) -> crate::runtime::Layout {
        let params: Vec<(&str, &[usize])> = vec![
            ("embed", &[24, 8][..]),
            ("l0.attn_norm", &[8][..]),
            ("l0.wq", &[8, 8][..]),
            ("l0.w_down", &[10, 8][..]),
            ("l1.wq", &[8, 8][..]),
            ("final_norm", &[8][..]),
            ("head", &[8, 24][..]),
        ];
        synthetic_layout(kind, &params)
    }

    #[test]
    fn grouped_fill_is_order_independent() {
        let layout = model_layout(OptKind::AdaLomo);
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let mut a = FusedHostGrads::new(engine.group_extents(), 5, 1, 0.1);
        let mut b = FusedHostGrads::new(engine.group_extents(), 5, 1, 0.1);
        let mut full = vec![0f32; layout.params_len];
        GradSource::fill(&mut a, 3, &mut full);
        // Filling the groups individually, in REVERSE walk order, must
        // reproduce the same image.
        let mut pieces = vec![0f32; layout.params_len];
        for g in (0..b.n_groups()).rev() {
            let (lo, hi) = b.group_extent(g);
            b.fill_group(3, g, &mut pieces[lo..hi]);
        }
        assert_eq!(full, pieces);
        // Distinct ranks and steps draw distinct streams.
        let mut c = FusedHostGrads::new(engine.group_extents(), 5, 2, 0.1);
        let mut other = vec![0f32; layout.params_len];
        GradSource::fill(&mut c, 3, &mut other);
        assert_ne!(full, other);
        GradSource::fill(&mut a, 4, &mut other);
        assert_ne!(full, other);
    }

    #[test]
    fn mirror_matches_monolithic_step_bitwise() {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let layout = model_layout(OptKind::AdaLomo);
            let (blob0, _) = seeded_blob_and_grads(&layout, 13);
            let probe =
                FlatOptimizer::new(OptKind::AdaLomo, &layout, 3, mode)
                    .unwrap();
            let mut cfg = PipelineConfig::new(3, 1);
            cfg.n_shards = 3;
            let sources = FusedHostGrads::per_rank_extents(
                probe.group_extents(),
                1,
                7,
                0.05,
            );
            let (mirror, report) = run_fused_host(
                &layout,
                OptKind::AdaLomo,
                mode,
                &blob0,
                sources,
                &cfg,
            )
            .unwrap();
            // Reference: whole-image steps with the identical gradients.
            let mut engine2 = FlatOptimizer::new(
                OptKind::AdaLomo,
                &layout,
                3,
                mode,
            )
            .unwrap();
            let mut src2 =
                FusedHostGrads::new(engine2.group_extents(), 7, 0, 0.05);
            let mut full = blob0.clone();
            let mut grad = vec![0f32; layout.params_len];
            for t in 1..=3u64 {
                GradSource::fill(&mut src2, t, &mut grad);
                engine2.step(&mut full, &grad, t, 1e-2, 0.0).unwrap();
            }
            for (i, (a, b)) in mirror.iter().zip(&full).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{mode:?} elem {i}: {a} vs {b}"
                );
            }
            // Liveness: peak is the largest group, strictly below the
            // full image.
            assert_eq!(report.n_groups, 4);
            assert_eq!(
                report.peak_live_grad_bytes,
                4 * probe.group_grad_sizes().iter().max().copied().unwrap()
            );
            assert!(
                report.peak_live_grad_bytes < report.full_grad_bytes,
                "{report:?}"
            );
            // The per-group tiling is the report's bucket count, and the
            // liveness curve matches the walk-order group sizes.
            assert_eq!(report.n_buckets, 4);
            assert_eq!(
                report.curve_bytes,
                probe
                    .group_grad_sizes()
                    .iter()
                    .map(|&e| 4 * e)
                    .collect::<Vec<_>>()
            );
        }
    }

    #[test]
    fn mismatched_source_is_rejected() {
        let layout = model_layout(OptKind::AdaLomo);
        let (mut blob, _) = seeded_blob_and_grads(&layout, 3);
        let mut engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        // Wrong group count.
        let mut short = FusedHostGrads::new(
            engine.group_extents()[..2].to_vec(),
            1,
            0,
            0.1,
        );
        assert!(
            fused_host_step(&mut engine, &mut blob, &mut short, 1, 1e-2, 0.0)
                .is_err()
        );
        // Right count, shifted extents.
        let shifted: Vec<(usize, usize)> = engine
            .group_extents()
            .iter()
            .map(|&(lo, hi)| (lo.saturating_sub(1), hi.saturating_sub(1)))
            .collect();
        let mut bad = FusedHostGrads::new(shifted, 1, 0, 0.1);
        assert!(
            fused_host_step(&mut engine, &mut blob, &mut bad, 1, 1e-2, 0.0)
                .is_err()
        );
        // The engine wrapper rejects them too.
        let cfg = PipelineConfig::new(1, 1);
        let bad_sources = FusedHostGrads::per_rank_extents(
            engine.group_extents()[..2].to_vec(),
            1,
            1,
            0.1,
        );
        assert!(run_fused_host(
            &layout,
            OptKind::AdaLomo,
            ShardMode::Segments,
            &blob,
            bad_sources,
            &cfg,
        )
        .is_err());
    }
}
