//! Group-granular fused-backward mirror on the host fast path.
//!
//! # Relation to `coordinator::fused`
//!
//! [`super::fused`] demonstrates LOMO/AdaLomo's liveness schedule (paper
//! §2.1) at *XLA-program granularity*: the step is split into G = L+2
//! chained group programs, each of which re-runs the whole forward so the
//! compiler dead-code-eliminates every other group's weight gradients.
//! That demonstrator needs AOT artifacts and pays G× compute — it proves
//! the schedule, it is not the fast path.
//!
//! This module is the *same schedule on the host engine*: a
//! [`GroupGradSource`] produces each fused-backward group's gradient
//! (head block, layers L-1..0, embedding — the grouping
//! [`FlatOptimizer::group_grad_sizes`] shares with
//! `fused::group_grad_sizes`), [`fused_host_step`] steps exactly that
//! group through the task-subset machinery the async pipeline already
//! uses ([`FlatOptimizer::step_group`]), and the gradient buffer is freed
//! *before* the next group is produced. Peak live-gradient bytes are
//! therefore **measured** (the largest group extent) rather than assumed,
//! and the integration tests pin them to the analytic prediction of
//! [`crate::memsim::liveness::simulate_grouped`] — the paper's memory
//! argument enforced by a test instead of narrated.
//!
//! Because every task's update arithmetic is self-contained, the
//! group-by-group walk is bit-identical to one whole-image
//! [`FlatOptimizer::step`] with the same gradient values; the proptests
//! pin that, for all seven optimizers and both shard plans.
//!
//! [`FusedHostGrads`] is the deterministic stand-in backward: its values
//! depend only on (rank, step, group, position), never on production
//! order, so the same source can feed the grouped mirror, the grouped
//! async pipeline ([`super::pipeline::run_pipelined_fused`], which
//! overlaps the bucket exchange with group *production*), and the
//! full-image lockstep paths — and all of them must agree bitwise.

use anyhow::{ensure, Result};

use crate::optim::flat::FlatOptimizer;
use crate::util::rng::Pcg32;

use super::pipeline::GradSource;

/// Per-rank *group-granular* gradient producer: the backward-order
/// counterpart of [`GradSource`], emitting one fused group at a time so a
/// consumer never needs the full gradient image.
///
/// `fill_group` must be deterministic in (source state, `step`, `g`) and
/// independent of the interleaving in which groups are requested — that
/// is what lets the grouped and full-image execution paths agree bitwise.
pub trait GroupGradSource: Send {
    /// Number of backward groups produced per step.
    fn n_groups(&self) -> usize;

    /// Blob extent `[lo, hi)` of group `g` (walk order: head block,
    /// layers L-1..0, embedding).
    fn group_extent(&self, g: usize) -> (usize, usize);

    /// Fill group `g`'s gradient for `step`; `out` covers exactly the
    /// group's extent.
    fn fill_group(&mut self, step: u64, g: usize, out: &mut [f32]);
}

/// Deterministic synthetic *grouped* gradients: each (rank, step, group)
/// triple seeds its own PRNG stream, so values depend only on the
/// position being filled — never on whether the caller materializes one
/// group at a time (the mirror, the grouped pipeline) or the whole image
/// (the lockstep reference, via the [`GradSource`] impl).
pub struct FusedHostGrads {
    seed: u64,
    rank: usize,
    scale: f32,
    /// Group extents in walk order; must tile the gradient image for the
    /// full-image `fill` to cover every slot.
    groups: Vec<(usize, usize)>,
}

impl FusedHostGrads {
    pub fn new(
        groups: Vec<(usize, usize)>,
        seed: u64,
        rank: usize,
        scale: f32,
    ) -> FusedHostGrads {
        FusedHostGrads { seed, rank, scale, groups }
    }

    /// One source per rank over `engine`'s fused-backward groups (same
    /// rank-seed spacing as the local-SGD workers' data streams).
    pub fn per_rank(
        engine: &FlatOptimizer,
        n_ranks: usize,
        seed: u64,
        scale: f32,
    ) -> Vec<FusedHostGrads> {
        (0..n_ranks)
            .map(|r| {
                FusedHostGrads::new(engine.group_extents(), seed, r, scale)
            })
            .collect()
    }
}

impl GroupGradSource for FusedHostGrads {
    fn n_groups(&self) -> usize {
        self.groups.len()
    }

    fn group_extent(&self, g: usize) -> (usize, usize) {
        self.groups[g]
    }

    fn fill_group(&mut self, step: u64, g: usize, out: &mut [f32]) {
        let (lo, hi) = self.groups[g];
        debug_assert_eq!(out.len(), hi - lo);
        // Stream keyed by (rank, step); one PCG stream per group.
        let mut rng = Pcg32::new(
            self.seed
                .wrapping_add(1000 * self.rank as u64)
                .wrapping_add(step.wrapping_mul(0x9E37_79B9_7F4A_7C15)),
            g as u64,
        );
        for x in out.iter_mut() {
            *x = rng.normal() * self.scale;
        }
    }
}

/// The full-image view of the same values: fill every group's slice of
/// `out`. Lets the identical gradients drive [`super::pipeline`]'s
/// materialized paths for the bitwise comparisons.
impl GradSource for FusedHostGrads {
    fn fill(&mut self, step: u64, out: &mut [f32]) {
        for g in 0..self.groups.len() {
            let (lo, hi) = self.groups[g];
            self.fill_group(step, g, &mut out[lo..hi]);
        }
    }
}

/// What one group-granular step measured.
#[derive(Debug, Clone)]
pub struct FusedHostReport {
    pub n_groups: usize,
    /// Per-group live-gradient bytes, walk order — the measured liveness
    /// curve (compare `memsim::liveness::simulate_grouped(..).curve`).
    pub curve_bytes: Vec<usize>,
    /// Measured peak live-gradient bytes across the walk: the largest
    /// single allocation the step ever held.
    pub peak_live_grad_bytes: usize,
    /// The full-gradient-image baseline (`params_len` f32s) the
    /// monolithic step materializes.
    pub full_grad_bytes: usize,
}

impl FusedHostReport {
    /// Measured peak as a fraction of the full-image baseline.
    pub fn live_fraction(&self) -> f64 {
        self.peak_live_grad_bytes as f64 / self.full_grad_bytes.max(1) as f64
    }
}

/// One fused-backward optimizer step, group by group: produce group g's
/// gradient into a buffer sized for its extent, step exactly that group,
/// and free the buffer before group g+1 is produced. Bit-identical to one
/// whole-image [`FlatOptimizer::step`] with the same gradient values.
pub fn fused_host_step(
    engine: &mut FlatOptimizer,
    blob: &mut [f32],
    src: &mut dyn GroupGradSource,
    t: u64,
    lr: f32,
    wd: f32,
) -> Result<FusedHostReport> {
    let extents = engine.group_extents();
    ensure!(
        src.n_groups() == extents.len(),
        "source has {} groups, engine {}",
        src.n_groups(),
        extents.len()
    );
    let mut curve = Vec::with_capacity(extents.len());
    let mut peak = 0usize;
    for (g, &(lo, hi)) in extents.iter().enumerate() {
        ensure!(
            src.group_extent(g) == (lo, hi),
            "group {g}: source extent {:?} != engine extent {:?}",
            src.group_extent(g),
            (lo, hi)
        );
        // The step's ONLY gradient allocation: this group's extent. It is
        // dropped at the bottom of the loop, before the next group exists
        // — the measured embodiment of the §2.1 liveness claim.
        let mut gbuf = vec![0f32; hi - lo];
        src.fill_group(t, g, &mut gbuf);
        let live = 4 * gbuf.len();
        peak = peak.max(live);
        curve.push(live);
        engine.step_group(blob, g, &gbuf, t, lr, wd)?;
    }
    Ok(FusedHostReport {
        n_groups: extents.len(),
        curve_bytes: curve,
        peak_live_grad_bytes: peak,
        full_grad_bytes: 4 * engine.params_len(),
    })
}

/// Drive [`fused_host_step`] for `steps` steps from `blob0`; returns the
/// final blob and the (step-invariant) liveness report.
pub fn run_fused_host(
    engine: &mut FlatOptimizer,
    blob0: &[f32],
    src: &mut dyn GroupGradSource,
    steps: usize,
    lr: f32,
    wd: f32,
) -> Result<(Vec<f32>, FusedHostReport)> {
    let mut blob = blob0.to_vec();
    let mut report = None;
    for t in 1..=steps as u64 {
        report = Some(fused_host_step(engine, &mut blob, src, t, lr, wd)?);
    }
    let report = report
        .ok_or_else(|| anyhow::anyhow!("steps must be >= 1"))?;
    Ok((blob, report))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::flat::{
        seeded_blob_and_grads, synthetic_layout, ShardMode,
    };
    use crate::optim::OptKind;

    fn model_layout(kind: OptKind) -> crate::runtime::Layout {
        let params: Vec<(&str, &[usize])> = vec![
            ("embed", &[24, 8][..]),
            ("l0.attn_norm", &[8][..]),
            ("l0.wq", &[8, 8][..]),
            ("l0.w_down", &[10, 8][..]),
            ("l1.wq", &[8, 8][..]),
            ("final_norm", &[8][..]),
            ("head", &[8, 24][..]),
        ];
        synthetic_layout(kind, &params)
    }

    #[test]
    fn grouped_fill_is_order_independent() {
        let layout = model_layout(OptKind::AdaLomo);
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let mut a = FusedHostGrads::new(engine.group_extents(), 5, 1, 0.1);
        let mut b = FusedHostGrads::new(engine.group_extents(), 5, 1, 0.1);
        let mut full = vec![0f32; layout.params_len];
        GradSource::fill(&mut a, 3, &mut full);
        // Filling the groups individually, in REVERSE walk order, must
        // reproduce the same image.
        let mut pieces = vec![0f32; layout.params_len];
        for g in (0..b.n_groups()).rev() {
            let (lo, hi) = b.group_extent(g);
            b.fill_group(3, g, &mut pieces[lo..hi]);
        }
        assert_eq!(full, pieces);
        // Distinct ranks and steps draw distinct streams.
        let mut c = FusedHostGrads::new(engine.group_extents(), 5, 2, 0.1);
        let mut other = vec![0f32; layout.params_len];
        GradSource::fill(&mut c, 3, &mut other);
        assert_ne!(full, other);
        GradSource::fill(&mut a, 4, &mut other);
        assert_ne!(full, other);
    }

    #[test]
    fn mirror_matches_monolithic_step_bitwise() {
        for mode in [ShardMode::Segments, ShardMode::Contiguous] {
            let layout = model_layout(OptKind::AdaLomo);
            let (blob0, _) = seeded_blob_and_grads(&layout, 13);
            let mut engine = FlatOptimizer::new(
                OptKind::AdaLomo,
                &layout,
                3,
                mode,
            )
            .unwrap();
            let mut src =
                FusedHostGrads::new(engine.group_extents(), 7, 0, 0.05);
            let (mirror, report) =
                run_fused_host(&mut engine, &blob0, &mut src, 3, 1e-2, 0.0)
                    .unwrap();
            // Reference: whole-image steps with the identical gradients.
            let mut engine2 = FlatOptimizer::new(
                OptKind::AdaLomo,
                &layout,
                3,
                mode,
            )
            .unwrap();
            let mut src2 =
                FusedHostGrads::new(engine2.group_extents(), 7, 0, 0.05);
            let mut full = blob0.clone();
            let mut grad = vec![0f32; layout.params_len];
            for t in 1..=3u64 {
                GradSource::fill(&mut src2, t, &mut grad);
                engine2.step(&mut full, &grad, t, 1e-2, 0.0).unwrap();
            }
            for (i, (a, b)) in mirror.iter().zip(&full).enumerate() {
                assert!(
                    a.to_bits() == b.to_bits(),
                    "{mode:?} elem {i}: {a} vs {b}"
                );
            }
            // Liveness: peak is the largest group, strictly below the
            // full image.
            assert_eq!(report.n_groups, 4);
            assert_eq!(
                report.peak_live_grad_bytes,
                4 * engine.group_grad_sizes().iter().max().copied().unwrap()
            );
            assert!(
                report.peak_live_grad_bytes < report.full_grad_bytes,
                "{report:?}"
            );
        }
    }

    #[test]
    fn mismatched_source_is_rejected() {
        let layout = model_layout(OptKind::AdaLomo);
        let (mut blob, _) = seeded_blob_and_grads(&layout, 3);
        let mut engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        // Wrong group count.
        let mut short = FusedHostGrads::new(
            engine.group_extents()[..2].to_vec(),
            1,
            0,
            0.1,
        );
        assert!(
            fused_host_step(&mut engine, &mut blob, &mut short, 1, 1e-2, 0.0)
                .is_err()
        );
        // Right count, shifted extents.
        let shifted: Vec<(usize, usize)> = engine
            .group_extents()
            .iter()
            .map(|&(lo, hi)| (lo.saturating_sub(1), hi.saturating_sub(1)))
            .collect();
        let mut bad = FusedHostGrads::new(shifted, 1, 0, 0.1);
        assert!(
            fused_host_step(&mut engine, &mut blob, &mut bad, 1, 1e-2, 0.0)
                .is_err()
        );
    }
}
