//! The training loop: drives a `train_step_*` artifact over the
//! device-resident state blob.
//!
//! Hot-path discipline (perf deliverable): per step the host does exactly
//! (a) one x upload + one y upload (the batch), (b) one 4-float sched
//! upload, (c) one execute_b — the blob output buffer becomes the next
//! step's input. Metrics are read back only every `log_every` steps via
//! the 8-float `read_metrics_*` program.

use std::time::Instant;

use anyhow::{anyhow, Context, Result};
use xla::PjRtBuffer;

use crate::config::RunConfig;
use crate::data::DataLoader;
use crate::metrics::{EvalAccum, RunLog, StepMetrics};
use crate::runtime::{HostBlob, Manifest, Session};

use super::schedule::Schedule;

#[derive(Debug, Clone)]
pub struct TrainReport {
    pub steps: usize,
    pub final_loss: f32,
    pub curve: Vec<(usize, f64)>,
    pub eval_curve: Vec<(usize, f64, f64)>, // (step, ppl, acc)
    pub wall_secs: f64,
    pub tokens_per_sec: f64,
}

pub struct Trainer<'s> {
    pub session: &'s Session,
    pub cfg: RunConfig,
    train_entry: String,
    metrics_entry: String,
    extract_entry: String,
    eval_entry: String,
    layout_key: String,
    blob: Option<PjRtBuffer>,
    pub loader: DataLoader,
    val_loader: Option<DataLoader>,
    log: Option<RunLog>,
    /// Offset added to the step counter uploaded to the train kernel.
    /// Resumed runs (local-SGD rounds) set this so bias corrections see
    /// the true global step instead of restarting at t=1 against warm
    /// optimizer state (which would inflate v_hat by ~1/(1-beta)).
    step_offset: usize,
}

impl<'s> Trainer<'s> {
    pub fn new(
        session: &'s Session,
        cfg: RunConfig,
        loader: DataLoader,
        val_loader: Option<DataLoader>,
    ) -> Result<Trainer<'s>> {
        let preset = &cfg.preset;
        let opt = &cfg.opt;
        let train_entry = Manifest::train_step_name(preset, opt);
        session
            .manifest
            .entry(&train_entry)
            .with_context(|| format!("preset {preset} / optimizer {opt}"))?;
        Ok(Trainer {
            session,
            train_entry,
            metrics_entry: Manifest::read_metrics_name(preset, opt),
            extract_entry: Manifest::extract_params_name(preset, opt),
            eval_entry: Manifest::eval_name(preset),
            layout_key: Manifest::layout_key(preset, opt),
            cfg,
            blob: None,
            loader,
            val_loader,
            log: None,
            step_offset: 0,
        })
    }

    /// Continue the kernel-side step counter from `offset` (the number of
    /// steps already taken on this blob's optimizer state).
    pub fn set_step_offset(&mut self, offset: usize) {
        self.step_offset = offset;
    }

    pub fn with_logging(mut self) -> Result<Self> {
        self.log = Some(RunLog::create(
            &self.cfg.out_dir,
            &self.cfg.run_name(),
        )?);
        Ok(self)
    }

    /// Initialize the device blob from the AOT `init_*` program (seeded,
    /// fully reproducible from Rust).
    pub fn init_from_seed(&mut self) -> Result<()> {
        let entry = Manifest::init_name(&self.cfg.preset, &self.cfg.opt);
        let seed = self.session.upload_i32(&[self.cfg.seed as i32], &[])?;
        let blob = self.session.execute_buf(&entry, &[&seed])?;
        self.blob = Some(blob);
        Ok(())
    }

    /// Start from a host checkpoint (e.g. a repacked pre-trained blob).
    pub fn set_host_blob(&mut self, blob: &HostBlob) -> Result<()> {
        let layout = self.session.manifest.layout(&self.layout_key)?;
        if blob.data.len() != layout.blob_len {
            anyhow::bail!(
                "checkpoint blob len {} != layout {} ({})",
                blob.data.len(),
                layout.blob_len,
                self.layout_key
            );
        }
        self.blob =
            Some(self.session.upload_f32(&blob.data, &[layout.blob_len])?);
        Ok(())
    }

    pub fn host_blob(&self) -> Result<HostBlob> {
        let layout = self.session.manifest.layout(&self.layout_key)?;
        let buf = self.blob.as_ref().ok_or_else(|| anyhow!("no blob"))?;
        let data = self.session.fetch_f32_raw(buf, layout.blob_len)?;
        HostBlob::new(data, &self.layout_key, layout)
    }

    /// Extract the bare parameter blob (on device) for eval entries.
    pub fn params_buffer(&self) -> Result<PjRtBuffer> {
        let buf = self.blob.as_ref().ok_or_else(|| anyhow!("no blob"))?;
        self.session.execute_buf(&self.extract_entry, &[buf])
    }

    pub fn read_metrics(&self) -> Result<Vec<f32>> {
        let buf = self.blob.as_ref().ok_or_else(|| anyhow!("no blob"))?;
        let m = self.session.execute_buf(&self.metrics_entry, &[buf])?;
        self.session.fetch_f32_raw(&m, 8)
    }

    /// Run `cfg.steps` training steps. Requires an initialized blob.
    pub fn train(&mut self) -> Result<TrainReport> {
        let schedule = Schedule::cosine(
            self.cfg.lr,
            self.cfg.warmup_steps,
            self.cfg.steps,
        );
        self.train_with_schedule(schedule)
    }

    pub fn train_with_schedule(&mut self, schedule: Schedule) -> Result<TrainReport> {
        if self.blob.is_none() {
            self.init_from_seed()?;
        }
        // Move compile time off the timed loop.
        self.session.compile(&self.train_entry)?;
        self.session.compile(&self.metrics_entry)?;

        let (b, t) = (self.loader.b, self.loader.t);
        let mut curve = Vec::new();
        let mut eval_curve = Vec::new();
        let mut last_loss = f32::NAN;
        // ANALYZE-WAIVE(determinism): wall-clock report fields only
        let started = Instant::now();
        // ANALYZE-WAIVE(determinism): steps/s logging only
        let mut step_t0 = Instant::now();

        for step in 1..=self.cfg.steps {
            let batch = self.loader.next_batch();
            let lr = schedule.lr_at(step);
            let global_step = self.step_offset + step;
            let x = self.session.upload_i32(&batch.x, &[b, t])?;
            let y = self.session.upload_i32(&batch.y, &[b, t])?;
            let sched = self.session.upload_f32(
                &[lr, global_step as f32, self.cfg.wd, self.cfg.clip],
                &[4],
            )?;
            let blob = self.blob.take().expect("initialized above");
            let next = self
                .session
                .execute_buf(&self.train_entry, &[&blob, &x, &y, &sched])?;
            self.blob = Some(next);

            if step % self.cfg.log_every == 0 || step == self.cfg.steps {
                let slots = self.read_metrics()?;
                let dt = step_t0.elapsed().as_secs_f64()
                    / self.cfg.log_every as f64;
                // ANALYZE-WAIVE(determinism): steps/s logging only
                step_t0 = Instant::now();
                let m = StepMetrics::from_slots(step, &slots, lr, dt);
                last_loss = m.loss;
                curve.push((step, m.loss as f64));
                if let Some(log) = &mut self.log {
                    log.log_train(&m)?;
                }
            }
            if self.cfg.eval_every > 0
                && self.val_loader.is_some()
                && (step % self.cfg.eval_every == 0 || step == self.cfg.steps)
            {
                // ANALYZE-WAIVE(determinism): eval-time logging only
                let eval_t0 = Instant::now();
                let e = self.evaluate()?;
                eval_curve.push((step, e.perplexity(), e.accuracy()));
                if let Some(log) = &mut self.log {
                    log.log_eval(step, &e)?;
                }
                // Evaluation wall-time must not leak into the logging
                // window's per-step dt / tokens-per-sec. Shifting the window
                // start forward by the eval duration (rather than restarting
                // the window) keeps the training time already accumulated in
                // a partially-elapsed window, so dt stays correct even when
                // eval steps are not aligned to log boundaries.
                step_t0 += eval_t0.elapsed();
            }
        }
        let wall = started.elapsed().as_secs_f64();
        let tokens = (self.cfg.steps * b * t) as f64;
        Ok(TrainReport {
            steps: self.cfg.steps,
            final_loss: last_loss,
            curve,
            eval_curve,
            wall_secs: wall,
            tokens_per_sec: tokens / wall,
        })
    }

    /// Evaluate on a FIXED validation set (one epoch's worth of batches,
    /// capped for tractability): the loader is rewound to its pristine
    /// state first, so every call scores the same batches and successive
    /// `eval_curve` points are comparable instead of drifting through the
    /// validation stream.
    pub fn evaluate(&mut self) -> Result<EvalAccum> {
        self.evaluate_current()
    }

    /// Evaluate a host checkpoint directly: upload it and score the fixed
    /// validation set — the one-call form the local-SGD leader (and any
    /// pipeline driver holding a host blob) uses on round boundaries.
    pub fn evaluate_blob(&mut self, blob: &HostBlob) -> Result<EvalAccum> {
        self.set_host_blob(blob)?;
        self.evaluate_current()
    }

    fn evaluate_current(&mut self) -> Result<EvalAccum> {
        let params = self.params_buffer()?;
        let val = self
            .val_loader
            .as_mut()
            .ok_or_else(|| anyhow!("no validation loader"))?;
        val.reset();
        let n_batches = val.batches_per_epoch().clamp(1, 8);
        let (b, t) = (val.b, val.t);
        let mut accum = EvalAccum::default();
        for _ in 0..n_batches {
            let batch = val.next_batch();
            let x = self.session.upload_i32(&batch.x, &[b, t])?;
            let y = self.session.upload_i32(&batch.y, &[b, t])?;
            let m = self
                .session
                .execute_buf(&self.eval_entry, &[&params, &x, &y])?;
            let slots = self.session.fetch_f32_raw(&m, 8)?;
            accum.add_slots(&slots);
        }
        Ok(accum)
    }
}
