//! Async rank pipeline: overlap gradient exchange with the flat optimizer
//! step.
//!
//! AdaLomo's fusion argument (PAPER.md §3) — hide the optimizer update
//! inside work that must happen anyway — applies across ranks too: while
//! the fabric is busy reducing one gradient bucket, the leader can already
//! be stepping the tensors completed by earlier buckets. This module is
//! that pipeline on the PR-1 flat engine, replacing the lockstep
//! clone-average-broadcast rounds of `workers::run_local_sgd` at gradient
//! granularity.
//!
//! # Bucket lifecycle
//!
//! The gradient image `[0, params_len)` is tiled by a [`BucketPlan`] into
//! fixed-size buckets. Each bucket moves through four phases:
//!
//! 1. **accumulate** — every rank thread computes its local gradient for
//!    the step and posts the bucket's range over a bounded channel (the
//!    fixed-depth channel is the backpressure a real exchange fabric
//!    applies);
//! 2. **reduce** — the leader receives one contribution per rank *in rank
//!    order* and combines them element-parallel on the worker pool
//!    ([`crate::optim::pool::par_average`] — bit-identical for any worker
//!    count), while charging the fabric the simulated per-bucket ring
//!    all-reduce cost ([`super::collective::allreduce_bucket_time`]);
//! 3. **step** — every task (trainable segment, fused-backward order)
//!    whose LAST overlapping bucket just landed becomes steppable and is
//!    handed to [`FlatOptimizer::step_tasks`]; per-task arithmetic is
//!    self-contained, so stepping tasks as their buckets complete is
//!    bitwise identical to one whole-image step with the same reduced
//!    gradient — the determinism contract pinned by the proptests;
//! 4. **broadcast** — the leader owns the canonical blob, so within the
//!    pipeline there is nothing to send back; across local-SGD rounds the
//!    broadcast half is `workers::Broadcast::Params`, the slim
//!    params-region sync.
//!
//! The [`PipelineReport`] quantifies the overlap: `exposed_secs` is the
//! modeled critical path (comm serialized on the fabric; each bucket's
//! optimizer work starts once its reduction lands and the previous
//! bucket's work has finished), which sits below `compute + comm` exactly
//! when the pipeline hides exchange behind stepping.

use std::collections::VecDeque;
use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::data::tokenizer::PAD;
use crate::data::{DataLoader, Domain};
use crate::optim::flat::{FlatOptimizer, ShardMode};
use crate::optim::{pool, OptKind};
use crate::runtime::Layout;
use crate::util::rng::Pcg32;

use super::collective::{
    allreduce_bucket_time, bucketed_allreduce_times, Fabric,
};
use super::fused_host::GroupGradSource;

/// Fixed-size exchange buckets tiling the gradient image `[0,
/// params_len)` in offset order.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub params_len: usize,
    pub bucket_elems: usize,
    /// Half-open `[lo, hi)` ranges; the last bucket may be partial.
    pub buckets: Vec<(usize, usize)>,
}

impl BucketPlan {
    pub fn new(params_len: usize, bucket_elems: usize) -> BucketPlan {
        assert!(bucket_elems > 0, "bucket_elems must be positive");
        let mut buckets = Vec::new();
        let mut lo = 0usize;
        while lo < params_len {
            let hi = (lo + bucket_elems).min(params_len);
            buckets.push((lo, hi));
            lo = hi;
        }
        BucketPlan { params_len, bucket_elems, buckets }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// For every task extent (from [`FlatOptimizer::task_extents`]), the
    /// bucket whose reduction completes it: per-bucket lists of task
    /// indices. Each list is sorted (extents are scanned in index order)
    /// and the lists partition `0..extents.len()`.
    pub fn ready_schedule(&self, extents: &[(usize, usize)]) -> Vec<Vec<usize>> {
        // Ascending walk: a task completes with its LAST element.
        self.schedule_by(extents, |off, size| off + size.max(1) - 1)
    }

    /// [`Self::ready_schedule`] for the DESCENDING bucket walk of the
    /// fused-host pipeline ([`run_pipelined_fused`]): when buckets land in
    /// reverse offset order — the order group-by-group backward production
    /// covers them — a task is completed by the bucket holding its FIRST
    /// element (every later-offset bucket has already landed). Same
    /// guarantees: sorted per-bucket lists partitioning the task indices.
    pub fn ready_schedule_backward(
        &self,
        extents: &[(usize, usize)],
    ) -> Vec<Vec<usize>> {
        self.schedule_by(extents, |off, _| off)
    }

    /// Shared body of the two schedules: bucket the anchor element of
    /// every extent. The fixed-size tiling makes the lookup a division
    /// (bucket i covers `[i*bucket_elems, ..)`, last bucket ragged).
    fn schedule_by(
        &self,
        extents: &[(usize, usize)],
        anchor: impl Fn(usize, usize) -> usize,
    ) -> Vec<Vec<usize>> {
        let mut ready = vec![Vec::new(); self.buckets.len()];
        for (ti, &(off, size)) in extents.iter().enumerate() {
            let a = anchor(off, size);
            let b = a / self.bucket_elems;
            assert!(
                b < self.buckets.len(),
                "task extent outside the bucketed region"
            );
            debug_assert!(
                self.buckets[b].0 <= a && a < self.buckets[b].1,
                "bucket tiling broke the division lookup"
            );
            ready[b].push(ti);
        }
        ready
    }
}

/// Per-rank gradient producer for the host-mirror pipeline. `fill` must be
/// deterministic in (its own seeded state, step): the bitwise-identity
/// guarantee quantifies only the exchange/step scheduling, so the
/// pipelined and sequential paths must see identical rank gradients.
pub trait GradSource: Send {
    fn fill(&mut self, step: u64, out: &mut [f32]);
}

/// Deterministic synthetic gradients from a rank-seeded PRNG stream — the
/// host-mirror stand-in for a backward pass.
pub struct SyntheticGrads {
    rng: Pcg32,
    scale: f32,
}

impl SyntheticGrads {
    pub fn new(seed: u64, rank: usize, scale: f32) -> SyntheticGrads {
        // Same rank-seed spacing as the local-SGD workers' data streams.
        SyntheticGrads {
            rng: Pcg32::new(seed + 1000 * rank as u64, 13),
            scale,
        }
    }
}

impl GradSource for SyntheticGrads {
    fn fill(&mut self, _step: u64, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.rng.normal() * self.scale;
        }
    }
}

/// Data-conditioned pseudo-gradients: every non-PAD (token, next-token)
/// pair in the rank's next batch pushes a pair of hashed parameter slots
/// together. Not a model backward — a stand-in whose gradient genuinely
/// depends on the rank's data stream, so data-order bugs change the final
/// parameters (and hence [`host_eval_loss`]).
pub struct TokenGrads {
    loader: DataLoader,
    scale: f32,
}

impl TokenGrads {
    pub fn new(loader: DataLoader, scale: f32) -> TokenGrads {
        TokenGrads { loader, scale }
    }
}

impl GradSource for TokenGrads {
    fn fill(&mut self, _step: u64, out: &mut [f32]) {
        out.fill(0.0);
        let batch = self.loader.next_batch();
        for (i, (&x, &y)) in batch.x.iter().zip(&batch.y).enumerate() {
            if y == PAD {
                continue;
            }
            out[token_slot(x, i, out.len())] += self.scale;
            out[token_slot(y, i + 1, out.len())] -= self.scale;
        }
    }
}

/// One rank's worth of [`SyntheticGrads`] per rank.
pub fn synthetic_sources(
    n_ranks: usize,
    seed: u64,
    scale: f32,
) -> Vec<Box<dyn GradSource>> {
    (0..n_ranks)
        .map(|r| {
            Box::new(SyntheticGrads::new(seed, r, scale))
                as Box<dyn GradSource>
        })
        .collect()
}

/// One independent [`TokenGrads`] data stream per rank (rank-seed spacing
/// as in `workers::run_local_sgd`).
pub fn token_sources(
    domain: Domain,
    seed: u64,
    n_ranks: usize,
    b: usize,
    t: usize,
    n_tokens: usize,
    scale: f32,
) -> Vec<Box<dyn GradSource>> {
    (0..n_ranks)
        .map(|r| {
            let loader =
                DataLoader::lm(domain, seed + 1000 * r as u64, b, t, n_tokens);
            Box::new(TokenGrads::new(loader, scale)) as Box<dyn GradSource>
        })
        .collect()
}

/// Deterministic parameter slot for a (token, position) pair — the hash
/// shared by the gradient and eval stand-ins, so the eval actually reads
/// the slots training moved.
fn token_slot(tok: i32, pos: usize, n: usize) -> usize {
    (tok as usize)
        .wrapping_mul(2654435761)
        .wrapping_add(pos.wrapping_mul(40503))
        % n
}

/// Deterministic host-side validation loss over a FIXED validation set:
/// the loader is rewound to its pristine order first (PR 1's
/// [`DataLoader::reset`] determinism fix), so every call scores the same
/// batches — two parameter images produce bitwise-equal losses iff they
/// agree on every slot the validation tokens touch.
pub fn host_eval_loss(
    params: &[f32],
    val: &mut DataLoader,
    n_batches: usize,
) -> f64 {
    val.reset();
    let n_batches = n_batches.clamp(1, val.batches_per_epoch().max(1));
    let mut loss = 0.0f64;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let batch = val.next_batch();
        for (i, (&x, &y)) in batch.x.iter().zip(&batch.y).enumerate() {
            if y == PAD {
                continue;
            }
            let d = (params[token_slot(x, i, params.len())]
                - params[token_slot(y, i + 1, params.len())])
                as f64;
            loss += d * d;
            count += 1;
        }
    }
    loss / count.max(1) as f64
}

/// Knobs shared by the pipelined and sequential drivers. Both paths must
/// run the same config for the bitwise-identity guarantee to apply (the
/// engine shard count fixes the reduction associativity).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub steps: usize,
    pub bucket_elems: usize,
    pub lr: f32,
    pub wd: f32,
    /// Worker shards for the leader's flat engine (and the bucket
    /// reduction). Results are deterministic for a FIXED value.
    pub n_shards: usize,
    pub fabric: Fabric,
}

impl PipelineConfig {
    pub fn new(steps: usize, bucket_elems: usize) -> PipelineConfig {
        PipelineConfig {
            steps,
            bucket_elems,
            lr: 1e-2,
            wd: 0.0,
            n_shards: 2,
            fabric: Fabric::default(),
        }
    }

    /// [`Self::new`] with `bucket_elems` chosen by
    /// [`adaptive_bucket_elems`] under the default
    /// [`ADAPTIVE_COMM_FRACTION`] budget, for a measured per-element
    /// optimizer step cost on this machine.
    pub fn adaptive(
        steps: usize,
        params_len: usize,
        n_ranks: usize,
        fabric: Fabric,
        step_secs_per_elem: f64,
    ) -> PipelineConfig {
        let bucket = adaptive_bucket_elems(
            params_len,
            n_ranks,
            fabric,
            step_secs_per_elem,
            ADAPTIVE_COMM_FRACTION,
        );
        let mut cfg = PipelineConfig::new(steps, bucket);
        cfg.fabric = fabric;
        cfg
    }
}

/// What the pipeline measured/modeled. `compute_secs` is measured wall
/// time inside `step_tasks`; `comm_secs` is the simulated fabric cost of
/// the bucketed ring all-reduces; `exposed_secs` is the modeled critical
/// path of the bucketed schedule.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub n_ranks: usize,
    pub steps: usize,
    pub n_buckets: usize,
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub exposed_secs: f64,
    /// `(compute + comm) / exposed` — 1.0 means nothing overlapped;
    /// higher is better (2.0 would mean perfect hiding of the smaller
    /// side).
    pub overlap_efficiency: f64,
    pub wall_secs: f64,
    /// Measured peak gradient bytes live on a producing rank: the full
    /// image for the materialized paths ([`run_pipelined`],
    /// [`run_sequential`]); for [`run_pipelined_fused`] the
    /// produced-but-unshipped group buffers, which can never exceed the
    /// image. In-flight exchange payloads (bounded by the channel depth ×
    /// bucket size) are the fabric's, not the producer's, on every path.
    pub peak_live_grad_bytes: usize,
    /// The full-gradient-image baseline in bytes (`params_len` × 4).
    pub full_grad_bytes: usize,
}

/// Run the bucketed rank pipeline: per-rank worker threads exchange
/// gradient buckets over bounded channels while the leader reduces (rank
/// order) and steps ready tasks. Returns the final blob and the overlap
/// report. Bitwise-identical to [`run_sequential`] under the same config
/// and sources.
pub fn run_pipelined(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, PipelineReport)> {
    ensure!(!sources.is_empty(), "need at least one rank");
    ensure!(
        blob0.len() == layout.blob_len,
        "blob len {} != layout {}",
        blob0.len(),
        layout.blob_len
    );
    let n_ranks = sources.len();
    let started = Instant::now();
    let mut engine = FlatOptimizer::new(kind, layout, cfg.n_shards, mode)?;
    let plan = BucketPlan::new(layout.params_len, cfg.bucket_elems);
    let ready = plan.ready_schedule(&engine.task_extents());
    // Fabric cost per bucket: the collective module's bucketed tiling is
    // byte-for-byte the same as BucketPlan's element tiling (4 bytes per
    // f32, ragged last bucket included) — one costing source, not two.
    let bucket_comm = bucketed_allreduce_times(
        (layout.params_len * 4) as f64,
        (cfg.bucket_elems * 4) as f64,
        n_ranks,
        cfg.fabric,
    );
    debug_assert_eq!(bucket_comm.len(), plan.n_buckets());

    // Rank threads: compute the step's gradient, then stream it out
    // bucket-by-bucket. The bounded channel depth is the exchange
    // fabric's backpressure — a rank can run at most two buckets ahead of
    // the reduction.
    let mut handles = Vec::with_capacity(n_ranks);
    let mut rx_ranks = Vec::with_capacity(n_ranks);
    for mut src in sources {
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        rx_ranks.push(rx);
        let buckets = plan.buckets.clone();
        let params_len = layout.params_len;
        let steps = cfg.steps;
        handles.push(thread::spawn(move || {
            let mut grad = vec![0f32; params_len];
            for step in 1..=steps as u64 {
                src.fill(step, &mut grad);
                for &(lo, hi) in &buckets {
                    if tx.send(grad[lo..hi].to_vec()).is_err() {
                        return; // leader bailed; stop producing
                    }
                }
            }
        }));
    }

    let order: Vec<usize> = (0..plan.n_buckets()).collect();
    let outcome = leader_loop(
        &mut engine, &plan, &order, &ready, &bucket_comm, &rx_ranks, blob0,
        cfg,
    );
    // Unblock any rank still parked on a bounded send before joining (the
    // error path stops receiving mid-stream).
    drop(rx_ranks);
    for h in handles {
        h.join().map_err(|_| anyhow!("rank thread panicked"))?;
    }
    let (blob, compute_secs, comm_secs, exposed_secs) = outcome?;

    let overlap_efficiency = if exposed_secs > 0.0 {
        (compute_secs + comm_secs) / exposed_secs
    } else {
        1.0
    };
    Ok((
        blob,
        PipelineReport {
            n_ranks,
            steps: cfg.steps,
            n_buckets: plan.n_buckets(),
            compute_secs,
            comm_secs,
            exposed_secs,
            overlap_efficiency,
            wall_secs: started.elapsed().as_secs_f64(),
            // Every rank thread materializes the full gradient image.
            peak_live_grad_bytes: 4 * layout.params_len,
            full_grad_bytes: 4 * layout.params_len,
        },
    ))
}

/// The leader half of the pipelined drivers: receive and reduce buckets
/// in rank order (visiting buckets in `order` — ascending for
/// [`run_pipelined`], descending for [`run_pipelined_fused`]), step ready
/// tasks, advance the modeled timeline. Returns `(blob, compute, comm,
/// exposed)`.
#[allow(clippy::too_many_arguments)]
fn leader_loop(
    engine: &mut FlatOptimizer,
    plan: &BucketPlan,
    order: &[usize],
    ready: &[Vec<usize>],
    bucket_comm: &[f64],
    rx_ranks: &[mpsc::Receiver<Vec<f32>>],
    blob0: &[f32],
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, f64, f64, f64)> {
    let n_ranks = rx_ranks.len();
    let inv = 1.0 / n_ranks as f32;
    let mut blob = blob0.to_vec();
    let mut grad = vec![0f32; plan.params_len];
    let (mut compute, mut comm, mut exposed) = (0.0f64, 0.0f64, 0.0f64);
    for t in 1..=cfg.steps as u64 {
        // Modeled per-step timeline: comm is serialized on the fabric
        // (`comm_front`); bucket b's optimizer work starts at
        // max(its reduction landing, previous work finishing).
        let mut comm_front = 0.0f64;
        let mut work_front = 0.0f64;
        for &b in order {
            let (lo, hi) = plan.buckets[b];
            // Accumulate: one contribution per rank, received in rank
            // order — the fixed reduction order determinism rests on.
            let mut chunks = Vec::with_capacity(n_ranks);
            for rx in rx_ranks {
                let chunk = rx.recv().map_err(|_| {
                    anyhow!("rank gradient stream ended early")
                })?;
                ensure!(chunk.len() == hi - lo, "bucket size mismatch");
                chunks.push(chunk);
            }
            // Reduce: mean in rank order, element-parallel on the pool
            // (bit-identical for any worker count).
            let refs: Vec<&[f32]> =
                chunks.iter().map(|c| c.as_slice()).collect();
            pool::par_average(&mut grad[lo..hi], &refs, inv, cfg.n_shards);
            comm_front += bucket_comm[b];
            comm += bucket_comm[b];
            // Step: every task whose last bucket just landed.
            let dt = if ready[b].is_empty() {
                0.0
            } else {
                let t0 = Instant::now();
                engine.step_tasks(
                    &mut blob, &grad, t, cfg.lr, cfg.wd, &ready[b],
                )?;
                t0.elapsed().as_secs_f64()
            };
            compute += dt;
            work_front = comm_front.max(work_front) + dt;
        }
        exposed += comm_front.max(work_front);
    }
    Ok((blob, compute, comm, exposed))
}

/// The fused-host pipeline: ranks produce their gradients GROUP BY GROUP
/// in fused-backward order ([`GroupGradSource`]) and ship each exchange
/// bucket the moment production has covered it, so the bucket exchange
/// overlaps actual gradient *production* — no rank ever materializes the
/// full gradient image. Buckets therefore move in DESCENDING offset order
/// (backward production covers the image top-down), the leader reduces
/// them in that same fixed order, and tasks step when the bucket holding
/// their first element lands ([`BucketPlan::ready_schedule_backward`]).
///
/// Requires the engine's fused groups to tile the gradient image in
/// descending offset order (true for model-shaped layouts). Per-task
/// arithmetic is self-contained and the per-bucket reductions are
/// order-independent across disjoint ranges, so the result is bitwise
/// identical to [`run_pipelined`] and [`run_sequential`] fed the same
/// gradient values — pinned by the proptests.
///
/// The returned report's `peak_live_grad_bytes` is MEASURED: the most
/// produced-but-unshipped group-buffer bytes any rank ever held (a group
/// buffer is freed once the shipped region covers it), the pipeline
/// counterpart of `fused_host::FusedHostReport`. With buckets no larger
/// than a group this tops out at two groups — the §2.1 bound — and by
/// construction it can never exceed the full image.
pub fn run_pipelined_fused(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GroupGradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, PipelineReport)> {
    ensure!(!sources.is_empty(), "need at least one rank");
    ensure!(
        blob0.len() == layout.blob_len,
        "blob len {} != layout {}",
        blob0.len(),
        layout.blob_len
    );
    let n_ranks = sources.len();
    let started = Instant::now();
    let mut engine = FlatOptimizer::new(kind, layout, cfg.n_shards, mode)?;
    let plan = BucketPlan::new(layout.params_len, cfg.bucket_elems);
    let ready = plan.ready_schedule_backward(&engine.task_extents());
    let groups = engine.group_extents();
    // The grouped walk ships buckets against a production frontier that
    // moves down from params_len: the groups must tile the image
    // top-down.
    let mut hi_expect = layout.params_len;
    for (g, &(lo, hi)) in groups.iter().enumerate() {
        ensure!(
            hi == hi_expect && lo < hi,
            "group {g} extent [{lo}, {hi}) breaks the descending tiling \
             (expected hi = {hi_expect}); fused-host pipelining needs a \
             model-shaped layout"
        );
        hi_expect = lo;
    }
    ensure!(hi_expect == 0, "fused groups must cover the gradient image");
    for (r, src) in sources.iter().enumerate() {
        ensure!(
            src.n_groups() == groups.len(),
            "rank {r}: source has {} groups, engine {}",
            src.n_groups(),
            groups.len()
        );
        for (g, &e) in groups.iter().enumerate() {
            ensure!(
                src.group_extent(g) == e,
                "rank {r} group {g}: source extent {:?} != engine {:?}",
                src.group_extent(g),
                e
            );
        }
    }
    let bucket_comm = bucketed_allreduce_times(
        (layout.params_len * 4) as f64,
        (cfg.bucket_elems * 4) as f64,
        n_ranks,
        cfg.fabric,
    );
    debug_assert_eq!(bucket_comm.len(), plan.n_buckets());

    // Rank threads: interleave group production with bucket shipping.
    // Each returns its measured peak live gradient elements.
    let mut handles = Vec::with_capacity(n_ranks);
    let mut rx_ranks = Vec::with_capacity(n_ranks);
    for mut src in sources {
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        rx_ranks.push(rx);
        let buckets = plan.buckets.clone();
        let extents = groups.clone();
        let steps = cfg.steps;
        handles.push(thread::spawn(move || -> usize {
            let mut peak_elems = 0usize;
            for step in 1..=steps as u64 {
                // Produced-but-unshipped group buffers, oldest (highest
                // extent) first. Each element is written once at
                // production and read once into its bucket payload; a
                // buffer is freed the moment the shipped region covers
                // it, so only the groups overlapping the unshipped span
                // stay allocated — with buckets no larger than a group
                // that is at most two groups, the host-path twin of the
                // paper's two-consecutive-gradients bound (§2.1), and it
                // can never exceed the full image.
                let mut segs: VecDeque<(usize, Vec<f32>)> = VecDeque::new();
                let mut live = 0usize;
                let mut next_bucket = buckets.len();
                for (g, &(lo, hi)) in extents.iter().enumerate() {
                    let mut gbuf = vec![0f32; hi - lo];
                    src.fill_group(step, g, &mut gbuf);
                    live += gbuf.len();
                    peak_elems = peak_elems.max(live);
                    segs.push_back((lo, gbuf));
                    // Ship every bucket production now covers; each send
                    // assembles the bucket payload from the overlapping
                    // buffers (the one copy the exchange itself needs).
                    while next_bucket > 0
                        && buckets[next_bucket - 1].0 >= lo
                    {
                        let (blo, bhi) = buckets[next_bucket - 1];
                        let mut chunk = vec![0f32; bhi - blo];
                        for (slo, sbuf) in segs.iter() {
                            let slo = *slo;
                            let shi = slo + sbuf.len();
                            let olo = blo.max(slo);
                            let ohi = bhi.min(shi);
                            if olo < ohi {
                                chunk[olo - blo..ohi - blo]
                                    .copy_from_slice(
                                        &sbuf[olo - slo..ohi - slo],
                                    );
                            }
                        }
                        if tx.send(chunk).is_err() {
                            return peak_elems; // leader bailed; stop
                        }
                        // Free every buffer the shipped region covers.
                        loop {
                            match segs.front() {
                                Some(&(slo, _)) if slo >= blo => {
                                    let (_, sbuf) = segs
                                        .pop_front()
                                        .expect("front checked above");
                                    live -= sbuf.len();
                                }
                                _ => break,
                            }
                        }
                        next_bucket -= 1;
                    }
                }
                debug_assert!(segs.is_empty() && next_bucket == 0);
            }
            peak_elems
        }));
    }

    let order: Vec<usize> = (0..plan.n_buckets()).rev().collect();
    let outcome = leader_loop(
        &mut engine, &plan, &order, &ready, &bucket_comm, &rx_ranks, blob0,
        cfg,
    );
    drop(rx_ranks);
    let mut peak_elems = 0usize;
    for h in handles {
        let rank_peak =
            h.join().map_err(|_| anyhow!("rank thread panicked"))?;
        peak_elems = peak_elems.max(rank_peak);
    }
    let (blob, compute_secs, comm_secs, exposed_secs) = outcome?;

    let overlap_efficiency = if exposed_secs > 0.0 {
        (compute_secs + comm_secs) / exposed_secs
    } else {
        1.0
    };
    Ok((
        blob,
        PipelineReport {
            n_ranks,
            steps: cfg.steps,
            n_buckets: plan.n_buckets(),
            compute_secs,
            comm_secs,
            exposed_secs,
            overlap_efficiency,
            wall_secs: started.elapsed().as_secs_f64(),
            peak_live_grad_bytes: 4 * peak_elems,
            full_grad_bytes: 4 * layout.params_len,
        },
    ))
}

/// Fraction of per-bucket optimizer compute the per-bucket fabric cost is
/// allowed to reach when [`adaptive_bucket_elems`] picks the bucket size.
pub const ADAPTIVE_COMM_FRACTION: f64 = 0.5;

/// Pick [`PipelineConfig::bucket_elems`] from the fabric model: the
/// smallest bucket — smaller buckets mean earlier first steps and finer
/// overlap — whose per-bucket ring all-reduce cost stays within
/// `comm_fraction` of its per-bucket optimizer compute
/// (`step_secs_per_elem`; measure it with `bench_micro_optim`).
///
/// Every bucket re-pays the full `2(n-1)` hop latencies
/// ([`super::collective::bucketed_allreduce_times`]), so below the
/// returned size the latency tax alone breaks the bound:
/// `comm(b) = 2(n-1)(alpha + 4b/(n*bw)) <= f * b * c` solves to
/// `b >= 2(n-1)alpha / (f*c - 8(n-1)/(n*bw))`. If the denominator is not
/// positive — the bandwidth term alone exceeds the compute budget — no
/// bucket size can hide the exchange and the choice degenerates to one
/// monolithic bucket (minimizing the latency tax). A single rank pays no
/// fabric at all, with the same degenerate answer.
pub fn adaptive_bucket_elems(
    params_len: usize,
    n_ranks: usize,
    fabric: Fabric,
    step_secs_per_elem: f64,
    comm_fraction: f64,
) -> usize {
    assert!(params_len > 0, "params_len must be positive");
    assert!(
        step_secs_per_elem > 0.0 && comm_fraction > 0.0,
        "step cost and comm fraction must be positive"
    );
    if n_ranks <= 1 {
        return params_len;
    }
    let n = n_ranks as f64;
    let slack = comm_fraction * step_secs_per_elem
        - 8.0 * (n - 1.0) / (n * fabric.bw);
    if slack <= 0.0 {
        return params_len;
    }
    let b = (2.0 * (n - 1.0) * fabric.alpha / slack).ceil() as usize;
    b.clamp(1, params_len)
}

/// Lockstep reference: reduce the FULL gradient image (same rank order,
/// same element-wise associativity as the bucketed reduction), then one
/// whole-image engine step — the PR-1 flat-engine path the pipeline must
/// match bitwise. Comm is modeled as one monolithic ring all-reduce per
/// step, fully exposed.
pub fn run_sequential(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    mut sources: Vec<Box<dyn GradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, PipelineReport)> {
    ensure!(!sources.is_empty(), "need at least one rank");
    ensure!(
        blob0.len() == layout.blob_len,
        "blob len {} != layout {}",
        blob0.len(),
        layout.blob_len
    );
    let n_ranks = sources.len();
    let started = Instant::now();
    let mut engine = FlatOptimizer::new(kind, layout, cfg.n_shards, mode)?;
    let inv = 1.0 / n_ranks as f32;
    let step_comm = allreduce_bucket_time(
        (layout.params_len * 4) as f64,
        n_ranks,
        cfg.fabric,
    );
    let mut blob = blob0.to_vec();
    let mut rank_grads = vec![vec![0f32; layout.params_len]; n_ranks];
    let mut grad = vec![0f32; layout.params_len];
    let (mut compute, mut comm) = (0.0f64, 0.0f64);
    for t in 1..=cfg.steps as u64 {
        for (src, g) in sources.iter_mut().zip(rank_grads.iter_mut()) {
            src.fill(t, g);
        }
        let refs: Vec<&[f32]> =
            rank_grads.iter().map(|g| g.as_slice()).collect();
        pool::par_average(&mut grad, &refs, inv, cfg.n_shards);
        let t0 = Instant::now();
        engine.step(&mut blob, &grad, t, cfg.lr, cfg.wd)?;
        compute += t0.elapsed().as_secs_f64();
        comm += step_comm;
    }
    let exposed = compute + comm;
    Ok((
        blob,
        PipelineReport {
            n_ranks,
            steps: cfg.steps,
            n_buckets: 1,
            compute_secs: compute,
            comm_secs: comm,
            exposed_secs: exposed,
            overlap_efficiency: 1.0,
            wall_secs: started.elapsed().as_secs_f64(),
            // The lockstep path holds every rank's full gradient image.
            peak_live_grad_bytes: 4 * layout.params_len,
            full_grad_bytes: 4 * layout.params_len,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::flat::synthetic_layout;

    #[test]
    fn bucket_plan_tiles_exactly() {
        for (n, b) in [(100usize, 7usize), (64, 64), (64, 100), (1, 1)] {
            let plan = BucketPlan::new(n, b);
            let mut expect = 0usize;
            for &(lo, hi) in &plan.buckets {
                assert_eq!(lo, expect);
                assert!(hi > lo && hi - lo <= b);
                expect = hi;
            }
            assert_eq!(expect, n);
            assert_eq!(plan.n_buckets(), n.div_ceil(b));
        }
    }

    #[test]
    fn ready_schedule_partitions_tasks() {
        let layout = synthetic_layout(
            OptKind::AdaLomo,
            &[
                ("embed", &[16, 8][..]),
                ("l0.wq", &[8, 8][..]),
                ("final_norm", &[8][..]),
                ("head", &[8, 16][..]),
            ],
        );
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let extents = engine.task_extents();
        for bucket_elems in [1usize, 13, 64, layout.params_len] {
            let plan = BucketPlan::new(layout.params_len, bucket_elems);
            let ready = plan.ready_schedule(&extents);
            let mut seen: Vec<usize> =
                ready.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(
                seen,
                (0..extents.len()).collect::<Vec<_>>(),
                "bucket_elems={bucket_elems}"
            );
            for list in &ready {
                assert!(list.windows(2).all(|w| w[0] < w[1]));
            }
            // A task is scheduled on the bucket holding its last element.
            for (ti, &(off, size)) in extents.iter().enumerate() {
                let b = ready.iter().position(|l| l.contains(&ti)).unwrap();
                let (lo, hi) = plan.buckets[b];
                let last = off + size - 1;
                assert!(lo <= last && last < hi);
            }
        }
    }

    #[test]
    fn backward_ready_schedule_partitions_tasks() {
        let layout = synthetic_layout(
            OptKind::AdaLomo,
            &[
                ("embed", &[16, 8][..]),
                ("l0.wq", &[8, 8][..]),
                ("final_norm", &[8][..]),
                ("head", &[8, 16][..]),
            ],
        );
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let extents = engine.task_extents();
        for bucket_elems in [1usize, 13, 64, layout.params_len] {
            let plan = BucketPlan::new(layout.params_len, bucket_elems);
            let ready = plan.ready_schedule_backward(&extents);
            let mut seen: Vec<usize> =
                ready.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(
                seen,
                (0..extents.len()).collect::<Vec<_>>(),
                "bucket_elems={bucket_elems}"
            );
            for list in &ready {
                assert!(list.windows(2).all(|w| w[0] < w[1]));
            }
            // A task is scheduled on the bucket holding its FIRST element
            // (all later-offset buckets have landed in the descending
            // walk).
            for (ti, &(off, _)) in extents.iter().enumerate() {
                let b = ready.iter().position(|l| l.contains(&ti)).unwrap();
                let (lo, hi) = plan.buckets[b];
                assert!(lo <= off && off < hi);
            }
        }
    }

    #[test]
    fn adaptive_bucket_bounds_fabric_latency() {
        let c = 2e-9; // 2 ns per element of optimizer step
        let frac = ADAPTIVE_COMM_FRACTION;
        let params_len = 50_000_000usize;
        let fabrics = [
            Fabric::default(),
            Fabric { alpha: 50e-6, bw: 25e9 },
            Fabric { alpha: 1e-6, bw: 400e9 },
        ];
        for fabric in fabrics {
            for n_ranks in [2usize, 4, 8] {
                let b = adaptive_bucket_elems(
                    params_len, n_ranks, fabric, c, frac,
                );
                assert!((1..=params_len).contains(&b));
                if b < params_len {
                    // The promised bound holds at the chosen size...
                    let comm =
                        allreduce_bucket_time((4 * b) as f64, n_ranks, fabric);
                    assert!(
                        comm <= frac * c * b as f64 * (1.0 + 1e-9),
                        "{fabric:?} x{n_ranks}: comm {comm} vs budget {}",
                        frac * c * b as f64
                    );
                    // ...and the latency tax breaks it one notch below
                    // (minimality of the choice).
                    if b > 1 {
                        let half = b / 2;
                        let comm_half = allreduce_bucket_time(
                            (4 * half) as f64,
                            n_ranks,
                            fabric,
                        );
                        assert!(
                            comm_half > frac * c * half as f64,
                            "{fabric:?} x{n_ranks}: half-size bucket \
                             should violate the budget"
                        );
                    }
                }
            }
        }
        // Chattier fabrics need coarser buckets.
        let quiet = adaptive_bucket_elems(
            params_len,
            4,
            Fabric { alpha: 1e-6, bw: 170e9 },
            c,
            frac,
        );
        let chatty = adaptive_bucket_elems(
            params_len,
            4,
            Fabric { alpha: 100e-6, bw: 170e9 },
            c,
            frac,
        );
        assert!(chatty > quiet, "{chatty} vs {quiet}");
        // Degenerate cases: single rank, or bandwidth alone over budget.
        assert_eq!(
            adaptive_bucket_elems(params_len, 1, Fabric::default(), c, frac),
            params_len
        );
        let starved = Fabric { alpha: 8e-6, bw: 1e6 };
        assert_eq!(
            adaptive_bucket_elems(params_len, 4, starved, c, frac),
            params_len
        );
    }

    #[test]
    fn synthetic_sources_replay_identically() {
        let mut a = synthetic_sources(2, 9, 0.1);
        let mut b = synthetic_sources(2, 9, 0.1);
        let mut ga = vec![0f32; 32];
        let mut gb = vec![0f32; 32];
        for step in 1..=3u64 {
            for r in 0..2 {
                a[r].fill(step, &mut ga);
                b[r].fill(step, &mut gb);
                assert_eq!(ga, gb, "rank {r} step {step}");
            }
        }
        // Distinct ranks draw distinct streams.
        a[0].fill(4, &mut ga);
        a[1].fill(4, &mut gb);
        assert_ne!(ga, gb);
    }

    #[test]
    fn host_eval_loss_is_reset_deterministic() {
        let params: Vec<f32> =
            (0..200).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut val = DataLoader::lm(Domain::C4, 41, 2, 16, 4_000);
        // Drift the loader, then score twice: reset() must pin the set.
        for _ in 0..7 {
            val.next_batch();
        }
        let a = host_eval_loss(&params, &mut val, 4);
        let b = host_eval_loss(&params, &mut val, 4);
        assert!(a > 0.0);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
