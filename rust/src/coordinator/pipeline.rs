//! Async rank pipeline: overlap gradient exchange with the flat optimizer
//! step.
//!
//! AdaLomo's fusion argument (PAPER.md §3) — hide the optimizer update
//! inside work that must happen anyway — applies across ranks too: while
//! the fabric is busy reducing one gradient bucket, the leader can already
//! be stepping the tensors completed by earlier buckets. This module is
//! that pipeline on the PR-1 flat engine, replacing the lockstep
//! clone-average-broadcast rounds of `workers::run_local_sgd` at gradient
//! granularity.
//!
//! # Bucket lifecycle
//!
//! The gradient image `[0, params_len)` is tiled by a [`BucketPlan`] into
//! fixed-size buckets. Each bucket moves through four phases:
//!
//! 1. **accumulate** — every rank thread computes its local gradient for
//!    the step and posts the bucket's range over a bounded channel (the
//!    fixed-depth channel is the backpressure a real exchange fabric
//!    applies);
//! 2. **reduce** — the leader receives one contribution per rank *in rank
//!    order* and combines them element-parallel on the worker pool
//!    ([`crate::optim::pool::par_average`] — bit-identical for any worker
//!    count), while charging the fabric the simulated per-bucket ring
//!    all-reduce cost ([`super::collective::allreduce_bucket_time`]);
//! 3. **step** — every task (trainable segment, fused-backward order)
//!    whose LAST overlapping bucket just landed becomes steppable and is
//!    handed to [`FlatOptimizer::step_tasks`]; per-task arithmetic is
//!    self-contained, so stepping tasks as their buckets complete is
//!    bitwise identical to one whole-image step with the same reduced
//!    gradient — the determinism contract pinned by the proptests;
//! 4. **broadcast** — the leader owns the canonical blob, so within the
//!    pipeline there is nothing to send back; across local-SGD rounds the
//!    broadcast half is `workers::Broadcast::Params`, the slim
//!    params-region sync.
//!
//! The [`PipelineReport`] quantifies the overlap: `exposed_secs` is the
//! modeled critical path (comm serialized on the fabric; each bucket's
//! optimizer work starts once its reduction lands and the previous
//! bucket's work has finished), which sits below `compute + comm` exactly
//! when the pipeline hides exchange behind stepping.

use std::sync::mpsc;
use std::thread;
use std::time::Instant;

use anyhow::{anyhow, ensure, Result};

use crate::data::tokenizer::PAD;
use crate::data::{DataLoader, Domain};
use crate::optim::flat::{FlatOptimizer, ShardMode};
use crate::optim::{pool, OptKind};
use crate::runtime::Layout;
use crate::util::rng::Pcg32;

use super::collective::{
    allreduce_bucket_time, bucketed_allreduce_times, Fabric,
};

/// Fixed-size exchange buckets tiling the gradient image `[0,
/// params_len)` in offset order.
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub params_len: usize,
    pub bucket_elems: usize,
    /// Half-open `[lo, hi)` ranges; the last bucket may be partial.
    pub buckets: Vec<(usize, usize)>,
}

impl BucketPlan {
    pub fn new(params_len: usize, bucket_elems: usize) -> BucketPlan {
        assert!(bucket_elems > 0, "bucket_elems must be positive");
        let mut buckets = Vec::new();
        let mut lo = 0usize;
        while lo < params_len {
            let hi = (lo + bucket_elems).min(params_len);
            buckets.push((lo, hi));
            lo = hi;
        }
        BucketPlan { params_len, bucket_elems, buckets }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// For every task extent (from [`FlatOptimizer::task_extents`]), the
    /// bucket whose reduction completes it: per-bucket lists of task
    /// indices. Each list is sorted (extents are scanned in index order)
    /// and the lists partition `0..extents.len()`.
    pub fn ready_schedule(&self, extents: &[(usize, usize)]) -> Vec<Vec<usize>> {
        let mut ready = vec![Vec::new(); self.buckets.len()];
        for (ti, &(off, size)) in extents.iter().enumerate() {
            let last = off + size.max(1) - 1;
            let b = self
                .buckets
                .iter()
                .position(|&(lo, hi)| lo <= last && last < hi)
                .expect("task extent outside the bucketed region");
            ready[b].push(ti);
        }
        ready
    }
}

/// Per-rank gradient producer for the host-mirror pipeline. `fill` must be
/// deterministic in (its own seeded state, step): the bitwise-identity
/// guarantee quantifies only the exchange/step scheduling, so the
/// pipelined and sequential paths must see identical rank gradients.
pub trait GradSource: Send {
    fn fill(&mut self, step: u64, out: &mut [f32]);
}

/// Deterministic synthetic gradients from a rank-seeded PRNG stream — the
/// host-mirror stand-in for a backward pass.
pub struct SyntheticGrads {
    rng: Pcg32,
    scale: f32,
}

impl SyntheticGrads {
    pub fn new(seed: u64, rank: usize, scale: f32) -> SyntheticGrads {
        // Same rank-seed spacing as the local-SGD workers' data streams.
        SyntheticGrads {
            rng: Pcg32::new(seed + 1000 * rank as u64, 13),
            scale,
        }
    }
}

impl GradSource for SyntheticGrads {
    fn fill(&mut self, _step: u64, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.rng.normal() * self.scale;
        }
    }
}

/// Data-conditioned pseudo-gradients: every non-PAD (token, next-token)
/// pair in the rank's next batch pushes a pair of hashed parameter slots
/// together. Not a model backward — a stand-in whose gradient genuinely
/// depends on the rank's data stream, so data-order bugs change the final
/// parameters (and hence [`host_eval_loss`]).
pub struct TokenGrads {
    loader: DataLoader,
    scale: f32,
}

impl TokenGrads {
    pub fn new(loader: DataLoader, scale: f32) -> TokenGrads {
        TokenGrads { loader, scale }
    }
}

impl GradSource for TokenGrads {
    fn fill(&mut self, _step: u64, out: &mut [f32]) {
        out.fill(0.0);
        let batch = self.loader.next_batch();
        for (i, (&x, &y)) in batch.x.iter().zip(&batch.y).enumerate() {
            if y == PAD {
                continue;
            }
            out[token_slot(x, i, out.len())] += self.scale;
            out[token_slot(y, i + 1, out.len())] -= self.scale;
        }
    }
}

/// One rank's worth of [`SyntheticGrads`] per rank.
pub fn synthetic_sources(
    n_ranks: usize,
    seed: u64,
    scale: f32,
) -> Vec<Box<dyn GradSource>> {
    (0..n_ranks)
        .map(|r| {
            Box::new(SyntheticGrads::new(seed, r, scale))
                as Box<dyn GradSource>
        })
        .collect()
}

/// One independent [`TokenGrads`] data stream per rank (rank-seed spacing
/// as in `workers::run_local_sgd`).
pub fn token_sources(
    domain: Domain,
    seed: u64,
    n_ranks: usize,
    b: usize,
    t: usize,
    n_tokens: usize,
    scale: f32,
) -> Vec<Box<dyn GradSource>> {
    (0..n_ranks)
        .map(|r| {
            let loader =
                DataLoader::lm(domain, seed + 1000 * r as u64, b, t, n_tokens);
            Box::new(TokenGrads::new(loader, scale)) as Box<dyn GradSource>
        })
        .collect()
}

/// Deterministic parameter slot for a (token, position) pair — the hash
/// shared by the gradient and eval stand-ins, so the eval actually reads
/// the slots training moved.
fn token_slot(tok: i32, pos: usize, n: usize) -> usize {
    (tok as usize)
        .wrapping_mul(2654435761)
        .wrapping_add(pos.wrapping_mul(40503))
        % n
}

/// Deterministic host-side validation loss over a FIXED validation set:
/// the loader is rewound to its pristine order first (PR 1's
/// [`DataLoader::reset`] determinism fix), so every call scores the same
/// batches — two parameter images produce bitwise-equal losses iff they
/// agree on every slot the validation tokens touch.
pub fn host_eval_loss(
    params: &[f32],
    val: &mut DataLoader,
    n_batches: usize,
) -> f64 {
    val.reset();
    let n_batches = n_batches.clamp(1, val.batches_per_epoch().max(1));
    let mut loss = 0.0f64;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let batch = val.next_batch();
        for (i, (&x, &y)) in batch.x.iter().zip(&batch.y).enumerate() {
            if y == PAD {
                continue;
            }
            let d = (params[token_slot(x, i, params.len())]
                - params[token_slot(y, i + 1, params.len())])
                as f64;
            loss += d * d;
            count += 1;
        }
    }
    loss / count.max(1) as f64
}

/// Knobs shared by the pipelined and sequential drivers. Both paths must
/// run the same config for the bitwise-identity guarantee to apply (the
/// engine shard count fixes the reduction associativity).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub steps: usize,
    pub bucket_elems: usize,
    pub lr: f32,
    pub wd: f32,
    /// Worker shards for the leader's flat engine (and the bucket
    /// reduction). Results are deterministic for a FIXED value.
    pub n_shards: usize,
    pub fabric: Fabric,
}

impl PipelineConfig {
    pub fn new(steps: usize, bucket_elems: usize) -> PipelineConfig {
        PipelineConfig {
            steps,
            bucket_elems,
            lr: 1e-2,
            wd: 0.0,
            n_shards: 2,
            fabric: Fabric::default(),
        }
    }
}

/// What the pipeline measured/modeled. `compute_secs` is measured wall
/// time inside `step_tasks`; `comm_secs` is the simulated fabric cost of
/// the bucketed ring all-reduces; `exposed_secs` is the modeled critical
/// path of the bucketed schedule.
#[derive(Debug, Clone)]
pub struct PipelineReport {
    pub n_ranks: usize,
    pub steps: usize,
    pub n_buckets: usize,
    pub compute_secs: f64,
    pub comm_secs: f64,
    pub exposed_secs: f64,
    /// `(compute + comm) / exposed` — 1.0 means nothing overlapped;
    /// higher is better (2.0 would mean perfect hiding of the smaller
    /// side).
    pub overlap_efficiency: f64,
    pub wall_secs: f64,
}

/// Run the bucketed rank pipeline: per-rank worker threads exchange
/// gradient buckets over bounded channels while the leader reduces (rank
/// order) and steps ready tasks. Returns the final blob and the overlap
/// report. Bitwise-identical to [`run_sequential`] under the same config
/// and sources.
pub fn run_pipelined(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, PipelineReport)> {
    ensure!(!sources.is_empty(), "need at least one rank");
    ensure!(
        blob0.len() == layout.blob_len,
        "blob len {} != layout {}",
        blob0.len(),
        layout.blob_len
    );
    let n_ranks = sources.len();
    let started = Instant::now();
    let mut engine = FlatOptimizer::new(kind, layout, cfg.n_shards, mode)?;
    let plan = BucketPlan::new(layout.params_len, cfg.bucket_elems);
    let ready = plan.ready_schedule(&engine.task_extents());
    // Fabric cost per bucket: the collective module's bucketed tiling is
    // byte-for-byte the same as BucketPlan's element tiling (4 bytes per
    // f32, ragged last bucket included) — one costing source, not two.
    let bucket_comm = bucketed_allreduce_times(
        (layout.params_len * 4) as f64,
        (cfg.bucket_elems * 4) as f64,
        n_ranks,
        cfg.fabric,
    );
    debug_assert_eq!(bucket_comm.len(), plan.n_buckets());

    // Rank threads: compute the step's gradient, then stream it out
    // bucket-by-bucket. The bounded channel depth is the exchange
    // fabric's backpressure — a rank can run at most two buckets ahead of
    // the reduction.
    let mut handles = Vec::with_capacity(n_ranks);
    let mut rx_ranks = Vec::with_capacity(n_ranks);
    for mut src in sources {
        let (tx, rx) = mpsc::sync_channel::<Vec<f32>>(2);
        rx_ranks.push(rx);
        let buckets = plan.buckets.clone();
        let params_len = layout.params_len;
        let steps = cfg.steps;
        handles.push(thread::spawn(move || {
            let mut grad = vec![0f32; params_len];
            for step in 1..=steps as u64 {
                src.fill(step, &mut grad);
                for &(lo, hi) in &buckets {
                    if tx.send(grad[lo..hi].to_vec()).is_err() {
                        return; // leader bailed; stop producing
                    }
                }
            }
        }));
    }

    let outcome =
        leader_loop(&mut engine, &plan, &ready, &bucket_comm, &rx_ranks, blob0, cfg);
    // Unblock any rank still parked on a bounded send before joining (the
    // error path stops receiving mid-stream).
    drop(rx_ranks);
    for h in handles {
        h.join().map_err(|_| anyhow!("rank thread panicked"))?;
    }
    let (blob, compute_secs, comm_secs, exposed_secs) = outcome?;

    let overlap_efficiency = if exposed_secs > 0.0 {
        (compute_secs + comm_secs) / exposed_secs
    } else {
        1.0
    };
    Ok((
        blob,
        PipelineReport {
            n_ranks,
            steps: cfg.steps,
            n_buckets: plan.n_buckets(),
            compute_secs,
            comm_secs,
            exposed_secs,
            overlap_efficiency,
            wall_secs: started.elapsed().as_secs_f64(),
        },
    ))
}

/// The leader half of [`run_pipelined`]: reduce buckets in rank order,
/// step ready tasks, advance the modeled timeline. Returns `(blob,
/// compute, comm, exposed)`.
fn leader_loop(
    engine: &mut FlatOptimizer,
    plan: &BucketPlan,
    ready: &[Vec<usize>],
    bucket_comm: &[f64],
    rx_ranks: &[mpsc::Receiver<Vec<f32>>],
    blob0: &[f32],
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, f64, f64, f64)> {
    let n_ranks = rx_ranks.len();
    let inv = 1.0 / n_ranks as f32;
    let mut blob = blob0.to_vec();
    let mut grad = vec![0f32; plan.params_len];
    let (mut compute, mut comm, mut exposed) = (0.0f64, 0.0f64, 0.0f64);
    for t in 1..=cfg.steps as u64 {
        // Modeled per-step timeline: comm is serialized on the fabric
        // (`comm_front`); bucket b's optimizer work starts at
        // max(its reduction landing, previous work finishing).
        let mut comm_front = 0.0f64;
        let mut work_front = 0.0f64;
        for (b, &(lo, hi)) in plan.buckets.iter().enumerate() {
            // Accumulate: one contribution per rank, received in rank
            // order — the fixed reduction order determinism rests on.
            let mut chunks = Vec::with_capacity(n_ranks);
            for rx in rx_ranks {
                let chunk = rx.recv().map_err(|_| {
                    anyhow!("rank gradient stream ended early")
                })?;
                ensure!(chunk.len() == hi - lo, "bucket size mismatch");
                chunks.push(chunk);
            }
            // Reduce: mean in rank order, element-parallel on the pool
            // (bit-identical for any worker count).
            let refs: Vec<&[f32]> =
                chunks.iter().map(|c| c.as_slice()).collect();
            pool::par_average(&mut grad[lo..hi], &refs, inv, cfg.n_shards);
            comm_front += bucket_comm[b];
            comm += bucket_comm[b];
            // Step: every task whose last bucket just landed.
            let dt = if ready[b].is_empty() {
                0.0
            } else {
                let t0 = Instant::now();
                engine.step_tasks(
                    &mut blob, &grad, t, cfg.lr, cfg.wd, &ready[b],
                )?;
                t0.elapsed().as_secs_f64()
            };
            compute += dt;
            work_front = comm_front.max(work_front) + dt;
        }
        exposed += comm_front.max(work_front);
    }
    Ok((blob, compute, comm, exposed))
}

/// Lockstep reference: reduce the FULL gradient image (same rank order,
/// same element-wise associativity as the bucketed reduction), then one
/// whole-image engine step — the PR-1 flat-engine path the pipeline must
/// match bitwise. Comm is modeled as one monolithic ring all-reduce per
/// step, fully exposed.
pub fn run_sequential(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    mut sources: Vec<Box<dyn GradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, PipelineReport)> {
    ensure!(!sources.is_empty(), "need at least one rank");
    ensure!(
        blob0.len() == layout.blob_len,
        "blob len {} != layout {}",
        blob0.len(),
        layout.blob_len
    );
    let n_ranks = sources.len();
    let started = Instant::now();
    let mut engine = FlatOptimizer::new(kind, layout, cfg.n_shards, mode)?;
    let inv = 1.0 / n_ranks as f32;
    let step_comm = allreduce_bucket_time(
        (layout.params_len * 4) as f64,
        n_ranks,
        cfg.fabric,
    );
    let mut blob = blob0.to_vec();
    let mut rank_grads = vec![vec![0f32; layout.params_len]; n_ranks];
    let mut grad = vec![0f32; layout.params_len];
    let (mut compute, mut comm) = (0.0f64, 0.0f64);
    for t in 1..=cfg.steps as u64 {
        for (src, g) in sources.iter_mut().zip(rank_grads.iter_mut()) {
            src.fill(t, g);
        }
        let refs: Vec<&[f32]> =
            rank_grads.iter().map(|g| g.as_slice()).collect();
        pool::par_average(&mut grad, &refs, inv, cfg.n_shards);
        let t0 = Instant::now();
        engine.step(&mut blob, &grad, t, cfg.lr, cfg.wd)?;
        compute += t0.elapsed().as_secs_f64();
        comm += step_comm;
    }
    let exposed = compute + comm;
    Ok((
        blob,
        PipelineReport {
            n_ranks,
            steps: cfg.steps,
            n_buckets: 1,
            compute_secs: compute,
            comm_secs: comm,
            exposed_secs: exposed,
            overlap_efficiency: 1.0,
            wall_secs: started.elapsed().as_secs_f64(),
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::flat::synthetic_layout;

    #[test]
    fn bucket_plan_tiles_exactly() {
        for (n, b) in [(100usize, 7usize), (64, 64), (64, 100), (1, 1)] {
            let plan = BucketPlan::new(n, b);
            let mut expect = 0usize;
            for &(lo, hi) in &plan.buckets {
                assert_eq!(lo, expect);
                assert!(hi > lo && hi - lo <= b);
                expect = hi;
            }
            assert_eq!(expect, n);
            assert_eq!(plan.n_buckets(), n.div_ceil(b));
        }
    }

    #[test]
    fn ready_schedule_partitions_tasks() {
        let layout = synthetic_layout(
            OptKind::AdaLomo,
            &[
                ("embed", &[16, 8][..]),
                ("l0.wq", &[8, 8][..]),
                ("final_norm", &[8][..]),
                ("head", &[8, 16][..]),
            ],
        );
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let extents = engine.task_extents();
        for bucket_elems in [1usize, 13, 64, layout.params_len] {
            let plan = BucketPlan::new(layout.params_len, bucket_elems);
            let ready = plan.ready_schedule(&extents);
            let mut seen: Vec<usize> =
                ready.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(
                seen,
                (0..extents.len()).collect::<Vec<_>>(),
                "bucket_elems={bucket_elems}"
            );
            for list in &ready {
                assert!(list.windows(2).all(|w| w[0] < w[1]));
            }
            // A task is scheduled on the bucket holding its last element.
            for (ti, &(off, size)) in extents.iter().enumerate() {
                let b = ready.iter().position(|l| l.contains(&ti)).unwrap();
                let (lo, hi) = plan.buckets[b];
                let last = off + size - 1;
                assert!(lo <= last && last < hi);
            }
        }
    }

    #[test]
    fn synthetic_sources_replay_identically() {
        let mut a = synthetic_sources(2, 9, 0.1);
        let mut b = synthetic_sources(2, 9, 0.1);
        let mut ga = vec![0f32; 32];
        let mut gb = vec![0f32; 32];
        for step in 1..=3u64 {
            for r in 0..2 {
                a[r].fill(step, &mut ga);
                b[r].fill(step, &mut gb);
                assert_eq!(ga, gb, "rank {r} step {step}");
            }
        }
        // Distinct ranks draw distinct streams.
        a[0].fill(4, &mut ga);
        a[1].fill(4, &mut gb);
        assert_ne!(ga, gb);
    }

    #[test]
    fn host_eval_loss_is_reset_deterministic() {
        let params: Vec<f32> =
            (0..200).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut val = DataLoader::lm(Domain::C4, 41, 2, 16, 4_000);
        // Drift the loader, then score twice: reset() must pin the set.
        for _ in 0..7 {
            val.next_batch();
        }
        let a = host_eval_loss(&params, &mut val, 4);
        let b = host_eval_loss(&params, &mut val, 4);
        assert!(a > 0.0);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
