//! Async rank pipeline: bucket plans, gradient sources and the public
//! entry points for exchange-overlapped training.
//!
//! AdaLomo's fusion argument (PAPER.md §3) — hide the optimizer update
//! inside work that must happen anyway — applies across ranks too: while
//! the fabric is busy reducing one gradient bucket, the leader can already
//! be stepping the tensors completed by earlier buckets. The execution
//! itself lives in the unified engine ([`super::engine`]); this module
//! keeps the pipeline's vocabulary — [`BucketPlan`] tiling, per-rank
//! [`GradSource`]s, the [`PipelineConfig`] knob set, the adaptive bucket
//! sizing — plus [`run_sequential`], [`run_pipelined`] and
//! [`run_pipelined_fused`], which are now thin [`ExecPlan`] constructors
//! over the one leader loop.
//!
//! # Bucket lifecycle
//!
//! The gradient image `[0, params_len)` is tiled by a [`BucketPlan`] into
//! fixed-size buckets. Each bucket moves through four phases:
//!
//! 1. **accumulate** — every rank thread computes its local gradient for
//!    the step and posts the bucket's range over a bounded channel (the
//!    fixed-depth channel is the backpressure a real exchange fabric
//!    applies);
//! 2. **reduce** — the leader receives one contribution per rank *in rank
//!    order* and combines them element-parallel on the worker pool
//!    ([`crate::optim::pool::par_average`] — bit-identical for any worker
//!    count), while charging the fabric the simulated per-bucket ring
//!    all-reduce cost ([`super::collective::allreduce_bucket_time`]);
//! 3. **step** — every task (trainable segment, fused-backward order)
//!    whose completing bucket just landed is handed to
//!    [`crate::optim::flat::FlatOptimizer::step_tasks`]; per-task
//!    arithmetic is
//!    self-contained, so stepping tasks as their buckets complete is
//!    bitwise identical to one whole-image step with the same reduced
//!    gradient — the determinism contract pinned by the proptests;
//! 4. **broadcast** — the leader owns the canonical blob, so within the
//!    pipeline there is nothing to send back; across local-SGD rounds the
//!    broadcast half is `workers::Broadcast::Params`, the slim
//!    params-region sync.
//!
//! The returned [`EngineReport`] quantifies the overlap: `exposed_secs`
//! is the modeled critical path (comm serialized on the fabric; each
//! bucket's optimizer work starts once its reduction lands and the
//! previous bucket's work has finished), which sits below `compute +
//! comm` exactly when the pipeline hides exchange behind stepping.

use anyhow::Result;

use crate::data::tokenizer::PAD;
use crate::data::{DataLoader, Domain};
use crate::optim::flat::ShardMode;
use crate::optim::OptKind;
use crate::runtime::Layout;
use crate::tensor::Dtype;
use crate::util::rng::Pcg32;

use super::collective::{Fabric, HierFabric, WireCodec};
use super::engine::{Engine, EngineReport, ExecPlan, RankSources};
use super::fused_host::GroupGradSource;

/// Fixed-size exchange buckets tiling the gradient image `[0,
/// params_len)` in offset order.
///
/// ```
/// use adalomo::coordinator::pipeline::BucketPlan;
///
/// let plan = BucketPlan::new(10, 4);
/// assert_eq!(plan.buckets, vec![(0, 4), (4, 8), (8, 10)]);
/// assert_eq!(plan.n_buckets(), 3);
/// ```
#[derive(Debug, Clone)]
pub struct BucketPlan {
    pub params_len: usize,
    pub bucket_elems: usize,
    /// Half-open `[lo, hi)` ranges; the last bucket may be partial.
    pub buckets: Vec<(usize, usize)>,
}

impl BucketPlan {
    pub fn new(params_len: usize, bucket_elems: usize) -> BucketPlan {
        assert!(bucket_elems > 0, "bucket_elems must be positive");
        let mut buckets = Vec::new();
        let mut lo = 0usize;
        while lo < params_len {
            let hi = (lo + bucket_elems).min(params_len);
            buckets.push((lo, hi));
            lo = hi;
        }
        BucketPlan { params_len, bucket_elems, buckets }
    }

    pub fn n_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// For every task extent (from
    /// [`crate::optim::flat::FlatOptimizer::task_extents`]), the
    /// bucket whose reduction completes it: per-bucket lists of task
    /// indices. Each list is sorted (extents are scanned in index order)
    /// and the lists partition `0..extents.len()`.
    pub fn ready_schedule(&self, extents: &[(usize, usize)]) -> Vec<Vec<usize>> {
        // Ascending walk: a task completes with its LAST element.
        self.schedule_by(extents, |off, size| off + size.max(1) - 1)
    }

    /// [`Self::ready_schedule`] for a DESCENDING bucket walk (grouped
    /// production): when buckets land in reverse offset order — the order
    /// group-by-group backward production covers them — a task is
    /// completed by the bucket holding its FIRST element (every
    /// later-offset bucket has already landed). Same guarantees: sorted
    /// per-bucket lists partitioning the task indices.
    pub fn ready_schedule_backward(
        &self,
        extents: &[(usize, usize)],
    ) -> Vec<Vec<usize>> {
        self.schedule_by(extents, |off, _| off)
    }

    /// Shared body of the two schedules: bucket the anchor element of
    /// every extent. The fixed-size tiling makes the lookup a division
    /// (bucket i covers `[i*bucket_elems, ..)`, last bucket ragged).
    fn schedule_by(
        &self,
        extents: &[(usize, usize)],
        anchor: impl Fn(usize, usize) -> usize,
    ) -> Vec<Vec<usize>> {
        let mut ready = vec![Vec::new(); self.buckets.len()];
        for (ti, &(off, size)) in extents.iter().enumerate() {
            let a = anchor(off, size);
            let b = a / self.bucket_elems;
            assert!(
                b < self.buckets.len(),
                "task extent outside the bucketed region"
            );
            debug_assert!(
                self.buckets[b].0 <= a && a < self.buckets[b].1,
                "bucket tiling broke the division lookup"
            );
            ready[b].push(ti);
        }
        ready
    }
}

/// Per-rank gradient producer for the host-mirror pipeline. `fill` must be
/// deterministic in (its own seeded state, step): the bitwise-identity
/// guarantee quantifies only the exchange/step scheduling, so the
/// pipelined and sequential paths must see identical rank gradients.
pub trait GradSource: Send {
    fn fill(&mut self, step: u64, out: &mut [f32]);

    /// Advance past `step` without consuming its gradient — how a resumed
    /// run fast-forwards a stream-stateful source to the checkpointed
    /// position. The default produces-and-discards into `scratch`
    /// (`scratch.len()` is the gradient image); step-keyed sources
    /// override it with a no-op.
    fn skip(&mut self, step: u64, scratch: &mut [f32]) {
        self.fill(step, scratch);
    }
}

/// Deterministic synthetic gradients from a rank-seeded PRNG stream — the
/// host-mirror stand-in for a backward pass.
pub struct SyntheticGrads {
    rng: Pcg32,
    scale: f32,
}

impl SyntheticGrads {
    pub fn new(seed: u64, rank: usize, scale: f32) -> SyntheticGrads {
        // Same rank-seed spacing as the local-SGD workers' data streams.
        SyntheticGrads {
            rng: Pcg32::new(seed + 1000 * rank as u64, 13),
            scale,
        }
    }
}

impl GradSource for SyntheticGrads {
    fn fill(&mut self, _step: u64, out: &mut [f32]) {
        for x in out.iter_mut() {
            *x = self.rng.normal() * self.scale;
        }
    }
}

/// Data-conditioned pseudo-gradients: every non-PAD (token, next-token)
/// pair in the rank's next batch pushes a pair of hashed parameter slots
/// together. Not a model backward — a stand-in whose gradient genuinely
/// depends on the rank's data stream, so data-order bugs change the final
/// parameters (and hence [`host_eval_loss`]).
pub struct TokenGrads {
    loader: DataLoader,
    scale: f32,
}

impl TokenGrads {
    pub fn new(loader: DataLoader, scale: f32) -> TokenGrads {
        TokenGrads { loader, scale }
    }
}

impl GradSource for TokenGrads {
    fn fill(&mut self, _step: u64, out: &mut [f32]) {
        out.fill(0.0);
        let batch = self.loader.next_batch();
        for (i, (&x, &y)) in batch.x.iter().zip(&batch.y).enumerate() {
            if y == PAD {
                continue;
            }
            out[token_slot(x, i, out.len())] += self.scale;
            out[token_slot(y, i + 1, out.len())] -= self.scale;
        }
    }
}

/// One rank's worth of [`SyntheticGrads`] per rank.
pub fn synthetic_sources(
    n_ranks: usize,
    seed: u64,
    scale: f32,
) -> Vec<Box<dyn GradSource>> {
    (0..n_ranks)
        .map(|r| {
            Box::new(SyntheticGrads::new(seed, r, scale))
                as Box<dyn GradSource>
        })
        .collect()
}

/// One independent [`TokenGrads`] data stream per rank (rank-seed spacing
/// as in `workers::run_local_sgd`).
pub fn token_sources(
    domain: Domain,
    seed: u64,
    n_ranks: usize,
    b: usize,
    t: usize,
    n_tokens: usize,
    scale: f32,
) -> Vec<Box<dyn GradSource>> {
    (0..n_ranks)
        .map(|r| {
            let loader =
                DataLoader::lm(domain, seed + 1000 * r as u64, b, t, n_tokens);
            Box::new(TokenGrads::new(loader, scale)) as Box<dyn GradSource>
        })
        .collect()
}

/// Deterministic parameter slot for a (token, position) pair — the hash
/// shared by the gradient and eval stand-ins, so the eval actually reads
/// the slots training moved.
fn token_slot(tok: i32, pos: usize, n: usize) -> usize {
    (tok as usize)
        .wrapping_mul(2654435761)
        .wrapping_add(pos.wrapping_mul(40503))
        % n
}

/// Deterministic host-side validation loss over a FIXED validation set:
/// the loader is rewound to its pristine order first (PR 1's
/// [`DataLoader::reset`] determinism fix), so every call scores the same
/// batches — two parameter images produce bitwise-equal losses iff they
/// agree on every slot the validation tokens touch.
pub fn host_eval_loss(
    params: &[f32],
    val: &mut DataLoader,
    n_batches: usize,
) -> f64 {
    val.reset();
    let n_batches = n_batches.clamp(1, val.batches_per_epoch().max(1));
    let mut loss = 0.0f64;
    let mut count = 0usize;
    for _ in 0..n_batches {
        let batch = val.next_batch();
        for (i, (&x, &y)) in batch.x.iter().zip(&batch.y).enumerate() {
            if y == PAD {
                continue;
            }
            let d = (params[token_slot(x, i, params.len())]
                - params[token_slot(y, i + 1, params.len())])
                as f64;
            loss += d * d;
            count += 1;
        }
    }
    loss / count.max(1) as f64
}

/// Knobs shared by every execution path. All paths must run the same
/// config for the bitwise-identity guarantee to apply (the engine shard
/// count fixes the reduction associativity).
#[derive(Debug, Clone)]
pub struct PipelineConfig {
    pub steps: usize,
    pub bucket_elems: usize,
    pub lr: f32,
    pub wd: f32,
    /// Worker shards for the leader's flat engine (and the bucket
    /// reduction). Results are deterministic for a FIXED value.
    pub n_shards: usize,
    pub fabric: Fabric,
    /// Storage dtype of the blob (see `ExecPlan::dtype`);
    /// [`Dtype::F32`] by default.
    pub dtype: Dtype,
    /// Wire rung for the bucket exchange. `None` (the default) resolves
    /// at plan-construction time to
    /// [`WireCodec::default_for`]`(dtype)` — the wire follows the
    /// storage dtype unless a rung is chosen explicitly, so pre-ladder
    /// configs behave exactly as before. Resolution is deferred (rather
    /// than baked into [`Self::new`]) because callers routinely mutate
    /// `dtype` after construction.
    pub wire: Option<WireCodec>,
    /// Optional hierarchical fabric overlay (see `ExecPlan::topology`):
    /// when set, plans built from this config cost their exchange tiles
    /// through the two-level intra/inter-node model instead of the flat
    /// [`Fabric`] ring. Cost-model only; `None` by default.
    pub topology: Option<HierFabric>,
}

impl PipelineConfig {
    pub fn new(steps: usize, bucket_elems: usize) -> PipelineConfig {
        PipelineConfig {
            steps,
            bucket_elems,
            lr: 1e-2,
            wd: 0.0,
            n_shards: 2,
            fabric: Fabric::default(),
            dtype: Dtype::F32,
            wire: None,
            topology: None,
        }
    }

    /// The wire rung this config resolves to (explicit choice, else the
    /// storage dtype's default rung).
    pub fn wire_codec(&self) -> WireCodec {
        self.wire.unwrap_or(WireCodec::default_for(self.dtype))
    }

    /// [`Self::new`] with `bucket_elems` chosen by
    /// [`adaptive_bucket_elems`] under the default
    /// [`ADAPTIVE_COMM_FRACTION`] budget, for a measured per-element
    /// optimizer step cost on this machine and the wire rung the
    /// exchange will actually ship (`None` = the `dtype` default rung).
    pub fn adaptive(
        steps: usize,
        params_len: usize,
        n_ranks: usize,
        fabric: Fabric,
        step_secs_per_elem: f64,
        dtype: Dtype,
        wire: Option<WireCodec>,
    ) -> PipelineConfig {
        let codec = wire.unwrap_or(WireCodec::default_for(dtype));
        let bucket = adaptive_bucket_elems(
            params_len,
            n_ranks,
            fabric,
            step_secs_per_elem,
            ADAPTIVE_COMM_FRACTION,
            codec,
        );
        let mut cfg = PipelineConfig::new(steps, bucket);
        cfg.fabric = fabric;
        cfg.dtype = dtype;
        cfg.wire = wire;
        cfg
    }
}

/// Run the bucketed rank pipeline: per-rank worker threads exchange
/// gradient buckets over bounded channels while the leader reduces (rank
/// order) and steps ready tasks. Returns the final blob and the overlap
/// report. Bitwise-identical to [`run_sequential`] under the same config
/// and sources. Thin wrapper over [`ExecPlan::pipelined`] — full-image
/// production, ascending exchange, `step_tasks` granularity.
pub fn run_pipelined(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, EngineReport)> {
    let plan = ExecPlan::pipelined(kind, mode, sources.len(), cfg);
    let mut engine = Engine::new(layout, blob0, plan)?;
    let report = engine.run(RankSources::Full(sources))?;
    Ok((engine.into_blob(), report))
}

/// The fused-host pipeline: ranks produce their gradients GROUP BY GROUP
/// in fused-backward order ([`GroupGradSource`]) and ship each exchange
/// bucket the moment production has covered it, so the bucket exchange
/// overlaps actual gradient *production* — no rank ever materializes the
/// full gradient image. Buckets therefore move in DESCENDING offset order
/// and tasks step when the bucket holding their first element lands
/// ([`BucketPlan::ready_schedule_backward`]). Thin wrapper over
/// [`ExecPlan::pipelined_fused`].
///
/// Requires the engine's fused groups to tile the gradient image in
/// descending offset order (true for model-shaped layouts). Per-task
/// arithmetic is self-contained and the per-bucket reductions are
/// order-independent across disjoint ranges, so the result is bitwise
/// identical to [`run_pipelined`] and [`run_sequential`] fed the same
/// gradient values — pinned by the proptests.
///
/// The returned report's `peak_live_grad_bytes` is MEASURED: the most
/// produced-but-unshipped group-buffer bytes any rank ever held (a group
/// buffer is freed once the shipped region covers it). With buckets no
/// larger than a group this tops out at two groups — the §2.1 bound —
/// and by construction it can never exceed the full image.
pub fn run_pipelined_fused(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GroupGradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, EngineReport)> {
    let plan = ExecPlan::pipelined_fused(kind, mode, sources.len(), cfg);
    let mut engine = Engine::new(layout, blob0, plan)?;
    let report = engine.run(RankSources::Grouped(sources))?;
    Ok((engine.into_blob(), report))
}

/// Lockstep reference: reduce the FULL gradient image (same rank order,
/// same element-wise associativity as the bucketed reduction), then one
/// whole-image engine step — the path the pipelines must match bitwise.
/// Comm is modeled as one monolithic ring all-reduce per step, fully
/// exposed. Thin wrapper over [`ExecPlan::sequential`].
pub fn run_sequential(
    layout: &Layout,
    kind: OptKind,
    mode: ShardMode,
    blob0: &[f32],
    sources: Vec<Box<dyn GradSource>>,
    cfg: &PipelineConfig,
) -> Result<(Vec<f32>, EngineReport)> {
    let plan = ExecPlan::sequential(kind, mode, sources.len(), cfg);
    let mut engine = Engine::new(layout, blob0, plan)?;
    let report = engine.run(RankSources::Full(sources))?;
    Ok((engine.into_blob(), report))
}

/// Fraction of per-bucket optimizer compute the per-bucket fabric cost is
/// allowed to reach when [`adaptive_bucket_elems`] picks the bucket size.
pub const ADAPTIVE_COMM_FRACTION: f64 = 0.5;

/// Pick [`PipelineConfig::bucket_elems`] from the fabric model: the
/// smallest bucket — smaller buckets mean earlier first steps and finer
/// overlap — whose per-bucket ring all-reduce cost stays within
/// `comm_fraction` of its per-bucket optimizer compute
/// (`step_secs_per_elem`; measure it with `bench_micro_optim`).
///
/// Every bucket re-pays the full `2(n-1)` hop latencies
/// ([`super::collective::bucketed_allreduce_times`]), so below the
/// returned size the latency tax alone breaks the bound: with `e`
/// wire bytes per element ([`WireCodec::elem_bytes`] — 4 for f32, 2
/// for bf16, 1.0625 for blockwise q8; an earlier version hard-coded
/// `2e = 8.0`, silently oversizing bf16 buckets),
/// `comm(b) = 2(n-1)(alpha + e*b/(n*bw)) <= f * b * c` solves to
/// `b >= 2(n-1)alpha / (f*c - 2e(n-1)/(n*bw))`. If the denominator is
/// not positive — the bandwidth term alone exceeds the compute budget —
/// no bucket size can hide the exchange and the choice degenerates to
/// one monolithic bucket (minimizing the latency tax). A single rank
/// pays no fabric at all, with the same degenerate answer.
///
/// Compressed rungs shrink `e`, which both shrinks the bandwidth tax
/// and lets the solver afford FINER buckets — the end-to-end reward
/// the benches measure as higher overlap efficiency.
pub fn adaptive_bucket_elems(
    params_len: usize,
    n_ranks: usize,
    fabric: Fabric,
    step_secs_per_elem: f64,
    comm_fraction: f64,
    wire: WireCodec,
) -> usize {
    assert!(params_len > 0, "params_len must be positive");
    assert!(
        step_secs_per_elem > 0.0 && comm_fraction > 0.0,
        "step cost and comm fraction must be positive"
    );
    if n_ranks <= 1 {
        return params_len;
    }
    let n = n_ranks as f64;
    let e = wire.elem_bytes();
    let slack = comm_fraction * step_secs_per_elem
        - 2.0 * e * (n - 1.0) / (n * fabric.bw);
    if slack <= 0.0 {
        return params_len;
    }
    let b = (2.0 * (n - 1.0) * fabric.alpha / slack).ceil() as usize;
    b.clamp(1, params_len)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::collective::allreduce_bucket_time;
    use crate::optim::flat::{synthetic_layout, FlatOptimizer};

    #[test]
    fn bucket_plan_tiles_exactly() {
        for (n, b) in [(100usize, 7usize), (64, 64), (64, 100), (1, 1)] {
            let plan = BucketPlan::new(n, b);
            let mut expect = 0usize;
            for &(lo, hi) in &plan.buckets {
                assert_eq!(lo, expect);
                assert!(hi > lo && hi - lo <= b);
                expect = hi;
            }
            assert_eq!(expect, n);
            assert_eq!(plan.n_buckets(), n.div_ceil(b));
        }
    }

    #[test]
    fn ready_schedule_partitions_tasks() {
        let layout = synthetic_layout(
            OptKind::AdaLomo,
            &[
                ("embed", &[16, 8][..]),
                ("l0.wq", &[8, 8][..]),
                ("final_norm", &[8][..]),
                ("head", &[8, 16][..]),
            ],
        );
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let extents = engine.task_extents();
        for bucket_elems in [1usize, 13, 64, layout.params_len] {
            let plan = BucketPlan::new(layout.params_len, bucket_elems);
            let ready = plan.ready_schedule(&extents);
            let mut seen: Vec<usize> =
                ready.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(
                seen,
                (0..extents.len()).collect::<Vec<_>>(),
                "bucket_elems={bucket_elems}"
            );
            for list in &ready {
                assert!(list.windows(2).all(|w| w[0] < w[1]));
            }
            // A task is scheduled on the bucket holding its last element.
            for (ti, &(off, size)) in extents.iter().enumerate() {
                let b = ready.iter().position(|l| l.contains(&ti)).unwrap();
                let (lo, hi) = plan.buckets[b];
                let last = off + size - 1;
                assert!(lo <= last && last < hi);
            }
        }
    }

    #[test]
    fn backward_ready_schedule_partitions_tasks() {
        let layout = synthetic_layout(
            OptKind::AdaLomo,
            &[
                ("embed", &[16, 8][..]),
                ("l0.wq", &[8, 8][..]),
                ("final_norm", &[8][..]),
                ("head", &[8, 16][..]),
            ],
        );
        let engine = FlatOptimizer::new(
            OptKind::AdaLomo,
            &layout,
            1,
            ShardMode::Segments,
        )
        .unwrap();
        let extents = engine.task_extents();
        for bucket_elems in [1usize, 13, 64, layout.params_len] {
            let plan = BucketPlan::new(layout.params_len, bucket_elems);
            let ready = plan.ready_schedule_backward(&extents);
            let mut seen: Vec<usize> =
                ready.iter().flatten().copied().collect();
            seen.sort_unstable();
            assert_eq!(
                seen,
                (0..extents.len()).collect::<Vec<_>>(),
                "bucket_elems={bucket_elems}"
            );
            for list in &ready {
                assert!(list.windows(2).all(|w| w[0] < w[1]));
            }
            // A task is scheduled on the bucket holding its FIRST element
            // (all later-offset buckets have landed in the descending
            // walk).
            for (ti, &(off, _)) in extents.iter().enumerate() {
                let b = ready.iter().position(|l| l.contains(&ti)).unwrap();
                let (lo, hi) = plan.buckets[b];
                assert!(lo <= off && off < hi);
            }
        }
    }

    #[test]
    fn adaptive_bucket_bounds_fabric_latency() {
        let c = 2e-9; // 2 ns per element of optimizer step
        let frac = ADAPTIVE_COMM_FRACTION;
        let params_len = 50_000_000usize;
        let fabrics = [
            Fabric::default(),
            Fabric { alpha: 50e-6, bw: 25e9 },
            Fabric { alpha: 1e-6, bw: 400e9 },
        ];
        // All three wire rungs: the bound must hold against the REAL
        // per-bucket cost at that rung's bytes-per-element (the
        // regression this test pins: the bandwidth term used to
        // hard-code 8.0 = 2 x 4 bytes, oversizing compressed buckets).
        for wire in [WireCodec::F32, WireCodec::Bf16, WireCodec::Q8Block] {
            let e = wire.elem_bytes();
            for fabric in fabrics {
                for n_ranks in [2usize, 4, 8] {
                    let b = adaptive_bucket_elems(
                        params_len, n_ranks, fabric, c, frac, wire,
                    );
                    assert!((1..=params_len).contains(&b));
                    if b < params_len {
                        // The promised bound holds at the chosen size...
                        let comm = allreduce_bucket_time(
                            e * b as f64,
                            n_ranks,
                            fabric,
                        );
                        assert!(
                            comm <= frac * c * b as f64 * (1.0 + 1e-9),
                            "{wire:?} {fabric:?} x{n_ranks}: comm {comm} \
                             vs budget {}",
                            frac * c * b as f64
                        );
                        // ...and the latency tax breaks it one notch
                        // below (minimality of the choice).
                        if b > 1 {
                            let half = b / 2;
                            let comm_half = allreduce_bucket_time(
                                e * half as f64,
                                n_ranks,
                                fabric,
                            );
                            assert!(
                                comm_half > frac * c * half as f64,
                                "{wire:?} {fabric:?} x{n_ranks}: \
                                 half-size bucket should violate the budget"
                            );
                        }
                    }
                }
            }
        }
        // Each compression rung ships fewer bytes per element, so its
        // bandwidth tax is smaller and the adaptive choice can afford
        // strictly finer buckets on a bandwidth-bound fabric.
        let bw_bound = Fabric { alpha: 8e-6, bw: 9e9 };
        let b32 = adaptive_bucket_elems(
            params_len,
            4,
            bw_bound,
            c,
            frac,
            WireCodec::F32,
        );
        let b16 = adaptive_bucket_elems(
            params_len,
            4,
            bw_bound,
            c,
            frac,
            WireCodec::Bf16,
        );
        let b8 = adaptive_bucket_elems(
            params_len,
            4,
            bw_bound,
            c,
            frac,
            WireCodec::Q8Block,
        );
        assert!(
            b8 < b16 && b16 < b32,
            "q8 {b8} vs bf16 {b16} vs f32 {b32}"
        );
        // Chattier fabrics need coarser buckets.
        let quiet = adaptive_bucket_elems(
            params_len,
            4,
            Fabric { alpha: 1e-6, bw: 170e9 },
            c,
            frac,
            WireCodec::F32,
        );
        let chatty = adaptive_bucket_elems(
            params_len,
            4,
            Fabric { alpha: 100e-6, bw: 170e9 },
            c,
            frac,
            WireCodec::F32,
        );
        assert!(chatty > quiet, "{chatty} vs {quiet}");
        // Degenerate cases: single rank, or bandwidth alone over budget.
        assert_eq!(
            adaptive_bucket_elems(
                params_len,
                1,
                Fabric::default(),
                c,
                frac,
                WireCodec::F32
            ),
            params_len
        );
        let starved = Fabric { alpha: 8e-6, bw: 1e6 };
        assert_eq!(
            adaptive_bucket_elems(
                params_len,
                4,
                starved,
                c,
                frac,
                WireCodec::F32
            ),
            params_len
        );
        // A fabric starved for f32 can still be bucketable at bf16.
        let tight = Fabric { alpha: 8e-6, bw: 4.5e9 };
        assert_eq!(
            adaptive_bucket_elems(
                params_len,
                4,
                tight,
                c,
                frac,
                WireCodec::F32
            ),
            params_len
        );
        assert!(
            adaptive_bucket_elems(
                params_len,
                4,
                tight,
                c,
                frac,
                WireCodec::Bf16
            ) < params_len
        );
        // Config-level resolution: explicit wire overrides the storage
        // default; None follows the (possibly later-mutated) dtype.
        let mut cfg = PipelineConfig::new(3, 64);
        assert_eq!(cfg.wire_codec(), WireCodec::F32);
        cfg.dtype = Dtype::Bf16;
        assert_eq!(cfg.wire_codec(), WireCodec::Bf16);
        cfg.wire = Some(WireCodec::Q8Block);
        assert_eq!(cfg.wire_codec(), WireCodec::Q8Block);
    }

    #[test]
    fn synthetic_sources_replay_identically() {
        let mut a = synthetic_sources(2, 9, 0.1);
        let mut b = synthetic_sources(2, 9, 0.1);
        let mut ga = vec![0f32; 32];
        let mut gb = vec![0f32; 32];
        for step in 1..=3u64 {
            for r in 0..2 {
                a[r].fill(step, &mut ga);
                b[r].fill(step, &mut gb);
                assert_eq!(ga, gb, "rank {r} step {step}");
            }
        }
        // Distinct ranks draw distinct streams.
        a[0].fill(4, &mut ga);
        a[1].fill(4, &mut gb);
        assert_ne!(ga, gb);
    }

    #[test]
    fn default_skip_advances_stream_sources() {
        // skip(step) on a stream-stateful source must advance it exactly
        // as a consumed fill would — the resume fast-forward contract.
        let mut consumed = synthetic_sources(1, 5, 0.1);
        let mut skipped = synthetic_sources(1, 5, 0.1);
        let mut ga = vec![0f32; 24];
        let mut gb = vec![0f32; 24];
        for step in 1..=2u64 {
            consumed[0].fill(step, &mut ga);
            skipped[0].skip(step, &mut gb);
        }
        consumed[0].fill(3, &mut ga);
        skipped[0].fill(3, &mut gb);
        assert_eq!(ga, gb);
    }

    #[test]
    fn host_eval_loss_is_reset_deterministic() {
        let params: Vec<f32> =
            (0..200).map(|i| (i as f32 * 0.7).sin()).collect();
        let mut val = DataLoader::lm(Domain::C4, 41, 2, 16, 4_000);
        // Drift the loader, then score twice: reset() must pin the set.
        for _ in 0..7 {
            val.next_batch();
        }
        let a = host_eval_loss(&params, &mut val, 4);
        let b = host_eval_loss(&params, &mut val, 4);
        assert!(a > 0.0);
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
