//! Repo-wide static analysis: machine-check the invariants the parity,
//! determinism, and checkpoint guarantees rest on, on every PR.
//!
//! Everything this repo sells — bitwise parity across all ExecPlan
//! cells, bit-exact suspend/resume, the bf16-vs-f32 tolerance harness —
//! depends on properties no test can prove by sampling: no unordered
//! iteration feeding a reduce, no stray threads outside the pool, no
//! float reductions outside the blessed kernels, no panic mid-step that
//! poisons the engine. [`analyze`] runs the rule registry
//! ([`rules::RULES`]) over a [`Tree`] (Rust sources token-scanned by
//! [`scanner`], plus the cross-artifact surfaces: Makefile, CI workflow,
//! bench baseline, docs) and reports findings; `adalomo analyze` exits
//! nonzero on any unwaivered violation and `make analyze` wires it into
//! tier-1 CI. Dynamic companions (`make miri`, `make tsan`) cover what a
//! token scan cannot.
//!
//! A finding is silenced in one of two ways, both explicit and both
//! visible in the JSON report: an `ANALYZE-WAIVE` — `(rule): reason` —
//! comment on (or directly above) the offending line, or — for
//! panic-discipline — an annotated budget in
//! [`rules::PANIC_ALLOWLIST`]. See docs/ANALYSIS.md.

pub mod conc;
pub mod lexer;
pub mod model;
pub mod rules;
pub mod scanner;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::util::json::{arr, num, obj, s, Json};

use scanner::SourceFile;

/// Aux-artifact keys in [`Tree::aux`] (repo-relative paths).
pub const AUX_MAKEFILE: &str = "Makefile";
pub const AUX_CI: &str = ".github/workflows/ci.yml";
pub const AUX_BASELINE: &str = "bench/baseline.json";
pub const AUX_DOCS: &str = "docs/ANALYSIS.md";
pub const AUX_README: &str = "README.md";
pub const AUX_EXCHANGE: &str = "docs/EXCHANGE.md";

/// One rule hit. `line == 0` marks a file-level finding (missing
/// attribute, count over budget, artifact drift).
#[derive(Debug, Clone)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
    /// `Some(reason)` when an ANALYZE-WAIVE comment covers the line —
    /// reported, but not a violation.
    pub waived: Option<String>,
}

/// Everything the analyzer looks at. Tests build these in memory;
/// [`Tree::load`] reads a real checkout.
#[derive(Debug, Default)]
pub struct Tree {
    /// Scanned `rust/src/**/*.rs`, sorted by path.
    pub sources: Vec<SourceFile>,
    /// `(path, raw text)` of the CI micro benches (metric-name surface;
    /// raw because the names live inside string literals).
    pub benches: Vec<(String, String)>,
    /// Cross-artifact files by repo-relative path (see the `AUX_*`
    /// constants); absent files are simply not in the map.
    pub aux: BTreeMap<String, String>,
}

impl Tree {
    /// Load the analyzable surface of the checkout rooted at `root`.
    pub fn load(root: &Path) -> Result<Tree> {
        let mut tree = Tree::default();
        let src = root.join("rust/src");
        let mut paths = Vec::new();
        walk_rs(&src, &mut paths)
            .with_context(|| format!("scanning {src:?}"))?;
        paths.sort();
        for p in paths {
            let rel = relative(&p, root);
            let text = std::fs::read_to_string(&p)
                .with_context(|| format!("reading {p:?}"))?;
            tree.sources.push(SourceFile::parse(&rel, &text));
        }
        let benches = root.join("rust/benches");
        if benches.is_dir() {
            let mut bpaths: Vec<PathBuf> = std::fs::read_dir(&benches)?
                .filter_map(|e| e.ok().map(|e| e.path()))
                .filter(|p| {
                    p.file_name()
                        .and_then(|n| n.to_str())
                        .is_some_and(|n| {
                            n.starts_with("bench_micro_") && n.ends_with(".rs")
                        })
                })
                .collect();
            bpaths.sort();
            for p in bpaths {
                let rel = relative(&p, root);
                tree.benches.push((rel, std::fs::read_to_string(&p)?));
            }
        }
        for key in [
            AUX_MAKEFILE,
            AUX_CI,
            AUX_BASELINE,
            AUX_DOCS,
            AUX_README,
            AUX_EXCHANGE,
        ] {
            if let Ok(text) = std::fs::read_to_string(root.join(key)) {
                tree.aux.insert(key.to_string(), text);
            }
        }
        Ok(tree)
    }
}

fn relative(p: &Path, root: &Path) -> String {
    p.strip_prefix(root)
        .unwrap_or(p)
        .to_string_lossy()
        .replace('\\', "/")
}

fn walk_rs(dir: &Path, out: &mut Vec<PathBuf>) -> Result<()> {
    for entry in std::fs::read_dir(dir)
        .with_context(|| format!("read_dir {dir:?}"))?
    {
        let path = entry?.path();
        if path.is_dir() {
            walk_rs(&path, out)?;
        } else if path.extension().and_then(|e| e.to_str()) == Some("rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Build a finding, attaching any waiver that covers the line.
fn finding(
    file: &SourceFile,
    rule: &'static str,
    line: usize,
    message: String,
) -> Finding {
    Finding {
        rule,
        file: file.path.clone(),
        line,
        message,
        waived: file.waiver_for(rule, line).map(|w| w.reason.clone()),
    }
}

/// The full analyzer output: findings (waived + not), advisory notes,
/// and the independently re-derived bench-metric name set.
#[derive(Debug)]
pub struct Report {
    pub findings: Vec<Finding>,
    pub notes: Vec<String>,
    pub files_scanned: usize,
    /// Metric names the micro benches emit, derived statically — the
    /// set `bench-check` gates against `bench/baseline.json`.
    pub bench_metrics: Vec<String>,
    /// `(file, line, rule)` of every stale waiver — the removal list
    /// `adalomo analyze --bless-waivers` prints as a diff. Each is
    /// also a waiver-syntax violation in [`Report::findings`].
    pub stale_waivers: Vec<(String, usize, String)>,
}

impl Report {
    /// Unwaivered findings — what fails `make analyze`.
    pub fn violations(&self) -> Vec<&Finding> {
        self.findings.iter().filter(|f| f.waived.is_none()).collect()
    }

    pub fn waived_count(&self) -> usize {
        self.findings.iter().filter(|f| f.waived.is_some()).count()
    }

    /// Machine-readable report (uploaded as a CI artifact).
    pub fn to_json(&self) -> Json {
        let mut per_rule: BTreeMap<&str, (usize, usize)> = BTreeMap::new();
        for (id, _) in rules::RULES {
            per_rule.insert(*id, (0, 0));
        }
        for f in &self.findings {
            let e = per_rule.entry(f.rule).or_insert((0, 0));
            if f.waived.is_some() {
                e.1 += 1;
            } else {
                e.0 += 1;
            }
        }
        let rules_json = Json::Obj(
            per_rule
                .into_iter()
                .map(|(id, (viol, waived))| {
                    (
                        id.to_string(),
                        obj(vec![
                            ("violations", num(viol as f64)),
                            ("waived", num(waived as f64)),
                        ]),
                    )
                })
                .collect(),
        );
        obj(vec![
            ("analyzer_version", num(1.0)),
            ("files_scanned", num(self.files_scanned as f64)),
            ("violations", num(self.violations().len() as f64)),
            ("waived", num(self.waived_count() as f64)),
            ("rules", rules_json),
            (
                "findings",
                arr(self
                    .findings
                    .iter()
                    .map(|f| {
                        let mut fields = vec![
                            ("rule", s(f.rule)),
                            ("file", s(&f.file)),
                            ("line", num(f.line as f64)),
                            ("message", s(&f.message)),
                            ("waived", Json::Bool(f.waived.is_some())),
                        ];
                        if let Some(reason) = &f.waived {
                            fields.push(("waiver_reason", s(reason)));
                        }
                        obj(fields)
                    })
                    .collect()),
            ),
            (
                "bench_metrics",
                arr(self.bench_metrics.iter().map(|m| s(m)).collect()),
            ),
            ("notes", arr(self.notes.iter().map(|n| s(n)).collect())),
        ])
    }

    /// Minimal SARIF 2.1.0 document (uploaded from CI so findings can
    /// annotate PR diffs; the JSON artifact stays the canonical
    /// machine-readable report). Violations map to level "error",
    /// waived findings to "note"; file-level findings (line 0) clamp
    /// to startLine 1 as the SARIF region grammar requires.
    pub fn to_sarif(&self) -> Json {
        let rule_objs = rules::RULES
            .iter()
            .map(|(id, desc)| {
                obj(vec![
                    ("id", s(id)),
                    ("shortDescription", obj(vec![("text", s(desc))])),
                ])
            })
            .collect();
        let results = self
            .findings
            .iter()
            .map(|f| {
                let level =
                    if f.waived.is_some() { "note" } else { "error" };
                let region = obj(vec![(
                    "startLine",
                    num(f.line.max(1) as f64),
                )]);
                let loc = obj(vec![(
                    "physicalLocation",
                    obj(vec![
                        (
                            "artifactLocation",
                            obj(vec![("uri", s(&f.file))]),
                        ),
                        ("region", region),
                    ]),
                )]);
                obj(vec![
                    ("ruleId", s(f.rule)),
                    ("level", s(level)),
                    ("message", obj(vec![("text", s(&f.message))])),
                    ("locations", arr(vec![loc])),
                ])
            })
            .collect();
        let driver = obj(vec![
            ("name", s("adalomo-analyze")),
            ("version", s("1.0")),
            ("rules", arr(rule_objs)),
        ]);
        obj(vec![
            (
                "$schema",
                s("https://json.schemastore.org/sarif-2.1.0.json"),
            ),
            ("version", s("2.1.0")),
            (
                "runs",
                arr(vec![obj(vec![
                    ("tool", obj(vec![("driver", driver)])),
                    ("results", arr(results)),
                ])]),
            ),
        ])
    }
}

/// Run every rule over `tree`.
pub fn analyze(tree: &Tree) -> Report {
    let mut findings = Vec::new();
    let mut notes = Vec::new();
    rules::waiver_syntax(tree, &mut findings);
    rules::no_unsafe(tree, &mut findings);
    rules::determinism(tree, &mut findings);
    rules::panic_discipline(tree, &mut findings, &mut notes);
    rules::hot_path_alloc(tree, &mut findings);
    let bench_metrics = rules::consistency(tree, &mut findings, &mut notes);
    conc::conc(tree, &mut findings);
    let stale_waivers = stale_waivers(tree, &findings);
    for (file, line, rule) in &stale_waivers {
        findings.push(Finding {
            rule: "waiver-syntax",
            file: file.clone(),
            line: *line,
            message: format!(
                "stale waiver: waives {rule:?} but no finding matches — \
                 the offending code was fixed, so the comment must go \
                 (`adalomo analyze --bless-waivers` prints the removal \
                 diff)"
            ),
            waived: None,
        });
    }
    Report {
        findings,
        notes,
        files_scanned: tree.sources.len()
            + tree.benches.len()
            + tree.aux.len(),
        bench_metrics,
        stale_waivers,
    }
}

/// A waiver no finding consumed is stale — the offending code was fixed,
/// so the comment should go. Stale waivers are hard violations (under
/// waiver-syntax): an outdated waiver is camouflage for the next real
/// finding on that line. Malformed and unknown-rule waivers are skipped
/// here — they are already violations in their own right.
fn stale_waivers(
    tree: &Tree,
    findings: &[Finding],
) -> Vec<(String, usize, String)> {
    let known: std::collections::BTreeSet<&str> =
        rules::RULES.iter().map(|(id, _)| *id).collect();
    let mut stale = Vec::new();
    for f in &tree.sources {
        for w in &f.waivers {
            if w.rule.is_empty() || !known.contains(w.rule.as_str()) {
                continue;
            }
            let used = findings.iter().any(|fd| {
                fd.file == f.path
                    && fd.rule == w.rule
                    && fd.waived.is_some()
                    && f.waiver_for(fd.rule, fd.line)
                        .is_some_and(|cov| cov.line == w.line)
            });
            if !used {
                stale.push((f.path.clone(), w.line, w.rule.clone()));
            }
        }
    }
    stale
}

/// Convenience: load + analyze a checkout.
pub fn run(root: &Path) -> Result<Report> {
    Ok(analyze(&Tree::load(root)?))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tree_of(files: &[(&str, &str)]) -> Tree {
        let mut t = Tree::default();
        for (path, text) in files {
            t.sources.push(SourceFile::parse(path, text));
        }
        t
    }

    fn violations_of(tree: &Tree, rule: &str) -> usize {
        analyze(tree)
            .violations()
            .iter()
            .filter(|f| f.rule == rule)
            .count()
    }

    const W: &str = "rust/src/coordinator/x.rs"; // a watched path

    #[test]
    fn unsafe_token_is_flagged_and_waivable() {
        let t = tree_of(&[(W, "unsafe fn f() {}\n")]);
        assert_eq!(violations_of(&t, "no-unsafe"), 1);
        let t = tree_of(&[(
            W,
            "// ANALYZE-WAIVE(no-unsafe): documented soundness proof\n\
             unsafe fn f() {}\n",
        )]);
        assert_eq!(violations_of(&t, "no-unsafe"), 0);
        assert_eq!(analyze(&t).waived_count(), 1);
    }

    #[test]
    fn forbid_attribute_required_in_crate_roots() {
        let t = tree_of(&[("rust/src/lib.rs", "pub mod x;\n")]);
        assert_eq!(violations_of(&t, "no-unsafe"), 1);
        let t = tree_of(&[(
            "rust/src/lib.rs",
            "#![forbid(unsafe_code)]\npub mod x;\n",
        )]);
        assert_eq!(violations_of(&t, "no-unsafe"), 0);
    }

    #[test]
    fn unordered_collections_flagged_in_watched_dirs_only() {
        let bad = "use std::collections::HashMap;\n";
        assert_eq!(violations_of(&tree_of(&[(W, bad)]), "determinism"), 1);
        // util/ is outside the watched tree.
        let t = tree_of(&[("rust/src/util/x.rs", bad)]);
        assert_eq!(violations_of(&t, "determinism"), 0);
        // Mentions in comments/strings don't count.
        let t = tree_of(&[(W, "// a HashMap would be wrong here\n")]);
        assert_eq!(violations_of(&t, "determinism"), 0);
        // BTreeMap is the house type: clean.
        let t = tree_of(&[(W, "use std::collections::BTreeMap;\n")]);
        assert_eq!(violations_of(&t, "determinism"), 0);
    }

    #[test]
    fn threads_belong_to_the_pool() {
        let bad = "let h = std::thread::spawn(|| {});\n";
        assert_eq!(violations_of(&tree_of(&[(W, bad)]), "determinism"), 1);
        let t = tree_of(&[("rust/src/optim/pool.rs", bad)]);
        assert_eq!(violations_of(&t, "determinism"), 0);
        // Scoped spawns via the pool are not thread::spawn.
        let t = tree_of(&[(W, "std::thread::scope(|s| s.spawn(f));\n")]);
        assert_eq!(violations_of(&t, "determinism"), 0);
    }

    #[test]
    fn clocks_and_float_ops_need_blessing_or_waivers() {
        let t = tree_of(&[(W, "let t0 = Instant::now();\n")]);
        assert_eq!(violations_of(&t, "determinism"), 1);
        let t = tree_of(&[(
            W,
            "let t = Instant::now(); // ANALYZE-WAIVE(determinism): \
             report-only timing\n",
        )]);
        assert_eq!(violations_of(&t, "determinism"), 0);
        let sum = "let s = xs.iter().sum::<f32>();\n";
        assert_eq!(violations_of(&tree_of(&[(W, sum)]), "determinism"), 1);
        // The kernels are blessed for float reductions.
        let t = tree_of(&[("rust/src/optim/update.rs", sum)]);
        assert_eq!(violations_of(&t, "determinism"), 0);
        // Tests are exempt from determinism scanning.
        let t = tree_of(&[(
            W,
            "#[cfg(test)]\nmod tests {\n  fn f() { \
             let t = Instant::now(); }\n}\n",
        )]);
        assert_eq!(violations_of(&t, "determinism"), 0);
    }

    #[test]
    fn panic_budget_is_enforced() {
        // fused.rs has a budget of 1: a second unwrap busts it.
        let p = "rust/src/coordinator/fused.rs";
        let t = tree_of(&[(p, "f().unwrap();\n")]);
        assert_eq!(violations_of(&t, "panic-discipline"), 0);
        let t = tree_of(&[(p, "f().unwrap();\ng().unwrap();\n")]);
        assert_eq!(violations_of(&t, "panic-discipline"), 1);
        // Under budget emits a ratchet note, not a violation.
        let t = tree_of(&[(p, "fn ok() {}\n")]);
        let r = analyze(&t);
        assert_eq!(r.violations().len(), 0);
        assert!(r.notes.iter().any(|n| n.contains("ratchet")));
        // engine.rs is ratcheted to zero: any panic site fails.
        let t = tree_of(&[(
            "rust/src/coordinator/engine.rs",
            "f().unwrap();\n",
        )]);
        assert_eq!(violations_of(&t, "panic-discipline"), 1);
        // A watched file with no allowlist entry may not panic at all.
        let t = tree_of(&[(W, "f().expect(\"boom\");\n")]);
        assert_eq!(violations_of(&t, "panic-discipline"), 1);
        // The checkpoint read path is pinned at zero.
        let t = tree_of(&[(
            "rust/src/runtime/checkpoint.rs",
            "bytes.get(0).unwrap();\n",
        )]);
        assert_eq!(violations_of(&t, "panic-discipline"), 1);
        // Test-module unwraps don't count.
        let t = tree_of(&[(
            "rust/src/runtime/checkpoint.rs",
            "fn ok() {}\n#[cfg(test)]\nmod tests { fn t() { \
             f().unwrap(); } }\n",
        )]);
        assert_eq!(violations_of(&t, "panic-discipline"), 0);
    }

    #[test]
    fn bench_metrics_must_match_baseline_both_ways() {
        let bench = r#"
            fn main() {
                sink.metric("a_ns", 1.0);
                sink.metric(&format!("bytes_{suffix}"), 2.0);
            }
        "#;
        let mut t = Tree::default();
        t.benches.push(("rust/benches/bench_micro_x.rs".into(), bench.into()));
        t.aux.insert(
            AUX_BASELINE.to_string(),
            r#"{"a_ns": {}, "bytes_f32": {}, "bytes_bf16": {}}"#.to_string(),
        );
        let r = analyze(&t);
        assert_eq!(r.violations().len(), 0, "{:?}", r.violations());
        assert_eq!(
            r.bench_metrics,
            vec!["a_ns", "bytes_bf16", "bytes_f32"]
        );
        // Drop a baseline key -> emitted-but-untracked violation.
        t.aux.insert(
            AUX_BASELINE.to_string(),
            r#"{"a_ns": {}, "bytes_f32": {}}"#.to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
        // Phantom baseline key -> tracked-but-never-emitted violation.
        t.aux.insert(
            AUX_BASELINE.to_string(),
            r#"{"a_ns": {}, "bytes_f32": {}, "bytes_bf16": {},
                "ghost": {}}"#
                .to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
    }

    #[test]
    fn ci_make_targets_must_exist() {
        let mut t = Tree::default();
        t.aux.insert(
            AUX_MAKEFILE.to_string(),
            "build:\n\tcargo build\nlint: build\n\t$(MAKE) build\n"
                .to_string(),
        );
        t.aux.insert(
            AUX_CI.to_string(),
            "jobs:\n  x:\n    steps:\n      - run: make lint\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 0);
        t.aux.insert(
            AUX_CI.to_string(),
            "      - run: make no-such-target\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
        // Comments don't count as references.
        t.aux.insert(
            AUX_CI.to_string(),
            "      # later: make imaginary\n      - run: make build\n"
                .to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 0);
        // A dangling $(MAKE) self-reference inside the Makefile fails too.
        t.aux.insert(
            AUX_MAKEFILE.to_string(),
            "build:\n\t$(MAKE) gone\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
    }

    #[test]
    fn checkpoint_version_must_match_docs() {
        let ckpt = "pub const VERSION: u32 = 2;\n";
        let mut t = tree_of(&[("rust/src/runtime/checkpoint.rs", ckpt)]);
        // No docs at all: violation.
        assert_eq!(violations_of(&t, "consistency"), 1);
        t.aux.insert(
            AUX_DOCS.to_string(),
            "stale pin. ADCP format version: 1\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
        t.aux.insert(
            AUX_DOCS.to_string(),
            "current pin. ADCP format version: 2\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 0);
    }

    #[test]
    fn readme_make_references_must_exist() {
        let mut t = Tree::default();
        t.aux.insert(
            AUX_MAKEFILE.to_string(),
            "build:\n\tcargo build\n".to_string(),
        );
        t.aux.insert(
            AUX_README.to_string(),
            "Run `make build` to get started.\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 0);
        t.aux.insert(
            AUX_README.to_string(),
            "Run `make imaginary` to get started.\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
        // Comments (and markdown headings, which share the `#` lead)
        // don't count as references.
        t.aux.insert(
            AUX_README.to_string(),
            "# how to make things\nRun `make build`.\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 0);
    }

    #[test]
    fn q8_block_size_must_match_exchange_docs() {
        let coll = "pub const Q8_BLOCK: usize = 64;\n";
        let mut t =
            tree_of(&[("rust/src/coordinator/collective.rs", coll)]);
        // No docs/EXCHANGE.md at all: violation.
        assert_eq!(violations_of(&t, "consistency"), 1);
        t.aux.insert(
            AUX_EXCHANGE.to_string(),
            "stale pin. q8 block size: 32\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 1);
        t.aux.insert(
            AUX_EXCHANGE.to_string(),
            "current pin. q8 block size: 64\n".to_string(),
        );
        assert_eq!(violations_of(&t, "consistency"), 0);
    }

    #[test]
    fn malformed_and_stale_waivers_surface() {
        let t = tree_of(&[(W, "// ANALYZE-WAIVE(determinism) no colon\n")]);
        assert_eq!(violations_of(&t, "waiver-syntax"), 1);
        // An unknown-rule waiver is one violation (unknown rule), not
        // two (it is excluded from the stale scan).
        let t = tree_of(&[(W, "// ANALYZE-WAIVE(imaginary-rule): hi\n")]);
        assert_eq!(violations_of(&t, "waiver-syntax"), 1);
        // A stale waiver is a hard violation and lands in the
        // bless-waivers removal list.
        let t = tree_of(&[(
            W,
            "// ANALYZE-WAIVE(determinism): nothing here needs this\n\
             fn clean() {}\n",
        )]);
        let r = analyze(&t);
        assert_eq!(r.violations().len(), 1, "{:?}", r.violations());
        assert!(r.violations()[0].message.contains("stale waiver"));
        assert_eq!(
            r.stale_waivers,
            vec![(W.to_string(), 1, "determinism".to_string())]
        );
        // A consumed waiver is not stale.
        let t = tree_of(&[(
            W,
            "let t = Instant::now(); // ANALYZE-WAIVE(determinism): \
             report-only timing\n",
        )]);
        let r = analyze(&t);
        assert_eq!(r.violations().len(), 0, "{:?}", r.violations());
        assert!(r.stale_waivers.is_empty());
    }

    #[test]
    fn concurrency_rules_run_through_analyze() {
        // End-to-end: a lock inversion seeded through the normal
        // pipeline surfaces as a lock-order violation, and a waiver on
        // the witness line silences it (and is then consumed, not
        // stale).
        // The cycle finding anchors at the first edge's witness — the
        // second acquisition in fwd — so the waiver sits there.
        let src = "fn fwd(s: &S) {\n\
                   let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());\n\
                   // ANALYZE-WAIVE(lock-order): fixture inversion\n\
                   let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());\n\
                   drop(gb);\n\
                   drop(ga);\n\
                   }\n\
                   fn rev(s: &S) {\n\
                   let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());\n\
                   let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());\n\
                   drop(ga);\n\
                   drop(gb);\n\
                   }\n";
        let t = tree_of(&[(W, src)]);
        let r = analyze(&t);
        assert_eq!(r.violations().len(), 0, "{:?}", r.violations());
        assert_eq!(r.waived_count(), 1);
        assert!(r.stale_waivers.is_empty());
        let unwaived = src.replace(
            "// ANALYZE-WAIVE(lock-order): fixture inversion\n",
            "",
        );
        let t = tree_of(&[(W, unwaived.as_str())]);
        assert_eq!(violations_of(&t, "lock-order"), 1);
    }

    #[test]
    fn sarif_shape() {
        let t = tree_of(&[(
            W,
            "let t0 = Instant::now();\n\
             let t1 = Instant::now(); // ANALYZE-WAIVE(determinism): \
             report-only timing\n",
        )]);
        let j = analyze(&t).to_sarif();
        assert_eq!(
            j.get("version").unwrap().as_str().unwrap(),
            "2.1.0"
        );
        let runs = j.get("runs").unwrap().as_arr().unwrap();
        assert_eq!(runs.len(), 1);
        let results = runs[0].get("results").unwrap().as_arr().unwrap();
        assert_eq!(results.len(), 2);
        let levels: Vec<&str> = results
            .iter()
            .map(|r| r.get("level").unwrap().as_str().unwrap())
            .collect();
        assert!(levels.contains(&"error"));
        assert!(levels.contains(&"note"));
        let driver = runs[0]
            .get("tool")
            .unwrap()
            .get("driver")
            .unwrap()
            .get("rules")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(driver.len(), rules::RULES.len());
        // Round-trips through the JSON parser.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }

    #[test]
    fn hot_regions_forbid_allocation_tokens() {
        // Alloc token inside a closed region: violation.
        let t = tree_of(&[(
            W,
            "fn f() {\n\
             // ANALYZE-HOT: dispatch loop\n\
             let v = xs.to_vec();\n\
             // ANALYZE-HOT-END\n\
             }\n",
        )]);
        assert_eq!(violations_of(&t, "hot-path-alloc"), 1);
        // The same token outside the region is fine.
        let t = tree_of(&[(
            W,
            "let v = xs.to_vec();\n\
             // ANALYZE-HOT: dispatch loop\n\
             let n = xs.len();\n\
             // ANALYZE-HOT-END\n",
        )]);
        assert_eq!(violations_of(&t, "hot-path-alloc"), 0);
        // Every token class is caught.
        for bad in [
            "let a = vec![0f32; n];",
            "let b = xs.to_vec();",
            "let c = Vec::with_capacity(n);",
            "let d = xs.clone();",
            "let e = Box::new(f);",
        ] {
            let src = format!(
                "// ANALYZE-HOT: k\n{bad}\n// ANALYZE-HOT-END\n"
            );
            let t = tree_of(&[(W, src.as_str())]);
            assert_eq!(violations_of(&t, "hot-path-alloc"), 1, "{bad}");
        }
        // Waivable with the standard grammar.
        let t = tree_of(&[(
            W,
            "// ANALYZE-HOT: k\n\
             // ANALYZE-WAIVE(hot-path-alloc): warm-up only, ring reuses it\n\
             let v = xs.to_vec();\n\
             // ANALYZE-HOT-END\n",
        )]);
        assert_eq!(violations_of(&t, "hot-path-alloc"), 0);
        assert_eq!(analyze(&t).waived_count(), 1);
        // An unterminated region is itself a violation.
        let t = tree_of(&[(
            W,
            "// ANALYZE-HOT: forgot to close\nlet n = xs.len();\n",
        )]);
        assert_eq!(violations_of(&t, "hot-path-alloc"), 1);
        // Alloc tokens in test code under a region don't count (mirrors
        // every other rule's test exemption).
        let t = tree_of(&[(
            W,
            "// ANALYZE-HOT: k\n\
             fn f() {}\n\
             #[cfg(test)]\n\
             mod tests { fn t() { let v = xs.to_vec(); } }\n\
             // ANALYZE-HOT-END\n",
        )]);
        assert_eq!(violations_of(&t, "hot-path-alloc"), 0);
    }

    #[test]
    fn report_json_shape() {
        let t = tree_of(&[(W, "let t = Instant::now();\n")]);
        let r = analyze(&t);
        let j = r.to_json();
        assert_eq!(j.get("violations").unwrap().as_usize().unwrap(), 1);
        let findings = j.get("findings").unwrap().as_arr().unwrap();
        assert_eq!(findings.len(), 1);
        assert_eq!(
            findings[0].get("rule").unwrap().as_str().unwrap(),
            "determinism"
        );
        // Round-trips through the JSON parser.
        let text = j.to_string();
        assert_eq!(Json::parse(&text).unwrap(), j);
    }
}
