//! Lightweight token scanner over Rust sources — the analyzer's front
//! end. No AST, no external deps (the build is offline-first): a small
//! character-level pass strips comments and string-literal *contents* so
//! rule tokens never match documentation or message text, tracks the
//! trailing `#[cfg(test)]` region every module in this repo uses, and
//! parses `ANALYZE-WAIVE` comments — `(rule): reason` form — into structured
//! waivers the rules consult.
//!
//! The scanner is deliberately conservative and its limits are
//! documented (docs/ANALYSIS.md): it assumes test modules are trailing
//! (true across the tree, and new mid-file test mods would only make
//! scanning *more* lenient, never produce false violations on shipped
//! code), and it matches tokens, not types — a renamed `use
//! std::collections::HashMap as Map;` would evade it, which review
//! catches far more easily than an unnamed import would.

/// One physical source line, post-strip.
#[derive(Debug, Clone)]
pub struct Line {
    /// 1-based line number.
    pub number: usize,
    /// The line with comments removed and string/char-literal contents
    /// blanked (quotes kept, so `""` still reads as an expression).
    pub code: String,
    /// Comment text on this line (line + block comments), used for
    /// waiver parsing only.
    pub comment: String,
    /// True from the first `#[cfg(test)]` line to end of file.
    pub is_test: bool,
}

/// A parsed `ANALYZE-WAIVE` comment (`(rule): reason` form).
#[derive(Debug, Clone)]
pub struct Waiver {
    pub rule: String,
    pub reason: String,
    /// Line the waiver comment sits on.
    pub line: usize,
    /// True when the line holds no code — the waiver then applies to the
    /// next code line below it; a trailing waiver applies to its own
    /// line.
    pub standalone: bool,
}

/// A scanned source file: repo-relative path + per-line code/comments.
#[derive(Debug, Clone)]
pub struct SourceFile {
    pub path: String,
    pub lines: Vec<Line>,
    pub waivers: Vec<Waiver>,
}

/// The marker rules look for inside comments.
pub const WAIVE_MARK: &str = "ANALYZE-WAIVE(";

/// Opens a hot region — the `ANALYZE-HOT` comment marker followed by a
/// colon and a label. Inside one, the `hot-path-alloc` rule treats
/// allocation tokens as violations. (This doc deliberately never spells
/// the marker-plus-colon sequence: the scanner would read it as a real
/// region opener in this very file.)
pub const HOT_MARK: &str = "ANALYZE-HOT:";
/// Closes the innermost open hot region.
pub const HOT_END_MARK: &str = "ANALYZE-HOT-END";

/// A parsed `ANALYZE-HOT` region (comment channel, like waivers).
#[derive(Debug, Clone)]
pub struct HotRegion {
    pub label: String,
    /// Line of the opening marker.
    pub start: usize,
    /// Line of the closing marker; `None` means unterminated (a
    /// violation in its own right — an open-ended region would silently
    /// police the whole rest of the file).
    pub end: Option<usize>,
}

impl SourceFile {
    /// Scan `text` into stripped lines + waivers. `path` should be
    /// repo-relative with forward slashes (`rust/src/...`).
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let (lines, mut malformed) = strip(text);
        let mut waivers = Vec::new();
        for l in &lines {
            match parse_waivers(&l.comment, l.number, l.code.trim().is_empty())
            {
                Ok(mut ws) => waivers.append(&mut ws),
                Err(msg) => malformed.push((l.number, msg)),
            }
        }
        // Malformed waivers surface as pseudo-waivers with an empty rule;
        // the driver turns them into findings (an unreadable waiver must
        // fail loudly, not silently waive nothing).
        for (line, msg) in malformed {
            waivers.push(Waiver {
                rule: String::new(),
                reason: msg,
                line,
                standalone: false,
            });
        }
        SourceFile { path: path.to_string(), lines, waivers }
    }

    /// Parse `ANALYZE-HOT` regions from the comment channel. Regions do
    /// not nest; a close with no open region is ignored, and an open
    /// region left unterminated is reported with `end: None`.
    pub fn hot_regions(&self) -> Vec<HotRegion> {
        let mut out: Vec<HotRegion> = Vec::new();
        let mut open: Option<usize> = None;
        for l in &self.lines {
            if l.comment.contains(HOT_END_MARK) {
                if let Some(idx) = open.take() {
                    out[idx].end = Some(l.number);
                }
                continue;
            }
            if let Some(at) = l.comment.find(HOT_MARK) {
                let label =
                    l.comment[at + HOT_MARK.len()..].trim().to_string();
                // A second open before the first closed leaves the first
                // with `end: None` — flagged, never silently merged.
                open = Some(out.len());
                out.push(HotRegion { label, start: l.number, end: None });
            }
        }
        out
    }

    /// Waivers for `rule` covering `line`: trailing waivers on the line
    /// itself plus standalone waiver lines stacked directly above it.
    pub fn waiver_for(&self, rule: &str, line: usize) -> Option<&Waiver> {
        if let Some(w) = self
            .waivers
            .iter()
            .find(|w| w.rule == rule && w.line == line && !w.standalone)
        {
            return Some(w);
        }
        // Walk upward through a contiguous block of standalone waiver
        // lines (several rules may be waived for one statement).
        let mut above = line;
        while above > 1 {
            above -= 1;
            let ws: Vec<&Waiver> = self
                .waivers
                .iter()
                .filter(|w| w.line == above && w.standalone)
                .collect();
            if ws.is_empty() {
                return None;
            }
            if let Some(w) = ws.iter().find(|w| w.rule == rule) {
                return Some(w);
            }
        }
        None
    }
}

/// Parse every waiver in one line's comment text. Errors on a marker
/// whose rule or reason is missing — an unreadable waiver is worse than
/// none.
fn parse_waivers(
    comment: &str,
    line: usize,
    standalone: bool,
) -> Result<Vec<Waiver>, String> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(at) = rest.find(WAIVE_MARK) {
        rest = &rest[at + WAIVE_MARK.len()..];
        let Some(close) = rest.find(')') else {
            return Err("unterminated ANALYZE-WAIVE(".to_string());
        };
        let rule = rest[..close].trim().to_string();
        rest = &rest[close + 1..];
        let Some(tail) = rest.strip_prefix(':') else {
            return Err(format!(
                "ANALYZE-WAIVE({rule}) needs a `: reason` suffix"
            ));
        };
        // Reason runs to the next waiver marker or end of comment.
        let end = tail.find(WAIVE_MARK).unwrap_or(tail.len());
        let reason = tail[..end].trim().trim_end_matches("//").trim();
        if rule.is_empty() || reason.is_empty() {
            return Err(
                "ANALYZE-WAIVE needs both a rule and a reason".to_string()
            );
        }
        out.push(Waiver {
            rule,
            reason: reason.to_string(),
            line,
            standalone,
        });
        rest = &tail[end..];
    }
    Ok(out)
}

#[derive(PartialEq)]
enum Mode {
    Code,
    LineComment,
    BlockComment(usize),
    Str,
    RawStr(usize),
}

/// Split `text` into per-line code/comment channels. Returns the lines
/// plus any (line, message) scan diagnostics.
#[allow(clippy::type_complexity)]
fn strip(text: &str) -> (Vec<Line>, Vec<(usize, String)>) {
    let bytes = text.as_bytes();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut number = 1usize;
    let mut mode = Mode::Code;
    let mut in_test = false;
    let mut i = 0usize;
    while i < bytes.len() {
        let b = bytes[i];
        if b == b'\n' {
            if code.contains("#[cfg(test)]") {
                in_test = true;
            }
            if let Mode::LineComment = mode {
                mode = Mode::Code;
            }
            lines.push(Line {
                number,
                code: std::mem::take(&mut code),
                comment: std::mem::take(&mut comment),
                is_test: in_test,
            });
            number += 1;
            i += 1;
            continue;
        }
        match mode {
            Mode::Code => {
                match b {
                    b'/' if bytes.get(i + 1) == Some(&b'/') => {
                        mode = Mode::LineComment;
                        i += 2;
                        continue;
                    }
                    b'/' if bytes.get(i + 1) == Some(&b'*') => {
                        mode = Mode::BlockComment(1);
                        i += 2;
                        continue;
                    }
                    b'"' => {
                        code.push('"');
                        mode = Mode::Str;
                        i += 1;
                        continue;
                    }
                    b'r' | b'b' if is_raw_str_start(bytes, i) => {
                        let (hashes, skip) = raw_str_open(bytes, i);
                        code.push('"');
                        mode = Mode::RawStr(hashes);
                        i += skip;
                        continue;
                    }
                    b'\'' => {
                        // Char literal vs lifetime: a literal closes
                        // within a few bytes; a lifetime has no closing
                        // quote before a non-ident char.
                        if let Some(len) = char_literal_len(bytes, i) {
                            code.push_str("''");
                            i += len;
                            continue;
                        }
                        code.push('\'');
                        i += 1;
                        continue;
                    }
                    _ => {}
                }
                code.push(b as char);
                i += 1;
            }
            Mode::LineComment => {
                comment.push(b as char);
                i += 1;
            }
            Mode::BlockComment(depth) => {
                if b == b'*' && bytes.get(i + 1) == Some(&b'/') {
                    mode = if depth == 1 {
                        Mode::Code
                    } else {
                        Mode::BlockComment(depth - 1)
                    };
                    i += 2;
                } else if b == b'/' && bytes.get(i + 1) == Some(&b'*') {
                    mode = Mode::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comment.push(b as char);
                    i += 1;
                }
            }
            Mode::Str => match b {
                // An escape consumes the next byte — except a
                // string-continuation backslash before a newline, which
                // must leave the newline for the line-tracking branch
                // above or every later line number in the file shifts.
                b'\\' if bytes.get(i + 1) == Some(&b'\n') => i += 1,
                b'\\' => i += 2,
                b'"' => {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1;
                }
                _ => i += 1,
            },
            Mode::RawStr(hashes) => {
                if b == b'"' && closes_raw(bytes, i, hashes) {
                    code.push('"');
                    mode = Mode::Code;
                    i += 1 + hashes;
                } else {
                    i += 1;
                }
            }
        }
    }
    if !code.is_empty() || !comment.is_empty() {
        if code.contains("#[cfg(test)]") {
            in_test = true;
        }
        lines.push(Line { number, code, comment, is_test: in_test });
    }
    let mut diags = Vec::new();
    if !matches!(mode, Mode::Code | Mode::LineComment) {
        diags.push((number, "unterminated comment or string".to_string()));
    }
    (lines, diags)
}

/// Does `r`/`br` at `i` open a raw string (`r"`, `r#"`, `br##"` ...)?
fn is_raw_str_start(bytes: &[u8], i: usize) -> bool {
    // Must not be the tail of an identifier (`for`, `attr` ...).
    if i > 0 && (bytes[i - 1].is_ascii_alphanumeric() || bytes[i - 1] == b'_')
    {
        return false;
    }
    let mut j = i + 1;
    if bytes.get(i) == Some(&b'b') {
        if bytes.get(j) != Some(&b'r') {
            return false;
        }
        j += 1;
    }
    while bytes.get(j) == Some(&b'#') {
        j += 1;
    }
    bytes.get(j) == Some(&b'"')
}

/// Number of `#`s and bytes to skip for the raw-string opener at `i`.
fn raw_str_open(bytes: &[u8], i: usize) -> (usize, usize) {
    let mut j = i + 1;
    if bytes[i] == b'b' {
        j += 1;
    }
    let mut hashes = 0usize;
    while bytes.get(j) == Some(&b'#') {
        hashes += 1;
        j += 1;
    }
    (hashes, j + 1 - i)
}

/// Does the `"` at `i` close a raw string opened with `hashes` `#`s?
fn closes_raw(bytes: &[u8], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| bytes.get(i + k) == Some(&b'#'))
}

/// Length of a char literal starting at the `'` at `i`, or `None` for a
/// lifetime.
fn char_literal_len(bytes: &[u8], i: usize) -> Option<usize> {
    match bytes.get(i + 1)? {
        b'\\' => {
            // Escaped char: scan to the closing quote (handles \n,
            // \u{..}). The scan starts PAST the escaped byte so the
            // quote inside '\'' is not mistaken for the terminator.
            let mut j = i + 3;
            while j < bytes.len() && j < i + 12 {
                if bytes[j] == b'\'' {
                    return Some(j + 1 - i);
                }
                j += 1;
            }
            None
        }
        _ => {
            if bytes.get(i + 2) == Some(&b'\'') {
                Some(3)
            } else {
                // Multi-byte char literal ('é') — closing quote within
                // the UTF-8 sequence.
                let j = i + 1 + utf8_len(bytes[i + 1]);
                (bytes.get(j) == Some(&b'\'')).then_some(j + 1 - i)
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

/// Word-boundary token match over stripped code: `needle` must be an
/// identifier-like token not embedded in a longer identifier
/// (`unsafe_code` does not hit `unsafe`; `HashMap::new` hits `HashMap`).
pub fn word_hit(code: &str, needle: &str) -> bool {
    let mut rest = code;
    let mut offset = 0usize;
    while let Some(at) = rest.find(needle) {
        let start = offset + at;
        let end = start + needle.len();
        let before_ok = start == 0
            || !is_ident(code.as_bytes()[start - 1]);
        let after_ok = end >= code.len() || !is_ident(code.as_bytes()[end]);
        if before_ok && after_ok {
            return true;
        }
        rest = &rest[at + needle.len()..];
        offset = end;
    }
    false
}

fn is_ident(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strips_comments_and_strings() {
        let f = SourceFile::parse(
            "x.rs",
            "let a = \"HashMap in a string\"; // HashMap in a comment\n\
             /* HashMap in\na block */ let b = HashMap::new();\n",
        );
        assert!(!f.lines[0].code.contains("HashMap"));
        assert!(f.lines[0].comment.contains("HashMap in a comment"));
        assert!(!f.lines[1].code.contains("HashMap"));
        assert!(f.lines[2].code.contains("HashMap::new"));
    }

    #[test]
    fn raw_strings_and_chars() {
        let f = SourceFile::parse(
            "x.rs",
            "let a = r#\"unsafe { }\"#; let c = '\\n'; let d: &'a str = s;\n\
             let e = 'x';\n",
        );
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(f.lines[0].code.contains("&'a str"));
        assert!(!f.lines[1].code.contains('x'));
    }

    #[test]
    fn escaped_quote_char_literal_does_not_derail_the_scan() {
        // '\'' used to terminate at the escape's own quote, leaving the
        // scanner one byte short and misreading the rest of the line.
        let f = SourceFile::parse(
            "x.rs",
            "let q = '\\''; let h = HashMap::new();\n\
             let b = '\\\\'; let n = '\\n'; unsafe {}\n",
        );
        assert!(f.lines[0].code.contains("HashMap"));
        assert!(f.lines[1].code.contains("unsafe"));
        assert!(!f.lines[1].code.contains("\\n"));
    }

    #[test]
    fn string_continuation_keeps_line_numbers() {
        // A trailing backslash inside a string literal continues it on
        // the next line; the swallowed newline used to shift every later
        // line number in the file.
        let f = SourceFile::parse(
            "x.rs",
            "let s = \"one \\\n     two\";\nlet t = Instant::now();\n",
        );
        assert_eq!(f.lines.len(), 3);
        assert_eq!(f.lines[2].number, 3);
        assert!(f.lines[2].code.contains("Instant::now"));
        // The continuation's contents stay blanked out of the code
        // channel on both physical lines.
        assert!(!f.lines[0].code.contains("one"));
        assert!(!f.lines[1].code.contains("two"));
    }

    #[test]
    fn multiline_raw_string_keeps_line_numbers() {
        let f = SourceFile::parse(
            "x.rs",
            "let s = r#\"first\nunsafe {}\nlast\"#;\nlet x = 1;\n",
        );
        assert_eq!(f.lines.len(), 4);
        assert_eq!(f.lines[3].number, 4);
        assert!(f.lines[3].code.contains("let x = 1;"));
        assert!(!f.lines[1].code.contains("unsafe"));
    }

    #[test]
    fn lifetimes_and_loop_labels_are_not_char_literals() {
        let f = SourceFile::parse(
            "x.rs",
            "fn f<'a>(x: &'a str) -> &'a str { x }\n\
             'outer: loop { break 'outer; }\n",
        );
        assert!(f.lines[0].code.contains("<'a>"));
        assert!(f.lines[0].code.contains("&'a str"));
        assert!(f.lines[1].code.contains("'outer: loop"));
    }

    #[test]
    fn test_region_is_trailing() {
        let f = SourceFile::parse(
            "x.rs",
            "fn a() {}\n#[cfg(test)]\nmod tests { fn b() {} }\n",
        );
        assert!(!f.lines[0].is_test);
        assert!(f.lines[1].is_test);
        assert!(f.lines[2].is_test);
    }

    #[test]
    fn waiver_parse_and_lookup() {
        let f = SourceFile::parse(
            "x.rs",
            "// ANALYZE-WAIVE(determinism): report-only timing\n\
             let t = Instant::now();\n\
             let u = Instant::now(); // ANALYZE-WAIVE(determinism): also ok\n\
             let v = Instant::now();\n",
        );
        assert_eq!(f.waivers.len(), 2);
        assert!(f.waiver_for("determinism", 2).is_some());
        assert!(f.waiver_for("determinism", 3).is_some());
        assert!(f.waiver_for("determinism", 4).is_none());
        assert!(f.waiver_for("no-unsafe", 2).is_none());
    }

    #[test]
    fn stacked_standalone_waivers() {
        let f = SourceFile::parse(
            "x.rs",
            "// ANALYZE-WAIVE(determinism): threads are rank-ordered\n\
             // ANALYZE-WAIVE(no-unsafe): ffi shim\n\
             thread::spawn(|| {});\n",
        );
        assert!(f.waiver_for("determinism", 3).is_some());
        assert!(f.waiver_for("no-unsafe", 3).is_some());
    }

    #[test]
    fn malformed_waiver_is_flagged() {
        let f = SourceFile::parse("x.rs", "// ANALYZE-WAIVE(determinism)\n");
        assert_eq!(f.waivers.len(), 1);
        assert!(f.waivers[0].rule.is_empty());
    }

    #[test]
    fn word_boundaries() {
        assert!(word_hit("unsafe fn f()", "unsafe"));
        assert!(!word_hit("#![forbid(unsafe_code)]", "unsafe"));
        assert!(word_hit("HashMap::new()", "HashMap"));
        assert!(!word_hit("MyHashMapLike", "HashMap"));
    }
}
