//! Item-level semantic model: functions, parameters, bodies and a
//! per-crate symbol table, built over the lexer's token streams. This
//! is the middle stage of the analysis pipeline (scanner → lexer →
//! model → rules): the concurrency rules in [`super::conc`] walk each
//! function body with guard state, resolve call sites through the
//! symbol table here, and propagate acquisition sets over the call
//! graph.
//!
//! The parser is item-level on purpose. It recognizes `fn` items
//! (free functions, inherent/trait methods, nested fns), their
//! parameter lists and brace-matched body ranges — nothing more. Rust's
//! expression grammar stays opaque; the rules that need expression
//! structure use small token-pattern recognizers over the body range.
//! Resolution is by bare name: same file wins, then a unique cross-file
//! definition; ambiguous names stay unresolved (the rules treat
//! unresolved calls as acquiring nothing, which keeps the analysis
//! sound for the watched tree where protocol functions have unique
//! names).

use std::collections::BTreeMap;

use super::lexer::{lex, Tok};
use super::Tree;

/// A function parameter: binding name and its type as token text
/// (joined with single spaces, e.g. `& ' a Mutex < T >`).
#[derive(Debug, Clone)]
pub struct Param {
    pub name: String,
    pub ty: String,
}

/// One `fn` item.
#[derive(Debug, Clone)]
pub struct FnDef {
    pub name: String,
    /// Index into [`Model::files`].
    pub file: usize,
    /// Source line of the `fn` keyword.
    pub line: usize,
    /// True when declared inside the trailing `#[cfg(test)]` region.
    pub is_test: bool,
    pub params: Vec<Param>,
    /// Token index of the `fn` keyword (start of the item).
    pub sig_start: usize,
    /// Token range of the body contents, exclusive of the braces:
    /// `toks[body.0..body.1]`. Empty for bodyless trait declarations.
    pub body: (usize, usize),
}

impl FnDef {
    pub fn has_body(&self) -> bool {
        self.body.1 > self.body.0
    }
}

/// One parsed file: path, stem (`pool` for `optim/pool.rs`), token
/// stream and the functions found in it.
#[derive(Debug)]
pub struct FileModel {
    pub path: String,
    pub stem: String,
    pub toks: Vec<Tok>,
    /// Indices into [`Model::fns`], in source order.
    pub fns: Vec<usize>,
}

/// Per-crate symbol table over a set of files.
#[derive(Debug, Default)]
pub struct Model {
    pub files: Vec<FileModel>,
    pub fns: Vec<FnDef>,
    by_name: BTreeMap<String, Vec<usize>>,
}

impl Model {
    /// Build the model over every tree file accepted by `keep`.
    pub fn build(tree: &Tree, keep: impl Fn(&str) -> bool) -> Model {
        let mut m = Model::default();
        for sf in &tree.sources {
            if !keep(&sf.path) {
                continue;
            }
            let toks = lex(sf);
            let file_idx = m.files.len();
            let mut fns = Vec::new();
            let mut i = 0usize;
            while i < toks.len() {
                if toks[i].text == "fn" {
                    if let Some(def) = parse_fn(&toks, i, file_idx) {
                        // Continue scanning from just after the
                        // signature so nested fns are found too; body
                        // ranges are recorded per item.
                        i = def.body.0.max(i + 1);
                        fns.push(m.fns.len());
                        m.by_name
                            .entry(def.name.clone())
                            .or_default()
                            .push(m.fns.len());
                        m.fns.push(def);
                        continue;
                    }
                }
                i += 1;
            }
            m.files.push(FileModel {
                path: sf.path.clone(),
                stem: stem_of(&sf.path),
                toks,
                fns,
            });
        }
        m
    }

    /// Resolve a call by bare name from a given file: a definition in
    /// the same file wins, else a unique cross-file definition; `None`
    /// when unknown or ambiguous.
    pub fn resolve(&self, from_file: usize, name: &str) -> Option<usize> {
        let cands = self.by_name.get(name)?;
        if let Some(&idx) =
            cands.iter().find(|&&idx| self.fns[idx].file == from_file)
        {
            return Some(idx);
        }
        if cands.len() == 1 {
            return Some(cands[0]);
        }
        None
    }

    /// Strictly-nested fn items inside `outer`'s body, as
    /// `(sig_start, body_end)` skip ranges for body walks.
    pub fn nested_ranges(&self, outer: usize) -> Vec<(usize, usize)> {
        let o = &self.fns[outer];
        self.files[o.file]
            .fns
            .iter()
            .map(|&i| &self.fns[i])
            .filter(|g| g.sig_start > o.body.0 && g.body.1 < o.body.1)
            .map(|g| (g.sig_start, g.body.1 + 1))
            .collect()
    }

    /// `stem.name` display form for findings.
    pub fn qual_name(&self, idx: usize) -> String {
        let f = &self.fns[idx];
        format!("{}::{}", self.files[f.file].stem, f.name)
    }
}

pub fn stem_of(path: &str) -> String {
    let base = path.rsplit('/').next().unwrap_or(path);
    base.strip_suffix(".rs").unwrap_or(base).to_string()
}

/// Parse one `fn` item starting at the `fn` keyword; `None` when the
/// token is a function-pointer type (`fn(`), or malformed.
fn parse_fn(toks: &[Tok], at: usize, file: usize) -> Option<FnDef> {
    let name_tok = toks.get(at + 1)?;
    if !name_tok.is_ident() || is_keyword(&name_tok.text) {
        return None;
    }
    let mut j = at + 2;
    // Generic parameter list: skip to the matching `>`. The lexer keeps
    // `->` as one token, so only bare `<`/`>` move the depth.
    if toks.get(j).map(|t| t.text.as_str()) == Some("<") {
        let mut depth = 0usize;
        while j < toks.len() {
            match toks[j].text.as_str() {
                "<" => depth += 1,
                ">" => {
                    depth -= 1;
                    if depth == 0 {
                        j += 1;
                        break;
                    }
                }
                _ => {}
            }
            j += 1;
        }
    }
    if toks.get(j).map(|t| t.text.as_str()) != Some("(") {
        return None;
    }
    let (params, after_params) = parse_params(toks, j);
    // Return type / where clause: scan to the body `{` or a bodyless
    // `;`, tracking paren/bracket depth (closure types in return
    // position carry parens; braces never legally appear before the
    // body in this crate's grammar).
    let mut k = after_params;
    let mut depth = 0isize;
    let mut body = (0usize, 0usize);
    while k < toks.len() {
        match toks[k].text.as_str() {
            "(" | "[" => depth += 1,
            ")" | "]" => depth -= 1,
            ";" if depth == 0 => break,
            "{" if depth == 0 => {
                let close = match_brace(toks, k);
                body = (k + 1, close);
                break;
            }
            _ => {}
        }
        k += 1;
    }
    Some(FnDef {
        name: name_tok.text.clone(),
        file,
        line: toks[at].line,
        is_test: toks[at].is_test,
        params,
        sig_start: at,
        body,
    })
}

/// Parse a parenthesized parameter list starting at `(`; returns the
/// params and the token index just past the closing `)`.
fn parse_params(toks: &[Tok], open: usize) -> (Vec<Param>, usize) {
    let mut params = Vec::new();
    let mut paren = 0isize;
    let mut angle = 0isize;
    let mut seg: Vec<&Tok> = Vec::new();
    let mut k = open;
    loop {
        let Some(t) = toks.get(k) else {
            return (params, k);
        };
        match t.text.as_str() {
            "(" => {
                paren += 1;
                if paren > 1 {
                    seg.push(t);
                }
            }
            ")" => {
                paren -= 1;
                if paren == 0 {
                    push_param(&mut params, &seg);
                    return (params, k + 1);
                }
                seg.push(t);
            }
            "<" => {
                angle += 1;
                seg.push(t);
            }
            ">" => {
                angle -= 1;
                seg.push(t);
            }
            "," if paren == 1 && angle == 0 => {
                push_param(&mut params, &seg);
                seg.clear();
            }
            _ => seg.push(t),
        }
        k += 1;
    }
}

/// Turn one comma-separated segment into a [`Param`]: the binding name
/// is the last identifier before the first top-level `:` (handles
/// `mut x: T`); `self` receivers (no `:`) are skipped.
fn push_param(params: &mut Vec<Param>, seg: &[&Tok]) {
    let Some(colon) = seg.iter().position(|t| t.text == ":") else {
        return;
    };
    let Some(name) =
        seg[..colon].iter().rev().find(|t| t.is_ident()).map(|t| &t.text)
    else {
        return;
    };
    let ty = seg[colon + 1..]
        .iter()
        .map(|t| t.text.as_str())
        .collect::<Vec<_>>()
        .join(" ");
    params.push(Param { name: name.clone(), ty });
}

/// Index of the `}` matching the `{` at `open` (or the last token when
/// unbalanced, which truncates rather than panics on malformed input).
fn match_brace(toks: &[Tok], open: usize) -> usize {
    let mut depth = 0isize;
    for (k, t) in toks.iter().enumerate().skip(open) {
        match t.text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return k;
                }
            }
            _ => {}
        }
    }
    toks.len().saturating_sub(1)
}

fn is_keyword(s: &str) -> bool {
    matches!(
        s,
        "if" | "while"
            | "for"
            | "match"
            | "return"
            | "loop"
            | "let"
            | "else"
            | "move"
            | "in"
            | "as"
            | "ref"
            | "mut"
            | "fn"
            | "impl"
            | "pub"
            | "use"
            | "where"
            | "break"
            | "continue"
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::analysis::scanner::SourceFile;

    fn model_of(src: &str) -> Model {
        let tree = Tree {
            sources: vec![SourceFile::parse("rust/src/optim/pool.rs", src)],
            ..Tree::default()
        };
        Model::build(&tree, |_| true)
    }

    #[test]
    fn finds_free_fns_methods_and_params() {
        let m = model_of(
            "fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {\n\
             \x20   m.lock().unwrap_or_else(|e| e.into_inner())\n\
             }\n\
             impl Crew {\n\
             \x20   fn round(&self, jobs: &mut [Job]) -> Result<()> {\n\
             \x20       Ok(())\n\
             \x20   }\n\
             }\n",
        );
        assert_eq!(m.fns.len(), 2);
        let lock = &m.fns[0];
        assert_eq!(lock.name, "lock");
        assert_eq!(lock.line, 1);
        assert_eq!(lock.params.len(), 1);
        assert_eq!(lock.params[0].name, "m");
        assert!(lock.params[0].ty.contains("Mutex"));
        let round = &m.fns[1];
        assert_eq!(round.name, "round");
        // `&self` is skipped; `jobs` keeps its type text.
        assert_eq!(round.params.len(), 1);
        assert_eq!(round.params[0].name, "jobs");
        assert!(m.files[0].toks[round.body.0..round.body.1]
            .iter()
            .any(|t| t.text == "Ok"));
    }

    #[test]
    fn fn_pointer_types_and_closure_param_types_are_not_items() {
        let m = model_of(
            "fn takes(cb: fn(u32) -> u32, body: impl FnOnce(&mut S)) {\n\
             \x20   body(cb)\n\
             }\n",
        );
        assert_eq!(m.fns.len(), 1);
        assert_eq!(m.fns[0].name, "takes");
        assert_eq!(m.fns[0].params.len(), 2);
        assert_eq!(m.fns[0].params[1].name, "body");
    }

    #[test]
    fn bodyless_decls_and_nested_fns() {
        let m = model_of(
            "trait T { fn hook(&self) -> u32; }\n\
             fn outer() {\n\
             \x20   fn inner() { helper(); }\n\
             \x20   inner();\n\
             }\n",
        );
        let names: Vec<_> =
            m.fns.iter().map(|f| f.name.as_str()).collect();
        assert_eq!(names, ["hook", "outer", "inner"]);
        assert!(!m.fns[0].has_body());
        let outer = m
            .fns
            .iter()
            .position(|f| f.name == "outer")
            .unwrap();
        assert_eq!(m.nested_ranges(outer).len(), 1);
    }

    #[test]
    fn resolve_prefers_same_file_then_unique() {
        let tree = Tree {
            sources: vec![
                SourceFile::parse(
                    "rust/src/optim/pool.rs",
                    "fn wait() {}\nfn only_here() {}\n",
                ),
                SourceFile::parse(
                    "rust/src/optim/flat.rs",
                    "fn wait() {}\n",
                ),
            ],
            ..Tree::default()
        };
        let m = Model::build(&tree, |_| true);
        // Same-file wins for the duplicate name.
        let from_flat = m.resolve(1, "wait").unwrap();
        assert_eq!(m.fns[from_flat].file, 1);
        // Unique cross-file name resolves from anywhere.
        let uniq = m.resolve(1, "only_here").unwrap();
        assert_eq!(m.qual_name(uniq), "pool::only_here");
        // Unknown stays unresolved.
        assert!(m.resolve(0, "nope").is_none());
    }

    #[test]
    fn test_region_fns_are_marked() {
        let m = model_of(
            "fn prod() {}\n\
             #[cfg(test)]\n\
             mod tests {\n\
             \x20   fn helper() {}\n\
             }\n",
        );
        assert!(!m.fns[0].is_test);
        assert!(m.fns[1].is_test);
    }
}
