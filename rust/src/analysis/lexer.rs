//! Token stream over scanner-stripped source — the semantic pipeline's
//! first stage. The [`super::scanner`] already removed comments and
//! blanked string/char-literal contents, so lexing here is a small,
//! deterministic pass: identifiers, numbers, and punctuation (with the
//! few two-character operators the parser cares about kept whole). Each
//! token remembers its source line and whether it sits in the trailing
//! test region, so every downstream rule inherits the scanner's
//! test-code exemption for free.

use super::scanner::SourceFile;

/// One token of stripped code.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    /// 1-based source line the token starts on.
    pub line: usize,
    /// True inside the trailing `#[cfg(test)]` region.
    pub is_test: bool,
}

impl Tok {
    /// Identifier-or-number check (path segments, receiver roots).
    pub fn is_word(&self) -> bool {
        self.text
            .bytes()
            .next()
            .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_')
    }

    /// Identifier check (starts with a letter or `_`, so `0` in a tuple
    /// field access is a word but not an ident).
    pub fn is_ident(&self) -> bool {
        self.text
            .bytes()
            .next()
            .is_some_and(|b| b.is_ascii_alphabetic() || b == b'_')
    }
}

/// Two-character operators kept as single tokens: `::` for paths, `->`
/// so generic-angle matching never miscounts a return arrow, `=>` so
/// match arms cannot read as assignments, `..` so full-range indexing
/// (`[..]`) is one recognizable token.
const DOUBLES: &[&str] = &["::", "->", "=>", ".."];

/// Lex a scanned file's code channel into a flat token stream.
pub fn lex(file: &SourceFile) -> Vec<Tok> {
    let mut out = Vec::new();
    for line in &file.lines {
        let bytes = line.code.as_bytes();
        let mut i = 0usize;
        while i < bytes.len() {
            let b = bytes[i];
            if b.is_ascii_whitespace() {
                i += 1;
                continue;
            }
            if b.is_ascii_alphanumeric() || b == b'_' {
                let start = i;
                while i < bytes.len()
                    && (bytes[i].is_ascii_alphanumeric() || bytes[i] == b'_')
                {
                    i += 1;
                }
                out.push(Tok {
                    text: line.code[start..i].to_string(),
                    line: line.number,
                    is_test: line.is_test,
                });
                continue;
            }
            // Multi-byte UTF-8 punctuation (only reachable through odd
            // doc text the scanner left in code position): skip whole.
            if b >= 0x80 {
                let mut end = i + 1;
                while end < bytes.len() && (bytes[end] & 0xC0) == 0x80 {
                    end += 1;
                }
                i = end;
                continue;
            }
            let two = &line.code[i..(i + 2).min(line.code.len())];
            if DOUBLES.contains(&two) {
                out.push(Tok {
                    text: two.to_string(),
                    line: line.number,
                    is_test: line.is_test,
                });
                i += 2;
                continue;
            }
            out.push(Tok {
                text: (b as char).to_string(),
                line: line.number,
                is_test: line.is_test,
            });
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn texts(src: &str) -> Vec<String> {
        let f = SourceFile::parse("x.rs", src);
        lex(&f).into_iter().map(|t| t.text).collect()
    }

    #[test]
    fn idents_paths_and_doubles() {
        assert_eq!(
            texts("let g = state.ctrl.lock();\n"),
            ["let", "g", "=", "state", ".", "ctrl", ".", "lock", "(", ")",
             ";"]
        );
        assert_eq!(
            texts("fn f() -> Result<()> { pool::run(x) }\n"),
            ["fn", "f", "(", ")", "->", "Result", "<", "(", ")", ">", "{",
             "pool", "::", "run", "(", "x", ")", "}"]
        );
        assert_eq!(texts("&buf[..]\n"), ["&", "buf", "[", "..", "]"]);
        assert_eq!(texts("m => 1,\n"), ["m", "=>", "1", ","]);
    }

    #[test]
    fn unwrap_or_else_is_one_token() {
        // `.unwrap()` matching must never fire inside the house
        // `unwrap_or_else(|e| e.into_inner())` idiom.
        let toks = texts("g.unwrap_or_else(|e| e.into_inner());\n");
        assert!(toks.contains(&"unwrap_or_else".to_string()));
        assert!(!toks.contains(&"unwrap".to_string()));
    }

    #[test]
    fn comments_and_strings_never_tokenize() {
        let toks = texts(
            "let s = \"lock() inside a string\"; // m.lock() in a comment\n",
        );
        assert!(!toks.contains(&"lock".to_string()));
    }

    #[test]
    fn line_numbers_and_test_region() {
        let f = SourceFile::parse(
            "x.rs",
            "fn a() {}\n#[cfg(test)]\nmod tests { fn b() {} }\n",
        );
        let toks = lex(&f);
        let a = toks.iter().find(|t| t.text == "a").unwrap();
        assert_eq!(a.line, 1);
        assert!(!a.is_test);
        let b = toks.iter().find(|t| t.text == "b").unwrap();
        assert_eq!(b.line, 3);
        assert!(b.is_test);
    }
}
