//! Concurrency-protocol rules over the semantic model: static
//! lock-order (deadlock witness), condvar discipline, channel
//! topology, and panic-under-guard. These run only over the watched
//! dirs (coordinator/optim/runtime), on non-test code, and feed the
//! same finding/waiver/report pipeline as the token rules.
//!
//! Heuristic bounds (documented in docs/ANALYSIS.md): guard tracking
//! is intraprocedural (a callee that panics under a caller's guard is
//! out of scope — `make tsan` is the dynamic companion); free calls
//! resolve by bare name (same file first, else a unique cross-file
//! def) while method calls resolve same-file only, so `.lock()` never
//! aliases `pool::lock`; acquisition is a `.lock()`/`.read()`/
//! `.write()` call with *empty* parens (io::Read/Write take buffers,
//! so they never match) or a call to a single-lock wrapper fn whose
//! lock is its own parameter (`pool::lock`). Acquisition sets
//! propagate transitively over the call graph, so an inverted order
//! hidden behind helpers still closes a cycle.

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::Tok;
use super::model::Model;
use super::scanner::SourceFile;
use super::{finding, rules, Finding, Tree};

pub const LOCK_ORDER: &str = "lock-order";
pub const CONDVAR: &str = "condvar-discipline";
pub const CHANNEL: &str = "channel-topology";
pub const LOCK_PANIC: &str = "lock-held-panic";

const ACQUIRES: &[&str] = &["lock", "read", "write"];

/// A live guard binding in the walk.
struct Guard {
    name: String,
    lock: String,
    line: usize,
    depth: usize,
}

/// Witness for one lock-order edge: where the second lock was taken.
struct Witness {
    file: String,
    line: usize,
    via: String,
}

type EdgeMap = BTreeMap<(String, String), Witness>;

/// One reachable `Condvar::wait` site.
struct WaitSite {
    cv: String,
    file: String,
    line: usize,
    in_loop: bool,
    held: bool,
}

/// Crate-wide facts the per-fn walk needs: transitive acquisition
/// sets, wrapper classification, and the condvar registry.
struct Facts {
    trans: Vec<BTreeSet<String>>,
    /// Single-lock wrapper whose lock is its own param (`pool::lock`):
    /// a call both acquires and — under `let` — binds a guard named
    /// after the call's first argument.
    lock_wrapper: Vec<bool>,
    /// Fn with a Condvar param that calls `.wait(` on it
    /// (`pool::wait`): its call sites are condvar wait sites.
    wait_wrapper: Vec<bool>,
    /// (id, file index, decl line) per registered condvar.
    condvars: Vec<(String, usize, usize)>,
    /// Bare condvar names per file index.
    cv_names: BTreeMap<usize, BTreeSet<String>>,
}

/// Run all four concurrency rules, appending findings.
pub fn conc(tree: &Tree, out: &mut Vec<Finding>) {
    let model = Model::build(tree, rules::in_watched);
    let facts = collect_facts(&model);
    let mut edges: EdgeMap = BTreeMap::new();
    let mut waits: Vec<WaitSite> = Vec::new();
    let mut notified: BTreeSet<String> = BTreeSet::new();
    for fi in 0..model.fns.len() {
        if model.fns[fi].is_test || !model.fns[fi].has_body() {
            continue;
        }
        walk_fn(
            tree, &model, &facts, fi, out, &mut edges, &mut waits,
            &mut notified,
        );
        channel_topology(tree, &model, fi, out);
    }
    let mut waited: BTreeSet<String> = BTreeSet::new();
    for w in &waits {
        waited.insert(w.cv.clone());
        let Some(src) = source_of(tree, &w.file) else { continue };
        if !w.in_loop {
            out.push(finding(
                src,
                CONDVAR,
                w.line,
                format!(
                    "Condvar::wait on {} is not wrapped in a predicate \
                     loop — spurious wakeups break the protocol",
                    w.cv
                ),
            ));
        }
        if !w.held {
            out.push(finding(
                src,
                CONDVAR,
                w.line,
                format!(
                    "Condvar::wait on {} reached without its paired \
                     mutex guard held",
                    w.cv
                ),
            ));
        }
    }
    for (id, file_idx, line) in &facts.condvars {
        let path = &model.files[*file_idx].path;
        let Some(src) = source_of(tree, path) else { continue };
        if waited.contains(id) && !notified.contains(id) {
            out.push(finding(
                src,
                CONDVAR,
                *line,
                format!("condvar {id} is waited but never notified"),
            ));
        }
        if notified.contains(id) && !waited.contains(id) {
            out.push(finding(
                src,
                CONDVAR,
                *line,
                format!("condvar {id} is notified but never waited"),
            ));
        }
    }
    report_cycles(tree, &edges, out);
}

fn source_of<'t>(tree: &'t Tree, path: &str) -> Option<&'t SourceFile> {
    tree.sources.iter().find(|s| s.path == path)
}

/// Resolve a call site: free calls use the symbol table (same file,
/// else unique cross-file); method calls (`recv.name(...)`) resolve in
/// the same file only — a method named `lock` must never alias the
/// free `pool::lock`.
fn resolve_call(
    model: &Model,
    file_idx: usize,
    toks: &[Tok],
    k: usize,
    name: &str,
) -> Option<usize> {
    let is_method = k >= 1 && toks[k - 1].text == ".";
    if is_method {
        model.files[file_idx]
            .fns
            .iter()
            .copied()
            .find(|&i| model.fns[i].name == name)
    } else {
        model.resolve(file_idx, name)
    }
}

/// Direct acquisitions + resolved callees per fn, then the transitive
/// closure, wrapper classification, and the condvar registry.
fn collect_facts(model: &Model) -> Facts {
    let n = model.fns.len();
    let mut direct: Vec<BTreeSet<String>> = vec![BTreeSet::new(); n];
    let mut callees: Vec<BTreeSet<usize>> = vec![BTreeSet::new(); n];
    for fi in 0..n {
        let f = &model.fns[fi];
        if !f.has_body() {
            continue;
        }
        let toks = &model.files[f.file].toks;
        let stem = &model.files[f.file].stem;
        let skips = model.nested_ranges(fi);
        let mut k = f.body.0;
        while k < f.body.1 {
            if let Some(&(_, e)) = skips.iter().find(|&&(s, _)| s == k)
            {
                k = e.max(k + 1);
                continue;
            }
            if let Some(lock) = acquisition_at(toks, k, stem) {
                direct[fi].insert(lock);
            }
            // `drop(x)` is std's drop, never a local `Drop::drop`.
            if let Some(name) = call_at(toks, k) {
                if name != "drop" {
                    let resolved =
                        resolve_call(model, f.file, toks, k, name);
                    if let Some(ci) = resolved {
                        if ci != fi {
                            callees[fi].insert(ci);
                        }
                    }
                }
            }
            k += 1;
        }
    }
    let mut memo: Vec<Option<BTreeSet<String>>> = vec![None; n];
    for fi in 0..n {
        let mut visiting = BTreeSet::new();
        close_over(fi, &direct, &callees, &mut memo, &mut visiting);
    }
    let trans: Vec<BTreeSet<String>> =
        memo.into_iter().map(|t| t.unwrap_or_default()).collect();
    let mut lock_wrapper = vec![false; n];
    let mut wait_wrapper = vec![false; n];
    for fi in 0..n {
        let f = &model.fns[fi];
        if trans[fi].len() == 1 {
            let last = trans[fi]
                .iter()
                .next()
                .and_then(|l| l.rsplit('.').next())
                .unwrap_or_default();
            lock_wrapper[fi] = f.params.iter().any(|p| p.name == last);
        }
        if f.params.iter().any(|p| p.ty.contains("Condvar")) {
            let toks = &model.files[f.file].toks;
            wait_wrapper[fi] = (f.body.0..f.body.1).any(|k| {
                tok_is(toks, k, ".")
                    && tok_is(toks, k + 1, "wait")
                    && tok_is(toks, k + 2, "(")
            });
        }
    }
    let mut condvars = Vec::new();
    let mut cv_names: BTreeMap<usize, BTreeSet<String>> = BTreeMap::new();
    for (file_idx, fm) in model.files.iter().enumerate() {
        for k in 0..fm.toks.len() {
            if fm.toks[k].is_test {
                continue;
            }
            let name = if fm.toks[k].is_ident()
                && tok_is(&fm.toks, k + 1, ":")
                && tok_is(&fm.toks, k + 2, "Condvar")
            {
                Some(&fm.toks[k].text)
            } else if tok_is(&fm.toks, k, "=")
                && tok_is(&fm.toks, k + 1, "Condvar")
                && tok_is(&fm.toks, k + 2, "::")
                && tok_is(&fm.toks, k + 3, "new")
                && k >= 1
                && fm.toks[k - 1].is_ident()
            {
                Some(&fm.toks[k - 1].text)
            } else {
                None
            };
            let Some(name) = name else { continue };
            let id = format!("{}.{}", fm.stem, name);
            if cv_names.entry(file_idx).or_default().insert(name.clone())
            {
                condvars.push((id, file_idx, fm.toks[k].line));
            }
        }
    }
    Facts { trans, lock_wrapper, wait_wrapper, condvars, cv_names }
}

fn close_over(
    fi: usize,
    direct: &[BTreeSet<String>],
    callees: &[BTreeSet<usize>],
    memo: &mut [Option<BTreeSet<String>>],
    visiting: &mut BTreeSet<usize>,
) -> BTreeSet<String> {
    if let Some(done) = &memo[fi] {
        return done.clone();
    }
    if !visiting.insert(fi) {
        return BTreeSet::new(); // recursion: already accumulating
    }
    let mut set = direct[fi].clone();
    for &ci in &callees[fi] {
        set.extend(close_over(ci, direct, callees, memo, visiting));
    }
    visiting.remove(&fi);
    memo[fi] = Some(set.clone());
    set
}

fn tok_is(toks: &[Tok], k: usize, s: &str) -> bool {
    toks.get(k).is_some_and(|t| t.text == s)
}

/// `.lock()` / `.read()` / `.write()` with empty parens at `k` (the
/// dot): returns the lock id `stem.receiver_last_segment`.
fn acquisition_at(toks: &[Tok], k: usize, stem: &str) -> Option<String> {
    if !tok_is(toks, k, ".") {
        return None;
    }
    let m = toks.get(k + 1)?;
    if !ACQUIRES.contains(&m.text.as_str())
        || !tok_is(toks, k + 2, "(")
        || !tok_is(toks, k + 3, ")")
    {
        return None;
    }
    let recv = if k >= 1 && toks[k - 1].is_word() {
        toks[k - 1].text.as_str()
    } else {
        "_expr"
    };
    Some(format!("{stem}.{recv}"))
}

/// Call site at `k`: an identifier directly followed by `(` (macros
/// have a `!` between, so they never match). Returns the bare name.
fn call_at(toks: &[Tok], k: usize) -> Option<&str> {
    let t = toks.get(k)?;
    if !t.is_ident()
        || is_stmt_keyword(&t.text)
        || !tok_is(toks, k + 1, "(")
    {
        return None;
    }
    Some(&t.text)
}

fn is_stmt_keyword(s: &str) -> bool {
    matches!(
        s,
        "if" | "while"
            | "for"
            | "match"
            | "return"
            | "loop"
            | "let"
            | "else"
            | "move"
            | "in"
            | "as"
            | "ref"
            | "mut"
            | "break"
            | "continue"
    )
}

/// Root identifier of the path ending at token `j` (`self.0.ctrl` →
/// `self`; `sl.pa` → `sl`).
fn path_root_left(toks: &[Tok], j: usize) -> Option<&str> {
    if !toks.get(j).is_some_and(Tok::is_word) {
        return None;
    }
    let mut r = j;
    while r >= 2
        && (toks[r - 1].text == "." || toks[r - 1].text == "::")
        && toks[r - 2].is_word()
    {
        r -= 2;
    }
    Some(&toks[r].text)
}

/// Last path segment of the first call argument, `k` = index of `(`.
fn arg0_last(toks: &[Tok], k: usize) -> Option<String> {
    let mut j = k + 1;
    let mut last = None;
    while let Some(t) = toks.get(j) {
        match t.text.as_str() {
            "&" | "mut" | "." | "::" => {}
            _ if t.is_word() => last = Some(t.text.clone()),
            _ => break,
        }
        j += 1;
    }
    last
}

/// What a `let` initializer binds, classified by its leading tokens.
enum LetKind {
    /// Direct acquisition or lock-wrapper call: a guard.
    Guard(String),
    /// Anything else (incl. `*acq()` deref copies): not a guard; a
    /// same-named earlier guard is shadowed dead.
    Plain,
}

/// Classify the initializer starting at `init` (first token after
/// `=`).
fn classify_init(
    toks: &[Tok],
    init: usize,
    stem: &str,
    model: &Model,
    facts: &Facts,
    file_idx: usize,
) -> LetKind {
    if tok_is(toks, init, "*") {
        return LetKind::Plain;
    }
    let mut j = init;
    while let Some(t) = toks.get(j) {
        match t.text.as_str() {
            "&" | "mut" | "::" => j += 1,
            "." => {
                if let Some(lock) = acquisition_at(toks, j, stem) {
                    return LetKind::Guard(lock);
                }
                j += 1;
            }
            "(" => {
                // Call: a lock-wrapper call binds a guard named after
                // its first argument (`lock(&state.ctrl)` →
                // `pool.ctrl`).
                if j > init {
                    if let Some(name) = call_at(toks, j - 1) {
                        let resolved = resolve_call(
                            model, file_idx, toks, j - 1, name,
                        );
                        if let Some(ci) = resolved {
                            if facts.lock_wrapper[ci] {
                                let seg = arg0_last(toks, j)
                                    .unwrap_or_else(|| "_expr".into());
                                return LetKind::Guard(format!(
                                    "{stem}.{seg}"
                                ));
                            }
                        }
                    }
                }
                return LetKind::Plain;
            }
            _ if t.is_word() => j += 1,
            _ => return LetKind::Plain,
        }
    }
    LetKind::Plain
}

/// Record lock-order edges: every held guard orders before every lock
/// the current expression acquires.
fn add_edges(
    guards: &[Guard],
    acquired: &BTreeSet<String>,
    file: &str,
    line: usize,
    via: &str,
    edges: &mut EdgeMap,
) {
    for g in guards {
        for t in acquired {
            if *t != g.lock {
                edges
                    .entry((g.lock.clone(), t.clone()))
                    .or_insert_with(|| Witness {
                        file: file.to_string(),
                        line,
                        via: via.to_string(),
                    });
            }
        }
    }
}

/// The per-fn guard walk: emits lock-order edges, lock-held-panic
/// findings, condvar wait sites and notify records.
#[allow(clippy::too_many_arguments)]
fn walk_fn(
    tree: &Tree,
    model: &Model,
    facts: &Facts,
    fi: usize,
    out: &mut Vec<Finding>,
    edges: &mut EdgeMap,
    waits: &mut Vec<WaitSite>,
    notified: &mut BTreeSet<String>,
) {
    let f = &model.fns[fi];
    let fm = &model.files[f.file];
    let toks = &fm.toks;
    let stem = &fm.stem;
    let Some(src) = source_of(tree, &fm.path) else { return };
    let empty = BTreeSet::new();
    let cv_set = facts.cv_names.get(&f.file).unwrap_or(&empty);
    let skips = model.nested_ranges(fi);
    let qual = model.qual_name(fi);
    let is_wait_wrapper = facts.wait_wrapper[fi];

    let mut guards: Vec<Guard> = Vec::new();
    let mut depth = 0usize;
    let mut blocks: Vec<&'static str> = Vec::new();
    let mut pending_kind: Option<&'static str> = None;
    // (depth, name, kind, line), applied at the `;` closing the let.
    let mut pending_lets: Vec<(usize, String, LetKind, usize)> =
        Vec::new();
    let mut exempt: BTreeSet<usize> = BTreeSet::new();

    let mut k = f.body.0;
    while k < f.body.1 {
        if let Some(&(_, e)) = skips.iter().find(|&&(s, _)| s == k) {
            k = e.max(k + 1);
            continue;
        }
        let line = toks[k].line;
        match toks[k].text.as_str() {
            "{" => {
                blocks.push(pending_kind.take().unwrap_or("plain"));
                depth += 1;
            }
            "}" => {
                depth = depth.saturating_sub(1);
                blocks.pop();
                guards.retain(|g| g.depth <= depth);
                pending_lets.retain(|p| p.0 <= depth);
            }
            ";" => {
                pending_kind = None;
                let mut rest = Vec::new();
                for p in pending_lets.drain(..) {
                    if p.0 == depth {
                        guards.retain(|g| g.name != p.1);
                        if let LetKind::Guard(lock) = p.2 {
                            guards.push(Guard {
                                name: p.1,
                                lock,
                                line: p.3,
                                depth,
                            });
                        }
                    } else {
                        rest.push(p);
                    }
                }
                pending_lets = rest;
            }
            "while" | "loop" => pending_kind = Some("loop"),
            "if" | "for" | "match" | "else" => {
                pending_kind = Some("plain");
            }
            "let" => {
                let mut i = k + 1;
                if tok_is(toks, i, "mut") {
                    i += 1;
                }
                if toks.get(i).is_some_and(Tok::is_ident)
                    && !is_stmt_keyword(&toks[i].text)
                {
                    let name = toks[i].text.clone();
                    let mut j = i + 1;
                    if tok_is(toks, j, ":") {
                        while j < f.body.1
                            && !tok_is(toks, j, "=")
                            && !tok_is(toks, j, ";")
                        {
                            j += 1;
                        }
                    }
                    if tok_is(toks, j, "=") {
                        let kind = classify_init(
                            toks, j + 1, stem, model, facts, f.file,
                        );
                        pending_lets.push((depth, name, kind, line));
                    }
                }
            }
            "drop" => {
                if tok_is(toks, k + 1, "(")
                    && toks.get(k + 2).is_some_and(Tok::is_ident)
                    && tok_is(toks, k + 3, ")")
                {
                    let name = &toks[k + 2].text;
                    guards.retain(|g| g.name != *name);
                }
            }
            "." => {
                if let Some(lock) = acquisition_at(toks, k, stem) {
                    let mut set = BTreeSet::new();
                    set.insert(lock);
                    add_edges(
                        &guards, &set, &fm.path, line, &qual, edges,
                    );
                    // House idiom: unwrap/expect chained directly onto
                    // the acquisition handles poisoning, not data — it
                    // is exempt from lock-held-panic.
                    if tok_is(toks, k + 4, ".")
                        && (tok_is(toks, k + 5, "unwrap")
                            || tok_is(toks, k + 5, "expect"))
                        && tok_is(toks, k + 6, "(")
                    {
                        exempt.insert(k + 5);
                    }
                } else if (tok_is(toks, k + 1, "notify_one")
                    || tok_is(toks, k + 1, "notify_all"))
                    && tok_is(toks, k + 2, "(")
                    && k >= 1
                    && toks[k - 1].is_word()
                    && cv_set.contains(&toks[k - 1].text)
                {
                    notified
                        .insert(format!("{stem}.{}", toks[k - 1].text));
                } else if tok_is(toks, k + 1, "wait")
                    && tok_is(toks, k + 2, "(")
                    && !is_wait_wrapper
                    && k >= 1
                    && toks[k - 1].is_word()
                    && cv_set.contains(&toks[k - 1].text)
                {
                    waits.push(WaitSite {
                        cv: format!("{stem}.{}", toks[k - 1].text),
                        file: fm.path.clone(),
                        line,
                        in_loop: blocks.contains(&"loop"),
                        held: !guards.is_empty(),
                    });
                } else if (tok_is(toks, k + 1, "unwrap")
                    || tok_is(toks, k + 1, "expect"))
                    && tok_is(toks, k + 2, "(")
                    && !exempt.contains(&(k + 1))
                    && !guards.is_empty()
                {
                    let g = &guards[guards.len() - 1];
                    out.push(finding(
                        src,
                        LOCK_PANIC,
                        line,
                        format!(
                            ".{}() while guard {} ({}, taken line {}) \
                             is live — a panic here poisons the lock",
                            toks[k + 1].text, g.name, g.lock, g.line
                        ),
                    ));
                }
            }
            "panic" | "unreachable" | "todo" | "unimplemented" => {
                if tok_is(toks, k + 1, "!") && !guards.is_empty() {
                    let g = &guards[guards.len() - 1];
                    out.push(finding(
                        src,
                        LOCK_PANIC,
                        line,
                        format!(
                            "{}! while guard {} ({}, taken line {}) \
                             is live — a panic here poisons the lock",
                            toks[k].text, g.name, g.lock, g.line
                        ),
                    ));
                }
            }
            "[" => {
                let full_range = tok_is(toks, k + 1, "..")
                    && tok_is(toks, k + 2, "]");
                if !full_range && k >= 1 {
                    let root = path_root_left(toks, k - 1);
                    let hit = root.and_then(|r| {
                        guards.iter().find(|g| g.name == r)
                    });
                    if let Some(g) = hit {
                        out.push(finding(
                            src,
                            LOCK_PANIC,
                            line,
                            format!(
                                "indexing through guard {} ({}, taken \
                                 line {}) may panic and poison the \
                                 lock — bound the index or use get()",
                                g.name, g.lock, g.line
                            ),
                        ));
                    }
                }
            }
            _ => {
                if let Some(name) = call_at(toks, k) {
                    let resolved =
                        resolve_call(model, f.file, toks, k, name);
                    if let Some(ci) = resolved {
                        if ci != fi {
                            let eff = effective_acquires(
                                toks, k, stem, facts, ci,
                            );
                            add_edges(
                                &guards, &eff, &fm.path, line, &qual,
                                edges,
                            );
                            if facts.wait_wrapper[ci] {
                                let seg = arg0_last(toks, k + 1);
                                let cv = seg
                                    .filter(|s| cv_set.contains(s));
                                if let Some(cv) = cv {
                                    waits.push(WaitSite {
                                        cv: format!("{stem}.{cv}"),
                                        file: fm.path.clone(),
                                        line,
                                        in_loop: blocks
                                            .contains(&"loop"),
                                        held: !guards.is_empty(),
                                    });
                                }
                            }
                        }
                    }
                }
            }
        }
        k += 1;
    }
}

/// Acquisition set a call site contributes: the callee's transitive
/// set, with a lock-wrapper's single param-lock renamed to the actual
/// argument (`lock(&state.ctrl)` acquires `pool.ctrl`, not `pool.m`).
fn effective_acquires(
    toks: &[Tok],
    k: usize,
    stem: &str,
    facts: &Facts,
    ci: usize,
) -> BTreeSet<String> {
    if facts.lock_wrapper[ci] {
        if let Some(seg) = arg0_last(toks, k + 1) {
            let mut set = BTreeSet::new();
            set.insert(format!("{stem}.{seg}"));
            return set;
        }
    }
    facts.trans[ci].clone()
}

/// Channel-topology rule, per fn: (a) both endpoints of a
/// `let (tx, rx) = …channel…()` destructure must be used after
/// creation; (b) a fn that `recv`s work buffers and participates in a
/// `ret_*` recycle ring must send a buffer back on it (the PR 9
/// alloc-free invariant).
fn channel_topology(
    tree: &Tree,
    model: &Model,
    fi: usize,
    out: &mut Vec<Finding>,
) {
    let f = &model.fns[fi];
    let fm = &model.files[f.file];
    let toks = &fm.toks;
    let Some(src) = source_of(tree, &fm.path) else { return };
    // (a) endpoint liveness.
    let mut k = f.body.0;
    while k < f.body.1 {
        if tok_is(toks, k, "let") && tok_is(toks, k + 1, "(") {
            let mut names = Vec::new();
            let mut j = k + 2;
            let mut pdepth = 1usize;
            while j < f.body.1 && pdepth > 0 {
                match toks[j].text.as_str() {
                    "(" => pdepth += 1,
                    ")" => pdepth -= 1,
                    _ if pdepth == 1 && toks[j].is_ident() => {
                        names
                            .push((toks[j].text.clone(), toks[j].line));
                    }
                    _ => {}
                }
                j += 1;
            }
            // Initializer runs to the `;` at this statement's brace
            // depth; track braces so closure bodies don't end it.
            let mut bdepth = 0usize;
            let mut is_channel = false;
            while j < f.body.1 {
                match toks[j].text.as_str() {
                    "{" => bdepth += 1,
                    "}" => bdepth = bdepth.saturating_sub(1),
                    ";" if bdepth == 0 => break,
                    "channel" | "sync_channel" => is_channel = true,
                    _ => {}
                }
                j += 1;
            }
            if is_channel && names.len() == 2 {
                for (name, line) in &names {
                    let used = toks[j..f.body.1]
                        .iter()
                        .any(|t| t.text == *name);
                    if !used {
                        out.push(finding(
                            src,
                            CHANNEL,
                            *line,
                            format!(
                                "channel endpoint {name} is never \
                                 used after creation — every send \
                                 needs a live receive path",
                            ),
                        ));
                    }
                }
            }
            k = j;
            continue;
        }
        k += 1;
    }
    // (b) ring return.
    let mut nonret_recv_line = None;
    let mut mentions_ret = false;
    let mut ring_returned = false;
    let mut stmt_has_ret = false;
    let mut stmt_has_send = false;
    for k in f.body.0..f.body.1 {
        let t = &toks[k];
        match t.text.as_str() {
            ";" | "{" | "}" => {
                if stmt_has_ret && stmt_has_send {
                    ring_returned = true;
                }
                stmt_has_ret = false;
                stmt_has_send = false;
            }
            "." => {
                if (tok_is(toks, k + 1, "recv")
                    || tok_is(toks, k + 1, "try_recv"))
                    && tok_is(toks, k + 2, "(")
                    && k >= 1
                    && toks[k - 1].is_word()
                    && !toks[k - 1].text.starts_with("ret_")
                    && nonret_recv_line.is_none()
                {
                    nonret_recv_line = Some(t.line);
                }
                if tok_is(toks, k + 1, "send")
                    && tok_is(toks, k + 2, "(")
                {
                    stmt_has_send = true;
                }
            }
            _ if t.text.starts_with("ret_") => {
                mentions_ret = true;
                stmt_has_ret = true;
            }
            _ => {}
        }
    }
    if stmt_has_ret && stmt_has_send {
        ring_returned = true;
    }
    if let Some(line) = nonret_recv_line {
        if mentions_ret && !ring_returned {
            out.push(finding(
                src,
                CHANNEL,
                line,
                format!(
                    "{} recv()s recycled buffers but never sends one \
                     back on a ret_* endpoint — the ring leaks and \
                     the steady state re-allocates",
                    model.qual_name(fi)
                ),
            ));
        }
    }
}

/// Cycle detection over the global lock-order graph; each cycle is one
/// finding with every conflicting acquisition path named.
fn report_cycles(tree: &Tree, edges: &EdgeMap, out: &mut Vec<Finding>) {
    let mut nodes: Vec<String> = Vec::new();
    for (a, b) in edges.keys() {
        if !nodes.contains(a) {
            nodes.push(a.clone());
        }
        if !nodes.contains(b) {
            nodes.push(b.clone());
        }
    }
    nodes.sort();
    let mut adj: Vec<Vec<usize>> = vec![Vec::new(); nodes.len()];
    for (a, b) in edges.keys() {
        let i = nodes.iter().position(|n| n == a);
        let j = nodes.iter().position(|n| n == b);
        if let (Some(i), Some(j)) = (i, j) {
            adj[i].push(j);
        }
    }
    let mut state = vec![0u8; nodes.len()];
    let mut stack = Vec::new();
    let mut cycles: Vec<Vec<usize>> = Vec::new();
    for v in 0..nodes.len() {
        if state[v] == 0 {
            dfs(v, &adj, &mut state, &mut stack, &mut cycles);
        }
    }
    let mut seen: BTreeSet<Vec<usize>> = BTreeSet::new();
    for cyc in cycles {
        let mut key = cyc.clone();
        key.sort_unstable();
        if !seen.insert(key) {
            continue;
        }
        let mut parts = Vec::new();
        let mut anchor: Option<&Witness> = None;
        for i in 0..cyc.len() {
            let from = &nodes[cyc[i]];
            let to = &nodes[cyc[(i + 1) % cyc.len()]];
            if let Some(w) = edges.get(&(from.clone(), to.clone())) {
                parts.push(format!(
                    "{from} -> {to} (acquired at {}:{} in {})",
                    w.file, w.line, w.via
                ));
                if anchor.is_none() {
                    anchor = Some(w);
                }
            }
        }
        let Some(w) = anchor else { continue };
        let Some(src) = source_of(tree, &w.file) else { continue };
        out.push(finding(
            src,
            LOCK_ORDER,
            w.line,
            format!(
                "lock-order cycle — a static deadlock witness: {}",
                parts.join(" vs ")
            ),
        ));
    }
}

fn dfs(
    v: usize,
    adj: &[Vec<usize>],
    state: &mut [u8],
    stack: &mut Vec<usize>,
    cycles: &mut Vec<Vec<usize>>,
) {
    state[v] = 1;
    stack.push(v);
    for &w in &adj[v] {
        if state[w] == 0 {
            dfs(w, adj, state, stack, cycles);
        } else if state[w] == 1 {
            if let Some(pos) = stack.iter().position(|&x| x == w) {
                cycles.push(stack[pos..].to_vec());
            }
        }
    }
    stack.pop();
    state[v] = 2;
}

#[cfg(test)]
mod tests {
    use super::*;

    const W: &str = "rust/src/optim/fix.rs";

    fn run_conc(src: &str) -> Vec<Finding> {
        let tree = Tree {
            sources: vec![SourceFile::parse(W, src)],
            ..Tree::default()
        };
        let mut out = Vec::new();
        conc(&tree, &mut out);
        out
    }

    fn count(out: &[Finding], rule: &str) -> usize {
        out.iter().filter(|f| f.rule == rule).count()
    }

    #[test]
    fn direct_lock_inversion_is_a_cycle_with_both_paths() {
        let out = run_conc(
            "struct S { a: Mutex<u64>, b: Mutex<u64> }\n\
             fn fwd(s: &S) {\n\
             let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());\n\
             let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());\n\
             drop(gb);\n\
             drop(ga);\n\
             }\n\
             fn rev(s: &S) {\n\
             let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());\n\
             let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());\n\
             drop(ga);\n\
             drop(gb);\n\
             }\n",
        );
        assert_eq!(count(&out, LOCK_ORDER), 1, "{out:?}");
        let f = out.iter().find(|f| f.rule == LOCK_ORDER).unwrap();
        assert!(f.message.contains("fix.a -> fix.b"), "{}", f.message);
        assert!(f.message.contains("fix.b -> fix.a"), "{}", f.message);
        assert!(f.message.contains("fix::"), "{}", f.message);
    }

    #[test]
    fn inversion_hidden_behind_helpers_is_caught() {
        // fwd/rev bind their first guard through the wrapper, then the
        // second acquisition happens one call deep: the cycle is only
        // visible interprocedurally.
        let out = run_conc(
            "fn lk<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {\n\
             m.lock().unwrap_or_else(|e| e.into_inner())\n\
             }\n\
             fn take_a(s: &S) {\n\
             let ga = s.a.lock().unwrap_or_else(|e| e.into_inner());\n\
             drop(ga);\n\
             }\n\
             fn take_b(s: &S) {\n\
             let gb = s.b.lock().unwrap_or_else(|e| e.into_inner());\n\
             drop(gb);\n\
             }\n\
             fn fwd(s: &S) {\n\
             let ga = lk(&s.a);\n\
             take_b(s);\n\
             drop(ga);\n\
             }\n\
             fn rev(s: &S) {\n\
             let gb = lk(&s.b);\n\
             take_a(s);\n\
             drop(gb);\n\
             }\n",
        );
        assert_eq!(count(&out, LOCK_ORDER), 1, "{out:?}");
        let f = out.iter().find(|f| f.rule == LOCK_ORDER).unwrap();
        assert!(f.message.contains("fix.a -> fix.b"), "{}", f.message);
        assert!(f.message.contains("fix.b -> fix.a"), "{}", f.message);
    }

    #[test]
    fn crew_barrier_protocol_is_clean() {
        // Distilled from optim/pool.rs: wrapper-bound guards, condvar
        // waits in predicate loops under the guard, notifies on both
        // condvars, drop-based release. Must produce zero findings.
        let out = run_conc(
            "struct CrewState { ctrl: Mutex<Ctrl>, go: Condvar, \
             done: Condvar }\n\
             fn lock<'a, T>(m: &'a Mutex<T>) -> MutexGuard<'a, T> {\n\
             m.lock().unwrap_or_else(|e| e.into_inner())\n\
             }\n\
             fn wait<'a, T>(cv: &Condvar, g: MutexGuard<'a, T>) \
             -> MutexGuard<'a, T> {\n\
             cv.wait(g).unwrap_or_else(|e| e.into_inner())\n\
             }\n\
             fn worker_loop(state: &CrewState) {\n\
             let mut seen = 0u64;\n\
             loop {\n\
             let mut ctrl = lock(&state.ctrl);\n\
             while !ctrl.shutdown && ctrl.generation == seen {\n\
             ctrl = wait(&state.go, ctrl);\n\
             }\n\
             if ctrl.shutdown {\n\
             return;\n\
             }\n\
             seen = ctrl.generation;\n\
             drop(ctrl);\n\
             let mut ctrl = lock(&state.ctrl);\n\
             ctrl.completed += 1;\n\
             state.done.notify_all();\n\
             }\n\
             }\n\
             fn round(state: &CrewState, n: usize) {\n\
             let mut ctrl = lock(&state.ctrl);\n\
             ctrl.generation += 1;\n\
             state.go.notify_all();\n\
             while ctrl.completed < n {\n\
             ctrl = wait(&state.done, ctrl);\n\
             }\n\
             drop(ctrl);\n\
             }\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn condvar_wait_needs_loop_and_notify() {
        let out = run_conc(
            "struct S2 { m: Mutex<u64>, cv: Condvar }\n\
             fn bad_wait(s: &S2) {\n\
             let g = s.m.lock().unwrap_or_else(|e| e.into_inner());\n\
             let g2 = s.cv.wait(g).unwrap_or_else(|e| e.into_inner());\n\
             drop(g2);\n\
             }\n",
        );
        assert_eq!(count(&out, CONDVAR), 2, "{out:?}");
        assert!(out.iter().any(|f| f.message.contains("predicate loop")));
        assert!(out.iter().any(|f| f.message.contains("never notified")));
    }

    #[test]
    fn condvar_wait_without_its_mutex_is_flagged() {
        let out = run_conc(
            "struct S2 { m: Mutex<u64>, cv: Condvar }\n\
             fn naked(s: &S2) {\n\
             loop {\n\
             let q = s.cv.wait(guard_from(s)).unwrap_or_else(|e| \
             e.into_inner());\n\
             drop(q);\n\
             s.cv.notify_one();\n\
             }\n\
             }\n",
        );
        assert_eq!(count(&out, CONDVAR), 1, "{out:?}");
        assert!(out[0].message.contains("without its paired mutex"));
    }

    #[test]
    fn orphaned_channel_endpoint_is_flagged() {
        let out = run_conc(
            "fn orphan() {\n\
             let (tx, rx) = std::sync::mpsc::channel::<u32>();\n\
             let _ = tx.send(1);\n\
             }\n",
        );
        assert_eq!(count(&out, CHANNEL), 1, "{out:?}");
        assert!(out[0].message.contains("rx"), "{out:?}");
    }

    #[test]
    fn recycled_ring_buffers_must_be_returned() {
        let leak = run_conc(
            "fn pump(rx: &Receiver<Vec<u8>>, ret_tx: &Sender<Vec<u8>>) {\n\
             while let Ok(buf) = rx.recv() {\n\
             consume(&buf);\n\
             }\n\
             drop(ret_tx);\n\
             }\n",
        );
        assert_eq!(count(&leak, CHANNEL), 1, "{leak:?}");
        assert!(leak[0].message.contains("ret_*"), "{leak:?}");
        let ok = run_conc(
            "fn pump(rx: &Receiver<Vec<u8>>, ret_tx: &Sender<Vec<u8>>) {\n\
             while let Ok(buf) = rx.recv() {\n\
             let _ = ret_tx.send(buf);\n\
             }\n\
             }\n",
        );
        assert!(ok.is_empty(), "{ok:?}");
    }

    #[test]
    fn panic_tokens_under_a_live_guard_are_flagged() {
        // The unwraps chained directly onto the two acquisitions are
        // the house poison idiom and exempt; the third unwrap and the
        // indexing through guard `g` are real violations.
        let out = run_conc(
            "fn risky(s: &S) {\n\
             let g = s.a.lock().unwrap();\n\
             let h = s.b.lock().unwrap();\n\
             let v = parse_it().unwrap();\n\
             g.buf[v] = 0;\n\
             drop(h);\n\
             drop(g);\n\
             }\n",
        );
        assert_eq!(count(&out, LOCK_PANIC), 2, "{out:?}");
        assert!(out.iter().any(|f| f.message.contains(".unwrap()")));
        assert!(out.iter().any(|f| f.message.contains("indexing")));
    }

    #[test]
    fn shadowing_and_drop_end_guard_liveness() {
        // `let g = &g[..]` is the flat.rs session idiom: the rebind
        // kills the guard, so later panic tokens are clean, and the
        // full-range `[..]` on the guard itself is exempt.
        let out = run_conc(
            "fn shadowed(s: &S) {\n\
             let g = s.a.lock().unwrap_or_else(|e| e.into_inner());\n\
             let g = &g[..];\n\
             let v = other().unwrap();\n\
             let n = g[0];\n\
             }\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }

    #[test]
    fn copy_returning_accessor_is_not_a_phantom_guard() {
        // read_scale has a singleton acquisition set but the lock is
        // not its own parameter, so callers do not bind phantom guards.
        let out = run_conc(
            "fn read_scale(sync: &SyncState) -> f32 {\n\
             *sync.scale.read().unwrap_or_else(|e| e.into_inner())\n\
             }\n\
             fn caller(sync: &SyncState) {\n\
             let scale = read_scale(sync);\n\
             let v = thing().unwrap();\n\
             }\n",
        );
        assert!(out.is_empty(), "{out:?}");
    }
}
